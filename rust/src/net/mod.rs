//! Network substrate: a discrete-event latency simulator and an
//! in-process message fabric.
//!
//! The paper's latency analysis (§5.3) models message send times as
//! log-normal random variables and compares tree all-reduce against
//! NoLoCo's pair averaging analytically and by simulation. Two tools live
//! here:
//!
//! * [`SimClock`] / [`LatencyModel`] — a deterministic discrete-event
//!   simulator over *virtual* time. Collectives are expressed as event
//!   DAGs; we measure completion times without sleeping. Regenerates
//!   Fig. 5A/5B exactly as the paper computes them.
//! * [`Fabric`] — a real in-process message network: one endpoint per
//!   worker thread, typed tensor messages over `std::sync::mpsc`
//!   channels, with optional injected latency and fault injection for
//!   tests. The distributed training driver ([`crate::train`]) runs on
//!   this.
//! * [`topo`] — heterogeneous WAN topologies (regions, per-link latency
//!   *and bandwidth*, stragglers) plus elastic membership
//!   ([`ChurnSchedule`] / [`Membership`]); [`SimClock::with_topology`]
//!   makes the cost models link- and payload-aware, and the trainers use
//!   the churn machinery for elastic NoLoCo runs.
//! * [`socket`] — real TCP transport: the same tag-matched [`Channel`]
//!   discipline as the fabric, over a length-prefixed, CRC32-framed,
//!   version-negotiated wire schema with a seed-node join protocol, so
//!   N OS processes train together instead of N threads.

mod fabric;
mod simclock;
pub mod socket;
pub mod topo;

pub use fabric::{payload_crc, Channel, Endpoint, Fabric, FaultPlan, Message, Payload, Tag};
pub use simclock::{erf, LatencyModel, SimClock};
pub use socket::{Frame, FrameReader, PeerNet, SocketEndpoint, WIRE_VERSION};
pub use topo::{ChurnEvent, ChurnSchedule, FailureDetector, Link, Membership, Topology};
