//! Heterogeneous network topology + elastic membership.
//!
//! The paper's headline systems claim (§5.3, Fig. 5) is that NoLoCo's
//! gossip-pair synchronization stays fast on *low-bandwidth, heterogeneous,
//! internet-scale* clusters where an all-reduce stalls on the slowest link
//! or member. The plain [`SimClock`](crate::net::SimClock) models one
//! homogeneous latency distribution for every link and a fixed worker set;
//! this module supplies what that misses:
//!
//! * [`Link`] — a latency model **plus bandwidth**, so a transfer costs
//!   `latency + bytes / bandwidth` instead of a size-blind draw.
//! * [`Topology`] — nodes grouped into regions with per-region-pair links
//!   and per-node straggler multipliers. Three presets mirror the config
//!   presets: [`Topology::single_switch`] (LAN), [`Topology::multi_region`]
//!   (WAN), [`Topology::long_tail`] (internet with stragglers).
//! * [`ChurnEvent`] / [`ChurnSchedule`] — deterministic node leave/join
//!   events at given (virtual) steps, and [`Membership`] — the live-set
//!   tracker the trainers and route planner consult.
//!
//! [`SimClock::with_topology`](crate::net::SimClock::with_topology) routes
//! its message costs through a `Topology`, which makes the
//! [`crate::collective::cost`] models topology- and payload-aware; the
//! trainers ([`crate::train`]) consume `ChurnSchedule` to run elastic
//! NoLoCo while the all-reduce baselines must abort — the measurable form
//! of the paper's no-global-barrier advantage.
//!
//! Determinism: all randomness flows through the caller-provided
//! [`Pcg64`]; two walks of the same schedule with the same seed produce
//! identical transfer times and membership histories.
//!
//! ## Typical use
//!
//! Configs build topologies through
//! [`NetTopoConfig::build`](crate::config::NetTopoConfig::build)
//! (`--topo lan|wan|long-tail` on the CLI); cost models then query
//! [`Topology::transfer_time`] (sampled) or
//! [`Topology::expected_transfer`] (analytic) per message. The pairing
//! policy [`BandwidthAwarePairing`](crate::train::BandwidthAwarePairing)
//! reads [`Topology::region_of`] to bias NoLoCo's gossip pairs toward
//! cheap intra-region links.
//!
//! ## Churn semantics
//!
//! A [`ChurnSchedule`] is a sorted list of `(step, leave/join)` events
//! over *DP columns* (one event drops or restores a replica across all
//! pipeline stages). It is part of the shared config, so every worker
//! derives the same per-step live mask ([`ChurnSchedule::live_at`])
//! without any control traffic — churn here is *scheduled*, standing in
//! for a failure detector (see ROADMAP). [`Membership`] is the
//! incremental tracker for code that walks events in order. Trainers
//! react through their strategy's
//! [`ChurnResponse`](crate::train::ChurnResponse): gossip repairs,
//! collectives abort, and streamed in-flight fragments that span a
//! membership change are dropped rather than folded.

use crate::net::LatencyModel;
use crate::rngx::Pcg64;

/// One (directionless) link class: a latency distribution plus a
/// bandwidth. Transfer time of a `b`-byte message is one latency draw
/// plus the serialization term `b / bandwidth`.
#[derive(Clone, Debug)]
pub struct Link {
    /// Per-message latency model (the paper's log-normal, or constant).
    pub latency: LatencyModel,
    /// Bytes per second; `f64::INFINITY` for a latency-only link.
    pub bandwidth: f64,
}

impl Link {
    /// Link with the given latency model and bandwidth (bytes/s).
    pub fn new(latency: LatencyModel, bandwidth: f64) -> Link {
        assert!(bandwidth > 0.0, "bandwidth must be positive");
        Link { latency, bandwidth }
    }

    /// Constant-latency, infinite-bandwidth link (the degenerate case in
    /// which the payload-aware cost models reduce to the seed's
    /// size-blind ones).
    pub fn constant(latency_secs: f64) -> Link {
        Link { latency: LatencyModel::Constant(latency_secs), bandwidth: f64::INFINITY }
    }

    /// Sample the transfer time of `bytes` over this link.
    pub fn sample_transfer(&self, bytes: u64, rng: &mut Pcg64) -> f64 {
        self.latency.sample(rng) + bytes as f64 / self.bandwidth
    }

    /// Analytic expected transfer time of `bytes`.
    pub fn expected_transfer(&self, bytes: u64) -> f64 {
        self.latency.expected() + bytes as f64 / self.bandwidth
    }
}

/// Regions, per-region-pair links, and per-node straggler multipliers.
///
/// `links[a][b]` is the link class between region `a` and region `b`
/// (diagonal entries are the intra-region links); the matrix is stored in
/// full but constructed symmetric. A node's straggler multiplier scales
/// every transfer it participates in (`max` of the two endpoints'
/// multipliers), modelling a slow NIC / oversubscribed uplink rather than
/// slow compute.
#[derive(Clone, Debug)]
pub struct Topology {
    region_names: Vec<String>,
    node_region: Vec<usize>,
    links: Vec<Vec<Link>>,
    straggler: Vec<f64>,
}

impl Topology {
    /// Build from explicit region names, per-node region assignment, and
    /// a `regions × regions` link matrix.
    pub fn new(
        region_names: Vec<String>,
        node_region: Vec<usize>,
        links: Vec<Vec<Link>>,
    ) -> Topology {
        let nr = region_names.len();
        assert!(nr > 0, "topology needs at least one region");
        assert_eq!(links.len(), nr, "link matrix rows != regions");
        for row in &links {
            assert_eq!(row.len(), nr, "link matrix is not square");
        }
        for &r in &node_region {
            assert!(r < nr, "node assigned to unknown region {r}");
        }
        let n = node_region.len();
        Topology { region_names, node_region, links, straggler: vec![1.0; n] }
    }

    /// Single-switch LAN preset: one region, every pair shares `link`.
    pub fn single_switch(n: usize, link: Link) -> Topology {
        Topology::new(vec!["lan".into()], vec![0; n], vec![vec![link]])
    }

    /// Multi-region WAN preset: `sizes[i]` nodes in region `i`, fast
    /// `intra` links inside a region and slow `inter` links between
    /// regions. Regions are named `r0`, `r1`, ….
    pub fn multi_region(sizes: &[usize], intra: Link, inter: Link) -> Topology {
        let nr = sizes.len();
        assert!(nr > 0, "multi_region needs at least one region");
        let names = (0..nr).map(|i| format!("r{i}")).collect();
        let mut node_region = Vec::new();
        for (i, &s) in sizes.iter().enumerate() {
            node_region.extend(std::iter::repeat(i).take(s));
        }
        let links: Vec<Vec<Link>> = (0..nr)
            .map(|a| {
                (0..nr)
                    .map(|b| if a == b { intra.clone() } else { inter.clone() })
                    .collect()
            })
            .collect();
        Topology::new(names, node_region, links)
    }

    /// Hierarchical datacenter preset: `pods * racks_per_pod` racks, each
    /// holding an equal share of the `n` nodes (remainder spread over the
    /// first racks). Two nodes in the same rack talk over `rack`, two
    /// racks in the same pod over `pod`, and anything crossing pods over
    /// `spine` — the classic three-tier fabric where each deeper tier is
    /// slower and narrower. Racks are the topology's regions, named
    /// `p{pod}.r{rack}`.
    pub fn hierarchical(
        n: usize,
        pods: usize,
        racks_per_pod: usize,
        rack: Link,
        pod: Link,
        spine: Link,
    ) -> Topology {
        assert!(pods > 0 && racks_per_pod > 0, "hierarchical needs pods and racks");
        let nracks = pods * racks_per_pod;
        let names: Vec<String> = (0..nracks)
            .map(|r| format!("p{}.r{}", r / racks_per_pod, r % racks_per_pod))
            .collect();
        let base = n / nracks;
        let rem = n % nracks;
        let mut node_region = Vec::with_capacity(n);
        for r in 0..nracks {
            let size = base + usize::from(r < rem);
            node_region.extend(std::iter::repeat(r).take(size));
        }
        let links: Vec<Vec<Link>> = (0..nracks)
            .map(|a| {
                (0..nracks)
                    .map(|b| {
                        if a == b {
                            rack.clone()
                        } else if a / racks_per_pod == b / racks_per_pod {
                            pod.clone()
                        } else {
                            spine.clone()
                        }
                    })
                    .collect()
            })
            .collect();
        Topology::new(names, node_region, links)
    }

    /// Long-tail internet preset: one region with log-normal link latency
    /// `LogNormal(mu, sigma²)` at `bandwidth` bytes/s, plus deterministic
    /// per-node straggler multipliers drawn log-normally from `seed`
    /// (median 1, spread `straggler_sigma`) — a crude but effective model
    /// of consumer uplinks.
    pub fn long_tail(
        n: usize,
        mu: f64,
        sigma: f64,
        bandwidth: f64,
        straggler_sigma: f64,
        seed: u64,
    ) -> Topology {
        let link = Link::new(LatencyModel::LogNormal { mu, sigma }, bandwidth);
        let mut t = Topology::new(vec!["internet".into()], vec![0; n], vec![vec![link]]);
        let mut rng = Pcg64::seed_from_u64(seed ^ 0x10_7a11);
        for s in t.straggler.iter_mut() {
            *s = rng.log_normal(0.0, straggler_sigma).max(1.0);
        }
        t
    }

    /// Node count.
    pub fn world(&self) -> usize {
        self.node_region.len()
    }

    /// Region count.
    pub fn regions(&self) -> usize {
        self.region_names.len()
    }

    /// Region index of a node.
    pub fn region_of(&self, node: usize) -> usize {
        self.node_region[node]
    }

    /// Region name by index.
    pub fn region_name(&self, region: usize) -> &str {
        &self.region_names[region]
    }

    /// The link class between two nodes.
    pub fn link(&self, a: usize, b: usize) -> &Link {
        &self.links[self.node_region[a]][self.node_region[b]]
    }

    /// Set a node's straggler multiplier (≥ 1 scales its transfers up).
    pub fn set_straggler(&mut self, node: usize, mult: f64) {
        assert!(mult > 0.0, "straggler multiplier must be positive");
        self.straggler[node] = mult;
    }

    /// Builder form of [`Topology::set_straggler`].
    pub fn with_straggler(mut self, node: usize, mult: f64) -> Topology {
        self.set_straggler(node, mult);
        self
    }

    /// A node's straggler multiplier.
    pub fn straggler_of(&self, node: usize) -> f64 {
        self.straggler[node]
    }

    /// Sample the time to move `bytes` from `from` to `to`:
    /// `max(straggler_from, straggler_to) · (latency + bytes/bandwidth)`.
    pub fn transfer_time(&self, from: usize, to: usize, bytes: u64, rng: &mut Pcg64) -> f64 {
        let base = self.link(from, to).sample_transfer(bytes, rng);
        base * self.straggler[from].max(self.straggler[to])
    }

    /// Analytic expected transfer time between two nodes.
    pub fn expected_transfer(&self, from: usize, to: usize, bytes: u64) -> f64 {
        self.link(from, to).expected_transfer(bytes) * self.straggler[from].max(self.straggler[to])
    }
}

/// One membership change, applied at the *start* of its scheduled step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnEvent {
    /// Node (DP replica, in the trainers) drops out.
    Leave(usize),
    /// Node (re)joins the live set.
    Join(usize),
}

impl ChurnEvent {
    /// The node this event concerns.
    pub fn node(&self) -> usize {
        match *self {
            ChurnEvent::Leave(n) | ChurnEvent::Join(n) => n,
        }
    }
}

/// Deterministic membership schedule: `(step, event)` pairs, fired in
/// order at the start of each step. Workers that share the schedule (and
/// the step counter) derive identical live sets with zero coordination
/// traffic — the same shared-seed trick the route planner uses.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChurnSchedule {
    events: Vec<(u64, ChurnEvent)>,
}

impl ChurnSchedule {
    /// Empty schedule (static membership).
    pub fn none() -> ChurnSchedule {
        ChurnSchedule::default()
    }

    /// True when no events are scheduled.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Append an event; keeps the schedule sorted by step (stable for
    /// same-step events, which fire in insertion order).
    pub fn push(&mut self, step: u64, event: ChurnEvent) {
        let at = self.events.partition_point(|&(s, _)| s <= step);
        self.events.insert(at, (step, event));
    }

    /// Builder: node leaves at `step`.
    pub fn leave(mut self, step: u64, node: usize) -> ChurnSchedule {
        self.push(step, ChurnEvent::Leave(node));
        self
    }

    /// Builder: node joins at `step`.
    pub fn join(mut self, step: u64, node: usize) -> ChurnSchedule {
        self.push(step, ChurnEvent::Join(node));
        self
    }

    /// All events, sorted by step.
    pub fn events(&self) -> &[(u64, ChurnEvent)] {
        &self.events
    }

    /// Events firing exactly at `step`.
    pub fn events_at(&self, step: u64) -> impl Iterator<Item = ChurnEvent> + '_ {
        self.events
            .iter()
            .filter(move |&&(s, _)| s == step)
            .map(|&(_, e)| e)
    }

    /// Live mask over `n` nodes after applying every event scheduled at or
    /// before `step` (all nodes start live).
    pub fn live_at(&self, n: usize, step: u64) -> Vec<bool> {
        let mut m = Membership::full(n);
        for &(s, e) in &self.events {
            if s > step {
                break;
            }
            m.apply(e);
        }
        m.into_mask()
    }

    /// Parse one event from the CLI/TOML string form
    /// `"leave:STEP:NODE"` / `"join:STEP:NODE"`, e.g. `"leave:30:1"`.
    pub fn parse_event(s: &str) -> Result<(u64, ChurnEvent), String> {
        let mut it = s.split(':');
        let kind = it.next().unwrap_or("");
        let step: u64 = it
            .next()
            .ok_or_else(|| format!("churn event `{s}` missing step"))?
            .trim()
            .parse()
            .map_err(|_| format!("churn event `{s}`: bad step"))?;
        let node: usize = it
            .next()
            .ok_or_else(|| format!("churn event `{s}` missing node"))?
            .trim()
            .parse()
            .map_err(|_| format!("churn event `{s}`: bad node"))?;
        if it.next().is_some() {
            return Err(format!("churn event `{s}`: trailing fields"));
        }
        match kind.trim() {
            "leave" => Ok((step, ChurnEvent::Leave(node))),
            "join" => Ok((step, ChurnEvent::Join(node))),
            other => Err(format!("churn event kind `{other}` (want leave|join)")),
        }
    }

    /// Parse a `;`-separated list of events (CLI `--churn` form).
    pub fn parse(s: &str) -> Result<ChurnSchedule, String> {
        let mut out = ChurnSchedule::none();
        for part in s.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (step, e) = Self::parse_event(part)?;
            out.push(step, e);
        }
        Ok(out)
    }
}

/// Live-set tracker over a fixed id space `0..n`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Membership {
    live: Vec<bool>,
}

impl Membership {
    /// All `n` nodes live.
    pub fn full(n: usize) -> Membership {
        Membership { live: vec![true; n] }
    }

    /// Id-space size (live or not).
    pub fn world(&self) -> usize {
        self.live.len()
    }

    /// Whether a node is currently live.
    pub fn is_live(&self, node: usize) -> bool {
        self.live[node]
    }

    /// Number of live nodes.
    pub fn live_count(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }

    /// Live node ids, ascending.
    pub fn live_nodes(&self) -> Vec<usize> {
        (0..self.live.len()).filter(|&i| self.live[i]).collect()
    }

    /// Apply one event; returns whether the live set changed (a `Leave`
    /// of a dead node or `Join` of a live node is a no-op).
    pub fn apply(&mut self, event: ChurnEvent) -> bool {
        let node = event.node();
        assert!(node < self.live.len(), "churn event for unknown node {node}");
        let want = matches!(event, ChurnEvent::Join(_));
        let changed = self.live[node] != want;
        self.live[node] = want;
        changed
    }

    /// Consume into the raw mask.
    pub fn into_mask(self) -> Vec<bool> {
        self.live
    }

    /// Borrow the raw mask.
    pub fn mask(&self) -> &[bool] {
        &self.live
    }
}

/// Heartbeat-based failure detector over a fixed id space `0..world`.
///
/// The schedule-driven churn above assumes failures are *announced*; this
/// detector infers them. Every node is expected to announce liveness once
/// per outer boundary (see
/// [`Communicator::send_heartbeat`](crate::train::Communicator::send_heartbeat));
/// the detector records the last boundary each node was heard at
/// ([`FailureDetector::observe`]) and, on [`FailureDetector::tick`],
/// declares a node dead once it has missed `misses` consecutive
/// boundaries — emitting the same [`ChurnEvent`]s a schedule would, so
/// detected failures feed the trainers' existing
/// [`ChurnResponse`](crate::train::ChurnResponse) repair machinery. A
/// dead node whose heartbeats resume is re-announced with a
/// [`ChurnEvent::Join`], reusing the rejoin/adoption logic.
///
/// Unlike the schedule, detection is a *local* judgment: each worker runs
/// its own detector over the heartbeats it received. Workers converge on
/// the same verdict within one boundary of each other because heartbeats
/// are emitted at boundary granularity; the gossip layer's straggler
/// timeout absorbs the transient disagreement.
#[derive(Clone, Debug)]
pub struct FailureDetector {
    misses: u64,
    /// Last boundary a heartbeat was observed from each node. Every node
    /// is granted an implicit boundary-0 heartbeat at construction so a
    /// run's first boundaries don't mass-suspect the world.
    last_seen: Vec<u64>,
    dead: Vec<bool>,
}

impl FailureDetector {
    /// Detector over `world` nodes declaring death after `misses`
    /// consecutive missed boundary heartbeats (`misses >= 1`).
    pub fn new(world: usize, misses: usize) -> FailureDetector {
        assert!(misses >= 1, "misses must be >= 1");
        FailureDetector {
            misses: misses as u64,
            last_seen: vec![0; world],
            dead: vec![false; world],
        }
    }

    /// Record a heartbeat from `node` stamped with `boundary` (stale or
    /// duplicate stamps are absorbed — only the max is kept).
    pub fn observe(&mut self, node: usize, boundary: u64) {
        if boundary > self.last_seen[node] {
            self.last_seen[node] = boundary;
        }
    }

    /// Last boundary `node` was heard at (0 = never).
    pub fn last_seen(&self, node: usize) -> u64 {
        self.last_seen[node]
    }

    /// Snapshot the detector's verdict state for a checkpoint:
    /// `(last_seen, dead)` per node. `misses` is config, not state.
    pub fn export_state(&self) -> (Vec<u64>, Vec<bool>) {
        (self.last_seen.clone(), self.dead.clone())
    }

    /// Restore a snapshot taken by [`FailureDetector::export_state`].
    /// The world size must match the constructed detector.
    pub fn restore_state(&mut self, last_seen: &[u64], dead: &[bool]) {
        assert_eq!(last_seen.len(), self.last_seen.len(), "detector world mismatch");
        assert_eq!(dead.len(), self.dead.len(), "detector world mismatch");
        self.last_seen.copy_from_slice(last_seen);
        self.dead.copy_from_slice(dead);
    }

    /// Whether the detector currently considers `node` dead.
    pub fn is_dead(&self, node: usize) -> bool {
        self.dead[node]
    }

    /// Evaluate all verdicts at `boundary`: a live node silent for
    /// `misses` boundaries (inclusive of this one) turns into a
    /// [`ChurnEvent::Leave`]; a dead node heard again within the same
    /// tolerance turns into a [`ChurnEvent::Join`]. The thresholds are
    /// symmetric (`silent >= misses` dead, `silent < misses` alive) so
    /// a recovered peer whose heartbeats are consistently observed a
    /// boundary late — the threaded executor's healthy skew — is still
    /// re-admitted, and no silence value satisfies both (no flapping).
    /// Events are emitted once per transition, ascending by node id.
    pub fn tick(&mut self, boundary: u64) -> Vec<ChurnEvent> {
        let mut events = Vec::new();
        for node in 0..self.last_seen.len() {
            let silent = boundary.saturating_sub(self.last_seen[node]);
            if !self.dead[node] && silent >= self.misses {
                self.dead[node] = true;
                events.push(ChurnEvent::Leave(node));
            } else if self.dead[node] && silent < self.misses {
                self.dead[node] = false;
                events.push(ChurnEvent::Join(node));
            }
        }
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_transfer_is_latency_plus_serialization() {
        let l = Link::new(LatencyModel::Constant(0.5), 100.0);
        let mut rng = Pcg64::seed_from_u64(0);
        assert_eq!(l.sample_transfer(200, &mut rng), 0.5 + 2.0);
        assert_eq!(l.expected_transfer(200), 2.5);
        // Infinite bandwidth degenerates to pure latency.
        let c = Link::constant(0.25);
        assert_eq!(c.sample_transfer(1 << 30, &mut rng), 0.25);
    }

    #[test]
    fn multi_region_links_are_asymmetric_in_cost() {
        let t = Topology::multi_region(&[2, 2], Link::constant(0.001), Link::constant(0.1));
        assert_eq!(t.world(), 4);
        assert_eq!(t.regions(), 2);
        assert_eq!(t.region_of(0), 0);
        assert_eq!(t.region_of(3), 1);
        let mut rng = Pcg64::seed_from_u64(1);
        // Intra-region cheap, inter-region two orders slower.
        assert_eq!(t.transfer_time(0, 1, 0, &mut rng), 0.001);
        assert_eq!(t.transfer_time(0, 2, 0, &mut rng), 0.1);
        assert_eq!(t.transfer_time(3, 2, 0, &mut rng), 0.001);
    }

    #[test]
    fn straggler_scales_both_directions() {
        let t = Topology::single_switch(3, Link::constant(1.0)).with_straggler(2, 4.0);
        let mut rng = Pcg64::seed_from_u64(2);
        assert_eq!(t.transfer_time(0, 1, 0, &mut rng), 1.0);
        assert_eq!(t.transfer_time(0, 2, 0, &mut rng), 4.0);
        assert_eq!(t.transfer_time(2, 0, 0, &mut rng), 4.0);
        assert_eq!(t.expected_transfer(2, 1, 0), 4.0);
    }

    #[test]
    fn long_tail_is_deterministic_given_seed() {
        let a = Topology::long_tail(16, -3.0, 0.8, 1e6, 0.5, 7);
        let b = Topology::long_tail(16, -3.0, 0.8, 1e6, 0.5, 7);
        for n in 0..16 {
            assert_eq!(a.straggler_of(n), b.straggler_of(n));
            assert!(a.straggler_of(n) >= 1.0);
        }
        // Some spread should exist.
        let stragglers: Vec<f64> = (0..16).map(|n| a.straggler_of(n)).collect();
        assert!(stragglers.iter().any(|&s| s > 1.0));
    }

    #[test]
    fn bandwidth_term_matches_payload() {
        let t = Topology::multi_region(
            &[2, 2],
            Link::new(LatencyModel::Constant(0.0), 1000.0),
            Link::new(LatencyModel::Constant(0.0), 10.0),
        );
        let mut rng = Pcg64::seed_from_u64(3);
        assert_eq!(t.transfer_time(0, 1, 500, &mut rng), 0.5);
        assert_eq!(t.transfer_time(0, 2, 500, &mut rng), 50.0);
    }

    #[test]
    fn churn_schedule_orders_and_masks() {
        let s = ChurnSchedule::none().join(9, 3).leave(3, 1).leave(6, 2);
        let steps: Vec<u64> = s.events().iter().map(|&(st, _)| st).collect();
        assert_eq!(steps, vec![3, 6, 9]);
        assert_eq!(s.live_at(4, 0), vec![true, true, true, true]);
        assert_eq!(s.live_at(4, 3), vec![true, false, true, true]);
        assert_eq!(s.live_at(4, 6), vec![true, false, false, true]);
        // Node 3 was live from the start; Join is a no-op but keeps it live.
        assert_eq!(s.live_at(4, 9), vec![true, false, false, true]);
        assert_eq!(s.events_at(6).collect::<Vec<_>>(), vec![ChurnEvent::Leave(2)]);
    }

    #[test]
    fn membership_apply_reports_changes() {
        let mut m = Membership::full(3);
        assert_eq!(m.live_count(), 3);
        assert!(m.apply(ChurnEvent::Leave(1)));
        assert!(!m.apply(ChurnEvent::Leave(1))); // already dead
        assert_eq!(m.live_nodes(), vec![0, 2]);
        assert!(m.apply(ChurnEvent::Join(1)));
        assert!(m.is_live(1));
        assert_eq!(m.live_count(), 3);
    }

    #[test]
    fn churn_parse_round_trips() {
        let s = ChurnSchedule::parse("leave:30:1; join:45:1 ;leave:50:0").unwrap();
        assert_eq!(
            s.events(),
            &[
                (30, ChurnEvent::Leave(1)),
                (45, ChurnEvent::Join(1)),
                (50, ChurnEvent::Leave(0)),
            ]
        );
        assert!(ChurnSchedule::parse_event("leave:x:1").is_err());
        assert!(ChurnSchedule::parse_event("hop:1:2").is_err());
        assert!(ChurnSchedule::parse_event("leave:1").is_err());
        assert!(ChurnSchedule::parse_event("leave:1:2:3").is_err());
    }

    #[test]
    fn hierarchical_tiers_order_and_cover() {
        let t = Topology::hierarchical(
            10, // 2 pods x 2 racks = 4 racks: sizes 3, 3, 2, 2
            2,
            2,
            Link::constant(0.001),
            Link::constant(0.01),
            Link::constant(0.1),
        );
        assert_eq!(t.world(), 10);
        assert_eq!(t.regions(), 4);
        assert_eq!(t.region_name(0), "p0.r0");
        assert_eq!(t.region_name(3), "p1.r1");
        // Remainder lands on the first racks: 3, 3, 2, 2.
        let counts: Vec<usize> = (0..4)
            .map(|r| (0..10).filter(|&n| t.region_of(n) == r).count())
            .collect();
        assert_eq!(counts, vec![3, 3, 2, 2]);
        let mut rng = Pcg64::seed_from_u64(0);
        // Same rack, same pod, cross pod.
        assert_eq!(t.transfer_time(0, 1, 0, &mut rng), 0.001);
        assert_eq!(t.transfer_time(0, 3, 0, &mut rng), 0.01);
        assert_eq!(t.transfer_time(0, 6, 0, &mut rng), 0.1);
        assert_eq!(t.transfer_time(8, 9, 0, &mut rng), 0.001);
    }

    #[test]
    fn detector_declares_dead_after_misses_and_rejoins_on_resume() {
        let mut d = FailureDetector::new(3, 2);
        // Boundary 1: everyone heartbeats.
        for n in 0..3 {
            d.observe(n, 1);
        }
        assert!(d.tick(1).is_empty());
        // Node 1 goes silent. One missed boundary is not enough...
        d.observe(0, 2);
        d.observe(2, 2);
        assert!(d.tick(2).is_empty());
        // ...two are.
        d.observe(0, 3);
        d.observe(2, 3);
        assert_eq!(d.tick(3), vec![ChurnEvent::Leave(1)]);
        assert!(d.is_dead(1));
        // The verdict is emitted once, not every boundary.
        d.observe(0, 4);
        d.observe(2, 4);
        assert!(d.tick(4).is_empty());
        // Heartbeats resume: one Join, then quiet again.
        for n in 0..3 {
            d.observe(n, 5);
        }
        assert_eq!(d.tick(5), vec![ChurnEvent::Join(1)]);
        assert!(!d.is_dead(1));
        for n in 0..3 {
            d.observe(n, 6);
        }
        assert!(d.tick(6).is_empty());
    }

    #[test]
    fn detector_state_round_trips_through_export() {
        let mut d = FailureDetector::new(3, 2);
        for n in 0..3 {
            d.observe(n, 1);
        }
        d.observe(0, 3);
        d.observe(2, 3);
        d.tick(3); // node 1 declared dead
        let (seen, dead) = d.export_state();
        let mut r = FailureDetector::new(3, 2);
        r.restore_state(&seen, &dead);
        assert!(r.is_dead(1));
        assert_eq!(r.last_seen(0), 3);
        // Restored detector continues identically: node 1 resumes.
        for n in 0..3 {
            d.observe(n, 4);
            r.observe(n, 4);
        }
        assert_eq!(d.tick(4), r.tick(4));
    }

    #[test]
    fn detector_grace_covers_the_run_start() {
        // The implicit boundary-0 heartbeat means nothing is suspected
        // before `misses` real boundaries have elapsed.
        let mut d = FailureDetector::new(2, 3);
        assert!(d.tick(1).is_empty());
        assert!(d.tick(2).is_empty());
        assert_eq!(d.tick(3), vec![ChurnEvent::Leave(0), ChurnEvent::Leave(1)]);
    }

    #[test]
    fn rejoin_after_leave_in_one_schedule() {
        let s = ChurnSchedule::none().leave(2, 0).join(5, 0);
        assert_eq!(s.live_at(2, 1), vec![true, true]);
        assert_eq!(s.live_at(2, 2), vec![false, true]);
        assert_eq!(s.live_at(2, 4), vec![false, true]);
        assert_eq!(s.live_at(2, 5), vec![true, true]);
    }
}
