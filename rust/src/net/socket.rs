//! Real TCP transport: the socket executor's [`Channel`].
//!
//! [`SocketEndpoint`] speaks a length-prefixed, CRC32-framed,
//! version-negotiated wire schema over [`std::net::TcpStream`] and
//! implements the same tag-matched stash discipline as the in-process
//! fabric [`Endpoint`](crate::net::Endpoint) — so
//! [`SocketComm`](crate::train::SocketComm) is the unmodified
//! [`FabricComm`](crate::train::FabricComm) protocol logic running over a
//! different [`Channel`]: the framing layer is a codec under the existing
//! endpoint discipline, not a fork of it.
//!
//! # Wire schema (version 1)
//!
//! Every frame is `len: u32 LE | crc: u32 LE | body`, where `len` is the
//! body length and `crc` is the CRC-32 (IEEE 802.3, reflected — the same
//! polynomial the fabric and the checkpoint format use) of the body. A
//! frame whose CRC does not match is skipped whole and counted (the
//! `net.corrupt_dropped` counter), exactly like the fabric's corrupt
//! fault handling: a corrupt frame behaves as a dropped one and the
//! straggler/staleness fallbacks absorb it.
//!
//! The body is `kind: u8` followed by kind-specific fields:
//!
//! | kind | frame         | fields                                    |
//! |------|---------------|-------------------------------------------|
//! | 1    | `Hello`       | version, rank, listen address             |
//! | 2    | `Welcome`     | version, world, address book              |
//! | 3    | `PeerHello`   | version, rank                             |
//! | 4    | `PeerWelcome` | version, rank                             |
//! | 5    | `Msg`         | from, tag (kind/a/b), payload             |
//! | 6    | `Replay`      | from, tag (kind/a/b), payload (unmetered) |
//!
//! `Msg` carries everything the communicator ships — fragment offers
//! `(round, fragment, Δ_k, φ_k)`, bounded-staleness round offers,
//! heartbeats, boundary activations — distinguished by the *tag* kind,
//! the same `(kind, a, b)` packing `FabricComm` already uses (fragment
//! round/index packed into `a` by `frag_seq`). `Replay` is byte-identical
//! to `Msg` apart from its frame kind: receivers treat both the same,
//! but the distinct kind makes checkpoint-replay traffic visible on the
//! wire (and keeps it out of the logical metering by construction on the
//! sender).
//!
//! # Version negotiation
//!
//! `Hello`/`PeerHello` carry the dialer's `WIRE_VERSION`; the responder
//! answers `Welcome`/`PeerWelcome` with its own. Each side checks the
//! other's version and refuses the connection on mismatch — negotiation
//! is an equality check today, but the field is what lets a future
//! version speak both.
//!
//! # Seed-node join protocol
//!
//! Rank 0 listens on the seed address. Every joiner binds its own
//! listener first, then dials the seed and sends `Hello` with its listen
//! address. Once all `world − 1` joiners have said hello, the seed
//! replies `Welcome` to each with the live-set-complete address book
//! (rank → address, every rank). The seed connection stays open as the
//! rank-0 data connection; all other pairs are dialed *lazily* — the
//! first `send` to an unconnected peer performs a
//! `PeerHello`/`PeerWelcome` handshake (also the RTT probe) and keeps
//! the stream. Two peers dialing each other simultaneously is benign:
//! both connections carry traffic, each side writes on the one it dialed
//! and reads from both.
//!
//! # Metering
//!
//! [`Channel::sent_totals`] meters *logical* wire bytes
//! ([`Payload::wire_bytes`], what the fabric meters) — not framed TCP
//! bytes — so a socket run's `CommStats` are bit-identical to the
//! same-seed threaded run. The actual per-peer frame bytes, frame
//! counts and handshake RTTs are tracked separately and journaled as
//! `net_peer` observability events by the socket executor.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::fabric::{crc32_update, Channel, Message, Payload, Tag};

/// Wire-schema version spoken by this build (negotiated at handshake).
pub const WIRE_VERSION: u16 = 1;

/// Sanity cap on one frame's body (a corrupt length must error, not OOM).
const MAX_FRAME: usize = 1 << 30;

/// How long a joiner keeps retrying the seed dial before giving up.
const JOIN_RETRY: Duration = Duration::from_secs(10);

/// Poison-proof lock (same idiom as the fabric's shared counters).
fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// CRC-32 of a byte slice (the frame check).
fn crc32(bytes: &[u8]) -> u32 {
    !crc32_update(0xffff_ffff, bytes.iter().copied())
}

// ---------------------------------------------------------------------
// Frames and the codec
// ---------------------------------------------------------------------

const K_HELLO: u8 = 1;
const K_WELCOME: u8 = 2;
const K_PEER_HELLO: u8 = 3;
const K_PEER_WELCOME: u8 = 4;
const K_MSG: u8 = 5;
const K_REPLAY: u8 = 6;

/// One wire frame (see the module docs for the schema table).
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Joiner → seed: version, rank, and the joiner's listen address.
    Hello { version: u16, rank: u32, listen: String },
    /// Seed → joiner: version, world size, and the full address book.
    Welcome { version: u16, world: u32, peers: Vec<(u32, String)> },
    /// Lazy-dial handshake: dialer announces itself to a gossip partner.
    PeerHello { version: u16, rank: u32 },
    /// Lazy-dial reply: the accepting side's identity.
    PeerWelcome { version: u16, rank: u32 },
    /// A tagged communicator message (offers, heartbeats, activations).
    Msg { from: u32, tag: Tag, payload: Payload },
    /// A checkpoint-replay message: same layout as `Msg`, distinct kind.
    Replay { from: u32, tag: Tag, payload: Payload },
}

fn put_u16(b: &mut Vec<u8>, x: u16) {
    b.extend_from_slice(&x.to_le_bytes());
}

fn put_u32(b: &mut Vec<u8>, x: u32) {
    b.extend_from_slice(&x.to_le_bytes());
}

fn put_str(b: &mut Vec<u8>, s: &str) {
    put_u16(b, s.len() as u16);
    b.extend_from_slice(s.as_bytes());
}

fn put_payload(b: &mut Vec<u8>, p: &Payload) {
    match p {
        Payload::F32(v) => {
            b.push(0);
            put_u32(b, v.len() as u32);
            for x in v {
                put_u32(b, x.to_bits());
            }
        }
        Payload::U32(v) => {
            b.push(1);
            put_u32(b, v.len() as u32);
            for &x in v {
                put_u32(b, x);
            }
        }
        Payload::Control => b.push(2),
    }
}

fn put_msg(b: &mut Vec<u8>, from: u32, tag: &Tag, payload: &Payload) {
    put_u32(b, from);
    put_u16(b, tag.kind);
    put_u32(b, tag.a);
    put_u32(b, tag.b);
    put_payload(b, payload);
}

impl Frame {
    /// Serialize to a complete wire frame (`len | crc | body`).
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::new();
        match self {
            Frame::Hello { version, rank, listen } => {
                body.push(K_HELLO);
                put_u16(&mut body, *version);
                put_u32(&mut body, *rank);
                put_str(&mut body, listen);
            }
            Frame::Welcome { version, world, peers } => {
                body.push(K_WELCOME);
                put_u16(&mut body, *version);
                put_u32(&mut body, *world);
                put_u32(&mut body, peers.len() as u32);
                for (rank, addr) in peers {
                    put_u32(&mut body, *rank);
                    put_str(&mut body, addr);
                }
            }
            Frame::PeerHello { version, rank } => {
                body.push(K_PEER_HELLO);
                put_u16(&mut body, *version);
                put_u32(&mut body, *rank);
            }
            Frame::PeerWelcome { version, rank } => {
                body.push(K_PEER_WELCOME);
                put_u16(&mut body, *version);
                put_u32(&mut body, *rank);
            }
            Frame::Msg { from, tag, payload } => {
                body.push(K_MSG);
                put_msg(&mut body, *from, tag, payload);
            }
            Frame::Replay { from, tag, payload } => {
                body.push(K_REPLAY);
                put_msg(&mut body, *from, tag, payload);
            }
        }
        let mut out = Vec::with_capacity(body.len() + 8);
        out.extend_from_slice(&(body.len() as u32).to_le_bytes());
        out.extend_from_slice(&crc32(&body).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    /// Deserialize a CRC-verified frame body.
    pub fn decode(body: &[u8]) -> Result<Frame> {
        let mut c = Cur { b: body, i: 0 };
        let frame = match c.u8()? {
            K_HELLO => Frame::Hello {
                version: c.u16()?,
                rank: c.u32()?,
                listen: c.str()?,
            },
            K_WELCOME => {
                let version = c.u16()?;
                let world = c.u32()?;
                let n = c.u32()? as usize;
                ensure!(n <= 1 << 20, "implausible address-book size {n}");
                let mut peers = Vec::with_capacity(n);
                for _ in 0..n {
                    let rank = c.u32()?;
                    let addr = c.str()?;
                    peers.push((rank, addr));
                }
                Frame::Welcome { version, world, peers }
            }
            K_PEER_HELLO => Frame::PeerHello { version: c.u16()?, rank: c.u32()? },
            K_PEER_WELCOME => Frame::PeerWelcome { version: c.u16()?, rank: c.u32()? },
            K_MSG => {
                let (from, tag, payload) = c.msg()?;
                Frame::Msg { from, tag, payload }
            }
            K_REPLAY => {
                let (from, tag, payload) = c.msg()?;
                Frame::Replay { from, tag, payload }
            }
            k => bail!("unknown frame kind {k}"),
        };
        ensure!(c.i == body.len(), "trailing bytes after frame body");
        Ok(frame)
    }
}

/// Bounds-checked little-endian cursor over one frame body.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl Cur<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        ensure!(self.i + n <= self.b.len(), "truncated frame body");
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u16()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).context("non-UTF-8 address string")
    }

    fn payload(&mut self) -> Result<Payload> {
        match self.u8()? {
            0 => {
                let n = self.u32()? as usize;
                ensure!(n < (1 << 28), "implausible payload length {n}");
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    v.push(f32::from_bits(self.u32()?));
                }
                Ok(Payload::F32(v))
            }
            1 => {
                let n = self.u32()? as usize;
                ensure!(n < (1 << 28), "implausible payload length {n}");
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    v.push(self.u32()?);
                }
                Ok(Payload::U32(v))
            }
            2 => Ok(Payload::Control),
            t => bail!("unknown payload type {t}"),
        }
    }

    fn msg(&mut self) -> Result<(u32, Tag, Payload)> {
        let from = self.u32()?;
        let kind = self.u16()?;
        let a = self.u32()?;
        let b = self.u32()?;
        let payload = self.payload()?;
        Ok((from, Tag::new(kind, a, b), payload))
    }
}

/// Incremental frame decoder: feed it byte chunks split at *arbitrary*
/// boundaries (TCP guarantees nothing else) and it yields complete,
/// CRC-verified frames. A frame failing its CRC — or whose body refuses
/// to decode — is skipped by its declared length and counted in
/// `corrupt`; an implausible length tears the stream down (the buffer is
/// cleared), since the length word itself can no longer be trusted.
#[derive(Default)]
pub struct FrameReader {
    buf: Vec<u8>,
    /// Frames discarded on CRC mismatch or decode failure.
    pub corrupt: u64,
}

impl FrameReader {
    /// Fresh decoder with an empty buffer.
    pub fn new() -> FrameReader {
        FrameReader::default()
    }

    /// Append `bytes` and decode every complete frame now available.
    pub fn push(&mut self, bytes: &[u8]) -> Vec<Frame> {
        self.buf.extend_from_slice(bytes);
        let mut out = Vec::new();
        loop {
            if self.buf.len() < 8 {
                break;
            }
            let len =
                u32::from_le_bytes([self.buf[0], self.buf[1], self.buf[2], self.buf[3]]) as usize;
            if len > MAX_FRAME {
                self.corrupt += 1;
                self.buf.clear();
                break;
            }
            if self.buf.len() < 8 + len {
                break;
            }
            let want =
                u32::from_le_bytes([self.buf[4], self.buf[5], self.buf[6], self.buf[7]]);
            let body = &self.buf[8..8 + len];
            if crc32(body) == want {
                match Frame::decode(body) {
                    Ok(f) => out.push(f),
                    Err(_) => self.corrupt += 1,
                }
            } else {
                self.corrupt += 1;
            }
            self.buf.drain(..8 + len);
        }
        out
    }
}

// ---------------------------------------------------------------------
// Connection plumbing
// ---------------------------------------------------------------------

/// Per-peer traffic actually framed onto TCP (not the logical metering):
/// frame bytes written, frames written, and the last handshake RTT.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PeerNet {
    /// Framed bytes written to this peer (headers included).
    pub bytes: u64,
    /// Frames written to this peer.
    pub msgs: u64,
    /// Last handshake round-trip to this peer, in microseconds.
    pub rtt_us: u64,
}

/// State shared between the endpoint, its acceptor and reader threads.
struct SocketShared {
    rank: usize,
    /// Open write streams by peer rank (a lazy dial or an accepted
    /// handshake registers one; `BTreeMap` keeps sweep order seeded, not
    /// hashed — analyze R2).
    writers: Mutex<BTreeMap<usize, TcpStream>>,
    /// Verified inbound messages from every reader thread.
    tx: Sender<Message>,
    /// Frames this rank discarded on CRC mismatch (→ `net.corrupt_dropped`).
    corrupt_dropped: AtomicU64,
    /// Per-peer framed-traffic counters (→ `net_peer` journal events).
    peer_net: Mutex<BTreeMap<usize, PeerNet>>,
}

impl SocketShared {
    /// Register `stream` as the write path to `peer` unless one exists
    /// (simultaneous dials keep the first; the duplicate connection still
    /// delivers whatever its dialer writes on it).
    fn register(&self, peer: usize, stream: &TcpStream) {
        let mut w = locked(&self.writers);
        if let std::collections::btree_map::Entry::Vacant(e) = w.entry(peer) {
            if let Ok(clone) = stream.try_clone() {
                e.insert(clone);
            }
        }
    }

    /// Pump one connection: decode frames, verify, forward messages.
    /// Returns when the peer hangs up.
    fn read_loop(&self, mut stream: TcpStream) {
        let mut reader = FrameReader::new();
        let mut chunk = [0u8; 64 * 1024];
        loop {
            let n = match stream.read(&mut chunk) {
                Ok(0) | Err(_) => break,
                Ok(n) => n,
            };
            for frame in reader.push(&chunk[..n]) {
                match frame {
                    Frame::Msg { from, tag, payload }
                    | Frame::Replay { from, tag, payload } => {
                        let msg = Message::delivered(from as usize, tag, payload);
                        if self.tx.send(msg).is_err() {
                            return; // endpoint retired
                        }
                    }
                    // Handshake frames are consumed before the read loop
                    // starts; one arriving here is a protocol error from
                    // the peer — drop it like a corrupt frame.
                    _ => {
                        self.corrupt_dropped.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            let c = std::mem::take(&mut reader.corrupt);
            if c > 0 {
                self.corrupt_dropped.fetch_add(c, Ordering::Relaxed);
            }
        }
    }
}

/// Read exactly one frame from `stream` (blocking) — the handshake path,
/// before a connection is handed to its reader thread. `read_exact`
/// consumes precisely the frame's bytes, so data frames the peer pipelines
/// right behind its handshake stay in the socket buffer for the reader.
fn read_one_frame(stream: &mut TcpStream) -> Result<Frame> {
    let mut hdr = [0u8; 8];
    stream.read_exact(&mut hdr).context("reading handshake header")?;
    let len = u32::from_le_bytes([hdr[0], hdr[1], hdr[2], hdr[3]]) as usize;
    let want = u32::from_le_bytes([hdr[4], hdr[5], hdr[6], hdr[7]]);
    ensure!(len <= MAX_FRAME, "implausible handshake frame length {len}");
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body).context("reading handshake body")?;
    ensure!(crc32(&body) == want, "corrupt handshake frame");
    Frame::decode(&body)
}

fn write_frame(stream: &mut TcpStream, frame: &Frame) -> Result<()> {
    stream.write_all(&frame.encode()).context("writing frame")?;
    Ok(())
}

/// Both sides of every handshake check the other's version; equality is
/// the whole negotiation today, but the wire carries the field so a
/// future version can speak both.
fn negotiate(theirs: u16) -> Result<()> {
    ensure!(
        theirs == WIRE_VERSION,
        "wire-version mismatch: peer speaks v{theirs}, this build speaks v{WIRE_VERSION}"
    );
    Ok(())
}

/// Accept-side handshake + reader spawn for one inbound connection.
fn serve_conn(shared: &Arc<SocketShared>, mut stream: TcpStream) -> Result<()> {
    match read_one_frame(&mut stream)? {
        Frame::PeerHello { version, rank } => {
            negotiate(version)?;
            write_frame(
                &mut stream,
                &Frame::PeerWelcome { version: WIRE_VERSION, rank: shared.rank as u32 },
            )?;
            shared.register(rank as usize, &stream);
            let sh = shared.clone();
            std::thread::spawn(move || sh.read_loop(stream));
            Ok(())
        }
        other => bail!("expected PeerHello, got {other:?}"),
    }
}

/// Run the accept loop: every inbound connection is a lazy-dial
/// `PeerHello` handshake. Exits when the listener errors (process end).
fn accept_loop(shared: Arc<SocketShared>, listener: TcpListener) {
    for stream in listener.incoming() {
        let Ok(stream) = stream else { break };
        let _ = stream.set_nodelay(true);
        let _ = serve_conn(&shared, stream);
    }
}

/// Dial `addr`, retrying until `deadline` (the peer's listener may not
/// be up yet during the join window).
fn dial_retry(addr: &str, deadline: Instant) -> Result<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => {
                let _ = s.set_nodelay(true);
                return Ok(s);
            }
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e).with_context(|| format!("dialing {addr}"));
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

// ---------------------------------------------------------------------
// The endpoint
// ---------------------------------------------------------------------

/// One process-rank's handle on the TCP world: the socket [`Channel`].
///
/// Construction *is* the join protocol — see [`SocketEndpoint::bootstrap`]
/// and the module docs. After bootstrap the endpoint owns the inbound
/// message channel (reader threads feed it), the stash, and the logical
/// wire counters; peer connections beyond the seed are dialed lazily on
/// first send.
pub struct SocketEndpoint {
    rank: usize,
    world: usize,
    /// Rank → dial address for every peer (the seed's address book).
    peers: BTreeMap<usize, String>,
    shared: Arc<SocketShared>,
    rx: Receiver<Message>,
    stash: Vec<Message>,
    /// Logical wire totals (payload bytes, messages) — the fabric-equal
    /// metering `CommStats` compare against.
    bytes_sent: u64,
    msgs_sent: u64,
}

impl SocketEndpoint {
    /// Join the TCP world: rank 0 listens on `seed_addr` and collects
    /// every joiner's `Hello`; other ranks bind `bind_addr` (use
    /// `"127.0.0.1:0"` for an ephemeral port), dial the seed, and block
    /// until the `Welcome` carries the complete address book. Returns
    /// once this rank can reach every peer (directly or lazily).
    pub fn bootstrap(
        rank: usize,
        world: usize,
        seed_addr: &str,
        bind_addr: &str,
    ) -> Result<SocketEndpoint> {
        ensure!(world >= 1, "socket world must have at least one rank");
        ensure!(rank < world, "rank {rank} out of range for world {world}");
        let (tx, rx) = channel();
        let shared = Arc::new(SocketShared {
            rank,
            writers: Mutex::new(BTreeMap::new()),
            tx,
            corrupt_dropped: AtomicU64::new(0),
            peer_net: Mutex::new(BTreeMap::new()),
        });
        let peers = if rank == 0 {
            Self::bootstrap_seed(&shared, world, seed_addr)?
        } else {
            Self::bootstrap_joiner(&shared, rank, world, seed_addr, bind_addr)?
        };
        Ok(SocketEndpoint {
            rank,
            world,
            peers,
            shared,
            rx,
            stash: Vec::new(),
            bytes_sent: 0,
            msgs_sent: 0,
        })
    }

    /// Seed side of the join: accept `world − 1` `Hello`s, then hand every
    /// joiner the address book and keep each connection as the data path.
    fn bootstrap_seed(
        shared: &Arc<SocketShared>,
        world: usize,
        seed_addr: &str,
    ) -> Result<BTreeMap<usize, String>> {
        let listener = TcpListener::bind(seed_addr)
            .with_context(|| format!("seed rank binding {seed_addr}"))?;
        let seed_local = listener.local_addr()?.to_string();
        let mut joiners: BTreeMap<usize, (String, TcpStream)> = BTreeMap::new();
        while joiners.len() < world - 1 {
            let (mut stream, _) = listener.accept().context("seed accept")?;
            let _ = stream.set_nodelay(true);
            match read_one_frame(&mut stream)? {
                Frame::Hello { version, rank, listen } => {
                    negotiate(version)?;
                    let r = rank as usize;
                    ensure!(r > 0 && r < world, "joiner announced invalid rank {r}");
                    ensure!(!joiners.contains_key(&r), "rank {r} joined twice");
                    joiners.insert(r, (listen, stream));
                }
                other => bail!("expected Hello at the seed, got {other:?}"),
            }
        }
        let mut book: Vec<(u32, String)> = vec![(0, seed_local)];
        for (&r, (addr, _)) in &joiners {
            book.push((r as u32, addr.clone()));
        }
        for (&r, (_, stream)) in &mut joiners {
            write_frame(
                stream,
                &Frame::Welcome {
                    version: WIRE_VERSION,
                    world: world as u32,
                    peers: book.clone(),
                },
            )?;
            shared.register(r, stream);
        }
        for (_, (_, stream)) in joiners {
            let sh = shared.clone();
            std::thread::spawn(move || sh.read_loop(stream));
        }
        let sh = shared.clone();
        std::thread::spawn(move || accept_loop(sh, listener));
        Ok(book
            .into_iter()
            .map(|(r, a)| (r as usize, a))
            .collect())
    }

    /// Joiner side: bind own listener, dial the seed, `Hello` → `Welcome`
    /// (the RTT probe for rank 0), keep the seed connection as data path.
    fn bootstrap_joiner(
        shared: &Arc<SocketShared>,
        rank: usize,
        world: usize,
        seed_addr: &str,
        bind_addr: &str,
    ) -> Result<BTreeMap<usize, String>> {
        let listener = TcpListener::bind(bind_addr)
            .with_context(|| format!("rank {rank} binding {bind_addr}"))?;
        let my_listen = listener.local_addr()?.to_string();
        let sh = shared.clone();
        std::thread::spawn(move || accept_loop(sh, listener));

        let mut stream = dial_retry(seed_addr, Instant::now() + JOIN_RETRY)?;
        let t0 = Instant::now();
        write_frame(
            &mut stream,
            &Frame::Hello { version: WIRE_VERSION, rank: rank as u32, listen: my_listen },
        )?;
        let book = match read_one_frame(&mut stream)? {
            Frame::Welcome { version, world: w, peers } => {
                negotiate(version)?;
                ensure!(
                    w as usize == world,
                    "seed runs a {w}-rank world, this rank was launched for {world}"
                );
                peers
            }
            other => bail!("expected Welcome from the seed, got {other:?}"),
        };
        let rtt = t0.elapsed().as_micros() as u64;
        locked(&shared.peer_net).entry(0).or_default().rtt_us = rtt;
        shared.register(0, &stream);
        let sh = shared.clone();
        std::thread::spawn(move || sh.read_loop(stream));
        Ok(book.into_iter().map(|(r, a)| (r as usize, a)).collect())
    }

    /// This endpoint's rank.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// World size.
    pub fn world(&self) -> usize {
        self.world
    }

    /// Frames this rank discarded on CRC mismatch (the socket analogue of
    /// [`Fabric::corrupt_dropped`](crate::net::Fabric::corrupt_dropped)).
    pub fn corrupt_dropped(&self) -> u64 {
        self.shared.corrupt_dropped.load(Ordering::Relaxed)
    }

    /// Per-peer framed-traffic counters, ascending by peer rank — the
    /// socket executor journals one `net_peer` event per entry.
    pub fn peer_net(&self) -> Vec<(usize, PeerNet)> {
        locked(&self.shared.peer_net)
            .iter()
            .map(|(&r, &n)| (r, n))
            .collect()
    }

    /// Write `frame` to `to`, dialing lazily on the first send (the
    /// `PeerHello`/`PeerWelcome` handshake doubles as the RTT probe).
    fn ship(&mut self, to: usize, frame: &Frame) -> Result<()> {
        let bytes = frame.encode();
        // The writers lock is never held across the dial handshake: two
        // ranks dialing each other simultaneously would each be waiting
        // for the other's acceptor, which needs this lock to register.
        if !locked(&self.shared.writers).contains_key(&to) {
            self.dial(to)?;
        }
        {
            let mut writers = locked(&self.shared.writers);
            let Some(stream) = writers.get_mut(&to) else {
                bail!("no write path to rank {to} after dialing");
            };
            stream.write_all(&bytes).with_context(|| format!("sending to rank {to}"))?;
        }
        let mut pn = locked(&self.shared.peer_net);
        let e = pn.entry(to).or_default();
        e.bytes += bytes.len() as u64;
        e.msgs += 1;
        Ok(())
    }

    /// Dial `to` from the address book, handshake, measure RTT, and
    /// register the connection. If the peer's own simultaneous dial won
    /// the writer slot, this connection still serves: the peer writes on
    /// it and our reader thread (spawned on a clone) keeps it alive.
    fn dial(&self, to: usize) -> Result<()> {
        let addr = self
            .peers
            .get(&to)
            .with_context(|| format!("rank {to} is not in the address book"))?
            .clone();
        let mut stream = dial_retry(&addr, Instant::now() + JOIN_RETRY)?;
        let t0 = Instant::now();
        write_frame(
            &mut stream,
            &Frame::PeerHello { version: WIRE_VERSION, rank: self.rank as u32 },
        )?;
        match read_one_frame(&mut stream)? {
            Frame::PeerWelcome { version, rank } => {
                negotiate(version)?;
                ensure!(
                    rank as usize == to,
                    "dialed rank {to} at {addr} but rank {rank} answered"
                );
            }
            other => bail!("expected PeerWelcome from rank {to}, got {other:?}"),
        }
        let rtt = t0.elapsed().as_micros() as u64;
        locked(&self.shared.peer_net).entry(to).or_default().rtt_us = rtt;
        let reader = stream.try_clone().context("cloning peer stream")?;
        let sh = self.shared.clone();
        std::thread::spawn(move || sh.read_loop(reader));
        self.shared.register(to, &stream);
        Ok(())
    }

    fn drain_into_stash(&mut self) {
        while let Ok(msg) = self.rx.try_recv() {
            self.stash.push(msg);
        }
    }
}

impl Channel for SocketEndpoint {
    fn executor_name(&self) -> &'static str {
        "socket"
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn send(&mut self, to: usize, tag: Tag, payload: Payload) {
        // Logical metering first, like the fabric: the attempt counts
        // even if the write then fails (a dead peer surfaces as a recv
        // timeout on the other side of the protocol, not a lost counter).
        self.bytes_sent += payload.wire_bytes() as u64;
        self.msgs_sent += 1;
        let frame = Frame::Msg { from: self.rank as u32, tag, payload };
        let _ = self.ship(to, &frame);
    }

    fn send_unmetered(&mut self, to: usize, tag: Tag, payload: Payload) {
        let frame = Frame::Replay { from: self.rank as u32, tag, payload };
        let _ = self.ship(to, &frame);
    }

    #[allow(clippy::expect_used)] // a hung-up socket world means every peer died: crash loudly
    fn recv(&mut self, tag: Tag) -> Message {
        if let Some(i) = self.stash.iter().position(|m| m.tag == tag) {
            return self.stash.swap_remove(i);
        }
        loop {
            let msg = self
                .rx
                .recv()
                .expect("socket world hung up while a recv was outstanding");
            if msg.tag == tag {
                return msg;
            }
            self.stash.push(msg);
        }
    }

    fn recv_timeout(&mut self, tag: Tag, timeout: Duration) -> Option<Message> {
        if let Some(i) = self.stash.iter().position(|m| m.tag == tag) {
            return Some(self.stash.swap_remove(i));
        }
        let deadline = Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            match self.rx.recv_timeout(left) {
                Ok(msg) if msg.tag == tag => return Some(msg),
                Ok(msg) => self.stash.push(msg),
                Err(RecvTimeoutError::Timeout) => return None,
                Err(RecvTimeoutError::Disconnected) => return None,
            }
        }
    }

    fn try_recv_ready(&mut self, tag: Tag) -> Option<Message> {
        self.drain_into_stash();
        let i = self.stash.iter().position(|m| m.tag == tag)?;
        Some(self.stash.swap_remove(i))
    }

    fn peek_ready(&mut self, tag: Tag) -> Option<Payload> {
        self.drain_into_stash();
        self.stash.iter().find(|m| m.tag == tag).map(|m| m.payload.clone())
    }

    fn stash_back(&mut self, msg: Message) {
        self.stash.push(msg);
    }

    fn sweep_stash(&mut self, keep: &mut dyn FnMut(&Tag) -> bool) -> usize {
        self.drain_into_stash();
        let before = self.stash.len();
        self.stash.retain(|m| keep(&m.tag));
        before - self.stash.len()
    }

    fn sent_totals(&self) -> (u64, u64) {
        (self.bytes_sent, self.msgs_sent)
    }

    fn restore_sent_totals(&mut self, bytes: u64, msgs: u64) {
        self.bytes_sent = bytes;
        self.msgs_sent = msgs;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg64;

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::Hello { version: 1, rank: 3, listen: "127.0.0.1:4242".into() },
            Frame::Welcome {
                version: 1,
                world: 3,
                peers: vec![
                    (0, "127.0.0.1:9000".into()),
                    (1, "127.0.0.1:9001".into()),
                    (2, "127.0.0.1:9002".into()),
                ],
            },
            Frame::PeerHello { version: 1, rank: 2 },
            Frame::PeerWelcome { version: 1, rank: 1 },
            Frame::Msg {
                from: 1,
                tag: Tag::new(112, 1029, 7),
                payload: Payload::F32(vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE]),
            },
            Frame::Msg {
                from: 0,
                tag: Tag::new(101, 4, 0),
                payload: Payload::U32(vec![9, 0, u32::MAX]),
            },
            Frame::Msg { from: 2, tag: Tag::new(114, 6, 2), payload: Payload::Control },
            Frame::Replay {
                from: 1,
                tag: Tag::new(115, 2048, 3),
                payload: Payload::F32(vec![0.25; 5]),
            },
        ]
    }

    #[test]
    fn every_frame_kind_round_trips() {
        for f in sample_frames() {
            let wire = f.encode();
            let body = &wire[8..];
            assert_eq!(
                u32::from_le_bytes([wire[0], wire[1], wire[2], wire[3]]) as usize,
                body.len()
            );
            assert_eq!(
                u32::from_le_bytes([wire[4], wire[5], wire[6], wire[7]]),
                crc32(body)
            );
            assert_eq!(Frame::decode(body).unwrap(), f, "round-trip failed for {f:?}");
        }
    }

    #[test]
    fn truncated_frames_yield_nothing_until_completed() {
        let wire = sample_frames()[4].encode();
        let mut r = FrameReader::new();
        // Every strict prefix yields no frame and counts nothing.
        assert!(r.push(&wire[..wire.len() - 1]).is_empty());
        assert_eq!(r.corrupt, 0);
        // The last byte completes it.
        let frames = r.push(&wire[wire.len() - 1..]);
        assert_eq!(frames, vec![sample_frames()[4].clone()]);
        assert_eq!(r.corrupt, 0);
    }

    #[test]
    fn bit_flipped_bodies_are_dropped_and_counted() {
        // Flip one bit in every body byte position in turn: each flip
        // must be caught by the CRC, never decoded as a different frame.
        let clean = sample_frames()[5].encode();
        let follow = sample_frames()[6].encode();
        for i in 8..clean.len() {
            let mut wire = clean.clone();
            wire[i] ^= 0x40;
            let mut r = FrameReader::new();
            let mut got = r.push(&wire);
            got.extend(r.push(&follow)); // resync on the next frame
            assert_eq!(r.corrupt, 1, "flip at byte {i} not counted");
            assert_eq!(got, vec![sample_frames()[6].clone()], "flip at byte {i}");
        }
    }

    #[test]
    fn reassembles_frames_split_at_arbitrary_boundaries() {
        let frames = sample_frames();
        let mut wire = Vec::new();
        for f in &frames {
            wire.extend_from_slice(&f.encode());
        }
        // Deterministic split points (R3: named seed, fixed provenance).
        let split_seed: u64 = 0x50c7_e75e;
        let mut rng = Pcg64::seed_from_u64(split_seed);
        for trial in 0..32 {
            let mut r = FrameReader::new();
            let mut got = Vec::new();
            let mut i = 0usize;
            while i < wire.len() {
                let step = 1 + (rng.next_u64() as usize) % 97;
                let j = (i + step).min(wire.len());
                got.extend(r.push(&wire[i..j]));
                i = j;
            }
            assert_eq!(got, frames, "trial {trial} reassembly mismatch");
            assert_eq!(r.corrupt, 0);
        }
    }

    #[test]
    fn implausible_length_tears_the_stream_down() {
        let mut r = FrameReader::new();
        let mut wire = Vec::new();
        wire.extend_from_slice(&(u32::MAX).to_le_bytes());
        wire.extend_from_slice(&0u32.to_le_bytes());
        wire.extend_from_slice(&[0; 16]);
        assert!(r.push(&wire).is_empty());
        assert_eq!(r.corrupt, 1);
        // The buffer was cleared: a clean frame afterwards decodes fine.
        assert_eq!(r.push(&sample_frames()[2].encode()), vec![sample_frames()[2].clone()]);
    }

    #[test]
    fn version_negotiation_is_an_equality_check() {
        assert!(negotiate(WIRE_VERSION).is_ok());
        let err = negotiate(WIRE_VERSION + 1).unwrap_err().to_string();
        assert!(err.contains("mismatch"), "got: {err}");
    }

    #[test]
    fn loopback_world_bootstraps_and_exchanges_tagged_messages() {
        // Two ranks over real localhost TCP: the seed handshake completes,
        // both directions deliver tag-matched, the stash discipline holds
        // (out-of-order tags, sweep, non-blocking polls), and metering is
        // logical payload bytes — identical to the fabric's rules.
        let seed = TcpListener::bind("127.0.0.1:0").unwrap();
        let seed_addr = seed.local_addr().unwrap().to_string();
        drop(seed); // free the port for the actual seed rank
        let addr = seed_addr.clone();
        let t = std::thread::spawn(move || {
            let mut e1 = SocketEndpoint::bootstrap(1, 2, &addr, "127.0.0.1:0").unwrap();
            e1.send(0, Tag::new(9, 0, 1), Payload::Control); // out-of-order noise
            e1.send(0, Tag::new(5, 1, 1), Payload::F32(vec![3.0, -4.0]));
            let m = Channel::recv(&mut e1, Tag::new(6, 0, 0));
            assert_eq!(m.payload.u32(), &[7, 8]);
            assert_eq!(m.from, 0);
            // Replay frames deliver but never touch the logical meters.
            let before = e1.sent_totals();
            e1.send_unmetered(0, Tag::new(115, 512, 1), Payload::F32(vec![1.0]));
            assert_eq!(e1.sent_totals(), before);
        });
        let mut e0 = SocketEndpoint::bootstrap(0, 2, &seed_addr, "127.0.0.1:0").unwrap();
        let m = Channel::recv(&mut e0, Tag::new(5, 1, 1));
        assert_eq!(m.payload.f32(), &[3.0, -4.0]);
        assert_eq!(m.from, 1);
        e0.send(1, Tag::new(6, 0, 0), Payload::U32(vec![7, 8]));
        // The noise frame is still stashed and matchable.
        assert!(Channel::recv_timeout(&mut e0, Tag::new(9, 0, 1), Duration::from_secs(2))
            .is_some());
        // The replay frame arrives like any tagged message.
        assert!(Channel::recv_timeout(&mut e0, Tag::new(115, 512, 1), Duration::from_secs(2))
            .is_some());
        // Logical metering: one U32(2) payload = 8 bytes, 1 message.
        assert_eq!(e0.sent_totals(), (8, 1));
        // Nothing else pending: polls never block and return None.
        assert!(Channel::try_recv_ready(&mut e0, Tag::new(99, 0, 0)).is_none());
        assert_eq!(e0.corrupt_dropped(), 0);
        // Per-peer framed traffic was tracked for the one peer.
        let pn = e0.peer_net();
        assert_eq!(pn.len(), 1);
        assert_eq!(pn[0].0, 1);
        assert!(pn[0].1.msgs >= 1);
        t.join().unwrap();
    }

    #[test]
    fn sweep_and_peek_follow_the_stash_discipline() {
        let seed = TcpListener::bind("127.0.0.1:0").unwrap();
        let seed_addr = seed.local_addr().unwrap().to_string();
        drop(seed);
        let addr = seed_addr.clone();
        let t = std::thread::spawn(move || {
            let mut e1 = SocketEndpoint::bootstrap(1, 2, &addr, "127.0.0.1:0").unwrap();
            e1.send(0, Tag::new(7, 1, 1), Payload::Control); // old round
            e1.send(0, Tag::new(7, 5, 1), Payload::Control); // fresh round
            e1.send(0, Tag::new(116, 1280, 1), Payload::F32(vec![2.0])); // peekable
            // Hold the rank open until rank 0 is done reading.
            assert!(Channel::recv_timeout(&mut e1, Tag::new(1, 0, 0), Duration::from_secs(5))
                .is_some());
        });
        let mut e0 = SocketEndpoint::bootstrap(0, 2, &seed_addr, "127.0.0.1:0").unwrap();
        // peek leaves the message readable again.
        let deadline = Instant::now() + Duration::from_secs(5);
        let p = loop {
            if let Some(p) = Channel::peek_ready(&mut e0, Tag::new(116, 1280, 1)) {
                break p;
            }
            assert!(Instant::now() < deadline, "peek never saw the offer");
            std::thread::sleep(Duration::from_millis(5));
        };
        assert_eq!(p.f32(), &[2.0]);
        assert!(Channel::peek_ready(&mut e0, Tag::new(116, 1280, 1)).is_some());
        // Make sure both kind-7 rounds arrived before sweeping.
        assert!(Channel::recv_timeout(&mut e0, Tag::new(7, 1, 1), Duration::from_secs(2))
            .map(|m| Channel::stash_back(&mut e0, m))
            .is_some());
        assert!(Channel::recv_timeout(&mut e0, Tag::new(7, 5, 1), Duration::from_secs(2))
            .map(|m| Channel::stash_back(&mut e0, m))
            .is_some());
        let dropped = Channel::sweep_stash(&mut e0, &mut |t: &Tag| t.kind != 7 || t.a >= 4);
        assert_eq!(dropped, 1);
        assert!(Channel::try_recv_ready(&mut e0, Tag::new(7, 1, 1)).is_none());
        assert!(Channel::try_recv_ready(&mut e0, Tag::new(7, 5, 1)).is_some());
        e0.send(1, Tag::new(1, 0, 0), Payload::Control);
        t.join().unwrap();
    }

    #[test]
    fn restored_wire_totals_continue_cumulatively() {
        let seed = TcpListener::bind("127.0.0.1:0").unwrap();
        let seed_addr = seed.local_addr().unwrap().to_string();
        drop(seed);
        let addr = seed_addr.clone();
        let t = std::thread::spawn(move || {
            SocketEndpoint::bootstrap(1, 2, &addr, "127.0.0.1:0").unwrap()
        });
        let mut e0 = SocketEndpoint::bootstrap(0, 2, &seed_addr, "127.0.0.1:0").unwrap();
        Channel::restore_sent_totals(&mut e0, 1000, 7);
        e0.send(1, Tag::new(1, 0, 0), Payload::F32(vec![0.0; 25]));
        assert_eq!(e0.sent_totals(), (1100, 8));
        t.join().unwrap();
    }
}
