//! Discrete-event simulation of communication schedules.
//!
//! Virtual time, no sleeping: each worker has a "ready" time, and a
//! message from `a` to `b` completes at `max(ready_a, start) + latency`,
//! with latencies drawn from a configurable model. The collectives cost
//! models in [`crate::collective`] walk their communication DAGs against
//! this clock. This is exactly the machinery behind the paper's Fig. 5A
//! (tree-reduce vs local averaging expected time) and Fig. 5B (global
//! blocking overhead of DiLoCo vs NoLoCo).

use crate::net::topo::Topology;
use crate::rngx::Pcg64;

/// Message latency model.
#[derive(Clone, Debug)]
pub enum LatencyModel {
    /// Every message takes exactly `t`.
    Constant(f64),
    /// `t ~ LogNormal(mu, sigma^2)` — the paper's §5.3 model. Expected
    /// value `exp(mu + sigma^2/2)`.
    LogNormal { mu: f64, sigma: f64 },
}

impl LatencyModel {
    /// Draw one message latency.
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        match self {
            LatencyModel::Constant(t) => *t,
            LatencyModel::LogNormal { mu, sigma } => rng.log_normal(*mu, *sigma),
        }
    }

    /// Analytic expected value.
    pub fn expected(&self) -> f64 {
        match self {
            LatencyModel::Constant(t) => *t,
            LatencyModel::LogNormal { mu, sigma } => (mu + sigma * sigma / 2.0).exp(),
        }
    }

    /// Analytic `E[max(t1, t2)]` of two iid draws — Eq. 7 of the paper:
    /// `(1 + erf(sigma/2)) exp(mu + sigma^2/2)` for the log-normal case.
    pub fn expected_max2(&self) -> f64 {
        match self {
            LatencyModel::Constant(t) => *t,
            LatencyModel::LogNormal { mu, sigma } => {
                (1.0 + erf(sigma / 2.0)) * (mu + sigma * sigma / 2.0).exp()
            }
        }
    }
}

/// Error function via the Abramowitz–Stegun 7.1.26 rational approximation
/// (|ε| < 1.5e-7 — far below the simulation noise it feeds).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Virtual-time simulator over a set of workers.
///
/// Two flavours: the homogeneous one ([`SimClock::new`]) draws every
/// message's cost from one payload-blind [`LatencyModel`]; the
/// topology-aware one ([`SimClock::with_topology`]) routes every message
/// through a [`Topology`], so cost = link latency + bytes/bandwidth,
/// scaled by straggler multipliers.
#[derive(Clone, Debug)]
pub struct SimClock {
    /// Per-worker time at which the worker becomes free.
    ready: Vec<f64>,
    latency: LatencyModel,
    topo: Option<Topology>,
    rng: Pcg64,
}

impl SimClock {
    /// `n` workers, all ready at t = 0, homogeneous links.
    pub fn new(n: usize, latency: LatencyModel, seed: u64) -> Self {
        SimClock {
            ready: vec![0.0; n],
            latency,
            topo: None,
            rng: Pcg64::seed_from_u64(seed),
        }
    }

    /// One worker per topology node, all ready at t = 0; message costs
    /// come from the topology's links (the `latency` model of the plain
    /// constructor is unused).
    pub fn with_topology(topo: Topology, seed: u64) -> Self {
        SimClock {
            ready: vec![0.0; topo.world()],
            latency: LatencyModel::Constant(0.0),
            topo: Some(topo),
            rng: Pcg64::seed_from_u64(seed),
        }
    }

    /// The topology, when this clock is link-aware.
    pub fn topology(&self) -> Option<&Topology> {
        self.topo.as_ref()
    }

    /// Number of workers.
    pub fn world(&self) -> usize {
        self.ready.len()
    }

    /// Worker `w`'s current ready time.
    pub fn ready_at(&self, w: usize) -> f64 {
        self.ready[w]
    }

    /// Advance worker `w` by local compute of duration `dt`.
    pub fn compute(&mut self, w: usize, dt: f64) {
        self.ready[w] += dt;
    }

    /// Sample the wire time of one `bytes`-sized message `from → to`
    /// *without* attributing it to either worker's schedule (cost models
    /// that roll their own schedules build on this). Topology-aware
    /// clocks charge link latency + serialization + stragglers; plain
    /// clocks fall back to the payload-blind latency model.
    pub fn link_time(&mut self, from: usize, to: usize, bytes: u64) -> f64 {
        match &self.topo {
            Some(t) => t.transfer_time(from, to, bytes, &mut self.rng),
            None => self.latency.sample(&mut self.rng),
        }
    }

    /// Simulate a zero-payload message `from → to`: the receiver becomes
    /// ready no earlier than sender-ready + latency. Returns the arrival
    /// time.
    pub fn send(&mut self, from: usize, to: usize) -> f64 {
        self.send_bytes(from, to, 0)
    }

    /// Simulate a `bytes`-sized message `from → to` through the link (or
    /// the homogeneous model when no topology is attached). Returns the
    /// arrival time.
    pub fn send_bytes(&mut self, from: usize, to: usize, bytes: u64) -> f64 {
        let lat = self.link_time(from, to, bytes);
        let arrive = self.ready[from] + lat;
        self.ready[to] = self.ready[to].max(arrive);
        arrive
    }

    /// Symmetric zero-payload exchange between two workers (both send,
    /// both wait): afterwards both are ready at `max(arrival_a,
    /// arrival_b)`. This is one NoLoCo gossip hop.
    pub fn exchange(&mut self, a: usize, b: usize) -> f64 {
        self.exchange_bytes(a, b, 0)
    }

    /// Symmetric exchange of `bytes` each way (the NoLoCo gossip hop with
    /// its real (Δ, φ) payload).
    pub fn exchange_bytes(&mut self, a: usize, b: usize, bytes: u64) -> f64 {
        let la = self.link_time(a, b, bytes);
        let lb = self.link_time(b, a, bytes);
        let t = (self.ready[a] + la).max(self.ready[b] + lb);
        self.ready[a] = t;
        self.ready[b] = t;
        t
    }

    /// Barrier: all workers wait for the slowest.
    pub fn barrier(&mut self) -> f64 {
        let t = self.ready.iter().cloned().fold(0.0, f64::max);
        for r in &mut self.ready {
            *r = t;
        }
        t
    }

    /// Largest ready time (current makespan).
    pub fn makespan(&self) -> f64 {
        self.ready.iter().cloned().fold(0.0, f64::max)
    }

    /// Draw a latency from the model without attributing it to a link
    /// (used by cost models that roll their own schedules).
    pub fn draw_latency(&mut self) -> f64 {
        self.latency.sample(&mut self.rng)
    }

    /// Draw from an arbitrary log-normal (e.g. inner-step compute times in
    /// the Fig. 5B study).
    pub fn draw_log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.rng.log_normal(mu, sigma)
    }

    /// Reset all workers to t = 0 (keeps the RNG stream).
    pub fn reset(&mut self) {
        for r in &mut self.ready {
            *r = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_values() {
        // erf(0)=0, erf(1)=0.8427008, erf(-1)=-erf(1), erf(2)=0.9953223.
        assert!(erf(0.0).abs() < 1e-9);
        assert!((erf(1.0) - 0.8427008).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427008).abs() < 1e-6);
        assert!((erf(2.0) - 0.9953223).abs() < 1e-6);
    }

    #[test]
    fn expected_max2_matches_monte_carlo() {
        // Eq. 7 check: analytic E[max(t1,t2)] vs simulation.
        let (mu, sigma) = (0.0, 0.7);
        let m = LatencyModel::LogNormal { mu, sigma };
        let analytic = m.expected_max2();
        let mut rng = Pcg64::seed_from_u64(77);
        let n = 300_000;
        let mut acc = 0.0;
        for _ in 0..n {
            let a = rng.log_normal(mu, sigma);
            let b = rng.log_normal(mu, sigma);
            acc += a.max(b);
        }
        let mc = acc / n as f64;
        assert!(
            (mc - analytic).abs() / analytic < 0.01,
            "mc={mc} analytic={analytic}"
        );
    }

    #[test]
    fn constant_model_send_is_deterministic() {
        let mut c = SimClock::new(3, LatencyModel::Constant(2.0), 0);
        c.compute(0, 1.0);
        let arr = c.send(0, 1);
        assert_eq!(arr, 3.0);
        assert_eq!(c.ready_at(1), 3.0);
        assert_eq!(c.ready_at(2), 0.0);
    }

    #[test]
    fn receiver_not_rewound_by_early_message() {
        let mut c = SimClock::new(2, LatencyModel::Constant(1.0), 0);
        c.compute(1, 10.0);
        c.send(0, 1);
        assert_eq!(c.ready_at(1), 10.0); // already later than arrival
    }

    #[test]
    fn exchange_synchronizes_pair() {
        let mut c = SimClock::new(4, LatencyModel::Constant(0.5), 0);
        c.compute(0, 2.0);
        let t = c.exchange(0, 1);
        assert_eq!(t, 2.5);
        assert_eq!(c.ready_at(0), 2.5);
        assert_eq!(c.ready_at(1), 2.5);
        // Untouched workers unaffected — no global blocking.
        assert_eq!(c.ready_at(2), 0.0);
        assert_eq!(c.ready_at(3), 0.0);
    }

    #[test]
    fn barrier_blocks_on_slowest() {
        let mut c = SimClock::new(3, LatencyModel::Constant(1.0), 0);
        c.compute(2, 5.0);
        assert_eq!(c.barrier(), 5.0);
        assert!(c.ready.iter().all(|&r| r == 5.0));
    }

    #[test]
    fn topology_clock_charges_bandwidth_and_links() {
        use crate::net::topo::{Link, Topology};
        // Two regions of two nodes: intra 0.1 s + 1 kB/s, inter 1.0 s +
        // 100 B/s.
        let topo = Topology::multi_region(
            &[2, 2],
            Link::new(LatencyModel::Constant(0.1), 1000.0),
            Link::new(LatencyModel::Constant(1.0), 100.0),
        );
        let mut c = SimClock::with_topology(topo, 0);
        assert_eq!(c.world(), 4);
        // Intra-region 500-byte message: 0.1 + 0.5.
        assert_eq!(c.send_bytes(0, 1, 500), 0.6);
        // Inter-region 500-byte message: 1.0 + 5.0.
        assert_eq!(c.send_bytes(0, 2, 500), 6.0);
        assert_eq!(c.ready_at(2), 6.0);
        // Zero-payload send degenerates to pure link latency.
        c.reset();
        assert_eq!(c.send(0, 3), 1.0);
    }

    #[test]
    fn topology_exchange_waits_on_slow_direction() {
        use crate::net::topo::{Link, Topology};
        let topo = Topology::single_switch(2, Link::constant(0.5)).with_straggler(1, 3.0);
        let mut c = SimClock::with_topology(topo, 0);
        // Both directions pay the straggler multiplier: 0.5 * 3.
        assert_eq!(c.exchange(0, 1), 1.5);
        assert_eq!(c.ready_at(0), 1.5);
        assert_eq!(c.ready_at(1), 1.5);
    }

    #[test]
    fn makespan_tracks_max() {
        let mut c = SimClock::new(2, LatencyModel::Constant(1.0), 0);
        c.compute(0, 3.0);
        assert_eq!(c.makespan(), 3.0);
        c.reset();
        assert_eq!(c.makespan(), 0.0);
    }
}
