//! In-process message fabric.
//!
//! One [`Endpoint`] per worker thread. Messages are tagged so a worker
//! can wait for *the* activation of microbatch `k` at stage boundary `s`
//! while gossip traffic arrives interleaved — out-of-order arrivals are
//! stashed per-endpoint and matched later, which is what makes the random
//! pipeline routing and the asynchronous gossip step composable on one
//! channel per worker.
//!
//! Latency injection: a message may carry a `deliver_at` instant; `recv`
//! waits until then, modelling link latency without occupying the sender
//! thread. Fault injection ([`FaultPlan`]) drops or duplicates messages
//! deterministically for robustness tests.
//!
//! # Determinism guarantees
//!
//! Fault decisions are made on the *sender* side, by a per-endpoint
//! [`Pcg64`] seeded as `seed ^ rank · φ64` at construction. Consequences:
//!
//! * Given the same fabric seed and the same per-endpoint sequence of
//!   `send` calls, the exact same messages are dropped / duplicated on
//!   every run — regardless of thread scheduling, because no endpoint's
//!   RNG is shared.
//! * Each `send` consumes one RNG draw for the drop decision (when
//!   `drop_prob > 0`), then — only if the message survived — one draw
//!   for latency (when enabled) and one for the duplicate decision (when
//!   `dup_prob > 0`). Drop and duplicate probabilities therefore compose
//!   independently per message: a message is delivered twice with
//!   probability `(1 − p_drop) · p_dup`, once with
//!   `(1 − p_drop)(1 − p_dup)`, and never with `p_drop`.
//! * A duplicated message reuses the original's `deliver_at`, so both
//!   copies become receivable at the same instant.
//!
//! Receive-side ordering (which of two racing senders lands first) is
//! *not* deterministic; tag-matched [`Endpoint::recv`] exists precisely
//! so callers never depend on it.

use crate::rngx::Pcg64;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Message kind + coordinates. `Ord` so stashes can be searched cheaply.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Tag {
    /// Kind discriminator (see the `tags` constants in [`crate::train`]).
    pub kind: u16,
    /// Outer coordinate (e.g. step or microbatch id).
    pub a: u32,
    /// Inner coordinate (e.g. stage boundary or slot).
    pub b: u32,
}

impl Tag {
    /// Construct a tag.
    pub fn new(kind: u16, a: u32, b: u32) -> Tag {
        Tag { kind, a, b }
    }
}

/// Message body.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Dense activations / gradients / parameters.
    F32(Vec<f32>),
    /// Token ids.
    U32(Vec<u32>),
    /// Pure control signal.
    Control,
}

impl Payload {
    /// Borrow as f32 slice (panics on wrong variant — tags define types).
    pub fn f32(&self) -> &[f32] {
        match self {
            Payload::F32(v) => v,
            other => panic!("expected F32 payload, got {other:?}"),
        }
    }

    /// Take the f32 vector.
    pub fn into_f32(self) -> Vec<f32> {
        match self {
            Payload::F32(v) => v,
            other => panic!("expected F32 payload, got {other:?}"),
        }
    }

    /// Borrow as u32 slice.
    pub fn u32(&self) -> &[u32] {
        match self {
            Payload::U32(v) => v,
            other => panic!("expected U32 payload, got {other:?}"),
        }
    }

    /// Approximate wire size in bytes (for traffic accounting).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::F32(v) => v.len() * 4,
            Payload::U32(v) => v.len() * 4,
            Payload::Control => 8,
        }
    }
}

/// A routed message.
#[derive(Debug)]
pub struct Message {
    /// Sender rank.
    pub from: usize,
    /// Matching tag.
    pub tag: Tag,
    /// Body.
    pub payload: Payload,
    /// Earliest delivery instant (latency injection), if any.
    deliver_at: Option<Instant>,
}

/// Deterministic fault injection for tests (see the module docs for the
/// exact determinism guarantees and how the probabilities compose).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Probability a message is silently dropped.
    pub drop_prob: f64,
    /// Probability a message is delivered twice.
    pub dup_prob: f64,
}

impl FaultPlan {
    /// A fault-free plan (what [`Fabric::new`] uses).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// True when no faults can fire.
    pub fn is_none(&self) -> bool {
        self.drop_prob <= 0.0 && self.dup_prob <= 0.0
    }
}

struct Shared {
    senders: Vec<Sender<Message>>,
    bytes_sent: Mutex<Vec<u64>>,
    msgs_sent: Mutex<Vec<u64>>,
}

/// The fabric: construct once, then [`Fabric::take_endpoints`] and hand
/// one endpoint to each worker thread.
pub struct Fabric {
    shared: Arc<Shared>,
    endpoints: Vec<Option<Endpoint>>,
}

impl Fabric {
    /// Build a fully connected fabric over `n` ranks.
    pub fn new(n: usize) -> Fabric {
        Self::with_faults(n, FaultPlan::default(), 0)
    }

    /// Build with fault injection (seeded per-endpoint).
    pub fn with_faults(n: usize, faults: FaultPlan, seed: u64) -> Fabric {
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        let shared = Arc::new(Shared {
            senders,
            bytes_sent: Mutex::new(vec![0; n]),
            msgs_sent: Mutex::new(vec![0; n]),
        });
        let endpoints = receivers
            .into_iter()
            .enumerate()
            .map(|(rank, rx)| {
                Some(Endpoint {
                    rank,
                    shared: shared.clone(),
                    rx,
                    stash: Vec::new(),
                    latency: None,
                    faults: faults.clone(),
                    rng: Pcg64::seed_from_u64(seed ^ (rank as u64).wrapping_mul(0x9e3779b97f4a7c15)),
                })
            })
            .collect();
        Fabric { shared, endpoints }
    }

    /// World size.
    pub fn world(&self) -> usize {
        self.endpoints.len()
    }

    /// Move all endpoints out (each worker thread owns one).
    pub fn take_endpoints(&mut self) -> Vec<Endpoint> {
        self.endpoints
            .iter_mut()
            .map(|e| e.take().expect("endpoints already taken"))
            .collect()
    }

    /// Total bytes put on the wire per rank so far (traffic accounting for
    /// the communication-volume comparisons).
    pub fn bytes_sent(&self) -> Vec<u64> {
        self.shared.bytes_sent.lock().unwrap().clone()
    }

    /// Total messages sent per rank.
    pub fn msgs_sent(&self) -> Vec<u64> {
        self.shared.msgs_sent.lock().unwrap().clone()
    }
}

/// One worker's handle on the fabric.
pub struct Endpoint {
    rank: usize,
    shared: Arc<Shared>,
    rx: Receiver<Message>,
    stash: Vec<Message>,
    latency: Option<(f64, f64)>, // (mu, sigma) log-normal seconds
    faults: FaultPlan,
    rng: Pcg64,
}

impl Endpoint {
    /// This endpoint's rank.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// World size.
    pub fn world(&self) -> usize {
        self.shared.senders.len()
    }

    /// Enable log-normal latency injection on *outgoing* messages,
    /// parameterized in seconds.
    pub fn set_latency_log_normal(&mut self, mu: f64, sigma: f64) {
        self.latency = Some((mu, sigma));
    }

    /// This rank's wire totals so far: `(bytes_sent, msgs_sent)`. The
    /// same counters [`Fabric::bytes_sent`] / [`Fabric::msgs_sent`]
    /// expose fabric-wide, readable from the worker side — attempted
    /// sends are counted even when fault injection drops them.
    pub fn sent_totals(&self) -> (u64, u64) {
        let bytes = self.shared.bytes_sent.lock().unwrap()[self.rank];
        let msgs = self.shared.msgs_sent.lock().unwrap()[self.rank];
        (bytes, msgs)
    }

    /// Send `payload` to `to` under `tag`.
    pub fn send(&mut self, to: usize, tag: Tag, payload: Payload) {
        {
            let mut b = self.shared.bytes_sent.lock().unwrap();
            b[self.rank] += payload.wire_bytes() as u64;
            let mut m = self.shared.msgs_sent.lock().unwrap();
            m[self.rank] += 1;
        }
        if self.faults.drop_prob > 0.0 && self.rng.next_f64() < self.faults.drop_prob {
            return; // dropped on the floor
        }
        let deliver_at = self.latency.map(|(mu, sigma)| {
            Instant::now() + Duration::from_secs_f64(self.rng.log_normal(mu, sigma))
        });
        let msg = Message {
            from: self.rank,
            tag,
            payload: payload.clone(),
            deliver_at,
        };
        let dup = self.faults.dup_prob > 0.0 && self.rng.next_f64() < self.faults.dup_prob;
        // A send to a hung-up receiver is not an error for the sender —
        // that worker has already finished (e.g. trailing gossip traffic).
        let _ = self.shared.senders[to].send(msg);
        if dup {
            let _ = self.shared.senders[to].send(Message {
                from: self.rank,
                tag,
                payload,
                deliver_at,
            });
        }
    }

    fn honor_latency(msg: &Message) {
        if let Some(at) = msg.deliver_at {
            let now = Instant::now();
            if at > now {
                std::thread::sleep(at - now);
            }
        }
    }

    /// Blocking receive of the first message matching `tag` (out-of-order
    /// arrivals under other tags are stashed).
    pub fn recv(&mut self, tag: Tag) -> Message {
        if let Some(i) = self.stash.iter().position(|m| m.tag == tag) {
            let msg = self.stash.swap_remove(i);
            Self::honor_latency(&msg);
            return msg;
        }
        loop {
            let msg = self
                .rx
                .recv()
                .expect("fabric hung up while a recv was outstanding");
            if msg.tag == tag {
                Self::honor_latency(&msg);
                return msg;
            }
            self.stash.push(msg);
        }
    }

    /// Receive matching `tag` with a timeout; `None` on expiry (used by
    /// fault-injection tests).
    pub fn recv_timeout(&mut self, tag: Tag, timeout: Duration) -> Option<Message> {
        if let Some(i) = self.stash.iter().position(|m| m.tag == tag) {
            let msg = self.stash.swap_remove(i);
            Self::honor_latency(&msg);
            return Some(msg);
        }
        let deadline = Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            match self.rx.recv_timeout(left) {
                Ok(msg) if msg.tag == tag => {
                    Self::honor_latency(&msg);
                    return Some(msg);
                }
                Ok(msg) => self.stash.push(msg),
                Err(RecvTimeoutError::Timeout) => return None,
                Err(RecvTimeoutError::Disconnected) => return None,
            }
        }
    }

    /// Return a received message to the stash (e.g. one half of a
    /// two-part payload whose sibling has not arrived yet — the caller
    /// backs off without losing what was already delivered).
    pub fn stash_back(&mut self, msg: Message) {
        self.stash.push(msg);
    }

    /// Truly non-blocking receive: drain the channel into the stash,
    /// then take a matching message only if its injected delivery
    /// instant has passed. A matched-but-not-yet-deliverable message
    /// stays stashed and `None` is returned — unlike
    /// [`Endpoint::recv_timeout`], this never sleeps on the latency
    /// model, which is what the polling paths (heartbeats, staleness
    /// fallback probes) require.
    pub fn try_recv_ready(&mut self, tag: Tag) -> Option<Message> {
        while let Ok(msg) = self.rx.try_recv() {
            self.stash.push(msg);
        }
        let now = Instant::now();
        let i = self.stash.iter().position(|m| {
            m.tag == tag
                && match m.deliver_at {
                    None => true,
                    Some(at) => at <= now,
                }
        })?;
        Some(self.stash.swap_remove(i))
    }

    /// Like [`Endpoint::try_recv_ready`], but *leaves the message in the
    /// stash* and returns a clone of its payload — for offers that must
    /// stay readable for a retention window (the bounded-staleness
    /// collects re-admit a peer's older offer at later boundaries; the
    /// stash-expiry sweep reclaims them).
    pub fn peek_ready(&mut self, tag: Tag) -> Option<Payload> {
        while let Ok(msg) = self.rx.try_recv() {
            self.stash.push(msg);
        }
        let now = Instant::now();
        self.stash
            .iter()
            .find(|m| {
                m.tag == tag
                    && match m.deliver_at {
                        None => true,
                        Some(at) => at <= now,
                    }
            })
            .map(|m| m.payload.clone())
    }

    /// Drain the channel into the stash (non-blocking), then drop every
    /// stashed message whose tag fails `keep`; returns how many were
    /// dropped. This is the stash-expiry hook: fragment, gossip and
    /// heartbeat messages that were never collected — churn-dropped
    /// folds, straggler timeouts, suppressed receivers — would otherwise
    /// sit in the stash for the rest of the run. Callers sweep with a
    /// tag-age predicate at a cadence of their choosing (the trainers
    /// sweep once per outer boundary).
    pub fn sweep_stash<F: FnMut(&Tag) -> bool>(&mut self, mut keep: F) -> usize {
        while let Ok(msg) = self.rx.try_recv() {
            self.stash.push(msg);
        }
        let before = self.stash.len();
        self.stash.retain(|m| keep(&m.tag));
        before - self.stash.len()
    }

    /// Receive any message (FIFO across stash + channel).
    pub fn recv_any(&mut self) -> Message {
        if !self.stash.is_empty() {
            let msg = self.stash.remove(0);
            Self::honor_latency(&msg);
            return msg;
        }
        let msg = self.rx.recv().expect("fabric hung up");
        Self::honor_latency(&msg);
        msg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn point_to_point_roundtrip() {
        let mut f = Fabric::new(2);
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t = thread::spawn(move || {
            e1.send(0, Tag::new(1, 0, 0), Payload::F32(vec![1.0, 2.0]));
            let m = e1.recv(Tag::new(2, 0, 0));
            assert_eq!(m.payload.u32(), &[7, 8, 9]);
        });
        let m = e0.recv(Tag::new(1, 0, 0));
        assert_eq!(m.from, 1);
        assert_eq!(m.payload.f32(), &[1.0, 2.0]);
        e0.send(1, Tag::new(2, 0, 0), Payload::U32(vec![7, 8, 9]));
        t.join().unwrap();
    }

    #[test]
    fn out_of_order_tags_are_stashed() {
        let mut f = Fabric::new(2);
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, Tag::new(9, 0, 0), Payload::Control); // noise first
        e1.send(0, Tag::new(5, 1, 2), Payload::F32(vec![3.0]));
        let m = e0.recv(Tag::new(5, 1, 2));
        assert_eq!(m.payload.f32(), &[3.0]);
        let n = e0.recv(Tag::new(9, 0, 0));
        assert_eq!(n.payload, Payload::Control);
    }

    #[test]
    fn traffic_accounting() {
        let mut f = Fabric::new(2);
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let _e0 = eps.pop().unwrap();
        e1.send(0, Tag::new(1, 0, 0), Payload::F32(vec![0.0; 100]));
        assert_eq!(f.bytes_sent()[1], 400);
        assert_eq!(f.msgs_sent()[1], 1);
        assert_eq!(f.bytes_sent()[0], 0);
        // The worker-side view agrees with the fabric-wide counters.
        assert_eq!(e1.sent_totals(), (400, 1));
        assert_eq!(_e0.sent_totals(), (0, 0));
    }

    #[test]
    fn drops_cause_timeouts() {
        let mut f = Fabric::with_faults(
            2,
            FaultPlan {
                drop_prob: 1.0,
                dup_prob: 0.0,
            },
            3,
        );
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, Tag::new(1, 0, 0), Payload::Control);
        assert!(e0
            .recv_timeout(Tag::new(1, 0, 0), Duration::from_millis(20))
            .is_none());
    }

    #[test]
    fn duplicates_are_observable_and_matchable() {
        let mut f = Fabric::with_faults(
            2,
            FaultPlan {
                drop_prob: 0.0,
                dup_prob: 1.0,
            },
            4,
        );
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, Tag::new(1, 0, 0), Payload::Control);
        assert!(e0
            .recv_timeout(Tag::new(1, 0, 0), Duration::from_millis(20))
            .is_some());
        assert!(e0
            .recv_timeout(Tag::new(1, 0, 0), Duration::from_millis(20))
            .is_some());
    }

    #[test]
    fn fault_plan_none_is_fault_free() {
        assert!(FaultPlan::none().is_none());
        assert_eq!(FaultPlan::none(), FaultPlan::default());
        assert!(!FaultPlan { drop_prob: 0.1, dup_prob: 0.0 }.is_none());
        assert!(!FaultPlan { drop_prob: 0.0, dup_prob: 0.1 }.is_none());
    }

    #[test]
    fn drop_beats_duplicate_when_both_certain() {
        // Composition rule from the module docs: P(any delivery) =
        // 1 - p_drop, regardless of dup_prob. With p_drop = 1 every
        // message dies even though dup_prob = 1.
        let mut f = Fabric::with_faults(
            2,
            FaultPlan { drop_prob: 1.0, dup_prob: 1.0 },
            11,
        );
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        for k in 0..8u32 {
            e1.send(0, Tag::new(1, k, 0), Payload::Control);
        }
        for k in 0..8u32 {
            assert!(e0
                .recv_timeout(Tag::new(1, k, 0), Duration::from_millis(5))
                .is_none());
        }
        // Traffic accounting still counts the attempted sends.
        assert_eq!(f.msgs_sent()[1], 8);
    }

    #[test]
    fn mixed_drop_dup_is_deterministic_per_seed() {
        // Same seed ⇒ identical per-message delivery multiset across runs,
        // independent of wall-clock scheduling (sender-side decisions).
        let deliveries = |seed: u64| -> Vec<usize> {
            let mut f = Fabric::with_faults(
                2,
                FaultPlan { drop_prob: 0.4, dup_prob: 0.4 },
                seed,
            );
            let mut eps = f.take_endpoints();
            let mut e1 = eps.pop().unwrap();
            let mut e0 = eps.pop().unwrap();
            let n = 32u32;
            for k in 0..n {
                e1.send(0, Tag::new(1, k, 0), Payload::Control);
            }
            (0..n)
                .map(|k| {
                    let mut copies = 0;
                    while e0
                        .recv_timeout(Tag::new(1, k, 0), Duration::from_millis(5))
                        .is_some()
                    {
                        copies += 1;
                    }
                    copies
                })
                .collect()
        };
        let a = deliveries(99);
        let b = deliveries(99);
        assert_eq!(a, b, "same seed must reproduce the same fault pattern");
        // With these probabilities all three outcomes should occur.
        assert!(a.iter().any(|&c| c == 0), "no drop observed");
        assert!(a.iter().any(|&c| c == 1), "no single delivery observed");
        assert!(a.iter().any(|&c| c == 2), "no duplicate observed");
        let c = deliveries(100);
        assert_ne!(a, c, "different seeds should differ");
    }

    #[test]
    fn sweep_stash_drops_only_unkept_tags() {
        let mut f = Fabric::new(2);
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, Tag::new(7, 1, 0), Payload::Control); // old round
        e1.send(0, Tag::new(7, 5, 0), Payload::Control); // fresh round
        e1.send(0, Tag::new(8, 1, 0), Payload::F32(vec![1.0])); // other kind
        // Sweep: keep kind 7 only when its round is recent, keep the rest.
        let dropped = e0.sweep_stash(|t| t.kind != 7 || t.a >= 4);
        assert_eq!(dropped, 1);
        // The fresh round and the other-kind message are still matchable.
        assert!(e0
            .recv_timeout(Tag::new(7, 5, 0), Duration::from_millis(20))
            .is_some());
        assert!(e0
            .recv_timeout(Tag::new(8, 1, 0), Duration::from_millis(20))
            .is_some());
        // The expired one is gone.
        assert!(e0
            .recv_timeout(Tag::new(7, 1, 0), Duration::from_millis(5))
            .is_none());
    }

    #[test]
    fn latency_injection_delays_delivery() {
        let mut f = Fabric::new(2);
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // LogNormal(ln(0.03), ~0) ≈ constant 30 ms.
        e1.set_latency_log_normal((0.03f64).ln(), 1e-6);
        let t0 = Instant::now();
        e1.send(0, Tag::new(1, 0, 0), Payload::Control);
        e0.recv(Tag::new(1, 0, 0));
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }
}
