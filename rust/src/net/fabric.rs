//! In-process message fabric.
//!
//! One [`Endpoint`] per worker thread. Messages are tagged so a worker
//! can wait for *the* activation of microbatch `k` at stage boundary `s`
//! while gossip traffic arrives interleaved — out-of-order arrivals are
//! stashed per-endpoint and matched later, which is what makes the random
//! pipeline routing and the asynchronous gossip step composable on one
//! channel per worker.
//!
//! Latency injection: a message may carry a `deliver_at` instant; `recv`
//! waits until then, modelling link latency without occupying the sender
//! thread. Fault injection ([`FaultPlan`]) drops, duplicates, delays,
//! reorders or corrupts messages deterministically for robustness tests.
//!
//! # CRC framing
//!
//! Every message carries a CRC-32 of its payload, computed at `send`
//! *before* fault injection gets a chance to corrupt the frame. Receivers
//! verify the CRC the moment a message is pulled off the channel: a
//! mismatching frame is dropped on the floor and counted (per receiving
//! rank, [`Fabric::corrupt_dropped`]) — it never reaches the stash, so a
//! corrupt frame behaves exactly like a dropped one from the protocol's
//! point of view and the straggler/staleness fallbacks absorb it.
//!
//! # Determinism guarantees
//!
//! Fault decisions are made on the *sender* side, by a per-endpoint
//! [`Pcg64`] seeded as `seed ^ rank · φ64` at construction. Consequences:
//!
//! * Given the same fabric seed and the same per-endpoint sequence of
//!   `send` calls, the exact same messages are dropped / duplicated /
//!   delayed / reordered / corrupted on every run — regardless of thread
//!   scheduling, because no endpoint's RNG is shared.
//! * Each `send` consumes one RNG draw for the drop decision (when
//!   `drop_prob > 0`), then — only if the message survived — one draw
//!   for latency (when enabled), one for the duplicate decision (when
//!   `dup_prob > 0`), one for extra delay (when `delay_prob > 0`), one
//!   for reorder (when `reorder_prob > 0`) and one-plus-one for the
//!   corrupt decision and the flipped bit (when `corrupt_prob > 0`) — in
//!   exactly that order. Every new draw is gated on its probability
//!   being positive, so configs that only use drop/dup reproduce the
//!   same fault pattern they always did under a given seed.
//! * Drop and duplicate probabilities compose independently per message:
//!   a message is delivered twice with probability
//!   `(1 − p_drop) · p_dup`, once with `(1 − p_drop)(1 − p_dup)`, and
//!   never with `p_drop`.
//! * A duplicated message reuses the original's `deliver_at` (and, when
//!   corruption fired, its corrupted payload), so both copies become
//!   receivable at the same instant and fail the CRC together.
//! * A reordered message is held back by its sender and released right
//!   after that sender's *next* `send` call (or at endpoint drop) — a
//!   deterministic adjacent swap in the sender's own stream; nothing is
//!   ever lost to reordering.
//!
//! Receive-side ordering (which of two racing senders lands first) is
//! *not* deterministic; tag-matched [`Endpoint::recv`] exists precisely
//! so callers never depend on it.

use crate::rngx::Pcg64;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Poison-proof lock: a peer that panicked mid-send must not poison
/// the shared wire counters for everyone else.
fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Message kind + coordinates. `Ord` so stashes can be searched cheaply.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Tag {
    /// Kind discriminator (see the `tags` constants in [`crate::train`]).
    pub kind: u16,
    /// Outer coordinate (e.g. step or microbatch id).
    pub a: u32,
    /// Inner coordinate (e.g. stage boundary or slot).
    pub b: u32,
}

impl Tag {
    /// Construct a tag.
    pub fn new(kind: u16, a: u32, b: u32) -> Tag {
        Tag { kind, a, b }
    }
}

/// Message body.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Dense activations / gradients / parameters.
    F32(Vec<f32>),
    /// Token ids.
    U32(Vec<u32>),
    /// Pure control signal.
    Control,
}

impl Payload {
    /// Borrow as f32 slice (panics on wrong variant — tags define types).
    pub fn f32(&self) -> &[f32] {
        match self {
            Payload::F32(v) => v,
            other => panic!("expected F32 payload, got {other:?}"),
        }
    }

    /// Take the f32 vector.
    pub fn into_f32(self) -> Vec<f32> {
        match self {
            Payload::F32(v) => v,
            other => panic!("expected F32 payload, got {other:?}"),
        }
    }

    /// Borrow as u32 slice.
    pub fn u32(&self) -> &[u32] {
        match self {
            Payload::U32(v) => v,
            other => panic!("expected U32 payload, got {other:?}"),
        }
    }

    /// Approximate wire size in bytes (for traffic accounting).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::F32(v) => v.len() * 4,
            Payload::U32(v) => v.len() * 4,
            Payload::Control => 8,
        }
    }
}

/// A routed message.
#[derive(Debug)]
pub struct Message {
    /// Sender rank.
    pub from: usize,
    /// Matching tag.
    pub tag: Tag,
    /// Body.
    pub payload: Payload,
    /// Earliest delivery instant (latency injection), if any.
    deliver_at: Option<Instant>,
    /// CRC-32 of the payload as the sender framed it (pre-corruption).
    crc: u32,
}

impl Message {
    /// An already-verified message with no pending latency — what a
    /// transport that performed its own integrity check (the socket
    /// framing layer) hands to the stash discipline.
    pub(crate) fn delivered(from: usize, tag: Tag, payload: Payload) -> Message {
        let crc = payload_crc(&payload);
        Message { from, tag, payload, deliver_at: None, crc }
    }
}

/// CRC-32 (IEEE 802.3 polynomial, reflected) over a byte stream.
pub(crate) fn crc32_update(mut crc: u32, bytes: impl IntoIterator<Item = u8>) -> u32 {
    for b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xedb8_8320 & mask);
        }
    }
    crc
}

/// CRC-32 of a payload's wire bytes (little-endian element order).
pub fn payload_crc(p: &Payload) -> u32 {
    let crc = match p {
        Payload::F32(v) => v.iter().fold(0xffff_ffff, |c, x| {
            crc32_update(c, x.to_bits().to_le_bytes())
        }),
        Payload::U32(v) => v
            .iter()
            .fold(0xffff_ffff, |c, x| crc32_update(c, x.to_le_bytes())),
        Payload::Control => 0xffff_ffff,
    };
    !crc
}

/// Flip one payload bit chosen by `r`; returns false when the payload has
/// no bytes to flip (pure control frames — the caller corrupts the CRC
/// field instead, which the receiver detects the same way).
fn corrupt_payload(p: &mut Payload, r: u64) -> bool {
    match p {
        Payload::F32(v) if !v.is_empty() => {
            let i = ((r >> 5) as usize) % v.len();
            let bit = (r & 31) as u32;
            v[i] = f32::from_bits(v[i].to_bits() ^ (1u32 << bit));
            true
        }
        Payload::U32(v) if !v.is_empty() => {
            let i = ((r >> 5) as usize) % v.len();
            let bit = (r & 31) as u32;
            v[i] ^= 1u32 << bit;
            true
        }
        _ => false,
    }
}

/// Deterministic fault injection for tests (see the module docs for the
/// exact determinism guarantees and how the probabilities compose).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Probability a message is silently dropped.
    pub drop_prob: f64,
    /// Probability a message is delivered twice.
    pub dup_prob: f64,
    /// Probability a message's delivery is postponed by `delay_secs`.
    pub delay_prob: f64,
    /// Extra delivery delay, in seconds, when the delay fault fires.
    pub delay_secs: f64,
    /// Probability a message is held back until the sender's next send
    /// (an adjacent swap in that sender's stream).
    pub reorder_prob: f64,
    /// Probability one payload bit is flipped in flight; the receiver's
    /// CRC check drops and counts such frames.
    pub corrupt_prob: f64,
}

impl FaultPlan {
    /// A fault-free plan (what [`Fabric::new`] uses).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// True when no faults can fire.
    pub fn is_none(&self) -> bool {
        self.drop_prob <= 0.0
            && self.dup_prob <= 0.0
            && self.delay_prob <= 0.0
            && self.reorder_prob <= 0.0
            && self.corrupt_prob <= 0.0
    }
}

struct Shared {
    senders: Vec<Sender<Message>>,
    bytes_sent: Mutex<Vec<u64>>,
    msgs_sent: Mutex<Vec<u64>>,
    /// Frames a *receiving* rank discarded on CRC mismatch.
    corrupt_dropped: Mutex<Vec<u64>>,
}

/// The fabric: construct once, then [`Fabric::take_endpoints`] and hand
/// one endpoint to each worker thread.
pub struct Fabric {
    shared: Arc<Shared>,
    endpoints: Vec<Option<Endpoint>>,
}

impl Fabric {
    /// Build a fully connected fabric over `n` ranks.
    pub fn new(n: usize) -> Fabric {
        Self::with_faults(n, FaultPlan::default(), 0)
    }

    /// Build with fault injection (seeded per-endpoint).
    pub fn with_faults(n: usize, faults: FaultPlan, seed: u64) -> Fabric {
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        let shared = Arc::new(Shared {
            senders,
            bytes_sent: Mutex::new(vec![0; n]),
            msgs_sent: Mutex::new(vec![0; n]),
            corrupt_dropped: Mutex::new(vec![0; n]),
        });
        let endpoints = receivers
            .into_iter()
            .enumerate()
            .map(|(rank, rx)| {
                Some(Endpoint {
                    rank,
                    shared: shared.clone(),
                    rx,
                    stash: Vec::new(),
                    latency: None,
                    faults: faults.clone(),
                    rng: Pcg64::seed_from_u64(seed ^ (rank as u64).wrapping_mul(0x9e3779b97f4a7c15)),
                    held: None,
                })
            })
            .collect();
        Fabric { shared, endpoints }
    }

    /// World size.
    pub fn world(&self) -> usize {
        self.endpoints.len()
    }

    /// Move all endpoints out (each worker thread owns one).
    #[allow(clippy::expect_used)] // double-take is a harness bug: crash loudly
    pub fn take_endpoints(&mut self) -> Vec<Endpoint> {
        self.endpoints
            .iter_mut()
            .map(|e| e.take().expect("endpoints already taken"))
            .collect()
    }

    /// Total bytes put on the wire per rank so far (traffic accounting for
    /// the communication-volume comparisons).
    pub fn bytes_sent(&self) -> Vec<u64> {
        locked(&self.shared.bytes_sent).clone()
    }

    /// Total messages sent per rank.
    pub fn msgs_sent(&self) -> Vec<u64> {
        locked(&self.shared.msgs_sent).clone()
    }

    /// Frames each *receiving* rank discarded on CRC mismatch (corrupt
    /// fault injection caught by the framing layer).
    pub fn corrupt_dropped(&self) -> Vec<u64> {
        locked(&self.shared.corrupt_dropped).clone()
    }
}

/// One worker's handle on the fabric.
pub struct Endpoint {
    rank: usize,
    shared: Arc<Shared>,
    rx: Receiver<Message>,
    stash: Vec<Message>,
    latency: Option<(f64, f64)>, // (mu, sigma) log-normal seconds
    faults: FaultPlan,
    rng: Pcg64,
    /// A reorder-faulted message held until the next send (or drop).
    held: Option<(usize, Message)>,
}

impl Drop for Endpoint {
    fn drop(&mut self) {
        // A held (reordered) message is late, never lost: flush it when
        // the endpoint retires without another send.
        if let Some((to, msg)) = self.held.take() {
            let _ = self.shared.senders[to].send(msg);
        }
    }
}

impl Endpoint {
    /// This endpoint's rank.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// World size.
    pub fn world(&self) -> usize {
        self.shared.senders.len()
    }

    /// Enable log-normal latency injection on *outgoing* messages,
    /// parameterized in seconds.
    pub fn set_latency_log_normal(&mut self, mu: f64, sigma: f64) {
        self.latency = Some((mu, sigma));
    }

    /// This rank's wire totals so far: `(bytes_sent, msgs_sent)`. The
    /// same counters [`Fabric::bytes_sent`] / [`Fabric::msgs_sent`]
    /// expose fabric-wide, readable from the worker side — attempted
    /// sends are counted even when fault injection drops them.
    pub fn sent_totals(&self) -> (u64, u64) {
        let bytes = locked(&self.shared.bytes_sent)[self.rank];
        let msgs = locked(&self.shared.msgs_sent)[self.rank];
        (bytes, msgs)
    }

    /// Send `payload` to `to` under `tag`. Fault/latency RNG draws follow
    /// the fixed order documented at module level: drop → latency → dup →
    /// delay → reorder → corrupt, each gated on its knob being active.
    pub fn send(&mut self, to: usize, tag: Tag, payload: Payload) {
        {
            let mut b = locked(&self.shared.bytes_sent);
            b[self.rank] += payload.wire_bytes() as u64;
            let mut m = locked(&self.shared.msgs_sent);
            m[self.rank] += 1;
        }
        // The CRC frames the payload *as intended* — corruption below
        // mutates the payload only, which is what receivers detect.
        let crc = payload_crc(&payload);
        if self.faults.drop_prob > 0.0 && self.rng.next_f64() < self.faults.drop_prob {
            self.release_held();
            return; // dropped on the floor
        }
        let mut deliver_at = self.latency.map(|(mu, sigma)| {
            Instant::now() + Duration::from_secs_f64(self.rng.log_normal(mu, sigma))
        });
        let dup = self.faults.dup_prob > 0.0 && self.rng.next_f64() < self.faults.dup_prob;
        if self.faults.delay_prob > 0.0 && self.rng.next_f64() < self.faults.delay_prob {
            let extra = Duration::from_secs_f64(self.faults.delay_secs.max(0.0));
            deliver_at = Some(deliver_at.unwrap_or_else(Instant::now) + extra);
        }
        let reorder =
            self.faults.reorder_prob > 0.0 && self.rng.next_f64() < self.faults.reorder_prob;
        let mut payload = payload;
        let mut crc = crc;
        if self.faults.corrupt_prob > 0.0 && self.rng.next_f64() < self.faults.corrupt_prob {
            let r = self.rng.next_u64();
            if !corrupt_payload(&mut payload, r) {
                crc ^= 1; // control frame: corrupt the frame check itself
            }
        }
        let msg = Message { from: self.rank, tag, payload: payload.clone(), deliver_at, crc };
        if dup {
            // The duplicate shares the original's deliver_at and (possibly
            // corrupted) payload; a send to a hung-up receiver is not an
            // error for the sender — that worker has already finished.
            let _ = self.shared.senders[to].send(Message {
                from: self.rank,
                tag,
                payload,
                deliver_at,
                crc,
            });
        }
        if reorder {
            // Hold this message until the next send; an already-held one
            // is released first (oldest-first, nothing accumulates).
            self.release_held();
            self.held = Some((to, msg));
        } else {
            let _ = self.shared.senders[to].send(msg);
            self.release_held();
        }
    }

    fn release_held(&mut self) {
        if let Some((to, msg)) = self.held.take() {
            let _ = self.shared.senders[to].send(msg);
        }
    }

    /// Checkpoint-replay send: no wire metering, no fault or latency RNG
    /// draws, immediate delivery. Resume uses this to re-publish retained
    /// offers without double-counting traffic the interrupted run already
    /// metered or perturbing the deterministic fault stream.
    pub fn send_unmetered(&mut self, to: usize, tag: Tag, payload: Payload) {
        let crc = payload_crc(&payload);
        let _ = self.shared.senders[to].send(Message {
            from: self.rank,
            tag,
            payload,
            deliver_at: None,
            crc,
        });
    }

    /// The fault RNG's raw state, for checkpointing mid-run so a resumed
    /// endpoint reproduces the interrupted run's remaining fault stream.
    pub fn fault_rng_state(&self) -> (u128, u128) {
        self.rng.state_parts()
    }

    /// Restore a fault RNG state captured by [`Endpoint::fault_rng_state`].
    pub fn restore_fault_rng(&mut self, state: u128, inc: u128) {
        self.rng = Pcg64::from_state_parts(state, inc);
    }

    /// Reset this rank's shared wire counters to checkpointed totals, so
    /// a resumed run's cumulative metering continues where the
    /// interrupted run left off.
    pub fn restore_sent_totals(&self, bytes: u64, msgs: u64) {
        locked(&self.shared.bytes_sent)[self.rank] = bytes;
        locked(&self.shared.msgs_sent)[self.rank] = msgs;
    }

    /// Verify an incoming frame's CRC; a mismatch counts against this
    /// (receiving) rank and the frame must be discarded by the caller.
    fn frame_ok(&self, msg: &Message) -> bool {
        if msg.crc == payload_crc(&msg.payload) {
            true
        } else {
            locked(&self.shared.corrupt_dropped)[self.rank] += 1;
            false
        }
    }

    /// Drain the channel into the stash, discarding CRC-corrupt frames.
    fn drain_into_stash(&mut self) {
        while let Ok(msg) = self.rx.try_recv() {
            if self.frame_ok(&msg) {
                self.stash.push(msg);
            }
        }
    }

    fn honor_latency(msg: &Message) {
        if let Some(at) = msg.deliver_at {
            let now = Instant::now();
            if at > now {
                std::thread::sleep(at - now);
            }
        }
    }

    /// Blocking receive of the first message matching `tag` (out-of-order
    /// arrivals under other tags are stashed).
    #[allow(clippy::expect_used)] // a hung-up fabric means a peer died: crash loudly
    pub fn recv(&mut self, tag: Tag) -> Message {
        if let Some(i) = self.stash.iter().position(|m| m.tag == tag) {
            let msg = self.stash.swap_remove(i);
            Self::honor_latency(&msg);
            return msg;
        }
        loop {
            let msg = self
                .rx
                .recv()
                .expect("fabric hung up while a recv was outstanding");
            if !self.frame_ok(&msg) {
                continue; // corrupt frame: dropped and counted
            }
            if msg.tag == tag {
                Self::honor_latency(&msg);
                return msg;
            }
            self.stash.push(msg);
        }
    }

    /// Receive matching `tag` with a timeout; `None` on expiry (used by
    /// fault-injection tests).
    pub fn recv_timeout(&mut self, tag: Tag, timeout: Duration) -> Option<Message> {
        if let Some(i) = self.stash.iter().position(|m| m.tag == tag) {
            let msg = self.stash.swap_remove(i);
            Self::honor_latency(&msg);
            return Some(msg);
        }
        let deadline = Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            match self.rx.recv_timeout(left) {
                Ok(msg) if !self.frame_ok(&msg) => {} // corrupt: drop + count
                Ok(msg) if msg.tag == tag => {
                    Self::honor_latency(&msg);
                    return Some(msg);
                }
                Ok(msg) => self.stash.push(msg),
                Err(RecvTimeoutError::Timeout) => return None,
                Err(RecvTimeoutError::Disconnected) => return None,
            }
        }
    }

    /// Return a received message to the stash (e.g. one half of a
    /// two-part payload whose sibling has not arrived yet — the caller
    /// backs off without losing what was already delivered).
    pub fn stash_back(&mut self, msg: Message) {
        self.stash.push(msg);
    }

    /// Truly non-blocking receive: drain the channel into the stash,
    /// then take a matching message only if its injected delivery
    /// instant has passed. A matched-but-not-yet-deliverable message
    /// stays stashed and `None` is returned — unlike
    /// [`Endpoint::recv_timeout`], this never sleeps on the latency
    /// model, which is what the polling paths (heartbeats, staleness
    /// fallback probes) require.
    pub fn try_recv_ready(&mut self, tag: Tag) -> Option<Message> {
        self.drain_into_stash();
        let now = Instant::now();
        let i = self.stash.iter().position(|m| {
            m.tag == tag
                && match m.deliver_at {
                    None => true,
                    Some(at) => at <= now,
                }
        })?;
        Some(self.stash.swap_remove(i))
    }

    /// Like [`Endpoint::try_recv_ready`], but *leaves the message in the
    /// stash* and returns a clone of its payload — for offers that must
    /// stay readable for a retention window (the bounded-staleness
    /// collects re-admit a peer's older offer at later boundaries; the
    /// stash-expiry sweep reclaims them).
    pub fn peek_ready(&mut self, tag: Tag) -> Option<Payload> {
        self.drain_into_stash();
        let now = Instant::now();
        self.stash
            .iter()
            .find(|m| {
                m.tag == tag
                    && match m.deliver_at {
                        None => true,
                        Some(at) => at <= now,
                    }
            })
            .map(|m| m.payload.clone())
    }

    /// Drain the channel into the stash (non-blocking), then drop every
    /// stashed message whose tag fails `keep`; returns how many were
    /// dropped. This is the stash-expiry hook: fragment, gossip and
    /// heartbeat messages that were never collected — churn-dropped
    /// folds, straggler timeouts, suppressed receivers — would otherwise
    /// sit in the stash for the rest of the run. Callers sweep with a
    /// tag-age predicate at a cadence of their choosing (the trainers
    /// sweep once per outer boundary).
    pub fn sweep_stash<F: FnMut(&Tag) -> bool>(&mut self, mut keep: F) -> usize {
        self.drain_into_stash();
        let before = self.stash.len();
        self.stash.retain(|m| keep(&m.tag));
        before - self.stash.len()
    }

    /// Receive any message (FIFO across stash + channel).
    #[allow(clippy::expect_used)] // a hung-up fabric means a peer died: crash loudly
    pub fn recv_any(&mut self) -> Message {
        if !self.stash.is_empty() {
            let msg = self.stash.remove(0);
            Self::honor_latency(&msg);
            return msg;
        }
        loop {
            let msg = self.rx.recv().expect("fabric hung up");
            if !self.frame_ok(&msg) {
                continue; // corrupt frame: dropped and counted
            }
            Self::honor_latency(&msg);
            return msg;
        }
    }
}

/// The tag-matched stash discipline a communicator runs over, abstracted
/// from the transport that delivers the messages. [`Endpoint`] (in-process
/// mpsc channels) and [`SocketEndpoint`](crate::net::SocketEndpoint)
/// (TCP framing) both implement it, so
/// [`FabricComm`](crate::train::FabricComm)'s protocol logic — two-phase
/// offers, windowed round retention, non-blocking heartbeat polls,
/// expiry sweeps, unmetered checkpoint replay — is written once against
/// this trait instead of forked per transport.
pub trait Channel {
    /// Executor name for reports ("threaded" / "socket").
    fn executor_name(&self) -> &'static str;
    /// This channel's rank in the world.
    fn rank(&self) -> usize;
    /// Send `payload` to rank `to` under `tag` (metered).
    fn send(&mut self, to: usize, tag: Tag, payload: Payload);
    /// Checkpoint-replay send: no metering, no fault draws.
    fn send_unmetered(&mut self, to: usize, tag: Tag, payload: Payload);
    /// Blocking receive of the first message matching `tag`.
    fn recv(&mut self, tag: Tag) -> Message;
    /// Receive matching `tag` with a timeout; `None` on expiry.
    fn recv_timeout(&mut self, tag: Tag, timeout: Duration) -> Option<Message>;
    /// Truly non-blocking receive (never sleeps, not even on latency).
    fn try_recv_ready(&mut self, tag: Tag) -> Option<Message>;
    /// Non-blocking payload peek that leaves the message stashed.
    fn peek_ready(&mut self, tag: Tag) -> Option<Payload>;
    /// Return a received message to the stash.
    fn stash_back(&mut self, msg: Message);
    /// Drop stashed messages whose tag fails `keep`; returns the count.
    fn sweep_stash(&mut self, keep: &mut dyn FnMut(&Tag) -> bool) -> usize;
    /// This rank's wire totals so far: `(bytes_sent, msgs_sent)`.
    fn sent_totals(&self) -> (u64, u64);
    /// Reset the wire counters to checkpointed totals.
    fn restore_sent_totals(&mut self, bytes: u64, msgs: u64);
    /// Fault-RNG stream `(state, inc)`, when the transport injects faults.
    fn fault_rng_state(&self) -> Option<(u128, u128)> {
        None
    }
    /// Restore a checkpointed fault-RNG stream (no-op by default).
    fn restore_fault_rng(&mut self, state: u128, inc: u128) {
        let _ = (state, inc);
    }
}

impl Channel for Endpoint {
    fn executor_name(&self) -> &'static str {
        "threaded"
    }

    fn rank(&self) -> usize {
        Endpoint::rank(self)
    }

    fn send(&mut self, to: usize, tag: Tag, payload: Payload) {
        Endpoint::send(self, to, tag, payload);
    }

    fn send_unmetered(&mut self, to: usize, tag: Tag, payload: Payload) {
        Endpoint::send_unmetered(self, to, tag, payload);
    }

    fn recv(&mut self, tag: Tag) -> Message {
        Endpoint::recv(self, tag)
    }

    fn recv_timeout(&mut self, tag: Tag, timeout: Duration) -> Option<Message> {
        Endpoint::recv_timeout(self, tag, timeout)
    }

    fn try_recv_ready(&mut self, tag: Tag) -> Option<Message> {
        Endpoint::try_recv_ready(self, tag)
    }

    fn peek_ready(&mut self, tag: Tag) -> Option<Payload> {
        Endpoint::peek_ready(self, tag)
    }

    fn stash_back(&mut self, msg: Message) {
        Endpoint::stash_back(self, msg);
    }

    fn sweep_stash(&mut self, keep: &mut dyn FnMut(&Tag) -> bool) -> usize {
        Endpoint::sweep_stash(self, keep)
    }

    fn sent_totals(&self) -> (u64, u64) {
        Endpoint::sent_totals(self)
    }

    fn restore_sent_totals(&mut self, bytes: u64, msgs: u64) {
        Endpoint::restore_sent_totals(self, bytes, msgs);
    }

    fn fault_rng_state(&self) -> Option<(u128, u128)> {
        Some(Endpoint::fault_rng_state(self))
    }

    fn restore_fault_rng(&mut self, state: u128, inc: u128) {
        Endpoint::restore_fault_rng(self, state, inc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn point_to_point_roundtrip() {
        let mut f = Fabric::new(2);
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t = thread::spawn(move || {
            e1.send(0, Tag::new(1, 0, 0), Payload::F32(vec![1.0, 2.0]));
            let m = e1.recv(Tag::new(2, 0, 0));
            assert_eq!(m.payload.u32(), &[7, 8, 9]);
        });
        let m = e0.recv(Tag::new(1, 0, 0));
        assert_eq!(m.from, 1);
        assert_eq!(m.payload.f32(), &[1.0, 2.0]);
        e0.send(1, Tag::new(2, 0, 0), Payload::U32(vec![7, 8, 9]));
        t.join().unwrap();
    }

    #[test]
    fn out_of_order_tags_are_stashed() {
        let mut f = Fabric::new(2);
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, Tag::new(9, 0, 0), Payload::Control); // noise first
        e1.send(0, Tag::new(5, 1, 2), Payload::F32(vec![3.0]));
        let m = e0.recv(Tag::new(5, 1, 2));
        assert_eq!(m.payload.f32(), &[3.0]);
        let n = e0.recv(Tag::new(9, 0, 0));
        assert_eq!(n.payload, Payload::Control);
    }

    #[test]
    fn traffic_accounting() {
        let mut f = Fabric::new(2);
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let _e0 = eps.pop().unwrap();
        e1.send(0, Tag::new(1, 0, 0), Payload::F32(vec![0.0; 100]));
        assert_eq!(f.bytes_sent()[1], 400);
        assert_eq!(f.msgs_sent()[1], 1);
        assert_eq!(f.bytes_sent()[0], 0);
        // The worker-side view agrees with the fabric-wide counters.
        assert_eq!(e1.sent_totals(), (400, 1));
        assert_eq!(_e0.sent_totals(), (0, 0));
    }

    #[test]
    fn drops_cause_timeouts() {
        let mut f = Fabric::with_faults(
            2,
            FaultPlan { drop_prob: 1.0, ..FaultPlan::none() },
            3,
        );
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, Tag::new(1, 0, 0), Payload::Control);
        assert!(e0
            .recv_timeout(Tag::new(1, 0, 0), Duration::from_millis(20))
            .is_none());
    }

    #[test]
    fn duplicates_are_observable_and_matchable() {
        let mut f = Fabric::with_faults(
            2,
            FaultPlan { dup_prob: 1.0, ..FaultPlan::none() },
            4,
        );
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, Tag::new(1, 0, 0), Payload::Control);
        assert!(e0
            .recv_timeout(Tag::new(1, 0, 0), Duration::from_millis(20))
            .is_some());
        assert!(e0
            .recv_timeout(Tag::new(1, 0, 0), Duration::from_millis(20))
            .is_some());
    }

    #[test]
    fn fault_plan_none_is_fault_free() {
        assert!(FaultPlan::none().is_none());
        assert_eq!(FaultPlan::none(), FaultPlan::default());
        assert!(!FaultPlan { drop_prob: 0.1, ..FaultPlan::none() }.is_none());
        assert!(!FaultPlan { dup_prob: 0.1, ..FaultPlan::none() }.is_none());
        assert!(!FaultPlan { delay_prob: 0.1, ..FaultPlan::none() }.is_none());
        assert!(!FaultPlan { reorder_prob: 0.1, ..FaultPlan::none() }.is_none());
        assert!(!FaultPlan { corrupt_prob: 0.1, ..FaultPlan::none() }.is_none());
    }

    #[test]
    fn drop_beats_duplicate_when_both_certain() {
        // Composition rule from the module docs: P(any delivery) =
        // 1 - p_drop, regardless of dup_prob. With p_drop = 1 every
        // message dies even though dup_prob = 1.
        let mut f = Fabric::with_faults(
            2,
            FaultPlan { drop_prob: 1.0, dup_prob: 1.0, ..FaultPlan::none() },
            11,
        );
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        for k in 0..8u32 {
            e1.send(0, Tag::new(1, k, 0), Payload::Control);
        }
        for k in 0..8u32 {
            assert!(e0
                .recv_timeout(Tag::new(1, k, 0), Duration::from_millis(5))
                .is_none());
        }
        // Traffic accounting still counts the attempted sends.
        assert_eq!(f.msgs_sent()[1], 8);
    }

    #[test]
    fn mixed_drop_dup_is_deterministic_per_seed() {
        // Same seed ⇒ identical per-message delivery multiset across runs,
        // independent of wall-clock scheduling (sender-side decisions).
        let deliveries = |seed: u64| -> Vec<usize> {
            let mut f = Fabric::with_faults(
                2,
                FaultPlan { drop_prob: 0.4, dup_prob: 0.4, ..FaultPlan::none() },
                seed,
            );
            let mut eps = f.take_endpoints();
            let mut e1 = eps.pop().unwrap();
            let mut e0 = eps.pop().unwrap();
            let n = 32u32;
            for k in 0..n {
                e1.send(0, Tag::new(1, k, 0), Payload::Control);
            }
            (0..n)
                .map(|k| {
                    let mut copies = 0;
                    while e0
                        .recv_timeout(Tag::new(1, k, 0), Duration::from_millis(5))
                        .is_some()
                    {
                        copies += 1;
                    }
                    copies
                })
                .collect()
        };
        let a = deliveries(99);
        let b = deliveries(99);
        assert_eq!(a, b, "same seed must reproduce the same fault pattern");
        // With these probabilities all three outcomes should occur.
        assert!(a.iter().any(|&c| c == 0), "no drop observed");
        assert!(a.iter().any(|&c| c == 1), "no single delivery observed");
        assert!(a.iter().any(|&c| c == 2), "no duplicate observed");
        let c = deliveries(100);
        assert_ne!(a, c, "different seeds should differ");
    }

    #[test]
    fn sweep_stash_drops_only_unkept_tags() {
        let mut f = Fabric::new(2);
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, Tag::new(7, 1, 0), Payload::Control); // old round
        e1.send(0, Tag::new(7, 5, 0), Payload::Control); // fresh round
        e1.send(0, Tag::new(8, 1, 0), Payload::F32(vec![1.0])); // other kind
        // Sweep: keep kind 7 only when its round is recent, keep the rest.
        let dropped = e0.sweep_stash(|t| t.kind != 7 || t.a >= 4);
        assert_eq!(dropped, 1);
        // The fresh round and the other-kind message are still matchable.
        assert!(e0
            .recv_timeout(Tag::new(7, 5, 0), Duration::from_millis(20))
            .is_some());
        assert!(e0
            .recv_timeout(Tag::new(8, 1, 0), Duration::from_millis(20))
            .is_some());
        // The expired one is gone.
        assert!(e0
            .recv_timeout(Tag::new(7, 1, 0), Duration::from_millis(5))
            .is_none());
    }

    #[test]
    fn delay_fault_is_deterministic_per_seed() {
        // Which messages get the extra delay is a sender-side RNG
        // decision: same seed ⇒ same delayed set; the delayed ones are
        // not ready immediately but are never lost.
        let delayed_set = |seed: u64| -> Vec<bool> {
            let mut f = Fabric::with_faults(
                2,
                FaultPlan { delay_prob: 0.5, delay_secs: 0.3, ..FaultPlan::none() },
                seed,
            );
            let mut eps = f.take_endpoints();
            let mut e1 = eps.pop().unwrap();
            let mut e0 = eps.pop().unwrap();
            let n = 16u32;
            for k in 0..n {
                e1.send(0, Tag::new(1, k, 0), Payload::Control);
            }
            // A non-delayed message is ready at once; a delayed one is
            // visible in the stash but not deliverable yet.
            let pattern: Vec<bool> = (0..n)
                .map(|k| e0.try_recv_ready(Tag::new(1, k, 0)).is_none())
                .collect();
            // Nothing is lost: blocking recv honors the delay and returns.
            for (k, &was_delayed) in pattern.iter().enumerate() {
                if was_delayed {
                    let m = e0.recv(Tag::new(1, k as u32, 0));
                    assert_eq!(m.payload, Payload::Control);
                }
            }
            pattern
        };
        let a = delayed_set(21);
        assert!(a.iter().any(|&d| d), "no delay observed");
        assert!(a.iter().any(|&d| !d), "everything delayed");
        assert_eq!(a, delayed_set(21), "same seed must reproduce the delayed set");
        assert_ne!(a, delayed_set(22), "different seeds should differ");
    }

    #[test]
    fn reorder_fault_is_deterministic_per_seed() {
        // A reordered message is released right after its sender's next
        // send — a deterministic adjacent swap. Same seed ⇒ same arrival
        // order at the receiver (single sender, so channel FIFO order is
        // exactly the sender's release order).
        let arrival_order = |seed: u64| -> Vec<u32> {
            let mut f = Fabric::with_faults(
                2,
                FaultPlan { reorder_prob: 0.5, ..FaultPlan::none() },
                seed,
            );
            let mut eps = f.take_endpoints();
            let mut e1 = eps.pop().unwrap();
            let mut e0 = eps.pop().unwrap();
            let n = 32u32;
            for k in 0..n {
                e1.send(0, Tag::new(1, k, 0), Payload::Control);
            }
            drop(e1); // flush a trailing held message
            (0..n).map(|_| e0.recv_any().tag.a).collect()
        };
        let a = arrival_order(31);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..32).collect::<Vec<u32>>(), "reorder must not lose frames");
        assert_ne!(a, (0..32).collect::<Vec<u32>>(), "no reorder observed");
        assert_eq!(a, arrival_order(31), "same seed must reproduce the order");
        assert_ne!(a, arrival_order(32), "different seeds should differ");
    }

    #[test]
    fn corrupt_frames_are_dropped_and_counted() {
        let mut f = Fabric::with_faults(
            2,
            FaultPlan { corrupt_prob: 1.0, ..FaultPlan::none() },
            5,
        );
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        for k in 0..4u32 {
            e1.send(0, Tag::new(1, k, 0), Payload::F32(vec![1.0, 2.0, 3.0]));
        }
        // Control frames have no payload bits; the CRC field itself is
        // corrupted and the framing check catches that the same way.
        e1.send(0, Tag::new(2, 0, 0), Payload::Control);
        for k in 0..4u32 {
            assert!(e0
                .recv_timeout(Tag::new(1, k, 0), Duration::from_millis(10))
                .is_none());
        }
        assert!(e0
            .recv_timeout(Tag::new(2, 0, 0), Duration::from_millis(10))
            .is_none());
        // Dropped-and-counted at the *receiving* rank; sends were metered.
        assert_eq!(f.corrupt_dropped()[0], 5);
        assert_eq!(f.corrupt_dropped()[1], 0);
        assert_eq!(f.msgs_sent()[1], 5);
    }

    #[test]
    fn corrupt_pattern_is_deterministic_per_seed() {
        let survivors = |seed: u64| -> Vec<bool> {
            let mut f = Fabric::with_faults(
                2,
                FaultPlan { corrupt_prob: 0.4, ..FaultPlan::none() },
                seed,
            );
            let mut eps = f.take_endpoints();
            let mut e1 = eps.pop().unwrap();
            let mut e0 = eps.pop().unwrap();
            let n = 24u32;
            for k in 0..n {
                e1.send(0, Tag::new(1, k, 0), Payload::U32(vec![k; 8]));
            }
            (0..n)
                .map(|k| {
                    e0.recv_timeout(Tag::new(1, k, 0), Duration::from_millis(5))
                        .is_some()
                })
                .collect()
        };
        let a = survivors(77);
        assert!(a.iter().any(|&s| s), "everything corrupted");
        assert!(a.iter().any(|&s| !s), "no corruption observed");
        assert_eq!(a, survivors(77), "same seed must reproduce the corrupt set");
        assert_ne!(a, survivors(78), "different seeds should differ");
    }

    #[test]
    fn clean_frames_pass_crc_verification() {
        // Fault-free fabric: framing is transparent — every payload kind
        // round-trips and nothing is counted as corrupt.
        let mut f = Fabric::new(2);
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.send(0, Tag::new(1, 0, 0), Payload::F32(vec![1.5, -2.5]));
        e1.send(0, Tag::new(2, 0, 0), Payload::U32(vec![9, 9]));
        e1.send(0, Tag::new(3, 0, 0), Payload::Control);
        assert_eq!(e0.recv(Tag::new(1, 0, 0)).payload.f32(), &[1.5, -2.5]);
        assert_eq!(e0.recv(Tag::new(2, 0, 0)).payload.u32(), &[9, 9]);
        assert_eq!(e0.recv(Tag::new(3, 0, 0)).payload, Payload::Control);
        assert_eq!(f.corrupt_dropped(), vec![0, 0]);
    }

    #[test]
    fn unmetered_send_skips_faults_and_counters() {
        // The checkpoint-replay path must deliver even on a fabric whose
        // fault plan would drop everything, and must not advance the
        // fault RNG or the wire counters.
        let mut f = Fabric::with_faults(
            2,
            FaultPlan { drop_prob: 1.0, ..FaultPlan::none() },
            9,
        );
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let rng_before = e1.fault_rng_state();
        e1.send_unmetered(0, Tag::new(1, 0, 0), Payload::F32(vec![4.0]));
        assert_eq!(e1.fault_rng_state(), rng_before);
        assert_eq!(e1.sent_totals(), (0, 0));
        let m = e0.recv(Tag::new(1, 0, 0));
        assert_eq!(m.payload.f32(), &[4.0]);
    }

    #[test]
    fn fault_rng_state_restores_mid_stream() {
        // Run A: 16 faulty sends, recording deliveries of the back half.
        // Run B: restore the fault RNG captured after A's front half and
        // send only the back half — the delivery pattern must match,
        // which is what makes checkpoint/resume fault-stream exact.
        let plan = FaultPlan { drop_prob: 0.5, dup_prob: 0.3, ..FaultPlan::none() };
        let copies = |e0: &mut Endpoint, k: u32| -> usize {
            let mut c = 0;
            while e0
                .recv_timeout(Tag::new(1, k, 0), Duration::from_millis(5))
                .is_some()
            {
                c += 1;
            }
            c
        };
        let mut fa = Fabric::with_faults(2, plan.clone(), 13);
        let mut eps = fa.take_endpoints();
        let mut a1 = eps.pop().unwrap();
        let mut a0 = eps.pop().unwrap();
        for k in 0..8u32 {
            a1.send(0, Tag::new(1, k, 0), Payload::Control);
        }
        let mid_state = a1.fault_rng_state();
        for k in 8..16u32 {
            a1.send(0, Tag::new(1, k, 0), Payload::Control);
        }
        let tail_a: Vec<usize> = (8..16).map(|k| copies(&mut a0, k)).collect();

        let mut fb = Fabric::with_faults(2, plan, 999); // different seed on purpose
        let mut eps = fb.take_endpoints();
        let mut b1 = eps.pop().unwrap();
        let mut b0 = eps.pop().unwrap();
        b1.restore_fault_rng(mid_state.0, mid_state.1);
        for k in 8..16u32 {
            b1.send(0, Tag::new(1, k, 0), Payload::Control);
        }
        let tail_b: Vec<usize> = (8..16).map(|k| copies(&mut b0, k)).collect();
        assert_eq!(tail_a, tail_b, "restored RNG must continue the fault stream");
    }

    #[test]
    fn restored_wire_totals_continue_cumulatively() {
        let mut f = Fabric::new(2);
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let _e0 = eps.pop().unwrap();
        e1.restore_sent_totals(1000, 7);
        e1.send(0, Tag::new(1, 0, 0), Payload::F32(vec![0.0; 25]));
        assert_eq!(e1.sent_totals(), (1100, 8));
        assert_eq!(f.bytes_sent()[1], 1100);
    }

    #[test]
    fn latency_injection_delays_delivery() {
        let mut f = Fabric::new(2);
        let mut eps = f.take_endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // LogNormal(ln(0.03), ~0) ≈ constant 30 ms.
        e1.set_latency_log_normal((0.03f64).ln(), 1e-6);
        let t0 = Instant::now();
        e1.send(0, Tag::new(1, 0, 0), Payload::Control);
        e0.recv(Tag::new(1, 0, 0));
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }
}
