//! Theorem-1 convergence harness (App. A).
//!
//! The paper analyzes the modified Nesterov outer optimizer on the
//! stochastic quadratic loss
//!
//! ```text
//! L(θ) = ½ (θ − c)ᵀ A (θ − c),   c ~ N(0, Σ),   A ≻ 0 symmetric
//! ```
//!
//! with SGD (constant rate ω) as the inner optimizer, and proves
//!
//! * **E(φ_{t,i}) → 0** as t → ∞ (Theorem 2), given β > α and
//!   0 < ωΛ_i ≤ 1,
//! * **V(φ_{t,i}) ∝ ω²** at stationarity (Theorem 3), provided γ sits in
//!   the Eq. 74 window.
//!
//! This module instantiates that exact setting — N replicas, random
//! gossip pairs, m inner SGD steps per outer step — so both claims are
//! checked numerically (tests here; full sweep in
//! `examples/quadratic_convergence.rs`).

use crate::config::OuterConfig;
use crate::optim::{NolocoOuter, OuterState, Sgd};
use crate::rngx::Pcg64;
use crate::tensor::Tensor;

/// Problem instance: diagonalized SPD quadratic with noise.
///
/// We generate `A = Q Λ Qᵀ` from chosen eigenvalues Λ and a random
/// orthogonal Q (so the spectrum — what convergence depends on — is
/// controlled exactly), and `Σ = σ_c² I`.
#[derive(Clone, Debug)]
pub struct Quadratic {
    /// Dimension.
    pub dim: usize,
    /// Eigenvalues of A (all > 0).
    pub eig: Vec<f64>,
    /// Orthogonal basis, row-major `dim × dim`.
    q: Vec<f64>,
    /// Std of the noise vector c.
    pub c_std: f64,
}

impl Quadratic {
    /// Build with log-uniform eigenvalues in `[eig_min, eig_max]`.
    pub fn new(dim: usize, eig_min: f64, eig_max: f64, c_std: f64, rng: &mut Pcg64) -> Quadratic {
        assert!(eig_min > 0.0 && eig_max >= eig_min);
        let eig: Vec<f64> = (0..dim)
            .map(|i| {
                let t = if dim == 1 { 0.0 } else { i as f64 / (dim - 1) as f64 };
                (eig_min.ln() + t * (eig_max.ln() - eig_min.ln())).exp()
            })
            .collect();
        let q = random_orthogonal(dim, rng);
        Quadratic { dim, eig, q, c_std }
    }

    /// `y = A x` via `Q Λ Qᵀ x`.
    pub fn apply_a(&self, x: &[f64]) -> Vec<f64> {
        let d = self.dim;
        // u = Qᵀ x
        let mut u = vec![0.0; d];
        for i in 0..d {
            for j in 0..d {
                u[j] += self.q[i * d + j] * x[i];
            }
        }
        for (uj, l) in u.iter_mut().zip(&self.eig) {
            *uj *= l;
        }
        // y = Q u
        let mut y = vec![0.0; d];
        for i in 0..d {
            for j in 0..d {
                y[i] += self.q[i * d + j] * u[j];
            }
        }
        y
    }

    /// Stochastic gradient at θ: `A(θ − c)` with a fresh draw of c.
    pub fn grad(&self, theta: &[f64], rng: &mut Pcg64) -> Vec<f64> {
        let mut tc: Vec<f64> = theta.to_vec();
        for t in tc.iter_mut() {
            *t -= rng.normal(0.0, self.c_std);
        }
        self.apply_a(&tc)
    }

    /// Deterministic loss at θ with c = 0 (distance-to-optimum measure).
    pub fn loss(&self, theta: &[f64]) -> f64 {
        let at = self.apply_a(theta);
        0.5 * theta.iter().zip(&at).map(|(a, b)| a * b).sum::<f64>()
    }
}

/// Random orthogonal matrix by Gram–Schmidt on a Gaussian matrix.
fn random_orthogonal(d: usize, rng: &mut Pcg64) -> Vec<f64> {
    let mut m: Vec<f64> = (0..d * d).map(|_| rng.next_normal()).collect();
    for i in 0..d {
        // Orthogonalize row i against previous rows.
        for k in 0..i {
            let dot: f64 = (0..d).map(|j| m[i * d + j] * m[k * d + j]).sum();
            for j in 0..d {
                m[i * d + j] -= dot * m[k * d + j];
            }
        }
        let norm: f64 = (0..d).map(|j| m[i * d + j] * m[i * d + j]).sum::<f64>().sqrt();
        assert!(norm > 1e-12, "degenerate Gram–Schmidt");
        for j in 0..d {
            m[i * d + j] /= norm;
        }
    }
    m
}

/// Result of one simulated NoLoCo run on the quadratic.
#[derive(Clone, Debug)]
pub struct QuadRunResult {
    /// ‖mean_i φ_i‖ per outer step — should → 0 (Theorem 2).
    pub mean_norm: Vec<f64>,
    /// Mean per-coordinate variance across replicas per outer step —
    /// should plateau ∝ ω² (Theorem 3).
    pub replica_var: Vec<f64>,
    /// Mean deterministic loss of the replicas at the end.
    pub final_loss: f64,
}

/// Simulation parameters.
#[derive(Clone, Debug)]
pub struct QuadSim {
    /// Replica count N.
    pub replicas: usize,
    /// Inner SGD steps per outer step, m.
    pub inner_steps: usize,
    /// Outer steps, T.
    pub outer_steps: usize,
    /// Inner learning rate ω.
    pub omega: f64,
    /// Outer hyper-parameters (α, β, γ, group n).
    pub outer: OuterConfig,
    /// Initial distance from the optimum.
    pub init_scale: f64,
}

/// Run NoLoCo (random gossip pairs) on the quadratic; returns trajectories
/// of the Theorem-1 quantities.
pub fn run_noloco(problem: &Quadratic, sim: &QuadSim, seed: u64) -> QuadRunResult {
    let mut rng = Pcg64::seed_from_u64(seed);
    let d = problem.dim;
    let n = sim.replicas;
    // All replicas start from the same point (App. B: φ_{0,i} ≡ φ₀).
    let init: Vec<f64> = (0..d).map(|_| rng.normal(0.0, sim.init_scale)).collect();
    let init_t = Tensor::from_vec(init.iter().map(|&x| x as f32).collect(), &[d]);
    let mut states: Vec<OuterState> = (0..n)
        .map(|_| OuterState::new(std::slice::from_ref(&init_t)))
        .collect();
    let mut worker_rngs: Vec<Pcg64> = (0..n).map(|_| rng.split()).collect();
    let opt = NolocoOuter {
        alpha: sim.outer.alpha,
        beta: sim.outer.beta,
        gamma: sim.outer.gamma,
    };
    let sgd = Sgd::new(sim.omega);

    let mut mean_norm = Vec::with_capacity(sim.outer_steps);
    let mut replica_var = Vec::with_capacity(sim.outer_steps);

    for _t in 0..sim.outer_steps {
        // Inner phase: each replica runs m SGD steps from its φ.
        let mut thetas: Vec<Vec<Tensor>> = Vec::with_capacity(n);
        for (i, st) in states.iter().enumerate() {
            let mut theta = st.phi.clone();
            for _ in 0..sim.inner_steps {
                let th64: Vec<f64> = theta[0].as_slice().iter().map(|&x| x as f64).collect();
                let g = problem.grad(&th64, &mut worker_rngs[i]);
                let gt = Tensor::from_vec(g.iter().map(|&x| x as f32).collect(), &[d]);
                sgd.step(&mut theta, std::slice::from_ref(&gt));
            }
            thetas.push(theta);
        }
        // Outer phase: random disjoint pairs; both members of a pair apply
        // the group update with the shared (Δ, φ) pool. Odd replica out
        // (if any) steps with itself as the whole group.
        let deltas: Vec<Vec<Tensor>> = states
            .iter()
            .zip(&thetas)
            .map(|(st, th)| st.outer_grad(th))
            .collect();
        let phis: Vec<Vec<Tensor>> = states.iter().map(|s| s.phi.clone()).collect();
        for (a, b) in rng.random_pairs(n) {
            match b {
                Some(b) => {
                    let gd = [deltas[a].clone(), deltas[b].clone()];
                    let gp = [phis[a].clone(), phis[b].clone()];
                    states[a].step_group_with(&opt, &thetas[a], &gd, &gp);
                    states[b].step_group_with(&opt, &thetas[b], &gd, &gp);
                }
                None => {
                    let gd = [deltas[a].clone()];
                    let gp = [phis[a].clone()];
                    states[a].step_group_with(&opt, &thetas[a], &gd, &gp);
                }
            }
        }
        // Metrics.
        let mut mean = vec![0.0f64; d];
        for st in &states {
            for (m, x) in mean.iter_mut().zip(st.phi[0].as_slice()) {
                *m += *x as f64 / n as f64;
            }
        }
        mean_norm.push(mean.iter().map(|x| x * x).sum::<f64>().sqrt());
        let mut var = 0.0f64;
        for j in 0..d {
            let mu = mean[j];
            let v: f64 = states
                .iter()
                .map(|st| {
                    let x = st.phi[0].as_slice()[j] as f64 - mu;
                    x * x
                })
                .sum::<f64>()
                / n as f64;
            var += v / d as f64;
        }
        replica_var.push(var);
    }
    let final_loss = states
        .iter()
        .map(|st| {
            let th: Vec<f64> = st.phi[0].as_slice().iter().map(|&x| x as f64).collect();
            problem.loss(&th)
        })
        .sum::<f64>()
        / n as f64;
    QuadRunResult {
        mean_norm,
        replica_var,
        final_loss,
    }
}

impl OuterState {
    /// Helper so the harness can call the group update without borrowing
    /// gymnastics (wraps [`NolocoOuter::step_group`]).
    pub fn step_group_with(
        &mut self,
        opt: &NolocoOuter,
        theta: &[Tensor],
        group_deltas: &[Vec<Tensor>],
        group_phis: &[Vec<Tensor>],
    ) {
        opt.step_group(self, theta, group_deltas, group_phis);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn default_sim(omega: f64, gamma: f64) -> QuadSim {
        QuadSim {
            replicas: 8,
            inner_steps: 10,
            outer_steps: 120,
            omega,
            outer: OuterConfig {
                method: crate::config::Method::NoLoCo,
                alpha: 0.5,
                beta: 0.7,
                gamma,
                group: 2,
                inner_steps: 10,
                staleness: 1,
            },
            init_scale: 2.0,
        }
    }

    fn problem(seed: u64) -> Quadratic {
        let mut rng = Pcg64::seed_from_u64(seed);
        Quadratic::new(6, 0.2, 1.0, 0.5, &mut rng)
    }

    #[test]
    fn orthogonal_basis_is_orthonormal() {
        let mut rng = Pcg64::seed_from_u64(31);
        let d = 8;
        let q = random_orthogonal(d, &mut rng);
        for i in 0..d {
            for k in 0..d {
                let dot: f64 = (0..d).map(|j| q[i * d + j] * q[k * d + j]).sum();
                let want = if i == k { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-9, "rows {i},{k}: {dot}");
            }
        }
    }

    #[test]
    fn apply_a_is_spd() {
        let p = problem(32);
        let mut rng = Pcg64::seed_from_u64(33);
        for _ in 0..20 {
            let x: Vec<f64> = (0..p.dim).map(|_| rng.next_normal()).collect();
            let ax = p.apply_a(&x);
            let xtax: f64 = x.iter().zip(&ax).map(|(a, b)| a * b).sum();
            if x.iter().map(|v| v * v).sum::<f64>() > 1e-9 {
                assert!(xtax > 0.0, "not positive definite: {xtax}");
            }
        }
    }

    #[test]
    fn theorem2_mean_converges_to_zero() {
        let p = problem(34);
        let r = run_noloco(&p, &default_sim(0.1, 0.9), 7);
        let start = r.mean_norm[0];
        let end = *r.mean_norm.last().unwrap();
        assert!(end < 0.05 * start, "start={start} end={end}");
    }

    #[test]
    fn theorem3_variance_scales_as_omega_squared() {
        // Quartering ω should cut stationary replica variance ~16×
        // (V ∝ ω², Theorem 3). The ω² law is the leading order as ω → 0,
        // so the test runs in the small-ωΛm regime (ωΛm ≪ 1) where it is
        // not masked by the O(ω³) contraction terms; averaged over seeds
        // to beat finite-ensemble noise.
        let mut prng = Pcg64::seed_from_u64(35);
        let p = Quadratic::new(6, 0.05, 0.2, 0.5, &mut prng);
        let var_at = |omega: f64| {
            let mut acc = 0.0;
            let seeds = [11u64, 12, 13];
            for &s in &seeds {
                let mut sim = default_sim(omega, 0.9);
                sim.replicas = 16;
                sim.outer_steps = 250;
                let r = run_noloco(&p, &sim, s);
                let tail = &r.replica_var[r.replica_var.len() * 3 / 4..];
                acc += tail.iter().sum::<f64>() / tail.len() as f64;
            }
            acc / seeds.len() as f64
        };
        let v1 = var_at(0.1);
        let v2 = var_at(0.025);
        let ratio = v1 / v2;
        assert!(
            (8.0..32.0).contains(&ratio),
            "variance ratio {ratio} not ≈ 16 (v1={v1:.3e} v2={v2:.3e})"
        );
    }

    #[test]
    fn gamma_outside_window_diverges_or_stagnates() {
        // γ above the Eq. 74 upper bound must not out-converge a valid γ;
        // in practice the consensus oscillation inflates variance.
        let p = problem(36);
        let (_, hi) = OuterConfig::gamma_window(0.5, 2);
        let good = run_noloco(&p, &default_sim(0.1, 0.9), 13);
        let bad = run_noloco(&p, &default_sim(0.1, hi * 1.35), 13);
        let tail = |r: &QuadRunResult| {
            let t = &r.replica_var[r.replica_var.len() * 3 / 4..];
            t.iter().sum::<f64>() / t.len() as f64
        };
        assert!(
            tail(&bad) > tail(&good),
            "unstable γ should inflate replica variance: bad={:.3e} good={:.3e}",
            tail(&bad),
            tail(&good)
        );
    }

    #[test]
    fn final_loss_improves_over_initialization() {
        let p = problem(37);
        let sim = default_sim(0.1, 0.9);
        let r = run_noloco(&p, &sim, 17);
        // Loss at init_scale-sized random point is O(eig * scale²); after
        // training it should be far below that.
        assert!(r.final_loss < 0.1, "final_loss={}", r.final_loss);
    }
}
