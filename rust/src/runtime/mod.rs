//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only module that touches the `xla` crate. The Python side
//! (`python/compile/aot.py`) lowers every training-time function to HLO
//! *text* — the id-safe interchange format for the pinned xla_extension
//! 0.5.1 (see /opt/xla-example/README.md) — into one directory per
//! `(model, pp, microbatch)` build. [`Engine`] compiles those files on the
//! PJRT CPU client once and caches the loaded executables; the training
//! hot path then only converts host buffers to/from [`xla::Literal`]s and
//! calls [`Engine::execute`].
//!
//! Python never runs here: after `make artifacts` the Rust binary is
//! self-contained.

mod manifest;

pub use manifest::{find_build, golden, Manifest};

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Artifact function names (the `<kind>.<fn>.hlo.txt` middle component).
pub mod funcs {
    pub const INIT: &str = "init";
    pub const FWD: &str = "fwd";
    pub const LOSS: &str = "loss";
    pub const BWD: &str = "bwd";
    pub const ADAM: &str = "adam";
    pub const OUTER_NOLOCO: &str = "outer_noloco";
    pub const OUTER_DILOCO: &str = "outer_diloco";
}

/// A compiled-artifact execution engine bound to one PJRT client.
///
/// Not `Send`: PJRT client handles are thread-local by construction here.
/// The threaded trainer builds one `Engine` per worker thread; the
/// single-threaded simulator shares one across all logical workers.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative number of `execute` calls (hot-path telemetry).
    executions: u64,
}

impl Engine {
    /// Create an engine over a PJRT CPU client rooted at an artifact
    /// directory (one `(model, pp, mb)` build).
    pub fn new(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.join("manifest.toml").is_file() {
            bail!(
                "{} is not an artifact build dir (no manifest.toml); run `make artifacts`",
                dir.display()
            );
        }
        let client = xla::PjRtClient::cpu().map_err(wrap_xla)?;
        Ok(Engine { client, dir, cache: BTreeMap::new(), executions: 0 })
    }

    /// The build directory this engine loads from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Parse this build's manifest.
    pub fn manifest(&self) -> Result<Manifest> {
        Manifest::load(&self.dir)
    }

    /// Number of `execute` calls made so far.
    pub fn executions(&self) -> u64 {
        self.executions
    }

    /// Compile (or fetch from cache) the artifact `"{kind}.{func}"`.
    fn compiled(&mut self, kind: &str, func: &str) -> Result<&xla::PjRtLoadedExecutable> {
        let key = format!("{kind}.{func}");
        if !self.cache.contains_key(&key) {
            let path = self.dir.join(format!("{key}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(wrap_xla)
                .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(wrap_xla)
                .with_context(|| format!("compiling {}", path.display()))?;
            self.cache.insert(key.clone(), exe);
        }
        Ok(&self.cache[&key])
    }

    /// Eagerly compile a set of functions (so first-step latency does not
    /// pollute benchmarks).
    pub fn warm(&mut self, kind: &str, fns: &[&str]) -> Result<()> {
        for f in fns {
            self.compiled(kind, f)?;
        }
        Ok(())
    }

    /// Execute `"{kind}.{func}"` and unpack the result tuple.
    ///
    /// All artifacts are lowered with `return_tuple=True`, so the single
    /// output literal is always a tuple — even for one result.
    ///
    /// Implementation note: this goes through `execute_b` with
    /// Rust-owned input buffers rather than `PjRtLoadedExecutable::execute`.
    /// The crate's literal-based `execute` **leaks every input device
    /// buffer** (`BufferFromHostLiteral` + `release()` with no free in
    /// `xla_rs.cc`), ~2.5 MB per call at tiny-model sizes — found via the
    /// RSS probe now preserved as `Engine::execute`'s regression test
    /// `engine_execute_does_not_leak`.
    pub fn execute(
        &mut self,
        kind: &str,
        func: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        self.executions += 1;
        self.compiled(kind, func)?; // ensure cached (drops the borrow)
        // Input transfer: buffers owned here, freed on drop.
        let mut bufs = Vec::with_capacity(inputs.len());
        for lit in inputs {
            bufs.push(
                self.client
                    .buffer_from_host_literal(None, lit)
                    .map_err(wrap_xla)?,
            );
        }
        let exe = &self.cache[&format!("{kind}.{func}")];
        let out = exe.execute_b::<xla::PjRtBuffer>(&bufs).map_err(wrap_xla)?;
        let lit = out
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("{kind}.{func}: empty execution result"))?
            .to_literal_sync()
            .map_err(wrap_xla)?;
        lit.to_tuple().map_err(wrap_xla)
    }
}

fn wrap_xla(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

// ---------------------------------------------------------------------------
// Literal <-> host buffer conversions
// ---------------------------------------------------------------------------

/// f32 literal with a logical shape. Single-copy: the data lands directly
/// in a literal of the right shape (no intermediate rank-1 literal +
/// reshape — that path copies twice and showed up in the §Perf profile).
#[allow(unsafe_code)] // sole unsafe in the crate (with lit_i32 below); see SAFETY
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("lit_f32: {} elements for shape {dims:?}", data.len());
    }
    // SAFETY: reinterprets the f32 slice as its own backing bytes — same
    // allocation, same lifetime, length in bytes = len * size_of::<f32>().
    // f32 has no invalid bit patterns and the callee copies before return.
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .map_err(wrap_xla)
}

/// i32 literal with a logical shape (token batches). Single-copy.
#[allow(unsafe_code)] // see SAFETY; same zero-copy byte view as lit_f32
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("lit_i32: {} elements for shape {dims:?}", data.len());
    }
    // SAFETY: identical to lit_f32 — byte view of the i32 slice's own
    // allocation, length in bytes = len * 4; copied by the callee.
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes)
        .map_err(wrap_xla)
}

/// i32 scalar literal (init seeds).
pub fn lit_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Scalars vector literal (`[6]` Adam, `[4]` outer updates).
pub fn lit_scalars(vals: &[f32]) -> xla::Literal {
    xla::Literal::vec1(vals)
}

/// Copy a literal out to host f32.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(wrap_xla)
}

/// Copy a scalar f32 out of a literal.
pub fn to_f32(lit: &xla::Literal) -> Result<f32> {
    let v = to_vec_f32(lit)?;
    v.first().copied().ok_or_else(|| anyhow!("empty literal"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lit_f32_roundtrip() {
        let xs = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&xs, &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), xs);
    }

    #[test]
    fn lit_shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn engine_requires_manifest() {
        let err = match Engine::new("/tmp/definitely-not-artifacts") {
            Err(e) => e,
            Ok(_) => panic!("engine must reject a dir without manifest"),
        };
        assert!(err.to_string().contains("manifest.toml"));
    }
}
