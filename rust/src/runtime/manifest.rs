//! Artifact build manifests — the contract between `python/compile/aot.py`
//! and the Rust runtime.
//!
//! Each build directory carries a `manifest.toml` (model dimensions, pp,
//! microbatch, per-stage-kind parameter counts) written in the TOML subset
//! [`crate::config::toml`] parses, and a `golden.toml` of reference
//! statistics the cross-language tests assert against.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::toml::Doc;
use crate::config::ModelConfig;

/// Parsed `manifest.toml` of one artifact build.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// Preset name the build was lowered from.
    pub model: String,
    /// Pipeline stage count the stages were split for.
    pub pp: usize,
    /// Microbatch size (sequences) baked into fwd/bwd/loss shapes.
    pub mb: usize,
    pub hidden: usize,
    pub layers: usize,
    pub layers_per_stage: usize,
    pub intermediate: usize,
    pub heads: usize,
    pub vocab: usize,
    pub seq_len: usize,
    /// Flat parameter count per stage kind (`first`/`mid`/`last`/`full`).
    pub params: BTreeMap<String, usize>,
}

impl Manifest {
    /// Load and parse `dir/manifest.toml`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let path = dir.as_ref().join("manifest.toml");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let doc = Doc::parse(text).map_err(|e| anyhow!("{e}"))?;
        let get = |k: &str| -> Result<i64> {
            doc.get(k)
                .and_then(|v| v.as_int())
                .ok_or_else(|| anyhow!("manifest missing integer key `{k}`"))
        };
        let model = doc
            .get("build.model")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("manifest missing `build.model`"))?
            .to_string();
        let mut params = BTreeMap::new();
        for (k, v) in doc.iter() {
            if let Some(kind) = k.strip_prefix("params.") {
                let n = v
                    .as_int()
                    .ok_or_else(|| anyhow!("bad param count for `{k}`"))?;
                params.insert(kind.to_string(), n as usize);
            }
        }
        if params.is_empty() {
            bail!("manifest has no [params] section");
        }
        Ok(Manifest {
            model,
            pp: get("build.pp")? as usize,
            mb: get("build.mb")? as usize,
            hidden: get("model.hidden")? as usize,
            layers: get("model.layers")? as usize,
            layers_per_stage: get("model.layers_per_stage")? as usize,
            intermediate: get("model.intermediate")? as usize,
            heads: get("model.heads")? as usize,
            vocab: get("model.vocab")? as usize,
            seq_len: get("model.seq_len")? as usize,
            params,
        })
    }

    /// Parameter count for a stage kind.
    pub fn param_count(&self, kind: &str) -> Result<usize> {
        self.params
            .get(kind)
            .copied()
            .ok_or_else(|| anyhow!("build has no `{kind}` stage (pp = {})", self.pp))
    }

    /// Check the manifest's model dimensions against a Rust-side config —
    /// the guard against preset drift between Python and Rust.
    pub fn check_against(&self, cfg: &ModelConfig, pp: usize) -> Result<()> {
        let pairs = [
            ("hidden", self.hidden, cfg.hidden),
            ("layers", self.layers, cfg.layers),
            ("intermediate", self.intermediate, cfg.intermediate),
            ("heads", self.heads, cfg.heads),
            ("vocab", self.vocab, cfg.vocab),
            ("seq_len", self.seq_len, cfg.seq_len),
        ];
        for (name, got, want) in pairs {
            if got != want {
                bail!("manifest {name}={got} != config {name}={want} (preset drift? re-run `make artifacts`)");
            }
        }
        if self.pp != pp {
            bail!("manifest pp={} != requested pp={pp}", self.pp);
        }
        if self.layers_per_stage * pp != self.layers {
            bail!("manifest inconsistent: {} layers/stage x {pp} != {}", self.layers_per_stage, self.layers);
        }
        Ok(())
    }
}

/// Locate the artifact build directory for `(model, pp)` under the
/// artifact root, e.g. `artifacts/tiny-pp2-mb2`. When several microbatch
/// variants exist, prefers the largest `mb` (fewest executions per batch).
pub fn find_build(root: impl AsRef<Path>, model: &str, pp: usize) -> Result<PathBuf> {
    let root = root.as_ref();
    let prefix = format!("{model}-pp{pp}-mb");
    let mut best: Option<(usize, PathBuf)> = None;
    let entries = std::fs::read_dir(root)
        .with_context(|| format!("listing artifact root {}", root.display()))?;
    for entry in entries {
        let path = entry?.path();
        let name = match path.file_name().and_then(|n| n.to_str()) {
            Some(n) => n.to_string(),
            None => continue,
        };
        if let Some(mb_str) = name.strip_prefix(&prefix) {
            if let Ok(mb) = mb_str.parse::<usize>() {
                if path.join("manifest.toml").is_file()
                    && best.as_ref().map_or(true, |(b, _)| mb > *b)
                {
                    best = Some((mb, path));
                }
            }
        }
    }
    best.map(|(_, p)| p).ok_or_else(|| {
        anyhow!(
            "no artifact build `{prefix}*` under {} — run `make artifacts` \
             (or add `--build {model}:{pp}:<mb>` to aot.py)",
            root.display()
        )
    })
}

/// Parse a build's `golden.toml` into name -> value.
pub fn golden(dir: impl AsRef<Path>) -> Result<BTreeMap<String, f64>> {
    let path = dir.as_ref().join("golden.toml");
    let text =
        std::fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
    let mut out = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (k, v) = line
            .split_once(" = ")
            .ok_or_else(|| anyhow!("bad golden line `{line}`"))?;
        out.insert(k.to_string(), v.parse::<f64>().with_context(|| format!("`{line}`"))?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
[build]
model = "tiny"
pp = 2
mb = 2
[model]
hidden = 64
layers = 4
layers_per_stage = 2
intermediate = 256
heads = 4
vocab = 512
seq_len = 64
[params]
first = 164096
last = 164160
"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model, "tiny");
        assert_eq!(m.pp, 2);
        assert_eq!(m.mb, 2);
        assert_eq!(m.hidden, 64);
        assert_eq!(m.param_count("first").unwrap(), 164_096);
        assert_eq!(m.param_count("last").unwrap(), 164_160);
        assert!(m.param_count("mid").is_err());
    }

    #[test]
    fn check_against_detects_drift() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let mut cfg = crate::config::presets::preset("tiny").unwrap().model;
        m.check_against(&cfg, 2).unwrap();
        cfg.hidden = 128;
        let err = m.check_against(&cfg, 2).unwrap_err().to_string();
        assert!(err.contains("hidden"), "{err}");
        let cfg = crate::config::presets::preset("tiny").unwrap().model;
        assert!(m.check_against(&cfg, 4).is_err());
    }

    #[test]
    fn missing_sections_rejected() {
        assert!(Manifest::parse("[build]\npp = 2\n").is_err());
        assert!(Manifest::parse("nonsense").is_err());
    }
}
