//! Rust-side mirror of the Layer-2 stage parameter layout.
//!
//! The coordinator treats stage parameters as flat `f32` vectors (that is
//! the artifact wire format), but several subsystems need the *structure*:
//! manifest validation cross-checks parameter counts, metrics can report
//! per-tensor statistics, and checkpoints record named shapes. This module
//! re-derives the exact `(name, shape)` ordering of
//! `python/compile/model.py::stage_shapes` — any drift is caught by
//! `rust/tests/integration.rs` comparing against the generated manifests.

use crate::config::ModelConfig;

/// Pipeline stage kinds, matching the artifact naming.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StageKind {
    /// Embedding + first block of layers (pp > 1).
    First,
    /// Interior block of layers (pp > 2).
    Mid,
    /// Final block + norm + LM head + loss (pp > 1).
    Last,
    /// Whole model in one stage (pp = 1).
    Full,
}

impl StageKind {
    /// Artifact file-name component.
    pub fn as_str(&self) -> &'static str {
        match self {
            StageKind::First => "first",
            StageKind::Mid => "mid",
            StageKind::Last => "last",
            StageKind::Full => "full",
        }
    }

    /// The kind of pipeline stage `s` out of `pp`.
    pub fn of_stage(s: usize, pp: usize) -> StageKind {
        assert!(s < pp, "stage {s} out of range for pp={pp}");
        if pp == 1 {
            StageKind::Full
        } else if s == 0 {
            StageKind::First
        } else if s == pp - 1 {
            StageKind::Last
        } else {
            StageKind::Mid
        }
    }

    /// All kinds present in a `pp`-stage pipeline, in stage order.
    pub fn kinds_for(pp: usize) -> Vec<StageKind> {
        (0..pp).map(|s| StageKind::of_stage(s, pp)).collect()
    }

    /// Whether this stage consumes tokens (vs hidden states) as input.
    pub fn takes_tokens(&self) -> bool {
        matches!(self, StageKind::First | StageKind::Full)
    }

    /// Whether this stage produces the loss.
    pub fn produces_loss(&self) -> bool {
        matches!(self, StageKind::Last | StageKind::Full)
    }
}

/// One named parameter tensor in a stage's flat vector.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    /// Dotted name, e.g. `l0.wq`.
    pub name: String,
    /// Logical shape.
    pub shape: Vec<usize>,
}

impl ParamSpec {
    /// Element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// True for zero-element specs (never produced in practice).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Ordered `(name, shape)` list of one decoder layer — mirrors
/// `model.layer_shapes`.
pub fn layer_shapes(cfg: &ModelConfig) -> Vec<ParamSpec> {
    let (h, i) = (cfg.hidden, cfg.intermediate);
    let spec = |name: &str, shape: &[usize]| ParamSpec { name: name.into(), shape: shape.to_vec() };
    vec![
        spec("attn_norm", &[h]),
        spec("wq", &[h, h]),
        spec("wk", &[h, h]),
        spec("wv", &[h, h]),
        spec("wo", &[h, h]),
        spec("mlp_norm", &[h]),
        spec("w_gate", &[h, i]),
        spec("w_up", &[h, i]),
        spec("w_down", &[i, h]),
    ]
}

/// Ordered parameter specs of a stage kind — mirrors `model.stage_shapes`.
pub fn stage_shapes(cfg: &ModelConfig, kind: StageKind, pp: usize) -> Vec<ParamSpec> {
    let (h, v) = (cfg.hidden, cfg.vocab);
    let n_layers = match kind {
        StageKind::Full => cfg.layers,
        _ => cfg.layers / pp,
    };
    let mut out = Vec::new();
    if matches!(kind, StageKind::First | StageKind::Full) {
        out.push(ParamSpec { name: "embed".into(), shape: vec![v, h] });
    }
    for li in 0..n_layers {
        for s in layer_shapes(cfg) {
            out.push(ParamSpec { name: format!("l{li}.{}", s.name), shape: s.shape });
        }
    }
    if matches!(kind, StageKind::Last | StageKind::Full) {
        out.push(ParamSpec { name: "final_norm".into(), shape: vec![h] });
        out.push(ParamSpec { name: "head".into(), shape: vec![h, v] });
    }
    out
}

/// Flat parameter count of a stage kind — must equal the manifest's.
pub fn stage_param_count(cfg: &ModelConfig, kind: StageKind, pp: usize) -> usize {
    stage_shapes(cfg, kind, pp).iter().map(|s| s.len()).sum()
}

/// Byte offset table: name -> (offset, len) into the flat vector.
pub fn offsets(cfg: &ModelConfig, kind: StageKind, pp: usize) -> Vec<(String, usize, usize)> {
    let mut out = Vec::new();
    let mut off = 0;
    for s in stage_shapes(cfg, kind, pp) {
        let n = s.len();
        out.push((s.name, off, n));
        off += n;
    }
    out
}

/// Slice one named parameter out of a stage's flat vector.
pub fn param_of<'a>(
    flat: &'a [f32],
    cfg: &ModelConfig,
    kind: StageKind,
    pp: usize,
    name: &str,
) -> Option<&'a [f32]> {
    offsets(cfg, kind, pp)
        .into_iter()
        .find(|(n, _, _)| n == name)
        .map(|(_, off, len)| &flat[off..off + len])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn tiny() -> ModelConfig {
        presets::preset("tiny").unwrap().model
    }

    #[test]
    fn kind_of_stage_layouts() {
        assert_eq!(StageKind::of_stage(0, 1), StageKind::Full);
        assert_eq!(StageKind::of_stage(0, 2), StageKind::First);
        assert_eq!(StageKind::of_stage(1, 2), StageKind::Last);
        assert_eq!(StageKind::of_stage(1, 4), StageKind::Mid);
        assert_eq!(StageKind::of_stage(2, 4), StageKind::Mid);
        assert_eq!(StageKind::of_stage(3, 4), StageKind::Last);
        assert_eq!(
            StageKind::kinds_for(3),
            vec![StageKind::First, StageKind::Mid, StageKind::Last]
        );
    }

    #[test]
    fn full_equals_sum_of_stages() {
        // Splitting must conserve parameters: first + (pp-2)*mid + last ==
        // full for every divisor pp.
        let cfg = tiny();
        for pp in [2, 4] {
            let first = stage_param_count(&cfg, StageKind::First, pp);
            let mid = stage_param_count(&cfg, StageKind::Mid, pp);
            let last = stage_param_count(&cfg, StageKind::Last, pp);
            let full = stage_param_count(&cfg, StageKind::Full, 1);
            assert_eq!(first + mid * (pp - 2) + last, full, "pp={pp}");
        }
    }

    #[test]
    fn param_count_formula_matches_config() {
        // stage shapes must agree with ModelConfig::total_params
        // (embedding + head + transformer body).
        let cfg = tiny();
        let full = stage_param_count(&cfg, StageKind::Full, 1);
        assert_eq!(full, cfg.total_params());
    }

    #[test]
    fn offsets_are_contiguous() {
        let cfg = tiny();
        let offs = offsets(&cfg, StageKind::Last, 2);
        let mut expect = 0;
        for (_, off, len) in &offs {
            assert_eq!(*off, expect);
            expect += len;
        }
        assert_eq!(expect, stage_param_count(&cfg, StageKind::Last, 2));
    }

    #[test]
    fn param_of_slices_named_tensor() {
        let cfg = tiny();
        let n = stage_param_count(&cfg, StageKind::First, 2);
        let flat: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let embed = param_of(&flat, &cfg, StageKind::First, 2, "embed").unwrap();
        assert_eq!(embed.len(), cfg.vocab * cfg.hidden);
        assert_eq!(embed[0], 0.0);
        let wq = param_of(&flat, &cfg, StageKind::First, 2, "l0.wq").unwrap();
        assert_eq!(wq.len(), cfg.hidden * cfg.hidden);
        assert_eq!(wq[0], (cfg.vocab * cfg.hidden + cfg.hidden) as f32);
        assert!(param_of(&flat, &cfg, StageKind::First, 2, "head").is_none());
    }

    #[test]
    fn takes_tokens_and_loss_flags() {
        assert!(StageKind::First.takes_tokens());
        assert!(StageKind::Full.takes_tokens());
        assert!(!StageKind::Mid.takes_tokens());
        assert!(StageKind::Last.produces_loss());
        assert!(StageKind::Full.produces_loss());
        assert!(!StageKind::First.produces_loss());
    }
}
