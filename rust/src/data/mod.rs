//! Synthetic corpora, tokenization, and sharded loading.
//!
//! The paper trains on Pushshift Reddit and C4. Neither is available on
//! this image (no network), so we substitute deterministic synthetic
//! corpora that preserve what the evaluation actually needs: a
//! non-trivial, learnable next-token distribution, *identical data* across
//! the methods being compared, a held-out validation stream, and two
//! distinguishable "datasets" with different breadth (the paper contrasts
//! Reddit's narrower topicality against C4's variety). See DESIGN.md §4.
//!
//! The generator is a topic-mixture Markov-ish process over a Zipfian
//! vocabulary: each document samples a topic; each topic biases token
//! draws toward its own sub-vocabulary and chains bigrams
//! deterministically, giving the model real structure to learn (validation
//! perplexity drops well below uniform). `RedditLike` uses few topics and
//! high repetition; `C4Like` uses many topics and flatter frequencies.

mod loader;

pub use loader::{Batch, Loader};

use crate::config::Dataset;
use crate::rngx::{Pcg64, Zipf};

/// Stream of token sequences for one dataset + split.
pub struct Corpus {
    vocab: usize,
    zipf: Zipf,
    topics: usize,
    /// Per-topic additive shift applied to sampled ranks (creates
    /// topic-specific sub-vocabularies).
    topic_stride: usize,
    /// Probability of chaining: next token = f(prev) instead of fresh draw.
    chain_prob: f64,
    rng: Pcg64,
    /// Reserved ids: 0 = BOS.
    bos: u32,
}

impl Corpus {
    /// Build the train split of a dataset flavour.
    pub fn train(kind: Dataset, vocab: usize, seed: u64) -> Corpus {
        Self::build(kind, vocab, seed ^ 0x7261_696e)
    }

    /// Build the held-out validation split (independent stream, same
    /// distribution — the paper holds out 10M Reddit tokens / C4's
    /// validation partition).
    pub fn validation(kind: Dataset, vocab: usize, seed: u64) -> Corpus {
        Self::build(kind, vocab, seed ^ 0x7661_6c69_6461)
    }

    fn build(kind: Dataset, vocab: usize, seed: u64) -> Corpus {
        assert!(vocab >= 16, "vocabulary too small");
        let (topics, s, chain_prob) = match kind {
            // Narrow topicality, steeper Zipf, heavier repetition.
            Dataset::RedditLike => (4usize, 1.3, 0.55),
            // Broader mixture, flatter frequencies, less repetition.
            Dataset::C4Like => (16usize, 1.05, 0.35),
        };
        Corpus {
            vocab,
            zipf: Zipf::new(vocab - 1, s),
            topics,
            topic_stride: (vocab - 1) / topics.max(1),
            chain_prob,
            rng: Pcg64::seed_from_u64(seed),
            bos: 0,
        }
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Generate the next sequence of exactly `len` tokens (BOS-prefixed).
    pub fn next_sequence(&mut self, len: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(len);
        out.push(self.bos);
        let topic = self.rng.next_below(self.topics as u64) as usize;
        let base = 1 + topic * self.topic_stride;
        let mut prev: u32 = self.bos;
        while out.len() < len {
            let tok = if prev != self.bos && self.rng.next_f64() < self.chain_prob {
                // Deterministic bigram chaining inside the topic: gives
                // the LM learnable transitions (low conditional entropy).
                let within = (prev as usize * 7 + 3) % self.topic_stride.max(1);
                (base + within) as u32
            } else {
                let r = self.zipf.sample(&mut self.rng);
                // Map global Zipf rank into the topic's sub-vocabulary
                // half the time; otherwise keep it global (shared words).
                if self.rng.next_f64() < 0.5 {
                    (1 + (r % self.topic_stride.max(1)) + topic * self.topic_stride) as u32
                } else {
                    (1 + r) as u32
                }
            };
            let tok = tok.min(self.vocab as u32 - 1);
            out.push(tok);
            prev = tok;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequences_have_exact_length_and_valid_ids() {
        let mut c = Corpus::train(Dataset::RedditLike, 512, 1);
        for _ in 0..10 {
            let s = c.next_sequence(64);
            assert_eq!(s.len(), 64);
            assert_eq!(s[0], 0);
            assert!(s.iter().all(|&t| (t as usize) < 512));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Corpus::train(Dataset::C4Like, 256, 9);
        let mut b = Corpus::train(Dataset::C4Like, 256, 9);
        assert_eq!(a.next_sequence(32), b.next_sequence(32));
    }

    #[test]
    fn train_and_validation_streams_differ() {
        let mut t = Corpus::train(Dataset::RedditLike, 256, 9);
        let mut v = Corpus::validation(Dataset::RedditLike, 256, 9);
        assert_ne!(t.next_sequence(64), v.next_sequence(64));
    }

    #[test]
    fn reddit_is_narrower_than_c4() {
        // Unigram entropy of the reddit-like stream should be lower.
        let entropy = |kind: Dataset| {
            let mut c = Corpus::train(kind, 512, 3);
            let mut counts = vec![0u32; 512];
            for _ in 0..200 {
                for t in c.next_sequence(128) {
                    counts[t as usize] += 1;
                }
            }
            let total: u32 = counts.iter().sum();
            counts
                .iter()
                .filter(|&&c| c > 0)
                .map(|&c| {
                    let p = c as f64 / total as f64;
                    -p * p.log2()
                })
                .sum::<f64>()
        };
        let r = entropy(Dataset::RedditLike);
        let c4 = entropy(Dataset::C4Like);
        assert!(r < c4, "reddit entropy {r} should be < c4 entropy {c4}");
    }

    #[test]
    fn stream_is_learnable_not_uniform() {
        // Bigram conditional entropy must be clearly below unigram
        // entropy — otherwise there is nothing for the model to learn.
        let mut c = Corpus::train(Dataset::RedditLike, 256, 5);
        let mut uni = vec![0f64; 256];
        let mut big = std::collections::BTreeMap::<(u32, u32), f64>::new();
        let mut prev_count = vec![0f64; 256];
        for _ in 0..400 {
            let s = c.next_sequence(128);
            for w in s.windows(2) {
                uni[w[1] as usize] += 1.0;
                *big.entry((w[0], w[1])).or_default() += 1.0;
                prev_count[w[0] as usize] += 1.0;
            }
        }
        let n: f64 = uni.iter().sum();
        let h_uni: f64 = uni
            .iter()
            .filter(|&&c| c > 0.0)
            .map(|&c| {
                let p = c / n;
                -p * p.log2()
            })
            .sum();
        let h_big: f64 = big
            .iter()
            .map(|(&(a, _), &c)| {
                let p_joint = c / n;
                let p_cond = c / prev_count[a as usize];
                -p_joint * p_cond.log2()
            })
            .sum();
        assert!(
            h_big < 0.8 * h_uni,
            "bigram H {h_big:.2} not << unigram H {h_uni:.2}"
        );
    }
}
