//! Sharded batch loading.
//!
//! Each data-parallel pipeline (i.e. each DP index) consumes a disjoint
//! shard of the corpus stream: DP rank `r` of `dp` takes every `dp`-th
//! sequence starting at `r`. Determinism: the shard assignment depends
//! only on `(seed, dp, rank)`, so FSDP / DiLoCo / NoLoCo comparisons see
//! *identical* data order — the paper's controlled-comparison requirement.

use super::Corpus;
use crate::config::Dataset;

/// One training batch: `seqs × seq_len` token matrix, row-major. Inputs
/// are `tokens[..len-1]`, targets `tokens[1..]` (shifted inside the
/// model's loss), so the matrix ships as-is.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Batch {
    /// Row-major `(seqs, seq_len)` token ids.
    pub tokens: Vec<u32>,
    /// Sequences in the batch.
    pub seqs: usize,
    /// Tokens per sequence.
    pub seq_len: usize,
}

impl Batch {
    /// Token count (seqs × seq_len).
    pub fn num_tokens(&self) -> usize {
        self.tokens.len()
    }
}

/// Sharded sequential loader over a [`Corpus`].
pub struct Loader {
    corpus: Corpus,
    rank: usize,
    dp: usize,
    seq_len: usize,
    seqs_per_batch: usize,
    /// Global sequence cursor (pre-shard).
    cursor: u64,
}

impl Loader {
    /// Train-split loader for DP shard `rank` of `dp`.
    pub fn train(
        kind: Dataset,
        vocab: usize,
        seed: u64,
        rank: usize,
        dp: usize,
        seq_len: usize,
        seqs_per_batch: usize,
    ) -> Loader {
        assert!(rank < dp);
        Loader {
            corpus: Corpus::train(kind, vocab, seed),
            rank,
            dp,
            seq_len,
            seqs_per_batch,
            cursor: 0,
        }
    }

    /// Validation loader (unsharded — every worker evaluates the same
    /// stream so perplexities are comparable).
    pub fn validation(
        kind: Dataset,
        vocab: usize,
        seed: u64,
        seq_len: usize,
        seqs_per_batch: usize,
    ) -> Loader {
        Loader {
            corpus: Corpus::validation(kind, vocab, seed),
            rank: 0,
            dp: 1,
            seq_len,
            seqs_per_batch,
            cursor: 0,
        }
    }

    /// Global (pre-shard) sequence cursor: how many corpus sequences have
    /// been drawn so far. Checkpoints record this per loader.
    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    /// Replay the stream up to a checkpointed `cursor`: `next_batch`
    /// draws exactly one corpus sequence per cursor increment (shard-owned
    /// or not), so discarding that many draws reproduces the interrupted
    /// loader's RNG state and shard position exactly.
    pub fn fast_forward(&mut self, cursor: u64) {
        assert!(
            cursor >= self.cursor,
            "cannot rewind a loader (at {}, asked for {})",
            self.cursor,
            cursor
        );
        while self.cursor < cursor {
            let _ = self.corpus.next_sequence(self.seq_len);
            self.cursor += 1;
        }
    }

    /// Produce the next batch for this shard.
    pub fn next_batch(&mut self) -> Batch {
        let mut tokens = Vec::with_capacity(self.seqs_per_batch * self.seq_len);
        let mut got = 0;
        while got < self.seqs_per_batch {
            let seq = self.corpus.next_sequence(self.seq_len);
            let mine = (self.cursor % self.dp as u64) as usize == self.rank;
            self.cursor += 1;
            if mine {
                tokens.extend_from_slice(&seq);
                got += 1;
            }
        }
        Batch {
            tokens,
            seqs: self.seqs_per_batch,
            seq_len: self.seq_len,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shape() {
        let mut l = Loader::train(Dataset::RedditLike, 256, 1, 0, 2, 32, 4);
        let b = l.next_batch();
        assert_eq!(b.seqs, 4);
        assert_eq!(b.seq_len, 32);
        assert_eq!(b.num_tokens(), 128);
    }

    #[test]
    fn shards_are_disjoint_and_cover() {
        // Two ranks draw from the same stream: rank 0 gets sequences
        // 0,2,4,... and rank 1 gets 1,3,5,... of the identical corpus.
        let all = |rank: usize| {
            let mut l = Loader::train(Dataset::C4Like, 256, 7, rank, 2, 16, 4);
            l.next_batch().tokens
        };
        let r0 = all(0);
        let r1 = all(1);
        assert_ne!(r0, r1);
        // Reference unsharded stream: interleaving r0/r1 sequence-wise
        // reproduces it.
        let mut c = Corpus::train(Dataset::C4Like, 256, 7);
        let mut want0 = Vec::new();
        let mut want1 = Vec::new();
        for i in 0..8 {
            let s = c.next_sequence(16);
            if i % 2 == 0 {
                want0.extend(s);
            } else {
                want1.extend(s);
            }
        }
        assert_eq!(r0, want0);
        assert_eq!(r1, want1);
    }

    #[test]
    fn determinism_across_loader_instances() {
        let mut a = Loader::train(Dataset::RedditLike, 128, 3, 1, 4, 8, 2);
        let mut b = Loader::train(Dataset::RedditLike, 128, 3, 1, 4, 8, 2);
        assert_eq!(a.next_batch(), b.next_batch());
        assert_eq!(a.next_batch(), b.next_batch());
    }

    #[test]
    fn fast_forward_matches_a_replayed_stream() {
        // Consume three batches, checkpoint the cursor, rebuild a fresh
        // loader, fast-forward — the next batches must coincide.
        let mut a = Loader::train(Dataset::C4Like, 128, 11, 1, 3, 8, 2);
        for _ in 0..3 {
            a.next_batch();
        }
        let cur = a.cursor();
        let mut b = Loader::train(Dataset::C4Like, 128, 11, 1, 3, 8, 2);
        b.fast_forward(cur);
        assert_eq!(b.cursor(), cur);
        assert_eq!(a.next_batch(), b.next_batch());
        assert_eq!(a.next_batch(), b.next_batch());
    }

    #[test]
    fn validation_is_unsharded() {
        let mut a = Loader::validation(Dataset::RedditLike, 128, 3, 8, 2);
        let mut b = Loader::validation(Dataset::RedditLike, 128, 3, 8, 2);
        assert_eq!(a.next_batch(), b.next_batch());
    }

    #[test]
    fn property_shards_partition_the_stream() {
        crate::prop::run("dp shards partition corpus sequences", 20, |g| {
            let dp = g.usize_in(1, 5).max(1);
            let seed = g.rng().next_u64();
            let seq_len = 8;
            let per = 3;
            // Collect `per` sequences from each rank.
            let mut shards: Vec<Vec<u32>> = Vec::new();
            for r in 0..dp {
                let mut l = Loader::train(Dataset::C4Like, 64, seed, r, dp, seq_len, per);
                shards.push(l.next_batch().tokens);
            }
            // Reference stream.
            let mut c = Corpus::train(Dataset::C4Like, 64, seed);
            let mut want: Vec<Vec<u32>> = vec![Vec::new(); dp];
            for i in 0..dp * per {
                let s = c.next_sequence(seq_len);
                want[i % dp].extend(s);
            }
            assert_eq!(shards, want);
        });
    }
}
