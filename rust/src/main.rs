//! `noloco` — leader binary for the NoLoCo training stack.
//!
//! Subcommands:
//!
//! * `train`            — run the single-process trainer (default)
//! * `train-threaded`   — run the threaded trainer over the message fabric
//! * `run`              — run ONE rank as an OS process over TCP
//!                        (`--transport socket --seed-addr H:P --rank R`)
//! * `presets`          — list configuration presets (Table 1 + CPU-scale)
//! * `topo`             — analyze the configured network topology (sync costs)
//! * `artifacts`        — inventory the compiled artifact builds
//! * `check`            — validate a config + artifact pairing, no training
//! * `drill`            — kill-restart drill: checkpoint, drop state, resume,
//!                        assert the trajectory is bit-identical
//! * `obs-smoke`        — emit a small sample trace journal (schema tooling)
//! * `bench-baseline`   — write the deterministic cost-model baseline JSON
//! * `perf`             — write the 64/256/1000-replica scale ladder JSON
//! * `analyze`          — static determinism/protocol analysis of this tree
//!                        (rules R1–R5; exits nonzero on findings)
//!
//! Common options: `--preset NAME`, `--method fsdp|diloco|noloco`,
//! `--dataset reddit|c4`, `--routing random|fixed`, `--steps N`, `--dp N`,
//! `--pp N`, `--seed N`, `--config FILE`, `--set path=value`, `--csv OUT`,
//! `--topo lan|wan|long-tail`, `--regions N`, `--churn "leave:S:R;join:S:R"`.

// Panic discipline mirrors lib.rs: no bare unwrap/expect on the
// non-test path without a local justified allow.
#![warn(clippy::unwrap_used, clippy::expect_used)]
#![cfg_attr(test, allow(clippy::unwrap_used, clippy::expect_used))]

use noloco::cli::{self, Args};
use noloco::config::presets;
use noloco::runtime::{find_build, Engine, Manifest};
use noloco::train::{SimTrainer, ThreadedTrainer};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let cmd = args.command.clone().unwrap_or_else(|| "train".to_string());
    let result = match cmd.as_str() {
        "train" => cmd_train(&args),
        "train-threaded" => cmd_train_threaded(&args),
        "run" => cmd_run(&args),
        "presets" => cmd_presets(),
        "topo" => cmd_topo(&args),
        "artifacts" => cmd_artifacts(&args),
        "check" => cmd_check(&args),
        "drill" => cmd_drill(&args),
        "obs-smoke" => cmd_obs_smoke(&args),
        "bench-baseline" => cmd_bench_baseline(&args),
        "perf" => cmd_perf(&args),
        "analyze" => cmd_analyze(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command `{other}`\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "noloco — no-all-reduce low-communication training\n\n\
         USAGE: noloco [COMMAND] [OPTIONS]\n\n\
         COMMANDS:\n\
           train            run the single-process trainer (default)\n\
           train-threaded   run the threaded trainer over the message fabric\n\
           run              run ONE rank as an OS process over TCP sockets\n\
           presets          list configuration presets\n\
           topo             analyze the configured network topology\n\
           artifacts        inventory compiled artifact builds\n\
           check            validate config + artifacts without training\n\
           drill            kill-restart drill: ckpt, drop state, resume, compare\n\
           obs-smoke        emit a small sample trace journal (--out FILE)\n\
           bench-baseline   write the cost-model baseline JSON (--out FILE)\n\
           perf             write the replica scale-ladder JSON (--out FILE)\n\
           analyze          static determinism/protocol analysis (R1–R5)\n\n\
         OPTIONS:\n\
           --preset NAME        preset (default: tiny); see `noloco presets`\n\
           --method M           fsdp | diloco | noloco\n\
           --dataset D          reddit | c4\n\
           --routing R          random | fixed\n\
           --steps N            total inner steps\n\
           --dp N / --pp N      topology\n\
           --inner-steps N      inner steps per outer step\n\
           --gamma X            NoLoCo consensus coefficient\n\
           --eval-every N       validation cadence\n\
           --seed N             RNG seed\n\
           --config FILE        TOML config overlay\n\
           --set path=value     targeted config override (repeatable)\n\
           --artifacts DIR      artifact root (default: artifacts)\n\
           --csv FILE           write the run trace as CSV\n\
           --latency-mu X       threaded: log-normal latency mu (seconds)\n\
           --latency-sigma X    threaded: log-normal latency sigma\n\
           --topo P             network preset: lan | wan | long-tail | hier\n\
           --regions N          WAN region count (hier: pod count)\n\
           --churn EVENTS       'leave:STEP:REPLICA;join:STEP:REPLICA;…'\n\
           --pairing P          NoLoCo gossip pairing: uniform | bandwidth-aware | per-fragment\n\
           --sync S             outer sync scheduling: gated | streaming\n\
           --fragments K        streaming / per-fragment async: (Δ, φ) fragment count\n\
           --overlap on|off     streaming: fold fragments one boundary late\n\
           --staleness S        async boundary: admit peer state up to S-1 boundaries old\n\
           --stash-age N        sweep uncollected sync payloads after N boundaries (0 = never)\n\
           --threads N          grid executor, pp=1: pooled inner-phase engine threads\n\
                                (0 = auto-detect, 1 = serial; trajectory is bit-identical)\n\
           --detect on|off      heartbeat failure detection (NoLoCo)\n\
           --detect-misses K    consecutive missed heartbeats before a peer is declared dead\n\
           --trace-out FILE     write the structured run journal (JSONL)\n\
           --metrics-out FILE   atomically rewrite a live metrics snapshot every boundary\n\
           --trace-level L      journal detail: off | boundary | step (default: step)\n\
           --ckpt-out FILE      write full-fidelity checkpoints here (atomic tmp+rename)\n\
           --ckpt-every K       checkpoint cadence in outer boundaries (0 = never)\n\
           --resume FILE        resume training from a checkpoint file\n\
           --fault-drop P       threaded: per-message drop probability\n\
           --fault-dup P        threaded: per-message duplication probability\n\
           --fault-delay P      threaded: per-message delay probability\n\
           --fault-delay-secs S threaded: hold-back duration for delayed messages\n\
           --fault-reorder P    threaded: adjacent-swap reorder probability\n\
           --fault-corrupt P    threaded: bit-flip probability (CRC drops + counts)\n\
           --transport T        run: threads | socket (default: socket)\n\
           --seed-addr H:P      run: seed-node address (rank 0 listens, others dial)\n\
           --rank R             run: this process's rank in 0..dp*pp\n\
           --bind H:P           run: listener bind address (default 127.0.0.1:0)\n\
           --report-out FILE    run: write this rank's report here (stdout otherwise)\n\
           --val-batches N      run: validation batches per eval point\n\
           --executor E         drill: grid | threads | socket | both (default: both)\n\
           --halt-after B       drill/run: boundary to kill at (drill default: mid-run)\n\
           --payload BYTES      topo: sync payload (default: model size)\n\
           --root DIR           analyze: source tree to scan (default: ./src or ./rust/src)\n\
           --format F           analyze: text | json (flat JSONL findings)"
    );
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg = cli::train_config_from(args).map_err(anyhow::Error::msg)?;
    println!(
        "run: {} | {} | dp={} pp={} | {} steps | routing {:?} | pairing {} | sync {}{}{} | seed {}",
        cfg.model.name,
        cfg.outer.method,
        cfg.topology.dp,
        cfg.topology.pp,
        cfg.steps,
        cfg.routing,
        cfg.pairing,
        cfg.sync,
        if cfg.sync == noloco::config::SyncMode::Streaming {
            format!(
                " ({} fragments, overlap {})",
                cfg.stream.fragments,
                if cfg.stream.overlap { "on" } else { "off" }
            )
        } else {
            String::new()
        },
        if cfg.outer.staleness > 1 {
            format!(" | async staleness {}", cfg.outer.staleness)
        } else {
            String::new()
        },
        cfg.seed
    );
    let dir = find_build(&cfg.artifacts_dir, &cfg.model.name, cfg.topology.pp)?;
    println!("artifacts: {}", dir.display());
    let mut eng = Engine::new(dir)?;
    let mut trainer = SimTrainer::new(cfg.clone(), &mut eng)?;
    if let Some(path) = &cfg.ckpt.resume {
        let ck = noloco::train::Checkpoint::load(path)?;
        trainer.resume_from(&ck)?;
        println!("resumed from {path} (boundary {}, step {})", ck.outer_idx, ck.step);
    }
    let report = trainer.run()?;
    println!(
        "done in {:.1}s | {} executions | final val nll {:.4} (ppl {:.2})",
        report.wall_secs, report.executions, report.final_val_nll, report.final_val_ppl
    );
    println!(
        "comm: {:.1} MiB payload | {} activation hops | {} blocking collectives | {} gossip pairs",
        report.comm.mib_sent(),
        report.comm.activation_hops,
        report.comm.blocking_collectives,
        report.comm.pair_exchanges
    );
    if let Some(csv) = args.opt("csv") {
        report.trace.write_csv(csv)?;
        println!("trace written to {csv}");
    }
    if let Some(p) = &report.obs.journal_path {
        println!("trace journal written to {p}");
    }
    Ok(())
}

fn cmd_train_threaded(args: &Args) -> anyhow::Result<()> {
    let cfg = cli::train_config_from(args).map_err(anyhow::Error::msg)?;
    println!(
        "threaded run: {} | {} | dp={} pp={} ({} worker threads) | {} steps",
        cfg.model.name,
        cfg.outer.method,
        cfg.topology.dp,
        cfg.topology.pp,
        cfg.topology.world(),
        cfg.steps
    );
    let mut t = ThreadedTrainer::new(cfg);
    let mu = args.opt_f64("latency-mu").map_err(anyhow::Error::msg)?;
    let sigma = args.opt_f64("latency-sigma").map_err(anyhow::Error::msg)?;
    if let (Some(mu), Some(sigma)) = (mu, sigma) {
        t = t.with_latency(mu, sigma);
        println!("latency injection: LogNormal(mu={mu}, sigma={sigma}) seconds");
    }
    let report = t.run()?;
    println!(
        "done in {:.1}s | final val nll {:.4} (ppl {:.2}) | {:.1} MiB / {} msgs over the fabric",
        report.wall_secs,
        report.final_val_nll,
        report.final_val_ppl,
        report.comm.mib_sent(),
        report.comm.msgs_sent
    );
    println!(
        "comm: {} activation hops | {} blocking collectives | {} gossip pairs",
        report.comm.activation_hops,
        report.comm.blocking_collectives,
        report.comm.pair_exchanges
    );
    let show = report.step_train_loss.len().min(5);
    println!("first {show} step losses: {:?}", &report.step_train_loss[..show]);
    if let Some(csv) = args.opt("csv") {
        report.trace.write_csv(csv)?;
        println!("trace written to {csv}");
    }
    if let Some(p) = &report.obs.journal_path {
        println!("trace journal written to {p}");
    }
    Ok(())
}

/// Run ONE rank of the DP × PP grid as this OS process, over real TCP.
/// Rank 0 listens at `--seed-addr`; every other rank dials it to join
/// and learns the live peer address book from the welcome. The rank's
/// result is written as a deterministic text report (`--report-out`,
/// stdout otherwise) for `merge_rank_reports`-style aggregation.
fn cmd_run(args: &Args) -> anyhow::Result<()> {
    use noloco::config::TransportKind;
    use noloco::train::SocketTrainer;

    let cfg = cli::train_config_from(args).map_err(anyhow::Error::msg)?;
    // `run` means sockets unless the flag says otherwise — the threaded
    // spelling exists so like-for-like comparisons can share a command.
    let kind = match args.opt("transport") {
        Some(_) => cfg.transport.kind,
        None => TransportKind::Socket,
    };
    if kind == TransportKind::Threads {
        return cmd_train_threaded(args);
    }
    let rank = cfg.transport.rank;
    let world = cfg.topology.world();
    println!(
        "socket run: {} | {} | rank {rank}/{world} | seed node {} | {} steps",
        cfg.model.name, cfg.outer.method, cfg.transport.seed_addr, cfg.steps
    );
    let mut t = SocketTrainer::new(cfg.clone(), rank, &cfg.transport.seed_addr)
        .with_bind(&cfg.transport.bind);
    if let Some(v) = args.opt_usize("val-batches").map_err(anyhow::Error::msg)? {
        t = t.with_val_batches(v);
    }
    if let Some(b) = args.opt_u64("halt-after").map_err(anyhow::Error::msg)? {
        t = t.with_halt_after(b);
    }
    let report = t.run()?;
    match &cfg.transport.report_out {
        Some(path) => {
            report.save(path)?;
            println!("rank {rank} report written to {path}");
        }
        None => print!("{}", report.to_text()),
    }
    Ok(())
}

fn cmd_presets() -> anyhow::Result<()> {
    println!(
        "{:<14} {:>7} {:>7} {:>12} {:>6} {:>9} {:>11} {:>8}",
        "preset", "hidden", "layers", "intermediate", "heads", "vocab", "params", "steps"
    );
    for name in presets::PRESET_NAMES {
        let Some(c) = presets::preset(name) else {
            continue;
        };
        println!(
            "{:<14} {:>7} {:>7} {:>12} {:>6} {:>9} {:>11} {:>8}",
            name,
            c.model.hidden,
            c.model.layers,
            c.model.intermediate,
            c.model.heads,
            c.model.vocab,
            human_count(c.model.transformer_params()),
            c.steps
        );
    }
    Ok(())
}

fn human_count(n: usize) -> String {
    if n >= 1_000_000_000 {
        format!("{:.1}B", n as f64 / 1e9)
    } else if n >= 1_000_000 {
        format!("{:.1}M", n as f64 / 1e6)
    } else {
        format!("{:.1}K", n as f64 / 1e3)
    }
}

fn cmd_topo(args: &Args) -> anyhow::Result<()> {
    use noloco::collective::{
        pair_average_time_bytes, ring_all_reduce_time_bytes, tree_all_reduce_time_bytes,
    };
    use noloco::net::SimClock;

    let cfg = cli::train_config_from(args).map_err(anyhow::Error::msg)?;
    let world = cfg.topology.world();
    let topo = cfg.net.build(world, cfg.seed);
    let payload = match args.opt_u64("payload").map_err(anyhow::Error::msg)? {
        Some(b) => b,
        None => (cfg.model.total_params() * 4) as u64,
    };
    println!(
        "topology: {} | {} nodes in {} region(s) | payload {:.1} MiB",
        cfg.net.preset,
        topo.world(),
        topo.regions(),
        payload as f64 / (1024.0 * 1024.0)
    );
    for n in 0..topo.world() {
        if topo.straggler_of(n) > 1.0 {
            println!("  straggler: node {n} x{:.2}", topo.straggler_of(n));
        }
    }
    let reps = 50;
    let mut tree = 0.0;
    let mut ring = 0.0;
    let mut pair = 0.0;
    for seed in 0..reps {
        let mut c = SimClock::with_topology(topo.clone(), cfg.seed ^ seed);
        tree += tree_all_reduce_time_bytes(&mut c, payload);
        let mut c = SimClock::with_topology(topo.clone(), cfg.seed ^ (seed + 1000));
        ring += ring_all_reduce_time_bytes(&mut c, payload);
        let mut c = SimClock::with_topology(topo.clone(), cfg.seed ^ (seed + 2000));
        pair += pair_average_time_bytes(&mut c, None, 2 * payload);
    }
    let r = reps as f64;
    println!(
        "expected sync cost: tree all-reduce {:.3}s | ring all-reduce {:.3}s | \
         gossip pair (2x payload) {:.3}s",
        tree / r,
        ring / r,
        pair / r
    );
    if cfg.churn.is_empty() {
        println!("churn: none");
    } else {
        println!("churn schedule over dp = {}:", cfg.topology.dp);
        for &(step, event) in cfg.churn.events() {
            println!("  step {step}: {event:?}");
        }
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> anyhow::Result<()> {
    let root = args.opt("artifacts").unwrap_or("artifacts");
    let mut found = 0;
    if let Ok(entries) = std::fs::read_dir(root) {
        for entry in entries.flatten() {
            let path = entry.path();
            if !path.join("manifest.toml").is_file() {
                continue;
            }
            let man = Manifest::load(&path)?;
            found += 1;
            println!(
                "{:<24} model={} pp={} mb={} seq={} vocab={} params={:?}",
                entry.file_name().to_string_lossy(),
                man.model,
                man.pp,
                man.mb,
                man.seq_len,
                man.vocab,
                man.params
            );
        }
    }
    if found == 0 {
        println!("no artifact builds under `{root}` — run `make artifacts`");
    }
    Ok(())
}

fn cmd_check(args: &Args) -> anyhow::Result<()> {
    let cfg = cli::train_config_from(args).map_err(anyhow::Error::msg)?;
    let dir = find_build(&cfg.artifacts_dir, &cfg.model.name, cfg.topology.pp)?;
    let man = Manifest::load(&dir)?;
    man.check_against(&cfg.model, cfg.topology.pp)?;
    let (lo, hi) = noloco::config::OuterConfig::gamma_window(cfg.outer.alpha, cfg.outer.group);
    println!("config OK: {} ({})", cfg.model.name, cfg.outer.method);
    println!("artifacts OK: {}", dir.display());
    println!("gamma window (Eq. 74): ({lo:.4}, {hi:.4}); gamma = {}", cfg.outer.gamma);
    Ok(())
}

/// Kill-restart drill: run the configured training three ways and assert
/// crash recovery is invisible in the trajectory.
///
/// * **A (reference)** — one uninterrupted run.
/// * **B (killed)** — same config with the `[ckpt]` cadence armed; every
///   worker halts right after the checkpoint covering `--halt-after`
///   (default: the mid-run boundary) hits disk, dropping all state.
/// * **C (resumed)** — a fresh trainer resumes from the file and runs to
///   completion.
///
/// C must match A bit-for-bit on every per-step training loss and on the
/// full communication accounting (wire bytes/messages included); only
/// wall-clock is exempt. Runs on the grid executor, the threaded
/// executor, or both (`--executor`).
fn cmd_drill(args: &Args) -> anyhow::Result<()> {
    use noloco::train::{Checkpoint, TrainReport};

    let cfg = cli::train_config_from(args).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(
        cfg.ckpt.resume.is_none(),
        "drill manages its own checkpoint lifecycle; drop --resume"
    );
    let m = cfg.outer.inner_steps.max(1) as u64;
    let boundaries = cfg.steps as u64 / m;
    anyhow::ensure!(
        boundaries >= 2,
        "drill needs at least 2 outer boundaries to kill mid-run \
         (steps = {}, inner_steps = {m} gives {boundaries})",
        cfg.steps
    );
    let halt = match args.opt_u64("halt-after").map_err(anyhow::Error::msg)? {
        Some(b) => {
            anyhow::ensure!(
                b >= 1 && b < boundaries,
                "--halt-after must be in 1..{boundaries} (killing at the final \
                 boundary leaves nothing to resume)"
            );
            b
        }
        None => (boundaries / 2).max(1),
    };
    let ckpt_path = match args.opt("ckpt-out") {
        Some(p) => std::path::PathBuf::from(p),
        None => std::env::temp_dir().join(format!("noloco_drill_{}.ckpt", std::process::id())),
    };
    let executor = args.opt("executor").unwrap_or("both");
    let (run_grid, run_threads, run_socket) = match executor {
        "grid" => (true, false, false),
        "threads" | "threaded" => (false, true, false),
        "socket" => (false, false, true),
        "both" => (true, true, false),
        other => {
            anyhow::bail!("--executor expects grid | threads | socket | both, got `{other}`")
        }
    };
    println!(
        "drill: {} | {} | dp={} pp={} | {} steps ({} boundaries) | kill after boundary \
         {halt} | ckpt {}",
        cfg.model.name,
        cfg.outer.method,
        cfg.topology.dp,
        cfg.topology.pp,
        cfg.steps,
        boundaries,
        ckpt_path.display()
    );

    // B's config: cadence armed so the checkpoint covering `halt` is cut
    // exactly there (`every = halt` fires first at boundary `halt`).
    let mut cfg_b = cfg.clone();
    cfg_b.ckpt.out = Some(ckpt_path.display().to_string());
    cfg_b.ckpt.every = halt as usize;

    let compare = |name: &str, a: &TrainReport, c: &TrainReport| -> anyhow::Result<()> {
        anyhow::ensure!(
            a.step_train_loss.len() == c.step_train_loss.len(),
            "{name}: loss trace lengths differ ({} vs {})",
            a.step_train_loss.len(),
            c.step_train_loss.len()
        );
        for (i, (x, y)) in a.step_train_loss.iter().zip(&c.step_train_loss).enumerate() {
            anyhow::ensure!(
                x.to_bits() == y.to_bits(),
                "{name}: step {i} train loss diverged after resume: {x} vs {y}"
            );
        }
        anyhow::ensure!(
            a.comm == c.comm,
            "{name}: communication accounting diverged after resume:\n  \
             reference {:?}\n  resumed   {:?}",
            a.comm,
            c.comm
        );
        println!(
            "{name}: resumed trajectory bit-identical ({} step losses, comm {:.1} MiB / {} msgs)",
            c.step_train_loss.len(),
            c.comm.mib_sent(),
            c.comm.msgs_sent
        );
        Ok(())
    };

    if run_grid {
        let dir = find_build(&cfg.artifacts_dir, &cfg.model.name, cfg.topology.pp)?;
        let mut eng = Engine::new(&dir)?;
        let reference = SimTrainer::new(cfg.clone(), &mut eng)?.run()?;
        let _killed = SimTrainer::new(cfg_b.clone(), &mut eng)?.halt_after(halt).run()?;
        println!("drill(grid): killed run stopped after step {} of {}", halt * m, cfg.steps);
        let ck = Checkpoint::load(&ckpt_path)?;
        anyhow::ensure!(
            ck.outer_idx == halt,
            "checkpoint covers boundary {} but the drill killed at {halt}",
            ck.outer_idx
        );
        let mut resumed = SimTrainer::new(cfg.clone(), &mut eng)?;
        resumed.resume_from(&ck)?;
        let resumed = resumed.run()?;
        compare("drill(grid)", &reference, &resumed)?;
    }
    if run_threads {
        let reference = ThreadedTrainer::new(cfg.clone()).run()?;
        ThreadedTrainer::new(cfg_b.clone()).with_halt_after(halt).run()?;
        let ck = Checkpoint::load(&ckpt_path)?;
        anyhow::ensure!(
            ck.outer_idx == halt,
            "checkpoint covers boundary {} but the drill killed at {halt}",
            ck.outer_idx
        );
        let resumed = ThreadedTrainer::new(cfg.clone()).with_resume(ck).run()?;
        compare("drill(threads)", &reference, &resumed)?;
    }
    if run_socket {
        drill_socket(args, &cfg, halt, &ckpt_path)?;
        for rank in 0..cfg.topology.world() {
            let _ = std::fs::remove_file(format!("{}.rank{rank}", ckpt_path.display()));
        }
    }
    let _ = std::fs::remove_file(&ckpt_path);
    println!("drill OK");
    Ok(())
}

/// The cross-process leg of the kill-restart drill: spawn one `noloco
/// run` child per rank over localhost TCP, halt the whole world right
/// after the checkpoint covering `halt` hits disk, restart every rank
/// from its own `<ckpt>.rank<R>` file under a fresh seed node, and
/// assert the merged rank reports match an unkilled *threaded* run
/// bit-for-bit — per-step loss bits and `CommStats` both.
fn drill_socket(
    args: &Args,
    cfg: &noloco::config::TrainConfig,
    halt: u64,
    ckpt_path: &std::path::Path,
) -> anyhow::Result<()> {
    use anyhow::Context as _;
    use noloco::train::{merge_rank_reports, RankReport};

    let world = cfg.topology.world();
    let exe = std::env::current_exe()?;
    let reference = ThreadedTrainer::new(cfg.clone()).run()?;

    // Child argv tail: forward the drill's own config-shaping options
    // (preset, steps, --set overrides, ...) minus the keys the drill
    // owns per phase.
    let mut tail: Vec<String> = Vec::new();
    for (k, v) in &args.options {
        let owned = matches!(
            k.as_str(),
            "executor"
                | "halt-after"
                | "ckpt-out"
                | "ckpt-every"
                | "resume"
                | "transport"
                | "seed-addr"
                | "rank"
                | "bind"
                | "report-out"
        );
        if !owned {
            tail.push(format!("--{k}"));
            tail.push(v.clone());
        }
    }
    for (p, v) in &args.sets {
        tail.push("--set".to_string());
        tail.push(format!("{p}={v}"));
    }

    let report_path = |phase: &str, rank: usize| {
        std::env::temp_dir().join(format!(
            "noloco_drill_{}_{phase}_rank{rank}.report",
            std::process::id()
        ))
    };
    let spawn_world = |extra: &dyn Fn(usize) -> Vec<String>| -> anyhow::Result<()> {
        let seed_addr = format!("127.0.0.1:{}", free_loopback_port()?);
        let mut children = Vec::new();
        for rank in 0..world {
            let mut argv: Vec<String> = vec![
                "run".to_string(),
                "--transport".to_string(),
                "socket".to_string(),
                "--seed-addr".to_string(),
                seed_addr.clone(),
                "--rank".to_string(),
                rank.to_string(),
            ];
            argv.extend(tail.iter().cloned());
            argv.extend(extra(rank));
            let child = std::process::Command::new(&exe)
                .args(&argv)
                .stdout(std::process::Stdio::null())
                .spawn()
                .with_context(|| format!("spawning rank {rank}"))?;
            children.push((rank, child));
        }
        for (rank, mut child) in children {
            let status = child.wait()?;
            anyhow::ensure!(status.success(), "rank {rank} exited with {status}");
        }
        Ok(())
    };

    // Phase B: the whole world checkpoints at `halt` and stops there.
    let ckpt = ckpt_path.display().to_string();
    spawn_world(&|rank| {
        vec![
            "--ckpt-out".to_string(),
            ckpt.clone(),
            "--ckpt-every".to_string(),
            halt.to_string(),
            "--halt-after".to_string(),
            halt.to_string(),
            "--report-out".to_string(),
            report_path("b", rank).display().to_string(),
        ]
    })?;
    println!(
        "drill(socket): {world} processes stopped after boundary {halt}, \
         per-rank checkpoints on disk"
    );

    // Phase C: a fresh world forms under a new seed node; every rank
    // resumes from its own file and runs to completion.
    spawn_world(&|rank| {
        vec![
            "--resume".to_string(),
            format!("{ckpt}.rank{rank}"),
            "--report-out".to_string(),
            report_path("c", rank).display().to_string(),
        ]
    })?;
    let mut reports = Vec::new();
    for rank in 0..world {
        let path = report_path("c", rank);
        reports.push(RankReport::load(&path.display().to_string())?);
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(report_path("b", rank));
    }
    let merged = merge_rank_reports(&reports)?;

    anyhow::ensure!(
        reference.step_train_loss.len() == merged.step_train_loss.len(),
        "drill(socket): loss trace lengths differ ({} vs {})",
        reference.step_train_loss.len(),
        merged.step_train_loss.len()
    );
    for (i, (x, y)) in reference.step_train_loss.iter().zip(&merged.step_train_loss).enumerate()
    {
        anyhow::ensure!(
            x.to_bits() == y.to_bits(),
            "drill(socket): step {i} train loss diverged: threaded {x} vs socket {y}"
        );
    }
    anyhow::ensure!(
        reference.comm == merged.comm,
        "drill(socket): communication accounting diverged:\n  threaded {:?}\n  socket   {:?}",
        reference.comm,
        merged.comm
    );
    println!(
        "drill(socket): merged socket trajectory bit-identical to the threaded run \
         ({} step losses, comm {:.1} MiB / {} msgs)",
        merged.step_train_loss.len(),
        merged.comm.mib_sent(),
        merged.comm.msgs_sent
    );
    Ok(())
}

/// Reserve-and-release an ephemeral loopback port for a drill's seed
/// node. The tiny release-to-bind window is acceptable for a local
/// drill; production runs pass an explicit `--seed-addr`.
fn free_loopback_port() -> anyhow::Result<u16> {
    let l = std::net::TcpListener::bind("127.0.0.1:0")?;
    Ok(l.local_addr()?.port())
}

/// Emit a small synthetic journal covering every event type — no
/// artifacts or training needed. `scripts/check_trace_schema.sh`
/// validates its output against the schema table.
fn cmd_obs_smoke(args: &Args) -> anyhow::Result<()> {
    use noloco::config::{ObsConfig, TraceLevel};
    use noloco::obs::{Event, ObsHub};
    use noloco::train::{AccountingComm, Communicator};

    let out = args.opt("out").unwrap_or("obs_smoke.jsonl").to_string();
    let obs_cfg = ObsConfig {
        trace_out: Some(out.clone()),
        metrics_out: None,
        trace_level: TraceLevel::Step,
    };
    let hub = ObsHub::from_config(&obs_cfg)?;
    let mut comm = AccountingComm::new();
    comm.set_obs(hub.clone());

    // A tiny synthetic run: replica 0 offers round-stashed state to
    // replica 1 at boundary 1; replica 1 folds it one boundary later
    // (age 1). The communicator journals the offer/fold pair itself;
    // the trainer-side events are recorded directly.
    hub.record(0, Event::InnerPhase { stage: 0, replica: 0, step: 0, loss: 2.5, dur_s: 0.01 });
    let delta = vec![0.5f32; 8];
    let phi = vec![1.0f32; 8];
    comm.set_obs_boundary(1, 49);
    comm.offer_round(0, 0, &[1], 1, 0, 2, &delta, &phi)?;
    comm.set_obs_boundary(2, 99);
    let folded = comm.collect_round(0, 1, 0, 1, 0, false)?;
    anyhow::ensure!(folded.is_some(), "smoke fold found no stashed offer");
    hub.record(99, Event::HeartbeatMiss { stage: 0, replica: 1, peer: 0, boundary: 2 });
    hub.record(99, Event::Detect { boundary: 2, node: 0, join: false });
    hub.record(99, Event::StashSwept { boundary: 2, dropped: 1 });
    hub.record(100, Event::ChurnApplied { step: 100, node: 0, join: true });
    let (bytes, msgs) = comm.wire_totals();
    hub.record(99, Event::Boundary { outer_idx: 2, inner_s: 0.5, sync_s: 0.05, bytes, msgs });
    hub.record(99, Event::Ckpt { boundary: 2, step: 100, bytes: 65536 });
    hub.record(100, Event::Resume { boundary: 2, step: 100 });
    hub.record(100, Event::Drain { outer_idx: 2, bytes: 0, msgs: 0 });
    hub.record(100, Event::NetPeer { peer: 1, bytes: 4096, msgs: 3, rtt_us: 120 });
    let report = hub.report();
    let events: u64 = report.counters.iter().map(|(_, v)| v).sum();
    println!("obs-smoke journal written to {out} ({events} events)");
    Ok(())
}

/// Write the deterministic cost-model baseline (`BENCH_baseline.json`);
/// `scripts/bench_check.sh` compares a fresh emission against the
/// checked-in copy and fails on >10% drift.
fn cmd_bench_baseline(args: &Args) -> anyhow::Result<()> {
    let out = args.opt("out").unwrap_or("BENCH_baseline.json");
    std::fs::write(out, noloco::obs::bench::baseline_json())?;
    println!("cost-model baseline written to {out}");
    Ok(())
}

/// Write the deterministic 64/256/1000-replica scale ladder
/// (`BENCH_steps.json`): steps/sec, bytes/boundary and modeled peak RSS
/// per rung. Same gate as the cost-model baseline
/// (`scripts/bench_check.sh`, >10% drift fails).
fn cmd_perf(args: &Args) -> anyhow::Result<()> {
    let out = args.opt("out").unwrap_or("BENCH_steps.json");
    std::fs::write(out, noloco::obs::bench::steps_json())?;
    for (k, v) in noloco::obs::bench::steps_ladder() {
        println!("{k} = {v}");
    }
    println!("scale ladder written to {out}");
    Ok(())
}

/// Static determinism/protocol analysis (rules R1–R5) over the crate's
/// own source tree. Exits 0 when clean, 1 with `file:line: [rule] msg`
/// diagnostics otherwise; `--format json` emits flat JSONL instead.
fn cmd_analyze(args: &Args) -> anyhow::Result<()> {
    use noloco::analyze;

    let root = match args.opt("root") {
        Some(r) => std::path::PathBuf::from(r),
        None => analyze::default_root()
            .ok_or_else(|| anyhow::anyhow!("no source tree found; pass --root DIR"))?,
    };
    let report = analyze::run_path(&root)?;
    match args.opt("format") {
        Some("json") => print!("{}", analyze::render_json(&report)),
        Some(other) if other != "text" => {
            anyhow::bail!("unknown --format `{other}` (expected text | json)")
        }
        _ => print!("{}", analyze::render_text(&report)),
    }
    if !report.clean() {
        // Diagnostics already printed; the nonzero exit is the verdict.
        std::process::exit(1);
    }
    Ok(())
}
