//! # NoLoCo — No-all-reduce Low Communication Training
//!
//! Production-shaped reproduction of *NoLoCo: No-all-reduce Low
//! Communication Training Method for Large Models* (Kolehmainen et al.,
//! 2025) as a three-layer Rust + JAX + Pallas stack.
//!
//! * **Layer 3 (this crate)** — the coordinator: topology, random pipeline
//!   routing, gossip outer steps, collectives, worker threads, data
//!   pipelines, metrics, CLI and config. Owns the event loop; Python never
//!   runs on the training path.
//! * **Layer 2** — `python/compile/model.py`: staged Llama-style
//!   transformer fwd/bwd + Adam + outer updates, AOT-lowered to HLO text.
//! * **Layer 1** — `python/compile/kernels/`: Pallas kernels (fused causal
//!   attention, fused NoLoCo outer update) called from Layer 2.
//!
//! The [`runtime`] module loads `artifacts/*.hlo.txt` through the PJRT C
//! API (`xla` crate) and executes them from the hot path.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`analyze`] | dependency-free static analysis of this tree: determinism / protocol-conformance rules R1–R5 behind `noloco analyze` |
//! | [`cli`] | zero-dependency argument parsing |
//! | [`config`] | TOML-subset parser, typed configs, paper presets (Table 1) |
//! | [`rngx`] | PCG64 RNG, normal / log-normal draws, permutations |
//! | [`tensor`] | host-side flat tensors + stats used by collectives |
//! | [`prop`] | minimal property-testing harness |
//! | [`net`] | discrete-event latency simulator + in-process message fabric |
//! | [`net::topo`] | heterogeneous WAN / hierarchical-DC topologies (regions, latency+bandwidth links, stragglers) + elastic membership (churn schedules, live sets, heartbeat failure detection) |
//! | [`collective`] | tree / ring all-reduce, broadcast, pair exchange; topology- and payload-aware cost models |
//! | [`obs`] | structured observability: JSONL run journal, counter registry, live metrics snapshots, deterministic cost-model baselines |
//! | [`routing`] | random-permutation pipeline routing (§3.1), incl. live-subset plans under churn |
//! | [`optim`] | Adam, LR schedules, DiLoCo Nesterov, NoLoCo modified Nesterov (Eq. 2) |
//! | [`quad`] | Theorem-1 quadratic-loss convergence harness |
//! | [`data`] | synthetic corpora, tokenizer, sharded loaders |
//! | [`metrics`] | perplexity, cross-replica weight σ, Pearson r, CSV |
//! | [`model`] | Rust mirror of Layer-2 stage parameter shapes |
//! | [`runtime`] | PJRT engine: artifact loading, compile cache, execution |
//! | [`train`] | distributed training API: one generic [`train::TrainerCore`] over pluggable [`train::SyncStrategy`] (fsdp / diloco / noloco / streaming-fragmented overlap via [`train::StreamingSync`] / bounded-staleness async gossip via [`train::AsyncGossipSync`]) and [`train::Communicator`] (accounting / fabric) impls, plus [`train::PairingPolicy`] gossip pairing |
//! | [`bench`] | measurement helpers for `cargo bench` targets |

// Panic discipline for library code: every `unwrap`/`expect` on the
// non-test path is either removed or carries a local, justified allow.
// Tests keep their idiomatic unwraps. (`unsafe_code = "deny"` lives in
// Cargo.toml `[lints]`; these are crate attrs so they scope to src/.)
#![warn(clippy::unwrap_used, clippy::expect_used)]
#![cfg_attr(test, allow(clippy::unwrap_used, clippy::expect_used))]

pub mod analyze;
pub mod bench;
pub mod cli;
pub mod collective;
pub mod config;
pub mod data;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod optim;
pub mod prop;
pub mod quad;
pub mod rngx;
pub mod routing;
pub mod runtime;
pub mod tensor;
pub mod train;
