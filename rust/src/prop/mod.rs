//! Minimal property-based testing harness.
//!
//! `proptest` is unavailable in this offline image, so the crate carries a
//! small in-repo equivalent: composable generators over a seeded
//! [`Pcg64`](crate::rngx::Pcg64), a runner that executes a property over
//! many random cases, and linear input shrinking on failure (retry with
//! "smaller" inputs derived from the failing seed's case). Coordinator
//! invariants (routing is a permutation, collectives preserve sums,
//! optimizer algebra) are checked with this harness in each module's
//! tests.
//!
//! ```no_run
//! # // no_run: doctest binaries lack the libxla rpath on this image.
//! use noloco::prop::{run, Gen};
//! run("sum is commutative", 256, |g| {
//!     let a = g.f32_in(-10.0, 10.0);
//!     let b = g.f32_in(-10.0, 10.0);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::rngx::Pcg64;

/// Per-case generator handle passed to properties.
pub struct Gen {
    rng: Pcg64,
    /// Size hint in `[0,1]`; early cases are "small", later cases larger.
    /// Generators scale their output ranges by this, which doubles as a
    /// crude shrinking mechanism: on failure the case is re-run at smaller
    /// sizes to report a minimal-ish reproduction.
    pub size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Self {
        Gen {
            rng: Pcg64::seed_from_u64(seed),
            size,
        }
    }

    /// Uniform `usize` in `[lo, hi]`, range scaled by the size hint.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let span = ((hi - lo) as f64 * self.size).ceil() as usize;
        lo + self.rng.next_below(span as u64 + 1) as usize
    }

    /// Uniform `f32` in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.next_f32()
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    /// Standard-normal `f32` vector of length `n`.
    pub fn vec_normal(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| (self.rng.next_normal() * std as f64) as f32).collect()
    }

    /// Coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Borrow the underlying RNG for bespoke draws.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `cases` random cases of a property. Panics (test failure) with the
/// reproducing seed if any case fails; before reporting, retries the
/// failing seed at smaller sizes to find a smaller reproduction.
pub fn run<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: u32, prop: F) {
    // Base seed is derived from the property name so independent
    // properties explore independent streams but remain reproducible.
    let base = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let size = ((case + 1) as f64 / cases as f64).min(1.0);
        let r = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, size);
            prop(&mut g);
        });
        if let Err(e) = r {
            // Shrink: retry the same seed at smaller sizes; report the
            // smallest size that still fails.
            let mut min_fail = size;
            for k in 1..=8 {
                let s = size * (1.0 - k as f64 / 9.0);
                if s <= 0.0 {
                    break;
                }
                let failed = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, s);
                    prop(&mut g);
                })
                .is_err();
                if failed {
                    min_fail = s;
                }
            }
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}, \
                 min failing size {min_fail:.3}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        run("tautology", 64, |g| {
            let x = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&x));
        });
    }

    #[test]
    fn catches_violations_and_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            run("always fails above threshold", 64, |g| {
                let x = g.usize_in(0, 100);
                assert!(x < 5, "x={x}");
            });
        });
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap();
        assert!(msg.contains("seed"), "missing repro seed in: {msg}");
    }

    #[test]
    fn sizes_grow_over_cases() {
        use std::sync::atomic::{AtomicU32, Ordering};
        static BIG: AtomicU32 = AtomicU32::new(0);
        run("size ramps", 100, |g| {
            if g.size > 0.9 {
                BIG.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(BIG.load(Ordering::Relaxed) >= 5);
    }
}
