//! Per-worker training state.
//!
//! A *worker* owns one pipeline stage of one data-parallel replica:
//! fast weights θ, Adam moments, and (for the inner/outer methods) the
//! slow weights φ and outer momentum δ of Eq. 1–3.

use crate::config::Method;
use crate::model::StageKind;

/// State of worker `(stage, replica)`.
#[derive(Clone, Debug)]
pub struct WorkerState {
    /// Pipeline stage index.
    pub stage: usize,
    /// Data-parallel replica index.
    pub replica: usize,
    /// Stage kind (selects the artifact set).
    pub kind: StageKind,
    /// Fast weights θ (flat).
    pub theta: Vec<f32>,
    /// Adam first moment.
    pub m: Vec<f32>,
    /// Adam second moment.
    pub v: Vec<f32>,
    /// Adam step count (1-based at first use).
    pub adam_t: u64,
    /// Slow weights φ (empty for FSDP).
    pub phi: Vec<f32>,
    /// Outer momentum δ (empty for FSDP).
    pub delta: Vec<f32>,
    /// Microbatch-accumulated gradient.
    pub grad_acc: Vec<f32>,
    /// Microbatches accumulated since the last optimizer step.
    pub acc_count: usize,
}

impl WorkerState {
    /// Fresh worker from shared initial weights (φ₀ ≡ θ₀ across replicas).
    pub fn new(
        stage: usize,
        replica: usize,
        kind: StageKind,
        init: Vec<f32>,
        method: Method,
    ) -> WorkerState {
        let n = init.len();
        let (phi, delta) = if method == Method::Fsdp {
            (Vec::new(), Vec::new())
        } else {
            (init.clone(), vec![0.0; n])
        };
        WorkerState {
            stage,
            replica,
            kind,
            theta: init,
            m: vec![0.0; n],
            v: vec![0.0; n],
            adam_t: 0,
            phi,
            delta,
            grad_acc: vec![0.0; n],
            acc_count: 0,
        }
    }

    /// Parameter count.
    pub fn len(&self) -> usize {
        self.theta.len()
    }

    /// True when the worker holds no parameters (never in practice).
    pub fn is_empty(&self) -> bool {
        self.theta.is_empty()
    }

    /// Add one microbatch's gradient into the accumulator. An empty
    /// accumulator (freshly drained, not yet recycled) re-arms lazily.
    pub fn accumulate(&mut self, g: &[f32]) {
        if self.grad_acc.is_empty() {
            self.grad_acc = vec![0.0; g.len()];
        }
        assert_eq!(g.len(), self.grad_acc.len());
        for (a, x) in self.grad_acc.iter_mut().zip(g) {
            *a += x;
        }
        self.acc_count += 1;
    }

    /// Drain the accumulator as the microbatch-mean gradient, leaving it
    /// empty. Callers hand the buffer back via
    /// [`recycle_grad`](WorkerState::recycle_grad) (or the next
    /// [`accumulate`](WorkerState::accumulate) re-arms it) — the
    /// inner-loop steady state allocates nothing.
    pub fn take_mean_grad(&mut self) -> Vec<f32> {
        assert!(self.acc_count > 0, "no gradients accumulated");
        let inv = 1.0 / self.acc_count as f32;
        let mut g = std::mem::take(&mut self.grad_acc);
        for x in &mut g {
            *x *= inv;
        }
        self.acc_count = 0;
        g
    }

    /// Return a drained gradient buffer to the accumulator, zeroed in
    /// place. No-op if the accumulator already re-armed.
    pub fn recycle_grad(&mut self, mut g: Vec<f32>) {
        if !self.grad_acc.is_empty() {
            return;
        }
        for x in &mut g {
            *x = 0.0;
        }
        self.grad_acc = g;
    }

    /// Outer gradient Δ = θ − φ (Eq. 1).
    pub fn outer_grad(&self) -> Vec<f32> {
        assert!(!self.phi.is_empty(), "outer_grad needs slow weights");
        self.theta
            .iter()
            .zip(&self.phi)
            .map(|(t, p)| t - p)
            .collect()
    }

    /// Reset fast weights to the (just-updated) slow weights; the start of
    /// the next inner phase in DiLoCo/NoLoCo.
    pub fn reset_theta_to_phi(&mut self) {
        self.theta.copy_from_slice(&self.phi);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w(method: Method) -> WorkerState {
        WorkerState::new(0, 0, StageKind::Full, vec![1.0, 2.0, 3.0], method)
    }

    #[test]
    fn fsdp_has_no_outer_state() {
        let st = w(Method::Fsdp);
        assert!(st.phi.is_empty() && st.delta.is_empty());
        let st = w(Method::NoLoCo);
        assert_eq!(st.phi, st.theta);
        assert_eq!(st.delta, vec![0.0; 3]);
    }

    #[test]
    fn accumulate_and_mean() {
        let mut st = w(Method::Fsdp);
        st.accumulate(&[1.0, 2.0, 3.0]);
        st.accumulate(&[3.0, 2.0, 1.0]);
        let g = st.take_mean_grad();
        assert_eq!(g, vec![2.0, 2.0, 2.0]);
        assert_eq!(st.acc_count, 0);
        // The buffer is handed out, not reallocated…
        assert!(st.grad_acc.is_empty());
        // …and recycling zeroes it in place.
        st.recycle_grad(g);
        assert_eq!(st.grad_acc, vec![0.0; 3]);
        // A drained-but-unrecycled accumulator re-arms on first use.
        let mut st = w(Method::Fsdp);
        st.accumulate(&[1.0, 1.0, 1.0]);
        let _ = st.take_mean_grad();
        st.accumulate(&[4.0, 5.0, 6.0]);
        assert_eq!(st.grad_acc, vec![4.0, 5.0, 6.0]);
    }

    #[test]
    #[should_panic(expected = "no gradients accumulated")]
    fn mean_grad_requires_accumulation() {
        w(Method::Fsdp).take_mean_grad();
    }

    #[test]
    fn outer_grad_is_theta_minus_phi() {
        let mut st = w(Method::NoLoCo);
        st.theta = vec![2.0, 4.0, 6.0];
        assert_eq!(st.outer_grad(), vec![1.0, 2.0, 3.0]);
        st.phi = vec![0.0, 0.0, 0.0];
        st.reset_theta_to_phi();
        assert_eq!(st.theta, vec![0.0; 3]);
    }
}
