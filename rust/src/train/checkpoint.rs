//! Training-state checkpoints.
//!
//! NoLoCo produces an *ensemble* of replicas (the paper's §6 observation),
//! so a checkpoint stores every worker's full state: fast weights θ, Adam
//! moments, slow weights φ and outer momentum δ. Format: a small
//! self-describing little-endian binary (magic + version + grid shape +
//! per-worker records). Data-loader cursors are *not* captured — resuming
//! re-reads the stream from the configured position, which is the usual
//! trade-off for deterministic synthetic data.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::state::WorkerState;

const MAGIC: &[u8; 8] = b"NOLOCKPT";
const VERSION: u32 = 1;

/// A serialized snapshot of the whole worker grid.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Inner step the snapshot was taken after.
    pub step: u64,
    /// Data-parallel world size.
    pub dp: u32,
    /// Pipeline stages.
    pub pp: u32,
    /// Worker records, stage-major (`stage * dp + replica`).
    pub workers: Vec<WorkerRecord>,
}

/// One worker's tensors.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerRecord {
    pub stage: u32,
    pub replica: u32,
    pub adam_t: u64,
    pub theta: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// Empty for FSDP runs.
    pub phi: Vec<f32>,
    /// Empty for FSDP runs.
    pub delta: Vec<f32>,
}

impl Checkpoint {
    /// Snapshot a worker grid.
    pub fn capture(step: u64, dp: usize, pp: usize, workers: &[WorkerState]) -> Checkpoint {
        assert_eq!(workers.len(), dp * pp);
        Checkpoint {
            step,
            dp: dp as u32,
            pp: pp as u32,
            workers: workers
                .iter()
                .map(|w| WorkerRecord {
                    stage: w.stage as u32,
                    replica: w.replica as u32,
                    adam_t: w.adam_t,
                    theta: w.theta.clone(),
                    m: w.m.clone(),
                    v: w.v.clone(),
                    phi: w.phi.clone(),
                    delta: w.delta.clone(),
                })
                .collect(),
        }
    }

    /// Restore tensors into a live worker grid (shapes must match).
    pub fn restore(&self, workers: &mut [WorkerState]) -> Result<u64> {
        ensure!(
            workers.len() == self.workers.len(),
            "grid mismatch: checkpoint has {} workers, run has {}",
            self.workers.len(),
            workers.len()
        );
        for (w, rec) in workers.iter_mut().zip(&self.workers) {
            ensure!(
                w.stage == rec.stage as usize && w.replica == rec.replica as usize,
                "worker order mismatch at ({}, {})",
                rec.stage,
                rec.replica
            );
            ensure!(
                w.theta.len() == rec.theta.len(),
                "shape mismatch at ({}, {}): {} vs {}",
                rec.stage,
                rec.replica,
                w.theta.len(),
                rec.theta.len()
            );
            w.theta.copy_from_slice(&rec.theta);
            w.m.copy_from_slice(&rec.m);
            w.v.copy_from_slice(&rec.v);
            w.adam_t = rec.adam_t;
            w.phi = rec.phi.clone();
            w.delta = rec.delta.clone();
        }
        Ok(self.step)
    }

    /// Write to a file (creating parent directories).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
        );
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&self.step.to_le_bytes())?;
        w.write_all(&self.dp.to_le_bytes())?;
        w.write_all(&self.pp.to_le_bytes())?;
        for rec in &self.workers {
            w.write_all(&rec.stage.to_le_bytes())?;
            w.write_all(&rec.replica.to_le_bytes())?;
            w.write_all(&rec.adam_t.to_le_bytes())?;
            for buf in [&rec.theta, &rec.m, &rec.v, &rec.phi, &rec.delta] {
                write_f32s(&mut w, buf)?;
            }
        }
        Ok(())
    }

    /// Read back from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let mut r = BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{} is not a NoLoCo checkpoint", path.display());
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let step = read_u64(&mut r)?;
        let dp = read_u32(&mut r)?;
        let pp = read_u32(&mut r)?;
        let n = (dp * pp) as usize;
        let mut workers = Vec::with_capacity(n);
        for _ in 0..n {
            let stage = read_u32(&mut r)?;
            let replica = read_u32(&mut r)?;
            let adam_t = read_u64(&mut r)?;
            let theta = read_f32s(&mut r)?;
            let m = read_f32s(&mut r)?;
            let v = read_f32s(&mut r)?;
            let phi = read_f32s(&mut r)?;
            let delta = read_f32s(&mut r)?;
            workers.push(WorkerRecord { stage, replica, adam_t, theta, m, v, phi, delta });
        }
        Ok(Checkpoint { step, dp, pp, workers })
    }
}

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> Result<()> {
    w.write_all(&(xs.len() as u64).to_le_bytes())?;
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32s<R: Read>(r: &mut R) -> Result<Vec<f32>> {
    let n = read_u64(r)? as usize;
    // 1 GiB sanity cap: a corrupt length should error, not OOM.
    ensure!(n < (1 << 28), "implausible tensor length {n}");
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::model::StageKind;

    fn grid() -> Vec<WorkerState> {
        let mut ws = Vec::new();
        for s in 0..2 {
            for r in 0..2 {
                let mut w = WorkerState::new(
                    s,
                    r,
                    StageKind::of_stage(s, 2),
                    vec![s as f32 + r as f32 * 0.5; 7],
                    Method::NoLoCo,
                );
                w.adam_t = 5;
                w.m[0] = 0.25;
                ws.push(w);
            }
        }
        ws
    }

    #[test]
    fn roundtrip_through_file() {
        let ws = grid();
        let ck = Checkpoint::capture(123, 2, 2, &ws);
        let path = std::env::temp_dir().join("noloco_ckpt_test.bin");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn restore_into_grid() {
        let ws = grid();
        let ck = Checkpoint::capture(9, 2, 2, &ws);
        let mut fresh = grid();
        for w in &mut fresh {
            w.theta.iter_mut().for_each(|x| *x = -1.0);
            w.adam_t = 0;
        }
        let step = ck.restore(&mut fresh).unwrap();
        assert_eq!(step, 9);
        assert_eq!(fresh[3].theta, ws[3].theta);
        assert_eq!(fresh[0].adam_t, 5);
    }

    #[test]
    fn restore_rejects_shape_mismatch() {
        let ws = grid();
        let ck = Checkpoint::capture(0, 2, 2, &ws);
        let mut wrong = vec![ws[0].clone()];
        assert!(ck.restore(&mut wrong).is_err());
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("noloco_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
