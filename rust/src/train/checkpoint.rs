//! Training-state checkpoints — the crash-recovery control plane's
//! on-disk format.
//!
//! NoLoCo produces an *ensemble* of replicas (the paper's §6 observation),
//! so a checkpoint stores every worker's full state: fast weights θ, Adam
//! moments, slow weights φ and outer momentum δ — plus everything else a
//! bit-identical resume needs: data-loader cursors, per-core boundary
//! clocks and live masks, the failure detector's verdicts, communication
//! accounting, fault-RNG streams, and the in-flight sync state
//! (streamed fragments awaiting their deferred fold, bounded-staleness
//! offers still inside their admission window).
//!
//! Checkpoints are taken at outer boundaries, *after* the outer step —
//! the grid's quiet point: gradient accumulators are empty, boundary
//! activation payloads are all consumed, and the only cross-boundary
//! state is the retained offer/fragment stash, which the strategy records
//! capture. Pairing draws, route plans and boundary clocks are *not*
//! serialized: they are pure functions of `(seed, schedule, outer_idx)`
//! and re-derive identically on resume.
//!
//! Format (version 2): `MAGIC | version | section count`, then one
//! section per state family — `id | length | payload | CRC-32` — so a
//! torn or bit-flipped file is rejected section-precisely instead of
//! deserializing garbage. [`Checkpoint::save`] writes to a sibling
//! temporary file and renames it into place, so a crash mid-write leaves
//! the previous checkpoint intact.

use std::collections::BTreeMap;
use std::io::{BufReader, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, ensure, Context, Result};

use super::state::WorkerState;
use super::CommStats;

const MAGIC: &[u8; 8] = b"NOLOCKPT";
const VERSION: u32 = 2;

const SEC_META: u32 = 1;
const SEC_WORKERS: u32 = 2;
const SEC_LOADERS: u32 = 3;
const SEC_CORES: u32 = 4;

/// A serialized snapshot of the whole worker grid plus the run's
/// coordination state.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Checkpoint {
    /// Inner steps completed when the snapshot was taken (a resumed run
    /// continues at step index `step`).
    pub step: u64,
    /// Outer boundaries completed (`step / inner_steps` when the cadence
    /// is boundary-aligned; 0 for bare tensor snapshots).
    pub outer_idx: u64,
    /// Data-parallel world size.
    pub dp: u32,
    /// Pipeline stages.
    pub pp: u32,
    /// Worker records, stage-major (`stage * dp + replica`).
    pub workers: Vec<WorkerRecord>,
    /// Per-replica data-loader cursors (stage-0 loaders own the stream).
    /// Empty for bare tensor snapshots.
    pub loaders: Vec<LoaderCursor>,
    /// Per-core runtime records: one for the grid executor, `dp · pp`
    /// for the threaded executor. Empty for bare tensor snapshots.
    pub cores: Vec<CoreRecord>,
}

/// One worker's tensors and in-flight sync state.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerRecord {
    pub stage: u32,
    pub replica: u32,
    pub adam_t: u64,
    pub theta: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// Empty for FSDP runs.
    pub phi: Vec<f32>,
    /// Empty for FSDP runs.
    pub delta: Vec<f32>,
    /// In-flight strategy state (streamed fragments, retained async
    /// offers); `None` for the lockstep strategies, which hold nothing
    /// across a boundary.
    pub strategy: Option<StrategyState>,
}

/// One data loader's stream position.
#[derive(Clone, Debug, PartialEq)]
pub struct LoaderCursor {
    pub replica: u32,
    pub cursor: u64,
}

/// One trainer core's runtime state (everything that is not worker
/// tensors but still shapes the trajectory or the final report).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct CoreRecord {
    /// Owning rank; `(0, 0)` with `grid = true` for the grid executor.
    pub stage: u32,
    pub replica: u32,
    /// True when this core owned the whole grid (sim executor).
    pub grid: bool,
    /// Live mask over DP replicas as this core saw it.
    pub live: Vec<bool>,
    /// Detector-suspected mask.
    pub suspected: Vec<bool>,
    /// Per-replica boundary clocks.
    pub clocks: Vec<u64>,
    /// Failure-detector state `(last_seen, dead)`, when detection is on.
    pub detector: Option<(Vec<u64>, Vec<bool>)>,
    /// Detection transitions so far: `(boundary, node, is_join)`.
    pub detected: Vec<(u64, u32, bool)>,
    /// Per-step training losses recorded so far (bit-exact, NaNs kept).
    pub step_train_loss: Vec<f64>,
    /// Eval trace rows so far: `(step, train, val, wstd, lr)`.
    pub trace: Vec<(u64, f64, f64, f64, f64)>,
    /// Wire totals at the last journaled boundary (delta attribution).
    pub last_wire: (u64, u64),
    /// Logical + wire communication accounting at snapshot time.
    pub stats: CommStats,
    /// Fabric fault-RNG stream `(state, inc)`, threaded executor only.
    pub fault_rng: Option<(u128, u128)>,
    /// This rank's fabric wire counters `(bytes, msgs)`, threaded only.
    pub wire_sent: (u64, u64),
}

/// In-flight synchronization state a strategy holds across boundaries.
#[derive(Clone, Debug, PartialEq)]
pub enum StrategyState {
    /// [`StreamingSync`](super::StreamingSync): fragments offered but not
    /// yet folded, plus the stale-drop counter.
    Streaming {
        inflight: Vec<InflightRecord>,
        dropped_stale: u64,
    },
    /// [`AsyncGossipSync`](super::AsyncGossipSync): own offers still
    /// inside the staleness window (re-published on resume so peers can
    /// fold them), plus the admission counters.
    Async {
        offers: Vec<OfferRecord>,
        admitted: u64,
        excluded_stale: u64,
        max_admitted_age: u64,
    },
}

/// One streamed fragment awaiting its deferred fold.
#[derive(Clone, Debug, PartialEq)]
pub struct InflightRecord {
    pub outer_idx: u64,
    pub frag: u32,
    pub group: Vec<u32>,
    pub live: Vec<u32>,
    pub delta: Vec<f32>,
    pub phi: Vec<f32>,
    pub theta: Vec<f32>,
}

/// One bounded-staleness offer retained inside the admission window.
#[derive(Clone, Debug, PartialEq)]
pub struct OfferRecord {
    pub round: u64,
    pub frag: u32,
    pub peers: Vec<u32>,
    pub delta: Vec<f32>,
    pub phi: Vec<f32>,
}

impl Checkpoint {
    /// Snapshot a worker grid's tensors only (no loaders, no core
    /// records) — the building block tests use; the trainers assemble
    /// full-fidelity checkpoints on top via their own capture paths.
    pub fn capture(step: u64, dp: usize, pp: usize, workers: &[WorkerState]) -> Checkpoint {
        assert_eq!(workers.len(), dp * pp);
        Checkpoint {
            step,
            outer_idx: 0,
            dp: dp as u32,
            pp: pp as u32,
            workers: workers.iter().map(|w| WorkerRecord::of(w, None)).collect(),
            loaders: Vec::new(),
            cores: Vec::new(),
        }
    }

    /// Restore tensors into a live worker grid (shapes must match).
    pub fn restore(&self, workers: &mut [WorkerState]) -> Result<u64> {
        ensure!(
            workers.len() == self.workers.len(),
            "grid mismatch: checkpoint has {} workers, run has {}",
            self.workers.len(),
            workers.len()
        );
        for (w, rec) in workers.iter_mut().zip(&self.workers) {
            rec.restore_into(w)?;
        }
        Ok(self.step)
    }

    /// The record for one worker, if present.
    pub fn worker(&self, stage: usize, replica: usize) -> Option<&WorkerRecord> {
        self.workers
            .iter()
            .find(|w| w.stage as usize == stage && w.replica as usize == replica)
    }

    /// The core record for one rank (or the grid core), if present.
    pub fn core(&self, stage: usize, replica: usize, grid: bool) -> Option<&CoreRecord> {
        self.cores.iter().find(|c| {
            c.grid == grid && (grid || (c.stage as usize == stage && c.replica as usize == replica))
        })
    }

    /// A replica's checkpointed loader cursor, if present.
    pub fn loader_cursor(&self, replica: usize) -> Option<u64> {
        self.loaders
            .iter()
            .find(|l| l.replica as usize == replica)
            .map(|l| l.cursor)
    }

    /// Write atomically (tmp + rename, creating parent directories);
    /// returns the file size in bytes.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<u64> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let bytes = self.to_bytes();
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, &bytes)
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} into place", path.display()))?;
        Ok(bytes.len() as u64)
    }

    /// Serialize to the versioned sectioned byte format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut meta = Vec::new();
        put_u64(&mut meta, self.step);
        put_u64(&mut meta, self.outer_idx);
        put_u32(&mut meta, self.dp);
        put_u32(&mut meta, self.pp);

        let mut workers = Vec::new();
        put_u32(&mut workers, self.workers.len() as u32);
        for rec in &self.workers {
            rec.encode(&mut workers);
        }

        let mut loaders = Vec::new();
        put_u32(&mut loaders, self.loaders.len() as u32);
        for l in &self.loaders {
            put_u32(&mut loaders, l.replica);
            put_u64(&mut loaders, l.cursor);
        }

        let mut cores = Vec::new();
        put_u32(&mut cores, self.cores.len() as u32);
        for c in &self.cores {
            c.encode(&mut cores);
        }

        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&4u32.to_le_bytes());
        for (id, body) in [
            (SEC_META, &meta),
            (SEC_WORKERS, &workers),
            (SEC_LOADERS, &loaders),
            (SEC_CORES, &cores),
        ] {
            out.extend_from_slice(&id.to_le_bytes());
            out.extend_from_slice(&(body.len() as u64).to_le_bytes());
            out.extend_from_slice(body);
            out.extend_from_slice(&crc32(body).to_le_bytes());
        }
        out
    }

    /// Read back from a file, verifying magic, version and per-section
    /// CRCs.
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let mut r = BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut bytes = Vec::new();
        r.read_to_end(&mut bytes)?;
        Self::from_bytes(&bytes).with_context(|| format!("reading {}", path.display()))
    }

    /// Deserialize from the sectioned byte format.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        ensure!(bytes.len() >= 16, "truncated checkpoint header");
        ensure!(&bytes[..8] == MAGIC, "not a NoLoCo checkpoint");
        let version = u32::from_le_bytes(arr(&bytes[8..12]));
        if version != VERSION {
            bail!("unsupported checkpoint version {version} (want {VERSION})");
        }
        let nsec = u32::from_le_bytes(arr(&bytes[12..16])) as usize;
        ensure!(nsec <= 64, "implausible section count {nsec}");
        let mut sections: BTreeMap<u32, &[u8]> = BTreeMap::new();
        let mut i = 16usize;
        for _ in 0..nsec {
            ensure!(bytes.len() >= i + 12, "truncated section header");
            let id = u32::from_le_bytes(arr(&bytes[i..i + 4]));
            let len = u64::from_le_bytes(arr(&bytes[i + 4..i + 12])) as usize;
            i += 12;
            ensure!(bytes.len() >= i + len + 4, "truncated section {id}");
            let body = &bytes[i..i + len];
            i += len;
            let want = u32::from_le_bytes(arr(&bytes[i..i + 4]));
            i += 4;
            ensure!(
                crc32(body) == want,
                "section {id} failed its CRC check (corrupt checkpoint)"
            );
            sections.insert(id, body);
        }

        let meta = sections.get(&SEC_META).context("checkpoint missing meta section")?;
        let mut c = Cur::new(meta);
        let step = c.u64()?;
        let outer_idx = c.u64()?;
        let dp = c.u32()?;
        let pp = c.u32()?;

        let wsec = sections
            .get(&SEC_WORKERS)
            .context("checkpoint missing workers section")?;
        let mut c = Cur::new(wsec);
        let n = c.u32()? as usize;
        ensure!(n <= 1 << 20, "implausible worker count {n}");
        let mut workers = Vec::with_capacity(n);
        for _ in 0..n {
            workers.push(WorkerRecord::decode(&mut c)?);
        }

        let mut loaders = Vec::new();
        if let Some(lsec) = sections.get(&SEC_LOADERS) {
            let mut c = Cur::new(lsec);
            let n = c.u32()? as usize;
            ensure!(n <= 1 << 20, "implausible loader count {n}");
            for _ in 0..n {
                let replica = c.u32()?;
                let cursor = c.u64()?;
                loaders.push(LoaderCursor { replica, cursor });
            }
        }

        let mut cores = Vec::new();
        if let Some(csec) = sections.get(&SEC_CORES) {
            let mut c = Cur::new(csec);
            let n = c.u32()? as usize;
            ensure!(n <= 1 << 20, "implausible core count {n}");
            for _ in 0..n {
                cores.push(CoreRecord::decode(&mut c)?);
            }
        }

        Ok(Checkpoint { step, outer_idx, dp, pp, workers, loaders, cores })
    }
}

impl WorkerRecord {
    /// Snapshot one worker's tensors plus its strategy's in-flight state.
    pub fn of(w: &WorkerState, strategy: Option<StrategyState>) -> WorkerRecord {
        WorkerRecord {
            stage: w.stage as u32,
            replica: w.replica as u32,
            adam_t: w.adam_t,
            theta: w.theta.clone(),
            m: w.m.clone(),
            v: w.v.clone(),
            phi: w.phi.clone(),
            delta: w.delta.clone(),
            strategy,
        }
    }

    /// Restore this record's tensors into a live worker (shape-checked).
    pub fn restore_into(&self, w: &mut WorkerState) -> Result<()> {
        ensure!(
            w.stage == self.stage as usize && w.replica == self.replica as usize,
            "worker order mismatch at ({}, {})",
            self.stage,
            self.replica
        );
        ensure!(
            w.theta.len() == self.theta.len(),
            "shape mismatch at ({}, {}): {} vs {}",
            self.stage,
            self.replica,
            w.theta.len(),
            self.theta.len()
        );
        w.theta.copy_from_slice(&self.theta);
        w.m.copy_from_slice(&self.m);
        w.v.copy_from_slice(&self.v);
        w.adam_t = self.adam_t;
        w.phi = self.phi.clone();
        w.delta = self.delta.clone();
        Ok(())
    }

    fn encode(&self, b: &mut Vec<u8>) {
        put_u32(b, self.stage);
        put_u32(b, self.replica);
        put_u64(b, self.adam_t);
        for buf in [&self.theta, &self.m, &self.v, &self.phi, &self.delta] {
            put_f32s(b, buf);
        }
        match &self.strategy {
            None => put_u8(b, 0),
            Some(StrategyState::Streaming { inflight, dropped_stale }) => {
                put_u8(b, 1);
                put_u64(b, *dropped_stale);
                put_u32(b, inflight.len() as u32);
                for e in inflight {
                    put_u64(b, e.outer_idx);
                    put_u32(b, e.frag);
                    put_u32s(b, &e.group);
                    put_u32s(b, &e.live);
                    put_f32s(b, &e.delta);
                    put_f32s(b, &e.phi);
                    put_f32s(b, &e.theta);
                }
            }
            Some(StrategyState::Async { offers, admitted, excluded_stale, max_admitted_age }) => {
                put_u8(b, 2);
                put_u64(b, *admitted);
                put_u64(b, *excluded_stale);
                put_u64(b, *max_admitted_age);
                put_u32(b, offers.len() as u32);
                for o in offers {
                    put_u64(b, o.round);
                    put_u32(b, o.frag);
                    put_u32s(b, &o.peers);
                    put_f32s(b, &o.delta);
                    put_f32s(b, &o.phi);
                }
            }
        }
    }

    fn decode(c: &mut Cur) -> Result<WorkerRecord> {
        let stage = c.u32()?;
        let replica = c.u32()?;
        let adam_t = c.u64()?;
        let theta = c.f32s()?;
        let m = c.f32s()?;
        let v = c.f32s()?;
        let phi = c.f32s()?;
        let delta = c.f32s()?;
        let strategy = match c.u8()? {
            0 => None,
            1 => {
                let dropped_stale = c.u64()?;
                let n = c.u32()? as usize;
                ensure!(n <= 1 << 16, "implausible inflight count {n}");
                let mut inflight = Vec::with_capacity(n);
                for _ in 0..n {
                    inflight.push(InflightRecord {
                        outer_idx: c.u64()?,
                        frag: c.u32()?,
                        group: c.u32s()?,
                        live: c.u32s()?,
                        delta: c.f32s()?,
                        phi: c.f32s()?,
                        theta: c.f32s()?,
                    });
                }
                Some(StrategyState::Streaming { inflight, dropped_stale })
            }
            2 => {
                let admitted = c.u64()?;
                let excluded_stale = c.u64()?;
                let max_admitted_age = c.u64()?;
                let n = c.u32()? as usize;
                ensure!(n <= 1 << 16, "implausible offer count {n}");
                let mut offers = Vec::with_capacity(n);
                for _ in 0..n {
                    offers.push(OfferRecord {
                        round: c.u64()?,
                        frag: c.u32()?,
                        peers: c.u32s()?,
                        delta: c.f32s()?,
                        phi: c.f32s()?,
                    });
                }
                Some(StrategyState::Async { offers, admitted, excluded_stale, max_admitted_age })
            }
            t => bail!("unknown strategy-state tag {t}"),
        };
        Ok(WorkerRecord { stage, replica, adam_t, theta, m, v, phi, delta, strategy })
    }
}

impl CoreRecord {
    fn encode(&self, b: &mut Vec<u8>) {
        put_u32(b, self.stage);
        put_u32(b, self.replica);
        put_u8(b, self.grid as u8);
        put_bools(b, &self.live);
        put_bools(b, &self.suspected);
        put_u64s(b, &self.clocks);
        match &self.detector {
            None => put_u8(b, 0),
            Some((seen, dead)) => {
                put_u8(b, 1);
                put_u64s(b, seen);
                put_bools(b, dead);
            }
        }
        put_u32(b, self.detected.len() as u32);
        for &(boundary, node, join) in &self.detected {
            put_u64(b, boundary);
            put_u32(b, node);
            put_u8(b, join as u8);
        }
        put_f64s(b, &self.step_train_loss);
        put_u32(b, self.trace.len() as u32);
        for &(s, t, v, w, l) in &self.trace {
            put_u64(b, s);
            put_f64(b, t);
            put_f64(b, v);
            put_f64(b, w);
            put_f64(b, l);
        }
        put_u64(b, self.last_wire.0);
        put_u64(b, self.last_wire.1);
        for x in [
            self.stats.floats_sent,
            self.stats.activation_hops,
            self.stats.blocking_collectives,
            self.stats.pair_exchanges,
            self.stats.bytes_sent,
            self.stats.msgs_sent,
        ] {
            put_u64(b, x);
        }
        match self.fault_rng {
            None => put_u8(b, 0),
            Some((state, inc)) => {
                put_u8(b, 1);
                put_u64(b, (state >> 64) as u64);
                put_u64(b, state as u64);
                put_u64(b, (inc >> 64) as u64);
                put_u64(b, inc as u64);
            }
        }
        put_u64(b, self.wire_sent.0);
        put_u64(b, self.wire_sent.1);
    }

    fn decode(c: &mut Cur) -> Result<CoreRecord> {
        let stage = c.u32()?;
        let replica = c.u32()?;
        let grid = c.u8()? != 0;
        let live = c.bools()?;
        let suspected = c.bools()?;
        let clocks = c.u64s()?;
        let detector = match c.u8()? {
            0 => None,
            _ => Some((c.u64s()?, c.bools()?)),
        };
        let n = c.u32()? as usize;
        ensure!(n <= 1 << 16, "implausible detected-event count {n}");
        let mut detected = Vec::with_capacity(n);
        for _ in 0..n {
            let boundary = c.u64()?;
            let node = c.u32()?;
            let join = c.u8()? != 0;
            detected.push((boundary, node, join));
        }
        let step_train_loss = c.f64s()?;
        let n = c.u32()? as usize;
        ensure!(n <= 1 << 24, "implausible trace length {n}");
        let mut trace = Vec::with_capacity(n);
        for _ in 0..n {
            trace.push((c.u64()?, c.f64()?, c.f64()?, c.f64()?, c.f64()?));
        }
        let last_wire = (c.u64()?, c.u64()?);
        let stats = CommStats {
            floats_sent: c.u64()?,
            activation_hops: c.u64()?,
            blocking_collectives: c.u64()?,
            pair_exchanges: c.u64()?,
            bytes_sent: c.u64()?,
            msgs_sent: c.u64()?,
        };
        let fault_rng = match c.u8()? {
            0 => None,
            _ => {
                let sh = c.u64()?;
                let sl = c.u64()?;
                let ih = c.u64()?;
                let il = c.u64()?;
                Some((((sh as u128) << 64) | sl as u128, ((ih as u128) << 64) | il as u128))
            }
        };
        let wire_sent = (c.u64()?, c.u64()?);
        Ok(CoreRecord {
            stage,
            replica,
            grid,
            live,
            suspected,
            clocks,
            detector,
            detected,
            step_train_loss,
            trace,
            last_wire,
            stats,
            fault_rng,
            wire_sent,
        })
    }
}

/// One rank's contribution to a threaded-executor checkpoint.
#[derive(Clone, Debug)]
pub struct RankSnapshot {
    /// Inner steps completed (identical across ranks at a boundary).
    pub step: u64,
    /// Outer boundaries completed.
    pub outer_idx: u64,
    /// This rank's worker record.
    pub worker: WorkerRecord,
    /// This rank's loader cursor (stage-0 ranks only).
    pub loader: Option<LoaderCursor>,
    /// This rank's core runtime record.
    pub core: CoreRecord,
}

/// Assembles rank-local snapshots into one consistent boundary-aligned
/// checkpoint — the threaded executor's coordinator. Each worker submits
/// its [`RankSnapshot`] when the cadence fires; the rank completing the
/// set writes the merged file atomically. No barrier: ranks submit and
/// move on, so a checkpoint costs no synchronization beyond one mutex.
pub struct CkptAssembler {
    path: PathBuf,
    world: usize,
    pending: Mutex<BTreeMap<u64, Vec<RankSnapshot>>>,
}

impl CkptAssembler {
    /// Coordinator writing to `path` once all `dp · pp` ranks have
    /// submitted a snapshot for the same step.
    pub fn new(path: impl Into<PathBuf>, dp: usize, pp: usize) -> CkptAssembler {
        CkptAssembler { path: path.into(), world: dp * pp, pending: Mutex::new(BTreeMap::new()) }
    }

    /// Submit one rank's snapshot. Returns `Some(bytes_written)` for the
    /// rank that completed the set (it performed the write), `None`
    /// otherwise.
    pub fn submit(&self, dp: u32, pp: u32, snap: RankSnapshot) -> Result<Option<u64>> {
        let step = snap.step;
        let ready = {
            let mut p = self.pending.lock().unwrap_or_else(|e| e.into_inner());
            let v = p.entry(step).or_default();
            v.push(snap);
            if v.len() == self.world {
                p.remove(&step)
            } else {
                None
            }
        };
        let Some(mut snaps) = ready else { return Ok(None) };
        snaps.sort_by_key(|s| (s.worker.stage, s.worker.replica));
        let outer_idx = snaps[0].outer_idx;
        let mut loaders: Vec<LoaderCursor> =
            snaps.iter().filter_map(|s| s.loader.clone()).collect();
        loaders.sort_by_key(|l| l.replica);
        let ck = Checkpoint {
            step,
            outer_idx,
            dp,
            pp,
            workers: snaps.iter().map(|s| s.worker.clone()).collect(),
            loaders,
            cores: snaps.iter().map(|s| s.core.clone()).collect(),
        };
        let bytes = ck.save(&self.path)?;
        Ok(Some(bytes))
    }
}

/// CRC-32 (IEEE 802.3 polynomial, reflected) — the per-section frame
/// check.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xffff_ffffu32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xedb8_8320 & mask);
        }
    }
    !crc
}

// ---- little-endian encoding helpers ----

/// Exact-length slice→array for `from_le_bytes`. Callers pass slices
/// whose length is checked (or produced by `chunks_exact`), so the
/// conversion cannot fail.
#[allow(clippy::unwrap_used)]
fn arr<const N: usize>(b: &[u8]) -> [u8; N] {
    b.try_into().unwrap()
}

fn put_u8(b: &mut Vec<u8>, x: u8) {
    b.push(x);
}

fn put_u32(b: &mut Vec<u8>, x: u32) {
    b.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, x: u64) {
    b.extend_from_slice(&x.to_le_bytes());
}

fn put_f64(b: &mut Vec<u8>, x: f64) {
    b.extend_from_slice(&x.to_le_bytes());
}

fn put_f32s(b: &mut Vec<u8>, xs: &[f32]) {
    put_u64(b, xs.len() as u64);
    for x in xs {
        b.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_f64s(b: &mut Vec<u8>, xs: &[f64]) {
    put_u64(b, xs.len() as u64);
    for x in xs {
        b.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_u32s(b: &mut Vec<u8>, xs: &[u32]) {
    put_u64(b, xs.len() as u64);
    for &x in xs {
        put_u32(b, x);
    }
}

fn put_u64s(b: &mut Vec<u8>, xs: &[u64]) {
    put_u64(b, xs.len() as u64);
    for &x in xs {
        put_u64(b, x);
    }
}

fn put_bools(b: &mut Vec<u8>, xs: &[bool]) {
    put_u64(b, xs.len() as u64);
    for &x in xs {
        b.push(x as u8);
    }
}

/// Bounds-checked little-endian cursor over a section body.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.i + n <= self.b.len(), "truncated checkpoint section");
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(arr(self.take(4)?)))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(arr(self.take(8)?)))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(arr(self.take(8)?)))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u64()? as usize;
        // 1 GiB sanity cap: a corrupt length should error, not OOM.
        ensure!(n < (1 << 28), "implausible tensor length {n}");
        Ok(self
            .take(n * 4)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u64()? as usize;
        ensure!(n < (1 << 27), "implausible series length {n}");
        Ok(self
            .take(n * 8)?
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(arr(c)))
            .collect())
    }

    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u64()? as usize;
        ensure!(n < (1 << 24), "implausible index length {n}");
        Ok(self
            .take(n * 4)?
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(arr(c)))
            .collect())
    }

    fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.u64()? as usize;
        ensure!(n < (1 << 24), "implausible series length {n}");
        Ok(self
            .take(n * 8)?
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(arr(c)))
            .collect())
    }

    fn bools(&mut self) -> Result<Vec<bool>> {
        let n = self.u64()? as usize;
        ensure!(n < (1 << 24), "implausible mask length {n}");
        Ok(self.take(n)?.iter().map(|&b| b != 0).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::model::StageKind;

    fn grid() -> Vec<WorkerState> {
        let mut ws = Vec::new();
        for s in 0..2 {
            for r in 0..2 {
                let mut w = WorkerState::new(
                    s,
                    r,
                    StageKind::of_stage(s, 2),
                    vec![s as f32 + r as f32 * 0.5; 7],
                    Method::NoLoCo,
                );
                w.adam_t = 5;
                w.m[0] = 0.25;
                ws.push(w);
            }
        }
        ws
    }

    fn full_checkpoint() -> Checkpoint {
        let ws = grid();
        let mut ck = Checkpoint::capture(123, 2, 2, &ws);
        ck.outer_idx = 3;
        ck.workers[1].strategy = Some(StrategyState::Streaming {
            inflight: vec![InflightRecord {
                outer_idx: 3,
                frag: 1,
                group: vec![0, 1],
                live: vec![0, 1],
                delta: vec![0.5, -0.5],
                phi: vec![1.0, 2.0],
                theta: vec![1.5, 2.5],
            }],
            dropped_stale: 2,
        });
        ck.workers[2].strategy = Some(StrategyState::Async {
            offers: vec![OfferRecord {
                round: 3,
                frag: 0,
                peers: vec![1],
                delta: vec![0.25; 3],
                phi: vec![0.75; 3],
            }],
            admitted: 7,
            excluded_stale: 1,
            max_admitted_age: 2,
        });
        ck.loaders = vec![
            LoaderCursor { replica: 0, cursor: 40 },
            LoaderCursor { replica: 1, cursor: 40 },
        ];
        ck.cores = vec![CoreRecord {
            stage: 0,
            replica: 0,
            grid: true,
            live: vec![true, false],
            suspected: vec![false, true],
            clocks: vec![3, 1],
            detector: Some((vec![3, 1], vec![false, true])),
            detected: vec![(2, 1, false)],
            step_train_loss: vec![1.5, f64::NAN, 1.25],
            trace: vec![(10, 1.5, 1.6, 0.01, 3e-4)],
            last_wire: (4096, 12),
            stats: CommStats {
                floats_sent: 100,
                activation_hops: 8,
                blocking_collectives: 0,
                pair_exchanges: 4,
                bytes_sent: 4096,
                msgs_sent: 12,
            },
            fault_rng: Some((u128::MAX - 5, 12345)),
            wire_sent: (2048, 6),
        }];
        ck
    }

    #[test]
    fn roundtrip_through_file() {
        let ws = grid();
        let ck = Checkpoint::capture(123, 2, 2, &ws);
        let path = std::env::temp_dir().join("noloco_ckpt_test.bin");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn full_fidelity_roundtrip_preserves_every_section() {
        let ck = full_checkpoint();
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        // NaN != NaN defeats PartialEq on the loss series; compare bits.
        assert_eq!(
            back.cores[0]
                .step_train_loss
                .iter()
                .map(|x| x.to_bits())
                .collect::<Vec<_>>(),
            ck.cores[0]
                .step_train_loss
                .iter()
                .map(|x| x.to_bits())
                .collect::<Vec<_>>()
        );
        assert_eq!(back.workers, ck.workers);
        assert_eq!(back.loaders, ck.loaders);
        assert_eq!(back.cores[0].fault_rng, ck.cores[0].fault_rng);
        assert_eq!(back.cores[0].stats, ck.cores[0].stats);
        assert_eq!(back.outer_idx, 3);
        assert_eq!(back.loader_cursor(1), Some(40));
        assert!(back.core(0, 0, true).is_some());
        assert!(back.worker(1, 1).is_some());
    }

    #[test]
    fn restore_into_grid() {
        let ws = grid();
        let ck = Checkpoint::capture(9, 2, 2, &ws);
        let mut fresh = grid();
        for w in &mut fresh {
            w.theta.iter_mut().for_each(|x| *x = -1.0);
            w.adam_t = 0;
        }
        let step = ck.restore(&mut fresh).unwrap();
        assert_eq!(step, 9);
        assert_eq!(fresh[3].theta, ws[3].theta);
        assert_eq!(fresh[0].adam_t, 5);
    }

    #[test]
    fn restore_rejects_shape_mismatch() {
        let ws = grid();
        let ck = Checkpoint::capture(0, 2, 2, &ws);
        let mut wrong = vec![ws[0].clone()];
        assert!(ck.restore(&mut wrong).is_err());
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("noloco_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bit_flip_fails_the_section_crc() {
        let ck = full_checkpoint();
        let mut bytes = ck.to_bytes();
        // Flip one payload bit well past the headers.
        let at = bytes.len() / 2;
        bytes[at] ^= 0x10;
        let err = Checkpoint::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("CRC") || err.contains("truncated"), "got: {err}");
    }

    #[test]
    fn save_is_atomic_tmp_plus_rename() {
        let dir = std::env::temp_dir().join("noloco_ckpt_atomic_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ck.bin");
        let ck = full_checkpoint();
        ck.save(&path).unwrap();
        // The temporary staging file must not survive.
        assert!(!path.with_extension("tmp").exists());
        assert!(Checkpoint::load(&path).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn assembler_writes_once_all_ranks_submit() {
        let ws = grid();
        let dir = std::env::temp_dir().join("noloco_ckpt_asm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("asm.bin");
        let asm = CkptAssembler::new(&path, 2, 2);
        let mut wrote = Vec::new();
        for (i, w) in ws.iter().enumerate() {
            let snap = RankSnapshot {
                step: 20,
                outer_idx: 2,
                worker: WorkerRecord::of(w, None),
                loader: (w.stage == 0).then(|| LoaderCursor {
                    replica: w.replica as u32,
                    cursor: 40 + w.replica as u64,
                }),
                core: CoreRecord {
                    stage: w.stage as u32,
                    replica: w.replica as u32,
                    ..CoreRecord::default()
                },
            };
            let r = asm.submit(2, 2, snap).unwrap();
            wrote.push((i, r));
        }
        // Exactly the final submission performed the write.
        assert!(wrote[..3].iter().all(|(_, r)| r.is_none()));
        assert!(wrote[3].1.is_some());
        let ck = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.step, 20);
        assert_eq!(ck.outer_idx, 2);
        assert_eq!(ck.workers.len(), 4);
        // Stage-major worker order, ascending loader cursors by replica.
        assert!(ck.workers.windows(2).all(|w| (w[0].stage, w[0].replica)
            <= (w[1].stage, w[1].replica)));
        assert_eq!(ck.loader_cursor(0), Some(40));
        assert_eq!(ck.loader_cursor(1), Some(41));
        assert_eq!(ck.cores.len(), 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crc32_matches_known_vector() {
        // The classic IEEE check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
    }
}
