//! Reusable fold-scratch buffers — the allocation-free boundary path.
//!
//! Every outer boundary used to allocate (and drop) a fresh pair of
//! accumulator vectors per fold — `dsum`/`psum` for the Eq. 2 weighted
//! sums — plus a Δ staging vector per offer. At `O(1000)` replicas those
//! transient allocations dominate the boundary cost. [`FoldScratch`] is
//! a small per-strategy arena: the buffers are allocated once, resized
//! lazily to the fragment length in play, and rewritten in place at
//! every boundary.
//!
//! Numerics are untouched: the scratch is fully overwritten by
//! [`FoldScratch::seed`] / [`FoldScratch::zeroed`] before any
//! accumulation, so a reused buffer holds exactly the values a freshly
//! allocated one would — the fold's f32 addition order (and therefore
//! its bits) is decided by the caller, never by the arena.

/// Per-strategy scratch arena for boundary folds. See the module docs.
#[derive(Debug, Default)]
pub struct FoldScratch {
    /// Weighted Δ accumulator (`Σ wᵩ Δᵩ` staging).
    dsum: Vec<f32>,
    /// Weighted φ accumulator (`Σ wᵩ φᵩ` staging).
    psum: Vec<f32>,
    /// Δ staging for offers that serialize `θ − φ` without retaining it.
    grad: Vec<f32>,
}

impl FoldScratch {
    /// Seed the accumulators with this worker's own contribution:
    /// `dsum = θ − φ`, `psum = φ` (elementwise, `θ.len()` entries).
    /// Returns both buffers for in-place accumulation.
    pub fn seed(&mut self, theta: &[f32], phi: &[f32]) -> (&mut Vec<f32>, &mut Vec<f32>) {
        debug_assert_eq!(theta.len(), phi.len());
        self.dsum.clear();
        self.dsum.extend(theta.iter().zip(phi).map(|(t, p)| t - p));
        self.psum.clear();
        self.psum.extend_from_slice(phi);
        (&mut self.dsum, &mut self.psum)
    }

    /// Zero both accumulators to length `n` and return them (the
    /// group-ordered accumulation path, where the caller adds its own
    /// entry at its group position).
    pub fn zeroed(&mut self, n: usize) -> (&mut Vec<f32>, &mut Vec<f32>) {
        self.dsum.clear();
        self.dsum.resize(n, 0.0);
        self.psum.clear();
        self.psum.resize(n, 0.0);
        (&mut self.dsum, &mut self.psum)
    }

    /// Stage `Δ = θ − φ` into the arena and return it as a borrowed
    /// slice — for offer paths that ship Δ but do not retain it.
    pub fn delta_of(&mut self, theta: &[f32], phi: &[f32]) -> &[f32] {
        debug_assert_eq!(theta.len(), phi.len());
        self.grad.clear();
        self.grad.extend(theta.iter().zip(phi).map(|(t, p)| t - p));
        &self.grad
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_writes_delta_and_phi() {
        let mut s = FoldScratch::default();
        let theta = [3.0f32, 5.0, 7.0];
        let phi = [1.0f32, 1.0, 2.0];
        let (d, p) = s.seed(&theta, &phi);
        assert_eq!(d.as_slice(), &[2.0, 4.0, 5.0]);
        assert_eq!(p.as_slice(), &[1.0, 1.0, 2.0]);
    }

    #[test]
    fn reuse_is_equivalent_to_fresh() {
        // A dirtied arena reseeded over a *shorter* fragment must match a
        // fresh allocation exactly — stale tail values may not leak.
        let mut s = FoldScratch::default();
        s.seed(&[9.0; 8], &[1.0; 8]);
        let (d, p) = s.seed(&[2.0, 4.0], &[1.0, 1.0]);
        assert_eq!(d.as_slice(), &[1.0, 3.0]);
        assert_eq!(p.as_slice(), &[1.0, 1.0]);
        let (d, p) = s.zeroed(3);
        assert_eq!(d.as_slice(), &[0.0; 3]);
        assert_eq!(p.as_slice(), &[0.0; 3]);
    }

    #[test]
    fn delta_of_stages_in_place() {
        let mut s = FoldScratch::default();
        assert_eq!(s.delta_of(&[5.0, 6.0], &[1.0, 4.0]), &[4.0, 2.0]);
        // Reuse overwrites rather than appends.
        assert_eq!(s.delta_of(&[1.0], &[1.0]), &[0.0]);
    }
}
