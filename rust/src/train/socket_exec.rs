//! Socket executor — one OS *process* per rank over real TCP, the
//! deployable counterpart of the threaded executor.
//!
//! [`SocketTrainer`] runs exactly one rank of the DP × PP grid: it joins
//! the TCP world through the seed-node protocol
//! ([`SocketEndpoint::bootstrap`]), wraps the endpoint in a
//! [`SocketComm`](super::SocketComm) (the same `EndpointComm` protocol
//! logic as the threaded executor, over a different
//! [`Channel`](crate::net::Channel)), and drives the shared
//! [`TrainerCore`] for its `(stage, replica)`. Route plans, gossip
//! pairings and live sets derive from the shared seed — same as the
//! threaded workers — so N processes coordinate without a master.
//!
//! What a single process cannot do is fold the whole run's report: each
//! rank writes a [`RankReport`] (deterministic key=value text, loss bits
//! and counters in hex) and the launching side merges them with
//! [`merge_rank_reports`] — the same arithmetic, in the same rank order,
//! as the threaded aggregation, so a merged socket run's per-step losses
//! and `CommStats` are bit-identical to the same-seed threaded run.
//!
//! Checkpointing is per-rank: each process assembles a single-rank
//! snapshot file (`<path>.rank<R>`) stamped with the real grid metadata,
//! and a restarted process resumes from its own file while peers replay
//! their retained offers over the wire — the cross-process form of the
//! kill-restart drill.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::config::TrainConfig;
use crate::net::SocketEndpoint;
use crate::obs::{Event, ObsHub};
use crate::runtime::{find_build, Engine, Manifest};

use super::checkpoint::{Checkpoint, CkptAssembler};
use super::comm::SocketComm;
use super::core::TrainerCore;
use super::strategy::{self, ChurnResponse};
use super::{CommStats, Communicator, TrainReport};

/// Single-rank socket trainer; the process-per-rank executor.
pub struct SocketTrainer {
    cfg: TrainConfig,
    rank: usize,
    seed_addr: String,
    bind_addr: String,
    /// Validation batches per eval point.
    val_batches: usize,
    /// Straggler tolerance for gossip collects (see the threaded
    /// executor's identical knob).
    gossip_timeout: Option<Duration>,
    /// Kill-restart drills: stop after the `[ckpt]` cadence covers this
    /// boundary.
    halt_after: Option<u64>,
}

impl SocketTrainer {
    /// New trainer for `rank`, joining the world at `seed_addr`. Call
    /// [`SocketTrainer::run`] to execute.
    pub fn new(cfg: TrainConfig, rank: usize, seed_addr: &str) -> SocketTrainer {
        SocketTrainer {
            cfg,
            rank,
            seed_addr: seed_addr.to_string(),
            bind_addr: "127.0.0.1:0".to_string(),
            val_batches: 4,
            gossip_timeout: None,
            halt_after: None,
        }
    }

    /// Listener bind address for this rank (default `127.0.0.1:0`, an
    /// ephemeral loopback port; set a routable address on a real WAN).
    pub fn with_bind(mut self, addr: &str) -> SocketTrainer {
        self.bind_addr = addr.to_string();
        self
    }

    /// Number of validation batches per eval point (0 disables eval).
    pub fn with_val_batches(mut self, n: usize) -> SocketTrainer {
        self.val_batches = n;
        self
    }

    /// Straggler-tolerant gossip: skip a peer that does not deliver
    /// within `t` (the outer step degrades to a smaller group).
    pub fn with_gossip_timeout(mut self, t: Duration) -> SocketTrainer {
        self.gossip_timeout = Some(t);
        self
    }

    /// Kill-restart drills: stop right after the `[ckpt]` cadence
    /// snapshots `boundary`.
    pub fn with_halt_after(mut self, boundary: u64) -> SocketTrainer {
        self.halt_after = Some(boundary);
        self
    }

    /// Join the world, train this rank, and return its [`RankReport`].
    pub fn run(&self) -> Result<RankReport> {
        let cfg = &self.cfg;
        cfg.validate().map_err(anyhow::Error::msg)?;
        let (dp, pp) = (cfg.topology.dp, cfg.topology.pp);
        let world = dp * pp;
        ensure!(
            self.rank < world,
            "rank {} outside the {world}-rank world (dp·pp = {dp}·{pp})",
            self.rank
        );
        let churn_response = strategy::for_config(cfg).churn_response();
        if !cfg.churn.is_empty() && matches!(churn_response, ChurnResponse::Abort) {
            bail!(
                "{} cannot change membership mid-run: its global all-reduce has no \
                 live-subset form; only NoLoCo's gossip re-pairs over survivors",
                cfg.outer.method
            );
        }
        {
            let mut m = crate::net::Membership::full(dp);
            for &(step, e) in cfg.churn.events() {
                m.apply(e);
                ensure!(
                    m.live_count() > 0,
                    "churn schedule leaves no live replicas after step {step}"
                );
            }
        }
        // Same defaulting rule as the threaded executor: detection needs
        // a straggler timeout to degrade collects from a dead peer.
        let gossip_timeout = match (self.gossip_timeout, cfg.detect.enabled) {
            (Some(t), _) => Some(t),
            (None, true) => Some(Duration::from_secs(2)),
            (None, false) => None,
        };
        let dir = find_build(&cfg.artifacts_dir, &cfg.model.name, pp)?;
        let man = Manifest::load(&dir)?;
        man.check_against(&cfg.model, pp)?;
        let per_replica_seqs = (cfg.model.batch_tokens / cfg.model.seq_len / dp).max(man.mb);
        let num_mb = (per_replica_seqs / man.mb).max(1);

        // analyze: wall-clock-ok — report-envelope timing only; never
        // feeds the trajectory, losses, or CommStats.
        let start = Instant::now();
        let ep = SocketEndpoint::bootstrap(self.rank, world, &self.seed_addr, &self.bind_addr)?;
        let hub = ObsHub::from_config(&cfg.obs)?;
        // Per-rank checkpoint files: a process snapshots only its own
        // rank, so the assembler world is 1·1 and the file is suffixed
        // `.rank<R>` (the submit call stamps the *real* grid metadata,
        // which is what a resume validates against).
        let sink: Option<Arc<CkptAssembler>> = match (&cfg.ckpt.out, cfg.ckpt.every) {
            (Some(path), every) if every > 0 => Some(Arc::new(CkptAssembler::new(
                &format!("{path}.rank{}", self.rank),
                1,
                1,
            ))),
            _ => None,
        };
        // A restarted rank resumes from its own single-rank file; peer
        // state it folded before the cut is replayed by the survivors'
        // own resumes (or re-requested through the staleness window).
        let resume: Option<Checkpoint> = match &cfg.ckpt.resume {
            Some(path) => Some(
                Checkpoint::load(path).with_context(|| format!("resuming from {path}"))?,
            ),
            None => None,
        };

        let (stage, replica) = (self.rank / dp, self.rank % dp);
        let comm = SocketComm::new(ep, dp, gossip_timeout);
        let mut eng = Engine::new(&dir)?;
        let mut core = TrainerCore::new_single(
            cfg.clone(),
            &mut eng,
            comm,
            man,
            stage,
            replica,
            num_mb,
            self.val_batches,
        )?;
        core.set_obs(hub.clone());
        if let Some(sink) = sink {
            core.set_ckpt_sink(sink);
        }
        if let Some(b) = self.halt_after {
            core.set_halt_after(b);
        }
        if let Some(ck) = &resume {
            core.resume_from(ck)?;
        }
        let report = core.run()?;

        // Transport accounting the report cannot see: the endpoint's
        // logical wire totals (what `CommStats` compare against), CRC
        // drops, and the per-peer framed-traffic counters.
        let comm = core.communicator();
        let (wire_bytes, wire_msgs) = comm.wire_totals();
        let ep = comm.channel();
        let corrupt = ep.corrupt_dropped();
        if corrupt > 0 {
            hub.count("net.corrupt_dropped", corrupt);
        }
        for (peer, pn) in ep.peer_net() {
            hub.record(
                cfg.steps as u64,
                Event::NetPeer { peer, bytes: pn.bytes, msgs: pn.msgs, rtt_us: pn.rtt_us },
            );
        }

        Ok(RankReport::from_run(
            self.rank,
            world,
            &report,
            (wire_bytes, wire_msgs),
            start.elapsed().as_secs_f64(),
        ))
    }
}

// ---------------------------------------------------------------------
// Rank reports: the cross-process merge protocol
// ---------------------------------------------------------------------

/// One rank's training result, serializable as deterministic key=value
/// text (f64 fields as hex bit patterns) so the launching side can merge
/// N process outputs — and a drill can compare them bit-for-bit against
/// a threaded run.
#[derive(Clone, Debug, PartialEq)]
pub struct RankReport {
    /// This rank.
    pub rank: usize,
    /// World size (dp·pp) the rank was launched for.
    pub world: usize,
    /// Final validation loss (mean NLL, nats; NaN when eval was off).
    pub final_val_nll: f64,
    /// Per-inner-step training loss (NaN for steps this rank's replica
    /// sat out).
    pub step_train_loss: Vec<f64>,
    /// Logical counters plus this rank's wire totals in
    /// `bytes_sent`/`msgs_sent` — absorbing all ranks' reports
    /// reproduces the threaded run's aggregate exactly.
    pub comm: CommStats,
    /// PJRT executions issued by this rank's engine.
    pub executions: u64,
    /// Wall-clock seconds (informational; never compared).
    pub wall_secs: f64,
}

impl RankReport {
    /// Build from a core's per-rank [`TrainReport`] plus the endpoint's
    /// wire totals.
    fn from_run(
        rank: usize,
        world: usize,
        report: &TrainReport,
        wire: (u64, u64),
        wall_secs: f64,
    ) -> RankReport {
        let mut comm = report.comm.clone();
        comm.bytes_sent = wire.0;
        comm.msgs_sent = wire.1;
        RankReport {
            rank,
            world,
            final_val_nll: report.final_val_nll,
            step_train_loss: report.step_train_loss.clone(),
            comm,
            executions: report.executions,
            wall_secs,
        }
    }

    /// Serialize as deterministic key=value text.
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "noloco-rank-report v1");
        let _ = writeln!(s, "rank={}", self.rank);
        let _ = writeln!(s, "world={}", self.world);
        let _ = writeln!(s, "final_val_nll=0x{:016x}", self.final_val_nll.to_bits());
        let _ = writeln!(s, "executions={}", self.executions);
        let _ = writeln!(s, "wall_secs=0x{:016x}", self.wall_secs.to_bits());
        let loss: Vec<String> = self
            .step_train_loss
            .iter()
            .map(|l| format!("0x{:016x}", l.to_bits()))
            .collect();
        let _ = writeln!(s, "loss={}", loss.join(","));
        let c = &self.comm;
        let _ = writeln!(s, "floats_sent={}", c.floats_sent);
        let _ = writeln!(s, "activation_hops={}", c.activation_hops);
        let _ = writeln!(s, "blocking_collectives={}", c.blocking_collectives);
        let _ = writeln!(s, "pair_exchanges={}", c.pair_exchanges);
        let _ = writeln!(s, "bytes_sent={}", c.bytes_sent);
        let _ = writeln!(s, "msgs_sent={}", c.msgs_sent);
        s
    }

    /// Parse the [`RankReport::to_text`] form back.
    pub fn parse(text: &str) -> Result<RankReport> {
        let mut lines = text.lines();
        ensure!(
            lines.next() == Some("noloco-rank-report v1"),
            "not a v1 rank report"
        );
        let mut kv: BTreeMap<&str, &str> = BTreeMap::new();
        for line in lines {
            if line.trim().is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("malformed rank-report line: {line}"))?;
            kv.insert(k, v);
        }
        let get = |k: &str| kv.get(k).copied().with_context(|| format!("missing key {k}"));
        let uint = |k: &str| -> Result<u64> {
            get(k)?.parse().with_context(|| format!("bad integer for {k}"))
        };
        let bits = |k: &str| -> Result<f64> {
            let v = get(k)?;
            let hex = v.strip_prefix("0x").with_context(|| format!("bad bits for {k}"))?;
            Ok(f64::from_bits(
                u64::from_str_radix(hex, 16).with_context(|| format!("bad bits for {k}"))?,
            ))
        };
        let loss_field = get("loss")?;
        let step_train_loss: Vec<f64> = if loss_field.is_empty() {
            Vec::new()
        } else {
            loss_field
                .split(',')
                .map(|v| -> Result<f64> {
                    let hex = v.strip_prefix("0x").context("bad loss bits")?;
                    Ok(f64::from_bits(u64::from_str_radix(hex, 16).context("bad loss bits")?))
                })
                .collect::<Result<_>>()?
        };
        Ok(RankReport {
            rank: uint("rank")? as usize,
            world: uint("world")? as usize,
            final_val_nll: bits("final_val_nll")?,
            step_train_loss,
            comm: CommStats {
                floats_sent: uint("floats_sent")?,
                activation_hops: uint("activation_hops")?,
                blocking_collectives: uint("blocking_collectives")?,
                pair_exchanges: uint("pair_exchanges")?,
                bytes_sent: uint("bytes_sent")?,
                msgs_sent: uint("msgs_sent")?,
            },
            executions: uint("executions")?,
            wall_secs: bits("wall_secs")?,
        })
    }

    /// Write the text form to `path`.
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_text()).with_context(|| format!("writing {path}"))
    }

    /// Load a report written by [`RankReport::save`].
    pub fn load(path: &str) -> Result<RankReport> {
        RankReport::parse(
            &std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?,
        )
    }
}

/// A full socket run merged from every rank's report — the fields a
/// drill compares against a threaded [`TrainReport`].
#[derive(Clone, Debug, PartialEq)]
pub struct MergedRun {
    /// Mean final validation NLL over ranks that evaluated.
    pub final_val_nll: f64,
    /// Per-step training loss, averaged over reporting replicas — the
    /// same fold, in the same rank order, as the threaded aggregation.
    pub step_train_loss: Vec<f64>,
    /// Summed counters; `bytes_sent`/`msgs_sent` are wire totals.
    pub comm: CommStats,
    /// Summed PJRT executions.
    pub executions: u64,
}

/// Merge every rank's report into one run view. Requires a complete,
/// consistent set: one report per rank of one world, equal step counts.
/// The fold replays the threaded aggregation exactly — rank order,
/// finite-only means, `CommStats::absorb` — so the result is
/// bit-comparable to a same-seed threaded run.
pub fn merge_rank_reports(reports: &[RankReport]) -> Result<MergedRun> {
    ensure!(!reports.is_empty(), "no rank reports to merge");
    let world = reports[0].world;
    ensure!(
        reports.len() == world,
        "expected {world} rank reports, got {}",
        reports.len()
    );
    let mut sorted: Vec<&RankReport> = reports.iter().collect();
    sorted.sort_by_key(|r| r.rank);
    let steps = sorted[0].step_train_loss.len();
    for (i, r) in sorted.iter().enumerate() {
        ensure!(r.rank == i, "rank {i} report missing (found rank {})", r.rank);
        ensure!(r.world == world, "rank {} reports world {}, expected {world}", r.rank, r.world);
        ensure!(
            r.step_train_loss.len() == steps,
            "rank {} ran {} steps, rank 0 ran {steps}",
            r.rank,
            r.step_train_loss.len()
        );
    }
    let mut comm = CommStats::default();
    let mut executions = 0u64;
    let mut step_train_loss = vec![0.0f64; steps];
    let mut counts = vec![0usize; steps];
    for r in &sorted {
        comm.absorb(&r.comm);
        executions += r.executions;
        for (i, l) in r.step_train_loss.iter().enumerate() {
            if l.is_finite() {
                step_train_loss[i] += l;
                counts[i] += 1;
            }
        }
    }
    for (acc, c) in step_train_loss.iter_mut().zip(&counts) {
        if *c == 0 {
            *acc = f64::NAN;
        } else {
            *acc /= *c as f64;
        }
    }
    let mut val_sum = 0.0;
    let mut val_n = 0usize;
    for r in &sorted {
        if r.final_val_nll.is_finite() {
            val_sum += r.final_val_nll;
            val_n += 1;
        }
    }
    let final_val_nll = if val_n == 0 { f64::NAN } else { val_sum / val_n as f64 };
    Ok(MergedRun { final_val_nll, step_train_loss, comm, executions })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(rank: usize) -> RankReport {
        RankReport {
            rank,
            world: 2,
            final_val_nll: 2.5 + rank as f64,
            step_train_loss: vec![1.0 + rank as f64, f64::NAN, 3.0],
            comm: CommStats {
                floats_sent: 10 + rank as u64,
                activation_hops: 1,
                blocking_collectives: 0,
                pair_exchanges: 2,
                bytes_sent: 100 * (rank as u64 + 1),
                msgs_sent: 5,
            },
            executions: 40,
            wall_secs: 1.25,
        }
    }

    #[test]
    fn rank_report_roundtrips_through_text_bit_exactly() {
        for rank in 0..2 {
            let r = sample(rank);
            let back = RankReport::parse(&r.to_text()).unwrap();
            // NaN != NaN, so compare bitwise where it matters.
            assert_eq!(back.rank, r.rank);
            assert_eq!(back.world, r.world);
            assert_eq!(back.final_val_nll.to_bits(), r.final_val_nll.to_bits());
            assert_eq!(back.comm, r.comm);
            assert_eq!(back.executions, r.executions);
            assert_eq!(back.wall_secs.to_bits(), r.wall_secs.to_bits());
            let bits: Vec<u64> = back.step_train_loss.iter().map(|l| l.to_bits()).collect();
            let want: Vec<u64> = r.step_train_loss.iter().map(|l| l.to_bits()).collect();
            assert_eq!(bits, want);
        }
    }

    #[test]
    fn empty_loss_vector_roundtrips() {
        let mut r = sample(0);
        r.step_train_loss.clear();
        let back = RankReport::parse(&r.to_text()).unwrap();
        assert!(back.step_train_loss.is_empty());
    }

    #[test]
    fn merge_replays_the_threaded_fold() {
        let merged = merge_rank_reports(&[sample(1), sample(0)]).unwrap();
        // Step 0: both finite, mean of 1.0 and 2.0. Step 1: both NaN →
        // NaN. Step 2: both 3.0.
        assert_eq!(merged.step_train_loss[0], 1.5);
        assert!(merged.step_train_loss[1].is_nan());
        assert_eq!(merged.step_train_loss[2], 3.0);
        assert_eq!(merged.final_val_nll, 3.0);
        assert_eq!(merged.comm.floats_sent, 21);
        assert_eq!(merged.comm.bytes_sent, 300);
        assert_eq!(merged.executions, 80);
    }

    #[test]
    fn merge_rejects_incomplete_or_inconsistent_sets() {
        assert!(merge_rank_reports(&[]).is_err());
        assert!(merge_rank_reports(&[sample(0)]).is_err(), "world 2 needs 2 reports");
        assert!(merge_rank_reports(&[sample(0), sample(0)]).is_err(), "duplicate rank");
        let mut short = sample(1);
        short.step_train_loss.pop();
        assert!(merge_rank_reports(&[sample(0), short]).is_err(), "unequal step counts");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(RankReport::parse("not a report").is_err());
        assert!(RankReport::parse("noloco-rank-report v1\nrank=0").is_err(), "missing keys");
    }
}
