//! Streaming fragmented outer synchronization ([`StreamingSync`]).
//!
//! The gated strategies exchange the full (Δ, φ) state in one shot at
//! every outer boundary, so the whole ensemble waits on the slowest
//! transfer before the next inner phase can begin. Streaming DiLoCo
//! (Douillard et al. 2025) shows that *fragmenting* the outer state and
//! letting each fragment's exchange ride behind the next inner phase
//! hides nearly all of that synchronization time. This module is that
//! idea over the [`TrainerCore`](super::TrainerCore) API:
//!
//! * [`FragmentSchedule`] splits the flat parameter vector into `K`
//!   balanced contiguous fragments and assigns fragment
//!   `(t − 1) mod K` to outer boundary `t` — each fragment synchronizes
//!   every `K`-th boundary, cutting the per-boundary payload to `1/K`.
//! * At boundary `t` the due fragment's `(Δ_k, φ_k)` is **offered** —
//!   eagerly sent on the fabric, buffered by the accounting
//!   communicator. With `overlap` on, the **fold** happens at boundary
//!   `t + 1` — the peers' state is one inner phase stale, exactly the
//!   staleness Streaming DiLoCo shows is benign — and the transfer is
//!   hidden behind the phase. With `overlap` off the fold happens at the
//!   same boundary (gated, but payload-split).
//! * The boundary order is **offer first, then fold** (the core calls
//!   [`SyncStrategy::fold_inflight`] after the offer phase): the offer
//!   snapshots `Δ = θ − φ` *before* the fold's θ-reset can touch the
//!   same range, so every inner phase's progress is offered exactly
//!   once — including the `K = 1` case, where fold and offer address
//!   the identical (full) range at every boundary.
//! * A fold applies the same outer math as the gated flavor — NoLoCo's
//!   Eq. 2–3 modified Nesterov over the gossip group, or DiLoCo's
//!   Nesterov over the fragment's mean Δ — restricted to the fragment's
//!   range and computed host-side (the fused XLA outer artifacts are
//!   compiled for the full parameter length, so fragments cannot reuse
//!   them). Per-fragment momentum state is just the fragment's slice of
//!   δ, which keeps each fragment's momentum decoupled (DeMo-adjacent).
//!   After the φ update, θ over the range becomes
//!   `φ' + (θ_now − θ_offer)`: the offered component is consumed by the
//!   outer update while the drift accumulated during the in-flight
//!   phase carries over, so no inner step is silently discarded. Gated
//!   folds have zero drift and reduce to the plain θ := φ reset.
//! * Both flavors send eagerly at offer time, so the overlap is real
//!   wall-clock overlap on the threaded executor. The DiLoCo flavor
//!   exchanges its fragment all-to-all across the live row and averages
//!   locally — the same result as the gated tree all-reduce, trading
//!   `(n−1)×` fragment bandwidth for zero blocking collectives
//!   (`CommStats::blocking_collectives` stays 0 in streamed runs; a
//!   tree-structured streamed reduce is a ROADMAP follow-up).
//!
//! ## The degenerate configuration routes through the gated strategy
//!
//! `fragments = 1` with overlap off is definitionally the gated method;
//! [`StreamingSync`] then *delegates* every call to the matching
//! [`NolocoSync`](super::NolocoSync) / [`DilocoSync`](super::DilocoSync)
//! — built by the same `gated_for` factory `for_config` uses, so the two
//! constructions cannot drift — and the trajectory, including the
//! artifact-executed outer update, is bit-for-bit identical to
//! `--sync gated` (pinned by `tests/streaming_sync.rs`).
//!
//! ## Churn: stale fragments are dropped, not folded
//!
//! An in-flight fragment records the live set and boundary it was
//! offered under. The fold is **dropped** — φ, δ and θ keep their
//! current values and the fragment simply rejoins the schedule `K`
//! boundaries later — if the live set changed, if any schedule event
//! fired inside the in-flight window (a leave+rejoin can restore the
//! offer-time live set while the rejoiner's state was rebuilt), or if
//! the entry is older than the boundary being folded (a worker that sat
//! out mid-run). Folds that do proceed mirror the gated strategy's
//! message-passing repair at fragment granularity: a rejoiner whose
//! offer-time state was stale adopts the first fresh peer's offered
//! φ_k — fragment by fragment as each comes due, driven by a staleness
//! window of `K` phases (a fragment's state predates the ensemble's
//! until its first post-rejoin exchange) — and fresh members exclude
//! stale peers' contributions from their consensus sums.

use std::collections::BTreeMap;
use std::ops::Range;

use anyhow::{bail, ensure, Result};

use crate::config::{Method, OuterConfig, StreamConfig, TrainConfig};
use crate::net::topo::ChurnEvent;
use crate::net::ChurnSchedule;
use crate::runtime::Engine;

use super::boundary::{fold_noloco_fused, ThetaUpdate};
use super::checkpoint::{InflightRecord, StrategyState};
use super::comm::Communicator;
use super::state::WorkerState;
use super::strategy::{
    gated_for, pairing_for, ChurnResponse, CommPattern, PairingCache, PairingPolicy,
    SyncStrategy, UniformPairing,
};

/// Balanced contiguous partition of a flat parameter vector into `K`
/// fragments, plus the round-robin boundary schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FragmentSchedule {
    n: usize,
    k: usize,
}

impl FragmentSchedule {
    /// Schedule over `n` parameters in `fragments` chunks (clamped to
    /// `1..=n` so empty fragments never occur).
    pub fn new(n: usize, fragments: usize) -> FragmentSchedule {
        FragmentSchedule { n, k: fragments.clamp(1, n.max(1)) }
    }

    /// Effective fragment count after clamping.
    pub fn fragments(&self) -> usize {
        self.k
    }

    /// Fragment `frag`'s element range: contiguous chunks, the first
    /// `n mod K` fragments one element larger.
    pub fn range(&self, frag: usize) -> Range<usize> {
        assert!(frag < self.k, "fragment {frag} outside schedule of {}", self.k);
        let base = self.n / self.k;
        let rem = self.n % self.k;
        let lo = frag * base + frag.min(rem);
        lo..lo + base + usize::from(frag < rem)
    }

    /// Which fragment is due at 1-based outer boundary `outer_idx`.
    pub fn due_at(&self, outer_idx: u64) -> usize {
        (outer_idx.saturating_sub(1) % self.k as u64) as usize
    }
}

/// One offered-but-unfolded fragment exchange.
struct Inflight {
    /// Outer boundary the offer was made at.
    outer_idx: u64,
    /// Fragment index within the schedule.
    frag: usize,
    /// Gossip group (NoLoCo flavor) or full live row (DiLoCo flavor) the
    /// offer went to, ascending.
    group: Vec<usize>,
    /// Live set snapshot at offer time — folds compare against the
    /// current live set and drop the fragment on any change.
    live: Vec<usize>,
    /// This worker's fragment Δ at offer time.
    delta: Vec<f32>,
    /// This worker's fragment φ at offer time.
    phi: Vec<f32>,
    /// This worker's fragment θ at offer time (the drift baseline the
    /// fold carries across its reset).
    theta: Vec<f32>,
}

/// Streaming fragmented outer sync over a gated flavor (NoLoCo gossip or
/// DiLoCo all-reduce). See the module docs for the offer/fold timeline.
pub struct StreamingSync {
    outer: OuterConfig,
    stream: StreamConfig,
    flavor: Method,
    seed: u64,
    dp: usize,
    /// Shared membership schedule: a deferred fold consults it to drop
    /// fragments whose phase saw *any* churn event — even a leave+rejoin
    /// that restored the offer-time live set — and to derive the
    /// rejoin-staleness rule mirrored from the gated NoLoCo strategy.
    churn: ChurnSchedule,
    pairing: Box<dyn PairingPolicy>,
    /// Gated delegate for the degenerate `fragments = 1`, overlap-off
    /// configuration (bit-identical trajectories by construction).
    delegate: Option<Box<dyn SyncStrategy>>,
    /// In-flight offers by owned worker `(stage, replica)`. At most two
    /// per worker: the previous boundary's (unfolded under overlap) and
    /// the one just offered — offers run before folds at a boundary.
    inflight: BTreeMap<(usize, usize), Vec<Inflight>>,
    /// Memoized pairing draws (see
    /// [`PairingCache`](super::strategy::PairingCache)): the grid
    /// executor calls the offer phase for every worker of a stage row
    /// with identical inputs, so one set of draws serves the row.
    cache: PairingCache,
    /// Fragments dropped instead of folded because membership changed
    /// while they were in flight.
    dropped_stale: u64,
}

impl StreamingSync {
    /// Build from the full config; the flavor is `cfg.outer.method`
    /// (FSDP is rejected by [`TrainConfig::validate`] before trainers
    /// construct strategies).
    pub fn from_config(cfg: &TrainConfig) -> StreamingSync {
        let flavor = cfg.outer.method;
        assert!(
            flavor != Method::Fsdp,
            "streaming sync needs an outer method (enforced by config validation)"
        );
        let degenerate = cfg.stream.fragments <= 1 && !cfg.stream.overlap;
        let delegate = degenerate.then(|| gated_for(cfg));
        // The pairing policy is consulted only on the non-delegated
        // NoLoCo path; a delegate or the DiLoCo flavor draws no pairs, so
        // skip building a (possibly topology-backed) policy for them.
        let pairing: Box<dyn PairingPolicy> = if delegate.is_none() && flavor == Method::NoLoCo {
            pairing_for(cfg)
        } else {
            Box::new(UniformPairing)
        };
        StreamingSync {
            outer: cfg.outer.clone(),
            stream: cfg.stream,
            flavor,
            seed: cfg.seed,
            dp: cfg.topology.dp,
            churn: cfg.churn.clone(),
            pairing,
            delegate,
            inflight: BTreeMap::new(),
            cache: PairingCache::new(),
            dropped_stale: 0,
        }
    }

    /// Fragments dropped (not folded) because membership changed while
    /// they were in flight.
    pub fn dropped_stale(&self) -> u64 {
        self.dropped_stale
    }

    /// This worker's exchange group at a boundary: the pairing policy's
    /// gossip group for the NoLoCo flavor (drawn once per
    /// `(stage, outer_idx, live)` through the cache), the whole live row
    /// for the DiLoCo flavor. The draw is keyed by the boundary's due
    /// `frag` (from the caller's parameter-length-clamped schedule), so
    /// `--pairing per-fragment` gives each fragment its own partner
    /// sequence; one fragment is due per boundary, which keeps the cache
    /// key valid — the fragment is a function of `outer_idx`.
    fn my_group(
        &mut self,
        live: &[usize],
        stage: usize,
        frag: u16,
        outer_idx: u64,
        me: usize,
    ) -> Vec<usize> {
        if self.flavor == Method::DiLoCo {
            return live.to_vec();
        }
        self.cache.my_group(
            self.pairing.as_ref(),
            live,
            self.outer.group,
            stage,
            frag,
            self.stream.fragments.max(1),
            outer_idx,
            self.seed,
            me,
        )
    }

    /// Whether replica `r`'s *fragment due at boundary `b`* is stale:
    /// `r` was dead at any step since that fragment's previous exchange,
    /// `k_rounds` boundaries back (a fragment syncs every K-th boundary,
    /// so its staleness window is K phases — the K = 1 case reduces to
    /// the gated `NolocoSync::is_stale` one-round window). Derived from
    /// the shared schedule, so every worker agrees without coordination;
    /// the window keeps flagging the rejoiner until each fragment has
    /// come due once post-rejoin and adopted fresh state.
    fn is_stale_at(&self, r: usize, b: u64, k_rounds: usize) -> bool {
        if self.churn.is_empty() {
            return false;
        }
        let m = self.outer.inner_steps as u64;
        let hi = (b * m).saturating_sub(1);
        let lo = hi.saturating_sub(k_rounds.max(1) as u64 * m);
        // Walk r's own (sorted) events, intersecting its dead intervals
        // [leave, join) with [lo, hi] — allocation-free, unlike a
        // per-step `live_at` scan.
        let mut live = true;
        let mut dead_since = 0u64;
        for &(step, e) in self.churn.events() {
            if e.node() != r {
                continue;
            }
            match e {
                ChurnEvent::Leave(_) => {
                    if live {
                        live = false;
                        dead_since = step;
                    }
                }
                ChurnEvent::Join(_) => {
                    if !live {
                        live = true;
                        if dead_since <= hi && step > lo {
                            return true;
                        }
                    }
                }
            }
        }
        !live && dead_since <= hi
    }

    /// Whether the churn schedule fires inside the inner phase that
    /// follows the offer at boundary `offered_at` — the window a deferred
    /// fragment is in flight for. Covers the case the live-set comparison
    /// cannot: a leave + rejoin within one phase restores the offer-time
    /// live set while the rejoiner's state was rebuilt underneath.
    fn churn_in_flight_window(&self, offered_at: u64) -> bool {
        if self.churn.is_empty() {
            return false;
        }
        let m = self.outer.inner_steps as u64;
        let lo = offered_at * m;
        self.churn
            .events()
            .iter()
            .any(|&(step, _)| step >= lo && step < lo + m)
    }

    /// Fold one fragment exchange into `(φ, δ, θ)` over its element
    /// range. Host-side math — deterministic and identical across
    /// communicators (collect order is the stored group order).
    fn fold_entry(
        &mut self,
        comm: &mut dyn Communicator,
        w: &mut WorkerState,
        entry: Inflight,
    ) -> Result<()> {
        let sched = FragmentSchedule::new(w.len(), self.stream.fragments);
        let r = sched.range(entry.frag);
        ensure!(
            r.len() == entry.delta.len(),
            "in-flight fragment {} has {} elements, schedule expects {}",
            entry.frag,
            entry.delta.len(),
            r.len()
        );
        let seq = entry.outer_idx as u32;
        let k = sched.fragments();
        let me = w.replica;
        let (alpha, beta, gamma) = (
            self.outer.alpha as f32,
            self.outer.beta as f32,
            self.outer.gamma as f32,
        );
        // Message-passing rejoin catch-up, at fragment granularity (the
        // grid executor instead hands a joiner a donor's φ at the join
        // event): a stale member adopts the first fresh peer's offered
        // φ_k outright — fragment by fragment as each comes due — and
        // the fresh side skips stale contributions so they cannot dilute
        // its consensus sums. Two stale members paired together fall
        // through to the plain averaged update, like the gated strategy.
        let repair = self.flavor == Method::NoLoCo
            && !comm.supports_join_bootstrap()
            && !self.churn.is_empty();
        if repair && self.is_stale_at(me, entry.outer_idx, k) {
            for &q in &entry.group {
                if q == me || self.is_stale_at(q, entry.outer_idx, k) {
                    continue;
                }
                if let Some(view) =
                    comm.collect_fragment_view(w.stage, me, q, seq, entry.frag as u16)?
                {
                    w.phi[r.clone()].copy_from_slice(view.phi());
                    for d in w.delta[r.clone()].iter_mut() {
                        *d = 0.0;
                    }
                    w.theta[r.clone()].copy_from_slice(&w.phi[r.clone()]);
                    return Ok(());
                }
            }
        }
        // Group sums start from this worker's *offer-time* state (not
        // the current θ/φ — the inner phase has moved on). The retained
        // entry buffers become the accumulators outright (the entry is
        // consumed by this fold); peer contributions accumulate straight
        // off the communicator's borrowed views — the fold path copies
        // nothing.
        let mut dsum = entry.delta;
        let mut psum = entry.phi;
        let mut gn = 1usize;
        for &q in &entry.group {
            if q == me {
                continue;
            }
            if repair && self.is_stale_at(q, entry.outer_idx, k) {
                continue; // stale peer: excluded from the fold
            }
            let Some(view) =
                comm.collect_fragment_view(w.stage, me, q, seq, entry.frag as u16)?
            else {
                continue; // straggler timeout: smaller group
            };
            let (d, p) = (view.delta(), view.phi());
            ensure!(
                d.len() == dsum.len(),
                "peer {q} offered fragment {} with mismatched length",
                entry.frag
            );
            for (a, x) in dsum.iter_mut().zip(d) {
                *a += x;
            }
            for (a, x) in psum.iter_mut().zip(p) {
                *a += x;
            }
            gn += 1;
        }
        // The fragment's inner phase restarts from the updated slow
        // weights, carrying the drift accumulated while the exchange was
        // in flight: θ ← φ' + (θ_now − θ_offer). The offered component
        // was consumed by the outer update; the drift since the offer
        // stays, so no inner step is silently discarded. Gated folds
        // have zero drift (fold follows the offer within one boundary)
        // and reduce to the plain θ := φ reset. For NoLoCo the carry is
        // fused into the same elementwise pass as the (φ, δ) update.
        match self.flavor {
            Method::NoLoCo => fold_noloco_fused(
                &mut w.phi[r.clone()],
                &mut w.delta[r.clone()],
                &dsum,
                &psum,
                gn as f32,
                alpha,
                beta,
                gamma,
                ThetaUpdate::Carry { theta: &mut w.theta[r], snap: &entry.theta },
            ),
            Method::DiLoCo => {
                // Local mean over the all-to-all exchange — the same
                // result as the gated tree all-reduce, without a
                // blocking collective.
                let inv_n = 1.0 / gn as f32;
                for x in dsum.iter_mut() {
                    *x *= inv_n;
                }
                fold_diloco_fragment(
                    &mut w.phi[r.clone()],
                    &mut w.delta[r.clone()],
                    &dsum,
                    alpha,
                    beta,
                );
                for (j, i) in r.enumerate() {
                    w.theta[i] = w.phi[i] + (w.theta[i] - entry.theta[j]);
                }
            }
            Method::Fsdp => unreachable!("streaming sync rejects FSDP at validation"),
        }
        Ok(())
    }

    /// Remove and return the entry offered at `offered_at` for `w`, if it
    /// is safe to fold; entries from older boundaries (a worker that sat
    /// out mid-run — whose peer offers may already be garbage-collected)
    /// are dropped as stale, and newer entries (the offer that just
    /// preceded this fold at the same boundary) are left in flight. The
    /// matching entry itself is dropped instead of returned when the live
    /// set changed or (for `deferred` folds, where a whole inner phase
    /// elapsed in between) a churn event fired while it was in flight.
    fn take_foldable(
        &mut self,
        w: &WorkerState,
        live: &[usize],
        offered_at: u64,
        deferred: bool,
    ) -> Option<Inflight> {
        let stale_window = deferred && self.churn_in_flight_window(offered_at);
        let entries = self.inflight.get_mut(&(w.stage, w.replica))?;
        // Leftovers from boundaries before `offered_at` are stale.
        let before = entries.len();
        entries.retain(|e| e.outer_idx >= offered_at);
        let mut dropped = (before - entries.len()) as u64;
        let mut found = None;
        if let Some(i) = entries.iter().position(|e| e.outer_idx == offered_at) {
            let e = entries.remove(i);
            if e.live == live && !stale_window {
                found = Some(e);
            } else {
                dropped += 1;
            }
        }
        self.dropped_stale += dropped;
        found
    }
}

impl SyncStrategy for StreamingSync {
    fn name(&self) -> &'static str {
        "streaming"
    }

    fn pattern(&self) -> CommPattern {
        match self.flavor {
            Method::NoLoCo => CommPattern::GossipPairs,
            _ => CommPattern::AllReduce,
        }
    }

    fn has_outer(&self) -> bool {
        true
    }

    fn churn_response(&self) -> ChurnResponse {
        match self.flavor {
            Method::NoLoCo => ChurnResponse::Repair,
            _ => ChurnResponse::Abort,
        }
    }

    fn offer_outer(
        &mut self,
        comm: &mut dyn Communicator,
        w: &WorkerState,
        live: &[usize],
        outer_idx: u64,
    ) -> Result<()> {
        if let Some(d) = self.delegate.as_mut() {
            return d.offer_outer(comm, w, live, outer_idx);
        }
        let sched = FragmentSchedule::new(w.len(), self.stream.fragments);
        let frag = sched.due_at(outer_idx);
        let r = sched.range(frag);
        let me = w.replica;
        let theta = w.theta[r.clone()].to_vec();
        let phi = w.phi[r.clone()].to_vec();
        let delta: Vec<f32> = theta.iter().zip(&phi).map(|(t, p)| t - p).collect();
        let group = self.my_group(live, w.stage, frag as u16, outer_idx, me);
        let peers: Vec<usize> = group.iter().copied().filter(|&q| q != me).collect();
        // Both flavors send eagerly: (Δ_k, φ_k) to the gossip group, or
        // Δ_k alone to the whole live row (the DiLoCo flavor's
        // all-to-all; φ is not part of its fold).
        let phi_payload: &[f32] = if self.flavor == Method::NoLoCo { &phi } else { &[] };
        comm.offer_fragment(
            w.stage,
            me,
            &peers,
            outer_idx as u32,
            frag as u16,
            &delta,
            phi_payload,
        )?;
        self.inflight
            .entry((w.stage, me))
            .or_default()
            .push(Inflight { outer_idx, frag, group, live: live.to_vec(), delta, phi, theta });
        Ok(())
    }

    fn apply_outer(
        &mut self,
        comm: &mut dyn Communicator,
        eng: &mut Engine,
        w: &mut WorkerState,
        live: &[usize],
        outer_idx: u64,
    ) -> Result<()> {
        if let Some(d) = self.delegate.as_mut() {
            return d.apply_outer(comm, eng, w, live, outer_idx);
        }
        if self.stream.overlap {
            // The fold happens in `fold_inflight` at the *next* boundary;
            // the fragment offered just now rides behind the coming inner
            // phase.
            return Ok(());
        }
        // Gated fragmented mode: fold this boundary's exchange now.
        if let Some(entry) = self.take_foldable(w, live, outer_idx, false) {
            self.fold_entry(comm, w, entry)?;
        }
        Ok(())
    }

    fn fold_inflight(
        &mut self,
        comm: &mut dyn Communicator,
        w: &mut WorkerState,
        live: &[usize],
        outer_idx: u64,
    ) -> Result<()> {
        if let Some(d) = self.delegate.as_mut() {
            return d.fold_inflight(comm, w, live, outer_idx);
        }
        if !self.stream.overlap {
            return Ok(());
        }
        if let Some(entry) = self.take_foldable(w, live, outer_idx.saturating_sub(1), true) {
            self.fold_entry(comm, w, entry)?;
        }
        Ok(())
    }

    fn drain(
        &mut self,
        comm: &mut dyn Communicator,
        w: &mut WorkerState,
        live: &[usize],
        final_outer_idx: u64,
    ) -> Result<()> {
        if let Some(d) = self.delegate.as_mut() {
            return d.drain(comm, w, live, final_outer_idx);
        }
        if !self.stream.overlap {
            return Ok(());
        }
        if let Some(entry) = self.take_foldable(w, live, final_outer_idx, true) {
            self.fold_entry(comm, w, entry)?;
        }
        Ok(())
    }

    fn report_obs(&self, hub: &crate::obs::ObsHub) {
        if let Some(d) = self.delegate.as_ref() {
            return d.report_obs(hub);
        }
        hub.count("streaming.dropped_stale", self.dropped_stale);
    }

    fn export_state(&self, w: &WorkerState) -> Option<StrategyState> {
        if self.delegate.is_some() {
            return None; // the gated delegate holds nothing across a boundary
        }
        let inflight = self
            .inflight
            .get(&(w.stage, w.replica))
            .map(|es| {
                es.iter()
                    .map(|e| InflightRecord {
                        outer_idx: e.outer_idx,
                        frag: e.frag as u32,
                        group: e.group.iter().map(|&x| x as u32).collect(),
                        live: e.live.iter().map(|&x| x as u32).collect(),
                        delta: e.delta.clone(),
                        phi: e.phi.clone(),
                        theta: e.theta.clone(),
                    })
                    .collect()
            })
            .unwrap_or_default();
        Some(StrategyState::Streaming { inflight, dropped_stale: self.dropped_stale })
    }

    fn restore_state(
        &mut self,
        comm: &mut dyn Communicator,
        w: &WorkerState,
        st: &StrategyState,
    ) -> Result<()> {
        let StrategyState::Streaming { inflight, dropped_stale } = st else {
            bail!("checkpoint strategy state is not the streaming kind");
        };
        // The counter is strategy-global; every owned worker's record
        // carries the same value (the grid executor restores it once per
        // worker, converging by max).
        self.dropped_stale = self.dropped_stale.max(*dropped_stale);
        let me = w.replica;
        for rec in inflight {
            let group: Vec<usize> = rec.group.iter().map(|&x| x as usize).collect();
            let peers: Vec<usize> = group.iter().copied().filter(|&q| q != me).collect();
            // Sender-replay: re-publish this worker's retained offer so
            // peers' deferred folds can still collect it (unmetered —
            // the original send was accounted before the checkpoint).
            let phi_payload: &[f32] =
                if self.flavor == Method::NoLoCo { &rec.phi } else { &[] };
            comm.replay_fragment(
                w.stage,
                me,
                &peers,
                rec.outer_idx as u32,
                rec.frag as u16,
                &rec.delta,
                phi_payload,
            )?;
            self.inflight.entry((w.stage, me)).or_default().push(Inflight {
                outer_idx: rec.outer_idx,
                frag: rec.frag as usize,
                group,
                live: rec.live.iter().map(|&x| x as usize).collect(),
                delta: rec.delta.clone(),
                phi: rec.phi.clone(),
                theta: rec.theta.clone(),
            });
        }
        Ok(())
    }
}

/// Eq. 2–3 restricted to one fragment, host-side:
/// `δ ← α δ + (β/n) Σ Δ − γ (φ − (1/n) Σ φ)`, then `φ ← φ + δ` — the
/// uniform (`W = n`) special case of the async engine's
/// [`fold_noloco_weighted`](super::boundary::fold_noloco_weighted), to
/// which it delegates so the Eq. 2–3 arithmetic exists once. The
/// streamed fold itself routes through
/// [`fold_noloco_fused`](super::boundary::fold_noloco_fused) with the
/// drift carry fused in; this wrapper is the reference form equivalence
/// tests pin against.
#[allow(clippy::too_many_arguments)]
pub fn fold_noloco_fragment(
    phi: &mut [f32],
    delta: &mut [f32],
    dsum: &[f32],
    psum: &[f32],
    gn: usize,
    alpha: f32,
    beta: f32,
    gamma: f32,
) {
    super::boundary::fold_noloco_weighted(phi, delta, dsum, psum, gn as f32, alpha, beta, gamma);
}

/// DiLoCo's Nesterov step restricted to one fragment, host-side:
/// `δ ← α δ + β Δ̄`, then `φ ← φ + δ`.
pub(crate) fn fold_diloco_fragment(
    phi: &mut [f32],
    delta: &mut [f32],
    dmean: &[f32],
    alpha: f32,
    beta: f32,
) {
    for i in 0..phi.len() {
        let d = alpha * delta[i] + beta * dmean[i];
        delta[i] = d;
        phi[i] += d;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, SyncMode};
    use crate::model::StageKind;
    use crate::optim::{NolocoOuter, OuterState};
    use crate::tensor::Tensor;
    use crate::train::AccountingComm;

    fn streaming_cfg(fragments: usize, overlap: bool) -> TrainConfig {
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.sync = SyncMode::Streaming;
        cfg.stream = StreamConfig { fragments, overlap, ..StreamConfig::default() };
        cfg
    }

    fn worker(replica: usize, theta: Vec<f32>) -> WorkerState {
        let mut w = WorkerState::new(0, replica, StageKind::Full, theta.clone(), Method::NoLoCo);
        // Give φ a distinct value so folds are observable.
        for (p, t) in w.phi.iter_mut().zip(&theta) {
            *p = t * 0.5;
        }
        w
    }

    /// One full overlapped boundary in the core's order: offers first,
    /// then the fold of the previous boundary's entries.
    fn boundary(
        s: &mut StreamingSync,
        comm: &mut AccountingComm,
        workers: &mut [WorkerState],
        live: &[usize],
        outer_idx: u64,
    ) {
        for w in workers.iter() {
            s.offer_outer(comm, w, live, outer_idx).unwrap();
        }
        for w in workers.iter_mut() {
            s.fold_inflight(comm, w, live, outer_idx).unwrap();
        }
    }

    #[test]
    fn fragment_schedule_partitions_and_cycles() {
        let s = FragmentSchedule::new(10, 3);
        assert_eq!(s.fragments(), 3);
        assert_eq!(s.range(0), 0..4);
        assert_eq!(s.range(1), 4..7);
        assert_eq!(s.range(2), 7..10);
        // Disjoint cover of 0..n.
        let covered: usize = (0..3).map(|f| s.range(f).len()).sum();
        assert_eq!(covered, 10);
        // Round-robin over 1-based boundaries.
        assert_eq!(s.due_at(1), 0);
        assert_eq!(s.due_at(2), 1);
        assert_eq!(s.due_at(3), 2);
        assert_eq!(s.due_at(4), 0);
        // Clamped: more fragments than parameters collapses to n.
        assert_eq!(FragmentSchedule::new(2, 8).fragments(), 2);
        assert_eq!(FragmentSchedule::new(5, 1).range(0), 0..5);
    }

    #[test]
    fn host_fold_matches_optim_reference_on_full_vector() {
        // A whole-vector fragment must reproduce the NolocoOuter tensor
        // update (same equations, different storage) to float tolerance.
        let phi0 = vec![0.5f32, -1.0, 2.0, 0.25];
        let theta_a = vec![1.0f32, -0.5, 2.5, 0.0];
        let theta_b = vec![0.0f32, -2.0, 1.5, 1.0];
        let phi_b = vec![0.4f32, -0.8, 1.9, 0.3];
        let (alpha, beta, gamma) = (0.5f32, 0.7f32, 0.9f32);

        // Reference: optim::NolocoOuter over tensors.
        let mut st = OuterState::new(&[Tensor::from_vec(phi0.clone(), &[4])]);
        let my_delta = st.outer_grad(&[Tensor::from_vec(theta_a.clone(), &[4])]);
        let peer_delta: Vec<f32> =
            theta_b.iter().zip(&phi_b).map(|(t, p)| t - p).collect();
        let theta_t = vec![Tensor::from_vec(theta_a.clone(), &[4])];
        NolocoOuter { alpha: alpha as f64, beta: beta as f64, gamma: gamma as f64 }.step_pair(
            &mut st,
            &theta_t,
            &my_delta,
            &[Tensor::from_vec(peer_delta.clone(), &[4])],
            &[Tensor::from_vec(phi_b.clone(), &[4])],
        );

        // Fragment fold over the same inputs.
        let mut phi = phi0.clone();
        let mut delta = vec![0.0f32; 4];
        let my_d: Vec<f32> = theta_a.iter().zip(&phi0).map(|(t, p)| t - p).collect();
        let dsum: Vec<f32> = my_d.iter().zip(&peer_delta).map(|(a, b)| a + b).collect();
        let psum: Vec<f32> = phi0.iter().zip(&phi_b).map(|(a, b)| a + b).collect();
        fold_noloco_fragment(&mut phi, &mut delta, &dsum, &psum, 2, alpha, beta, gamma);
        for (got, want) in phi.iter().zip(st.phi[0].as_slice()) {
            assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        }
    }

    #[test]
    fn degenerate_config_delegates_to_the_gated_strategy() {
        let s = StreamingSync::from_config(&streaming_cfg(1, false));
        assert!(s.delegate.is_some(), "fragments=1 + overlap off must delegate");
        let s = StreamingSync::from_config(&streaming_cfg(1, true));
        assert!(s.delegate.is_none(), "overlap on streams even a single fragment");
        let s = StreamingSync::from_config(&streaming_cfg(4, false));
        assert!(s.delegate.is_none(), "payload-split gated mode is not the delegate");
        assert_eq!(s.name(), "streaming");
        assert_eq!(s.pattern(), CommPattern::GossipPairs);
        assert_eq!(s.churn_response(), ChurnResponse::Repair);
        assert!(s.has_outer());
    }

    #[test]
    fn overlapped_fold_lags_one_boundary_and_touches_only_the_fragment() {
        let mut s = StreamingSync::from_config(&streaming_cfg(2, true));
        let mut comm = AccountingComm::new();
        let live = vec![0usize, 1];
        let mut ws = [
            worker(0, vec![1.0, 2.0, 3.0, 4.0]),
            worker(1, vec![4.0, 3.0, 2.0, 1.0]),
        ];
        let phi_a0 = ws[0].phi.clone();

        // Boundary 1: offer fragment 0 (elements 0..2); nothing folds yet
        // (no earlier boundary's entry in flight).
        boundary(&mut s, &mut comm, &mut ws, &live, 1);
        assert_eq!(ws[0].phi, phi_a0, "boundary 1 must not mutate state");

        // Boundary 2: fragment 0 folds; elements 2..4 stay untouched.
        boundary(&mut s, &mut comm, &mut ws, &live, 2);
        assert_ne!(&ws[0].phi[..2], &phi_a0[..2], "fragment 0 must fold");
        assert_eq!(&ws[0].phi[2..], &phi_a0[2..], "fragment 1 still in φ₀ state");
        // No inner steps ran, so the drift is zero and θ == φ.
        assert_eq!(&ws[0].theta[..2], &ws[0].phi[..2], "θ resets to φ on the folded fragment");
        assert_eq!(s.dropped_stale(), 0);
    }

    #[test]
    fn single_fragment_overlap_keeps_offering_real_progress() {
        // The offer-before-fold boundary order means K = 1 with overlap
        // (delayed full-state averaging) still offers each phase's
        // progress: Δ snapshots before the fold's θ-reset hits the same
        // range.
        let mut s = StreamingSync::from_config(&streaming_cfg(1, true));
        let mut comm = AccountingComm::new();
        let live = vec![0usize, 1];
        let mut ws = [
            worker(0, vec![1.0, 2.0, 3.0, 4.0]),
            worker(1, vec![4.0, 3.0, 2.0, 1.0]),
        ];
        for outer_idx in 1..=3u64 {
            boundary(&mut s, &mut comm, &mut ws, &live, outer_idx);
            // A fake inner phase between boundaries.
            for w in ws.iter_mut() {
                for x in w.theta.iter_mut() {
                    *x += 0.1;
                }
            }
        }
        // The entry offered at boundary 3 captured the phase-3 progress —
        // nonzero Δ even though boundary 3's fold reset θ just afterwards.
        let entries = &s.inflight[&(0usize, 0usize)];
        assert_eq!(entries.len(), 1);
        assert!(
            entries[0].delta.iter().any(|&d| d != 0.0),
            "Δ must keep capturing inner progress under K = 1 overlap"
        );
    }

    #[test]
    fn fold_carries_inflight_drift_into_theta() {
        let mut s = StreamingSync::from_config(&streaming_cfg(2, true));
        let mut comm = AccountingComm::new();
        let live = vec![0usize, 1];
        let mut ws = [
            worker(0, vec![1.0, 2.0, 3.0, 4.0]),
            worker(1, vec![4.0, 3.0, 2.0, 1.0]),
        ];
        boundary(&mut s, &mut comm, &mut ws, &live, 1);
        // Inner phase while fragment 0 is in flight: drift of +0.25.
        for x in ws[0].theta.iter_mut() {
            *x += 0.25;
        }
        boundary(&mut s, &mut comm, &mut ws, &live, 2);
        // θ over the folded range is φ' plus the in-flight drift.
        for i in 0..2 {
            let want = ws[0].phi[i] + 0.25;
            assert!(
                (ws[0].theta[i] - want).abs() < 1e-6,
                "drift must survive the fold: {} vs {want}",
                ws[0].theta[i]
            );
        }
    }

    #[test]
    fn streamed_diloco_fold_matches_mean_nesterov_and_agrees_across_replicas() {
        let mut cfg = presets::as_diloco(streaming_cfg(2, true));
        cfg.sync = SyncMode::Streaming;
        let mut s = StreamingSync::from_config(&cfg);
        let mut comm = AccountingComm::new();
        let live = vec![0usize, 1];
        // Same φ, different θ — the all-to-all mean must keep φ identical
        // across replicas, like the gated all-reduce.
        let init = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut a = WorkerState::new(0, 0, StageKind::Full, init.clone(), Method::DiLoCo);
        let mut b = WorkerState::new(0, 1, StageKind::Full, init.clone(), Method::DiLoCo);
        a.phi = init.clone();
        b.phi = init.clone();
        a.delta = vec![0.0; 4];
        b.delta = vec![0.0; 4];
        for (i, x) in a.theta.iter_mut().enumerate() {
            *x += 0.5 + i as f32;
        }
        for x in b.theta.iter_mut() {
            *x -= 0.5;
        }
        s.offer_outer(&mut comm, &a, &live, 1).unwrap();
        s.offer_outer(&mut comm, &b, &live, 1).unwrap();
        s.fold_inflight(&mut comm, &mut a, &live, 2).unwrap();
        s.fold_inflight(&mut comm, &mut b, &live, 2).unwrap();
        // Fragment 0 (elements 0..2): φ' = φ + β · mean(Δ) with δ₀ = 0.
        let beta = cfg.outer.beta as f32;
        for i in 0..2 {
            let mean = ((0.5 + i as f32) + (-0.5)) / 2.0;
            let want = init[i] + beta * mean;
            assert!((a.phi[i] - want).abs() < 1e-6, "{} vs {want}", a.phi[i]);
        }
        assert_eq!(&a.phi[..2], &b.phi[..2], "replicas agree like an all-reduce");
        assert_eq!(&a.phi[2..], &init[2..], "fragment 1 untouched");
    }

    #[test]
    fn stale_fragment_is_dropped_after_membership_change() {
        let mut s = StreamingSync::from_config(&streaming_cfg(2, true));
        let mut comm = AccountingComm::new();
        let mut a = worker(0, vec![1.0, 2.0, 3.0, 4.0]);
        let b = worker(1, vec![4.0, 3.0, 2.0, 1.0]);
        let phi_a0 = a.phi.clone();

        // Offered under live = {0, 1}; replica 1 leaves before the fold.
        s.offer_outer(&mut comm, &a, &[0, 1], 1).unwrap();
        s.offer_outer(&mut comm, &b, &[0, 1], 1).unwrap();
        s.fold_inflight(&mut comm, &mut a, &[0], 2).unwrap();
        assert_eq!(a.phi, phi_a0, "stale fragment must be dropped, not folded");
        assert_eq!(s.dropped_stale(), 1);

        // An entry from a sat-out boundary is dropped at a later fold.
        s.offer_outer(&mut comm, &a, &[0, 1], 2).unwrap();
        s.offer_outer(&mut comm, &b, &[0, 1], 2).unwrap();
        s.fold_inflight(&mut comm, &mut a, &[0, 1], 4).unwrap();
        assert_eq!(a.phi, phi_a0);
        assert_eq!(s.dropped_stale(), 2);
    }

    #[test]
    fn fabric_fold_adopts_fresh_peer_fragment_after_rejoin() {
        // tiny's m = 50; replica 1 dead over steps 60..69 (leave 60,
        // join 70). Boundary 2 closes step 99: replica 1 is live again
        // but *stale* there (dead inside the K·m window), and the
        // in-flight window [100, 150) is churn-free, so the fold at
        // boundary 3 proceeds with the message-passing repair semantics:
        // the rejoiner adopts the fresh peer's offered φ fragment and the
        // fresh side folds a singleton, excluding the stale contribution.
        let mut cfg = streaming_cfg(2, true);
        cfg.churn = crate::net::ChurnSchedule::none().leave(60, 1).join(70, 1);
        let mut fabric = crate::net::Fabric::new(2);
        let mut eps = fabric.take_endpoints().into_iter();
        let mut ca = crate::train::FabricComm::new(eps.next().unwrap(), 2, None);
        let mut cb = crate::train::FabricComm::new(eps.next().unwrap(), 2, None);
        let mut sa = StreamingSync::from_config(&cfg);
        let mut sb = StreamingSync::from_config(&cfg);
        let mut a = worker(0, vec![1.0, 2.0, 3.0, 4.0]);
        let mut b = worker(1, vec![4.0, 3.0, 2.0, 1.0]);
        let live = vec![0usize, 1];
        let phi_a_offer = a.phi.clone();
        // Boundary 2's due fragment is 1 (elements 2..4).
        sa.offer_outer(&mut ca, &a, &live, 2).unwrap();
        sb.offer_outer(&mut cb, &b, &live, 2).unwrap();
        sa.fold_inflight(&mut ca, &mut a, &live, 3).unwrap();
        sb.fold_inflight(&mut cb, &mut b, &live, 3).unwrap();
        // The stale rejoiner adopted the fresh peer's offer-time φ_k.
        assert_eq!(&b.phi[2..], &phi_a_offer[2..]);
        assert_eq!(&b.delta[2..], &[0.0f32, 0.0][..]);
        assert_eq!(&b.theta[2..], &phi_a_offer[2..]);
        // The fresh side folded a singleton update: moved, but not onto
        // the stale peer's values.
        assert_ne!(&a.phi[2..], &phi_a_offer[2..]);
        assert_ne!(&a.phi[2..], &b.phi[2..]);
    }

    #[test]
    fn leave_and_rejoin_within_one_phase_still_drops_the_fragment() {
        // A leave + rejoin inside the in-flight window restores the
        // offer-time live set, so the live comparison alone would pass —
        // the schedule-window check must still drop the fragment (the
        // rejoiner's state was rebuilt underneath the exchange).
        let mut cfg = streaming_cfg(2, true);
        // tiny's inner_steps is 50: boundary 1 closes step 49, so the
        // fragment is in flight over steps 50..99.
        cfg.churn = crate::net::ChurnSchedule::none().leave(60, 1).join(70, 1);
        let mut s = StreamingSync::from_config(&cfg);
        let mut comm = AccountingComm::new();
        let live = vec![0usize, 1];
        let mut a = worker(0, vec![1.0, 2.0, 3.0, 4.0]);
        let b = worker(1, vec![4.0, 3.0, 2.0, 1.0]);
        let phi_a0 = a.phi.clone();
        s.offer_outer(&mut comm, &a, &live, 1).unwrap();
        s.offer_outer(&mut comm, &b, &live, 1).unwrap();
        s.fold_inflight(&mut comm, &mut a, &live, 2).unwrap();
        assert_eq!(a.phi, phi_a0, "intra-phase churn must drop the fragment");
        assert_eq!(s.dropped_stale(), 1);
    }

    #[test]
    fn gated_fragmented_fold_updates_at_the_same_boundary() {
        let mut s = StreamingSync::from_config(&streaming_cfg(2, false));
        let mut comm = AccountingComm::new();
        let live = vec![0usize, 1];
        let mut a = worker(0, vec![1.0, 2.0, 3.0, 4.0]);
        let b = worker(1, vec![4.0, 3.0, 2.0, 1.0]);
        let phi_a0 = a.phi.clone();
        s.offer_outer(&mut comm, &a, &live, 1).unwrap();
        s.offer_outer(&mut comm, &b, &live, 1).unwrap();
        let entry = s.take_foldable(&a, &live, 1, false).unwrap();
        s.fold_entry(&mut comm, &mut a, entry).unwrap();
        assert_ne!(&a.phi[..2], &phi_a0[..2]);
        assert_eq!(&a.phi[2..], &phi_a0[2..]);
        assert_eq!(&a.theta[..2], &a.phi[..2], "zero drift: plain θ := φ");
    }

    #[test]
    fn export_restore_resumes_inflight_folds_bit_identically() {
        let cfg = streaming_cfg(2, true);
        let live = vec![0usize, 1];
        let mut s = StreamingSync::from_config(&cfg);
        let mut comm = AccountingComm::new();
        let mut ws = [
            worker(0, vec![1.0, 2.0, 3.0, 4.0]),
            worker(1, vec![4.0, 3.0, 2.0, 1.0]),
        ];
        for w in ws.iter() {
            s.offer_outer(&mut comm, w, &live, 1).unwrap();
        }
        // Inner-phase drift while fragment 0 is in flight.
        for w in ws.iter_mut() {
            for x in w.theta.iter_mut() {
                *x += 0.25;
            }
        }
        // Checkpoint mid-flight: worker tensors + exported strategy state.
        let snaps: Vec<(WorkerState, StrategyState)> =
            ws.iter().map(|w| (w.clone(), s.export_state(w).unwrap())).collect();
        // Reference run continues uninterrupted through boundary 2.
        boundary(&mut s, &mut comm, &mut ws, &live, 2);
        // Resumed run: fresh strategy + fresh comm, sender-replay restore.
        let mut s2 = StreamingSync::from_config(&cfg);
        let mut comm2 = AccountingComm::new();
        let mut ws2: Vec<WorkerState> = snaps.iter().map(|(w, _)| w.clone()).collect();
        for (w, st) in &snaps {
            s2.restore_state(&mut comm2, w, st).unwrap();
        }
        boundary(&mut s2, &mut comm2, &mut ws2, &live, 2);
        for (a, b) in ws.iter().zip(&ws2) {
            assert_eq!(a.phi, b.phi, "resumed φ must match bit-for-bit");
            assert_eq!(a.theta, b.theta, "resumed θ must match bit-for-bit");
            assert_eq!(a.delta, b.delta, "resumed δ must match bit-for-bit");
        }
        assert_eq!(s.dropped_stale(), s2.dropped_stale());
    }

    #[test]
    fn drain_folds_the_final_inflight_fragment_but_not_an_older_one() {
        let mut s = StreamingSync::from_config(&streaming_cfg(2, true));
        let mut comm = AccountingComm::new();
        let live = vec![0usize, 1];
        let mut a = worker(0, vec![1.0, 2.0, 3.0, 4.0]);
        let b = worker(1, vec![4.0, 3.0, 2.0, 1.0]);
        let phi_a0 = a.phi.clone();
        s.offer_outer(&mut comm, &a, &live, 1).unwrap();
        s.offer_outer(&mut comm, &b, &live, 1).unwrap();
        // An entry left over from an *earlier* boundary (a worker that
        // sat out the tail of the run) must be dropped at drain time.
        s.drain(&mut comm, &mut a, &live, 3).unwrap();
        assert_eq!(a.phi, phi_a0, "stale tail entry must not fold");
        assert_eq!(s.dropped_stale(), 1);
        // The final boundary's entry folds.
        s.offer_outer(&mut comm, &a, &live, 3).unwrap();
        s.offer_outer(&mut comm, &b, &live, 3).unwrap();
        s.drain(&mut comm, &mut a, &live, 3).unwrap();
        assert_ne!(&a.phi[..2], &phi_a0[..2], "drain must fold the tail fragment");
        assert!(s.inflight[&(0usize, 0usize)].is_empty());
    }
}
