//! Threaded executor — a thin spawner over [`TrainerCore`] with the
//! [`FabricComm`] communicator: one OS thread + PJRT engine per worker,
//! all communication over the in-process message [`Fabric`].
//!
//! This is the "real system" counterpart of [`super::SimTrainer`]: the
//! same core and the same [`SyncStrategy`](super::SyncStrategy) impls,
//! but no shared state — every activation, gradient, token batch,
//! all-reduce and gossip exchange is an actual message, and workers only
//! coordinate through deterministic shared-seed derivations (route plans,
//! gossip pairings and live sets are *computed*, not negotiated — the
//! same trick SWARM-style systems use to avoid a routing master).
//!
//! Latency injection (`with_latency`) turns the fabric into the paper's
//! §5.3 network model; `with_gossip_timeout` enables straggler-tolerant
//! gossip (a peer that misses the deadline degrades the outer update to a
//! smaller group — only possible *because* NoLoCo has no collective).
//!
//! Elastic membership: every worker derives the per-step live set from
//! the shared [`ChurnSchedule`] — no control traffic — and a rejoining
//! column catches up by absorbing a fresh gossip peer's slow weights (the
//! message-passing form of the grid executor's donor bootstrap). FSDP /
//! DiLoCo refuse churn up front: their global all-reduce has no
//! live-subset form, which is exactly the no-global-barrier contrast the
//! paper draws (§5.3).
//!
//! The run returns the unified [`TrainReport`]: worker traces and
//! logical communication counters are folded together (their
//! once-per-row / once-per-pair counting reproduces the grid executor's
//! totals) and the wire counters come from the fabric's own metering.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::config::TrainConfig;
use crate::metrics::RunTrace;
use crate::obs::ObsHub;
use crate::net::topo::{ChurnEvent, ChurnSchedule};
use crate::net::Fabric;
use crate::runtime::{find_build, Engine, Manifest};

use super::checkpoint::{Checkpoint, CkptAssembler};
use super::comm::FabricComm;
use super::core::TrainerCore;
use super::strategy::{self, ChurnResponse, SyncStrategy};
use super::{CommStats, TrainReport};

/// Threaded DP × PP trainer.
pub struct ThreadedTrainer {
    cfg: TrainConfig,
    /// Log-normal latency injection on every message, `(mu, sigma)` in
    /// seconds — `None` for a fast fabric.
    latency: Option<(f64, f64)>,
    /// Validation batches per eval point.
    val_batches: usize,
    /// Straggler tolerance: give up on a gossip peer after this long and
    /// fall back to a smaller outer group. Only possible *because*
    /// NoLoCo has no collective — a DiLoCo all-reduce cannot skip a
    /// member. `None` = wait forever.
    gossip_timeout: Option<std::time::Duration>,
    /// Fault injection for detection tests: crash `(replica, at_step)` —
    /// the worker thread stops outright, announcing nothing.
    silence: Option<(usize, u64)>,
    /// Kill-restart drills: every worker stops right after the `[ckpt]`
    /// cadence covers this boundary.
    halt_after: Option<u64>,
    /// Resume from this snapshot instead of `cfg.ckpt.resume` (drills
    /// hand the loaded checkpoint over directly).
    resume: Option<Arc<Checkpoint>>,
}

impl ThreadedTrainer {
    /// New trainer; call [`ThreadedTrainer::run`] to execute. Any churn
    /// schedule on the config is honored (NoLoCo only).
    pub fn new(cfg: TrainConfig) -> ThreadedTrainer {
        ThreadedTrainer {
            cfg,
            latency: None,
            val_batches: 4,
            gossip_timeout: None,
            silence: None,
            halt_after: None,
            resume: None,
        }
    }

    /// Fault injection for failure-detection tests: the worker column
    /// `replica` crashes outright at `at_step` — no announcement, no
    /// schedule entry; survivors must *detect* the failure through
    /// missed heartbeats (enable `[churn] detect` and set a gossip
    /// timeout so collects from the dead peer degrade instead of
    /// blocking). Meaningful with `pp = 1`: a crashed pipeline stage
    /// would starve its consumers, which is stage-failure territory the
    /// detector does not repair yet.
    pub fn with_silence(mut self, replica: usize, at_step: u64) -> ThreadedTrainer {
        self.silence = Some((replica, at_step));
        self
    }

    /// Enable straggler-tolerant gossip: skip a peer that does not
    /// deliver within `t` (the outer step degrades to a smaller group).
    pub fn with_gossip_timeout(mut self, t: std::time::Duration) -> ThreadedTrainer {
        self.gossip_timeout = Some(t);
        self
    }

    /// Override the membership schedule (DP-column leave/join events).
    pub fn with_churn(mut self, churn: ChurnSchedule) -> ThreadedTrainer {
        self.cfg.churn = churn;
        self
    }

    /// Inject log-normal per-message latency (`mu`, `sigma` in seconds).
    pub fn with_latency(mut self, mu: f64, sigma: f64) -> ThreadedTrainer {
        self.latency = Some((mu, sigma));
        self
    }

    /// Number of validation batches per eval point (0 disables eval).
    pub fn with_val_batches(mut self, n: usize) -> ThreadedTrainer {
        self.val_batches = n;
        self
    }

    /// Kill-restart drills: every worker stops right after the `[ckpt]`
    /// cadence snapshots `boundary` (see [`TrainerCore::set_halt_after`]).
    pub fn with_halt_after(mut self, boundary: u64) -> ThreadedTrainer {
        self.halt_after = Some(boundary);
        self
    }

    /// Resume all workers from an already-loaded snapshot (the drill
    /// path; `cfg.ckpt.resume` is the file-path form of the same thing).
    pub fn with_resume(mut self, ck: Checkpoint) -> ThreadedTrainer {
        self.resume = Some(Arc::new(ck));
        self
    }

    /// Spawn the worker grid, train, validate, and aggregate.
    pub fn run(&self) -> Result<TrainReport> {
        let cfg = &self.cfg;
        cfg.validate().map_err(anyhow::Error::msg)?;
        let churn_response = strategy::for_config(cfg).churn_response();
        if !cfg.churn.is_empty() && matches!(churn_response, ChurnResponse::Abort) {
            anyhow::bail!(
                "{} cannot change membership mid-run: its global all-reduce has no \
                 live-subset form; only NoLoCo's gossip re-pairs over survivors",
                cfg.outer.method
            );
        }
        // The schedule must never empty the live set: walking the sorted
        // events tracks the live count through every prefix.
        {
            let mut m = crate::net::Membership::full(cfg.topology.dp);
            for &(step, e) in cfg.churn.events() {
                m.apply(e);
                anyhow::ensure!(
                    m.live_count() > 0,
                    "churn schedule leaves no live replicas after step {step}"
                );
            }
        }
        // Detection without a straggler timeout would block forever on a
        // crashed peer's gossip collect — the timeout is what lets the
        // fold degrade while the detector converges. Default one in.
        let gossip_timeout = match (self.gossip_timeout, cfg.detect.enabled) {
            (Some(t), _) => Some(t),
            (None, true) => Some(std::time::Duration::from_secs(2)),
            (None, false) => None,
        };
        let (dp, pp) = (cfg.topology.dp, cfg.topology.pp);
        let dir = find_build(&cfg.artifacts_dir, &cfg.model.name, pp)?;
        let man = Manifest::load(&dir)?;
        man.check_against(&cfg.model, pp)?;
        let per_replica_seqs = (cfg.model.batch_tokens / cfg.model.seq_len / dp).max(man.mb);
        let num_mb = (per_replica_seqs / man.mb).max(1);

        // analyze: wall-clock-ok — report-envelope timing only; never
        // feeds the trajectory, losses, or CommStats.
        let start = Instant::now();
        // Fault injection rides the fabric: a fault-free plan is exactly
        // `Fabric::new`, so this is unconditional. The per-receiver fault
        // RNGs derive from the run seed — faulty runs replay exactly.
        let mut fabric = Fabric::with_faults(dp * pp, cfg.faults.plan(), cfg.seed);
        let endpoints = fabric.take_endpoints();
        // One shared hub for the whole run: every worker core (and its
        // fabric communicator) journals into the same sink, each stamping
        // events with its own (stage, replica).
        let hub = ObsHub::from_config(&cfg.obs)?;
        // Periodic checkpoints: ranks snapshot independently at the same
        // boundary and the assembler writes once the dp·pp set is whole.
        let sink: Option<Arc<CkptAssembler>> = match (&cfg.ckpt.out, cfg.ckpt.every) {
            (Some(path), every) if every > 0 => Some(Arc::new(CkptAssembler::new(path, dp, pp))),
            _ => None,
        };
        // Resume: the drill path hands a loaded snapshot over; the config
        // path names a file. Loaded once, shared read-only by every rank.
        let resume: Option<Arc<Checkpoint>> = match (&self.resume, &cfg.ckpt.resume) {
            (Some(ck), _) => Some(ck.clone()),
            (None, Some(path)) => Some(Arc::new(
                Checkpoint::load(path).with_context(|| format!("resuming from {path}"))?,
            )),
            (None, None) => None,
        };

        let reports: Vec<TrainReport> = thread::scope(|scope| -> Result<Vec<TrainReport>> {
            let mut handles = Vec::new();
            for (rank, mut ep) in endpoints.into_iter().enumerate() {
                if let Some((mu, sigma)) = self.latency {
                    ep.set_latency_log_normal(mu, sigma);
                }
                let dir = dir.clone();
                let man = man.clone();
                let cfg = cfg.clone();
                let val_batches = self.val_batches;
                let silence = self.silence;
                let halt_after = self.halt_after;
                let hub = hub.clone();
                let sink = sink.clone();
                let resume = resume.clone();
                handles.push(scope.spawn(move || -> Result<TrainReport> {
                    let (stage, replica) = (rank / dp, rank % dp);
                    let comm = FabricComm::new(ep, dp, gossip_timeout);
                    let mut eng = Engine::new(&dir)?;
                    let mut core = TrainerCore::new_single(
                        cfg, &mut eng, comm, man, stage, replica, num_mb, val_batches,
                    )?;
                    core.set_obs(hub);
                    if let Some(sink) = sink {
                        core.set_ckpt_sink(sink);
                    }
                    if let Some(b) = halt_after {
                        core.set_halt_after(b);
                    }
                    if let Some(ck) = &resume {
                        core.resume_from(ck)?;
                    }
                    if let Some((r, at)) = silence {
                        core.set_silence(r, at, u64::MAX);
                    }
                    core.run()
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().map_err(|_| anyhow!("worker thread panicked"))?)
                .collect()
        })?;

        // ---- aggregate the per-worker reports into one ----
        let mut comm = CommStats::default();
        let mut executions = 0u64;
        for r in &reports {
            comm.absorb(&r.comm);
            executions += r.executions;
        }
        // Wire metering is the fabric's ground truth (a resumed run
        // restores the snapshot's per-rank totals into these counters, so
        // they stay prefix-inclusive).
        comm.bytes_sent = fabric.bytes_sent().iter().sum();
        comm.msgs_sent = fabric.msgs_sent().iter().sum();
        // CRC-rejected frames (corrupt fault injection): surfaced as an
        // obs counter so a faulty run's report shows what the framing
        // layer absorbed.
        let corrupt_dropped: u64 = fabric.corrupt_dropped().iter().sum();
        if corrupt_dropped > 0 {
            hub.count("net.corrupt_dropped", corrupt_dropped);
        }

        // Per-step training loss: mean across reporting replicas; steps a
        // replica sat out (churn) arrive as NaN and are excluded.
        let mut step_train_loss = vec![0.0f64; cfg.steps];
        let mut counts = vec![0usize; cfg.steps];
        for r in &reports {
            for (i, l) in r.step_train_loss.iter().enumerate() {
                if l.is_finite() {
                    step_train_loss[i] += l;
                    counts[i] += 1;
                }
            }
        }
        for (acc, c) in step_train_loss.iter_mut().zip(&counts) {
            if *c == 0 {
                *acc = f64::NAN;
            } else {
                *acc /= *c as f64;
            }
        }

        // Eval trace: merge rows by step (replicas dead at an eval point
        // contribute no row); weight-σ is unknowable worker-locally.
        let mut rows: BTreeMap<usize, (f64, f64, f64, usize)> = BTreeMap::new();
        for r in &reports {
            let t = &r.trace;
            for i in 0..t.steps.len() {
                let e = rows.entry(t.steps[i]).or_insert((0.0, 0.0, t.lr[i], 0));
                e.0 += t.train_loss[i];
                e.1 += t.val_loss[i];
                e.3 += 1;
            }
        }
        let mut trace = RunTrace::default();
        for (step, (ts, vs, lr, n)) in rows {
            trace.push(step, ts / n as f64, vs / n as f64, f64::NAN, lr);
        }

        // Detection transitions: every surviving worker runs its own
        // detector over the same boundary-granular heartbeats, so their
        // observations coincide up to a one-boundary skew. Group same
        // events together, collapse entries within one boundary of each
        // other (keeping the earliest), then restore chronological order.
        let mut detected: Vec<(u64, ChurnEvent)> = reports
            .iter()
            .flat_map(|r| r.detected.iter().copied())
            .collect();
        detected.sort_by_key(|&(b, e)| (e.node(), matches!(e, ChurnEvent::Join(_)), b));
        detected.dedup_by(|later, earlier| {
            later.1 == earlier.1 && later.0.saturating_sub(earlier.0) <= 1
        });
        detected.sort_by_key(|&(b, e)| (b, e.node(), matches!(e, ChurnEvent::Join(_))));

        let mut val_sum = 0.0;
        let mut val_n = 0usize;
        for r in &reports {
            if r.final_val_nll.is_finite() {
                val_sum += r.final_val_nll;
                val_n += 1;
            }
        }
        let final_val_nll = if val_n == 0 { f64::NAN } else { val_sum / val_n as f64 };

        Ok(TrainReport::assemble(
            final_val_nll,
            trace,
            step_train_loss,
            comm,
            start.elapsed().as_secs_f64(),
            executions,
            "threaded",
            detected,
            hub.report(),
        ))
    }
}
