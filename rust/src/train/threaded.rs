//! Threaded executor: one OS thread + PJRT engine per worker, all
//! communication over the in-process message [`Fabric`].
//!
//! This is the "real system" counterpart of [`super::SimTrainer`]: the
//! same algorithm, but no shared state — every activation, gradient,
//! token batch, all-reduce and gossip exchange is an actual message, and
//! workers only coordinate through deterministic shared-seed derivations
//! (route plans and gossip pairings are *computed*, not negotiated — the
//! same trick SWARM-style systems use to avoid a routing master).
//!
//! Latency injection (`latency_log_normal`) turns the fabric into the
//! paper's §5.3 network model, making the blocking-communication effects
//! of Fig. 5B measurable in wall-clock terms on the real pipeline.
//!
//! Elastic membership: a [`ChurnSchedule`] names DP columns that leave or
//! (re)join at given steps. Every worker derives the per-step live set
//! from the shared schedule — no control traffic — and the route plans
//! and gossip pairings re-draw over the survivors, so a NoLoCo run keeps
//! training through churn. A rejoining column catches up by absorbing its
//! first gossip peer's slow weights. FSDP / DiLoCo refuse churn up front:
//! their global all-reduce has no live-subset form, which is exactly the
//! no-global-barrier contrast the paper draws (§5.3).

use std::thread;

use anyhow::{anyhow, Result};

use crate::collective::all_reduce_mean;
use crate::config::{Method, TrainConfig};
use crate::data::Loader;
use crate::metrics::perplexity;
use crate::model::StageKind;
use crate::net::topo::ChurnSchedule;
use crate::net::{Endpoint, Fabric, Payload, Tag};
use crate::optim::LrSchedule;
use crate::rngx::Pcg64;
use crate::routing::RoutePlan;
use crate::runtime::{find_build, Engine, Manifest};

use super::exec::{self, AdamScalars};
use super::state::WorkerState;

// Train-side tag kinds (collectives reserve 1..=4).
const K_ACT: u16 = 100;
const K_TOK: u16 = 101;
const K_GRD: u16 = 102;
const K_VACT: u16 = 103;
const K_VTOK: u16 = 104;

/// Result of a threaded run.
#[derive(Clone, Debug)]
pub struct ThreadedReport {
    /// Mean training loss per inner step (averaged over replicas).
    pub step_train_loss: Vec<f64>,
    /// Final validation NLL (mean over replicas and batches).
    pub final_val_nll: f64,
    /// Final validation perplexity.
    pub final_val_ppl: f64,
    /// Wall-clock seconds for the whole run.
    pub wall_secs: f64,
    /// Total bytes sent over the fabric.
    pub bytes_sent: u64,
    /// Total messages sent over the fabric.
    pub msgs_sent: u64,
}

/// Threaded DP × PP trainer.
pub struct ThreadedTrainer {
    cfg: TrainConfig,
    /// Log-normal latency injection on every message, `(mu, sigma)` in
    /// seconds — `None` for a fast fabric.
    latency: Option<(f64, f64)>,
    /// Validation batches to run at the end.
    val_batches: usize,
    /// Straggler tolerance: give up on a gossip peer after this long and
    /// fall back to a singleton outer update. Only possible *because*
    /// NoLoCo has no collective — a DiLoCo all-reduce cannot skip a
    /// member. `None` = wait forever.
    gossip_timeout: Option<std::time::Duration>,
}

/// What one worker thread hands back.
struct WorkerOut {
    /// stage == pp-1 only: per-step mean microbatch loss.
    step_loss: Vec<f64>,
    /// stage == pp-1 only: mean validation NLL over batches.
    val_nll: Option<f64>,
}

impl ThreadedTrainer {
    /// New trainer; call [`ThreadedTrainer::run`] to execute. Any churn
    /// schedule on the config is honored (NoLoCo only).
    pub fn new(cfg: TrainConfig) -> ThreadedTrainer {
        ThreadedTrainer { cfg, latency: None, val_batches: 4, gossip_timeout: None }
    }

    /// Enable straggler-tolerant gossip: skip a peer that does not
    /// deliver within `t` (the outer step degrades to a singleton group).
    pub fn with_gossip_timeout(mut self, t: std::time::Duration) -> ThreadedTrainer {
        self.gossip_timeout = Some(t);
        self
    }

    /// Override the membership schedule (DP-column leave/join events).
    pub fn with_churn(mut self, churn: ChurnSchedule) -> ThreadedTrainer {
        self.cfg.churn = churn;
        self
    }

    /// Inject log-normal per-message latency (`mu`, `sigma` in seconds).
    pub fn with_latency(mut self, mu: f64, sigma: f64) -> ThreadedTrainer {
        self.latency = Some((mu, sigma));
        self
    }

    /// Number of end-of-run validation batches.
    pub fn with_val_batches(mut self, n: usize) -> ThreadedTrainer {
        self.val_batches = n;
        self
    }

    /// Spawn the worker grid, train, validate, and aggregate.
    pub fn run(&self) -> Result<ThreadedReport> {
        let cfg = &self.cfg;
        cfg.validate().map_err(anyhow::Error::msg)?;
        if cfg.outer.method == crate::config::Method::NoLoCo && cfg.outer.group != 2 {
            anyhow::bail!(
                "the threaded executor implements the paper's minimum gossip group (n = 2); \
                 use SimTrainer for general group sizes"
            );
        }
        if !cfg.churn.is_empty() && cfg.outer.method != Method::NoLoCo {
            anyhow::bail!(
                "{} cannot change membership mid-run: its global all-reduce has no \
                 live-subset form; only NoLoCo's gossip re-pairs over survivors",
                cfg.outer.method
            );
        }
        // The schedule must never empty the live set: walking the sorted
        // events tracks the live count through every prefix.
        {
            let mut m = crate::net::Membership::full(cfg.topology.dp);
            for &(step, e) in cfg.churn.events() {
                m.apply(e);
                anyhow::ensure!(
                    m.live_count() > 0,
                    "churn schedule leaves no live replicas after step {step}"
                );
            }
        }
        let (dp, pp) = (cfg.topology.dp, cfg.topology.pp);
        let dir = find_build(&cfg.artifacts_dir, &cfg.model.name, pp)?;
        let man = Manifest::load(&dir)?;
        man.check_against(&cfg.model, pp)?;
        let per_replica_seqs = (cfg.model.batch_tokens / cfg.model.seq_len / dp).max(man.mb);
        let num_mb = (per_replica_seqs / man.mb).max(1);

        let start = std::time::Instant::now();
        let mut fabric = Fabric::new(dp * pp);
        let endpoints = fabric.take_endpoints();

        let outs: Vec<WorkerOut> = thread::scope(|scope| -> Result<Vec<WorkerOut>> {
            let mut handles = Vec::new();
            for (rank, mut ep) in endpoints.into_iter().enumerate() {
                if let Some((mu, sigma)) = self.latency {
                    ep.set_latency_log_normal(mu, sigma);
                }
                let dir = dir.clone();
                let man = man.clone();
                let cfg = cfg.clone();
                let val_batches = self.val_batches;
                let gossip_timeout = self.gossip_timeout;
                handles.push(scope.spawn(move || -> Result<WorkerOut> {
                    worker_main(rank, ep, cfg, dir, man, num_mb, val_batches, gossip_timeout)
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().map_err(|_| anyhow!("worker thread panicked"))?)
                .collect()
        })?;

        // Aggregate last-stage outputs. Steps a replica sat out (churn)
        // are reported as NaN and excluded from that step's mean.
        let mut step_train_loss = vec![0.0f64; cfg.steps];
        let mut step_counts = vec![0usize; cfg.steps];
        let mut val_sum = 0.0;
        let mut val_n = 0usize;
        for out in &outs {
            if out.step_loss.is_empty() {
                continue;
            }
            for (i, l) in out.step_loss.iter().enumerate() {
                if l.is_finite() {
                    step_train_loss[i] += l;
                    step_counts[i] += 1;
                }
            }
            if let Some(v) = out.val_nll {
                val_sum += v;
                val_n += 1;
            }
        }
        for (acc, c) in step_train_loss.iter_mut().zip(&step_counts) {
            *acc /= (*c).max(1) as f64;
        }
        let final_val_nll = val_sum / val_n.max(1) as f64;
        Ok(ThreadedReport {
            step_train_loss,
            final_val_nll,
            final_val_ppl: perplexity(final_val_nll),
            wall_secs: start.elapsed().as_secs_f64(),
            bytes_sent: fabric.bytes_sent().iter().sum(),
            msgs_sent: fabric.msgs_sent().iter().sum(),
        })
    }
}

/// Which live origin replica's path crosses `(stage, me)` under `plan`.
fn origin_through(plan: &RoutePlan, stage: usize, me: usize, live: &[usize]) -> usize {
    for &r0 in live {
        if plan.path_from(r0)[stage] == me {
            return r0;
        }
    }
    unreachable!("live permutation routing covers every live replica");
}

/// Symmetric gossip exchange of `(Δ, φ)` with an optional straggler
/// timeout. Sends both payloads eagerly (one RTT), then waits; `None`
/// means the peer missed the deadline and the caller should fall back to
/// a singleton update. Trailing late messages are absorbed harmlessly by
/// the endpoint stash (tags are unique per outer step).
fn gossip_exchange(
    ep: &mut Endpoint,
    peer: usize,
    seq: u32,
    delta: &[f32],
    phi: &[f32],
    timeout: Option<std::time::Duration>,
) -> Option<(Vec<f32>, Vec<f32>)> {
    const K_GOSSIP_D: u16 = 110;
    const K_GOSSIP_P: u16 = 111;
    let me = ep.rank() as u32;
    ep.send(peer, Tag::new(K_GOSSIP_D, seq, me), Payload::F32(delta.to_vec()));
    ep.send(peer, Tag::new(K_GOSSIP_P, seq, me), Payload::F32(phi.to_vec()));
    let td = Tag::new(K_GOSSIP_D, seq, peer as u32);
    let tp = Tag::new(K_GOSSIP_P, seq, peer as u32);
    match timeout {
        None => Some((ep.recv(td).payload.into_f32(), ep.recv(tp).payload.into_f32())),
        Some(t) => {
            let d = ep.recv_timeout(td, t)?.payload.into_f32();
            let p = ep.recv_timeout(tp, t)?.payload.into_f32();
            Some((d, p))
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_main(
    rank: usize,
    mut ep: Endpoint,
    cfg: TrainConfig,
    dir: std::path::PathBuf,
    man: Manifest,
    num_mb: usize,
    val_batches: usize,
    gossip_timeout: Option<std::time::Duration>,
) -> Result<WorkerOut> {
    let (dp, pp) = (cfg.topology.dp, cfg.topology.pp);
    let (stage, replica) = (rank / dp, rank % dp);
    let kind = StageKind::of_stage(stage, pp);
    let is_first = stage == 0;
    let is_last = stage == pp - 1;
    let mb_toks = man.mb * man.seq_len;
    let rank_of = |s: usize, r: usize| s * dp + r;
    let row: Vec<usize> = (0..dp).map(|r| rank_of(stage, r)).collect();

    let mut eng = Engine::new(&dir)?;
    let init = exec::init_stage(&mut eng, kind, (cfg.seed as i32) ^ (stage as i32 * 7901))?;
    let mut w = WorkerState::new(stage, replica, kind, init, cfg.outer.method);

    let mut loader = is_first.then(|| {
        Loader::train(
            cfg.dataset,
            cfg.model.vocab,
            cfg.seed,
            replica,
            dp,
            cfg.model.seq_len,
            num_mb * man.mb,
        )
    });
    let lr = LrSchedule {
        peak: cfg.model.inner_lr,
        warmup: cfg.warmup,
        total: cfg.steps,
        floor_frac: cfg.lr_floor,
    };

    let mut step_loss = Vec::new();
    let mut coll_seq: u32 = 0; // collective tag namespace, same on all row members

    for step in 0..cfg.steps {
        // Elastic membership: every worker derives the same live set from
        // the shared schedule — zero coordination traffic, like the route
        // plans. A dead column sits the step out entirely (no data, no
        // compute, no messages); live columns route and gossip over the
        // survivors.
        let live_mask = cfg.churn.live_at(dp, step as u64);
        if !live_mask[replica] {
            if is_last || pp == 1 {
                step_loss.push(f64::NAN); // sat out; excluded from means
            }
            continue;
        }
        let live_idx: Vec<usize> = (0..dp).filter(|&r| live_mask[r]).collect();

        let batch: Option<Vec<i32>> = loader
            .as_mut()
            .map(|l| l.next_batch().tokens.iter().map(|&t| t as i32).collect());
        let mut losses = Vec::new();
        // Stash of (wave, x_in) for the backward pass.
        let mut stash: Vec<(u32, usize, Vec<f32>, Vec<i32>)> = Vec::new();

        // ---- forward sweep over this step's waves ----
        for mb in 0..num_mb {
            let wave = (step * num_mb + mb) as u32;
            let plan = RoutePlan::for_step_over(
                cfg.routing, &live_idx, dp, pp, cfg.seed ^ 0x0a17, wave as u64,
            );
            if pp == 1 {
                let toks = &batch.as_ref().unwrap()[mb * mb_toks..(mb + 1) * mb_toks];
                let (loss, g) = exec::bwd_full(&mut eng, &man, &w.theta, toks)?;
                w.accumulate(&g);
                losses.push(loss as f64);
                continue;
            }
            if is_first {
                let toks = batch.as_ref().unwrap()[mb * mb_toks..(mb + 1) * mb_toks].to_vec();
                let x = exec::fwd_first(&mut eng, &man, &w.theta, &toks)?;
                let nxt = rank_of(1, plan.next_of(0, replica));
                ep.send(nxt, Tag::new(K_ACT, wave, replica as u32), Payload::F32(x));
                ep.send(
                    nxt,
                    Tag::new(K_TOK, wave, replica as u32),
                    Payload::U32(toks.iter().map(|&t| t as u32).collect()),
                );
                stash.push((wave, replica, Vec::new(), toks));
            } else {
                let r0 = origin_through(&plan, stage, replica, &live_idx);
                let act = ep.recv(Tag::new(K_ACT, wave, r0 as u32)).payload.into_f32();
                let toks: Vec<i32> = ep
                    .recv(Tag::new(K_TOK, wave, r0 as u32))
                    .payload
                    .u32()
                    .iter()
                    .map(|&t| t as i32)
                    .collect();
                if is_last {
                    let (loss, g_theta, gx) =
                        exec::bwd_last(&mut eng, &man, &w.theta, &act, &toks)?;
                    w.accumulate(&g_theta);
                    losses.push(loss as f64);
                    let prv = rank_of(stage - 1, plan.prev_of(stage, replica));
                    ep.send(prv, Tag::new(K_GRD, wave, r0 as u32), Payload::F32(gx));
                } else {
                    let x_out = exec::fwd_mid(&mut eng, &man, &w.theta, &act)?;
                    let nxt = rank_of(stage + 1, plan.next_of(stage, replica));
                    ep.send(nxt, Tag::new(K_ACT, wave, r0 as u32), Payload::F32(x_out));
                    ep.send(
                        nxt,
                        Tag::new(K_TOK, wave, r0 as u32),
                        Payload::U32(toks.iter().map(|&t| t as u32).collect()),
                    );
                    stash.push((wave, r0, act, toks));
                }
            }
        }

        // ---- backward sweep (first and mid stages drain gradients) ----
        if pp > 1 && !is_last {
            for (wave, r0, x_in, toks) in stash.drain(..) {
                let plan = RoutePlan::for_step_over(
                    cfg.routing, &live_idx, dp, pp, cfg.seed ^ 0x0a17, wave as u64,
                );
                let g_out = ep
                    .recv(Tag::new(K_GRD, wave, r0 as u32))
                    .payload
                    .into_f32();
                if is_first {
                    let g = exec::bwd_first(&mut eng, &man, &w.theta, &toks, &g_out)?;
                    w.accumulate(&g);
                } else {
                    let (g, gx) = exec::bwd_mid(&mut eng, &man, &w.theta, &x_in, &g_out)?;
                    w.accumulate(&g);
                    let prv = rank_of(stage - 1, plan.prev_of(stage, replica));
                    ep.send(prv, Tag::new(K_GRD, wave, r0 as u32), Payload::F32(gx));
                }
            }
        }

        // ---- inner optimizer ----
        let mut g = w.take_mean_grad();
        if cfg.outer.method == Method::Fsdp && dp > 1 {
            let mut t = crate::tensor::Tensor::from_vec(std::mem::take(&mut g), &[w.len()]);
            all_reduce_mean(&mut ep, &row, coll_seq, &mut t);
            coll_seq += 1;
            g = t.into_vec();
        }
        w.adam_t += 1;
        let sc = AdamScalars::at(lr.at(step), w.adam_t, cfg.grad_clip);
        let (mut theta, mut m, mut v) = (
            std::mem::take(&mut w.theta),
            std::mem::take(&mut w.m),
            std::mem::take(&mut w.v),
        );
        exec::adam_step(&mut eng, kind, &mut theta, &mut m, &mut v, &g, sc)?;
        w.theta = theta;
        w.m = m;
        w.v = v;

        // ---- outer optimizer ----
        let outer_due =
            cfg.outer.method != Method::Fsdp && (step + 1) % cfg.outer.inner_steps == 0;
        if outer_due && dp > 1 {
            let outer_idx = (step + 1) / cfg.outer.inner_steps;
            match cfg.outer.method {
                Method::DiLoCo => {
                    let mut d = crate::tensor::Tensor::from_vec(w.outer_grad(), &[w.len()]);
                    all_reduce_mean(&mut ep, &row, coll_seq, &mut d);
                    coll_seq += 1;
                    let (mut phi, mut delta) =
                        (std::mem::take(&mut w.phi), std::mem::take(&mut w.delta));
                    exec::outer_diloco(
                        &mut eng,
                        kind,
                        &mut phi,
                        &mut delta,
                        d.as_slice(),
                        cfg.outer.alpha as f32,
                        cfg.outer.beta as f32,
                    )?;
                    w.phi = phi;
                    w.delta = delta;
                    w.reset_theta_to_phi();
                }
                Method::NoLoCo => {
                    // Deterministic shared-seed pairing over the *live*
                    // columns: every row member derives the same pairs
                    // without any coordination (and a dead column is
                    // never named, so nobody blocks on it — the elastic
                    // counterpart of the paper's no-global-barrier
                    // argument). The gossip tag namespace is keyed by
                    // outer_idx, which stays aligned across workers even
                    // when some sat out earlier steps.
                    let mut prng = Pcg64::seed_from_u64(
                        cfg.seed ^ 0x9055 ^ ((stage as u64) << 40) ^ (outer_idx as u64),
                    );
                    let pairs = prng.random_pairs(live_idx.len());
                    let me = live_idx
                        .iter()
                        .position(|&r| r == replica)
                        .expect("live worker is in its own live set");
                    let peer = pairs.iter().find_map(|&(a, b)| match b {
                        Some(b) if a == me => Some(Some(live_idx[b])),
                        Some(b) if b == me => Some(Some(live_idx[a])),
                        None if a == me => Some(None),
                        _ => None,
                    });
                    let gossip_seq = outer_idx as u32;
                    // A column is *stale* at this boundary if it was dead
                    // at any step since (and including) the previous
                    // boundary — i.e. it missed inner steps of this round
                    // or the previous outer update, so its (Δ, φ) predate
                    // the ensemble's. Every worker derives this from the
                    // shared schedule, so both sides of a pair agree on
                    // it: the stale side absorbs its peer's slow weights
                    // instead of averaging its stale state into the
                    // ensemble, and the fresh side updates as a
                    // singleton. Two stale columns paired together fall
                    // through to the plain averaged update — neither has
                    // fresh state to offer, and the γ-consensus term
                    // pulls their shared stale estimate back toward the
                    // ensemble over the following boundaries (accepted
                    // degradation, same regime as a timed-out peer).
                    let window_start = step.saturating_sub(cfg.outer.inner_steps);
                    let is_stale = |r: usize| {
                        !cfg.churn.is_empty()
                            && (window_start..=step)
                                .any(|s| !cfg.churn.live_at(dp, s as u64)[r])
                    };
                    let i_am_stale = is_stale(replica);
                    let peer_r_opt = peer.flatten();
                    let my_delta = w.outer_grad();
                    let (mut phi, mut delta) =
                        (std::mem::take(&mut w.phi), std::mem::take(&mut w.delta));
                    let exchanged = match peer_r_opt {
                        Some(peer_r) => {
                            let peer_rank = rank_of(stage, peer_r);
                            gossip_exchange(
                                &mut ep, peer_rank, gossip_seq, &my_delta, &phi,
                                gossip_timeout,
                            )
                        }
                        None => None,
                    };
                    match exchanged {
                        Some((_, p_theirs))
                            if i_am_stale && !is_stale(peer_r_opt.unwrap()) =>
                        {
                            // Rejoin catch-up: adopt the peer's φ outright.
                            phi.copy_from_slice(&p_theirs);
                            for d in delta.iter_mut() {
                                *d = 0.0;
                            }
                        }
                        Some((_, _))
                            if peer_r_opt.is_some_and(|p| is_stale(p)) && !i_am_stale =>
                        {
                            // The peer is catching up from my φ; its stale
                            // (Δ, φ) must not dilute mine — singleton step.
                            let psum = phi.clone();
                            exec::outer_noloco(
                                &mut eng,
                                kind,
                                &mut phi,
                                &mut delta,
                                &my_delta,
                                &psum,
                                cfg.outer.alpha as f32,
                                cfg.outer.beta as f32,
                                cfg.outer.gamma as f32,
                                1.0,
                            )?;
                        }
                        Some((d_theirs, p_theirs)) => {
                            let dsum: Vec<f32> = my_delta
                                .iter()
                                .zip(&d_theirs)
                                .map(|(a, b)| a + b)
                                .collect();
                            let psum: Vec<f32> =
                                phi.iter().zip(&p_theirs).map(|(a, b)| a + b).collect();
                            exec::outer_noloco(
                                &mut eng,
                                kind,
                                &mut phi,
                                &mut delta,
                                &dsum,
                                &psum,
                                cfg.outer.alpha as f32,
                                cfg.outer.beta as f32,
                                cfg.outer.gamma as f32,
                                0.5,
                            )?;
                        }
                        // No peer (odd live count) or peer timed out: a
                        // singleton group — NoLoCo degrades gracefully
                        // where a collective would hang.
                        None => {
                            let psum = phi.clone();
                            exec::outer_noloco(
                                &mut eng,
                                kind,
                                &mut phi,
                                &mut delta,
                                &my_delta,
                                &psum,
                                cfg.outer.alpha as f32,
                                cfg.outer.beta as f32,
                                cfg.outer.gamma as f32,
                                1.0,
                            )?;
                        }
                    }
                    w.phi = phi;
                    w.delta = delta;
                    w.reset_theta_to_phi();
                }
                Method::Fsdp => unreachable!(),
            }
        } else if outer_due {
            // dp == 1: outer step degenerates to lookahead on one replica.
            let my_delta = w.outer_grad();
            let (mut phi, mut delta) = (std::mem::take(&mut w.phi), std::mem::take(&mut w.delta));
            let psum = phi.clone();
            exec::outer_noloco(
                &mut eng,
                kind,
                &mut phi,
                &mut delta,
                &my_delta,
                &psum,
                cfg.outer.alpha as f32,
                cfg.outer.beta as f32,
                0.0,
                1.0,
            )?;
            w.phi = phi;
            w.delta = delta;
            w.reset_theta_to_phi();
        }

        if is_last || pp == 1 {
            let n = losses.len().max(1) as f64;
            step_loss.push(losses.iter().sum::<f64>() / n);
        }
    }

    // ---- final validation: fixed route r -> r, shared val stream ----
    // Columns dead at the end of the run sit validation out (their whole
    // pipeline is dark, so nobody waits on them).
    let live_at_end = cfg.churn.live_at(dp, cfg.steps.saturating_sub(1) as u64);
    let mut val_nll = None;
    if val_batches > 0 && live_at_end[replica] {
        let mut vloader = Loader::validation(
            cfg.dataset,
            cfg.model.vocab,
            cfg.seed ^ 0x5eed,
            cfg.model.seq_len,
            man.mb,
        );
        let mut sum = 0.0;
        for vb in 0..val_batches {
            let toks: Vec<i32> = vloader
                .next_batch()
                .tokens
                .iter()
                .map(|&t| t as i32)
                .collect();
            if pp == 1 {
                sum += exec::loss_full(&mut eng, &man, &w.theta, &toks)? as f64;
            } else if is_first {
                let x = exec::fwd_first(&mut eng, &man, &w.theta, &toks)?;
                let nxt = rank_of(1, replica);
                ep.send(nxt, Tag::new(K_VACT, vb as u32, replica as u32), Payload::F32(x));
                ep.send(
                    nxt,
                    Tag::new(K_VTOK, vb as u32, replica as u32),
                    Payload::U32(toks.iter().map(|&t| t as u32).collect()),
                );
            } else {
                let act = ep
                    .recv(Tag::new(K_VACT, vb as u32, replica as u32))
                    .payload
                    .into_f32();
                let vtoks: Vec<i32> = ep
                    .recv(Tag::new(K_VTOK, vb as u32, replica as u32))
                    .payload
                    .u32()
                    .iter()
                    .map(|&t| t as i32)
                    .collect();
                if is_last {
                    sum += exec::loss_last(&mut eng, &man, &w.theta, &act, &vtoks)? as f64;
                } else {
                    let x = exec::fwd_mid(&mut eng, &man, &w.theta, &act)?;
                    let nxt = rank_of(stage + 1, replica);
                    ep.send(nxt, Tag::new(K_VACT, vb as u32, replica as u32), Payload::F32(x));
                    ep.send(
                        nxt,
                        Tag::new(K_VTOK, vb as u32, replica as u32),
                        Payload::U32(vtoks.iter().map(|&t| t as u32).collect()),
                    );
                }
            }
        }
        if is_last || pp == 1 {
            val_nll = Some(sum / val_batches as f64);
        }
    }

    Ok(WorkerOut { step_loss, val_nll })
}
