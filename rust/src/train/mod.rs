//! Distributed training driver — the Layer-3 coordination contribution.
//!
//! Three training methods over a DP × PP worker grid (§2–3):
//!
//! * **FSDP** — fully synchronous data parallel: gradients all-reduced
//!   every inner step (the paper's upper baseline).
//! * **DiLoCo** — m local Adam steps, then a Nesterov outer step over an
//!   all-reduce of outer gradients (Douillard et al. 2023).
//! * **NoLoCo** — m local Adam steps, then the modified-Nesterov gossip
//!   step of Eq. 2–3 over *random pairs*: no collective, no global
//!   barrier.
//!
//! Plus the paper's §3.1 dynamic pipeline routing: each microbatch draws a
//! fresh random permutation wiring stage-k replicas to stage-(k+1)
//! replicas; the backward pass retraces the forward route.
//!
//! Two interchangeable executors run the same algorithm:
//!
//! * [`SimTrainer`] — single-threaded over one shared PJRT engine;
//!   deterministic, used for every convergence experiment.
//! * [`ThreadedTrainer`] — one OS thread + PJRT engine per worker,
//!   communicating over the in-process [`crate::net::Fabric`]; used by the
//!   end-to-end example and the blocking/latency studies.
//!
//! Both executors support *elastic membership* for NoLoCo: a
//! [`crate::net::ChurnSchedule`] on the config drops / rejoins whole DP
//! columns mid-run, with routing permutations and gossip pairings
//! re-drawn over the live set. FSDP and DiLoCo abort on churn — their
//! global all-reduce has no live-subset form (§5.3's no-global-barrier
//! contrast, made measurable).
//!
//! All compute (fwd/bwd/Adam/outer updates) executes inside AOT-compiled
//! XLA artifacts; this module only moves buffers and decides who talks to
//! whom — exactly the paper's separation of concerns.

mod checkpoint;
mod exec;
mod sim;
mod state;
mod threaded;

pub use checkpoint::Checkpoint;
pub use exec::{
    adam_step, bwd_first, bwd_full, bwd_last, bwd_mid, fwd_first, fwd_mid, init_stage,
    loss_full, loss_last, outer_diloco, outer_noloco, AdamScalars,
};
pub use sim::SimTrainer;
pub use state::WorkerState;
pub use threaded::{ThreadedReport, ThreadedTrainer};

use anyhow::Result;

use crate::config::TrainConfig;
use crate::metrics::RunTrace;
use crate::runtime::{find_build, Engine};

/// Communication accounting (what *would* cross the network).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommStats {
    /// Total f32 payload elements shipped (activations + grads + sync).
    pub floats_sent: u64,
    /// Point-to-point activation/gradient hops between pipeline stages.
    pub activation_hops: u64,
    /// Globally blocking collectives issued (FSDP grad + DiLoCo outer
    /// all-reduces) — the operations NoLoCo eliminates.
    pub blocking_collectives: u64,
    /// NoLoCo gossip pair exchanges.
    pub pair_exchanges: u64,
}

impl CommStats {
    /// Payload in MiB, assuming 4-byte floats.
    pub fn mib_sent(&self) -> f64 {
        self.floats_sent as f64 * 4.0 / (1024.0 * 1024.0)
    }
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Final validation loss (mean NLL, nats).
    pub final_val_nll: f64,
    /// Final validation perplexity (Table 2's metric).
    pub final_val_ppl: f64,
    /// Per-eval-point series (loss / PPL / weight-σ / LR curves).
    pub trace: RunTrace,
    /// Communication accounting.
    pub comm: CommStats,
    /// Wall-clock seconds.
    pub wall_secs: f64,
    /// PJRT executions issued.
    pub executions: u64,
}

/// Convenience: resolve artifacts, build an engine, run [`SimTrainer`].
///
/// Experiments comparing several configs over the *same* artifact build
/// should construct one [`Engine`] themselves and call
/// [`SimTrainer::new`] per run to amortize XLA compilation.
pub fn run_sim(cfg: &TrainConfig) -> Result<TrainReport> {
    let dir = find_build(&cfg.artifacts_dir, &cfg.model.name, cfg.topology.pp)?;
    let mut eng = Engine::new(dir)?;
    SimTrainer::new(cfg.clone(), &mut eng)?.run()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_stats_mib() {
        let c = CommStats { floats_sent: 1024 * 1024, ..Default::default() };
        assert!((c.mib_sent() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn run_trace_reexport_links() {
        // Compile-time check that RunTrace is reachable for TrainReport
        // consumers.
        let _t: RunTrace = RunTrace::default();
    }
}
