//! Distributed training driver — the Layer-3 coordination contribution.
//!
//! The paper's observation is architectural: FSDP, DiLoCo and NoLoCo run
//! the *same* inner loop and differ only in how replicas synchronize.
//! This module is shaped accordingly — one training core, three methods,
//! two executors:
//!
//! * [`TrainerCore`] — the single generic inner-loop driver. Owns the
//!   DP × PP grid walk with §3.1 random-permutation routing, Adam inner
//!   steps, eval cadence and the churn-driven live-set logic.
//! * [`SyncStrategy`] — what replicas exchange and how peer state folds
//!   into the outer optimizer: [`FsdpSync`] (per-step gradient
//!   all-reduce), [`DilocoSync`] (Nesterov outer step over an all-reduced
//!   outer gradient), [`NolocoSync`] (the Eq. 2–3 modified-Nesterov
//!   gossip step over random pairs — no collective, no global barrier).
//!   NoLoCo's pair draw is itself pluggable via [`PairingPolicy`]:
//!   [`UniformPairing`] (the paper's uniform draw) or
//!   [`BandwidthAwarePairing`] (intra-region-biased pairs on a WAN, with
//!   periodic uniform rounds preserving the mixing guarantee).
//!   [`StreamingSync`] (`--sync streaming`) layers Streaming-DiLoCo-style
//!   fragmented overlap on either outer flavor: the (Δ, φ) state splits
//!   into `outer.fragments` chunks, each offered at one boundary and
//!   folded at the next so the exchange hides behind the inner phase.
//!   [`AsyncGossipSync`] (`outer.staleness > 1`) generalizes the boundary
//!   into a bounded-staleness event-driven engine: per-replica boundary
//!   clocks ([`BoundaryClock`]), age-weighted admission of peer state up
//!   to `staleness − 1` boundaries old, and per-fragment partners
//!   (`--pairing per-fragment`); `staleness = 1` is the lockstep special
//!   case and routes through the gated / streaming paths untouched.
//! * [`Communicator`] — how payloads move: [`AccountingComm`] hands
//!   buffers over in memory and *accounts* the traffic (the deterministic
//!   harness behind every convergence experiment), [`FabricComm`] sends
//!   real tagged messages over the in-process [`crate::net::Fabric`]
//!   (latency injection, gossip timeouts, the blocking studies), and
//!   [`SocketComm`] runs the identical protocol over real TCP streams so
//!   N OS processes train together ([`SocketTrainer`], one per rank).
//!
//! [`SimTrainer`] and [`ThreadedTrainer`] are thin constructors over
//! `TrainerCore<AccountingComm>` (one core owning the whole grid) and
//! `TrainerCore<FabricComm>` (one core per worker thread). Both return
//! the same [`TrainReport`]. A new synchronization variant — streaming
//! overlap, decoupled momentum, a new pairing bias — is one new trait
//! impl, picked up by both executors at once.
//!
//! Elastic membership: a [`crate::net::ChurnSchedule`] drops / rejoins
//! whole DP columns mid-run. The strategy decides the response
//! ([`ChurnResponse`]): NoLoCo re-pairs over survivors (a rejoiner
//! bootstraps from a donor on the grid executor, or by absorbing a fresh
//! gossip peer's slow weights over the fabric); FSDP / DiLoCo abort —
//! their global all-reduce has no live-subset form (§5.3).
//!
//! All compute (fwd/bwd/Adam/outer updates) executes inside AOT-compiled
//! XLA artifacts; this module only moves buffers and decides who talks to
//! whom — exactly the paper's separation of concerns.

mod arena;
mod boundary;
mod checkpoint;
mod comm;
mod core;
mod exec;
mod par;
mod sim;
mod socket_exec;
mod state;
mod strategy;
mod streaming;
mod threaded;

pub use arena::FoldScratch;
pub use boundary::{
    fold_noloco_fused, fold_noloco_weighted, AsyncGossipSync, BoundaryClock, ThetaUpdate,
};
pub use checkpoint::{
    Checkpoint, CkptAssembler, CoreRecord, InflightRecord, LoaderCursor, OfferRecord,
    RankSnapshot, StrategyState, WorkerRecord,
};
pub use comm::{
    AccountingComm, BoundaryTag, Communicator, EndpointComm, FabricComm, FragView, SocketComm,
    Wire,
};
pub use self::core::TrainerCore;
pub use exec::{
    adam_step, bwd_first, bwd_full, bwd_last, bwd_mid, fwd_first, fwd_mid, init_stage,
    loss_full, loss_last, outer_diloco, outer_noloco, AdamScalars,
};
pub use par::{resolve_threads, ExecPool, PoolOut, PoolTask};
pub use sim::SimTrainer;
pub use socket_exec::{merge_rank_reports, MergedRun, RankReport, SocketTrainer};
pub use state::WorkerState;
pub use strategy::{
    for_config as strategy_for_config, BandwidthAwarePairing, ChurnResponse, CommPattern,
    DilocoSync, FsdpSync, NolocoSync, PairingPolicy, PerFragmentPairing, SyncStrategy,
    UniformPairing,
};
pub use streaming::{fold_noloco_fragment, FragmentSchedule, StreamingSync};
pub use threaded::ThreadedTrainer;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::metrics::RunTrace;
use crate::runtime::{find_build, Engine};

/// Communication accounting, unified across executors.
///
/// The *logical* counters (`floats_sent`, `activation_hops`,
/// `blocking_collectives`, `pair_exchanges`) keep the seed semantics:
/// training-path payload elements, counted once per hop / row collective
/// / symmetric pair. The *wire* counters (`bytes_sent`, `msgs_sent`)
/// meter everything shipped — tokens and validation traffic included —
/// and agree between executors: the grid executor models the same
/// messages the fabric actually sends (tree-edge collectives, eager
/// gossip pairs, per-boundary activations + tokens).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommStats {
    /// Total f32 payload elements shipped on the training path
    /// (activations + grads + sync).
    pub floats_sent: u64,
    /// Point-to-point activation/gradient hops between pipeline stages.
    pub activation_hops: u64,
    /// Globally blocking collectives issued (FSDP grad + DiLoCo outer
    /// all-reduces) — the operations NoLoCo eliminates.
    pub blocking_collectives: u64,
    /// NoLoCo gossip pair exchanges.
    pub pair_exchanges: u64,
    /// Total wire bytes shipped (all payload kinds).
    pub bytes_sent: u64,
    /// Total messages shipped.
    pub msgs_sent: u64,
}

impl CommStats {
    /// Wire payload in MiB. Falls back to the logical f32 counter (4
    /// bytes per element) when no wire metering happened — which keeps
    /// the value comparable across executors either way.
    pub fn mib_sent(&self) -> f64 {
        let bytes = if self.bytes_sent > 0 {
            self.bytes_sent as f64
        } else {
            self.floats_sent as f64 * 4.0
        };
        bytes / (1024.0 * 1024.0)
    }

    /// Fold another worker's counters into this one (threaded
    /// aggregation). The once-per-row / once-per-pair counting rules make
    /// the sum across workers equal the grid executor's totals.
    pub fn absorb(&mut self, other: &CommStats) {
        self.floats_sent += other.floats_sent;
        self.activation_hops += other.activation_hops;
        self.blocking_collectives += other.blocking_collectives;
        self.pair_exchanges += other.pair_exchanges;
        self.bytes_sent += other.bytes_sent;
        self.msgs_sent += other.msgs_sent;
    }
}

/// Result of a training run — one shape for both executors.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Final validation loss (mean NLL, nats).
    pub final_val_nll: f64,
    /// Final validation perplexity (Table 2's metric).
    pub final_val_ppl: f64,
    /// Per-eval-point series (loss / PPL / weight-σ / LR curves). The
    /// threaded executor reports NaN weight-σ (a worker cannot see its
    /// row peers).
    pub trace: RunTrace,
    /// Mean training loss per inner step (NaN for steps every reporting
    /// replica sat out under churn).
    pub step_train_loss: Vec<f64>,
    /// Communication accounting ([`CommStats`]).
    pub comm: CommStats,
    /// Wall-clock seconds.
    pub wall_secs: f64,
    /// PJRT executions issued (summed across worker engines).
    pub executions: u64,
    /// Which executor produced the report ("sim" / "threaded").
    pub executor: &'static str,
    /// Failure-detection transitions `(boundary, event)` observed by the
    /// heartbeat detector (`[churn] detect`); empty when detection is
    /// off or nothing failed. The threaded executor reports the union of
    /// worker observations, deduplicated.
    pub detected: Vec<(u64, crate::net::topo::ChurnEvent)>,
    /// Observability summary ([`crate::obs::ObsReport`]): counter
    /// registry, fold-age histogram and per-boundary breakdown. Default
    /// (all empty) when no `[obs]` sink was configured.
    pub obs: crate::obs::ObsReport,
}

impl TrainReport {
    /// The one place a report is assembled from its parts — both
    /// executors call this, so derived fields (`final_val_ppl`) can
    /// never drift between them.
    #[allow(clippy::too_many_arguments)]
    pub fn assemble(
        final_val_nll: f64,
        trace: RunTrace,
        step_train_loss: Vec<f64>,
        comm: CommStats,
        wall_secs: f64,
        executions: u64,
        executor: &'static str,
        detected: Vec<(u64, crate::net::topo::ChurnEvent)>,
        obs: crate::obs::ObsReport,
    ) -> TrainReport {
        TrainReport {
            final_val_nll,
            final_val_ppl: crate::metrics::perplexity(final_val_nll),
            trace,
            step_train_loss,
            comm,
            wall_secs,
            executions,
            executor,
            detected,
            obs,
        }
    }
}

/// Convenience: resolve artifacts, build an engine, run [`SimTrainer`].
///
/// Experiments comparing several configs over the *same* artifact build
/// should construct one [`Engine`] themselves and call
/// [`SimTrainer::new`] per run to amortize XLA compilation.
pub fn run_sim(cfg: &TrainConfig) -> Result<TrainReport> {
    use anyhow::Context;
    let dir = find_build(&cfg.artifacts_dir, &cfg.model.name, cfg.topology.pp)?;
    let mut eng = Engine::new(dir)?;
    let mut t = SimTrainer::new(cfg.clone(), &mut eng)?;
    if let Some(path) = &cfg.ckpt.resume {
        let ck = Checkpoint::load(path).with_context(|| format!("resuming from {path}"))?;
        t.resume_from(&ck)?;
    }
    t.run()
}

/// Convenience sibling of [`run_sim`]: run [`ThreadedTrainer`] (one OS
/// thread + engine per worker over the message fabric) and return the
/// same unified [`TrainReport`].
pub fn run_threaded(cfg: &TrainConfig) -> Result<TrainReport> {
    ThreadedTrainer::new(cfg.clone()).run()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_stats_mib_prefers_wire_bytes() {
        let c = CommStats { bytes_sent: 4 * 1024 * 1024, ..Default::default() };
        assert!((c.mib_sent() - 4.0).abs() < 1e-12);
        // Logical fallback when no wire metering happened.
        let c = CommStats { floats_sent: 1024 * 1024, ..Default::default() };
        assert!((c.mib_sent() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn comm_stats_absorb_sums_fields() {
        let mut a = CommStats {
            floats_sent: 1,
            activation_hops: 2,
            blocking_collectives: 3,
            pair_exchanges: 4,
            bytes_sent: 5,
            msgs_sent: 6,
        };
        a.absorb(&a.clone());
        assert_eq!(
            a,
            CommStats {
                floats_sent: 2,
                activation_hops: 4,
                blocking_collectives: 6,
                pair_exchanges: 8,
                bytes_sent: 10,
                msgs_sent: 12,
            }
        );
    }

    #[test]
    fn run_trace_reexport_links() {
        // Compile-time check that RunTrace is reachable for TrainReport
        // consumers.
        let _t: RunTrace = RunTrace::default();
    }
}
