//! The generic inner-loop driver both executors share.
//!
//! [`TrainerCore`] owns everything the paper's three methods have in
//! common — the DP × PP grid walk with §3.1 random-permutation routing,
//! microbatch accumulation, Adam inner steps, eval cadence, and the
//! churn-driven live-set logic — parameterized by a
//! [`Communicator`] (how payloads move: in-memory accounting vs. real
//! fabric messages) and a [`SyncStrategy`](super::SyncStrategy) (what
//! replicas exchange at each synchronization point).
//!
//! One core instance owns a *set of workers*:
//!
//! * the grid executor ([`SimTrainer`](super::SimTrainer)) owns the whole
//!   `dp × pp` grid, stage-major, over one shared engine;
//! * each threaded worker ([`ThreadedTrainer`](super::ThreadedTrainer))
//!   owns exactly one worker over its private engine.
//!
//! The walk is written SPMD from the worker's point of view: every
//! owned worker on a live path receives its boundary payloads, computes,
//! and sends onward. On the mailbox communicator the forward sweep visits
//! stages in ascending order (and the backward sweep in descending
//! order), so every producer runs before its consumer; on the fabric the
//! same code blocks on tagged receives exactly like the seed's
//! per-worker loop.
//!
//! Determinism: route plans, gossip groups and live sets all derive from
//! `(seed, step)` and the shared churn schedule, never from execution
//! order, so the grid executor reproduces the seed trajectories
//! bit-for-bit and threaded workers agree without coordination traffic.

// `expect` discipline: the remaining expects document executor
// invariants established earlier in the same function (`checked
// above`, `armed above`, grid ownership). A violation is a driver bug
// and must crash loudly, not be papered over.
#![allow(clippy::expect_used)]

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::config::TrainConfig;
use crate::data::Loader;
use crate::metrics::RunTrace;
use crate::model::StageKind;
use crate::net::topo::{ChurnEvent, FailureDetector};
use crate::obs::{Event, ObsHub};
use crate::optim::LrSchedule;
use crate::routing::RoutePlan;
use crate::runtime::{Engine, Manifest};
use crate::tensor::Tensor;

use super::checkpoint::{Checkpoint, CkptAssembler, CoreRecord, LoaderCursor, RankSnapshot, WorkerRecord};
use super::comm::{BoundaryTag, Communicator, Wire, K_ACT, K_GRD, K_TOK, K_VACT, K_VTOK};
use super::exec::{self, AdamScalars};
use super::par::{ExecPool, PoolOut, PoolTask};
use super::state::WorkerState;
use super::strategy::{self, ChurnResponse, SyncStrategy};
use super::TrainReport;

/// The shared DP × PP training driver. See the module docs.
pub struct TrainerCore<'e, C: Communicator> {
    cfg: TrainConfig,
    eng: &'e mut Engine,
    man: Manifest,
    comm: C,
    strategy: Box<dyn SyncStrategy>,
    /// Locally-owned workers: the whole grid (stage-major,
    /// `stage * dp + replica`) for the grid executor, exactly one for a
    /// threaded worker.
    workers: Vec<WorkerState>,
    /// Training loaders for locally-owned stage-0 columns, by replica.
    loaders: Vec<(usize, Loader)>,
    /// Pre-drawn validation batches (same stream on every replica); empty
    /// for owned workers that never touch validation tokens directly.
    val_batches: Vec<Vec<i32>>,
    /// Validation batches per eval point (agreed across all workers).
    n_val: usize,
    lr: LrSchedule,
    trace: RunTrace,
    /// Microbatch waves per replica per step.
    num_mb: usize,
    /// Live mask over DP columns, driven by the churn schedule and (when
    /// detection is on) the heartbeat failure detector.
    live: Vec<bool>,
    /// Per-step mean training loss observed at owned last-stage workers
    /// (NaN for steps the own column sat out).
    step_train_loss: Vec<f64>,
    /// Per-replica boundary clocks: outer boundaries each replica
    /// participated in (advanced for live replicas at every boundary
    /// this core drives). The async engine derives the same clocks from
    /// the shared schedule; these are the core's ground truth.
    clocks: Vec<u64>,
    /// Heartbeat failure detector (`[churn] detect`); `None` when
    /// detection is off.
    detector: Option<FailureDetector>,
    /// Replicas removed by *detection* (as opposed to the schedule):
    /// alive-but-partitioned from this core's view, still expected to
    /// heartbeat again.
    suspected: Vec<bool>,
    /// Detection transitions observed: `(boundary, event)`.
    detected: Vec<(u64, ChurnEvent)>,
    /// Fault injection for detection tests: `(replica, from_step,
    /// until_step)`. On the grid executor the replica's heartbeats are
    /// suppressed over `[from, until)`; a single-worker executor owning
    /// the replica crashes outright at `from`.
    silence: Option<(usize, u64, u64)>,
    /// Whether this core's worker crashed mid-run (silence fault on a
    /// single-worker executor): skip the end-of-run drain.
    crashed: bool,
    /// Observability sink: built from `[obs]` by the grid executor,
    /// attached post-construction by the threaded trainer (one shared
    /// hub per run), disabled otherwise.
    obs: ObsHub,
    /// Wire totals `(bytes, msgs)` at the last boundary capture — the
    /// reference for per-boundary delta attribution.
    last_wire: (u64, u64),
    /// Inner-phase seconds accumulated since the last boundary capture.
    inner_accum: f64,
    /// Auto-checkpoint cadence in outer boundaries (`[ckpt] every`);
    /// 0 disables the cadence.
    ckpt_every: u64,
    /// Grid executor: the file the cadence writes (atomically).
    ckpt_out: Option<PathBuf>,
    /// Threaded executor: the shared coordinator every rank submits its
    /// [`RankSnapshot`] to; the rank completing a boundary's set writes
    /// the merged file.
    ckpt_sink: Option<Arc<CkptAssembler>>,
    /// Kill-restart drills: stop right after the checkpoint at this
    /// boundary is written — the run "crashes" at the cut (no drain).
    halt_after: Option<u64>,
    /// First inner step the run loop executes (a resume continues at the
    /// checkpoint's step).
    start_step: usize,
    /// Whether the run stopped at `halt_after` (skip the drain, exactly
    /// like a crash).
    halted: bool,
    /// Parallel inner-phase worker pool (`[perf] threads`): grid
    /// executor with `pp = 1` only — deeper pipelines route waves across
    /// DP columns mid-step, so their walk stays serial. Results are
    /// applied in the exact serial order, keeping any thread count
    /// bit-identical to `None` (the serial walk).
    pool: Option<ExecPool>,
    /// Pool engine executions already attributed to a finished report.
    pool_exec0: u64,
}

fn draw_val_batches(cfg: &TrainConfig, man: &Manifest, n: usize) -> Vec<Vec<i32>> {
    let mut val_loader = Loader::validation(
        cfg.dataset,
        cfg.model.vocab,
        cfg.seed ^ 0x5eed,
        cfg.model.seq_len,
        man.mb,
    );
    (0..n)
        .map(|_| {
            val_loader
                .next_batch()
                .tokens
                .iter()
                .map(|&t| t as i32)
                .collect()
        })
        .collect()
}

/// Which live origin replica's path crosses `(stage, me)` under `plan`.
fn origin_through(plan: &RoutePlan, stage: usize, me: usize, live: &[usize]) -> usize {
    for &r0 in live {
        if plan.path_from(r0)[stage] == me {
            return r0;
        }
    }
    unreachable!("live permutation routing covers every live replica");
}

impl<'e, C: Communicator> TrainerCore<'e, C> {
    /// Grid executor: own every worker of the DP × PP grid over one
    /// shared engine, with identical per-stage init across replicas
    /// (φ₀,ᵢ ≡ φ₀), sharded loaders and a pre-drawn validation set.
    pub fn new_grid(cfg: TrainConfig, eng: &'e mut Engine, mut comm: C) -> Result<Self> {
        cfg.validate().map_err(anyhow::Error::msg)?;
        let man = eng.manifest()?;
        man.check_against(&cfg.model, cfg.topology.pp)?;
        let (dp, pp) = (cfg.topology.dp, cfg.topology.pp);

        // Per-replica microbatching: the global batch is split across DP,
        // then walked in manifest-sized microbatches.
        let per_replica_seqs = (cfg.model.batch_tokens / cfg.model.seq_len / dp).max(1);
        ensure!(
            per_replica_seqs >= man.mb,
            "per-replica batch ({per_replica_seqs} seqs) smaller than artifact microbatch ({}); \
             lower dp or rebuild artifacts with a smaller mb",
            man.mb
        );
        let num_mb = per_replica_seqs / man.mb;

        // Shared init per stage: seed depends on the stage only.
        let mut workers = Vec::with_capacity(dp * pp);
        for s in 0..pp {
            let kind = StageKind::of_stage(s, pp);
            let init = exec::init_stage(eng, kind, (cfg.seed as i32) ^ (s as i32 * 7901))
                .with_context(|| format!("initializing stage {s}"))?;
            for r in 0..dp {
                workers.push(WorkerState::new(s, r, kind, init.clone(), cfg.outer.method));
            }
        }
        let loaders: Vec<(usize, Loader)> = (0..dp)
            .map(|r| {
                (
                    r,
                    Loader::train(
                        cfg.dataset,
                        cfg.model.vocab,
                        cfg.seed,
                        r,
                        dp,
                        cfg.model.seq_len,
                        num_mb * man.mb,
                    ),
                )
            })
            .collect();

        let val_seqs = (cfg.eval_tokens / cfg.model.seq_len).max(man.mb);
        let n_val = (val_seqs / man.mb).max(1);
        let val_batches = draw_val_batches(&cfg, &man, n_val);
        let lr = LrSchedule {
            peak: cfg.model.inner_lr,
            warmup: cfg.warmup,
            total: cfg.steps,
            floor_frac: cfg.lr_floor,
        };
        let strategy = strategy::for_config(&cfg);
        let detector = cfg
            .detect
            .enabled
            .then(|| FailureDetector::new(dp, cfg.detect.misses));
        let obs = ObsHub::from_config(&cfg.obs)?;
        comm.set_obs(obs.clone());
        // The pool parallelizes the pp = 1 inner phase only: deeper
        // pipelines route every wave across DP columns mid-step, so the
        // serial grid walk stays authoritative there.
        let pool = (cfg.perf.parallel_requested() && pp == 1).then(|| {
            ExecPool::new(cfg.perf.threads, dp, eng.dir().to_path_buf(), man.clone())
        });
        Ok(TrainerCore {
            live: vec![true; dp],
            ckpt_every: cfg.ckpt.every as u64,
            ckpt_out: cfg.ckpt.out.as_ref().map(PathBuf::from),
            cfg,
            eng,
            man,
            comm,
            strategy,
            workers,
            loaders,
            val_batches,
            n_val,
            lr,
            trace: RunTrace::default(),
            num_mb,
            step_train_loss: Vec::new(),
            clocks: vec![0; dp],
            detector,
            suspected: vec![false; dp],
            detected: Vec::new(),
            silence: None,
            crashed: false,
            obs,
            last_wire: (0, 0),
            inner_accum: 0.0,
            ckpt_sink: None,
            halt_after: None,
            start_step: 0,
            halted: false,
            pool,
            pool_exec0: 0,
        })
    }

    /// Threaded worker executor: own exactly `(stage, replica)` over this
    /// worker's private engine. `num_mb` and `n_val` are computed once by
    /// the spawning trainer so every worker agrees on the wave and eval
    /// schedule.
    #[allow(clippy::too_many_arguments)]
    pub fn new_single(
        cfg: TrainConfig,
        eng: &'e mut Engine,
        comm: C,
        man: Manifest,
        stage: usize,
        replica: usize,
        num_mb: usize,
        n_val: usize,
    ) -> Result<Self> {
        cfg.validate().map_err(anyhow::Error::msg)?;
        let (dp, pp) = (cfg.topology.dp, cfg.topology.pp);
        ensure!(stage < pp && replica < dp, "worker ({stage}, {replica}) outside the grid");
        let kind = StageKind::of_stage(stage, pp);
        let init = exec::init_stage(eng, kind, (cfg.seed as i32) ^ (stage as i32 * 7901))
            .with_context(|| format!("initializing stage {stage}"))?;
        let workers = vec![WorkerState::new(stage, replica, kind, init, cfg.outer.method)];
        let loaders = if stage == 0 {
            vec![(
                replica,
                Loader::train(
                    cfg.dataset,
                    cfg.model.vocab,
                    cfg.seed,
                    replica,
                    dp,
                    cfg.model.seq_len,
                    num_mb * man.mb,
                ),
            )]
        } else {
            Vec::new()
        };
        // Only workers that feed tokens into a pipeline draw the shared
        // validation stream; interior/last stages receive tokens over the
        // boundary channel.
        let val_batches = if n_val > 0 && (stage == 0 || pp == 1) {
            draw_val_batches(&cfg, &man, n_val)
        } else {
            Vec::new()
        };
        let lr = LrSchedule {
            peak: cfg.model.inner_lr,
            warmup: cfg.warmup,
            total: cfg.steps,
            floor_frac: cfg.lr_floor,
        };
        let strategy = strategy::for_config(&cfg);
        let detector = cfg
            .detect
            .enabled
            .then(|| FailureDetector::new(dp, cfg.detect.misses));
        Ok(TrainerCore {
            live: vec![true; dp],
            ckpt_every: cfg.ckpt.every as u64,
            cfg,
            eng,
            man,
            comm,
            strategy,
            workers,
            loaders,
            val_batches,
            n_val,
            lr,
            trace: RunTrace::default(),
            num_mb,
            step_train_loss: Vec::new(),
            clocks: vec![0; dp],
            detector,
            suspected: vec![false; dp],
            detected: Vec::new(),
            silence: None,
            crashed: false,
            obs: ObsHub::disabled(),
            last_wire: (0, 0),
            inner_accum: 0.0,
            ckpt_out: None,
            ckpt_sink: None,
            halt_after: None,
            start_step: 0,
            halted: false,
            // A threaded worker is already one thread of a pool-of-ranks;
            // its single-worker inner phase has nothing to fan out.
            pool: None,
            pool_exec0: 0,
        })
    }

    fn dp(&self) -> usize {
        self.cfg.topology.dp
    }

    fn pp(&self) -> usize {
        self.cfg.topology.pp
    }

    /// Whether this core owns the whole grid (the grid executor).
    pub fn owns_grid(&self) -> bool {
        self.workers.len() == self.dp() * self.pp()
    }

    fn owns_last_stage(&self) -> bool {
        let pp = self.pp();
        self.workers.iter().any(|w| w.stage + 1 == pp)
    }

    fn widx(&self, stage: usize, replica: usize) -> usize {
        debug_assert!(self.owns_grid());
        stage * self.dp() + replica
    }

    /// Currently live DP replicas, ascending.
    pub fn live_replicas(&self) -> Vec<usize> {
        (0..self.dp()).filter(|&r| self.live[r]).collect()
    }

    /// Per-replica boundary clocks: boundaries each replica participated
    /// in so far (see the async boundary engine,
    /// [`BoundaryClock`](super::BoundaryClock)).
    pub fn boundary_clocks(&self) -> &[u64] {
        &self.clocks
    }

    /// Detection transitions observed so far: `(boundary, event)`.
    /// Empty when `[churn] detect` is off or nothing failed.
    pub fn detected_events(&self) -> &[(u64, ChurnEvent)] {
        &self.detected
    }

    /// Fault injection for failure-detection tests: silence `replica`
    /// over inner steps `[from, until)`. On the grid executor the
    /// replica keeps existing but stops heartbeating (a network
    /// partition); a single-worker executor owning the replica crashes
    /// outright at `from` (and `until` is ignored). Detection then has
    /// to *infer* the failure — there is no schedule entry.
    pub fn set_silence(&mut self, replica: usize, from_step: u64, until_step: u64) {
        self.silence = Some((replica, from_step, until_step));
    }

    /// Attach the threaded executor's checkpoint coordinator: every rank
    /// submits its [`RankSnapshot`] here when the `[ckpt]` cadence fires
    /// and the rank completing a boundary's set writes the merged file.
    pub fn set_ckpt_sink(&mut self, sink: Arc<CkptAssembler>) {
        self.ckpt_sink = Some(sink);
    }

    /// Kill-restart drills: stop right after the checkpoint at
    /// `boundary` is written — no drain, no further steps, exactly the
    /// state a crash at the cut would leave behind.
    pub fn set_halt_after(&mut self, boundary: u64) {
        self.halt_after = Some(boundary);
    }

    /// Whether DP replica `r` is currently live.
    pub fn is_live(&self, r: usize) -> bool {
        self.live[r]
    }

    /// The manifest this core is bound to.
    pub fn manifest(&self) -> &Manifest {
        &self.man
    }

    /// Communication accounting so far.
    pub fn comm_stats(&self) -> &super::CommStats {
        self.comm.stats()
    }

    /// The communicator itself — the socket executor reads its wire
    /// totals and per-peer transport counters after the run.
    pub fn communicator(&self) -> &C {
        &self.comm
    }

    /// Attach an observability hub after construction: the threaded
    /// trainer builds one shared hub per run and clones it into every
    /// worker core (and its communicator), so all workers journal into
    /// the same sink. The grid executor builds its own from `[obs]`.
    pub fn set_obs(&mut self, hub: ObsHub) {
        self.comm.set_obs(hub.clone());
        self.obs = hub;
    }

    /// This core's observability hub (disabled unless configured).
    pub fn obs(&self) -> &ObsHub {
        &self.obs
    }

    /// Immutable access to an owned worker (tests / inspection).
    pub fn worker(&self, stage: usize, replica: usize) -> &WorkerState {
        self.workers
            .iter()
            .find(|w| w.stage == stage && w.replica == replica)
            .expect("worker not owned by this executor")
    }

    /// All owned workers (stage-major for the grid executor).
    pub fn workers(&self) -> &[WorkerState] {
        &self.workers
    }

    /// Mutable access for checkpoint restore.
    pub(crate) fn workers_mut(&mut self) -> &mut [WorkerState] {
        &mut self.workers
    }

    /// Apply one membership event (a whole DP column across all stages).
    ///
    /// The configured [`SyncStrategy`](super::SyncStrategy) decides the
    /// response: gossip methods repair (re-pair over survivors, bootstrap
    /// a joiner), collective methods abort — their world-wide all-reduce
    /// has no live-subset form, which is the measurable shape of the
    /// paper's no-global-barrier claim (§5.3).
    pub fn apply_churn(&mut self, event: ChurnEvent) -> Result<()> {
        ensure!(
            matches!(self.strategy.churn_response(), ChurnResponse::Repair),
            "{} cannot change membership mid-run: its global all-reduce has no \
             live-subset form; only NoLoCo's gossip re-pairs over survivors ({event:?})",
            self.cfg.outer.method
        );
        let r = event.node();
        ensure!(r < self.dp(), "churn event for replica {r} outside dp = {}", self.dp());
        match event {
            ChurnEvent::Leave(_) => {
                self.live[r] = false;
                ensure!(self.live.iter().any(|&l| l), "all replicas left the run");
            }
            ChurnEvent::Join(_) => {
                if !self.live[r] {
                    self.live[r] = true;
                    if self.comm.supports_join_bootstrap() && self.owns_grid() {
                        self.reseed_replica(r);
                    }
                }
            }
        }
        Ok(())
    }

    /// Bootstrap a joining replica: copy the slow weights φ from the
    /// lowest live donor in each stage row (the freshest consensus state),
    /// reset θ to φ and zero the Adam moments and outer momentum. Without
    /// a donor (solo rejoin) the replica resumes from its own last state.
    /// Grid executor only; message-passing joiners catch up through their
    /// first gossip exchange instead (see the NoLoCo strategy).
    fn reseed_replica(&mut self, r: usize) {
        let dp = self.dp();
        let donor = (0..dp).find(|&d| d != r && self.live[d]);
        for s in 0..self.pp() {
            let i = self.widx(s, r);
            if let Some(d) = donor {
                let phi = self.workers[self.widx(s, d)].phi.clone();
                self.workers[i].phi = phi;
            }
            let w = &mut self.workers[i];
            let n = w.len();
            w.reset_theta_to_phi();
            w.m = vec![0.0; n];
            w.v = vec![0.0; n];
            w.adam_t = 0;
            w.delta = vec![0.0; n];
            w.grad_acc = vec![0.0; n];
            w.acc_count = 0;
        }
    }

    /// Run the configured number of inner steps; returns the report.
    pub fn run(&mut self) -> Result<TrainReport> {
        // analyze: wall-clock-ok — report-envelope timing only; never
        // feeds the trajectory, losses, or CommStats.
        let start = Instant::now();
        let exec0 = self.eng.executions();
        self.pool_exec0 = self.pool.as_ref().map_or(0, ExecPool::executions);
        // A resumed run starts from the checkpoint's restored trace: the
        // final report's val loss must survive a resume that never evals
        // again.
        let mut last_val = self
            .trace
            .val_loss
            .iter()
            .rev()
            .copied()
            .find(|v| v.is_finite())
            .unwrap_or(f64::NAN);
        for step in self.start_step..self.cfg.steps {
            // A crash fault on a single-worker executor: the worker stops
            // outright — no more compute, messages or heartbeats. Its
            // peers must *detect* the failure; nothing announces it.
            if let Some((r, from, _)) = self.silence {
                if !self.owns_grid() && self.workers[0].replica == r && step as u64 >= from {
                    self.crashed = true;
                    break;
                }
            }
            let due: Vec<ChurnEvent> = self.cfg.churn.events_at(step as u64).collect();
            for event in due {
                self.apply_churn(event)?;
                self.obs.record(
                    step as u64,
                    Event::ChurnApplied {
                        step: step as u64,
                        node: event.node(),
                        join: matches!(event, ChurnEvent::Join(_)),
                    },
                );
            }
            // A single-worker executor whose column is dead sits the step
            // out entirely: no data, no compute, no messages.
            if !self.owns_grid() && !self.live[self.workers[0].replica] {
                if self.owns_last_stage() {
                    self.step_train_loss.push(f64::NAN); // excluded from means
                }
                // A dead column still contributes its rank snapshot when
                // the cadence fires: the assembler needs all dp·pp ranks,
                // and the column's checkpointed state is exactly what a
                // resume must recreate (sitting the run out).
                if self.maybe_checkpoint(step)? {
                    self.halted = true;
                    break;
                }
                continue;
            }
            // analyze: wall-clock-ok — journaled inner-phase duration;
            // observability only, never read back by training.
            let t_inner = Instant::now();
            let train_loss = self.inner_step(step)?;
            let dur_s = t_inner.elapsed().as_secs_f64();
            self.inner_accum += dur_s;
            if self.owns_last_stage() {
                self.step_train_loss.push(train_loss);
            }
            if self.obs.is_enabled() {
                let pp = self.pp();
                for w in &self.workers {
                    if w.stage + 1 == pp && self.live[w.replica] {
                        self.obs.record(
                            step as u64,
                            Event::InnerPhase {
                                stage: w.stage,
                                replica: w.replica,
                                step: step as u64,
                                loss: train_loss,
                                dur_s,
                            },
                        );
                    }
                }
            }
            let outer_due =
                self.strategy.has_outer() && (step + 1) % self.cfg.outer.inner_steps == 0;
            if outer_due {
                self.outer_step(((step + 1) / self.cfg.outer.inner_steps) as u64)?;
            }
            let eval_due = self.cfg.eval_every > 0 && (step + 1) % self.cfg.eval_every == 0;
            if (eval_due || step + 1 == self.cfg.steps) && self.n_val > 0 {
                let val = self.validate_at(step)?;
                if self.owns_last_stage() {
                    last_val = val;
                    let wstd = self.weight_std();
                    self.trace
                        .push(step + 1, train_loss, val, wstd, self.lr.at(step));
                }
            }
            // The cadence cuts *after* everything the step does — outer
            // fold and eval included — so the snapshot is a true prefix
            // of the uninterrupted trajectory (eval traffic is already in
            // the accounting) and a resume continues at `step + 1`.
            if self.maybe_checkpoint(step)? {
                self.halted = true;
                break;
            }
        }
        // Streamed overlap leaves the final boundary's fragment in
        // flight; drain it so the finishing (φ, θ) include every offered
        // exchange (no-op for gated strategies). The last eval above ran
        // before this fold, mirroring a real deployment where the tail
        // fragment lands after the final report. A crashed or
        // drill-halted worker drains nothing — it is gone.
        let final_outer = (self.cfg.steps / self.cfg.outer.inner_steps) as u64;
        if !self.crashed && !self.halted {
            let live = self.live_replicas();
            let TrainerCore { comm, strategy, workers, live: live_mask, .. } = self;
            for w in workers.iter_mut() {
                if live_mask[w.replica] {
                    strategy.drain(comm, w, &live, final_outer)?;
                }
            }
        }
        // The residual wire delta past the last boundary capture (final
        // in-flight folds, validation shipping) closes the attribution
        // invariant: Σ boundary bytes + drain bytes == comm totals.
        if self.obs.is_enabled() {
            let (b, m) = self.comm.wire_totals();
            let (b0, m0) = self.last_wire;
            self.last_wire = (b, m);
            self.obs.record(
                self.cfg.steps as u64,
                Event::Drain {
                    outer_idx: final_outer,
                    bytes: b.saturating_sub(b0),
                    msgs: m.saturating_sub(m0),
                },
            );
            self.strategy.report_obs(&self.obs);
            let loss = self.last_finite_loss();
            let sigma = self.weight_std();
            self.obs
                .snapshot_metrics(self.cfg.steps as u64, final_outer, loss, sigma, b, m);
        }
        Ok(TrainReport::assemble(
            last_val,
            std::mem::take(&mut self.trace),
            std::mem::take(&mut self.step_train_loss),
            self.comm.stats().clone(),
            start.elapsed().as_secs_f64(),
            self.eng.executions() - exec0
                + self.pool.as_ref().map_or(0, ExecPool::executions)
                - self.pool_exec0,
            self.comm.executor(),
            self.detected.clone(),
            self.obs.report(),
        ))
    }

    /// Most recent finite per-step training loss (NaN when none yet) —
    /// the "current loss" a live metrics snapshot reports.
    fn last_finite_loss(&self) -> f64 {
        self.step_train_loss
            .iter()
            .rev()
            .find(|l| l.is_finite())
            .copied()
            .unwrap_or(f64::NAN)
    }

    /// One inner optimizer step: route + fwd/bwd every owned worker's
    /// microbatch waves, sync gradients through the strategy (FSDP), then
    /// Adam on every owned live worker. Returns the mean training loss
    /// over the losses observed at owned last-stage workers.
    // Index loops are deliberate: the walk interleaves `&mut self.comm`
    // and `&mut self.eng` with worker access, which iterator forms of
    // `self.workers` would lock out.
    #[allow(clippy::needless_range_loop, clippy::type_complexity)]
    pub fn inner_step(&mut self, step: usize) -> Result<f64> {
        let (dp, pp) = (self.dp(), self.pp());
        let num_mb = self.num_mb;
        let mb_toks = self.man.mb * self.man.seq_len;
        let live = self.live_replicas();

        // Draw this step's batches for locally-owned live stage-0 columns.
        let mut batches: Vec<Option<Vec<i32>>> = vec![None; dp];
        {
            let TrainerCore { loaders, live: live_mask, .. } = self;
            for (r, loader) in loaders.iter_mut() {
                if live_mask[*r] {
                    batches[*r] = Some(
                        loader
                            .next_batch()
                            .tokens
                            .iter()
                            .map(|&t| t as i32)
                            .collect(),
                    );
                }
            }
        }

        // Losses indexed [wave][origin] so the final fold reproduces the
        // seed's wave-major, ascending-origin accumulation order exactly.
        let mut losses: Vec<Vec<Option<f64>>> = vec![vec![None; dp]; num_mb];
        // Backward stash: (local worker, wave, origin, x_in, toks).
        let mut stash: Vec<(usize, u32, usize, Vec<f32>, Vec<i32>)> = Vec::new();

        // ---- parallel pp = 1 fan-out (`[perf] threads`) ----
        // Between boundaries every pp = 1 replica's waves depend only on
        // its own (θ, tokens), so they dispatch to the pool as a batch
        // and the results fold in the exact serial order below — the
        // trajectory is bit-identical to the serial walk at any thread
        // count.
        let pooled = pp == 1 && self.pool.is_some();
        if pooled {
            self.pooled_full_waves(&batches, &mut losses)?;
        }

        // ---- forward sweep (the last stage also runs its backward) ----
        for mb in 0..num_mb {
            if pooled {
                break; // waves already computed and folded via the pool
            }
            let wave = (step * num_mb + mb) as u64;
            let wave32 = wave as u32;
            let plan = RoutePlan::for_step_over(
                self.cfg.routing,
                &live,
                dp,
                pp,
                self.cfg.seed ^ 0x0a17,
                wave,
            );
            for li in 0..self.workers.len() {
                let (s, q) = (self.workers[li].stage, self.workers[li].replica);
                if !self.live[q] {
                    continue;
                }
                if pp == 1 {
                    let batch = batches[q].as_ref().expect("live stage-0 column has a batch");
                    let toks = &batch[mb * mb_toks..(mb + 1) * mb_toks];
                    let (loss, g) =
                        exec::bwd_full(self.eng, &self.man, &self.workers[li].theta, toks)?;
                    self.workers[li].accumulate(&g);
                    losses[mb][q] = Some(loss as f64);
                } else if s == 0 {
                    let batch = batches[q].as_ref().expect("live stage-0 column has a batch");
                    let toks = batch[mb * mb_toks..(mb + 1) * mb_toks].to_vec();
                    let x =
                        exec::fwd_first(self.eng, &self.man, &self.workers[li].theta, &toks)?;
                    let nxt = (1, plan.next_of(0, q));
                    self.comm
                        .send_boundary(nxt, BoundaryTag::new(K_ACT, wave32, q as u32), Wire::F32(x))?;
                    self.comm.send_boundary(
                        nxt,
                        BoundaryTag::new(K_TOK, wave32, q as u32),
                        Wire::I32(toks.clone()),
                    )?;
                    stash.push((li, wave32, q, Vec::new(), toks));
                } else {
                    let r0 = origin_through(&plan, s, q, &live);
                    let act = self
                        .comm
                        .recv_boundary((s, q), BoundaryTag::new(K_ACT, wave32, r0 as u32))?
                        .into_f32();
                    let toks = self
                        .comm
                        .recv_boundary((s, q), BoundaryTag::new(K_TOK, wave32, r0 as u32))?
                        .into_i32();
                    if s == pp - 1 {
                        let (loss, g_theta, gx) = exec::bwd_last(
                            self.eng,
                            &self.man,
                            &self.workers[li].theta,
                            &act,
                            &toks,
                        )?;
                        self.workers[li].accumulate(&g_theta);
                        losses[mb][r0] = Some(loss as f64);
                        let prv = (s - 1, plan.prev_of(s, q));
                        self.comm.send_boundary(
                            prv,
                            BoundaryTag::new(K_GRD, wave32, r0 as u32),
                            Wire::F32(gx),
                        )?;
                    } else {
                        let x =
                            exec::fwd_mid(self.eng, &self.man, &self.workers[li].theta, &act)?;
                        let nxt = (s + 1, plan.next_of(s, q));
                        self.comm.send_boundary(
                            nxt,
                            BoundaryTag::new(K_ACT, wave32, r0 as u32),
                            Wire::F32(x),
                        )?;
                        self.comm.send_boundary(
                            nxt,
                            BoundaryTag::new(K_TOK, wave32, r0 as u32),
                            Wire::I32(toks.clone()),
                        )?;
                        stash.push((li, wave32, r0, act, toks));
                    }
                }
            }
        }

        // ---- backward sweep (first / mid stages drain gradients) ----
        if pp > 1 {
            // Wave-ascending, deeper stages first, so the mailbox executor
            // produces every gradient before its consumer reads it.
            stash.sort_by_key(|&(li, wave, _, _, _)| {
                (wave, std::cmp::Reverse(self.workers[li].stage))
            });
            // The stash is wave-major, so one plan derivation serves every
            // stage of a wave.
            let mut cached: Option<(u32, RoutePlan)> = None;
            for (li, wave32, r0, x_in, toks) in stash {
                let (s, q) = (self.workers[li].stage, self.workers[li].replica);
                if cached.as_ref().map(|(w, _)| *w) != Some(wave32) {
                    let plan = RoutePlan::for_step_over(
                        self.cfg.routing,
                        &live,
                        dp,
                        pp,
                        self.cfg.seed ^ 0x0a17,
                        wave32 as u64,
                    );
                    cached = Some((wave32, plan));
                }
                let plan = &cached.as_ref().expect("plan cached above").1;
                let g_out = self
                    .comm
                    .recv_boundary((s, q), BoundaryTag::new(K_GRD, wave32, r0 as u32))?
                    .into_f32();
                if s == 0 {
                    let g = exec::bwd_first(
                        self.eng,
                        &self.man,
                        &self.workers[li].theta,
                        &toks,
                        &g_out,
                    )?;
                    self.workers[li].accumulate(&g);
                } else {
                    let (g, gx) = exec::bwd_mid(
                        self.eng,
                        &self.man,
                        &self.workers[li].theta,
                        &x_in,
                        &g_out,
                    )?;
                    self.workers[li].accumulate(&g);
                    let prv = (s - 1, plan.prev_of(s, q));
                    self.comm.send_boundary(
                        prv,
                        BoundaryTag::new(K_GRD, wave32, r0 as u32),
                        Wire::F32(gx),
                    )?;
                }
            }
        }

        // ---- strategy-owned gradient synchronization (FSDP) ----
        let step64 = step as u64;
        {
            let TrainerCore { comm, strategy, workers, live: live_mask, .. } = self;
            for w in workers.iter() {
                if live_mask[w.replica] {
                    strategy.offer_grads(comm, w, &live, step64)?;
                }
            }
            for w in workers.iter_mut() {
                if live_mask[w.replica] {
                    strategy.sync_grads(comm, w, &live, step64)?;
                }
            }
        }

        // ---- inner optimizer ----
        let lr_now = self.lr.at(step);
        if pooled {
            self.pooled_adam(lr_now)?;
        } else {
            for li in 0..self.workers.len() {
                if !self.live[self.workers[li].replica] {
                    continue; // dead column: no gradients, no update
                }
                let g = self.workers[li].take_mean_grad();
                let w = &mut self.workers[li];
                w.adam_t += 1;
                let sc = AdamScalars::at(lr_now, w.adam_t, self.cfg.grad_clip);
                let (kind, mut theta, mut m, mut v) = (
                    w.kind,
                    std::mem::take(&mut w.theta),
                    std::mem::take(&mut w.m),
                    std::mem::take(&mut w.v),
                );
                exec::adam_step(self.eng, kind, &mut theta, &mut m, &mut v, &g, sc)?;
                let w = &mut self.workers[li];
                w.theta = theta;
                w.m = m;
                w.v = v;
                w.recycle_grad(g);
            }
        }

        // Mean training loss in the seed's accumulation order.
        let mut loss_sum = 0.0;
        let mut loss_n = 0usize;
        for wave in &losses {
            for &r in &live {
                if let Some(l) = wave[r] {
                    loss_sum += l;
                    loss_n += 1;
                }
            }
        }
        Ok(loss_sum / loss_n.max(1) as f64)
    }

    /// Fan one step's `pp = 1` microbatch waves over the pool and fold
    /// the results in the exact serial order (wave-major, ascending
    /// worker index), so gradient accumulation sees the same f32
    /// addition order — and therefore the same bits — as the serial
    /// walk.
    fn pooled_full_waves(
        &mut self,
        batches: &[Option<Vec<i32>>],
        losses: &mut [Vec<Option<f64>>],
    ) -> Result<()> {
        let num_mb = self.num_mb;
        let mb_toks = self.man.mb * self.man.seq_len;
        // One shared θ snapshot per live worker: the waves of a step all
        // read the same pre-update weights, so an `Arc` replaces a
        // per-wave copy.
        let mut thetas: Vec<Option<Arc<Vec<f32>>>> = {
            let TrainerCore { workers, live, .. } = self;
            workers
                .iter_mut()
                .map(|w| live[w.replica].then(|| Arc::new(std::mem::take(&mut w.theta))))
                .collect()
        };
        let mut order: Vec<(usize, usize, usize)> = Vec::new();
        let mut tasks: Vec<PoolTask> = Vec::new();
        for mb in 0..num_mb {
            for li in 0..self.workers.len() {
                let q = self.workers[li].replica;
                if !self.live[q] {
                    continue;
                }
                let batch = batches[q].as_ref().expect("live stage-0 column has a batch");
                let toks = batch[mb * mb_toks..(mb + 1) * mb_toks].to_vec();
                let theta = thetas[li].as_ref().expect("live worker snapshot armed above");
                tasks.push(PoolTask::BwdFull { theta: Arc::clone(theta), toks });
                order.push((mb, li, q));
            }
        }
        let outs = self
            .pool
            .as_mut()
            .expect("pooled walk gated on pool presence")
            .run(tasks)?;
        for ((mb, li, q), out) in order.into_iter().zip(outs) {
            let PoolOut::BwdFull { loss, grad } = out else {
                unreachable!("BwdFull tasks return BwdFull results");
            };
            self.workers[li].accumulate(&grad);
            losses[mb][q] = Some(loss as f64);
        }
        // Hand the θ snapshots back. Every task clone was dropped before
        // its reply was sent, so the unwrap path is the only one taken;
        // the clone fallback merely keeps this panic-free.
        for (w, t) in self.workers.iter_mut().zip(thetas.iter_mut()) {
            if let Some(arc) = t.take() {
                w.theta = Arc::try_unwrap(arc).unwrap_or_else(|a| (*a).clone());
            }
        }
        Ok(())
    }

    /// Fan the per-worker Adam steps over the pool. Each task owns its
    /// worker's `(θ, m, v, g)` and the write-backs land by worker index,
    /// so the update matches the serial loop exactly; the gradient
    /// buffer rides back for recycling into the accumulator.
    fn pooled_adam(&mut self, lr_now: f64) -> Result<()> {
        let mut order: Vec<usize> = Vec::new();
        let mut tasks: Vec<PoolTask> = Vec::new();
        for li in 0..self.workers.len() {
            if !self.live[self.workers[li].replica] {
                continue; // dead column: no gradients, no update
            }
            let g = self.workers[li].take_mean_grad();
            let w = &mut self.workers[li];
            w.adam_t += 1;
            let sc = AdamScalars::at(lr_now, w.adam_t, self.cfg.grad_clip);
            tasks.push(PoolTask::Adam {
                kind: w.kind,
                theta: std::mem::take(&mut w.theta),
                m: std::mem::take(&mut w.m),
                v: std::mem::take(&mut w.v),
                g,
                sc,
            });
            order.push(li);
        }
        let outs = self
            .pool
            .as_mut()
            .expect("pooled adam gated on pool presence")
            .run(tasks)?;
        for (li, out) in order.into_iter().zip(outs) {
            let PoolOut::Adam { theta, m, v, g } = out else {
                unreachable!("Adam tasks return Adam results");
            };
            let w = &mut self.workers[li];
            w.theta = theta;
            w.m = m;
            w.v = v;
            w.recycle_grad(g);
        }
        Ok(())
    }

    /// Outer optimizer step, fully delegated to the configured
    /// [`SyncStrategy`](super::SyncStrategy). The boundary is the
    /// event-driven engine's beat:
    ///
    /// 1. heartbeats + failure detection (when `[churn] detect` is on) —
    ///    liveness announcements go out, verdicts come back, and a
    ///    detected failure repairs the live set through the same
    ///    [`apply_churn`](TrainerCore::apply_churn) machinery a
    ///    scheduled leave uses;
    /// 2. per-replica boundary clocks advance for the participants;
    /// 3. the stash-expiry sweep drops sync payloads nobody collected
    ///    (`outer.stash_age`);
    /// 4. the three strategy phases: offers for every owned live worker
    ///    first (so a streamed offer snapshots `Δ = θ − φ` before any
    ///    fold resets θ over the same range), then any fragment exchange
    ///    left in flight from the previous boundary
    ///    ([`SyncStrategy::fold_inflight`](super::SyncStrategy::fold_inflight),
    ///    a no-op for gated strategies), then the fold/update phase.
    ///
    /// `outer_idx` is the 1-based outer-step counter shared by both
    /// executors.
    pub fn outer_step(&mut self, outer_idx: u64) -> Result<()> {
        // analyze: wall-clock-ok — journaled sync-phase duration;
        // observability only, never read back by training.
        let t_sync = Instant::now();
        // The boundary closes at this global inner step — the sim stamp
        // for everything emitted here and by the communicator.
        let sim = (outer_idx * self.cfg.outer.inner_steps as u64).saturating_sub(1);
        self.comm.set_obs_boundary(outer_idx, sim);
        self.boundary_heartbeats(outer_idx)?;
        // Clocks advance for this boundary's participants (live owned
        // replicas) — each replica counts the boundaries it was part of.
        if self.owns_grid() {
            for r in 0..self.dp() {
                if self.live[r] {
                    self.clocks[r] += 1;
                }
            }
        } else {
            let r = self.workers[0].replica;
            if self.live[r] {
                self.clocks[r] += 1;
            }
        }
        // Expiry sweep, thresholded on the slowest owned live clock so a
        // lagging rejoiner's admissible rounds are never swept
        // (`stash_age >= staleness` is enforced by config validation).
        let stash_age = self.cfg.stream.stash_age as u64;
        if stash_age > 0 {
            let min_clock = (0..self.dp())
                .filter(|&r| self.live[r])
                .filter_map(|r| {
                    let owned = self.owns_grid() || self.workers[0].replica == r;
                    owned.then_some(self.clocks[r])
                })
                .min()
                .unwrap_or(0);
            let dropped = self.comm.expire_stale(min_clock.saturating_sub(stash_age) as u32);
            if dropped > 0 {
                self.obs
                    .record(sim, Event::StashSwept { boundary: outer_idx, dropped });
            }
        }
        let live = self.live_replicas();
        {
            let TrainerCore { comm, strategy, workers, eng, live: live_mask, .. } = self;
            for w in workers.iter() {
                if live_mask[w.replica] {
                    strategy.offer_outer(comm, w, &live, outer_idx)?;
                }
            }
            for w in workers.iter_mut() {
                if live_mask[w.replica] {
                    strategy.fold_inflight(comm, w, &live, outer_idx)?;
                }
            }
            for w in workers.iter_mut() {
                if live_mask[w.replica] {
                    strategy.apply_outer(comm, &mut **eng, w, &live, outer_idx)?;
                }
            }
        }
        // One boundary row per passage: inner seconds since the last
        // boundary, this boundary's sync seconds, and the wire delta.
        if self.obs.is_enabled() {
            let (b, m) = self.comm.wire_totals();
            let (b0, m0) = self.last_wire;
            self.last_wire = (b, m);
            let inner_s = std::mem::take(&mut self.inner_accum);
            self.obs.record(
                sim,
                Event::Boundary {
                    outer_idx,
                    inner_s,
                    sync_s: t_sync.elapsed().as_secs_f64(),
                    bytes: b.saturating_sub(b0),
                    msgs: m.saturating_sub(m0),
                },
            );
            let loss = self.last_finite_loss();
            let sigma = self.weight_std();
            self.obs.snapshot_metrics(sim, outer_idx, loss, sigma, b, m);
        }
        Ok(())
    }

    /// The heartbeat half of a boundary (no-op without `[churn] detect`):
    /// every owned live replica announces liveness to its stage row, the
    /// detector folds in what has arrived — never waiting; detection is
    /// an inference over delivered messages — and each verdict feeds the
    /// existing churn-repair machinery. One boundary of grace is polled
    /// behind the current one to absorb in-flight delivery.
    ///
    /// The grid executor heartbeats on the stage-0 row only: replica
    /// liveness is a column property, one row arbitrates it. Detection is
    /// a *local* judgment per core — on the threaded executor transient
    /// disagreement between workers is absorbed by the gossip straggler
    /// timeout until their detectors converge (within one boundary).
    fn boundary_heartbeats(&mut self, outer_idx: u64) -> Result<()> {
        if self.detector.is_none() {
            return Ok(());
        }
        let dp = self.dp();
        let m = self.cfg.outer.inner_steps as u64;
        let closing = (outer_idx * m).saturating_sub(1);
        let grid = self.owns_grid();
        let hb_stage = if grid { 0 } else { self.workers[0].stage };
        let own: Vec<usize> = if grid {
            (0..dp).collect()
        } else {
            vec![self.workers[0].replica]
        };
        for &r in &own {
            let silenced = matches!(
                self.silence,
                Some((sr, from, until)) if sr == r && closing >= from && closing < until
            );
            // A detection-suspected replica is alive-but-partitioned: it
            // keeps heartbeating (unlike a schedule-dead one) so the
            // detector can re-admit it when the partition heals.
            if silenced || !(self.live[r] || self.suspected[r]) {
                continue;
            }
            let peers: Vec<usize> = (0..dp).filter(|&q| q != r).collect();
            self.comm.send_heartbeat(hb_stage, r, &peers, outer_idx as u32)?;
            self.detector
                .as_mut()
                .expect("checked above")
                .observe(r, outer_idx);
        }
        // Poll the whole tolerance window, freshest first: a heartbeat
        // delivered up to `misses` boundaries late must still be
        // observed, or the configured tolerance would silently shrink to
        // one boundary and a slow-but-alive peer could be declared dead
        // with no way back (Join needs a current observation).
        let me0 = own[0];
        let lo = outer_idx.saturating_sub(self.cfg.detect.misses as u64).max(1);
        for q in 0..dp {
            if own.contains(&q) {
                continue;
            }
            let mut seen = false;
            for hb in (lo..=outer_idx).rev() {
                if self.comm.poll_heartbeat(hb_stage, me0, q, hb as u32)? {
                    self.detector
                        .as_mut()
                        .expect("checked above")
                        .observe(q, hb);
                    seen = true;
                    break;
                }
            }
            // Journal a miss only for peers we still expect to signal
            // (live, or suspected-but-heartbeating) — a schedule-dead
            // column missing forever is not news.
            if !seen && (self.live[q] || self.suspected[q]) {
                self.obs.record(
                    closing,
                    Event::HeartbeatMiss {
                        stage: hb_stage,
                        replica: me0,
                        peer: q,
                        boundary: outer_idx,
                    },
                );
            }
        }
        let events = self
            .detector
            .as_mut()
            .expect("checked above")
            .tick(outer_idx);
        for e in events {
            match e {
                ChurnEvent::Leave(r) if self.live[r] => {
                    self.suspected[r] = true;
                    self.detected.push((outer_idx, e));
                    self.obs.record(
                        closing,
                        Event::Detect { boundary: outer_idx, node: r, join: false },
                    );
                    self.apply_churn(e)?;
                }
                ChurnEvent::Join(r) if self.suspected[r] && !self.live[r] => {
                    self.suspected[r] = false;
                    self.detected.push((outer_idx, e));
                    self.obs.record(
                        closing,
                        Event::Detect { boundary: outer_idx, node: r, join: true },
                    );
                    self.apply_churn(e)?;
                }
                // Schedule-driven absences arbitrate themselves: the
                // shared schedule already updated the live mask.
                _ => {}
            }
        }
        Ok(())
    }

    /// Mean validation NLL over the fixed validation set, averaged across
    /// the live replicas evaluated at owned last-stage workers (each
    /// replica through its own fixed-route pipeline). Returns NaN for
    /// owned workers that never see a loss (first/mid threaded stages).
    pub fn validate(&mut self) -> Result<f64> {
        // Standalone calls (tests / SimTrainer API) namespace their eval
        // traffic past any step the schedule could produce.
        self.validate_at(self.cfg.steps + 1)
    }

    #[allow(clippy::needless_range_loop)]
    fn validate_at(&mut self, step: usize) -> Result<f64> {
        let pp = self.pp();
        let n_val = self.n_val;
        // Eval boundary tags derive from the step so every worker agrees
        // without coordination; 4096 batches per eval point is far above
        // any configured n_val.
        let slot0 = (step as u32 + 1).wrapping_mul(1 << 12);
        let mut nlls: Vec<(usize, usize, f64)> = Vec::new();
        for vb in 0..n_val {
            let slot = slot0.wrapping_add(vb as u32);
            for li in 0..self.workers.len() {
                let (s, q) = (self.workers[li].stage, self.workers[li].replica);
                if !self.live[q] {
                    continue;
                }
                if pp == 1 {
                    let toks = &self.val_batches[vb];
                    let l =
                        exec::loss_full(self.eng, &self.man, &self.workers[li].theta, toks)?;
                    nlls.push((q, vb, l as f64));
                } else if s == 0 {
                    let toks = self.val_batches[vb].clone();
                    let x =
                        exec::fwd_first(self.eng, &self.man, &self.workers[li].theta, &toks)?;
                    self.comm.send_boundary(
                        (1, q),
                        BoundaryTag::new(K_VACT, slot, q as u32),
                        Wire::F32(x),
                    )?;
                    self.comm.send_boundary(
                        (1, q),
                        BoundaryTag::new(K_VTOK, slot, q as u32),
                        Wire::I32(toks),
                    )?;
                } else {
                    let act = self
                        .comm
                        .recv_boundary((s, q), BoundaryTag::new(K_VACT, slot, q as u32))?
                        .into_f32();
                    let toks = self
                        .comm
                        .recv_boundary((s, q), BoundaryTag::new(K_VTOK, slot, q as u32))?
                        .into_i32();
                    if s == pp - 1 {
                        let l = exec::loss_last(
                            self.eng,
                            &self.man,
                            &self.workers[li].theta,
                            &act,
                            &toks,
                        )?;
                        nlls.push((q, vb, l as f64));
                    } else {
                        let x =
                            exec::fwd_mid(self.eng, &self.man, &self.workers[li].theta, &act)?;
                        self.comm.send_boundary(
                            (s + 1, q),
                            BoundaryTag::new(K_VACT, slot, q as u32),
                            Wire::F32(x),
                        )?;
                        self.comm.send_boundary(
                            (s + 1, q),
                            BoundaryTag::new(K_VTOK, slot, q as u32),
                            Wire::I32(toks),
                        )?;
                    }
                }
            }
        }
        if nlls.is_empty() {
            return Ok(f64::NAN);
        }
        // Seed accumulation order: replica-major, then batch.
        nlls.sort_by_key(|&(r, b, _)| (r, b));
        let n = nlls.len();
        let sum: f64 = nlls.iter().map(|&(_, _, l)| l).sum();
        Ok(sum / n as f64)
    }

    /// Cross-replica weight standard deviation (Fig. 3B / Fig. 4A):
    /// per-stage σ over the live DP replicas' fast weights, averaged
    /// across stages weighted by parameter count. Grid executor only —
    /// a threaded worker cannot see its row peers, so it reports NaN.
    pub fn weight_std(&self) -> f64 {
        if !self.owns_grid() {
            return f64::NAN;
        }
        let pp = self.pp();
        let live = self.live_replicas();
        if live.len() < 2 {
            return 0.0;
        }
        let mut acc = 0.0;
        let mut total = 0usize;
        for s in 0..pp {
            let tensors: Vec<Tensor> = live
                .iter()
                .map(|&r| {
                    let w = &self.workers[self.widx(s, r)];
                    Tensor::from_vec(w.theta.clone(), &[w.len()])
                })
                .collect();
            let refs: Vec<&Tensor> = tensors.iter().collect();
            let n = tensors[0].len();
            acc += crate::tensor::replica_std(&refs) * n as f64;
            total += n;
        }
        acc / total.max(1) as f64
    }

    /// Snapshot everything this core owns as a [`Checkpoint`]. The grid
    /// executor returns the complete run checkpoint; a threaded rank
    /// returns a single-rank checkpoint of its own state (the `[ckpt]`
    /// cadence instead routes [`TrainerCore::rank_snapshot`]s through
    /// the [`CkptAssembler`] coordinator, which merges all `dp · pp` of
    /// them into one file).
    pub fn checkpoint(&self, step: u64) -> Result<Checkpoint> {
        let boundary = step / self.cfg.outer.inner_steps as u64;
        if self.owns_grid() {
            return self.capture_full(step, boundary);
        }
        let snap = self.rank_snapshot(step, boundary);
        Ok(Checkpoint {
            step,
            outer_idx: boundary,
            dp: self.dp() as u32,
            pp: self.pp() as u32,
            workers: vec![snap.worker],
            loaders: snap.loader.into_iter().collect(),
            cores: vec![snap.core],
        })
    }

    /// Restore a snapshot's tensors into this grid; returns the
    /// snapshot's step. Tensor-only — [`TrainerCore::resume_from`] is
    /// the full-fidelity path.
    pub fn restore(&mut self, ck: &Checkpoint) -> Result<u64> {
        ck.restore(self.workers_mut())
    }

    /// Full-fidelity snapshot of the whole run (grid executor): worker
    /// tensors + in-flight strategy state, loader cursors, and the one
    /// grid core record.
    pub fn capture_full(&self, step: u64, boundary: u64) -> Result<Checkpoint> {
        ensure!(
            self.owns_grid(),
            "capture_full snapshots the whole grid; threaded ranks assemble \
             rank snapshots through the CkptAssembler instead"
        );
        let workers = self
            .workers
            .iter()
            .map(|w| WorkerRecord::of(w, self.strategy.export_state(w)))
            .collect();
        let loaders = self
            .loaders
            .iter()
            .map(|(r, l)| LoaderCursor { replica: *r as u32, cursor: l.cursor() })
            .collect();
        Ok(Checkpoint {
            step,
            outer_idx: boundary,
            dp: self.dp() as u32,
            pp: self.pp() as u32,
            workers,
            loaders,
            cores: vec![self.core_record(true)],
        })
    }

    /// This rank's contribution to a threaded-executor checkpoint
    /// (exactly one owned worker).
    pub fn rank_snapshot(&self, step: u64, boundary: u64) -> RankSnapshot {
        debug_assert_eq!(self.workers.len(), 1, "rank snapshots are per threaded worker");
        let w = &self.workers[0];
        RankSnapshot {
            step,
            outer_idx: boundary,
            worker: WorkerRecord::of(w, self.strategy.export_state(w)),
            loader: self
                .loaders
                .first()
                .map(|(r, l)| LoaderCursor { replica: *r as u32, cursor: l.cursor() }),
            core: self.core_record(false),
        }
    }

    /// Everything this core holds outside worker tensors that still
    /// shapes the trajectory or the final report.
    fn core_record(&self, grid: bool) -> CoreRecord {
        let (stage, replica) = if grid {
            (0, 0)
        } else {
            (self.workers[0].stage as u32, self.workers[0].replica as u32)
        };
        CoreRecord {
            stage,
            replica,
            grid,
            live: self.live.clone(),
            suspected: self.suspected.clone(),
            clocks: self.clocks.clone(),
            detector: self.detector.as_ref().map(|d| d.export_state()),
            detected: self
                .detected
                .iter()
                .map(|&(b, e)| (b, e.node() as u32, matches!(e, ChurnEvent::Join(_))))
                .collect(),
            step_train_loss: self.step_train_loss.clone(),
            trace: (0..self.trace.steps.len())
                .map(|i| {
                    (
                        self.trace.steps[i] as u64,
                        self.trace.train_loss[i],
                        self.trace.val_loss[i],
                        self.trace.weight_std[i],
                        self.trace.lr[i],
                    )
                })
                .collect(),
            last_wire: self.last_wire,
            stats: self.comm.stats().clone(),
            fault_rng: self.comm.fault_rng_state(),
            wire_sent: self.comm.wire_totals(),
        }
    }

    /// The `[ckpt]` cadence: at every `every`-th outer boundary (after
    /// the fold and any eval of the closing step), the grid executor
    /// writes the full checkpoint atomically and a threaded rank submits
    /// its snapshot to the coordinator. Returns whether the run must
    /// halt (kill-restart drill).
    fn maybe_checkpoint(&mut self, step: usize) -> Result<bool> {
        let armed = self.ckpt_every > 0
            && if self.owns_grid() { self.ckpt_out.is_some() } else { self.ckpt_sink.is_some() };
        if !armed {
            return Ok(false);
        }
        let m = self.cfg.outer.inner_steps as u64;
        let done = step as u64 + 1; // inner steps completed
        if done % (self.ckpt_every * m) != 0 {
            return Ok(false);
        }
        let boundary = done / m;
        let written = if self.owns_grid() {
            let ck = self.capture_full(done, boundary)?;
            let path = self.ckpt_out.as_ref().expect("armed above");
            Some(ck.save(path)?)
        } else {
            let snap = self.rank_snapshot(done, boundary);
            let sink = self.ckpt_sink.as_ref().expect("armed above");
            sink.submit(self.dp() as u32, self.pp() as u32, snap)?
        };
        // One journal row per written file: the grid core always writes;
        // on the threaded executor the rank completing the set does.
        if let Some(bytes) = written {
            self.obs
                .record(done.saturating_sub(1), Event::Ckpt { boundary, step: done, bytes });
        }
        Ok(self.halt_after == Some(boundary))
    }

    /// Restore a full-fidelity checkpoint into this core (both
    /// executors) and arm the run loop to continue at the snapshot's
    /// step: worker tensors, in-flight strategy state (each rank
    /// re-publishes its own retained offers — the sender-replay
    /// protocol, so peers' folds admit them exactly as before the
    /// crash), loader cursors, live/suspected masks, boundary clocks,
    /// detector verdicts, recorded losses and trace, communication
    /// accounting and the fabric's fault-RNG / wire counters.
    pub fn resume_from(&mut self, ck: &Checkpoint) -> Result<()> {
        ensure!(
            ck.dp as usize == self.dp() && ck.pp as usize == self.pp(),
            "checkpoint grid {}×{} does not match the run ({}×{})",
            ck.dp,
            ck.pp,
            self.dp(),
            self.pp()
        );
        let m = self.cfg.outer.inner_steps as u64;
        ensure!(
            ck.step % m == 0,
            "checkpoint step {} is not boundary-aligned (inner_steps = {m})",
            ck.step
        );
        ensure!(
            ck.step as usize <= self.cfg.steps,
            "checkpoint step {} is past the configured run ({} steps)",
            ck.step,
            self.cfg.steps
        );
        // Worker tensors + each worker's in-flight strategy state.
        for i in 0..self.workers.len() {
            let (s, r) = (self.workers[i].stage, self.workers[i].replica);
            let rec = ck
                .worker(s, r)
                .with_context(|| format!("checkpoint has no record for worker ({s}, {r})"))?
                .clone();
            rec.restore_into(&mut self.workers[i])?;
            if let Some(st) = &rec.strategy {
                let TrainerCore { comm, strategy, workers, .. } = self;
                strategy.restore_state(comm, &workers[i], st)?;
            }
        }
        // Loader cursors: replay the stream up to the recorded position.
        for (r, loader) in self.loaders.iter_mut() {
            let cur = ck
                .loader_cursor(*r)
                .with_context(|| format!("checkpoint has no loader cursor for replica {r}"))?;
            loader.fast_forward(cur);
        }
        // Core runtime state.
        let grid = self.owns_grid();
        let (s0, r0) = (self.workers[0].stage, self.workers[0].replica);
        let core = ck.core(s0, r0, grid).with_context(|| {
            format!("checkpoint has no core record for ({s0}, {r0}, grid = {grid})")
        })?;
        ensure!(
            core.live.len() == self.dp(),
            "checkpoint live mask covers {} replicas, run has {}",
            core.live.len(),
            self.dp()
        );
        self.live = core.live.clone();
        self.suspected = core.suspected.clone();
        self.clocks = core.clocks.clone();
        if let (Some(det), Some((seen, dead))) = (self.detector.as_mut(), core.detector.as_ref())
        {
            det.restore_state(seen, dead);
        }
        self.detected = core
            .detected
            .iter()
            .map(|&(b, n, join)| {
                let n = n as usize;
                (b, if join { ChurnEvent::Join(n) } else { ChurnEvent::Leave(n) })
            })
            .collect();
        self.step_train_loss = core.step_train_loss.clone();
        self.trace = RunTrace::default();
        for &(st, tr, va, ws, lr) in &core.trace {
            self.trace.push(st as usize, tr, va, ws, lr);
        }
        self.last_wire = core.last_wire;
        self.comm.restore_stats(&core.stats);
        if let Some((state, inc)) = core.fault_rng {
            self.comm.restore_fault_rng(state, inc);
        }
        self.comm.restore_wire_totals(core.wire_sent.0, core.wire_sent.1);
        // Re-announce the checkpoint boundary's heartbeat: the original
        // message died with the old fabric, but peers' next poll window
        // still reaches back to this boundary.
        if self.detector.is_some() && ck.outer_idx > 0 {
            let hb_stage = if grid { 0 } else { s0 };
            let own: Vec<usize> =
                if grid { (0..self.dp()).collect() } else { vec![r0] };
            for &r in &own {
                if self.live[r] || self.suspected[r] {
                    let peers: Vec<usize> = (0..self.dp()).filter(|&q| q != r).collect();
                    self.comm.replay_heartbeat(hb_stage, r, &peers, ck.outer_idx as u32)?;
                }
            }
        }
        self.start_step = ck.step as usize;
        self.obs.record(
            ck.step.saturating_sub(1),
            Event::Resume { boundary: ck.outer_idx, step: ck.step },
        );
        Ok(())
    }
}
