//! Deterministic inner-phase execution pool (`[perf] threads` /
//! `--threads`).
//!
//! The grid executor's `pp = 1` inner phase is embarrassingly parallel:
//! between two outer boundaries every replica's microbatch waves depend
//! only on that replica's θ and its own token stream, and the fused Adam
//! steps depend only on per-worker state. [`ExecPool`] exploits exactly
//! that window — and nothing more — by fanning
//! [`PoolTask::BwdFull`] / [`PoolTask::Adam`] tasks over a set of
//! persistent worker threads, each owning a **private**
//! [`Engine`](crate::runtime::Engine) over the same artifact directory
//! (PJRT client handles are thread-local by construction; the threaded
//! executor has always built one engine per worker thread the same way).
//!
//! ## Ordering contract (why any thread count is bit-identical)
//!
//! Every task is a *pure function* of its operands: XLA CPU executables
//! are deterministic, so `bwd_full(θ, toks)` returns the same bits on
//! any thread of any machine. The pool therefore only has to keep the
//! *apply* order fixed: [`ExecPool::run`] returns results **in
//! submission order**, and the caller folds them exactly where the
//! serial walk would have (wave-major, ascending worker index for
//! gradient accumulation; per-worker write-back for Adam). Scheduling
//! jitter can change which thread computes a task, never what the task
//! returns nor the order its result is folded — thread count is a
//! throughput knob, not a determinism input. The parallel-equivalence
//! golden tests (`rust/tests/parallel_equiv.rs`) pin this end to end.
//!
//! Tasks are distributed round-robin by submission index, which keeps
//! the per-thread engine compile caches warm (worker `i` sees the same
//! task shapes every step) without any shared-queue locking on the hot
//! path.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::model::StageKind;
use crate::runtime::{Engine, Manifest};

use super::exec::{self, AdamScalars};

/// One unit of inner-phase work shipped to a pool thread.
#[derive(Debug)]
pub enum PoolTask {
    /// Fused forward+backward of the single-stage (`full`) model:
    /// `(θ, tokens) → (loss, ∂θ)`. The θ snapshot is shared across a
    /// worker's waves via `Arc` — no per-task copy.
    BwdFull {
        /// Flat fast weights θ (shared snapshot for the whole step).
        theta: std::sync::Arc<Vec<f32>>,
        /// This microbatch wave's tokens.
        toks: Vec<i32>,
    },
    /// One fused Adam step: consumes the worker's `(θ, m, v)` and mean
    /// gradient, returns the updated triple (and the gradient buffer,
    /// which the caller recycles into the accumulator).
    Adam {
        /// Stage kind selecting the artifact set.
        kind: StageKind,
        /// Flat fast weights θ (moved in, moved back out updated).
        theta: Vec<f32>,
        /// Adam first moment.
        m: Vec<f32>,
        /// Adam second moment.
        v: Vec<f32>,
        /// Microbatch-mean gradient.
        g: Vec<f32>,
        /// Step scalars (lr, t, betas, eps, clip).
        sc: AdamScalars,
    },
}

/// The result of one [`PoolTask`], same variant as the task.
#[derive(Debug)]
pub enum PoolOut {
    /// `BwdFull` result.
    BwdFull {
        /// Microbatch mean loss.
        loss: f32,
        /// Flat parameter gradient.
        grad: Vec<f32>,
    },
    /// `Adam` result: the updated triple plus the recycled gradient.
    Adam {
        /// Updated fast weights θ.
        theta: Vec<f32>,
        /// Updated first moment.
        m: Vec<f32>,
        /// Updated second moment.
        v: Vec<f32>,
        /// The gradient buffer, returned for reuse.
        g: Vec<f32>,
    },
}

/// `(thread index, task index, result, cumulative engine executions)`.
type PoolReply = (usize, usize, Result<PoolOut>, u64);

/// Resolve a configured thread count: `0` auto-detects the machine's
/// available parallelism (a throughput decision only — see the module
/// docs on why this never touches the trajectory).
pub fn resolve_threads(configured: usize) -> usize {
    if configured == 0 {
        // Ambient machine width, consumed only by the scheduler: results
        // are applied in submission order, so the trajectory is identical
        // at any resolved count. The R1 allowance for this ambient input
        // is scoped to this file (see analyze/rules.rs), not annotated
        // away — moving this call anywhere else trips the analyzer.
        std::thread::available_parallelism().map_or(1, |n| n.get())
    } else {
        configured
    }
}

/// A persistent pool of engine-owning worker threads. See the module
/// docs for the ordering contract.
pub struct ExecPool {
    /// Per-thread task channels (round-robin distribution).
    task_tx: Vec<Sender<(usize, PoolTask)>>,
    /// Shared reply channel.
    reply_rx: Receiver<PoolReply>,
    handles: Vec<JoinHandle<()>>,
    /// Last cumulative engine-execution count reported per thread.
    execs_seen: Vec<u64>,
}

impl ExecPool {
    /// Spawn `threads` (after [`resolve_threads`], clamped to
    /// `1..=max_useful`) workers over the artifact directory `dir`.
    /// Engines are built lazily on each thread's first task, so an
    /// artifact problem surfaces as a task error, exactly where the
    /// serial walk would hit it.
    pub fn new(threads: usize, max_useful: usize, dir: PathBuf, man: Manifest) -> ExecPool {
        let n = resolve_threads(threads).clamp(1, max_useful.max(1));
        let (reply_tx, reply_rx) = channel::<PoolReply>();
        let mut task_tx = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for t in 0..n {
            let (tx, rx) = channel::<(usize, PoolTask)>();
            task_tx.push(tx);
            let reply = reply_tx.clone();
            let dir = dir.clone();
            let man = man.clone();
            handles.push(std::thread::spawn(move || worker_loop(t, dir, man, rx, reply)));
        }
        ExecPool { task_tx, reply_rx, handles, execs_seen: vec![0; n] }
    }

    /// Worker-thread count.
    pub fn threads(&self) -> usize {
        self.task_tx.len()
    }

    /// Run a batch of tasks and return their results **in submission
    /// order**. Errors are reported for the lowest-indexed failing task
    /// (deterministic regardless of which thread failed first).
    pub fn run(&mut self, tasks: Vec<PoolTask>) -> Result<Vec<PoolOut>> {
        let n = tasks.len();
        for (idx, task) in tasks.into_iter().enumerate() {
            let lane = idx % self.task_tx.len();
            self.task_tx[lane]
                .send((idx, task))
                .map_err(|_| anyhow!("exec pool thread {lane} died"))?;
        }
        let mut slots: Vec<Option<Result<PoolOut>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (tid, idx, out, execs) = self
                .reply_rx
                .recv()
                .map_err(|_| anyhow!("exec pool reply channel closed"))?;
            self.execs_seen[tid] = execs;
            slots[idx] = Some(out);
        }
        let mut out = Vec::with_capacity(n);
        for (idx, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(Ok(o)) => out.push(o),
                Some(Err(e)) => return Err(e.context(format!("pool task {idx}"))),
                None => return Err(anyhow!("pool task {idx} never replied")),
            }
        }
        Ok(out)
    }

    /// Cumulative engine executions across all pool threads (absorbed
    /// into the run report's `executions` so parallel and serial runs
    /// report the same count).
    pub fn executions(&self) -> u64 {
        self.execs_seen.iter().sum()
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        // Closing the task channels ends each worker loop; join so no
        // engine outlives the pool.
        self.task_tx.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    tid: usize,
    dir: PathBuf,
    man: Manifest,
    rx: Receiver<(usize, PoolTask)>,
    reply: Sender<PoolReply>,
) {
    // The engine is thread-private and lazily built: PJRT clients are
    // not Send, and a pool wider than the live task stream should not
    // pay for clients it never uses.
    let mut eng: Option<Engine> = None;
    while let Ok((idx, task)) = rx.recv() {
        let out = run_task(&mut eng, &dir, &man, task);
        let execs = eng.as_ref().map_or(0, Engine::executions);
        if reply.send((tid, idx, out, execs)).is_err() {
            return; // pool dropped mid-batch; nothing left to report to
        }
    }
}

fn run_task(
    eng: &mut Option<Engine>,
    dir: &PathBuf,
    man: &Manifest,
    task: PoolTask,
) -> Result<PoolOut> {
    let eng = match eng {
        Some(e) => e,
        None => eng.insert(Engine::new(dir)?),
    };
    match task {
        PoolTask::BwdFull { theta, toks } => {
            let (loss, grad) = exec::bwd_full(eng, man, &theta, &toks)?;
            Ok(PoolOut::BwdFull { loss, grad })
        }
        PoolTask::Adam { kind, mut theta, mut m, mut v, g, sc } => {
            exec::adam_step(eng, kind, &mut theta, &mut m, &mut v, &g, sc)?;
            Ok(PoolOut::Adam { theta, m, v, g })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_threads_passthrough_and_auto() {
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(7), 7);
        // Auto-detect resolves to at least one worker on any machine.
        assert!(resolve_threads(0) >= 1);
    }

    // Engine-dependent pool behaviour (lazy construction, ordering,
    // execution accounting) is pinned by the artifact-gated golden tests
    // in rust/tests/parallel_equiv.rs; nothing here needs artifacts.
}
