//! The [`SyncStrategy`] abstraction: *what* replicas exchange and how
//! peer state folds into the outer optimizer.
//!
//! The paper's three methods differ only here — FSDP all-reduces
//! gradients every inner step, DiLoCo all-reduces outer gradients every m
//! steps, NoLoCo gossips `(Δ, φ)` over random pairs — so each is one impl
//! of this trait, shared verbatim by both executors through the
//! [`Communicator`](super::Communicator) abstraction. A new
//! synchronization variant is one new impl, not two new trainer forks —
//! [`StreamingSync`](super::StreamingSync) (streaming fragmented overlap
//! à la Streaming DiLoCo) is exactly that, layered on the
//! [`SyncStrategy::fold_inflight`] / [`SyncStrategy::drain`] hooks the
//! core calls around each boundary.
//!
//! Every synchronization point is two-phase (see [`super::comm`]): the
//! core calls `offer_*` for each locally-owned live worker, then the
//! matching fold. On the grid executor the offer phase publishes the
//! whole row before any fold reads it; on the threaded executor each
//! worker offers (eagerly sending) and folds only for itself.
//!
//! [`NolocoSync`] draws its gossip groups through a [`PairingPolicy`]:
//! [`UniformPairing`] reproduces the seed's shared-seed draw bit-for-bit,
//! and [`BandwidthAwarePairing`] biases pairs toward cheap intra-region
//! links on a [`Topology`] while keeping the mixing guarantee with
//! periodic uniform rounds (selectable via
//! [`PairingMode`](crate::config::PairingMode) / `--pairing`).

// `expect` discipline: the remaining expects document cache/pairing
// invariants established earlier in the same boundary pass (`cached
// above`, policy coverage). A violation is a strategy bug and must
// crash loudly, not be papered over.
#![allow(clippy::expect_used)]

use anyhow::Result;

use crate::config::{Method, OuterConfig, PairingMode, SyncMode, TrainConfig};
use crate::net::{ChurnSchedule, Topology};
use crate::rngx::Pcg64;
use crate::runtime::Engine;

use super::arena::FoldScratch;
use super::checkpoint::StrategyState;
use super::comm::Communicator;
use super::exec;
use super::state::WorkerState;

/// What a method's synchronization point exchanges (§2–3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommPattern {
    /// Globally blocking collective (FSDP gradients, DiLoCo outer step).
    AllReduce,
    /// Random disjoint gossip groups — no collective, no global barrier.
    GossipPairs,
    /// No cross-replica exchange (dp = 1 degenerate runs).
    None,
}

/// How a method responds to a membership change mid-run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnResponse {
    /// Abort: a world-wide collective has no live-subset form (§5.3).
    Abort,
    /// Keep training: routing and gossip re-draw over the live set; a
    /// rejoiner bootstraps from a donor (grid executor) or by absorbing
    /// its first gossip peer's slow weights (threaded executor).
    Repair,
}

/// One training method's synchronization behaviour, shared by both
/// executors. Implementations must be deterministic given
/// `(seed, stage, step/outer_idx, live)` — the shared-seed discipline
/// that lets threaded workers agree without coordination traffic.
pub trait SyncStrategy: Send {
    /// Method name for reports.
    fn name(&self) -> &'static str;

    /// What the outer synchronization exchanges.
    fn pattern(&self) -> CommPattern;

    /// Whether the method runs an outer step at all (false for FSDP).
    fn has_outer(&self) -> bool;

    /// Abort vs. repair on membership events.
    fn churn_response(&self) -> ChurnResponse;

    /// Per-inner-step gradient sync, phase 1: publish this worker's raw
    /// accumulated gradient sums. Only FSDP does work here.
    fn offer_grads(
        &mut self,
        _comm: &mut dyn Communicator,
        _w: &WorkerState,
        _live: &[usize],
        _step: u64,
    ) -> Result<()> {
        Ok(())
    }

    /// Per-inner-step gradient sync, phase 2: fold the stage row's
    /// gradients into this worker's accumulator (before the Adam step).
    fn sync_grads(
        &mut self,
        _comm: &mut dyn Communicator,
        _w: &mut WorkerState,
        _live: &[usize],
        _step: u64,
    ) -> Result<()> {
        Ok(())
    }

    /// Outer step, phase 1: publish this worker's `(Δ, φ)` (or outer
    /// gradient) for round `outer_idx`.
    fn offer_outer(
        &mut self,
        _comm: &mut dyn Communicator,
        _w: &WorkerState,
        _live: &[usize],
        _outer_idx: u64,
    ) -> Result<()> {
        Ok(())
    }

    /// Outer step, phase 2: fold peer state, update `(φ, δ)` through the
    /// compiled outer artifact, and reset θ := φ.
    fn apply_outer(
        &mut self,
        _comm: &mut dyn Communicator,
        _eng: &mut Engine,
        _w: &mut WorkerState,
        _live: &[usize],
        _outer_idx: u64,
    ) -> Result<()> {
        Ok(())
    }

    /// Streaming overlap: fold any fragment exchange left in flight from
    /// the *previous* boundary. Called by the core at every outer
    /// boundary **after** the offer phase — the offer snapshots
    /// `Δ = θ − φ` before the fold's θ-reset can touch the same range
    /// (the `fragments = 1` case addresses the identical range at every
    /// boundary). Gated strategies have nothing in flight (default
    /// no-op). See [`StreamingSync`](super::StreamingSync).
    fn fold_inflight(
        &mut self,
        _comm: &mut dyn Communicator,
        _w: &mut WorkerState,
        _live: &[usize],
        _outer_idx: u64,
    ) -> Result<()> {
        Ok(())
    }

    /// End-of-run drain: fold whatever is still in flight so the reported
    /// slow weights include the final boundary's offered exchange. Called
    /// by the core once after the step loop with the last outer boundary
    /// the run executed (`final_outer_idx`), so a leftover entry from an
    /// earlier boundary — e.g. a worker that died mid-run — is recognized
    /// as stale and dropped rather than folded. Default no-op.
    fn drain(
        &mut self,
        _comm: &mut dyn Communicator,
        _w: &mut WorkerState,
        _live: &[usize],
        _final_outer_idx: u64,
    ) -> Result<()> {
        Ok(())
    }

    /// Push this strategy's private counters into the observability hub
    /// (under a `<name>.` key prefix). Called by the core once at the
    /// end of a run; strategies with nothing beyond the journaled
    /// offer/fold stream keep the default no-op.
    fn report_obs(&self, _hub: &crate::obs::ObsHub) {}

    /// Export worker `w`'s in-flight cross-boundary state for a
    /// checkpoint. `None` for gated strategies — they hold nothing
    /// across a boundary, which is exactly why checkpoints are cut
    /// there. Overlapped strategies return their retained fragments /
    /// offers (see [`StrategyState`]).
    fn export_state(&self, _w: &WorkerState) -> Option<StrategyState> {
        None
    }

    /// Restore worker `w`'s checkpointed in-flight state, re-publishing
    /// this worker's retained offers through `comm`'s unmetered replay
    /// hooks so peers' folds can still admit them (the sender-replay
    /// resume protocol — receiver stashes are never serialized).
    fn restore_state(
        &mut self,
        _comm: &mut dyn Communicator,
        _w: &WorkerState,
        _st: &StrategyState,
    ) -> Result<()> {
        Ok(())
    }
}

/// Build the configured NoLoCo pairing policy (shared by the gated and
/// streaming strategy constructors).
pub(crate) fn pairing_for(cfg: &TrainConfig) -> Box<dyn PairingPolicy> {
    match cfg.pairing {
        PairingMode::Uniform => Box::new(UniformPairing),
        PairingMode::BandwidthAware => Box::new(BandwidthAwarePairing::new(
            cfg.net.build(cfg.topology.dp, cfg.seed),
        )),
        PairingMode::PerFragment => Box::new(PerFragmentPairing::new(Box::new(UniformPairing))),
    }
}

/// Build the *gated* strategy for `cfg.outer.method` — the one
/// construction shared by [`for_config`] and the streaming strategy's
/// degenerate delegate, so the two can never drift apart.
pub(crate) fn gated_for(cfg: &TrainConfig) -> Box<dyn SyncStrategy> {
    match cfg.outer.method {
        Method::Fsdp => Box::new(FsdpSync),
        Method::DiLoCo => Box::new(DilocoSync {
            alpha: cfg.outer.alpha as f32,
            beta: cfg.outer.beta as f32,
        }),
        Method::NoLoCo => Box::new(NolocoSync::new(
            cfg.outer.clone(),
            cfg.seed,
            cfg.topology.dp,
            cfg.churn.clone(),
            pairing_for(cfg),
        )),
    }
}

/// Build the strategy configured on `cfg`: the gated method impls below,
/// [`StreamingSync`](super::StreamingSync) over the configured flavor
/// when `--sync streaming` is selected (FSDP has no outer state to
/// stream; config validation rejects that pairing before trainers get
/// here), or the bounded-staleness
/// [`AsyncGossipSync`](super::AsyncGossipSync) engine when
/// `outer.staleness > 1` (NoLoCo only; either `--sync` mode is accepted
/// since the async engine owns the overlap itself — `staleness = 1` is
/// the lockstep contract and routes through the gated / streaming code
/// paths untouched, bit-for-bit).
pub fn for_config(cfg: &TrainConfig) -> Box<dyn SyncStrategy> {
    if cfg.outer.staleness > 1 {
        return Box::new(super::boundary::AsyncGossipSync::from_config(cfg));
    }
    if cfg.sync == SyncMode::Streaming && cfg.outer.method != Method::Fsdp {
        return Box::new(super::streaming::StreamingSync::from_config(cfg));
    }
    gated_for(cfg)
}

// ---------------------------------------------------------------------
// FSDP: per-step gradient all-reduce, no outer optimizer
// ---------------------------------------------------------------------

/// Fully synchronous data parallel (the paper's upper baseline).
pub struct FsdpSync;

impl SyncStrategy for FsdpSync {
    fn name(&self) -> &'static str {
        "fsdp"
    }

    fn pattern(&self) -> CommPattern {
        CommPattern::AllReduce
    }

    fn has_outer(&self) -> bool {
        false
    }

    fn churn_response(&self) -> ChurnResponse {
        ChurnResponse::Abort
    }

    fn offer_grads(
        &mut self,
        comm: &mut dyn Communicator,
        w: &WorkerState,
        live: &[usize],
        step: u64,
    ) -> Result<()> {
        if live.len() > 1 {
            comm.offer_reduce(w.stage, w.replica, step as u32, &w.grad_acc)?;
        }
        Ok(())
    }

    fn sync_grads(
        &mut self,
        comm: &mut dyn Communicator,
        w: &mut WorkerState,
        live: &[usize],
        step: u64,
    ) -> Result<()> {
        if live.len() <= 1 {
            return Ok(());
        }
        // Reduce the *raw* microbatch sums; the per-worker mean division
        // (by microbatch count) happens afterwards in the Adam path, which
        // keeps the grid executor's seed trajectory bit-identical.
        let mut g = std::mem::take(&mut w.grad_acc);
        comm.all_reduce_mean(w.stage, w.replica, live, step as u32, &mut g)?;
        w.grad_acc = g;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// DiLoCo: Nesterov outer step over an all-reduced mean outer gradient
// ---------------------------------------------------------------------

/// DiLoCo (Douillard et al. 2023): m local steps, then a blocking outer
/// all-reduce.
pub struct DilocoSync {
    /// Nesterov momentum α.
    pub alpha: f32,
    /// Outer learning rate β.
    pub beta: f32,
}

impl SyncStrategy for DilocoSync {
    fn name(&self) -> &'static str {
        "diloco"
    }

    fn pattern(&self) -> CommPattern {
        CommPattern::AllReduce
    }

    fn has_outer(&self) -> bool {
        true
    }

    fn churn_response(&self) -> ChurnResponse {
        ChurnResponse::Abort
    }

    fn offer_outer(
        &mut self,
        comm: &mut dyn Communicator,
        w: &WorkerState,
        _live: &[usize],
        outer_idx: u64,
    ) -> Result<()> {
        comm.offer_reduce(w.stage, w.replica, outer_idx as u32, &w.outer_grad())
    }

    fn apply_outer(
        &mut self,
        comm: &mut dyn Communicator,
        eng: &mut Engine,
        w: &mut WorkerState,
        live: &[usize],
        outer_idx: u64,
    ) -> Result<()> {
        let mut dmean = w.outer_grad();
        comm.all_reduce_mean(w.stage, w.replica, live, outer_idx as u32, &mut dmean)?;
        let (kind, mut phi, mut delta) =
            (w.kind, std::mem::take(&mut w.phi), std::mem::take(&mut w.delta));
        exec::outer_diloco(eng, kind, &mut phi, &mut delta, &dmean, self.alpha, self.beta)?;
        w.phi = phi;
        w.delta = delta;
        w.reset_theta_to_phi();
        Ok(())
    }
}

// ---------------------------------------------------------------------
// NoLoCo: gossip-group modified-Nesterov outer step (Eq. 2–3)
// ---------------------------------------------------------------------

/// NoLoCo: m local steps, then the modified Nesterov gossip update over
/// random disjoint groups drawn by a [`PairingPolicy`].
pub struct NolocoSync {
    outer: OuterConfig,
    seed: u64,
    dp: usize,
    churn: ChurnSchedule,
    pairing: Box<dyn PairingPolicy>,
    /// Memoized last draw, keyed by `(stage, outer_idx, live)`: the offer
    /// and fold phases (and, on the grid executor, every worker of a
    /// stage row) share one partition instead of re-drawing it.
    cache: Option<(usize, u64, Vec<usize>, Vec<Vec<usize>>)>,
    /// Reusable fold accumulators — the boundary path allocates no fresh
    /// `dsum`/`psum` per fold.
    scratch: FoldScratch,
}

impl NolocoSync {
    /// New strategy over the given pairing policy.
    pub fn new(
        outer: OuterConfig,
        seed: u64,
        dp: usize,
        churn: ChurnSchedule,
        pairing: Box<dyn PairingPolicy>,
    ) -> NolocoSync {
        NolocoSync { outer, seed, dp, churn, pairing, cache: None, scratch: FoldScratch::default() }
    }

    fn my_group(&mut self, live: &[usize], stage: usize, outer_idx: u64, me: usize) -> Vec<usize> {
        let hit = matches!(
            &self.cache,
            Some((s, o, l, _)) if *s == stage && *o == outer_idx && l.as_slice() == live
        );
        if !hit {
            let groups = self.pairing.draw(live, self.outer.group, stage, outer_idx, self.seed);
            self.cache = Some((stage, outer_idx, live.to_vec(), groups));
        }
        let (_, _, _, groups) = self.cache.as_ref().expect("cached above");
        groups
            .iter()
            .find(|g| g.contains(&me))
            .expect("pairing policy must cover every live replica")
            .clone()
    }

    /// A column is *stale* at outer boundary `outer_idx` if it was dead at
    /// any step of the closing round (or the previous boundary): its
    /// `(Δ, φ)` predate the ensemble's. Derived from the shared schedule,
    /// so every worker agrees without coordination.
    fn is_stale(&self, r: usize, outer_idx: u64) -> bool {
        if self.churn.is_empty() {
            return false;
        }
        let step = (outer_idx as usize * self.outer.inner_steps).saturating_sub(1);
        let window_start = step.saturating_sub(self.outer.inner_steps);
        (window_start..=step).any(|s| !self.churn.live_at(self.dp, s as u64)[r])
    }
}

impl SyncStrategy for NolocoSync {
    fn name(&self) -> &'static str {
        "noloco"
    }

    fn pattern(&self) -> CommPattern {
        CommPattern::GossipPairs
    }

    fn has_outer(&self) -> bool {
        true
    }

    fn churn_response(&self) -> ChurnResponse {
        ChurnResponse::Repair
    }

    fn offer_outer(
        &mut self,
        comm: &mut dyn Communicator,
        w: &WorkerState,
        live: &[usize],
        outer_idx: u64,
    ) -> Result<()> {
        let me = w.replica;
        let group = self.my_group(live, w.stage, outer_idx, me);
        let peers: Vec<usize> = group.iter().copied().filter(|&r| r != me).collect();
        comm.offer_state(w.stage, me, &peers, outer_idx as u32, &w.outer_grad(), &w.phi)
    }

    fn apply_outer(
        &mut self,
        comm: &mut dyn Communicator,
        eng: &mut Engine,
        w: &mut WorkerState,
        live: &[usize],
        outer_idx: u64,
    ) -> Result<()> {
        let me = w.replica;
        let seq = outer_idx as u32;
        let group = self.my_group(live, w.stage, outer_idx, me);
        // Collect every member's (Δ, φ) in group order; `None` marks a
        // peer that missed the straggler deadline.
        let my_delta = w.outer_grad();
        let mut avail: Vec<Option<(Vec<f32>, Vec<f32>)>> = Vec::with_capacity(group.len());
        for &r in &group {
            if r == me {
                avail.push(Some((my_delta.clone(), w.phi.clone())));
            } else {
                avail.push(comm.collect_state(w.stage, me, r, seq)?);
            }
        }
        // Message-passing rejoin catch-up (the grid executor instead hands
        // a joiner a donor's φ at the join event): a stale member adopts
        // the first fresh peer's slow weights outright, and the fresh side
        // drops stale contributions so they cannot dilute its state. Two
        // stale members paired together fall through to the plain averaged
        // update — neither has fresh state to offer, and the γ-consensus
        // term pulls them back toward the ensemble over later boundaries.
        if !comm.supports_join_bootstrap() && !self.churn.is_empty() {
            if self.is_stale(me, outer_idx) {
                for (i, &r) in group.iter().enumerate() {
                    if r == me || self.is_stale(r, outer_idx) {
                        continue;
                    }
                    if let Some((_, p_theirs)) = &avail[i] {
                        w.phi.copy_from_slice(p_theirs);
                        for d in w.delta.iter_mut() {
                            *d = 0.0;
                        }
                        w.reset_theta_to_phi();
                        return Ok(());
                    }
                }
            } else {
                for (i, &r) in group.iter().enumerate() {
                    if r != me && self.is_stale(r, outer_idx) {
                        avail[i] = None;
                    }
                }
            }
        }
        // Fold the available members in group order; a group that shrank
        // to one (odd live count, timeout, stale peers) degrades to a
        // singleton update — NoLoCo's graceful form of the situation where
        // a collective would simply hang.
        let n = w.len();
        let (dsum, psum) = self.scratch.zeroed(n);
        let mut gn = 0usize;
        for (d, p) in avail.iter().flatten() {
            for (a, x) in dsum.iter_mut().zip(d) {
                *a += x;
            }
            for (a, x) in psum.iter_mut().zip(p) {
                *a += x;
            }
            gn += 1;
        }
        let (alpha, beta, gamma) = (
            self.outer.alpha as f32,
            self.outer.beta as f32,
            self.outer.gamma as f32,
        );
        let (kind, mut phi, mut delta) =
            (w.kind, std::mem::take(&mut w.phi), std::mem::take(&mut w.delta));
        exec::outer_noloco(
            eng, kind, &mut phi, &mut delta, dsum, psum, alpha, beta, gamma,
            1.0 / gn as f32,
        )?;
        w.phi = phi;
        w.delta = delta;
        w.reset_theta_to_phi();
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Pairing policies
// ---------------------------------------------------------------------

/// How NoLoCo's gossip groups are drawn each outer round. Must return a
/// disjoint cover of `live` in groups of `group` members (at most one
/// smaller leftover group), deterministically in
/// `(live, stage, outer_idx, seed)` — every worker re-derives the same
/// partition with zero coordination traffic.
pub trait PairingPolicy: Send + Sync {
    /// Policy name for reports.
    fn name(&self) -> &'static str;

    /// Draw the round's groups over `live` (ascending DP replica ids).
    fn draw(
        &self,
        live: &[usize],
        group: usize,
        stage: usize,
        outer_idx: u64,
        seed: u64,
    ) -> Vec<Vec<usize>>;

    /// Draw the round's groups for one *fragment* of the outer state.
    /// The default ignores the fragment — every fragment of a round
    /// shares one partition, the classic single-partner gossip.
    /// [`PerFragmentPairing`] overrides this so each fragment draws its
    /// own partner, mixing K× faster per round at the same total
    /// payload. Must satisfy the same disjoint-cover contract as
    /// [`PairingPolicy::draw`] for every fragment independently.
    fn draw_for_fragment(
        &self,
        live: &[usize],
        group: usize,
        stage: usize,
        frag: u16,
        outer_idx: u64,
        seed: u64,
    ) -> Vec<Vec<usize>> {
        let _ = frag;
        self.draw(live, group, stage, outer_idx, seed)
    }
}

/// Uniform random disjoint groups — the seed derivation, bit-for-bit:
/// `Pcg64(seed ^ 0x9055 ^ (stage << 40) ^ outer_idx)` over live positions.
pub struct UniformPairing;

impl PairingPolicy for UniformPairing {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn draw(
        &self,
        live: &[usize],
        group: usize,
        stage: usize,
        outer_idx: u64,
        seed: u64,
    ) -> Vec<Vec<usize>> {
        let mut prng = Pcg64::seed_from_u64(seed ^ 0x9055 ^ ((stage as u64) << 40) ^ outer_idx);
        prng.random_groups(live.len(), group)
            .into_iter()
            .map(|g| g.into_iter().map(|i| live[i]).collect())
            .collect()
    }
}

/// Region-biased pairing over a network [`Topology`]: groups are drawn
/// inside a region (cheap links) whenever possible, with per-region
/// leftovers paired uniformly across regions. Every
/// [`cross_every`](BandwidthAwarePairing::with_cross_every)-th round
/// falls back to a full uniform draw so the gossip graph keeps mixing
/// globally — without it, even region sizes would partition the ensemble
/// and the γ-consensus term could never equalize regions.
pub struct BandwidthAwarePairing {
    topo: Topology,
    cross_every: u64,
}

impl BandwidthAwarePairing {
    /// New policy over `topo` (replica `r` ↦ topology node `r`), mixing
    /// uniformly every 4th round.
    pub fn new(topo: Topology) -> BandwidthAwarePairing {
        BandwidthAwarePairing { topo, cross_every: 4 }
    }

    /// Override the uniform-round cadence (0 disables uniform rounds —
    /// only safe when region sizes guarantee cross-region leftovers).
    pub fn with_cross_every(mut self, cross_every: u64) -> BandwidthAwarePairing {
        self.cross_every = cross_every;
        self
    }

    fn region_of(&self, replica: usize) -> usize {
        if replica < self.topo.world() {
            self.topo.region_of(replica)
        } else {
            replica % self.topo.regions()
        }
    }
}

impl PairingPolicy for BandwidthAwarePairing {
    fn name(&self) -> &'static str {
        "bandwidth-aware"
    }

    fn draw(
        &self,
        live: &[usize],
        group: usize,
        stage: usize,
        outer_idx: u64,
        seed: u64,
    ) -> Vec<Vec<usize>> {
        if self.cross_every > 0 && outer_idx % self.cross_every == 0 {
            return UniformPairing.draw(live, group, stage, outer_idx, seed);
        }
        let mut prng =
            Pcg64::seed_from_u64(seed ^ 0xba9d_11a5 ^ ((stage as u64) << 40) ^ outer_idx);
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); self.topo.regions()];
        for &r in live {
            buckets[self.region_of(r)].push(r);
        }
        let mut groups = Vec::new();
        let mut leftovers = Vec::new();
        for bucket in &mut buckets {
            prng.shuffle(bucket);
            let full = bucket.len() - bucket.len() % group;
            for c in bucket[..full].chunks(group) {
                groups.push(c.to_vec());
            }
            leftovers.extend_from_slice(&bucket[full..]);
        }
        prng.shuffle(&mut leftovers);
        for c in leftovers.chunks(group) {
            groups.push(c.to_vec());
        }
        groups
    }
}

/// One-entry memo for a boundary's pairing draws, shared by the gossip
/// strategies: keyed by `(stage, outer_idx, live)`, holding a lazily
/// filled slot per fragment — only fragments actually requested are
/// drawn (streaming asks for one per boundary; the async engine for
/// all of them). The grid executor calls the offer and fold phases for
/// every worker of a stage row with identical inputs, so one set of
/// draws serves the whole row instead of being re-derived per worker
/// per phase.
pub(crate) struct PairingCache {
    entry: Option<(usize, u64, Vec<usize>, Vec<Option<Vec<Vec<usize>>>>)>,
}

impl PairingCache {
    /// Empty cache.
    pub(crate) fn new() -> PairingCache {
        PairingCache { entry: None }
    }

    /// The group containing `me` for fragment `frag` (of `fragments`),
    /// drawing and memoizing that fragment's partition on a miss.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn my_group(
        &mut self,
        pairing: &dyn PairingPolicy,
        live: &[usize],
        group: usize,
        stage: usize,
        frag: u16,
        fragments: usize,
        outer_idx: u64,
        seed: u64,
        me: usize,
    ) -> Vec<usize> {
        let hit = matches!(
            &self.entry,
            Some((s, o, l, _)) if *s == stage && *o == outer_idx && l.as_slice() == live
        );
        if !hit {
            self.entry = Some((stage, outer_idx, live.to_vec(), vec![None; fragments.max(1)]));
        }
        let (_, _, _, draws) = self.entry.as_mut().expect("keyed above");
        let slot = &mut draws[frag as usize];
        if slot.is_none() {
            *slot = Some(pairing.draw_for_fragment(live, group, stage, frag, outer_idx, seed));
        }
        slot.as_ref()
            .expect("filled above")
            .iter()
            .find(|g| g.contains(&me))
            .expect("pairing policy must cover every live replica")
            .clone()
    }
}

/// Per-fragment pairing: every fragment of a round draws its *own*
/// disjoint partition by perturbing the round seed with the fragment
/// index, so a replica gossips each (Δ_k, φ_k) slice with a different
/// partner. Wraps any inner policy (uniform here by construction — the
/// fragment perturbation composes with the inner policy's own bias).
/// With one fragment this reduces to the inner policy's draw with a
/// shifted seed: a valid partition, just a different one — selecting
/// `--pairing per-fragment` opts into a new partner sequence.
pub struct PerFragmentPairing {
    inner: Box<dyn PairingPolicy>,
}

impl PerFragmentPairing {
    /// Wrap `inner`, fragment-perturbing its seed.
    pub fn new(inner: Box<dyn PairingPolicy>) -> PerFragmentPairing {
        PerFragmentPairing { inner }
    }

    fn frag_seed(seed: u64, frag: u16) -> u64 {
        seed ^ (frag as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15)
    }
}

impl PairingPolicy for PerFragmentPairing {
    fn name(&self) -> &'static str {
        "per-fragment"
    }

    fn draw(
        &self,
        live: &[usize],
        group: usize,
        stage: usize,
        outer_idx: u64,
        seed: u64,
    ) -> Vec<Vec<usize>> {
        self.draw_for_fragment(live, group, stage, 0, outer_idx, seed)
    }

    fn draw_for_fragment(
        &self,
        live: &[usize],
        group: usize,
        stage: usize,
        frag: u16,
        outer_idx: u64,
        seed: u64,
    ) -> Vec<Vec<usize>> {
        self.inner
            .draw(live, group, stage, outer_idx, Self::frag_seed(seed, frag))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NetPreset, NetTopoConfig};

    fn assert_valid_partition(groups: &[Vec<usize>], live: &[usize], group: usize) {
        let mut seen: Vec<usize> = groups.iter().flatten().copied().collect();
        seen.sort_unstable();
        let mut want = live.to_vec();
        want.sort_unstable();
        assert_eq!(seen, want, "groups must cover the live set exactly once");
        let short = groups.iter().filter(|g| g.len() < group).count();
        assert!(short <= 1, "at most one leftover group, got {short}");
        for g in groups {
            assert!(!g.is_empty() && g.len() <= group);
        }
    }

    #[test]
    fn uniform_pairing_reproduces_seed_derivation() {
        // Golden: the policy must replicate the exact inline draw both
        // pre-redesign executors used — Pcg64(seed ^ 0x9055 ^ (stage << 40)
        // ^ outer_idx) pairs over live *positions*, mapped through `live`.
        let live = vec![0usize, 2, 3, 5, 6];
        for (seed, stage, outer_idx) in [(0x0107c0u64, 0usize, 1u64), (42, 1, 7), (9, 3, 100)] {
            let mut prng =
                Pcg64::seed_from_u64(seed ^ 0x9055 ^ ((stage as u64) << 40) ^ outer_idx);
            let want: Vec<Vec<usize>> = prng
                .random_pairs(live.len())
                .into_iter()
                .map(|(a, b)| match b {
                    Some(b) => vec![live[a], live[b]],
                    None => vec![live[a]],
                })
                .collect();
            let got = UniformPairing.draw(&live, 2, stage, outer_idx, seed);
            assert_eq!(got, want, "seed={seed} stage={stage} outer={outer_idx}");
        }
        // General group sizes replicate the grid executor's random_groups.
        let mut prng = Pcg64::seed_from_u64(11 ^ 0x9055 ^ (2u64 << 40) ^ 5);
        let want: Vec<Vec<usize>> = prng
            .random_groups(live.len(), 3)
            .into_iter()
            .map(|g| g.into_iter().map(|i| live[i]).collect())
            .collect();
        assert_eq!(UniformPairing.draw(&live, 3, 2, 5, 11), want);
    }

    #[test]
    fn per_fragment_pairing_varies_partners_but_keeps_valid_partitions() {
        let live: Vec<usize> = (0..8).collect();
        let p = PerFragmentPairing::new(Box::new(UniformPairing));
        let mut distinct = false;
        let base = p.draw_for_fragment(&live, 2, 0, 0, 5, 7);
        assert_valid_partition(&base, &live, 2);
        for frag in 1..4u16 {
            let g = p.draw_for_fragment(&live, 2, 0, frag, 5, 7);
            assert_valid_partition(&g, &live, 2);
            distinct |= g != base;
            // Deterministic per (fragment, round): redrawing agrees.
            assert_eq!(g, p.draw_for_fragment(&live, 2, 0, frag, 5, 7));
        }
        assert!(distinct, "fragments must be able to draw different partners");
        // The plain draw is fragment 0's partition (one coherent story
        // for code paths that never learned about fragments).
        assert_eq!(p.draw(&live, 2, 0, 5, 7), base);
        // The default-impl passthrough on other policies ignores frag.
        assert_eq!(
            UniformPairing.draw_for_fragment(&live, 2, 0, 3, 5, 7),
            UniformPairing.draw(&live, 2, 0, 5, 7)
        );
    }

    #[test]
    fn property_pairing_policies_emit_perfect_matchings() {
        let wan = NetTopoConfig {
            preset: NetPreset::MultiRegionWan,
            regions: 3,
            ..NetTopoConfig::default()
        };
        crate::prop::run("pairing policies partition the live set", 120, |g| {
            let dp = g.usize_in(2, 16).max(2);
            let group = g.usize_in(2, 4).max(2);
            let seed = g.rng().next_u64();
            let outer_idx = 1 + g.rng().next_u64() % 50;
            let stage = g.usize_in(0, 3);
            // Random live subset of size >= 1.
            let live: Vec<usize> = (0..dp).filter(|_| g.bool()).collect();
            let live = if live.is_empty() { vec![0] } else { live };
            let uni = UniformPairing.draw(&live, group, stage, outer_idx, seed);
            assert_valid_partition(&uni, &live, group);
            let ba = BandwidthAwarePairing::new(wan.build(dp, seed));
            let groups = ba.draw(&live, group, stage, outer_idx, seed);
            assert_valid_partition(&groups, &live, group);
        });
    }

    #[test]
    fn bandwidth_aware_cuts_wan_sync_time_but_keeps_mixing() {
        // 12 replicas over 3 WAN regions: region-biased rounds pair
        // entirely inside regions (4 per region, even), so the expected
        // slowest-pair transfer collapses vs the uniform draw, while the
        // periodic uniform rounds keep cross-region edges appearing.
        let wan = NetTopoConfig {
            preset: NetPreset::MultiRegionWan,
            regions: 3,
            ..NetTopoConfig::default()
        };
        let dp = 12;
        let topo = wan.build(dp, 7);
        let live: Vec<usize> = (0..dp).collect();
        let payload = 2u64 * (4 << 20); // both directions of (Δ, φ)
        let round_cost = |groups: &[Vec<usize>]| -> f64 {
            groups
                .iter()
                .filter(|g| g.len() == 2)
                .map(|g| topo.expected_transfer(g[0], g[1], payload))
                .fold(0.0, f64::max)
        };
        let ba = BandwidthAwarePairing::new(wan.build(dp, 7));
        let (mut uni_sum, mut ba_sum, mut cross_seen) = (0.0, 0.0, false);
        let rounds = 60u64;
        for outer_idx in 1..=rounds {
            uni_sum += round_cost(&UniformPairing.draw(&live, 2, 0, outer_idx, 7));
            let groups = ba.draw(&live, 2, 0, outer_idx, 7);
            ba_sum += round_cost(&groups);
            cross_seen |= groups
                .iter()
                .any(|g| g.len() == 2 && topo.region_of(g[0]) != topo.region_of(g[1]));
        }
        let (uni_mean, ba_mean) = (uni_sum / rounds as f64, ba_sum / rounds as f64);
        assert!(
            ba_mean < uni_mean * 0.7,
            "bandwidth-aware should cut WAN sync time: {ba_mean:.3}s vs {uni_mean:.3}s"
        );
        assert!(cross_seen, "mixing rounds must still produce cross-region pairs");
    }

    #[test]
    fn strategy_factory_matches_method() {
        let mut cfg = crate::config::presets::preset("tiny").unwrap();
        let s = for_config(&cfg);
        assert_eq!(s.name(), "noloco");
        assert_eq!(s.pattern(), CommPattern::GossipPairs);
        assert_eq!(s.churn_response(), ChurnResponse::Repair);
        assert!(s.has_outer());
        cfg = crate::config::presets::as_fsdp(cfg);
        let s = for_config(&cfg);
        assert_eq!(s.name(), "fsdp");
        assert_eq!(s.pattern(), CommPattern::AllReduce);
        assert_eq!(s.churn_response(), ChurnResponse::Abort);
        assert!(!s.has_outer());
        cfg = crate::config::presets::as_diloco(cfg);
        let s = for_config(&cfg);
        assert_eq!(s.name(), "diloco");
        assert!(s.has_outer());
        assert_eq!(s.churn_response(), ChurnResponse::Abort);
        // The bandwidth-aware policy is selectable from config.
        cfg.outer.method = Method::NoLoCo;
        cfg.outer.gamma = OuterConfig::default_gamma(cfg.outer.alpha, cfg.outer.group);
        cfg.pairing = PairingMode::BandwidthAware;
        let s = for_config(&cfg);
        assert_eq!(s.name(), "noloco");
        // Streaming sync wraps the configured flavor for both outer
        // methods and keeps its churn/pattern semantics.
        cfg.sync = SyncMode::Streaming;
        let s = for_config(&cfg);
        assert_eq!(s.name(), "streaming");
        assert_eq!(s.pattern(), CommPattern::GossipPairs);
        assert_eq!(s.churn_response(), ChurnResponse::Repair);
        cfg = crate::config::presets::as_diloco(cfg);
        cfg.sync = SyncMode::Streaming;
        let s = for_config(&cfg);
        assert_eq!(s.name(), "streaming");
        assert_eq!(s.pattern(), CommPattern::AllReduce);
        assert_eq!(s.churn_response(), ChurnResponse::Abort);
    }

    #[test]
    fn staleness_window_matches_schedule() {
        // Replica 1 dead for steps 2..=4 (leave at 2, join at 5) with
        // m = 2: boundaries close after steps 1, 3, 5, 7. It is stale at
        // outer 2 and 3 (dead inside the window) and fresh again at 4.
        let outer = OuterConfig {
            method: Method::NoLoCo,
            alpha: 0.5,
            beta: 0.7,
            gamma: OuterConfig::default_gamma(0.5, 2),
            group: 2,
            inner_steps: 2,
            staleness: 1,
        };
        let churn = ChurnSchedule::none().leave(2, 1).join(5, 1);
        let s = NolocoSync::new(outer, 0, 2, churn, Box::new(UniformPairing));
        assert!(!s.is_stale(1, 1));
        assert!(s.is_stale(1, 2));
        assert!(s.is_stale(1, 3));
        assert!(!s.is_stale(1, 4));
        assert!(!s.is_stale(0, 2), "the surviving column is never stale");
    }
}
