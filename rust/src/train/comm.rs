//! The [`Communicator`] abstraction: *how* bytes move between workers.
//!
//! [`TrainerCore`](super::TrainerCore) drives the same DP × PP grid walk
//! and the same [`SyncStrategy`](super::SyncStrategy) impls over either
//! communicator:
//!
//! * [`AccountingComm`] — the single-process executor's substrate: payloads
//!   are handed over through an in-memory mailbox and *accounted* (what
//!   would cross the network) instead of transported. Peer state published
//!   with [`Communicator::offer_state`] / [`Communicator::offer_reduce`]
//!   is read back directly, which is why the grid executor can fold a
//!   whole stage row without any scheduling.
//! * [`FabricComm`] — one per worker thread, wrapping a
//!   [`Fabric`](crate::net::Fabric) [`Endpoint`]: every hand-off is a real
//!   tagged message, collectives run the tree algorithm from
//!   [`crate::collective`], and gossip reads honour the optional straggler
//!   timeout.
//! * [`SocketComm`] — one per OS *process*, wrapping a
//!   [`SocketEndpoint`]: the same stash discipline over real TCP streams
//!   (length-prefixed, CRC32-framed — see [`crate::net::socket`]).
//!
//! The latter two are one generic impl, [`EndpointComm`]`<E:`
//! [`Channel`]`>`: every protocol decision (tag packing, stash retention,
//! collect/stash-back asymmetry, expiry sweeps, unmetered replay) lives
//! here once, and the transport only moves tagged payloads.
//!
//! The protocol is two-phase per synchronization round: every participant
//! first *offers* its contribution (`offer_reduce` / `offer_state`), then
//! folds peers' contributions (`all_reduce_mean` / `collect_state`). On
//! the fabric the offer eagerly sends (one RTT per gossip pair, exactly
//! the seed behaviour); on the accounting substrate it populates the
//! mailbox the fold phase reads.
//!
//! ## Streamed fragments
//!
//! The streaming strategy ([`StreamingSync`](super::StreamingSync))
//! stretches the two phases across *boundaries*: fragment `k` of the
//! (Δ, φ) state is offered at outer boundary `t` and folded at `t + 1`,
//! so the transfer rides behind the intervening inner phase instead of
//! gating it. [`Communicator::offer_fragment`] /
//! [`Communicator::collect_fragment`] carry that protocol for both
//! flavors — the gossip flavor ships (Δ_k, φ_k) to its pairs, the
//! streamed-DiLoCo flavor ships Δ_k alone (φ empty) all-to-all across
//! the row and averages locally. Payloads are tagged
//! `(round, fragment)` and — unlike `offer_state`, whose mailbox holds
//! exactly one round — stay readable after the *next* round's offers
//! begin. [`AccountingComm`] keeps an in-flight fragment buffer
//! garbage-collected two rounds back; [`FabricComm`] sends real tagged
//! messages whose `(round, fragment)` pair is packed into the tag's
//! sequence field (hence the 256-fragment cap enforced by
//! [`crate::config::TrainConfig::validate`]). Fragment messages the
//! receiver never collects — a churn event dropped the fold, or a
//! straggler timeout gave up on the pair — are garbage-collected by the
//! [`Communicator::expire_stale`] sweep once they are `outer.stash_age`
//! boundaries old (the trainers sweep once per boundary; `stash_age = 0`
//! restores the old keep-forever behaviour).
//!
//! ## Bounded-staleness rounds and heartbeats
//!
//! The asynchronous boundary engine
//! ([`AsyncGossipSync`](super::AsyncGossipSync)) needs two more
//! primitives:
//!
//! * [`Communicator::offer_round`] / [`Communicator::collect_round`] —
//!   like the fragment pair, but tagged with the boundary the offer was
//!   made at and retained for a declared window of boundaries, so a fold
//!   may admit a peer's offer from up to `outer.staleness − 1`
//!   boundaries back. Absence is a legitimate outcome (`Ok(None)`), not
//!   a protocol error: the engine degrades to older offers or a smaller
//!   group instead of blocking.
//! * [`Communicator::send_heartbeat`] / [`Communicator::poll_heartbeat`]
//!   — per-boundary liveness announcements to the stage row, consumed by
//!   the [`FailureDetector`](crate::net::FailureDetector). Polls never
//!   block: detection is an inference over what has already arrived.
//!
//! Accounting semantics (kept identical to the seed counters):
//! `activation_hops` / `floats_sent` count training-path activations,
//! gradients and sync payloads in f32 elements; `bytes_sent` /
//! `msgs_sent` count *everything shipped* (tokens and validation traffic
//! included) in wire bytes, mirroring what [`Fabric`](crate::net::Fabric)
//! meters on the threaded side so [`CommStats::mib_sent`] agrees between
//! executors.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::collective;
use crate::net::{Channel, Endpoint, Payload, SocketEndpoint, Tag};
use crate::obs::{Event, ObsHub};
use crate::tensor::Tensor;

use super::CommStats;

/// Stage-boundary tag kinds (collectives reserve 1..=4; gossip 110/111).
pub const K_ACT: u16 = 100;
/// Token shipment alongside activations.
pub const K_TOK: u16 = 101;
/// Backward-pass gradient w.r.t. the boundary activation.
pub const K_GRD: u16 = 102;
/// Validation activations.
pub const K_VACT: u16 = 103;
/// Validation tokens.
pub const K_VTOK: u16 = 104;
const K_GOSSIP_D: u16 = 110;
const K_GOSSIP_P: u16 = 111;
const K_FRAG_D: u16 = 112;
const K_FRAG_P: u16 = 113;
const K_HB: u16 = 114;
const K_ASYNC_D: u16 = 115;
const K_ASYNC_P: u16 = 116;

/// Pack a `(round, fragment)` pair into one 32-bit sequence value for
/// fragment-tagged messages and fragment reduce rounds. Fragment counts
/// are capped at 256 by config validation, so the low byte is the
/// fragment and the rest the (wrapping) round counter.
pub(crate) fn frag_seq(seq: u32, frag: u16) -> u32 {
    seq.wrapping_mul(256).wrapping_add(frag as u32 & 0xff)
}

/// Tag of one stage-boundary payload: kind + wave (or eval slot) + origin
/// replica. Unique per in-flight payload on both substrates.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct BoundaryTag {
    /// Payload kind (`K_ACT`, `K_TOK`, `K_GRD`, `K_VACT`, `K_VTOK`).
    pub kind: u16,
    /// Microbatch wave (training) or eval slot (validation).
    pub a: u32,
    /// Origin replica whose path this payload belongs to.
    pub origin: u32,
}

impl BoundaryTag {
    /// Construct a tag.
    pub fn new(kind: u16, a: u32, origin: u32) -> BoundaryTag {
        BoundaryTag { kind, a, origin }
    }
}

/// A boundary payload: activations / gradients or token ids.
#[derive(Clone, Debug)]
pub enum Wire {
    /// Dense activations or gradients.
    F32(Vec<f32>),
    /// Token ids (host-side i32, shipped as u32 on the fabric).
    I32(Vec<i32>),
}

impl Wire {
    /// Take the f32 vector (panics on tokens — kinds define types).
    pub fn into_f32(self) -> Vec<f32> {
        match self {
            Wire::F32(v) => v,
            Wire::I32(_) => panic!("expected an f32 boundary payload, got tokens"),
        }
    }

    /// Take the token vector (panics on f32 payloads).
    pub fn into_i32(self) -> Vec<i32> {
        match self {
            Wire::I32(v) => v,
            Wire::F32(_) => panic!("expected a token boundary payload, got f32s"),
        }
    }

    fn len(&self) -> usize {
        match self {
            Wire::F32(v) => v.len(),
            Wire::I32(v) => v.len(),
        }
    }
}

/// A borrowed-or-owned view of an offered `(Δ, φ)` pair — the zero-copy
/// collect path.
///
/// The in-memory mailbox ([`AccountingComm`]) retains every offer in a
/// stash anyway, so a fold can accumulate straight off borrowed slices
/// instead of cloning the payload per collect (at `O(1000)` replicas the
/// clones dominate boundary cost). Transports that deserialize off a
/// wire return the owned flavor; [`FragView::into_owned`] bridges to the
/// owning collect API either way.
pub enum FragView<'a> {
    /// Slices lent out of the communicator's retention stash.
    Borrowed(&'a [f32], &'a [f32]),
    /// Owned buffers (deserialized off a wire, or via the default
    /// wrappers over the owning collects).
    Owned(Vec<f32>, Vec<f32>),
}

impl FragView<'_> {
    /// The offered Δ payload.
    pub fn delta(&self) -> &[f32] {
        match self {
            FragView::Borrowed(d, _) => d,
            FragView::Owned(d, _) => d,
        }
    }

    /// The offered φ payload.
    pub fn phi(&self) -> &[f32] {
        match self {
            FragView::Borrowed(_, p) => p,
            FragView::Owned(_, p) => p,
        }
    }

    /// Materialize the pair (copies only the borrowed flavor).
    pub fn into_owned(self) -> (Vec<f32>, Vec<f32>) {
        match self {
            FragView::Borrowed(d, p) => (d.to_vec(), p.to_vec()),
            FragView::Owned(d, p) => (d, p),
        }
    }
}

/// How an executor moves payloads between workers of the grid.
///
/// Implementations are SPMD from the worker's point of view: the grid
/// executor simply plays every rank's part itself. All methods take the
/// caller's `(stage, replica)` coordinates so one communicator instance
/// can serve any number of locally-owned workers.
pub trait Communicator {
    /// Executor name for reports ("sim" / "threaded").
    fn executor(&self) -> &'static str;

    /// Whether a joining replica can be handed a live donor's state
    /// directly (single-process grids). When `false`, the NoLoCo strategy
    /// recovers a rejoiner through its first gossip exchange instead.
    fn supports_join_bootstrap(&self) -> bool;

    /// Ship a stage-boundary payload to worker `to`.
    fn send_boundary(&mut self, to: (usize, usize), tag: BoundaryTag, data: Wire) -> Result<()>;

    /// Receive the boundary payload addressed to worker `at` under `tag`.
    fn recv_boundary(&mut self, at: (usize, usize), tag: BoundaryTag) -> Result<Wire>;

    /// Phase 1 of a mean all-reduce: publish this worker's contribution
    /// for round `seq`. No-op on the fabric (the collective sends inline).
    fn offer_reduce(&mut self, stage: usize, me: usize, seq: u32, buf: &[f32]) -> Result<()>;

    /// Phase 2: overwrite `buf` with the elementwise mean over `replicas`
    /// (ascending, must include `me`) of the stage row. Blocking
    /// collective; counted once per row (at `replicas[0]`).
    fn all_reduce_mean(
        &mut self,
        stage: usize,
        me: usize,
        replicas: &[usize],
        seq: u32,
        buf: &mut Vec<f32>,
    ) -> Result<()>;

    /// Phase 1 of a gossip round: publish `(Δ, φ)` to `peers` (same stage
    /// row) under round `seq`. On the fabric this eagerly sends both
    /// payloads (one RTT per pair).
    fn offer_state(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        seq: u32,
        delta: &[f32],
        phi: &[f32],
    ) -> Result<()>;

    /// Phase 2: collect `peer`'s offered `(Δ, φ)`. `None` means the peer
    /// missed the straggler deadline (fabric only) and the caller should
    /// degrade to a smaller group.
    fn collect_state(
        &mut self,
        stage: usize,
        me: usize,
        peer: usize,
        seq: u32,
    ) -> Result<Option<(Vec<f32>, Vec<f32>)>>;

    /// Zero-copy variant of [`Communicator::collect_state`]: same
    /// semantics (including the error and straggler cases), but the
    /// payload comes back as a [`FragView`] the fold can accumulate from
    /// without owning it. The default wraps the owning collect;
    /// stash-retaining communicators override it to lend slices.
    fn collect_state_view(
        &mut self,
        stage: usize,
        me: usize,
        peer: usize,
        seq: u32,
    ) -> Result<Option<FragView<'_>>> {
        Ok(self
            .collect_state(stage, me, peer, seq)?
            .map(|(d, p)| FragView::Owned(d, p)))
    }

    /// Streamed-fragment phase 1: publish fragment `frag` of this
    /// worker's `(Δ, φ)` to `peers` under round `seq`. Unlike
    /// [`Communicator::offer_state`], the offer survives the next round's
    /// offers — the fold may happen one boundary later (see the module
    /// docs on streamed fragments).
    #[allow(clippy::too_many_arguments)]
    fn offer_fragment(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        seq: u32,
        frag: u16,
        delta: &[f32],
        phi: &[f32],
    ) -> Result<()>;

    /// Streamed-fragment phase 2: collect `peer`'s fragment `frag` offered
    /// under round `seq`. `None` means the peer missed the straggler
    /// deadline (fabric only); the caller folds a smaller group.
    fn collect_fragment(
        &mut self,
        stage: usize,
        me: usize,
        peer: usize,
        seq: u32,
        frag: u16,
    ) -> Result<Option<(Vec<f32>, Vec<f32>)>>;

    /// Zero-copy variant of [`Communicator::collect_fragment`] (see
    /// [`Communicator::collect_state_view`]).
    fn collect_fragment_view(
        &mut self,
        stage: usize,
        me: usize,
        peer: usize,
        seq: u32,
        frag: u16,
    ) -> Result<Option<FragView<'_>>> {
        Ok(self
            .collect_fragment(stage, me, peer, seq, frag)?
            .map(|(d, p)| FragView::Owned(d, p)))
    }

    /// Bounded-staleness phase 1: publish fragment `frag` of this
    /// worker's `(Δ, φ)` under the boundary `round` it is offered at,
    /// retained for `window` rounds (so a fold up to `window − 1`
    /// boundaries later can still admit it). Unlike
    /// [`Communicator::offer_fragment`]'s fixed two-round retention, the
    /// window is the engine's staleness knob.
    #[allow(clippy::too_many_arguments)]
    fn offer_round(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        round: u32,
        frag: u16,
        window: u32,
        delta: &[f32],
        phi: &[f32],
    ) -> Result<()>;

    /// Bounded-staleness phase 2: collect `peer`'s fragment `frag`
    /// offered under `round`. `Ok(None)` means the offer is not
    /// available — expired, never made, or (fabric, `wait = true`) past
    /// the straggler deadline — and the caller degrades to an older
    /// round or a smaller group. `wait` distinguishes the peer's current
    /// round (worth blocking/waiting for) from older fallback rounds
    /// (checked against what already arrived, never waited on).
    fn collect_round(
        &mut self,
        stage: usize,
        me: usize,
        peer: usize,
        round: u32,
        frag: u16,
        wait: bool,
    ) -> Result<Option<(Vec<f32>, Vec<f32>)>>;

    /// Zero-copy variant of [`Communicator::collect_round`] (see
    /// [`Communicator::collect_state_view`]).
    #[allow(clippy::too_many_arguments)]
    fn collect_round_view(
        &mut self,
        stage: usize,
        me: usize,
        peer: usize,
        round: u32,
        frag: u16,
        wait: bool,
    ) -> Result<Option<FragView<'_>>> {
        Ok(self
            .collect_round(stage, me, peer, round, frag, wait)?
            .map(|(d, p)| FragView::Owned(d, p)))
    }

    /// Announce liveness at outer `boundary` to the stage-row `peers`
    /// (a tiny control message; consumed by the failure detector).
    fn send_heartbeat(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        boundary: u32,
    ) -> Result<()>;

    /// Non-blocking check whether `peer`'s heartbeat for `boundary` has
    /// arrived at this worker. Never waits — detection infers from what
    /// is already here.
    fn poll_heartbeat(
        &mut self,
        stage: usize,
        me: usize,
        peer: usize,
        boundary: u32,
    ) -> Result<bool>;

    /// Stash-expiry sweep: drop retained sync payloads (streamed
    /// fragments, bounded-staleness rounds, gossip offers, heartbeats)
    /// older than `before_round`, returning how many were dropped.
    /// Boundary payloads are untouched — their tags are wave-scoped and
    /// always consumed. The trainers call this once per outer boundary
    /// with `outer_idx − outer.stash_age`.
    fn expire_stale(&mut self, before_round: u32) -> u64;

    /// Communication accounting so far.
    fn stats(&self) -> &CommStats;

    /// Attach an observability hub. Communicators that report per-peer
    /// `offer`/`fold` journal events keep the handle; the default
    /// ignores it (a disabled hub costs nothing either way).
    fn set_obs(&mut self, _hub: ObsHub) {}

    /// Tell the communicator which outer `boundary` it is serving and
    /// the sim-clock stamp (`sim`, global inner-step index) to put on
    /// the events it emits — the trainers call this once per boundary.
    /// The boundary is the reference for fold-age derivation
    /// (`age = boundary − offered round`).
    fn set_obs_boundary(&mut self, _boundary: u64, _sim: u64) {}

    /// This communicator's wire totals `(bytes_sent, msgs_sent)` — the
    /// counters the journal's `boundary`/`drain` events attribute. The
    /// default reads [`Communicator::stats`]; the fabric overrides it
    /// with the endpoint's own metering (the trainer overwrites fabric
    /// stats post-hoc, so its local `stats()` wire fields stay zero).
    fn wire_totals(&self) -> (u64, u64) {
        let s = self.stats();
        (s.bytes_sent, s.msgs_sent)
    }

    // --- crash-recovery hooks (sender-replay resume protocol) ---
    //
    // Receiver-side stashes are never serialized: a checkpoint records
    // each rank's *own* retained offers, and a resumed rank re-publishes
    // them through the `replay_*` methods — unmetered (no stats, no
    // journal events, no fault-RNG draws), because the original sends
    // were already accounted before the checkpoint was cut. Both
    // substrates converge to the same post-resume state: the accounting
    // mailbox repopulates its retention maps, the fabric re-delivers the
    // messages into peers' channels.

    /// Re-publish a retained streamed-fragment offer after a resume.
    #[allow(clippy::too_many_arguments)]
    fn replay_fragment(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        seq: u32,
        frag: u16,
        delta: &[f32],
        phi: &[f32],
    ) -> Result<()> {
        let _ = (stage, me, peers, seq, frag, delta, phi);
        Ok(())
    }

    /// Re-publish a retained bounded-staleness offer after a resume.
    #[allow(clippy::too_many_arguments)]
    fn replay_round(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        round: u32,
        frag: u16,
        delta: &[f32],
        phi: &[f32],
    ) -> Result<()> {
        let _ = (stage, me, peers, round, frag, delta, phi);
        Ok(())
    }

    /// Re-announce the checkpoint boundary's heartbeat after a resume
    /// (so peers' detectors keep seeing this rank alive).
    fn replay_heartbeat(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        boundary: u32,
    ) -> Result<()> {
        let _ = (stage, me, peers, boundary);
        Ok(())
    }

    /// Restore checkpointed accounting so resumed counters continue
    /// cumulatively (wire fields are restored separately on the fabric
    /// via [`Communicator::restore_wire_totals`]).
    fn restore_stats(&mut self, stats: &CommStats) {
        let _ = stats;
    }

    /// Fault-injection RNG stream `(state, inc)` of the underlying
    /// transport, if it has one (fabric only).
    fn fault_rng_state(&self) -> Option<(u128, u128)> {
        None
    }

    /// Restore a checkpointed fault-RNG stream so post-resume fault
    /// draws continue the original sequence.
    fn restore_fault_rng(&mut self, state: u128, inc: u128) {
        let _ = (state, inc);
    }

    /// Restore this rank's transport wire counters (fabric only).
    fn restore_wire_totals(&mut self, bytes: u64, msgs: u64) {
        let _ = (bytes, msgs);
    }
}

// ---------------------------------------------------------------------
// Accounting communicator (single-process grid executor)
// ---------------------------------------------------------------------

/// In-memory mailbox communicator for the grid executor. See the module
/// docs for the counting semantics.
pub struct AccountingComm {
    stats: CommStats,
    /// Boundary payloads in flight, keyed by destination + tag.
    /// `BTreeMap` (not `HashMap`) everywhere in this struct: fold and
    /// sweep order must never depend on hasher state (analyze R2).
    boundary: BTreeMap<(usize, usize, BoundaryTag), Wire>,
    /// Published reduction contributions for the current round.
    reduces: BTreeMap<(usize, usize), Vec<f32>>,
    reduce_seq: u32,
    /// Published gossip `(Δ, φ)` for the current round.
    offers: BTreeMap<(usize, usize), (Vec<f32>, Vec<f32>)>,
    offer_seq: u32,
    /// Streamed fragment offers in flight, keyed by
    /// `(stage, replica, round, fragment)`. Entries persist across
    /// boundaries (an overlapped fold reads the *previous* round's offers
    /// after the current round began) and are garbage-collected two
    /// rounds back.
    frags: BTreeMap<(usize, usize, u32, u16), (Vec<f32>, Vec<f32>)>,
    /// Bounded-staleness offers keyed `(stage, replica, round, fragment)`,
    /// each retained for its offerer's declared window of rounds.
    rounds: BTreeMap<(usize, usize, u32, u16), (Vec<f32>, Vec<f32>)>,
    /// Latest boundary heartbeat per `(stage, replica)`.
    hearts: BTreeMap<(usize, usize), u32>,
    /// Observability sink (disabled unless the trainer attaches one).
    hub: ObsHub,
    /// Outer boundary currently being served (fold-age reference).
    cur_boundary: u64,
    /// Sim-clock stamp for emitted events (global inner-step index).
    cur_sim: u64,
}

impl AccountingComm {
    /// Fresh communicator with zeroed counters.
    pub fn new() -> AccountingComm {
        AccountingComm {
            stats: CommStats::default(),
            boundary: BTreeMap::new(),
            reduces: BTreeMap::new(),
            reduce_seq: 0,
            offers: BTreeMap::new(),
            offer_seq: 0,
            frags: BTreeMap::new(),
            rounds: BTreeMap::new(),
            hearts: BTreeMap::new(),
            hub: ObsHub::disabled(),
            cur_boundary: 0,
            cur_sim: 0,
        }
    }
}

impl Default for AccountingComm {
    fn default() -> AccountingComm {
        AccountingComm::new()
    }
}

impl Communicator for AccountingComm {
    fn executor(&self) -> &'static str {
        "sim"
    }

    fn supports_join_bootstrap(&self) -> bool {
        true
    }

    fn send_boundary(&mut self, to: (usize, usize), tag: BoundaryTag, data: Wire) -> Result<()> {
        let n = data.len() as u64;
        self.stats.msgs_sent += 1;
        self.stats.bytes_sent += 4 * n;
        if matches!(tag.kind, K_ACT | K_GRD) {
            // Training-path activations/gradients: the seed counters.
            self.stats.activation_hops += 1;
            self.stats.floats_sent += n;
        }
        self.boundary.insert((to.0, to.1, tag), data);
        Ok(())
    }

    fn recv_boundary(&mut self, at: (usize, usize), tag: BoundaryTag) -> Result<Wire> {
        match self.boundary.remove(&(at.0, at.1, tag)) {
            Some(w) => Ok(w),
            None => bail!(
                "boundary payload {tag:?} for worker ({}, {}) was never sent \
                 (grid walk ordering bug)",
                at.0,
                at.1
            ),
        }
    }

    fn offer_reduce(&mut self, stage: usize, me: usize, seq: u32, buf: &[f32]) -> Result<()> {
        if seq != self.reduce_seq {
            self.reduces.clear();
            self.reduce_seq = seq;
        }
        self.reduces.insert((stage, me), buf.to_vec());
        Ok(())
    }

    fn all_reduce_mean(
        &mut self,
        stage: usize,
        me: usize,
        replicas: &[usize],
        seq: u32,
        buf: &mut Vec<f32>,
    ) -> Result<()> {
        if seq != self.reduce_seq {
            bail!("all_reduce_mean round {seq} folded before any offer (expected {})", self.reduce_seq);
        }
        let k = replicas.len();
        let mut mean = vec![0.0f32; buf.len()];
        for &r in replicas {
            let Some(c) = self.reduces.get(&(stage, r)) else {
                bail!("replica {r} of stage {stage} never offered to reduce round {seq}");
            };
            for (m, x) in mean.iter_mut().zip(c) {
                *m += x / k as f32;
            }
        }
        *buf = mean;
        if me == replicas[0] {
            // One blocking collective per stage row; tree cost: every edge
            // carries the payload twice (reduce up + broadcast down).
            let n = buf.len() as u64;
            let edges = 2 * (k as u64 - 1);
            self.stats.blocking_collectives += 1;
            self.stats.floats_sent += edges * n;
            self.stats.msgs_sent += edges;
            self.stats.bytes_sent += edges * 4 * n;
        }
        Ok(())
    }

    fn offer_state(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        seq: u32,
        delta: &[f32],
        phi: &[f32],
    ) -> Result<()> {
        if seq != self.offer_seq {
            self.offers.clear();
            self.offer_seq = seq;
        }
        self.offers.insert((stage, me), (delta.to_vec(), phi.to_vec()));
        let n = delta.len() as u64;
        let p = peers.len() as u64;
        // Each member ships (Δ, φ) to each peer; symmetric pair exchanges
        // are counted once (by the lower-numbered side).
        self.stats.pair_exchanges += peers.iter().filter(|&&q| q > me).count() as u64;
        self.stats.floats_sent += p * 2 * n;
        self.stats.msgs_sent += p * 2;
        self.stats.bytes_sent += p * 2 * 4 * n;
        for &q in peers {
            self.hub.record(
                self.cur_sim,
                Event::Offer {
                    stage,
                    replica: me,
                    peer: q,
                    round: u64::from(seq),
                    frag: 0,
                    bytes: 2 * 4 * n,
                },
            );
        }
        Ok(())
    }

    fn collect_state(
        &mut self,
        stage: usize,
        me: usize,
        peer: usize,
        seq: u32,
    ) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        Ok(self
            .collect_state_view(stage, me, peer, seq)?
            .map(FragView::into_owned))
    }

    fn collect_state_view(
        &mut self,
        stage: usize,
        me: usize,
        peer: usize,
        seq: u32,
    ) -> Result<Option<FragView<'_>>> {
        if seq != self.offer_seq {
            bail!("gossip round {seq} collected before any offer (expected {})", self.offer_seq);
        }
        match self.offers.get(&(stage, peer)) {
            Some(dp) => {
                self.hub.record(
                    self.cur_sim,
                    Event::Fold {
                        stage,
                        replica: me,
                        peer,
                        round: u64::from(seq),
                        frag: 0,
                        age: 0,
                        bytes: 4 * (dp.0.len() + dp.1.len()) as u64,
                    },
                );
                Ok(Some(FragView::Borrowed(&dp.0, &dp.1)))
            }
            None => bail!("replica {peer} of stage {stage} never offered to gossip round {seq}"),
        }
    }

    fn offer_fragment(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        seq: u32,
        frag: u16,
        delta: &[f32],
        phi: &[f32],
    ) -> Result<()> {
        // Keep this round and the previous (its folds may still be due);
        // anything older was either folded or dropped as stale.
        self.frags.retain(|&(_, _, s, _), _| s + 2 > seq);
        self.frags.insert((stage, me, seq, frag), (delta.to_vec(), phi.to_vec()));
        // Same counting rules as `offer_state`, at fragment granularity:
        // each member ships its payload to each peer, symmetric pairs
        // counted once by the lower-numbered side. The payload is the
        // *actual* element count — (Δ_k, φ_k) for the gossip flavor, Δ_k
        // alone (φ empty) for the streamed-DiLoCo all-to-all.
        let n = (delta.len() + phi.len()) as u64;
        let p = peers.len() as u64;
        self.stats.pair_exchanges += peers.iter().filter(|&&q| q > me).count() as u64;
        self.stats.floats_sent += p * n;
        self.stats.msgs_sent += p * 2;
        self.stats.bytes_sent += p * 4 * n;
        for &q in peers {
            self.hub.record(
                self.cur_sim,
                Event::Offer {
                    stage,
                    replica: me,
                    peer: q,
                    round: u64::from(seq),
                    frag,
                    bytes: 4 * n,
                },
            );
        }
        Ok(())
    }

    fn collect_fragment(
        &mut self,
        stage: usize,
        me: usize,
        peer: usize,
        seq: u32,
        frag: u16,
    ) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        Ok(self
            .collect_fragment_view(stage, me, peer, seq, frag)?
            .map(FragView::into_owned))
    }

    fn collect_fragment_view(
        &mut self,
        stage: usize,
        me: usize,
        peer: usize,
        seq: u32,
        frag: u16,
    ) -> Result<Option<FragView<'_>>> {
        match self.frags.get(&(stage, peer, seq, frag)) {
            Some(dp) => {
                self.hub.record(
                    self.cur_sim,
                    Event::Fold {
                        stage,
                        replica: me,
                        peer,
                        round: u64::from(seq),
                        frag,
                        age: self.cur_boundary.saturating_sub(u64::from(seq)),
                        bytes: 4 * (dp.0.len() + dp.1.len()) as u64,
                    },
                );
                Ok(Some(FragView::Borrowed(&dp.0, &dp.1)))
            }
            None => bail!(
                "replica {peer} of stage {stage} never offered fragment {frag} of round {seq}"
            ),
        }
    }

    fn offer_round(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        round: u32,
        frag: u16,
        window: u32,
        delta: &[f32],
        phi: &[f32],
    ) -> Result<()> {
        // Per-replica GC: this worker's rounds older than its own window
        // can no longer be admitted by any fold.
        self.rounds.retain(|&(s, r, rd, _), _| {
            s != stage || r != me || rd.saturating_add(window) > round
        });
        self.rounds.insert((stage, me, round, frag), (delta.to_vec(), phi.to_vec()));
        // Same counting rules as `offer_fragment`: actual element count,
        // symmetric pairs counted once by the lower-numbered side.
        let n = (delta.len() + phi.len()) as u64;
        let p = peers.len() as u64;
        self.stats.pair_exchanges += peers.iter().filter(|&&q| q > me).count() as u64;
        self.stats.floats_sent += p * n;
        self.stats.msgs_sent += p * 2;
        self.stats.bytes_sent += p * 4 * n;
        for &q in peers {
            self.hub.record(
                self.cur_sim,
                Event::Offer {
                    stage,
                    replica: me,
                    peer: q,
                    round: u64::from(round),
                    frag,
                    bytes: 4 * n,
                },
            );
        }
        Ok(())
    }

    fn collect_round(
        &mut self,
        stage: usize,
        me: usize,
        peer: usize,
        round: u32,
        frag: u16,
        wait: bool,
    ) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        Ok(self
            .collect_round_view(stage, me, peer, round, frag, wait)?
            .map(FragView::into_owned))
    }

    fn collect_round_view(
        &mut self,
        stage: usize,
        me: usize,
        peer: usize,
        round: u32,
        frag: u16,
        _wait: bool,
    ) -> Result<Option<FragView<'_>>> {
        let got = self.rounds.get(&(stage, peer, round, frag));
        if let Some(dp) = got {
            self.hub.record(
                self.cur_sim,
                Event::Fold {
                    stage,
                    replica: me,
                    peer,
                    round: u64::from(round),
                    frag,
                    age: self.cur_boundary.saturating_sub(u64::from(round)),
                    bytes: 4 * (dp.0.len() + dp.1.len()) as u64,
                },
            );
        }
        Ok(got.map(|dp| FragView::Borrowed(&dp.0, &dp.1)))
    }

    fn send_heartbeat(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        boundary: u32,
    ) -> Result<()> {
        let slot = self.hearts.entry((stage, me)).or_insert(0);
        *slot = (*slot).max(boundary);
        // Control-sized messages, like the fabric's Payload::Control.
        self.stats.msgs_sent += peers.len() as u64;
        self.stats.bytes_sent += 8 * peers.len() as u64;
        Ok(())
    }

    fn poll_heartbeat(
        &mut self,
        stage: usize,
        _me: usize,
        peer: usize,
        boundary: u32,
    ) -> Result<bool> {
        Ok(self.hearts.get(&(stage, peer)).is_some_and(|&b| b >= boundary))
    }

    fn expire_stale(&mut self, before_round: u32) -> u64 {
        let before_len = (self.rounds.len() + self.frags.len()) as u64;
        self.rounds.retain(|&(_, _, rd, _), _| rd >= before_round);
        self.frags.retain(|&(_, _, rd, _), _| rd >= before_round);
        before_len - (self.rounds.len() + self.frags.len()) as u64
    }

    fn stats(&self) -> &CommStats {
        &self.stats
    }

    fn set_obs(&mut self, hub: ObsHub) {
        self.hub = hub;
    }

    fn set_obs_boundary(&mut self, boundary: u64, sim: u64) {
        self.cur_boundary = boundary;
        self.cur_sim = sim;
    }

    fn replay_fragment(
        &mut self,
        stage: usize,
        me: usize,
        _peers: &[usize],
        seq: u32,
        frag: u16,
        delta: &[f32],
        phi: &[f32],
    ) -> Result<()> {
        // Straight re-insertion: no metering, no GC (the next real offer
        // re-applies the retention rule over the replayed rounds).
        self.frags.insert((stage, me, seq, frag), (delta.to_vec(), phi.to_vec()));
        Ok(())
    }

    fn replay_round(
        &mut self,
        stage: usize,
        me: usize,
        _peers: &[usize],
        round: u32,
        frag: u16,
        delta: &[f32],
        phi: &[f32],
    ) -> Result<()> {
        self.rounds.insert((stage, me, round, frag), (delta.to_vec(), phi.to_vec()));
        Ok(())
    }

    fn replay_heartbeat(
        &mut self,
        stage: usize,
        me: usize,
        _peers: &[usize],
        boundary: u32,
    ) -> Result<()> {
        let slot = self.hearts.entry((stage, me)).or_insert(0);
        *slot = (*slot).max(boundary);
        Ok(())
    }

    fn restore_stats(&mut self, stats: &CommStats) {
        self.stats = stats.clone();
    }
}

// ---------------------------------------------------------------------
// Fabric communicator (threaded executor, one per worker thread)
// ---------------------------------------------------------------------

/// Message-passing communicator over one tagged-message [`Channel`].
///
/// Logical counters ([`CommStats`]) follow the same once-per-row /
/// once-per-pair rules as [`AccountingComm`] so summing worker stats
/// reproduces the grid executor's totals; `bytes_sent` / `msgs_sent` are
/// left to the channel's own wire metering (the trainer overwrites them
/// from [`Fabric::bytes_sent`](crate::net::Fabric::bytes_sent) on the
/// threaded executor; the socket executor reads
/// [`Communicator::wire_totals`] per rank).
pub struct EndpointComm<E: Channel> {
    ep: E,
    dp: usize,
    /// Straggler tolerance for gossip collects; `None` = wait forever.
    gossip_timeout: Option<Duration>,
    stats: CommStats,
    /// Observability sink (disabled unless the trainer attaches one).
    hub: ObsHub,
    /// Outer boundary currently being served (fold-age reference).
    cur_boundary: u64,
    /// Sim-clock stamp for emitted events (global inner-step index).
    cur_sim: u64,
}

/// The threaded executor's communicator: one per worker thread, over an
/// in-process fabric [`Endpoint`].
pub type FabricComm = EndpointComm<Endpoint>;

/// The socket executor's communicator: one per OS process, over a TCP
/// [`SocketEndpoint`].
pub type SocketComm = EndpointComm<SocketEndpoint>;

impl<E: Channel> EndpointComm<E> {
    /// Wrap a channel. `dp` maps `(stage, replica)` to transport ranks.
    pub fn new(ep: E, dp: usize, gossip_timeout: Option<Duration>) -> EndpointComm<E> {
        EndpointComm {
            ep,
            dp,
            gossip_timeout,
            stats: CommStats::default(),
            hub: ObsHub::disabled(),
            cur_boundary: 0,
            cur_sim: 0,
        }
    }

    fn rank_of(&self, stage: usize, replica: usize) -> usize {
        stage * self.dp + replica
    }

    /// Borrow the underlying channel (the socket executor reads per-peer
    /// wire counters off it for the obs journal).
    pub fn channel(&self) -> &E {
        &self.ep
    }
}

impl<E: Channel> Communicator for EndpointComm<E> {
    fn executor(&self) -> &'static str {
        self.ep.executor_name()
    }

    fn supports_join_bootstrap(&self) -> bool {
        false
    }

    fn send_boundary(&mut self, to: (usize, usize), tag: BoundaryTag, data: Wire) -> Result<()> {
        let n = data.len() as u64;
        if matches!(tag.kind, K_ACT | K_GRD) {
            self.stats.activation_hops += 1;
            self.stats.floats_sent += n;
        }
        let payload = match data {
            Wire::F32(v) => Payload::F32(v),
            Wire::I32(v) => Payload::U32(v.iter().map(|&t| t as u32).collect()),
        };
        let rank = self.rank_of(to.0, to.1);
        self.ep.send(rank, Tag::new(tag.kind, tag.a, tag.origin), payload);
        Ok(())
    }

    fn recv_boundary(&mut self, _at: (usize, usize), tag: BoundaryTag) -> Result<Wire> {
        let msg = self.ep.recv(Tag::new(tag.kind, tag.a, tag.origin));
        Ok(match msg.payload {
            Payload::F32(v) => Wire::F32(v),
            Payload::U32(v) => Wire::I32(v.iter().map(|&t| t as i32).collect()),
            Payload::Control => bail!("unexpected control payload under boundary tag {tag:?}"),
        })
    }

    fn offer_reduce(&mut self, _stage: usize, _me: usize, _seq: u32, _buf: &[f32]) -> Result<()> {
        Ok(()) // the tree collective sends inline during the fold phase
    }

    fn all_reduce_mean(
        &mut self,
        stage: usize,
        me: usize,
        replicas: &[usize],
        seq: u32,
        buf: &mut Vec<f32>,
    ) -> Result<()> {
        let ranks: Vec<usize> = replicas.iter().map(|&r| self.rank_of(stage, r)).collect();
        let n = buf.len();
        let mut t = Tensor::from_vec(std::mem::take(buf), &[n]);
        collective::all_reduce_mean(&mut self.ep, &ranks, seq, &mut t);
        *buf = t.into_vec();
        if me == replicas[0] {
            let k = replicas.len() as u64;
            self.stats.blocking_collectives += 1;
            self.stats.floats_sent += 2 * (k - 1) * n as u64;
        }
        Ok(())
    }

    fn offer_state(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        seq: u32,
        delta: &[f32],
        phi: &[f32],
    ) -> Result<()> {
        let my_rank = self.rank_of(stage, me) as u32;
        for &p in peers {
            let rank = self.rank_of(stage, p);
            self.ep
                .send(rank, Tag::new(K_GOSSIP_D, seq, my_rank), Payload::F32(delta.to_vec()));
            self.ep
                .send(rank, Tag::new(K_GOSSIP_P, seq, my_rank), Payload::F32(phi.to_vec()));
            self.hub.record(
                self.cur_sim,
                Event::Offer {
                    stage,
                    replica: me,
                    peer: p,
                    round: u64::from(seq),
                    frag: 0,
                    bytes: 4 * (delta.len() + phi.len()) as u64,
                },
            );
        }
        self.stats.pair_exchanges += peers.iter().filter(|&&q| q > me).count() as u64;
        self.stats.floats_sent += peers.len() as u64 * 2 * delta.len() as u64;
        Ok(())
    }

    fn collect_state(
        &mut self,
        stage: usize,
        me: usize,
        peer: usize,
        seq: u32,
    ) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        let peer_rank = self.rank_of(stage, peer) as u32;
        let td = Tag::new(K_GOSSIP_D, seq, peer_rank);
        let tp = Tag::new(K_GOSSIP_P, seq, peer_rank);
        // Trailing late messages after a timeout are absorbed harmlessly by
        // the endpoint stash (tags are unique per outer round).
        let got = match self.gossip_timeout {
            None => Some((
                self.ep.recv(td).payload.into_f32(),
                self.ep.recv(tp).payload.into_f32(),
            )),
            Some(t) => {
                let Some(d) = self.ep.recv_timeout(td, t) else { return Ok(None) };
                let Some(p) = self.ep.recv_timeout(tp, t) else { return Ok(None) };
                Some((d.payload.into_f32(), p.payload.into_f32()))
            }
        };
        if let Some(dp) = &got {
            self.hub.record(
                self.cur_sim,
                Event::Fold {
                    stage,
                    replica: me,
                    peer,
                    round: u64::from(seq),
                    frag: 0,
                    age: 0,
                    bytes: 4 * (dp.0.len() + dp.1.len()) as u64,
                },
            );
        }
        Ok(got)
    }

    fn offer_fragment(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        seq: u32,
        frag: u16,
        delta: &[f32],
        phi: &[f32],
    ) -> Result<()> {
        let my_rank = self.rank_of(stage, me) as u32;
        let a = frag_seq(seq, frag);
        for &p in peers {
            let rank = self.rank_of(stage, p);
            self.ep
                .send(rank, Tag::new(K_FRAG_D, a, my_rank), Payload::F32(delta.to_vec()));
            self.ep
                .send(rank, Tag::new(K_FRAG_P, a, my_rank), Payload::F32(phi.to_vec()));
            self.hub.record(
                self.cur_sim,
                Event::Offer {
                    stage,
                    replica: me,
                    peer: p,
                    round: u64::from(seq),
                    frag,
                    bytes: 4 * (delta.len() + phi.len()) as u64,
                },
            );
        }
        self.stats.pair_exchanges += peers.iter().filter(|&&q| q > me).count() as u64;
        self.stats.floats_sent += peers.len() as u64 * (delta.len() + phi.len()) as u64;
        Ok(())
    }

    fn collect_fragment(
        &mut self,
        stage: usize,
        me: usize,
        peer: usize,
        seq: u32,
        frag: u16,
    ) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        let peer_rank = self.rank_of(stage, peer) as u32;
        let a = frag_seq(seq, frag);
        let td = Tag::new(K_FRAG_D, a, peer_rank);
        let tp = Tag::new(K_FRAG_P, a, peer_rank);
        let got = match self.gossip_timeout {
            None => Some((
                self.ep.recv(td).payload.into_f32(),
                self.ep.recv(tp).payload.into_f32(),
            )),
            Some(t) => {
                let Some(d) = self.ep.recv_timeout(td, t) else { return Ok(None) };
                let Some(p) = self.ep.recv_timeout(tp, t) else { return Ok(None) };
                Some((d.payload.into_f32(), p.payload.into_f32()))
            }
        };
        if let Some(dp) = &got {
            self.hub.record(
                self.cur_sim,
                Event::Fold {
                    stage,
                    replica: me,
                    peer,
                    round: u64::from(seq),
                    frag,
                    age: self.cur_boundary.saturating_sub(u64::from(seq)),
                    bytes: 4 * (dp.0.len() + dp.1.len()) as u64,
                },
            );
        }
        Ok(got)
    }

    fn offer_round(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        round: u32,
        frag: u16,
        _window: u32,
        delta: &[f32],
        phi: &[f32],
    ) -> Result<()> {
        // Retention is receiver-side on the fabric: messages sit in the
        // endpoint stash until collected or expired by `expire_stale`.
        let my_rank = self.rank_of(stage, me) as u32;
        let a = frag_seq(round, frag);
        for &p in peers {
            let rank = self.rank_of(stage, p);
            self.ep
                .send(rank, Tag::new(K_ASYNC_D, a, my_rank), Payload::F32(delta.to_vec()));
            self.ep
                .send(rank, Tag::new(K_ASYNC_P, a, my_rank), Payload::F32(phi.to_vec()));
            self.hub.record(
                self.cur_sim,
                Event::Offer {
                    stage,
                    replica: me,
                    peer: p,
                    round: u64::from(round),
                    frag,
                    bytes: 4 * (delta.len() + phi.len()) as u64,
                },
            );
        }
        self.stats.pair_exchanges += peers.iter().filter(|&&q| q > me).count() as u64;
        self.stats.floats_sent += peers.len() as u64 * (delta.len() + phi.len()) as u64;
        Ok(())
    }

    fn collect_round(
        &mut self,
        stage: usize,
        me: usize,
        peer: usize,
        round: u32,
        frag: u16,
        wait: bool,
    ) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        let peer_rank = self.rank_of(stage, peer) as u32;
        let a = frag_seq(round, frag);
        let td = Tag::new(K_ASYNC_D, a, peer_rank);
        let tp = Tag::new(K_ASYNC_P, a, peer_rank);
        // Unlike the single-shot gossip collects, round offers stay
        // *readable for the whole retention window* — a later boundary
        // may re-admit the same offer at a higher age, exactly as the
        // accounting communicator's retention map does — so every path
        // leaves the messages in the stash (the expiry sweep reclaims
        // them). Fallback rounds (`wait = false`) only consult what has
        // already arrived — never sleeping, not even on the latency
        // model; the current round honours the straggler timeout, or
        // blocks when none is configured (the peer's offer is certain).
        let got = match (wait, self.gossip_timeout) {
            (true, None) => {
                let d = self.ep.recv(td);
                let p = self.ep.recv(tp);
                let out = (d.payload.clone().into_f32(), p.payload.clone().into_f32());
                self.ep.stash_back(d);
                self.ep.stash_back(p);
                Some(out)
            }
            (true, Some(t)) => {
                let Some(d) = self.ep.recv_timeout(td, t) else { return Ok(None) };
                let Some(p) = self.ep.recv_timeout(tp, t) else {
                    self.ep.stash_back(d);
                    return Ok(None);
                };
                let out = (d.payload.clone().into_f32(), p.payload.clone().into_f32());
                self.ep.stash_back(d);
                self.ep.stash_back(p);
                Some(out)
            }
            (false, _) => {
                let Some(d) = self.ep.peek_ready(td) else { return Ok(None) };
                let Some(p) = self.ep.peek_ready(tp) else { return Ok(None) };
                Some((d.into_f32(), p.into_f32()))
            }
        };
        if let Some(dp) = &got {
            self.hub.record(
                self.cur_sim,
                Event::Fold {
                    stage,
                    replica: me,
                    peer,
                    round: u64::from(round),
                    frag,
                    age: self.cur_boundary.saturating_sub(u64::from(round)),
                    bytes: 4 * (dp.0.len() + dp.1.len()) as u64,
                },
            );
        }
        Ok(got)
    }

    fn send_heartbeat(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        boundary: u32,
    ) -> Result<()> {
        let my_rank = self.rank_of(stage, me) as u32;
        for &p in peers {
            let rank = self.rank_of(stage, p);
            self.ep.send(rank, Tag::new(K_HB, boundary, my_rank), Payload::Control);
        }
        Ok(())
    }

    fn poll_heartbeat(
        &mut self,
        stage: usize,
        _me: usize,
        peer: usize,
        boundary: u32,
    ) -> Result<bool> {
        let peer_rank = self.rank_of(stage, peer) as u32;
        let tag = Tag::new(K_HB, boundary, peer_rank);
        Ok(self.ep.try_recv_ready(tag).is_some())
    }

    fn expire_stale(&mut self, before_round: u32) -> u64 {
        self.ep.sweep_stash(&mut |t| match t.kind {
            K_GOSSIP_D | K_GOSSIP_P | K_HB => t.a >= before_round,
            K_FRAG_D | K_FRAG_P | K_ASYNC_D | K_ASYNC_P => t.a / 256 >= before_round,
            _ => true,
        }) as u64
    }

    fn stats(&self) -> &CommStats {
        &self.stats
    }

    fn set_obs(&mut self, hub: ObsHub) {
        self.hub = hub;
    }

    fn set_obs_boundary(&mut self, boundary: u64, sim: u64) {
        self.cur_boundary = boundary;
        self.cur_sim = sim;
    }

    fn wire_totals(&self) -> (u64, u64) {
        // The channel meters actual sends; the local stats' wire fields
        // stay zero on these executors (the trainer back-fills them from
        // the transport-wide counters post-run).
        self.ep.sent_totals()
    }

    fn replay_fragment(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        seq: u32,
        frag: u16,
        delta: &[f32],
        phi: &[f32],
    ) -> Result<()> {
        let my_rank = self.rank_of(stage, me) as u32;
        let a = frag_seq(seq, frag);
        for &p in peers {
            let rank = self.rank_of(stage, p);
            self.ep.send_unmetered(rank, Tag::new(K_FRAG_D, a, my_rank), Payload::F32(delta.to_vec()));
            self.ep.send_unmetered(rank, Tag::new(K_FRAG_P, a, my_rank), Payload::F32(phi.to_vec()));
        }
        Ok(())
    }

    fn replay_round(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        round: u32,
        frag: u16,
        delta: &[f32],
        phi: &[f32],
    ) -> Result<()> {
        let my_rank = self.rank_of(stage, me) as u32;
        let a = frag_seq(round, frag);
        for &p in peers {
            let rank = self.rank_of(stage, p);
            self.ep.send_unmetered(rank, Tag::new(K_ASYNC_D, a, my_rank), Payload::F32(delta.to_vec()));
            self.ep.send_unmetered(rank, Tag::new(K_ASYNC_P, a, my_rank), Payload::F32(phi.to_vec()));
        }
        Ok(())
    }

    fn replay_heartbeat(
        &mut self,
        stage: usize,
        me: usize,
        peers: &[usize],
        boundary: u32,
    ) -> Result<()> {
        let my_rank = self.rank_of(stage, me) as u32;
        for &p in peers {
            let rank = self.rank_of(stage, p);
            self.ep.send_unmetered(rank, Tag::new(K_HB, boundary, my_rank), Payload::Control);
        }
        Ok(())
    }

    fn restore_stats(&mut self, stats: &CommStats) {
        // Wire fields live in the transport's own counters on these
        // executors (restored via `restore_wire_totals`); the local copy
        // keeps only the logical counters, as before the crash.
        self.stats = CommStats { bytes_sent: 0, msgs_sent: 0, ..stats.clone() };
    }

    fn fault_rng_state(&self) -> Option<(u128, u128)> {
        self.ep.fault_rng_state()
    }

    fn restore_fault_rng(&mut self, state: u128, inc: u128) {
        self.ep.restore_fault_rng(state, inc);
    }

    fn restore_wire_totals(&mut self, bytes: u64, msgs: u64) {
        self.ep.restore_sent_totals(bytes, msgs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_boundary_roundtrip_and_counting() {
        let mut c = AccountingComm::new();
        let tag = BoundaryTag::new(K_ACT, 3, 1);
        c.send_boundary((1, 0), tag, Wire::F32(vec![1.0, 2.0, 3.0])).unwrap();
        assert_eq!(c.stats().activation_hops, 1);
        assert_eq!(c.stats().floats_sent, 3);
        assert_eq!(c.stats().bytes_sent, 12);
        assert_eq!(c.stats().msgs_sent, 1);
        let back = c.recv_boundary((1, 0), tag).unwrap().into_f32();
        assert_eq!(back, vec![1.0, 2.0, 3.0]);
        // A second receive of the same tag is a protocol bug.
        assert!(c.recv_boundary((1, 0), tag).is_err());
        // Tokens count bytes but not the seed's activation counters.
        c.send_boundary((1, 0), BoundaryTag::new(K_TOK, 3, 1), Wire::I32(vec![7, 8])).unwrap();
        assert_eq!(c.stats().activation_hops, 1);
        assert_eq!(c.stats().floats_sent, 3);
        assert_eq!(c.stats().bytes_sent, 20);
    }

    #[test]
    fn accounting_all_reduce_matches_row_mean() {
        let mut c = AccountingComm::new();
        c.offer_reduce(0, 0, 5, &[1.0, 3.0]).unwrap();
        c.offer_reduce(0, 1, 5, &[3.0, 5.0]).unwrap();
        let mut buf = vec![1.0, 3.0];
        c.all_reduce_mean(0, 0, &[0, 1], 5, &mut buf).unwrap();
        assert_eq!(buf, vec![2.0, 4.0]);
        // Counted once per row, with the seed's tree-edge payload model:
        // 2 · (k − 1) edges of n = 2 floats.
        assert_eq!(c.stats().blocking_collectives, 1);
        assert_eq!(c.stats().floats_sent, 4);
        let mut buf2 = vec![3.0, 5.0];
        c.all_reduce_mean(0, 1, &[0, 1], 5, &mut buf2).unwrap();
        assert_eq!(buf2, vec![2.0, 4.0]);
        assert_eq!(c.stats().blocking_collectives, 1, "fold at replica 1 must not recount");
    }

    #[test]
    fn accounting_gossip_offers_round_and_pair_counting() {
        let mut c = AccountingComm::new();
        c.offer_state(0, 0, &[1], 1, &[1.0], &[2.0]).unwrap();
        c.offer_state(0, 1, &[0], 1, &[3.0], &[4.0]).unwrap();
        assert_eq!(c.stats().pair_exchanges, 1, "pair counted once");
        assert_eq!(c.stats().floats_sent, 2 * 2, "both sides ship (Δ, φ)");
        let (d, p) = c.collect_state(0, 0, 1, 1).unwrap().unwrap();
        assert_eq!((d, p), (vec![3.0], vec![4.0]));
        // A new round clears the previous offers.
        c.offer_state(0, 0, &[], 2, &[9.0], &[9.0]).unwrap();
        assert!(c.collect_state(0, 0, 1, 2).is_err());
    }

    #[test]
    fn accounting_missing_offer_is_an_error() {
        let mut c = AccountingComm::new();
        c.offer_reduce(0, 0, 1, &[1.0]).unwrap();
        let mut buf = vec![1.0];
        assert!(c.all_reduce_mean(0, 0, &[0, 1], 1, &mut buf).is_err());
    }

    #[test]
    fn accounting_fragments_survive_the_next_round_then_expire() {
        let mut c = AccountingComm::new();
        c.offer_fragment(0, 0, &[1], 1, 0, &[1.0], &[2.0]).unwrap();
        c.offer_fragment(0, 1, &[0], 1, 0, &[3.0], &[4.0]).unwrap();
        // New round's offers do NOT clear the previous round's fragments —
        // the stale-fold contract of the streaming strategy.
        c.offer_fragment(0, 0, &[1], 2, 1, &[5.0], &[6.0]).unwrap();
        let (d, p) = c.collect_fragment(0, 0, 1, 1, 0).unwrap().unwrap();
        assert_eq!((d, p), (vec![3.0], vec![4.0]));
        // Two rounds on, round-1 fragments are garbage-collected.
        c.offer_fragment(0, 0, &[1], 3, 2, &[7.0], &[8.0]).unwrap();
        assert!(c.collect_fragment(0, 0, 1, 1, 0).is_err());
    }

    #[test]
    fn accounting_fragment_counting_matches_gossip_rules() {
        let mut c = AccountingComm::new();
        c.offer_fragment(0, 0, &[1], 1, 0, &[1.0, 2.0], &[3.0, 4.0]).unwrap();
        c.offer_fragment(0, 1, &[0], 1, 0, &[5.0, 6.0], &[7.0, 8.0]).unwrap();
        assert_eq!(c.stats().pair_exchanges, 1, "pair counted once per fragment round");
        assert_eq!(c.stats().floats_sent, 2 * 2 * 2, "both sides ship (Δ_k, φ_k)");
        assert_eq!(c.stats().msgs_sent, 4);
        assert_eq!(c.stats().bytes_sent, 4 * 4 * 2);
    }

    #[test]
    fn accounting_rounds_respect_the_declared_window() {
        let mut c = AccountingComm::new();
        c.offer_round(0, 1, &[0], 1, 0, 3, &[1.0], &[2.0]).unwrap();
        c.offer_round(0, 1, &[0], 2, 0, 3, &[3.0], &[4.0]).unwrap();
        c.offer_round(0, 1, &[0], 3, 0, 3, &[5.0], &[6.0]).unwrap();
        // All three rounds are inside the window of the latest offer.
        assert_eq!(c.collect_round(0, 0, 1, 1, 0, true).unwrap(), Some((vec![1.0], vec![2.0])));
        assert_eq!(c.collect_round(0, 0, 1, 3, 0, false).unwrap(), Some((vec![5.0], vec![6.0])));
        // Round 4 pushes round 1 out of the 3-round window.
        c.offer_round(0, 1, &[0], 4, 0, 3, &[7.0], &[8.0]).unwrap();
        assert_eq!(c.collect_round(0, 0, 1, 1, 0, true).unwrap(), None);
        assert_eq!(c.collect_round(0, 0, 1, 2, 0, true).unwrap(), Some((vec![3.0], vec![4.0])));
        // Absence is None, never an error.
        assert_eq!(c.collect_round(0, 0, 1, 9, 0, true).unwrap(), None);
        assert_eq!(c.collect_round(1, 0, 1, 2, 0, true).unwrap(), None);
    }

    #[test]
    fn accounting_heartbeats_poll_latest_boundary() {
        let mut c = AccountingComm::new();
        assert!(!c.poll_heartbeat(0, 0, 1, 1).unwrap());
        c.send_heartbeat(0, 1, &[0], 3).unwrap();
        assert!(c.poll_heartbeat(0, 0, 1, 3).unwrap());
        assert!(c.poll_heartbeat(0, 0, 1, 2).unwrap(), "later heartbeat covers earlier polls");
        assert!(!c.poll_heartbeat(0, 0, 1, 4).unwrap());
        // Stale re-announcements never roll the clock back.
        c.send_heartbeat(0, 1, &[0], 2).unwrap();
        assert!(c.poll_heartbeat(0, 0, 1, 3).unwrap());
        // Heartbeats are metered as control-sized wire traffic.
        assert_eq!(c.stats().msgs_sent, 2);
        assert_eq!(c.stats().bytes_sent, 16);
    }

    #[test]
    fn accounting_expire_drops_old_rounds_and_fragments() {
        let mut c = AccountingComm::new();
        c.offer_round(0, 0, &[1], 2, 0, 8, &[1.0], &[1.0]).unwrap();
        c.offer_round(0, 0, &[1], 5, 0, 8, &[2.0], &[2.0]).unwrap();
        c.offer_fragment(0, 1, &[0], 2, 0, &[3.0], &[3.0]).unwrap();
        assert_eq!(c.expire_stale(4), 2, "round 2 and fragment round 2 expire");
        assert_eq!(c.collect_round(0, 1, 0, 2, 0, true).unwrap(), None);
        assert_eq!(c.collect_round(0, 1, 0, 5, 0, true).unwrap(), Some((vec![2.0], vec![2.0])));
        assert!(c.collect_fragment(0, 0, 1, 2, 0).is_err());
    }

    #[test]
    fn accounting_replay_repopulates_stashes_without_metering() {
        let mut c = AccountingComm::new();
        c.replay_round(0, 1, &[0], 4, 1, &[1.0], &[2.0]).unwrap();
        c.replay_fragment(0, 1, &[0], 4, 0, &[3.0], &[4.0]).unwrap();
        c.replay_heartbeat(0, 1, &[0], 4).unwrap();
        assert_eq!(c.stats(), &CommStats::default(), "replays are unmetered");
        assert_eq!(c.collect_round(0, 0, 1, 4, 1, true).unwrap(), Some((vec![1.0], vec![2.0])));
        assert_eq!(
            c.collect_fragment(0, 0, 1, 4, 0).unwrap(),
            Some((vec![3.0], vec![4.0]))
        );
        assert!(c.poll_heartbeat(0, 0, 1, 4).unwrap());
    }

    #[test]
    fn accounting_restore_stats_resumes_counters_cumulatively() {
        let mut c = AccountingComm::new();
        let prior = CommStats { floats_sent: 10, msgs_sent: 3, bytes_sent: 40, ..Default::default() };
        c.restore_stats(&prior);
        c.send_boundary((1, 0), BoundaryTag::new(K_ACT, 0, 0), Wire::F32(vec![0.0; 5])).unwrap();
        assert_eq!(c.stats().floats_sent, 15);
        assert_eq!(c.stats().msgs_sent, 4);
        assert_eq!(c.stats().bytes_sent, 60);
    }

    #[test]
    fn fabric_replay_delivers_without_counting_or_fault_draws() {
        // Even under certain drop, replays arrive: they model traffic
        // that already survived the faulty wire before the checkpoint.
        let plan = crate::net::FaultPlan { drop_prob: 1.0, ..crate::net::FaultPlan::none() };
        let mut fabric = crate::net::Fabric::with_faults(2, plan, 77);
        let mut eps = fabric.take_endpoints().into_iter();
        let mut a = FabricComm::new(eps.next().unwrap(), 2, None);
        let mut b = FabricComm::new(eps.next().unwrap(), 2, None);
        let rng_before = a.fault_rng_state();
        a.replay_round(0, 0, &[1], 6, 2, &[1.5], &[2.5]).unwrap();
        a.replay_fragment(0, 0, &[1], 6, 0, &[3.5], &[4.5]).unwrap();
        a.replay_heartbeat(0, 0, &[1], 6).unwrap();
        assert_eq!(a.fault_rng_state(), rng_before, "replays draw no fault randomness");
        assert_eq!(a.wire_totals(), (0, 0), "replays are unmetered");
        assert_eq!(b.collect_round(0, 1, 0, 6, 2, false).unwrap(), Some((vec![1.5], vec![2.5])));
        assert_eq!(
            b.collect_fragment(0, 1, 0, 6, 0).unwrap(),
            Some((vec![3.5], vec![4.5]))
        );
        assert!(b.poll_heartbeat(0, 1, 0, 6).unwrap());
    }

    #[test]
    fn fabric_fault_rng_and_wire_totals_round_trip() {
        let plan = crate::net::FaultPlan { drop_prob: 0.3, ..crate::net::FaultPlan::none() };
        let mut fabric = crate::net::Fabric::with_faults(2, plan, 5);
        let mut eps = fabric.take_endpoints().into_iter();
        let mut a = FabricComm::new(eps.next().unwrap(), 2, None);
        let _b = eps.next().unwrap();
        let (state, inc) = a.fault_rng_state().unwrap();
        a.restore_fault_rng(state, inc);
        assert_eq!(a.fault_rng_state(), Some((state, inc)));
        a.restore_wire_totals(4096, 17);
        assert_eq!(a.wire_totals(), (4096, 17));
    }

    #[test]
    fn fabric_rounds_heartbeats_and_expiry() {
        let mut fabric = crate::net::Fabric::new(2);
        let mut eps = fabric.take_endpoints().into_iter();
        let mut a = FabricComm::new(eps.next().unwrap(), 2, None);
        let mut b = FabricComm::new(eps.next().unwrap(), 2, None);
        // Round offers land under their (round, frag) tag and are
        // collectable in any order; fallback collects never block.
        a.offer_round(0, 0, &[1], 3, 1, 4, &[1.0, 2.0], &[3.0, 4.0]).unwrap();
        a.offer_round(0, 0, &[1], 4, 1, 4, &[5.0], &[6.0]).unwrap();
        assert_eq!(
            b.collect_round(0, 1, 0, 4, 1, true).unwrap(),
            Some((vec![5.0], vec![6.0]))
        );
        assert_eq!(
            b.collect_round(0, 1, 0, 3, 1, false).unwrap(),
            Some((vec![1.0, 2.0], vec![3.0, 4.0]))
        );
        // A round never offered: the non-waiting collect reports None.
        assert_eq!(b.collect_round(0, 1, 0, 9, 1, false).unwrap(), None);
        // Heartbeats: poll is non-blocking and consumes the announcement.
        a.send_heartbeat(0, 0, &[1], 7).unwrap();
        assert!(b.poll_heartbeat(0, 1, 0, 7).unwrap());
        assert!(!b.poll_heartbeat(0, 1, 0, 8).unwrap());
        // Expiry sweeps uncollected old rounds out of the stash.
        a.offer_round(0, 0, &[1], 2, 0, 4, &[9.0], &[9.0]).unwrap();
        a.send_heartbeat(0, 0, &[1], 2).unwrap();
        let dropped = b.expire_stale(3);
        assert_eq!(dropped, 3, "two round payloads + one heartbeat expire");
        assert_eq!(b.collect_round(0, 1, 0, 2, 0, false).unwrap(), None);
    }

    #[test]
    fn obs_offers_and_folds_are_journaled_with_ages() {
        let hub = crate::obs::ObsHub::in_memory(crate::config::TraceLevel::Step);
        let mut c = AccountingComm::new();
        c.set_obs(hub.clone());
        c.set_obs_boundary(1, 50);
        c.offer_round(0, 1, &[0], 1, 0, 3, &[1.0, 2.0], &[3.0]).unwrap();
        // Fold one boundary later: age = 2 − 1 = 1.
        c.set_obs_boundary(2, 100);
        assert!(c.collect_round(0, 0, 1, 1, 0, false).unwrap().is_some());
        // A probe of a round never offered emits nothing.
        assert!(c.collect_round(0, 0, 1, 7, 0, false).unwrap().is_none());
        assert_eq!(hub.counter("offers"), 1);
        assert_eq!(hub.counter("folds"), 1);
        let evs = hub.events();
        assert_eq!(evs.len(), 2);
        match &evs[0] {
            Event::Offer { peer, round, bytes, .. } => {
                assert_eq!((*peer, *round, *bytes), (0, 1, 12));
            }
            other => panic!("expected an offer, got {other:?}"),
        }
        match &evs[1] {
            Event::Fold { round, age, bytes, .. } => {
                assert_eq!((*round, *age, *bytes), (1, 1, 12));
            }
            other => panic!("expected a fold, got {other:?}"),
        }
        assert_eq!(hub.report().fold_age_hist, vec![0, 1]);
    }

    #[test]
    fn frag_seq_packs_round_and_fragment_distinctly() {
        assert_eq!(frag_seq(1, 0), 256);
        assert_eq!(frag_seq(1, 1), 257);
        assert_eq!(frag_seq(2, 0), 512);
        assert_ne!(frag_seq(3, 7), frag_seq(7, 3));
    }
}
