//! Single-process training driver — a thin constructor over
//! [`TrainerCore`] with the [`AccountingComm`] communicator.
//!
//! One core owns the full DP × PP grid over one shared PJRT [`Engine`]:
//! the de-facto harness for the paper's convergence experiments (Tables
//! 2–3, Figs. 2–4), where wall-clock parallelism is irrelevant (one CPU
//! core) but *trajectory fidelity* is everything. Communication is
//! accounted (not transported): boundary payloads hand over through the
//! in-memory mailbox while [`CommStats`](super::CommStats) records what
//! would cross the network, which the latency analysis (Fig. 5) combines
//! with the [`crate::net::SimClock`] latency model.
//!
//! The synchronization behaviour (FSDP / DiLoCo / NoLoCo) lives entirely
//! in the shared [`SyncStrategy`](super::SyncStrategy) impls — the same
//! code the threaded executor runs.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::net::topo::ChurnEvent;
use crate::runtime::{Engine, Manifest};

use super::comm::AccountingComm;
use super::core::TrainerCore;
use super::state::WorkerState;
use super::{CommStats, TrainReport};

/// Single-threaded DP × PP trainer over one shared engine.
pub struct SimTrainer<'e> {
    core: TrainerCore<'e, AccountingComm>,
}

impl<'e> SimTrainer<'e> {
    /// Build the worker grid: identical per-stage init across replicas
    /// (φ₀,ᵢ ≡ φ₀), sharded loaders, pre-drawn validation set.
    pub fn new(cfg: TrainConfig, eng: &'e mut Engine) -> Result<SimTrainer<'e>> {
        Ok(SimTrainer { core: TrainerCore::new_grid(cfg, eng, AccountingComm::new())? })
    }

    /// Currently live DP replicas, ascending.
    pub fn live_replicas(&self) -> Vec<usize> {
        self.core.live_replicas()
    }

    /// Whether DP replica `r` is currently live.
    pub fn is_live(&self, r: usize) -> bool {
        self.core.is_live(r)
    }

    /// Apply one membership event (a whole DP column across all stages).
    /// The configured strategy decides: NoLoCo repairs, FSDP / DiLoCo
    /// abort (see [`TrainerCore::apply_churn`]).
    pub fn apply_churn(&mut self, event: ChurnEvent) -> Result<()> {
        self.core.apply_churn(event)
    }

    /// Fault injection for failure-detection tests: suppress `replica`'s
    /// heartbeats over inner steps `[from, until)` — a network partition
    /// with no schedule entry; the detector must notice and the repair
    /// machinery must absorb it (see [`TrainerCore::set_silence`]).
    pub fn with_silence(mut self, replica: usize, from_step: u64, until_step: u64) -> Self {
        self.core.set_silence(replica, from_step, until_step);
        self
    }

    /// Detection transitions `(boundary, event)` observed so far.
    pub fn detected_events(&self) -> &[(u64, ChurnEvent)] {
        self.core.detected_events()
    }

    /// Per-replica boundary clocks (boundaries each replica participated
    /// in so far).
    pub fn boundary_clocks(&self) -> &[u64] {
        self.core.boundary_clocks()
    }

    /// Run the configured number of inner steps; returns the report.
    pub fn run(&mut self) -> Result<TrainReport> {
        self.core.run()
    }

    /// One inner optimizer step (see [`TrainerCore::inner_step`]).
    /// Returns the mean training loss across microbatches.
    pub fn inner_step(&mut self, step: usize) -> Result<f64> {
        self.core.inner_step(step)
    }

    /// Outer optimizer step, delegated to the configured
    /// [`SyncStrategy`](super::SyncStrategy). `outer_idx` is the 1-based
    /// outer-step counter shared with the threaded executor, so the two
    /// follow identical trajectories given identical inputs.
    pub fn outer_step(&mut self, outer_idx: u64) -> Result<()> {
        self.core.outer_step(outer_idx)
    }

    /// Mean validation NLL over the fixed validation set, averaged across
    /// the *live* replicas (each evaluated through its own fixed-route
    /// pipeline).
    pub fn validate(&mut self) -> Result<f64> {
        self.core.validate()
    }

    /// Cross-replica weight standard deviation (Fig. 3B / Fig. 4A).
    pub fn weight_std(&self) -> f64 {
        self.core.weight_std()
    }

    /// Immutable access to a worker (tests / inspection).
    pub fn worker(&self, stage: usize, replica: usize) -> &WorkerState {
        self.core.worker(stage, replica)
    }

    /// Snapshot the whole worker grid — tensors, loader cursors, core
    /// runtime state and in-flight sync state (see
    /// [`super::Checkpoint`]). The `[ckpt]` cadence writes the same
    /// snapshot to disk automatically.
    #[allow(clippy::expect_used)] // grid ownership is this executor's invariant
    pub fn checkpoint(&self, step: u64) -> super::Checkpoint {
        self.core
            .checkpoint(step)
            .expect("the grid executor always owns the full grid")
    }

    /// Restore a snapshot's tensors into this grid; returns the
    /// snapshot's step. [`SimTrainer::resume_from`] is the
    /// full-fidelity path (loaders, clocks, accounting, in-flight sync
    /// state included).
    pub fn restore(&mut self, ck: &super::Checkpoint) -> Result<u64> {
        self.core.restore(ck)
    }

    /// Full-fidelity resume: restore everything a bit-identical
    /// continuation needs and arm the run loop to continue at the
    /// checkpoint's step (see [`TrainerCore::resume_from`]).
    pub fn resume_from(&mut self, ck: &super::Checkpoint) -> Result<()> {
        self.core.resume_from(ck)
    }

    /// Kill-restart drills: stop right after the `[ckpt]` cadence
    /// writes the checkpoint at `boundary` (see
    /// [`TrainerCore::set_halt_after`]).
    pub fn halt_after(mut self, boundary: u64) -> Self {
        self.core.set_halt_after(boundary);
        self
    }

    /// Current communication accounting.
    pub fn comm(&self) -> &CommStats {
        self.core.comm_stats()
    }

    /// The run's observability hub (built from `[obs]`; disabled when no
    /// sink is configured). Tests and tooling can read counters and the
    /// in-memory event mirror mid-run.
    pub fn obs(&self) -> &crate::obs::ObsHub {
        self.core.obs()
    }

    /// The manifest this trainer is bound to.
    pub fn manifest(&self) -> &Manifest {
        self.core.manifest()
    }
}
