//! Single-process training driver.
//!
//! Runs the full DP × PP grid synchronously in one thread, sharing one
//! PJRT [`Engine`]: the de-facto harness for the paper's convergence
//! experiments (Tables 2–3, Figs. 2–4), where wall-clock parallelism is
//! irrelevant (one CPU core) but *trajectory fidelity* is everything. The
//! threaded driver ([`super::threaded`]) runs the same algorithm over real
//! threads + the message fabric and is used by the end-to-end example and
//! the latency work.
//!
//! Communication is accounted (not transported): every all-reduce /
//! gossip exchange increments [`CommStats`] with the payload it *would*
//! ship, which the latency analysis (Fig. 5) combines with the
//! [`crate::net::SimClock`] latency model.

use anyhow::{ensure, Context, Result};

use crate::config::{Method, TrainConfig};
use crate::data::Loader;
use crate::metrics::{perplexity, RunTrace};
use crate::model::StageKind;
use crate::net::topo::ChurnEvent;
use crate::optim::LrSchedule;
use crate::rngx::Pcg64;
use crate::routing::RoutePlan;
use crate::runtime::{Engine, Manifest};
use crate::tensor::Tensor;

use super::exec::{self, AdamScalars};
use super::state::WorkerState;
use super::{CommStats, TrainReport};

/// Single-threaded DP × PP trainer over one shared engine.
pub struct SimTrainer<'e> {
    cfg: TrainConfig,
    eng: &'e mut Engine,
    man: Manifest,
    /// Worker grid, indexed `stage * dp + replica`.
    workers: Vec<WorkerState>,
    loaders: Vec<Loader>,
    /// Pre-drawn validation token batches (shared by every replica).
    val_batches: Vec<Vec<i32>>,
    lr: LrSchedule,
    comm: CommStats,
    trace: RunTrace,
    /// Global microbatch counter (routing seed input).
    mb_counter: u64,
    /// Microbatches per replica per step.
    num_mb: usize,
    /// Elastic membership: which DP columns (all stages of a replica) are
    /// currently live. Driven by `cfg.churn` or [`SimTrainer::apply_churn`].
    live: Vec<bool>,
}

impl<'e> SimTrainer<'e> {
    /// Build the worker grid: identical per-stage init across replicas
    /// (φ₀,ᵢ ≡ φ₀), sharded loaders, pre-drawn validation set.
    pub fn new(cfg: TrainConfig, eng: &'e mut Engine) -> Result<SimTrainer<'e>> {
        cfg.validate().map_err(anyhow::Error::msg)?;
        let man = eng.manifest()?;
        man.check_against(&cfg.model, cfg.topology.pp)?;
        let (dp, pp) = (cfg.topology.dp, cfg.topology.pp);

        // Per-replica microbatching: the global batch is split across DP,
        // then walked in manifest-sized microbatches.
        let per_replica_seqs = (cfg.model.batch_tokens / cfg.model.seq_len / dp).max(1);
        ensure!(
            per_replica_seqs >= man.mb,
            "per-replica batch ({per_replica_seqs} seqs) smaller than artifact microbatch ({}); \
             lower dp or rebuild artifacts with a smaller mb",
            man.mb
        );
        let num_mb = per_replica_seqs / man.mb;

        // Shared init per stage: seed depends on the stage only.
        let mut workers = Vec::with_capacity(dp * pp);
        for s in 0..pp {
            let kind = StageKind::of_stage(s, pp);
            let init = exec::init_stage(eng, kind, (cfg.seed as i32) ^ (s as i32 * 7901))
                .with_context(|| format!("initializing stage {s}"))?;
            for r in 0..dp {
                workers.push(WorkerState::new(s, r, kind, init.clone(), cfg.outer.method));
            }
        }
        let loaders: Vec<Loader> = (0..dp)
            .map(|r| {
                Loader::train(
                    cfg.dataset,
                    cfg.model.vocab,
                    cfg.seed,
                    r,
                    dp,
                    cfg.model.seq_len,
                    num_mb * man.mb,
                )
            })
            .collect();

        // Validation set: fixed token batches drawn once.
        let val_seqs = (cfg.eval_tokens / cfg.model.seq_len).max(man.mb);
        let mut val_loader = Loader::validation(
            cfg.dataset,
            cfg.model.vocab,
            cfg.seed ^ 0x5eed,
            cfg.model.seq_len,
            man.mb,
        );
        let n_val_batches = (val_seqs / man.mb).max(1);
        let val_batches: Vec<Vec<i32>> = (0..n_val_batches)
            .map(|_| {
                val_loader
                    .next_batch()
                    .tokens
                    .iter()
                    .map(|&t| t as i32)
                    .collect()
            })
            .collect();

        let lr = LrSchedule {
            peak: cfg.model.inner_lr,
            warmup: cfg.warmup,
            total: cfg.steps,
            floor_frac: cfg.lr_floor,
        };
        Ok(SimTrainer {
            live: vec![true; dp],
            cfg,
            eng,
            man,
            workers,
            loaders,
            val_batches,
            lr,
            comm: CommStats::default(),
            trace: RunTrace::default(),
            mb_counter: 0,
            num_mb,
        })
    }

    fn dp(&self) -> usize {
        self.cfg.topology.dp
    }

    fn pp(&self) -> usize {
        self.cfg.topology.pp
    }

    fn widx(&self, stage: usize, replica: usize) -> usize {
        stage * self.dp() + replica
    }

    /// Currently live DP replicas, ascending.
    pub fn live_replicas(&self) -> Vec<usize> {
        (0..self.dp()).filter(|&r| self.live[r]).collect()
    }

    /// Whether DP replica `r` is currently live.
    pub fn is_live(&self, r: usize) -> bool {
        self.live[r]
    }

    /// Apply one membership event (a whole DP column across all stages).
    ///
    /// Only NoLoCo supports this: its gossip pairing and routing
    /// permutations re-draw over the live set, so training continues
    /// without any global coordination. FSDP / DiLoCo synchronize through
    /// a world-wide all-reduce that has no live-subset form, so a
    /// membership change aborts the run — the measurable shape of the
    /// paper's no-global-barrier claim (§5.3).
    pub fn apply_churn(&mut self, event: ChurnEvent) -> Result<()> {
        ensure!(
            self.cfg.outer.method == Method::NoLoCo,
            "{} cannot change membership mid-run: its global all-reduce has no \
             live-subset form; only NoLoCo's gossip re-pairs over survivors ({event:?})",
            self.cfg.outer.method
        );
        let r = event.node();
        ensure!(r < self.dp(), "churn event for replica {r} outside dp = {}", self.dp());
        match event {
            ChurnEvent::Leave(_) => {
                self.live[r] = false;
                ensure!(self.live.iter().any(|&l| l), "all replicas left the run");
            }
            ChurnEvent::Join(_) => {
                if !self.live[r] {
                    self.live[r] = true;
                    self.reseed_replica(r);
                }
            }
        }
        Ok(())
    }

    /// Bootstrap a joining replica: copy the slow weights φ from the
    /// lowest live donor in each stage row (the freshest consensus state),
    /// reset θ to φ and zero the Adam moments and outer momentum. Without
    /// a donor (solo rejoin) the replica resumes from its own last state.
    fn reseed_replica(&mut self, r: usize) {
        let dp = self.dp();
        let donor = (0..dp).find(|&d| d != r && self.live[d]);
        for s in 0..self.pp() {
            let i = self.widx(s, r);
            if let Some(d) = donor {
                let phi = self.workers[self.widx(s, d)].phi.clone();
                self.workers[i].phi = phi;
            }
            let w = &mut self.workers[i];
            let n = w.len();
            w.reset_theta_to_phi();
            w.m = vec![0.0; n];
            w.v = vec![0.0; n];
            w.adam_t = 0;
            w.delta = vec![0.0; n];
            w.grad_acc = vec![0.0; n];
            w.acc_count = 0;
        }
    }

    /// Run the configured number of inner steps; returns the report.
    pub fn run(&mut self) -> Result<TrainReport> {
        let start = std::time::Instant::now();
        let exec0 = self.eng.executions();
        let mut last_val = f64::NAN;
        for step in 0..self.cfg.steps {
            let due: Vec<ChurnEvent> = self.cfg.churn.events_at(step as u64).collect();
            for event in due {
                self.apply_churn(event)?;
            }
            let train_loss = self.inner_step(step)?;
            let outer_due = self.cfg.outer.method != Method::Fsdp
                && (step + 1) % self.cfg.outer.inner_steps == 0;
            if outer_due {
                let outer_idx = (step + 1) / self.cfg.outer.inner_steps;
                self.outer_step(outer_idx as u64)?;
            }
            let eval_due = self.cfg.eval_every > 0 && (step + 1) % self.cfg.eval_every == 0;
            if eval_due || step + 1 == self.cfg.steps {
                last_val = self.validate()?;
                let wstd = self.weight_std();
                self.trace
                    .push(step + 1, train_loss, last_val, wstd, self.lr.at(step));
            }
        }
        Ok(TrainReport {
            final_val_nll: last_val,
            final_val_ppl: perplexity(last_val),
            trace: std::mem::take(&mut self.trace),
            comm: self.comm.clone(),
            wall_secs: start.elapsed().as_secs_f64(),
            executions: self.eng.executions() - exec0,
        })
    }

    /// One inner optimizer step: route + fwd/bwd every replica's
    /// microbatches, then Adam on every worker (FSDP all-reduces first).
    /// Returns the mean training loss across microbatches.
    pub fn inner_step(&mut self, step: usize) -> Result<f64> {
        let (dp, pp) = (self.dp(), self.pp());
        let mb_toks = self.man.mb * self.man.seq_len;
        let mut loss_sum = 0.0;
        let mut loss_n = 0usize;

        // One route plan per microbatch *wave*: all live DP paths of a
        // wave share a permutation (Fig. 1A) — exactly what the threaded
        // executor derives independently on each worker. Dead columns
        // neither load data nor appear on any path.
        let live: Vec<usize> = self.live_replicas();
        let batches: Vec<Option<Vec<i32>>> = (0..dp)
            .map(|r| {
                self.live[r].then(|| {
                    self.loaders[r]
                        .next_batch()
                        .tokens
                        .iter()
                        .map(|&t| t as i32)
                        .collect()
                })
            })
            .collect();
        for mb in 0..self.num_mb {
            let plan = RoutePlan::for_step_over(
                self.cfg.routing,
                &live,
                dp,
                pp,
                self.cfg.seed ^ 0x0a17,
                self.mb_counter,
            );
            self.mb_counter += 1;
            for &r in &live {
                let batch = batches[r].as_ref().expect("live replica has a batch");
                let toks = &batch[mb * mb_toks..(mb + 1) * mb_toks];
                let loss = self.run_microbatch(&plan, r, toks)?;
                loss_sum += loss as f64;
                loss_n += 1;
            }
        }

        // FSDP: all-reduce the mean gradient across each stage row before
        // the (then-identical) Adam updates.
        if self.cfg.outer.method == Method::Fsdp && dp > 1 {
            self.allreduce_grads();
        }

        let sc = AdamScalars::at(self.lr.at(step), step as u64 + 1, self.cfg.grad_clip);
        for i in 0..self.workers.len() {
            if !self.live[i % dp] {
                continue; // dead column: no gradients, no update
            }
            let g = self.workers[i].take_mean_grad();
            let w = &mut self.workers[i];
            w.adam_t += 1;
            let (kind, mut theta, mut m, mut v) = (
                w.kind,
                std::mem::take(&mut w.theta),
                std::mem::take(&mut w.m),
                std::mem::take(&mut w.v),
            );
            exec::adam_step(self.eng, kind, &mut theta, &mut m, &mut v, &g, sc)?;
            let w = &mut self.workers[i];
            w.theta = theta;
            w.m = m;
            w.v = v;
        }
        Ok(loss_sum / loss_n.max(1) as f64)
    }

    /// Forward + backward one microbatch along its route; accumulates
    /// gradients into every worker on the path. Returns the loss.
    fn run_microbatch(&mut self, plan: &RoutePlan, r0: usize, toks: &[i32]) -> Result<f32> {
        let pp = self.pp();
        if pp == 1 {
            let i = self.widx(0, r0);
            let theta = std::mem::take(&mut self.workers[i].theta);
            let (loss, g) = exec::bwd_full(self.eng, &self.man, &theta, toks)?;
            self.workers[i].theta = theta;
            self.workers[i].accumulate(&g);
            return Ok(loss);
        }

        let path = plan.path_from(r0);
        // ---- forward: record each stage's input ----
        let mut stage_inputs: Vec<Vec<f32>> = Vec::with_capacity(pp);
        let i0 = self.widx(0, path[0]);
        let theta0 = std::mem::take(&mut self.workers[i0].theta);
        let mut x = exec::fwd_first(self.eng, &self.man, &theta0, toks)?;
        self.workers[i0].theta = theta0;
        self.comm.activation_hops += 1;
        self.comm.floats_sent += x.len() as u64;
        for s in 1..pp - 1 {
            let i = self.widx(s, path[s]);
            let theta = std::mem::take(&mut self.workers[i].theta);
            stage_inputs.push(std::mem::take(&mut x));
            x = exec::fwd_mid(self.eng, &self.man, &theta, stage_inputs.last().unwrap())?;
            self.workers[i].theta = theta;
            self.comm.activation_hops += 1;
            self.comm.floats_sent += x.len() as u64;
        }

        // ---- last stage: loss + backward ----
        let il = self.widx(pp - 1, path[pp - 1]);
        let theta_l = std::mem::take(&mut self.workers[il].theta);
        let (loss, g_last, mut gx) = exec::bwd_last(self.eng, &self.man, &theta_l, &x, toks)?;
        self.workers[il].theta = theta_l;
        self.workers[il].accumulate(&g_last);
        self.comm.activation_hops += 1;
        self.comm.floats_sent += gx.len() as u64;

        // ---- backward through interior stages (reverse route) ----
        for s in (1..pp - 1).rev() {
            let i = self.widx(s, path[s]);
            let theta = std::mem::take(&mut self.workers[i].theta);
            let x_in = &stage_inputs[s - 1];
            let (g_mid, gx_new) = exec::bwd_mid(self.eng, &self.man, &theta, x_in, &gx)?;
            self.workers[i].theta = theta;
            self.workers[i].accumulate(&g_mid);
            gx = gx_new;
            self.comm.activation_hops += 1;
            self.comm.floats_sent += gx.len() as u64;
        }

        // ---- first stage backward ----
        let theta0 = std::mem::take(&mut self.workers[i0].theta);
        let g_first = exec::bwd_first(self.eng, &self.man, &theta0, toks, &gx)?;
        self.workers[i0].theta = theta0;
        self.workers[i0].accumulate(&g_first);
        Ok(loss)
    }

    /// Host-side mean all-reduce of accumulated gradients across each
    /// stage row (the FSDP baseline's per-step synchronization).
    fn allreduce_grads(&mut self) {
        let (dp, pp) = (self.dp(), self.pp());
        for s in 0..pp {
            let n = self.workers[self.widx(s, 0)].grad_acc.len();
            let mut mean = vec![0.0f32; n];
            for r in 0..dp {
                let w = &self.workers[self.widx(s, r)];
                for (m, g) in mean.iter_mut().zip(&w.grad_acc) {
                    *m += g / dp as f32;
                }
            }
            for r in 0..dp {
                let i = self.widx(s, r);
                self.workers[i].grad_acc.copy_from_slice(&mean);
            }
            // Tree all-reduce cost: every edge carries the payload twice
            // (reduce up + broadcast down).
            self.comm.blocking_collectives += 1;
            self.comm.floats_sent += 2 * (dp as u64 - 1) * n as u64;
        }
    }

    /// Outer optimizer step (DiLoCo all-reduce or NoLoCo gossip pairs).
    /// `outer_idx` is the 1-based outer-step counter; gossip pairings are
    /// derived from `(seed, stage, outer_idx)` exactly as the threaded
    /// executor derives them, so the two executors follow identical
    /// trajectories given identical inputs.
    pub fn outer_step(&mut self, outer_idx: u64) -> Result<()> {
        let (dp, pp) = (self.dp(), self.pp());
        match self.cfg.outer.method {
            Method::Fsdp => {}
            Method::DiLoCo => {
                let (alpha, beta) = (self.cfg.outer.alpha as f32, self.cfg.outer.beta as f32);
                for s in 0..pp {
                    // Mean outer gradient across the row (all-reduce).
                    let n = self.workers[self.widx(s, 0)].len();
                    let mut dmean = vec![0.0f32; n];
                    for r in 0..dp {
                        let d = self.workers[self.widx(s, r)].outer_grad();
                        for (m, x) in dmean.iter_mut().zip(&d) {
                            *m += x / dp as f32;
                        }
                    }
                    self.comm.blocking_collectives += 1;
                    self.comm.floats_sent += 2 * (dp as u64 - 1) * n as u64;
                    for r in 0..dp {
                        let i = self.widx(s, r);
                        let w = &mut self.workers[i];
                        let (kind, mut phi, mut delta) = (
                            w.kind,
                            std::mem::take(&mut w.phi),
                            std::mem::take(&mut w.delta),
                        );
                        exec::outer_diloco(self.eng, kind, &mut phi, &mut delta, &dmean, alpha, beta)?;
                        let w = &mut self.workers[i];
                        w.phi = phi;
                        w.delta = delta;
                        w.reset_theta_to_phi();
                    }
                }
            }
            Method::NoLoCo => {
                let (alpha, beta, gamma) = (
                    self.cfg.outer.alpha as f32,
                    self.cfg.outer.beta as f32,
                    self.cfg.outer.gamma as f32,
                );
                let group_size = self.cfg.outer.group;
                let live = self.live_replicas();
                for s in 0..pp {
                    // Fresh random disjoint groups over the *live* columns
                    // per stage row per outer step (§3.2: "for each
                    // iteration we update the local subgroup"; the paper
                    // uses the minimum size, 2). Shared-seed derivation
                    // matches train::threaded so no coordination is
                    // needed there; with full membership the draw is
                    // identical to the static-grid one.
                    let mut prng = Pcg64::seed_from_u64(
                        self.cfg.seed ^ 0x9055 ^ ((s as u64) << 40) ^ outer_idx,
                    );
                    let groups: Vec<Vec<usize>> = prng
                        .random_groups(live.len(), group_size)
                        .into_iter()
                        .map(|g| g.into_iter().map(|i| live[i]).collect())
                        .collect();
                    for group in groups {
                        let gn = group.len();
                        let n = self.workers[self.widx(s, group[0])].len();
                        // Group sums of Δ and φ (what members gossip).
                        let mut dsum = vec![0.0f32; n];
                        let mut psum = vec![0.0f32; n];
                        for &r in &group {
                            let w = &self.workers[self.widx(s, r)];
                            let d = w.outer_grad();
                            for (a, x) in dsum.iter_mut().zip(&d) {
                                *a += x;
                            }
                            for (a, x) in psum.iter_mut().zip(&w.phi) {
                                *a += x;
                            }
                        }
                        if gn > 1 {
                            // Each member ships (Δ, φ) to each other member
                            // (for n=2: one symmetric pair exchange).
                            self.comm.pair_exchanges += (gn * (gn - 1) / 2) as u64;
                            self.comm.floats_sent += (gn * (gn - 1) * 2 * n) as u64;
                        }
                        for &r in &group {
                            let i = self.widx(s, r);
                            let w = &mut self.workers[i];
                            let (kind, mut phi, mut delta) = (
                                w.kind,
                                std::mem::take(&mut w.phi),
                                std::mem::take(&mut w.delta),
                            );
                            exec::outer_noloco(
                                self.eng, kind, &mut phi, &mut delta, &dsum, &psum, alpha,
                                beta, gamma, 1.0 / gn as f32,
                            )?;
                            let w = &mut self.workers[i];
                            w.phi = phi;
                            w.delta = delta;
                            w.reset_theta_to_phi();
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Mean validation NLL over the fixed validation set, averaged across
    /// the *live* replicas (each evaluated through its own fixed-route
    /// pipeline).
    pub fn validate(&mut self) -> Result<f64> {
        let (dp, pp) = (self.dp(), self.pp());
        let mut sum = 0.0;
        let mut n = 0usize;
        let batches = self.val_batches.clone();
        for r in 0..dp {
            if !self.live[r] {
                continue;
            }
            for toks in &batches {
                let nll = if pp == 1 {
                    let i = self.widx(0, r);
                    let theta = std::mem::take(&mut self.workers[i].theta);
                    let l = exec::loss_full(self.eng, &self.man, &theta, toks)?;
                    self.workers[i].theta = theta;
                    l
                } else {
                    // Fixed route r -> r -> … for evaluation.
                    let i0 = self.widx(0, r);
                    let theta0 = std::mem::take(&mut self.workers[i0].theta);
                    let mut x = exec::fwd_first(self.eng, &self.man, &theta0, toks)?;
                    self.workers[i0].theta = theta0;
                    for s in 1..pp - 1 {
                        let i = self.widx(s, r);
                        let theta = std::mem::take(&mut self.workers[i].theta);
                        x = exec::fwd_mid(self.eng, &self.man, &theta, &x)?;
                        self.workers[i].theta = theta;
                    }
                    let il = self.widx(pp - 1, r);
                    let theta_l = std::mem::take(&mut self.workers[il].theta);
                    let l = exec::loss_last(self.eng, &self.man, &theta_l, &x, toks)?;
                    self.workers[il].theta = theta_l;
                    l
                };
                sum += nll as f64;
                n += 1;
            }
        }
        Ok(sum / n as f64)
    }

    /// Cross-replica weight standard deviation (Fig. 3B / Fig. 4A):
    /// per-stage σ over the *live* DP replicas' fast weights, averaged
    /// across stages weighted by parameter count.
    pub fn weight_std(&self) -> f64 {
        let pp = self.pp();
        let live = self.live_replicas();
        if live.len() < 2 {
            return 0.0;
        }
        let mut acc = 0.0;
        let mut total = 0usize;
        for s in 0..pp {
            let tensors: Vec<Tensor> = live
                .iter()
                .map(|&r| {
                    let w = &self.workers[self.widx(s, r)];
                    Tensor::from_vec(w.theta.clone(), &[w.len()])
                })
                .collect();
            let refs: Vec<&Tensor> = tensors.iter().collect();
            let n = tensors[0].len();
            acc += crate::tensor::replica_std(&refs) * n as f64;
            total += n;
        }
        acc / total.max(1) as f64
    }

    /// Immutable access to a worker (tests / inspection).
    pub fn worker(&self, stage: usize, replica: usize) -> &WorkerState {
        &self.workers[stage * self.dp() + replica]
    }

    /// Snapshot the whole worker grid (see [`super::Checkpoint`]).
    pub fn checkpoint(&self, step: u64) -> super::Checkpoint {
        super::Checkpoint::capture(step, self.dp(), self.pp(), &self.workers)
    }

    /// Restore a snapshot into this grid; returns the snapshot's step.
    /// Loader cursors are not part of the snapshot (see checkpoint docs).
    pub fn restore(&mut self, ck: &super::Checkpoint) -> Result<u64> {
        ck.restore(&mut self.workers)
    }

    /// Current communication accounting.
    pub fn comm(&self) -> &CommStats {
        &self.comm
    }

    /// The manifest this trainer is bound to.
    pub fn manifest(&self) -> &Manifest {
        &self.man
    }
}
