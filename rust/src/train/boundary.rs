//! The bounded-staleness asynchronous boundary engine.
//!
//! The gated strategies advance every replica through the outer boundary
//! in lockstep: offer at boundary `t`, fold at `t` (gated) or `t + 1`
//! (streamed), and a peer whose state predates the round is excluded
//! outright. That keeps a single straggler or rejoiner on the critical
//! path — exactly the stall NoLoCo's no-global-barrier design is meant
//! to remove. This module generalizes the boundary into an *event-driven*
//! engine:
//!
//! * [`BoundaryClock`] — each replica has its own boundary clock: the
//!   number of outer boundaries it actually participated in (derived
//!   from the shared churn schedule, so every worker computes every
//!   peer's participation with zero coordination traffic — the same
//!   shared-seed discipline as routing and pairing).
//!   [`TrainerCore`](super::TrainerCore) mirrors the clocks at run time;
//!   the engine consults the schedule-derived form to know *which
//!   boundaries a peer offered at*.
//! * [`AsyncGossipSync`] — a [`SyncStrategy`] whose fold admits peer
//!   state up to `outer.staleness − 1` boundaries old, weighted down by
//!   age (`w = 1 / (1 + age)`), instead of the gated binary
//!   admit-or-exclude. Offers are tagged with the boundary they were
//!   made at ([`Communicator::offer_round`]) and retained for the
//!   staleness window; a fold probes the window newest-boundary-first
//!   and, on the fabric, a straggler's missing current offer degrades to
//!   its freshest *already-delivered* one ([`Communicator::collect_round`]
//!   with `wait = false` never blocks) instead of stalling the boundary.
//!   A peer that offered nothing inside the window is excluded from the
//!   fold; a churn-stale rejoiner still adopts a fresh peer's slow
//!   weights within the repair window — the gated repair semantics are
//!   the edge of this engine, and `staleness = 1` *is* the lockstep
//!   contract (config routes it through the unchanged gated / streaming
//!   paths, bit-for-bit).
//! * Per-fragment pairing: with `--pairing per-fragment` the (Δ, φ)
//!   state splits into `outer.fragments` ranges and each fragment
//!   gossips with its *own* partner this round
//!   ([`PairingPolicy::draw_for_fragment`](super::PairingPolicy::draw_for_fragment)),
//!   mixing K× faster per round at the same total payload. Any other
//!   pairing mode keeps one partner for the whole state (one fragment).
//!
//! The update restricted to an admitted set `A` (self included) is the
//! Eq. 2–3 modified Nesterov with a weighted mean instead of the plain
//! group mean:
//!
//! ```text
//! δ ← α δ + (β / W) Σ_{q∈A} w_q Δ_q − γ (φ − (1/W) Σ_{q∈A} w_q φ_q),
//! φ ← φ + δ,   θ ← φ,        W = Σ w_q,  w_q = 1 / (1 + age_q)
//! ```
//!
//! where `age_q` is how many boundaries ago the admitted offer was made
//! — 0 for a current offer, even from a replica that missed boundaries
//! long past (its *state* is repaired by adoption / the donor bootstrap
//! and then re-admitted at full weight; staleness measures the offer,
//! not the replica's history). With every age 0 this is exactly the
//! gated group mean, so the engine's trajectory coincides with the
//! lockstep one on a churn-free, straggler-free run; the Eq. 74
//! γ-window analysis applies verbatim to the uniform-weight case and
//! carries over as a well-behaved approximation under mixed weights,
//! which remain a convex combination of member states. Folds are
//! computed host-side, like the streamed fragments — the fused XLA
//! outer artifact is compiled for the uniform full-state mean — and the
//! gated fragment fold ([`fold_noloco_fragment`](super::streaming)) is
//! the `W = n` special case of [`fold_noloco_weighted`].
//!
//! Failure *detection* (the heartbeat half of the async boundary) lives
//! in [`TrainerCore`](super::TrainerCore) /
//! [`FailureDetector`](crate::net::FailureDetector): strategies decide
//! what a boundary exchanges, the core decides who is still alive.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::config::{OuterConfig, PairingMode, TrainConfig};
use crate::net::topo::ChurnEvent;
use crate::net::ChurnSchedule;
use crate::runtime::Engine;

use super::arena::FoldScratch;
use super::checkpoint::{OfferRecord, StrategyState};
use super::comm::Communicator;
use super::state::WorkerState;
use super::strategy::{
    pairing_for, ChurnResponse, CommPattern, PairingCache, PairingPolicy, SyncStrategy,
};
use super::streaming::FragmentSchedule;

/// Per-replica boundary clocks, derived from the shared churn schedule.
///
/// Replica `r`'s clock at global boundary `t` is the number of
/// boundaries in `1..=t` whose closing step `r` was live at — its own
/// count of participated boundaries. Fully-live replicas read `t`; a
/// replica that sat out boundaries lags by exactly the boundaries it
/// missed. The async engine consults [`BoundaryClock::live_at_boundary`]
/// to know which boundaries a peer offered at;
/// [`TrainerCore::boundary_clocks`](super::TrainerCore::boundary_clocks)
/// is the incrementally-maintained run-time mirror.
#[derive(Clone, Debug)]
pub struct BoundaryClock {
    churn: ChurnSchedule,
    dp: usize,
    inner_steps: u64,
}

impl BoundaryClock {
    /// Clock over `dp` replicas under `churn`, `inner_steps` per
    /// boundary.
    pub fn new(churn: ChurnSchedule, dp: usize, inner_steps: usize) -> BoundaryClock {
        BoundaryClock { churn, dp, inner_steps: inner_steps.max(1) as u64 }
    }

    /// Whether replica `r` participates in (is live at the closing step
    /// of) 1-based boundary `b`. Allocation-free walk of `r`'s own
    /// events — this sits inside the fold's per-peer window probe, so it
    /// must not replay the full live mask per call.
    pub fn live_at_boundary(&self, r: usize, b: u64) -> bool {
        if self.churn.is_empty() {
            return true;
        }
        debug_assert!(r < self.dp, "replica outside the clock's world");
        let closing = (b * self.inner_steps).saturating_sub(1);
        let mut live = true;
        for &(step, e) in self.churn.events() {
            if step > closing {
                break;
            }
            if e.node() == r {
                live = matches!(e, ChurnEvent::Join(_));
            }
        }
        live
    }

    /// Replica `r`'s own boundary clock at global boundary `outer_idx`.
    pub fn clock_of(&self, r: usize, outer_idx: u64) -> u64 {
        if self.churn.is_empty() {
            return outer_idx;
        }
        (1..=outer_idx)
            .filter(|&b| self.live_at_boundary(r, b))
            .count() as u64
    }
}

/// How [`fold_noloco_fused`] updates θ alongside the Eq. 2–3 (φ, δ)
/// update — the third line of the boundary fused into the same pass.
pub enum ThetaUpdate<'a> {
    /// Leave θ to the caller (the plain Eq. 2 fold).
    None,
    /// Lockstep reset `θ ← φ′`: gated / async boundaries fold with the
    /// inner phase quiesced, so θ restarts from the folded slow weights.
    Reset(&'a mut [f32]),
    /// Streamed carry `θ ← φ′ + (θ − snap)`: the inner progress made
    /// since the offer snapshot `snap` rides on top of the folded slow
    /// weights (Streaming DiLoCo's overlap correction).
    Carry {
        /// Fast weights over the fragment range.
        theta: &'a mut [f32],
        /// θ as it was when the in-flight offer snapshotted it.
        snap: &'a [f32],
    },
}

/// Eq. 2–3 with an age-weighted admitted set, host-side (see the module
/// docs): `dsum`/`psum` are the already-weighted sums over the admitted
/// members (self included) and `wsum` their total weight — with the
/// boundary's θ treatment fused into the same elementwise pass instead
/// of a separate sweep over the fragment. This is the single approved
/// reduction kernel of the boundary path (analyzer rule R5); every
/// strategy fold routes through it.
///
/// Per element the update is exactly the unfused sequence: `δᵢ ← αδᵢ +
/// (β/W)dsumᵢ − γ(φᵢ − psumᵢ/W)`, `φᵢ += δᵢ`, then the [`ThetaUpdate`].
/// Fusing changes neither the operation order within an element nor the
/// order across elements, so the bits match the unfused fold.
#[allow(clippy::too_many_arguments)]
pub fn fold_noloco_fused(
    phi: &mut [f32],
    delta: &mut [f32],
    dsum: &[f32],
    psum: &[f32],
    wsum: f32,
    alpha: f32,
    beta: f32,
    gamma: f32,
    theta: ThetaUpdate<'_>,
) {
    let inv = 1.0 / wsum;
    match theta {
        ThetaUpdate::None => {
            for i in 0..phi.len() {
                let d =
                    alpha * delta[i] + beta * inv * dsum[i] - gamma * (phi[i] - inv * psum[i]);
                delta[i] = d;
                phi[i] += d;
            }
        }
        ThetaUpdate::Reset(theta) => {
            for i in 0..phi.len() {
                let d =
                    alpha * delta[i] + beta * inv * dsum[i] - gamma * (phi[i] - inv * psum[i]);
                delta[i] = d;
                phi[i] += d;
                theta[i] = phi[i];
            }
        }
        ThetaUpdate::Carry { theta, snap } => {
            for i in 0..phi.len() {
                let d =
                    alpha * delta[i] + beta * inv * dsum[i] - gamma * (phi[i] - inv * psum[i]);
                delta[i] = d;
                phi[i] += d;
                theta[i] = phi[i] + (theta[i] - snap[i]);
            }
        }
    }
}

/// The φ/δ half of [`fold_noloco_fused`] (θ left to the caller). The
/// gated fragment fold is the `wsum = n` special case and delegates
/// here.
#[allow(clippy::too_many_arguments)]
pub fn fold_noloco_weighted(
    phi: &mut [f32],
    delta: &mut [f32],
    dsum: &[f32],
    psum: &[f32],
    wsum: f32,
    alpha: f32,
    beta: f32,
    gamma: f32,
) {
    fold_noloco_fused(phi, delta, dsum, psum, wsum, alpha, beta, gamma, ThetaUpdate::None);
}

/// Bounded-staleness asynchronous gossip (`outer.staleness > 1`). See
/// the module docs for the admission and weighting rules.
pub struct AsyncGossipSync {
    outer: OuterConfig,
    seed: u64,
    churn: ChurnSchedule,
    clock: BoundaryClock,
    pairing: Box<dyn PairingPolicy>,
    /// Fragment count: `outer.fragments` under per-fragment pairing
    /// (each fragment draws its own partner), 1 otherwise.
    fragments: usize,
    /// Memoized pairing draws (see [`PairingCache`]): one set of
    /// per-fragment partitions per `(stage, outer_idx, live)` key.
    cache: PairingCache,
    /// Observability: oldest admitted offer age (boundaries) so far.
    max_admitted_age: u64,
    /// Peer contributions admitted into folds.
    admitted: u64,
    /// Peer contributions excluded: repair-stale, or no offer delivered
    /// inside the staleness window.
    excluded_stale: u64,
    /// Own offers still inside the staleness window, per owned worker
    /// (the grid executor drives every `(stage, replica)` through one
    /// strategy instance). Peers' folds may still admit any of these, so
    /// a checkpoint retains them ([`SyncStrategy::export_state`]) and a
    /// resume re-publishes them through the communicator's unmetered
    /// replay hook; the offer phase GCs entries the admission window can
    /// no longer reach.
    sent: BTreeMap<(usize, usize), Vec<SentOffer>>,
    /// Reusable fold accumulators (one pair per strategy instance — the
    /// boundary path allocates nothing in steady state).
    scratch: FoldScratch,
}

/// One retained own offer (see [`AsyncGossipSync::sent`]): the exact
/// payload handed to [`Communicator::offer_round`], plus its addressing.
struct SentOffer {
    round: u64,
    frag: usize,
    peers: Vec<usize>,
    delta: Vec<f32>,
    phi: Vec<f32>,
}

impl AsyncGossipSync {
    /// Build from the full config (NoLoCo + gated sync, enforced by
    /// [`TrainConfig::validate`]; `staleness = 1` is permitted here for
    /// equivalence tests but
    /// [`for_config`](super::strategy_for_config) only dispatches to
    /// this engine above 1).
    pub fn from_config(cfg: &TrainConfig) -> AsyncGossipSync {
        assert!(
            cfg.outer.method == crate::config::Method::NoLoCo,
            "the async boundary engine is NoLoCo-only (enforced by config validation)"
        );
        let fragments = if cfg.pairing == PairingMode::PerFragment {
            cfg.stream.fragments.max(1)
        } else {
            1
        };
        AsyncGossipSync {
            outer: cfg.outer.clone(),
            seed: cfg.seed,
            churn: cfg.churn.clone(),
            clock: BoundaryClock::new(cfg.churn.clone(), cfg.topology.dp, cfg.outer.inner_steps),
            pairing: pairing_for(cfg),
            fragments,
            cache: PairingCache::new(),
            max_admitted_age: 0,
            admitted: 0,
            excluded_stale: 0,
            sent: BTreeMap::new(),
            scratch: FoldScratch::default(),
        }
    }

    /// The engine's boundary clock (tests / inspection).
    pub fn boundary_clock(&self) -> &BoundaryClock {
        &self.clock
    }

    /// Fragment count per boundary (1 unless per-fragment pairing).
    pub fn fragments(&self) -> usize {
        self.fragments
    }

    /// Oldest offer age (in boundaries) any fold has admitted so far —
    /// `max_admitted_age < outer.staleness` is the engine's
    /// bounded-staleness guarantee.
    pub fn max_admitted_age(&self) -> u64 {
        self.max_admitted_age
    }

    /// Peer contributions admitted into folds so far.
    pub fn admitted(&self) -> u64 {
        self.admitted
    }

    /// Peer contributions excluded (repair-stale or nothing admissible
    /// delivered) so far.
    pub fn excluded_stale(&self) -> u64 {
        self.excluded_stale
    }

    /// This worker's gossip group for `frag` at `outer_idx`, through the
    /// shared per-round draw cache.
    fn my_group(
        &mut self,
        live: &[usize],
        stage: usize,
        frag: u16,
        outer_idx: u64,
        me: usize,
    ) -> Vec<usize> {
        self.cache.my_group(
            self.pairing.as_ref(),
            live,
            self.outer.group,
            stage,
            frag,
            self.fragments,
            outer_idx,
            self.seed,
            me,
        )
    }

    /// Whether `r` was dead at any step of the staleness window closing
    /// at boundary `outer_idx` — its (Δ, φ) predate the window's mixing
    /// and the message-passing repair (adopt / exclude) applies beyond
    /// the weighted admission. Allocation-free walk of `r`'s dead
    /// intervals, mirroring the gated strategies.
    fn is_stale(&self, r: usize, outer_idx: u64) -> bool {
        if self.churn.is_empty() {
            return false;
        }
        let m = self.outer.inner_steps as u64;
        let s = self.outer.staleness as u64;
        let hi = (outer_idx * m).saturating_sub(1);
        let lo = outer_idx.saturating_sub(s) * m;
        let mut live = true;
        let mut dead_since = 0u64;
        for &(step, e) in self.churn.events() {
            if e.node() != r {
                continue;
            }
            match e {
                ChurnEvent::Leave(_) => {
                    if live {
                        live = false;
                        dead_since = step;
                    }
                }
                ChurnEvent::Join(_) => {
                    if !live {
                        live = true;
                        if dead_since <= hi && step > lo {
                            return true;
                        }
                    }
                }
            }
        }
        !live && dead_since <= hi
    }

    /// The fold half of the boundary, engine-free (the async update is
    /// host-side; [`SyncStrategy::apply_outer`] delegates here). Public
    /// so staleness-invariant tests can drive folds without PJRT
    /// artifacts.
    pub fn fold_boundary(
        &mut self,
        comm: &mut dyn Communicator,
        w: &mut WorkerState,
        live: &[usize],
        outer_idx: u64,
    ) -> Result<()> {
        let me = w.replica;
        let stage = w.stage;
        let s = self.outer.staleness as u64;
        // Admissible offer boundaries: the last `s`, newest first.
        let win_lo = (outer_idx + 1).saturating_sub(s).max(1);
        let (alpha, beta, gamma) = (
            self.outer.alpha as f32,
            self.outer.beta as f32,
            self.outer.gamma as f32,
        );
        // Message-passing rejoin catch-up (the grid executor hands a
        // joiner a donor's φ at the join event instead): a stale member
        // adopts the first fresh peer's current-boundary φ fragment.
        let repair = !comm.supports_join_bootstrap() && !self.churn.is_empty();
        let me_stale = repair && self.is_stale(me, outer_idx);
        let sched = FragmentSchedule::new(w.len(), self.fragments);
        'frags: for frag in 0..sched.fragments() {
            let range = sched.range(frag);
            let group = self.my_group(live, stage, frag as u16, outer_idx, me);
            if me_stale {
                for &q in &group {
                    if q == me || self.is_stale(q, outer_idx) {
                        continue;
                    }
                    if let Some(view) = comm.collect_round_view(
                        stage,
                        me,
                        q,
                        outer_idx as u32,
                        frag as u16,
                        true,
                    )? {
                        w.phi[range.clone()].copy_from_slice(view.phi());
                        for d in w.delta[range.clone()].iter_mut() {
                            *d = 0.0;
                        }
                        for i in range.clone() {
                            w.theta[i] = w.phi[i];
                        }
                        continue 'frags;
                    }
                }
                // No fresh peer reachable: fall through to the weighted
                // fold (two stale members keep each other moving and the
                // γ-consensus pulls them back over later boundaries).
            }
            // Repair-staleness verdicts, precomputed so the scratch
            // borrow below never competes with `&self` method calls.
            let peer_stale: Vec<bool> = group
                .iter()
                .map(|&q| repair && self.is_stale(q, outer_idx))
                .collect();
            // Weighted admission; sums start from this worker's own
            // contribution at weight 1 (θ and φ are untouched since the
            // offer phase, so this equals the offered payload). The
            // arena buffers are rewritten in full — no per-boundary
            // allocation.
            let (dsum, psum) = self
                .scratch
                .seed(&w.theta[range.clone()], &w.phi[range.clone()]);
            let mut wsum = 1.0f32;
            for (gi, &q) in group.iter().enumerate() {
                if q == me {
                    continue;
                }
                if peer_stale[gi] {
                    self.excluded_stale += 1;
                    continue;
                }
                // Probe the window, newest boundary first. The peer made
                // an offer at a boundary only if it participated in it;
                // only the current boundary's offer is worth waiting for
                // (older ones either already arrived or never will). The
                // admitted payload is accumulated straight off the
                // communicator's borrowed view — no copy.
                let mut hit = false;
                for b in (win_lo..=outer_idx).rev() {
                    if !self.clock.live_at_boundary(q, b) {
                        continue;
                    }
                    let wait = b == outer_idx;
                    if let Some(view) =
                        comm.collect_round_view(stage, me, q, b as u32, frag as u16, wait)?
                    {
                        let (d, p) = (view.delta(), view.phi());
                        let age = outer_idx - b;
                        ensure!(
                            d.len() == dsum.len() && p.len() == psum.len(),
                            "peer {q} offered fragment {frag} with mismatched length at age {age}"
                        );
                        debug_assert!(age < s, "admission must respect the staleness window");
                        let wgt = 1.0 / (1.0 + age as f32);
                        for (a, x) in dsum.iter_mut().zip(d) {
                            *a += wgt * x;
                        }
                        for (a, x) in psum.iter_mut().zip(p) {
                            *a += wgt * x;
                        }
                        wsum += wgt;
                        self.admitted += 1;
                        self.max_admitted_age = self.max_admitted_age.max(age);
                        hit = true;
                        break;
                    }
                }
                if !hit {
                    // Nothing admissible delivered inside the window:
                    // the fold degrades to a smaller group.
                    self.excluded_stale += 1;
                }
            }
            // Fused Eq. 2–3: Δ apply, φ mix and the lockstep θ ← φ reset
            // in one elementwise pass over the fragment.
            fold_noloco_fused(
                &mut w.phi[range.clone()],
                &mut w.delta[range.clone()],
                dsum,
                psum,
                wsum,
                alpha,
                beta,
                gamma,
                ThetaUpdate::Reset(&mut w.theta[range]),
            );
        }
        Ok(())
    }
}

impl SyncStrategy for AsyncGossipSync {
    fn name(&self) -> &'static str {
        "async"
    }

    fn pattern(&self) -> CommPattern {
        CommPattern::GossipPairs
    }

    fn has_outer(&self) -> bool {
        true
    }

    fn churn_response(&self) -> ChurnResponse {
        ChurnResponse::Repair
    }

    fn offer_outer(
        &mut self,
        comm: &mut dyn Communicator,
        w: &WorkerState,
        live: &[usize],
        outer_idx: u64,
    ) -> Result<()> {
        let me = w.replica;
        let window = self.outer.staleness as u32;
        let s = self.outer.staleness as u64;
        // GC retained offers the admission window can no longer reach: a
        // fold at boundary b admits rounds in (b − s, b], and no future
        // fold is earlier than this boundary.
        self.sent
            .entry((w.stage, me))
            .or_default()
            .retain(|o| o.round + s > outer_idx);
        let sched = FragmentSchedule::new(w.len(), self.fragments);
        for frag in 0..sched.fragments() {
            let r = sched.range(frag);
            let phi = &w.phi[r.clone()];
            let delta: Vec<f32> = w.theta[r.clone()]
                .iter()
                .zip(phi)
                .map(|(t, p)| t - p)
                .collect();
            let group = self.my_group(live, w.stage, frag as u16, outer_idx, me);
            let peers: Vec<usize> = group.into_iter().filter(|&q| q != me).collect();
            comm.offer_round(
                w.stage,
                me,
                &peers,
                outer_idx as u32,
                frag as u16,
                window,
                &delta,
                phi,
            )?;
            // Retain the published payload: a crash after this offer but
            // before the window closes must be able to re-publish it so
            // peers' post-resume folds still admit it.
            self.sent.entry((w.stage, me)).or_default().push(SentOffer {
                round: outer_idx,
                frag,
                peers,
                delta,
                phi: phi.to_vec(),
            });
        }
        Ok(())
    }

    fn apply_outer(
        &mut self,
        comm: &mut dyn Communicator,
        _eng: &mut Engine,
        w: &mut WorkerState,
        live: &[usize],
        outer_idx: u64,
    ) -> Result<()> {
        self.fold_boundary(comm, w, live, outer_idx)
    }

    fn export_state(&self, w: &WorkerState) -> Option<StrategyState> {
        let offers = self
            .sent
            .get(&(w.stage, w.replica))
            .map(|os| {
                os.iter()
                    .map(|o| OfferRecord {
                        round: o.round,
                        frag: o.frag as u32,
                        peers: o.peers.iter().map(|&p| p as u32).collect(),
                        delta: o.delta.clone(),
                        phi: o.phi.clone(),
                    })
                    .collect()
            })
            .unwrap_or_default();
        Some(StrategyState::Async {
            offers,
            admitted: self.admitted,
            excluded_stale: self.excluded_stale,
            max_admitted_age: self.max_admitted_age,
        })
    }

    fn restore_state(
        &mut self,
        comm: &mut dyn Communicator,
        w: &WorkerState,
        st: &StrategyState,
    ) -> Result<()> {
        let StrategyState::Async { offers, admitted, excluded_stale, max_admitted_age } = st
        else {
            bail!("checkpoint strategy state is not the async kind");
        };
        // The counters are strategy-global; every owned worker's record
        // carries the same value, so max-merge is idempotent on the grid
        // (restored once per worker) and a plain restore per rank on the
        // fabric.
        self.admitted = self.admitted.max(*admitted);
        self.excluded_stale = self.excluded_stale.max(*excluded_stale);
        self.max_admitted_age = self.max_admitted_age.max(*max_admitted_age);
        let me = w.replica;
        for rec in offers {
            let peers: Vec<usize> = rec.peers.iter().map(|&p| p as usize).collect();
            comm.replay_round(
                w.stage,
                me,
                &peers,
                rec.round as u32,
                rec.frag as u16,
                &rec.delta,
                &rec.phi,
            )?;
            self.sent.entry((w.stage, me)).or_default().push(SentOffer {
                round: rec.round,
                frag: rec.frag as usize,
                peers,
                delta: rec.delta.clone(),
                phi: rec.phi.clone(),
            });
        }
        Ok(())
    }

    fn report_obs(&self, hub: &crate::obs::ObsHub) {
        hub.count("async.admitted", self.admitted);
        hub.count("async.excluded_stale", self.excluded_stale);
        hub.count("async.max_admitted_age", self.max_admitted_age);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Method, PairingMode};
    use crate::model::StageKind;
    use crate::train::streaming::fold_noloco_fragment;
    use crate::train::AccountingComm;

    fn async_cfg(staleness: usize) -> TrainConfig {
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.outer.staleness = staleness;
        cfg
    }

    fn worker(replica: usize, theta: Vec<f32>) -> WorkerState {
        let mut w = WorkerState::new(0, replica, StageKind::Full, theta.clone(), Method::NoLoCo);
        for (p, t) in w.phi.iter_mut().zip(&theta) {
            *p = t * 0.5;
        }
        w
    }

    fn ab_coeffs(s: &AsyncGossipSync) -> (f32, f32, f32) {
        (
            s.outer.alpha as f32,
            s.outer.beta as f32,
            s.outer.gamma as f32,
        )
    }

    #[test]
    fn boundary_clock_counts_participation() {
        // m = 50; replica 1 dead over steps 40..119 misses the boundaries
        // closing at steps 49 and 99, then participates again at 149.
        let churn = ChurnSchedule::none().leave(40, 1).join(120, 1);
        let c = BoundaryClock::new(churn, 2, 50);
        assert_eq!(c.clock_of(0, 3), 3);
        assert_eq!(c.clock_of(1, 1), 0);
        assert_eq!(c.clock_of(1, 2), 0);
        assert_eq!(c.clock_of(1, 3), 1);
        assert!(!c.live_at_boundary(1, 1));
        assert!(c.live_at_boundary(1, 3));
        // No churn: the clock is the global boundary index.
        let c = BoundaryClock::new(ChurnSchedule::none(), 2, 50);
        assert_eq!(c.clock_of(1, 7), 7);
    }

    /// The fused kernel's θ arms are bit-equal to the unfused reference
    /// — `fold_noloco_fragment` followed by the separate θ sweep each
    /// arm replaces — and the `None` arm is the weighted wrapper. The
    /// gated, streaming and async paths all lean on exactly this.
    #[test]
    fn fused_theta_arms_match_unfused_reference_bits() {
        let (alpha, beta, gamma) = (0.5f32, 0.7f32, 0.61f32);
        let n = 6usize;
        let phi0: Vec<f32> = (0..n).map(|i| 0.25 * i as f32 - 0.5).collect();
        let delta0: Vec<f32> = (0..n).map(|i| 0.125 * i as f32 - 0.3).collect();
        let dsum: Vec<f32> = (0..n).map(|i| 1.0 - 0.3 * i as f32).collect();
        let psum: Vec<f32> = (0..n).map(|i| 0.5 + 0.2 * i as f32).collect();
        let theta0: Vec<f32> = (0..n).map(|i| 2.0 - 0.4 * i as f32).collect();
        let snap: Vec<f32> = (0..n).map(|i| 1.5 - 0.35 * i as f32).collect();

        // Unfused reference: fragment fold, then the θ sweeps.
        let mut phi_ref = phi0.clone();
        let mut delta_ref = delta0.clone();
        fold_noloco_fragment(&mut phi_ref, &mut delta_ref, &dsum, &psum, 2, alpha, beta, gamma);
        let theta_reset_ref = phi_ref.clone();
        let theta_carry_ref: Vec<f32> = (0..n)
            .map(|i| phi_ref[i] + (theta0[i] - snap[i]))
            .collect();

        let (mut phi, mut delta, mut theta) = (phi0.clone(), delta0.clone(), theta0.clone());
        fold_noloco_fused(
            &mut phi, &mut delta, &dsum, &psum, 2.0, alpha, beta, gamma,
            ThetaUpdate::Reset(&mut theta),
        );
        assert_eq!(phi, phi_ref);
        assert_eq!(delta, delta_ref);
        assert_eq!(theta, theta_reset_ref);

        let (mut phi, mut delta, mut theta) = (phi0.clone(), delta0.clone(), theta0.clone());
        fold_noloco_fused(
            &mut phi, &mut delta, &dsum, &psum, 2.0, alpha, beta, gamma,
            ThetaUpdate::Carry { theta: &mut theta, snap: &snap },
        );
        assert_eq!(phi, phi_ref);
        assert_eq!(delta, delta_ref);
        assert_eq!(theta, theta_carry_ref);

        let (mut phi, mut delta) = (phi0.clone(), delta0.clone());
        fold_noloco_weighted(&mut phi, &mut delta, &dsum, &psum, 2.0, alpha, beta, gamma);
        assert_eq!(phi, phi_ref);
        assert_eq!(delta, delta_ref);
    }

    #[test]
    fn zero_lag_fold_matches_the_uniform_group_mean() {
        // With no churn every age is 0 and the weighted fold must equal
        // the gated host-side group fold (fold_noloco_fragment, gn = 2).
        let mut s = AsyncGossipSync::from_config(&async_cfg(3));
        let mut comm = AccountingComm::new();
        let live = vec![0usize, 1];
        let mut a = worker(0, vec![1.0, 2.0, 3.0, 4.0]);
        let b = worker(1, vec![4.0, 3.0, 2.0, 1.0]);
        let (alpha, beta, gamma) = ab_coeffs(&s);
        // Reference on copies, before the fold mutates `a`.
        let mut phi_ref = a.phi.clone();
        let mut delta_ref = a.delta.clone();
        let da: Vec<f32> = a.theta.iter().zip(&a.phi).map(|(t, p)| t - p).collect();
        let db: Vec<f32> = b.theta.iter().zip(&b.phi).map(|(t, p)| t - p).collect();
        let dsum: Vec<f32> = da.iter().zip(&db).map(|(x, y)| x + y).collect();
        let psum: Vec<f32> = a.phi.iter().zip(&b.phi).map(|(x, y)| x + y).collect();
        fold_noloco_fragment(&mut phi_ref, &mut delta_ref, &dsum, &psum, 2, alpha, beta, gamma);

        s.offer_outer(&mut comm, &a, &live, 1).unwrap();
        s.offer_outer(&mut comm, &b, &live, 1).unwrap();
        s.fold_boundary(&mut comm, &mut a, &live, 1).unwrap();
        assert_eq!(a.phi, phi_ref, "zero-age weighted fold == uniform group fold");
        assert_eq!(a.delta, delta_ref);
        assert_eq!(a.theta, a.phi, "θ resets to φ at a gated async boundary");
        assert_eq!(s.max_admitted_age(), 0);
        assert_eq!(s.admitted(), 1);
    }

    #[test]
    fn missing_current_offer_degrades_to_an_aged_one() {
        // Replica 1 participates at boundary 2 but not 3 (dead at the
        // closing step), while the caller's live view still includes it
        // — the detection-lag / straggler shape. The fold at boundary 3
        // falls back to its boundary-2 offer at age 1, weight 1/2.
        let mut cfg = async_cfg(4);
        cfg.churn = ChurnSchedule::none().leave(40, 1).join(70, 1).leave(140, 1);
        let mut s = AsyncGossipSync::from_config(&cfg);
        let mut comm = AccountingComm::new();
        let live = vec![0usize, 1];
        let mut a = worker(0, vec![1.0, 2.0, 3.0, 4.0]);
        let b = worker(1, vec![4.0, 3.0, 2.0, 1.0]);
        // Boundary 2: both offer (replica 1 participates — closing step
        // 99 is inside its live window 70..140).
        s.offer_outer(&mut comm, &a, &live, 2).unwrap();
        s.offer_outer(&mut comm, &b, &live, 2).unwrap();
        // Boundary 3: only replica 0 offers (1 is dead at closing 149).
        s.offer_outer(&mut comm, &a, &live, 3).unwrap();

        let (alpha, beta, gamma) = ab_coeffs(&s);
        let wgt = 0.5f32;
        let da: Vec<f32> = a.theta.iter().zip(&a.phi).map(|(t, p)| t - p).collect();
        let db: Vec<f32> = b.theta.iter().zip(&b.phi).map(|(t, p)| t - p).collect();
        let dsum: Vec<f32> = da.iter().zip(&db).map(|(x, y)| x + wgt * y).collect();
        let psum: Vec<f32> = a.phi.iter().zip(&b.phi).map(|(x, y)| x + wgt * y).collect();
        let mut phi_ref = a.phi.clone();
        let mut delta_ref = a.delta.clone();
        fold_noloco_weighted(
            &mut phi_ref, &mut delta_ref, &dsum, &psum, 1.0 + wgt, alpha, beta, gamma,
        );

        s.fold_boundary(&mut comm, &mut a, &live, 3).unwrap();
        assert_eq!(a.phi, phi_ref);
        assert_eq!(s.max_admitted_age(), 1);
        assert_eq!(s.admitted(), 1, "one aged admission at the single fold");
        assert_eq!(s.excluded_stale(), 0);
    }

    #[test]
    fn peer_with_no_offer_inside_the_window_is_excluded() {
        // Replica 1's only offer is at boundary 1; with staleness 2 the
        // window at boundary 3 is {2, 3}, where it never participated —
        // the retained boundary-1 offer must NOT fold and the update
        // degrades to a singleton.
        let mut cfg = async_cfg(2);
        cfg.churn = ChurnSchedule::none().leave(60, 1).join(320, 1);
        let mut s = AsyncGossipSync::from_config(&cfg);
        let mut comm = AccountingComm::new();
        let live = vec![0usize, 1];
        let mut a = worker(0, vec![1.0, 2.0, 3.0, 4.0]);
        let b = worker(1, vec![4.0, 3.0, 2.0, 1.0]);
        // Boundary 1: both participate (closing step 49 < 60).
        s.offer_outer(&mut comm, &a, &live, 1).unwrap();
        s.offer_outer(&mut comm, &b, &live, 1).unwrap();
        // Boundaries 2 and 3: only replica 0 offers.
        s.offer_outer(&mut comm, &a, &live, 2).unwrap();
        s.offer_outer(&mut comm, &a, &live, 3).unwrap();

        let (alpha, beta, gamma) = ab_coeffs(&s);
        let dsum: Vec<f32> = a.theta.iter().zip(&a.phi).map(|(t, p)| t - p).collect();
        let psum = a.phi.clone();
        let mut phi_ref = a.phi.clone();
        let mut delta_ref = a.delta.clone();
        fold_noloco_weighted(&mut phi_ref, &mut delta_ref, &dsum, &psum, 1.0, alpha, beta, gamma);

        s.fold_boundary(&mut comm, &mut a, &live, 3).unwrap();
        assert_eq!(a.phi, phi_ref, "out-of-window state must not fold");
        assert_eq!(s.admitted(), 0);
        assert_eq!(s.excluded_stale(), 1);
        assert_eq!(s.max_admitted_age(), 0);
    }

    #[test]
    fn recovered_replica_is_readmitted_at_full_weight() {
        // A replica that missed boundaries long ago but participates now
        // offers current state: age 0, weight 1 — staleness measures the
        // offer, not the replica's history.
        let mut cfg = async_cfg(2);
        // Replica 1 dead over steps 40..119: misses boundaries 1 and 2,
        // fully participating again from boundary 3 on.
        cfg.churn = ChurnSchedule::none().leave(40, 1).join(120, 1);
        let mut s = AsyncGossipSync::from_config(&cfg);
        let mut comm = AccountingComm::new();
        let live = vec![0usize, 1];
        let mut a = worker(0, vec![1.0, 2.0, 3.0, 4.0]);
        let b = worker(1, vec![4.0, 3.0, 2.0, 1.0]);
        // Boundary 5 is well past the repair window (dead interval ended
        // at step 120 <= (5-2)*50 = 150): no exclusion, no adoption.
        s.offer_outer(&mut comm, &a, &live, 5).unwrap();
        s.offer_outer(&mut comm, &b, &live, 5).unwrap();
        s.fold_boundary(&mut comm, &mut a, &live, 5).unwrap();
        assert_eq!(s.admitted(), 1, "the recovered peer folds again");
        assert_eq!(s.max_admitted_age(), 0, "…at full weight");
        assert_eq!(s.excluded_stale(), 0);
    }

    #[test]
    fn per_fragment_pairing_splits_the_state_into_fragments() {
        let mut cfg = async_cfg(2);
        cfg.pairing = PairingMode::PerFragment;
        cfg.stream.fragments = 2;
        let s = AsyncGossipSync::from_config(&cfg);
        assert_eq!(s.fragments(), 2);
        // Uniform pairing keeps the whole state as one fragment.
        let s = AsyncGossipSync::from_config(&async_cfg(2));
        assert_eq!(s.fragments(), 1);
    }

    #[test]
    fn per_fragment_fold_touches_each_range_with_its_own_group() {
        // dp = 2 means every fragment's partition is {0, 1} regardless of
        // seed, so both fragments fold — the point is the plumbing:
        // fragment-sliced offers and folds reproduce the full-state fold
        // when the groups coincide.
        let mut cfg = async_cfg(2);
        cfg.pairing = PairingMode::PerFragment;
        cfg.stream.fragments = 2;
        let mut s = AsyncGossipSync::from_config(&cfg);
        let mut comm = AccountingComm::new();
        let live = vec![0usize, 1];
        let mut a = worker(0, vec![1.0, 2.0, 3.0, 4.0]);
        let b = worker(1, vec![4.0, 3.0, 2.0, 1.0]);
        let mut a_full = a.clone();
        s.offer_outer(&mut comm, &a, &live, 1).unwrap();
        s.offer_outer(&mut comm, &b, &live, 1).unwrap();
        s.fold_boundary(&mut comm, &mut a, &live, 1).unwrap();

        // Reference: the one-fragment engine over the same states.
        let mut s1 = AsyncGossipSync::from_config(&async_cfg(2));
        let mut comm1 = AccountingComm::new();
        s1.offer_outer(&mut comm1, &a_full, &live, 1).unwrap();
        s1.offer_outer(&mut comm1, &b, &live, 1).unwrap();
        s1.fold_boundary(&mut comm1, &mut a_full, &live, 1).unwrap();
        assert_eq!(a.phi, a_full.phi, "2-replica fragmented fold == full fold");
        assert_eq!(a.theta, a_full.theta);
    }

    #[test]
    fn strategy_factory_dispatches_on_staleness() {
        use crate::train::strategy_for_config;
        let cfg = async_cfg(1);
        assert_eq!(strategy_for_config(&cfg).name(), "noloco", "staleness 1 is the gated path");
        let cfg = async_cfg(3);
        let s = strategy_for_config(&cfg);
        assert_eq!(s.name(), "async");
        assert_eq!(s.pattern(), CommPattern::GossipPairs);
        assert_eq!(s.churn_response(), ChurnResponse::Repair);
        assert!(s.has_outer());
    }

    #[test]
    fn fabric_rejoiner_adopts_a_fresh_peer_round() {
        // Message-passing repair: the churn-stale rejoiner adopts the
        // fresh peer's current-boundary φ outright; the fresh side
        // excludes the repair-stale contribution and folds a singleton.
        let mut cfg = async_cfg(2);
        cfg.churn = ChurnSchedule::none().leave(40, 1).join(120, 1);
        let mut fabric = crate::net::Fabric::new(2);
        let mut eps = fabric.take_endpoints().into_iter();
        let mut ca = crate::train::FabricComm::new(eps.next().unwrap(), 2, None);
        let mut cb = crate::train::FabricComm::new(eps.next().unwrap(), 2, None);
        let mut sa = AsyncGossipSync::from_config(&cfg);
        let mut sb = AsyncGossipSync::from_config(&cfg);
        let mut a = worker(0, vec![1.0, 2.0, 3.0, 4.0]);
        let mut b = worker(1, vec![4.0, 3.0, 2.0, 1.0]);
        let live = vec![0usize, 1];
        let phi_a_offer = a.phi.clone();
        sa.offer_outer(&mut ca, &a, &live, 3).unwrap();
        sb.offer_outer(&mut cb, &b, &live, 3).unwrap();
        sa.fold_boundary(&mut ca, &mut a, &live, 3).unwrap();
        sb.fold_boundary(&mut cb, &mut b, &live, 3).unwrap();
        // The rejoiner adopted the fresh peer's offered φ.
        assert_eq!(b.phi, phi_a_offer);
        assert_eq!(b.delta, vec![0.0; 4]);
        assert_eq!(b.theta, phi_a_offer);
        // The fresh side moved, but not onto the stale peer's values.
        assert_ne!(a.phi, phi_a_offer);
        assert_ne!(a.phi, b.phi);
        assert_eq!(sa.admitted(), 0);
        assert_eq!(sa.excluded_stale(), 1);
    }

    #[test]
    fn export_restore_resumes_aged_admission_bit_identically() {
        // Checkpoint after the boundary-2 fold, with replica 1's round-2
        // offer still inside the staleness-4 window; the resumed engine
        // must fold boundary 3 (which admits that offer at age 1) onto
        // exactly the reference trajectory, from replayed offers alone.
        let mut cfg = async_cfg(4);
        cfg.churn = ChurnSchedule::none().leave(40, 1).join(70, 1).leave(140, 1);
        let mut s = AsyncGossipSync::from_config(&cfg);
        let mut comm = AccountingComm::new();
        let live = vec![0usize, 1];
        let mut a = worker(0, vec![1.0, 2.0, 3.0, 4.0]);
        let mut b = worker(1, vec![4.0, 3.0, 2.0, 1.0]);
        s.offer_outer(&mut comm, &a, &live, 2).unwrap();
        s.offer_outer(&mut comm, &b, &live, 2).unwrap();
        s.fold_boundary(&mut comm, &mut a, &live, 2).unwrap();
        s.fold_boundary(&mut comm, &mut b, &live, 2).unwrap();
        // --- checkpoint cut: per-worker strategy records + worker clones.
        let rec_a = s.export_state(&a).unwrap();
        let rec_b = s.export_state(&b).unwrap();
        let mut a2 = a.clone();
        let b2 = b.clone();
        // Reference continues: boundary 3, replica 1 dead at closing 149.
        s.offer_outer(&mut comm, &a, &live, 3).unwrap();
        s.fold_boundary(&mut comm, &mut a, &live, 3).unwrap();
        // Resumed side: fresh engine + fresh communicator, sender-replay.
        let mut s2 = AsyncGossipSync::from_config(&cfg);
        let mut comm2 = AccountingComm::new();
        s2.restore_state(&mut comm2, &a2, &rec_a).unwrap();
        s2.restore_state(&mut comm2, &b2, &rec_b).unwrap();
        s2.offer_outer(&mut comm2, &a2, &live, 3).unwrap();
        s2.fold_boundary(&mut comm2, &mut a2, &live, 3).unwrap();
        assert_eq!(a2.phi, a.phi, "resumed fold must be bit-identical");
        assert_eq!(a2.delta, a.delta);
        assert_eq!(a2.theta, a.theta);
        assert_eq!(s2.admitted(), s.admitted());
        assert_eq!(s2.excluded_stale(), s.excluded_stale());
        assert_eq!(s2.max_admitted_age(), s.max_admitted_age());
        assert_eq!(s2.max_admitted_age(), 1, "the aged offer folded on both sides");
    }

    #[test]
    fn chaos_faults_keep_the_async_boundary_live_and_convergent() {
        // Combined fault soak over the real fabric: drops, duplicates,
        // reorders and CRC-corrupt frames together. Two replicas run a
        // quadratic inner problem (θ ← θ − lr (θ − target)) under the
        // bounded-staleness engine; the run must stay live (no fold ever
        // blocks past the gossip timeout), converge onto the target, and
        // the corrupt frames must show up dropped-and-counted.
        use std::time::Duration;
        let mut cfg = async_cfg(3);
        cfg.seed = 11;
        let plan = crate::net::FaultPlan {
            drop_prob: 0.2,
            dup_prob: 0.1,
            reorder_prob: 0.2,
            corrupt_prob: 0.15,
            ..crate::net::FaultPlan::none()
        };
        let mut fabric = crate::net::Fabric::with_faults(2, plan, cfg.seed);
        let rounds = 60u64;
        let dim = 8usize;
        let handles: Vec<_> = fabric
            .take_endpoints()
            .into_iter()
            .enumerate()
            .map(|(me, ep)| {
                let cfg = cfg.clone();
                std::thread::spawn(move || {
                    let mut comm = crate::train::FabricComm::new(
                        ep,
                        2,
                        Some(Duration::from_millis(30)),
                    );
                    let mut s = AsyncGossipSync::from_config(&cfg);
                    let start = 1.0 + 3.0 * me as f32;
                    let mut w = worker(me, vec![start; dim]);
                    w.phi.copy_from_slice(&w.theta);
                    let target = 0.5f32;
                    let live = vec![0usize, 1];
                    for b in 1..=rounds {
                        for _ in 0..4 {
                            for t in w.theta.iter_mut() {
                                *t -= 0.4 * (*t - target);
                            }
                        }
                        s.offer_outer(&mut comm, &w, &live, b).unwrap();
                        s.fold_boundary(&mut comm, &mut w, &live, b).unwrap();
                    }
                    let dist = w
                        .theta
                        .iter()
                        .fold(0.0f32, |m, t| m.max((t - target).abs()));
                    (dist, s.admitted() + s.excluded_stale())
                })
            })
            .collect();
        for h in handles {
            let (dist, folds) = h.join().expect("a chaos worker panicked");
            assert!(dist < 0.1, "worker ended {dist} away from the target");
            assert_eq!(folds, rounds, "every boundary folded exactly once");
        }
        let corrupt: u64 = fabric.corrupt_dropped().iter().sum();
        assert!(corrupt > 0, "corrupt frames must be dropped and counted");
    }
}
