//! Typed wrappers around the artifact functions.
//!
//! Each wrapper packs host buffers into literals, invokes the compiled
//! executable, and unpacks the tuple — the *only* place argument order of
//! the Python-lowered functions is encoded on the Rust side (and the
//! cross-language golden tests in `rust/tests/runtime_e2e.rs` pin it).

use anyhow::{ensure, Result};

use crate::model::StageKind;
use crate::runtime::{self, funcs, Engine, Manifest};

/// Adam hyper-parameters for the scalar operand (paper §4 settings).
#[derive(Clone, Copy, Debug)]
pub struct AdamScalars {
    pub lr: f32,
    /// 1-based step count (bias correction).
    pub t: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Global-norm clip threshold (paper: 1.0).
    pub clip: f32,
}

impl AdamScalars {
    /// Paper defaults at a given LR and step.
    pub fn at(lr: f64, t: u64, clip: f64) -> AdamScalars {
        AdamScalars {
            lr: lr as f32,
            t: t as f32,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            clip: clip as f32,
        }
    }

    fn pack(&self) -> [f32; 6] {
        [self.lr, self.t, self.beta1, self.beta2, self.eps, self.clip]
    }
}

/// Initialize a stage's flat parameters on-device (the `init` artifact).
pub fn init_stage(eng: &mut Engine, kind: StageKind, seed: i32) -> Result<Vec<f32>> {
    let out = eng.execute(kind.as_str(), funcs::INIT, &[runtime::lit_scalar_i32(seed)])?;
    runtime::to_vec_f32(&out[0])
}

/// Forward a token-consuming stage (`first`): tokens -> hidden.
pub fn fwd_first(eng: &mut Engine, man: &Manifest, flat: &[f32], toks: &[i32]) -> Result<Vec<f32>> {
    let (mb, s) = (man.mb, man.seq_len);
    ensure!(toks.len() == mb * s, "fwd_first: token shape");
    let out = eng.execute(
        "first",
        funcs::FWD,
        &[
            runtime::lit_f32(flat, &[flat.len()])?,
            runtime::lit_i32(toks, &[mb, s])?,
        ],
    )?;
    runtime::to_vec_f32(&out[0])
}

/// Forward an interior stage (`mid`): hidden -> hidden.
pub fn fwd_mid(eng: &mut Engine, man: &Manifest, flat: &[f32], x: &[f32]) -> Result<Vec<f32>> {
    let (mb, s, h) = (man.mb, man.seq_len, man.hidden);
    ensure!(x.len() == mb * s * h, "fwd_mid: hidden shape");
    let out = eng.execute(
        "mid",
        funcs::FWD,
        &[
            runtime::lit_f32(flat, &[flat.len()])?,
            runtime::lit_f32(x, &[mb, s, h])?,
        ],
    )?;
    runtime::to_vec_f32(&out[0])
}

/// Validation loss of the `last` stage: (hidden, tokens) -> mean nll.
pub fn loss_last(
    eng: &mut Engine,
    man: &Manifest,
    flat: &[f32],
    x: &[f32],
    toks: &[i32],
) -> Result<f32> {
    let (mb, s, h) = (man.mb, man.seq_len, man.hidden);
    let out = eng.execute(
        "last",
        funcs::LOSS,
        &[
            runtime::lit_f32(flat, &[flat.len()])?,
            runtime::lit_f32(x, &[mb, s, h])?,
            runtime::lit_i32(toks, &[mb, s])?,
        ],
    )?;
    runtime::to_f32(&out[0])
}

/// Validation loss of the `full` (pp = 1) stage.
pub fn loss_full(eng: &mut Engine, man: &Manifest, flat: &[f32], toks: &[i32]) -> Result<f32> {
    let (mb, s) = (man.mb, man.seq_len);
    let out = eng.execute(
        "full",
        funcs::LOSS,
        &[
            runtime::lit_f32(flat, &[flat.len()])?,
            runtime::lit_i32(toks, &[mb, s])?,
        ],
    )?;
    runtime::to_f32(&out[0])
}

/// Backward of `last`: (hidden, tokens) -> (loss, param grads, input grad).
pub fn bwd_last(
    eng: &mut Engine,
    man: &Manifest,
    flat: &[f32],
    x: &[f32],
    toks: &[i32],
) -> Result<(f32, Vec<f32>, Vec<f32>)> {
    let (mb, s, h) = (man.mb, man.seq_len, man.hidden);
    let out = eng.execute(
        "last",
        funcs::BWD,
        &[
            runtime::lit_f32(flat, &[flat.len()])?,
            runtime::lit_f32(x, &[mb, s, h])?,
            runtime::lit_i32(toks, &[mb, s])?,
        ],
    )?;
    ensure!(out.len() == 3, "last.bwd arity");
    Ok((
        runtime::to_f32(&out[0])?,
        runtime::to_vec_f32(&out[1])?,
        runtime::to_vec_f32(&out[2])?,
    ))
}

/// Backward of `mid`: (x_in, g_out) -> (param grads, input grad).
pub fn bwd_mid(
    eng: &mut Engine,
    man: &Manifest,
    flat: &[f32],
    x: &[f32],
    g: &[f32],
) -> Result<(Vec<f32>, Vec<f32>)> {
    let (mb, s, h) = (man.mb, man.seq_len, man.hidden);
    let out = eng.execute(
        "mid",
        funcs::BWD,
        &[
            runtime::lit_f32(flat, &[flat.len()])?,
            runtime::lit_f32(x, &[mb, s, h])?,
            runtime::lit_f32(g, &[mb, s, h])?,
        ],
    )?;
    ensure!(out.len() == 2, "mid.bwd arity");
    Ok((runtime::to_vec_f32(&out[0])?, runtime::to_vec_f32(&out[1])?))
}

/// Backward of `first`: (tokens, g_out) -> param grads.
pub fn bwd_first(
    eng: &mut Engine,
    man: &Manifest,
    flat: &[f32],
    toks: &[i32],
    g: &[f32],
) -> Result<Vec<f32>> {
    let (mb, s, h) = (man.mb, man.seq_len, man.hidden);
    let out = eng.execute(
        "first",
        funcs::BWD,
        &[
            runtime::lit_f32(flat, &[flat.len()])?,
            runtime::lit_i32(toks, &[mb, s])?,
            runtime::lit_f32(g, &[mb, s, h])?,
        ],
    )?;
    runtime::to_vec_f32(&out[0])
}

/// Backward of `full`: tokens -> (loss, param grads).
pub fn bwd_full(
    eng: &mut Engine,
    man: &Manifest,
    flat: &[f32],
    toks: &[i32],
) -> Result<(f32, Vec<f32>)> {
    let (mb, s) = (man.mb, man.seq_len);
    let out = eng.execute(
        "full",
        funcs::BWD,
        &[
            runtime::lit_f32(flat, &[flat.len()])?,
            runtime::lit_i32(toks, &[mb, s])?,
        ],
    )?;
    ensure!(out.len() == 2, "full.bwd arity");
    Ok((runtime::to_f32(&out[0])?, runtime::to_vec_f32(&out[1])?))
}

/// One fused Adam step (`adam` artifact): updates `(flat, m, v)` in place.
pub fn adam_step(
    eng: &mut Engine,
    kind: StageKind,
    flat: &mut Vec<f32>,
    m: &mut Vec<f32>,
    v: &mut Vec<f32>,
    g: &[f32],
    sc: AdamScalars,
) -> Result<()> {
    let n = flat.len();
    let out = eng.execute(
        kind.as_str(),
        funcs::ADAM,
        &[
            runtime::lit_f32(flat, &[n])?,
            runtime::lit_f32(m, &[n])?,
            runtime::lit_f32(v, &[n])?,
            runtime::lit_f32(g, &[n])?,
            runtime::lit_scalars(&sc.pack()),
        ],
    )?;
    ensure!(out.len() == 3, "adam arity");
    *flat = runtime::to_vec_f32(&out[0])?;
    *m = runtime::to_vec_f32(&out[1])?;
    *v = runtime::to_vec_f32(&out[2])?;
    Ok(())
}

/// Fused NoLoCo outer step (Eq. 2–3) over group *sums*: updates
/// `(phi, delta)` in place. `inv_n` is `1/group-size`.
#[allow(clippy::too_many_arguments)]
pub fn outer_noloco(
    eng: &mut Engine,
    kind: StageKind,
    phi: &mut Vec<f32>,
    delta: &mut Vec<f32>,
    dsum: &[f32],
    psum: &[f32],
    alpha: f32,
    beta: f32,
    gamma: f32,
    inv_n: f32,
) -> Result<()> {
    let n = phi.len();
    let out = eng.execute(
        kind.as_str(),
        funcs::OUTER_NOLOCO,
        &[
            runtime::lit_f32(phi, &[n])?,
            runtime::lit_f32(delta, &[n])?,
            runtime::lit_f32(dsum, &[n])?,
            runtime::lit_f32(psum, &[n])?,
            runtime::lit_scalars(&[alpha, beta, gamma, inv_n]),
        ],
    )?;
    ensure!(out.len() == 2, "outer_noloco arity");
    *phi = runtime::to_vec_f32(&out[0])?;
    *delta = runtime::to_vec_f32(&out[1])?;
    Ok(())
}

/// Fused DiLoCo outer step over the all-reduced *mean* outer gradient:
/// updates `(phi, delta)` in place.
pub fn outer_diloco(
    eng: &mut Engine,
    kind: StageKind,
    phi: &mut Vec<f32>,
    delta: &mut Vec<f32>,
    dmean: &[f32],
    alpha: f32,
    beta: f32,
) -> Result<()> {
    let n = phi.len();
    let out = eng.execute(
        kind.as_str(),
        funcs::OUTER_DILOCO,
        &[
            runtime::lit_f32(phi, &[n])?,
            runtime::lit_f32(delta, &[n])?,
            runtime::lit_f32(dmean, &[n])?,
            runtime::lit_scalars(&[alpha, beta, 0.0, 1.0]),
        ],
    )?;
    ensure!(out.len() == 2, "outer_diloco arity");
    *phi = runtime::to_vec_f32(&out[0])?;
    *delta = runtime::to_vec_f32(&out[1])?;
    Ok(())
}
