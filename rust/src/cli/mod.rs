//! Zero-dependency command-line parsing.
//!
//! `clap` is not available offline; this is the small substrate standing
//! in for it. Grammar: `prog <subcommand> [--flag] [--key value]...
//! [positional]...`. Flags may also be written `--key=value`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token (subcommand), if any.
    pub command: Option<String>,
    /// `--key value` / `--key=value` options (last occurrence wins), except
    /// repeatable keys collected in [`Args::multi`].
    pub options: BTreeMap<String, String>,
    /// Repeated `--set path=value` overrides, in order.
    pub sets: Vec<(String, String)>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
    /// Remaining positionals after the subcommand.
    pub positionals: Vec<String>,
}

/// Keys that take a value. Anything else starting `--` is a boolean flag.
const VALUE_KEYS: &[&str] = &[
    "preset", "config", "method", "dataset", "routing", "steps", "dp", "pp", "seed",
    "out", "artifacts", "set", "eval-every", "inner-steps", "group", "alpha", "beta",
    "gamma", "warmup", "world", "sigma", "mu", "iters", "dim", "omega", "outer-steps",
    "batch-tokens", "csv", "topo", "regions", "churn", "payload", "pairing", "sync",
    "fragments", "overlap", "staleness", "stash-age", "detect", "detect-misses",
    "trace-out", "metrics-out", "trace-level", "ckpt-out", "ckpt-every", "resume",
    "fault-drop", "fault-dup", "fault-delay", "fault-delay-secs", "fault-reorder",
    "fault-corrupt", "executor", "halt-after", "format", "root", "transport",
    "seed-addr", "rank", "bind", "report-out", "val-batches", "threads",
];

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if VALUE_KEYS.contains(&key.as_str()) {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{key} expects a value"))?,
                    };
                    if key == "set" {
                        let (p, v) = val
                            .split_once('=')
                            .ok_or_else(|| format!("--set expects path=value, got `{val}`"))?;
                        out.sets.push((p.to_string(), v.to_string()));
                    } else {
                        out.options.insert(key, val);
                    }
                } else if let Some(v) = inline_val {
                    // Unknown-but-valued key: accept as option (forward
                    // compatibility for example-specific knobs).
                    out.options.insert(key, v);
                } else {
                    out.flags.push(key);
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the real process arguments.
    pub fn from_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    /// Option value as string.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Option parsed as `usize`.
    pub fn opt_usize(&self, key: &str) -> Result<Option<usize>, String> {
        self.opt(key)
            .map(|v| v.parse().map_err(|_| format!("--{key} expects an integer, got `{v}`")))
            .transpose()
    }

    /// Option parsed as `f64`.
    pub fn opt_f64(&self, key: &str) -> Result<Option<f64>, String> {
        self.opt(key)
            .map(|v| v.parse().map_err(|_| format!("--{key} expects a number, got `{v}`")))
            .transpose()
    }

    /// Option parsed as `u64`.
    pub fn opt_u64(&self, key: &str) -> Result<Option<u64>, String> {
        self.opt(key)
            .map(|v| v.parse().map_err(|_| format!("--{key} expects an integer, got `{v}`")))
            .transpose()
    }

    /// Whether a bare flag was passed.
    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

/// Build a [`crate::config::TrainConfig`] from preset + file + overrides,
/// shared by the binary and the examples.
pub fn train_config_from(args: &Args) -> Result<crate::config::TrainConfig, String> {
    use crate::config::{presets, toml::Doc, Dataset, Method, Routing};
    let preset_name = args.opt("preset").unwrap_or("tiny");
    let mut cfg = presets::preset(preset_name)
        .ok_or_else(|| format!("unknown preset `{preset_name}` (try: {:?})", presets::PRESET_NAMES))?;
    if let Some(path) = args.opt("config") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        let doc = Doc::parse(&text).map_err(|e| e.to_string())?;
        cfg.apply_doc(&doc)?;
    }
    if let Some(m) = args.opt("method") {
        match Method::parse(m) {
            Some(Method::DiLoCo) => cfg = presets::as_diloco(cfg),
            Some(Method::Fsdp) => cfg = presets::as_fsdp(cfg),
            Some(Method::NoLoCo) => cfg.outer.method = Method::NoLoCo,
            None => return Err(format!("unknown method `{m}`")),
        }
    }
    if let Some(d) = args.opt("dataset") {
        cfg.dataset = Dataset::parse(d).ok_or_else(|| format!("unknown dataset `{d}`"))?;
    }
    if let Some(r) = args.opt("routing") {
        cfg.routing = Routing::parse(r).ok_or_else(|| format!("unknown routing `{r}`"))?;
    }
    if let Some(v) = args.opt_usize("steps")? {
        cfg.steps = v;
    }
    if let Some(v) = args.opt_usize("dp")? {
        cfg.topology.dp = v;
    }
    if let Some(v) = args.opt_usize("pp")? {
        cfg.topology.pp = v;
    }
    if let Some(v) = args.opt_u64("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = args.opt_usize("inner-steps")? {
        cfg.outer.inner_steps = v;
    }
    if let Some(v) = args.opt_f64("gamma")? {
        cfg.outer.gamma = v;
    }
    if let Some(v) = args.opt_usize("eval-every")? {
        cfg.eval_every = v;
    }
    if let Some(v) = args.opt_usize("batch-tokens")? {
        cfg.model.batch_tokens = v;
    }
    if let Some(a) = args.opt("artifacts") {
        cfg.artifacts_dir = a.to_string();
    }
    if let Some(t) = args.opt("topo") {
        cfg.net.preset = crate::config::NetPreset::parse(t)
            .ok_or_else(|| format!("unknown network preset `{t}` (lan|wan|long-tail)"))?;
    }
    if let Some(v) = args.opt_usize("regions")? {
        cfg.net.regions = v;
    }
    if let Some(c) = args.opt("churn") {
        cfg.churn = crate::net::topo::ChurnSchedule::parse(c)?;
    }
    if let Some(p) = args.opt("pairing") {
        cfg.pairing = crate::config::PairingMode::parse(p)
            .ok_or_else(|| format!("unknown pairing policy `{p}` (uniform|bandwidth-aware)"))?;
    }
    if let Some(s) = args.opt("sync") {
        cfg.sync = crate::config::SyncMode::parse(s)
            .ok_or_else(|| format!("unknown sync mode `{s}` (gated|streaming)"))?;
    }
    if let Some(v) = args.opt_usize("fragments")? {
        cfg.stream.fragments = v;
    }
    if let Some(o) = args.opt("overlap") {
        cfg.stream.overlap = match o.to_ascii_lowercase().as_str() {
            "on" | "true" | "1" => true,
            "off" | "false" | "0" => false,
            _ => return Err(format!("--overlap expects on|off, got `{o}`")),
        };
    }
    if let Some(v) = args.opt_usize("staleness")? {
        cfg.outer.staleness = v;
    }
    if let Some(v) = args.opt_usize("stash-age")? {
        cfg.stream.stash_age = v;
    }
    if let Some(d) = args.opt("detect") {
        cfg.detect.enabled = match d.to_ascii_lowercase().as_str() {
            "on" | "true" | "1" => true,
            "off" | "false" | "0" => false,
            _ => return Err(format!("--detect expects on|off, got `{d}`")),
        };
    }
    if let Some(v) = args.opt_usize("detect-misses")? {
        cfg.detect.misses = v;
    }
    if let Some(p) = args.opt("trace-out") {
        cfg.obs.trace_out = Some(p.to_string());
    }
    if let Some(p) = args.opt("metrics-out") {
        cfg.obs.metrics_out = Some(p.to_string());
    }
    if let Some(l) = args.opt("trace-level") {
        cfg.obs.trace_level = crate::config::TraceLevel::parse(l)
            .ok_or_else(|| format!("unknown trace level `{l}` (off|boundary|step)"))?;
    }
    if let Some(p) = args.opt("ckpt-out") {
        cfg.ckpt.out = Some(p.to_string());
    }
    if let Some(v) = args.opt_usize("ckpt-every")? {
        cfg.ckpt.every = v;
    }
    if let Some(p) = args.opt("resume") {
        cfg.ckpt.resume = Some(p.to_string());
    }
    if let Some(v) = args.opt_f64("fault-drop")? {
        cfg.faults.drop = v;
    }
    if let Some(v) = args.opt_f64("fault-dup")? {
        cfg.faults.dup = v;
    }
    if let Some(v) = args.opt_f64("fault-delay")? {
        cfg.faults.delay = v;
    }
    if let Some(v) = args.opt_f64("fault-delay-secs")? {
        cfg.faults.delay_secs = v;
    }
    if let Some(v) = args.opt_f64("fault-reorder")? {
        cfg.faults.reorder = v;
    }
    if let Some(v) = args.opt_f64("fault-corrupt")? {
        cfg.faults.corrupt = v;
    }
    if let Some(t) = args.opt("transport") {
        cfg.transport.kind = crate::config::TransportKind::parse(t)
            .ok_or_else(|| format!("unknown transport `{t}` (threads|socket)"))?;
    }
    if let Some(a) = args.opt("seed-addr") {
        cfg.transport.seed_addr = a.to_string();
    }
    if let Some(v) = args.opt_usize("rank")? {
        cfg.transport.rank = v;
    }
    if let Some(b) = args.opt("bind") {
        cfg.transport.bind = b.to_string();
    }
    if let Some(p) = args.opt("report-out") {
        cfg.transport.report_out = Some(p.to_string());
    }
    if let Some(v) = args.opt_usize("threads")? {
        cfg.perf.threads = v;
    }
    // --set model.hidden=128 style overrides, applied last.
    if !args.sets.is_empty() {
        let mut text = String::new();
        for (p, v) in &args.sets {
            text.push_str(&format!("{p} = {v}\n"));
        }
        let doc = Doc::parse(&text).map_err(|e| e.to_string())?;
        cfg.apply_doc(&doc)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_options_flags_positionals() {
        let a = parse(&["train", "--preset", "small", "--verbose", "extra"]);
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.opt("preset"), Some("small"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positionals, vec!["extra"]);
    }

    #[test]
    fn equals_syntax_and_sets() {
        let a = parse(&["train", "--steps=42", "--set", "model.hidden=96", "--set=outer.alpha=0.4"]);
        assert_eq!(a.opt_usize("steps").unwrap(), Some(42));
        assert_eq!(a.sets.len(), 2);
        assert_eq!(a.sets[0], ("model.hidden".into(), "96".into()));
        assert_eq!(a.sets[1], ("outer.alpha".into(), "0.4".into()));
    }

    #[test]
    fn missing_value_is_an_error() {
        assert!(Args::parse(["--preset".to_string()]).is_err());
    }

    #[test]
    fn train_config_pipeline_applies_overrides() {
        let a = parse(&[
            "train",
            "--preset",
            "tiny",
            "--method",
            "diloco",
            "--dp",
            "4",
            "--steps",
            "10",
            "--set",
            "model.hidden=96",
        ]);
        let cfg = train_config_from(&a).unwrap();
        assert_eq!(cfg.outer.method, crate::config::Method::DiLoCo);
        assert_eq!(cfg.topology.dp, 4);
        assert_eq!(cfg.steps, 10);
        assert_eq!(cfg.model.hidden, 96);
        // heads=4 divides 96, layers=4 divide pp=2 — still valid.
        cfg.validate().unwrap();
    }

    #[test]
    fn train_config_rejects_bad_method() {
        let a = parse(&["train", "--method", "sgd"]);
        assert!(train_config_from(&a).is_err());
    }

    #[test]
    fn pairing_flag_plumbs_through() {
        let a = parse(&["train", "--pairing", "bandwidth-aware", "--topo", "wan"]);
        let cfg = train_config_from(&a).unwrap();
        assert_eq!(cfg.pairing, crate::config::PairingMode::BandwidthAware);
        let a = parse(&["train", "--pairing", "nearest"]);
        assert!(train_config_from(&a).unwrap_err().contains("pairing"));
    }

    #[test]
    fn sync_flags_plumb_through() {
        let a = parse(&[
            "train", "--sync", "streaming", "--fragments", "8", "--overlap", "off",
        ]);
        let cfg = train_config_from(&a).unwrap();
        assert_eq!(cfg.sync, crate::config::SyncMode::Streaming);
        assert_eq!(cfg.stream.fragments, 8);
        assert!(!cfg.stream.overlap);
        let a = parse(&["train", "--sync", "bulk"]);
        assert!(train_config_from(&a).unwrap_err().contains("sync"));
        let a = parse(&["train", "--overlap", "maybe"]);
        assert!(train_config_from(&a).unwrap_err().contains("overlap"));
        // Streaming over FSDP is rejected by validation at the end.
        let a = parse(&["train", "--sync", "streaming", "--method", "fsdp"]);
        assert!(train_config_from(&a).is_err());
    }

    #[test]
    fn async_boundary_flags_plumb_through() {
        let a = parse(&[
            "train", "--staleness", "3", "--stash-age", "6", "--detect", "on",
            "--detect-misses", "4", "--pairing", "per-fragment",
        ]);
        let cfg = train_config_from(&a).unwrap();
        assert_eq!(cfg.outer.staleness, 3);
        assert_eq!(cfg.stream.stash_age, 6);
        assert!(cfg.detect.enabled);
        assert_eq!(cfg.detect.misses, 4);
        assert_eq!(cfg.pairing, crate::config::PairingMode::PerFragment);
        // Staleness > 1 over a collective method fails validation.
        let a = parse(&["train", "--staleness", "2", "--method", "diloco"]);
        assert!(train_config_from(&a).is_err());
        let a = parse(&["train", "--detect", "maybe"]);
        assert!(train_config_from(&a).unwrap_err().contains("detect"));
        // The hier topology preset parses from --topo.
        let a = parse(&["train", "--topo", "hier"]);
        let cfg = train_config_from(&a).unwrap();
        assert_eq!(cfg.net.preset, crate::config::NetPreset::HierarchicalDc);
    }

    #[test]
    fn obs_flags_plumb_through() {
        let a = parse(&[
            "train", "--trace-out", "run.jsonl", "--metrics-out=live.json",
            "--trace-level", "boundary",
        ]);
        let cfg = train_config_from(&a).unwrap();
        assert_eq!(cfg.obs.trace_out.as_deref(), Some("run.jsonl"));
        assert_eq!(cfg.obs.metrics_out.as_deref(), Some("live.json"));
        assert_eq!(cfg.obs.trace_level, crate::config::TraceLevel::Boundary);
        assert!(cfg.obs.enabled());
        let a = parse(&["train", "--trace-level", "verbose"]);
        assert!(train_config_from(&a).unwrap_err().contains("trace level"));
        // No sink configured: observability stays off.
        let cfg = train_config_from(&parse(&["train"])).unwrap();
        assert!(!cfg.obs.enabled());
    }

    #[test]
    fn ckpt_and_fault_flags_plumb_through() {
        let a = parse(&[
            "train", "--ckpt-out", "run.ckpt", "--ckpt-every", "2", "--resume=old.ckpt",
            "--fault-drop", "0.2", "--fault-dup", "0.1", "--fault-reorder", "0.2",
            "--fault-corrupt", "0.05",
        ]);
        let cfg = train_config_from(&a).unwrap();
        assert_eq!(cfg.ckpt.out.as_deref(), Some("run.ckpt"));
        assert_eq!(cfg.ckpt.every, 2);
        assert_eq!(cfg.ckpt.resume.as_deref(), Some("old.ckpt"));
        assert!(cfg.ckpt.armed());
        assert!((cfg.faults.drop - 0.2).abs() < 1e-12);
        assert!((cfg.faults.corrupt - 0.05).abs() < 1e-12);
        assert!(cfg.faults.any());
        let plan = cfg.faults.plan();
        assert!((plan.drop_prob - 0.2).abs() < 1e-12 && !plan.is_none());
        // A path without a cadence never fires — rejected up front.
        let a = parse(&["train", "--ckpt-out", "run.ckpt"]);
        assert!(train_config_from(&a).unwrap_err().contains("ckpt.every"));
        // Probabilities must be probabilities.
        let a = parse(&["train", "--fault-drop", "1.5"]);
        assert!(train_config_from(&a).unwrap_err().contains("probability"));
    }

    #[test]
    fn transport_flags_plumb_through() {
        let a = parse(&[
            "run", "--transport", "socket", "--seed-addr", "127.0.0.1:29500",
            "--rank", "1", "--bind=0.0.0.0:0", "--report-out", "r1.report",
        ]);
        let cfg = train_config_from(&a).unwrap();
        assert_eq!(cfg.transport.kind, crate::config::TransportKind::Socket);
        assert_eq!(cfg.transport.seed_addr, "127.0.0.1:29500");
        assert_eq!(cfg.transport.rank, 1);
        assert_eq!(cfg.transport.bind, "0.0.0.0:0");
        assert_eq!(cfg.transport.report_out.as_deref(), Some("r1.report"));
        let a = parse(&["run", "--transport", "avian"]);
        assert!(train_config_from(&a).unwrap_err().contains("transport"));
        // A rank outside the dp·pp world fails validation up front.
        let a = parse(&["run", "--transport", "socket", "--rank", "9"]);
        assert!(train_config_from(&a).unwrap_err().contains("transport.rank"));
    }

    #[test]
    fn perf_flags_plumb_through() {
        // Default is the serial walk.
        let cfg = train_config_from(&parse(&["train"])).unwrap();
        assert_eq!(cfg.perf.threads, 1);
        assert!(!cfg.perf.parallel_requested());
        let a = parse(&["train", "--threads", "8"]);
        let cfg = train_config_from(&a).unwrap();
        assert_eq!(cfg.perf.threads, 8);
        assert!(cfg.perf.parallel_requested());
        // 0 = auto-detect; the pool resolves it to the machine width.
        let a = parse(&["train", "--threads", "0"]);
        let cfg = train_config_from(&a).unwrap();
        assert!(cfg.perf.parallel_requested());
        // The [perf] config-file path feeds the same knob.
        let a = parse(&["train", "--set", "perf.threads=4"]);
        assert_eq!(train_config_from(&a).unwrap().perf.threads, 4);
        // Implausible counts are a config error, not a silent hang.
        let a = parse(&["train", "--threads", "100000"]);
        assert!(train_config_from(&a).unwrap_err().contains("perf.threads"));
    }

    #[test]
    fn topo_and_churn_flags_plumb_through() {
        let a = parse(&[
            "train", "--topo", "wan", "--regions", "3", "--churn", "leave:4:1;join:8:1",
        ]);
        let cfg = train_config_from(&a).unwrap();
        assert_eq!(cfg.net.preset, crate::config::NetPreset::MultiRegionWan);
        assert_eq!(cfg.net.regions, 3);
        assert_eq!(cfg.churn.events().len(), 2);
        // Churn referencing a replica outside the dp grid fails validation.
        let a = parse(&["train", "--churn", "leave:4:7"]);
        assert!(train_config_from(&a).is_err());
    }
}
