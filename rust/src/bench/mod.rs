//! Measurement helpers shared by the `cargo bench` targets.
//!
//! Criterion is unavailable offline, so the bench binaries are
//! `harness = false` and use this small, deterministic-enough measurement
//! core: warm-up phase, timed phase, robust statistics (median/p95), and
//! aligned table output.

use std::time::{Duration, Instant};

use crate::collective::{
    boundary_idle_times, pair_average_time_bytes, streamed_pair_residual_bytes,
};
use crate::config::NetTopoConfig;
use crate::net::SimClock;
use crate::rngx::Pcg64;
use crate::train::{PairingPolicy, UniformPairing};

/// Mean gated outer-sync time vs streamed residual over `rounds` uniform
/// NoLoCo pairings on `cfg`'s topology: per round, the gated cost is the
/// full `payload` pair exchange ([`pair_average_time_bytes`]) and the
/// streamed cost is the per-fragment residual left visible after each of
/// `fragments` chunks hides behind `compute` seconds of inner phase
/// ([`streamed_pair_residual_bytes`]). Returns `(gated, residual)` mean
/// seconds. One measurement protocol shared by `bench_topo`'s hiding-ratio
/// section and `examples/streaming_overlap` so the two cannot drift.
pub fn gated_vs_streamed_pair_sync(
    cfg: &NetTopoConfig,
    dp: usize,
    payload: u64,
    fragments: usize,
    compute: f64,
    rounds: u64,
) -> (f64, f64) {
    let live: Vec<usize> = (0..dp).collect();
    let (mut gated, mut resid) = (0.0f64, 0.0f64);
    for outer_idx in 1..=rounds {
        let pairs: Vec<(usize, usize)> = UniformPairing
            .draw(&live, 2, 0, outer_idx, 7)
            .into_iter()
            .filter(|g| g.len() == 2)
            .map(|g| (g[0], g[1]))
            .collect();
        let mut c = SimClock::with_topology(cfg.build(dp, 11), outer_idx);
        gated += pair_average_time_bytes(&mut c, Some(&pairs), payload);
        let mut c = SimClock::with_topology(cfg.build(dp, 11), outer_idx ^ 0x5a5a);
        resid += streamed_pair_residual_bytes(&mut c, Some(&pairs), payload, fragments, compute);
    }
    (gated / rounds as f64, resid / rounds as f64)
}

/// Mean per-worker boundary idle under the lockstep (gated) barrier vs
/// the bounded-staleness engine's wait-only-for-your-pair discipline:
/// per round, every replica draws a log-normal inner-phase compute time
/// (`LogNormal(-1, 0.45²)` seconds, the wan_churn compute model), the
/// uniform pairing exchanges `payload` bytes per pair at expected
/// transfer times, and [`boundary_idle_times`] splits the stall. An
/// optional `(node, mult)` straggler scales that node's links *and*
/// compute. Returns `(lockstep, async)` mean idle seconds — one
/// measurement protocol shared by `bench_topo`'s boundary-idle section
/// and `examples/async_gossip` so the two cannot drift.
pub fn lockstep_vs_async_idle(
    cfg: &NetTopoConfig,
    dp: usize,
    payload: u64,
    rounds: u64,
    straggler: Option<(usize, f64)>,
    seed: u64,
) -> (f64, f64) {
    let mut topo = cfg.build(dp, seed);
    if let Some((node, mult)) = straggler {
        topo.set_straggler(node, mult);
    }
    let live: Vec<usize> = (0..dp).collect();
    let mut rng = Pcg64::seed_from_u64(seed ^ 0xa51c);
    let (mut lock_sum, mut async_sum) = (0.0f64, 0.0f64);
    for outer_idx in 1..=rounds {
        let mut computes: Vec<f64> = (0..dp).map(|_| rng.log_normal(-1.0, 0.45)).collect();
        if let Some((node, mult)) = straggler {
            computes[node] *= mult;
        }
        let pairs: Vec<(usize, usize)> = UniformPairing
            .draw(&live, 2, 0, outer_idx, seed)
            .into_iter()
            .filter(|g| g.len() == 2)
            .map(|g| (g[0], g[1]))
            .collect();
        let (l, a) = boundary_idle_times(&topo, &pairs, &computes, payload);
        lock_sum += l;
        async_sum += a;
    }
    (lock_sum / rounds as f64, async_sum / rounds as f64)
}

/// One benchmark's raw measurements.
#[derive(Clone, Debug)]
pub struct Sample {
    /// Benchmark label.
    pub name: String,
    /// Per-iteration wall time in nanoseconds.
    pub iters_ns: Vec<f64>,
}

impl Sample {
    /// Median iteration time.
    pub fn median_ns(&self) -> f64 {
        percentile(&self.iters_ns, 50.0)
    }

    /// 95th-percentile iteration time.
    pub fn p95_ns(&self) -> f64 {
        percentile(&self.iters_ns, 95.0)
    }

    /// Mean iteration time.
    pub fn mean_ns(&self) -> f64 {
        if self.iters_ns.is_empty() {
            return 0.0;
        }
        self.iters_ns.iter().sum::<f64>() / self.iters_ns.len() as f64
    }
}

/// Percentile (linear interpolation) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Run `f` repeatedly: `warmup` of untimed iterations, then timed
/// iterations until `measure` elapses (at least 5).
pub fn bench<F: FnMut()>(name: &str, warmup: Duration, measure: Duration, mut f: F) -> Sample {
    let warm_start = Instant::now();
    while warm_start.elapsed() < warmup {
        f();
    }
    let mut iters_ns = Vec::new();
    let start = Instant::now();
    while start.elapsed() < measure || iters_ns.len() < 5 {
        let t = Instant::now();
        f();
        iters_ns.push(t.elapsed().as_nanos() as f64);
        if iters_ns.len() >= 100_000 {
            break;
        }
    }
    Sample { name: name.to_string(), iters_ns }
}

/// [`bench`] with default timing (0.2 s warm-up, 1 s measure) that also
/// prints the formatted row.
pub fn bench_row<F: FnMut()>(name: &str, f: F) -> Sample {
    let s = bench(name, Duration::from_millis(200), Duration::from_secs(1), f);
    println!("{}", format_row(&s));
    s
}

/// One aligned output row: name, median, p95, iteration count.
pub fn format_row(s: &Sample) -> String {
    format!(
        "  {:<44} median {:>10}  p95 {:>10}  (n={})",
        s.name,
        fmt_ns(s.median_ns()),
        fmt_ns(s.p95_ns()),
        s.iters_ns.len()
    )
}

/// Human-format nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Print a section header (visual grouping in bench output).
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn bench_collects_at_least_five_iters() {
        let s = bench("noop", Duration::ZERO, Duration::ZERO, || {});
        assert!(s.iters_ns.len() >= 5);
        assert!(s.median_ns() >= 0.0);
        assert!(s.p95_ns() >= s.median_ns());
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(1.5e9), "1.500 s");
    }

    #[test]
    fn sample_stats_on_known_data() {
        let s = Sample { name: "x".into(), iters_ns: vec![10.0, 20.0, 30.0] };
        assert!((s.mean_ns() - 20.0).abs() < 1e-12);
        assert_eq!(s.median_ns(), 20.0);
    }

    #[test]
    fn gated_vs_streamed_walk_degenerates_and_hides() {
        // On the constant-latency LAN preset, one fragment at zero
        // compute is exactly the gated exchange; a long phase hides the
        // streamed exchange entirely.
        let lan = NetTopoConfig::default();
        let (gated, resid) = gated_vs_streamed_pair_sync(&lan, 8, 1 << 20, 1, 0.0, 10);
        assert!((gated - resid).abs() < 1e-12, "{gated} vs {resid}");
        assert!(gated > 0.0);
        let (_, hidden) = gated_vs_streamed_pair_sync(&lan, 8, 1 << 20, 4, 10.0, 10);
        assert_eq!(hidden, 0.0);
    }
}
