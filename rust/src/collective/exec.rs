//! Executable collectives over fabric endpoints.
//!
//! Every rank in the participating group calls the same function with its
//! own endpoint; the functions are SPMD and deadlock-free for any group
//! that is consistent across ranks. Tags carry `(kind, step, slot)` so
//! concurrent collectives at different steps never cross-match.

// `expect` discipline: group membership (`caller not in group`) is the
// collective's caller contract — a violation is a harness bug and must
// crash loudly rather than limp into a wrong reduction.
#![allow(clippy::expect_used)]

use crate::net::{Channel, Payload, Tag};
use crate::tensor::Tensor;

use super::{tree_children, tree_parent};

/// Tag kinds reserved by the collectives (train-side tags start at 100).
const K_REDUCE: u16 = 1;
const K_BCAST: u16 = 2;
const K_PAIR: u16 = 3;
const K_RING: u16 = 4;

/// Binary-tree all-reduce **mean** over `group` (absolute ranks, must be
/// identical on all callers). `my` is this rank's contribution and is
/// overwritten with the mean. `step` namespaces the tags.
///
/// This is the DiLoCo outer-step collective (and the FSDP gradient
/// collective) of the paper's baselines.
pub fn all_reduce_mean<E: Channel>(ep: &mut E, group: &[usize], step: u32, my: &mut Tensor) {
    let n = group.len();
    if n <= 1 {
        return;
    }
    let me = group
        .iter()
        .position(|&r| r == ep.rank())
        .expect("caller not in group");
    // Reduce up the tree: children send partial sums to parents.
    for &c in &tree_children(me, n) {
        let m = ep.recv(Tag::new(K_REDUCE, step, c as u32));
        let child = Tensor::from_vec(m.payload.into_f32(), &[my.len()]);
        my.add_assign(&child);
    }
    if let Some(p) = tree_parent(me) {
        ep.send(
            group[p],
            Tag::new(K_REDUCE, step, me as u32),
            Payload::F32(my.as_slice().to_vec()),
        );
        // Wait for the broadcast of the final mean.
        let m = ep.recv(Tag::new(K_BCAST, step, me as u32));
        my.as_mut_slice().copy_from_slice(m.payload.f32());
    } else {
        // Root: finish the mean, then broadcast down.
        my.scale(1.0 / n as f32);
    }
    for &c in &tree_children(me, n) {
        ep.send(
            group[c],
            Tag::new(K_BCAST, step, c as u32),
            Payload::F32(my.as_slice().to_vec()),
        );
    }
}

/// Broadcast `buf` from `group[0]` to the rest of the group (binary tree).
pub fn broadcast<E: Channel>(ep: &mut E, group: &[usize], step: u32, buf: &mut Tensor) {
    let n = group.len();
    if n <= 1 {
        return;
    }
    let me = group
        .iter()
        .position(|&r| r == ep.rank())
        .expect("caller not in group");
    if tree_parent(me).is_some() {
        let m = ep.recv(Tag::new(K_BCAST, step, me as u32));
        buf.as_mut_slice().copy_from_slice(m.payload.f32());
    }
    for &c in &tree_children(me, n) {
        ep.send(
            group[c],
            Tag::new(K_BCAST, step, c as u32),
            Payload::F32(buf.as_slice().to_vec()),
        );
    }
}

/// Symmetric pair exchange: send `mine` to `peer`, receive theirs, return
/// it. The NoLoCo gossip primitive — exactly two messages, no collective.
pub fn pair_exchange<E: Channel>(ep: &mut E, peer: usize, step: u32, mine: &Tensor) -> Tensor {
    ep.send(
        peer,
        Tag::new(K_PAIR, step, ep.rank() as u32),
        Payload::F32(mine.as_slice().to_vec()),
    );
    let m = ep.recv(Tag::new(K_PAIR, step, peer as u32));
    Tensor::from_vec(m.payload.into_f32(), &[mine.len()])
}

/// Ring all-reduce mean (reduce-scatter + all-gather), the
/// bandwidth-optimal collective large clusters actually deploy; included
/// as a second baseline topology for the latency study and tested for
/// numerical agreement with the tree.
pub fn reduce_scatter_gather<E: Channel>(ep: &mut E, group: &[usize], step: u32, my: &mut Tensor) {
    let n = group.len();
    if n <= 1 {
        return;
    }
    let me = group
        .iter()
        .position(|&r| r == ep.rank())
        .expect("caller not in group");
    let len = my.len();
    // Chunk boundaries (chunk c covers [off[c], off[c+1])).
    let off: Vec<usize> = (0..=n).map(|c| c * len / n).collect();
    let next = group[(me + 1) % n];
    let prev_idx = (me + n - 1) % n;
    // Phase 1: reduce-scatter. After n-1 hops, rank me owns the full sum
    // of chunk (me+1) % n.
    for hop in 0..n - 1 {
        let send_c = (me + n - hop) % n;
        let recv_c = (me + n - hop - 1) % n;
        let seg = my.as_slice()[off[send_c]..off[send_c + 1]].to_vec();
        ep.send(
            next,
            Tag::new(K_RING, step, (hop * n + send_c) as u32),
            Payload::F32(seg),
        );
        let m = ep.recv(Tag::new(K_RING, step, (hop * n + recv_c) as u32));
        debug_assert_eq!(m.from, group[prev_idx]);
        let data = m.payload.f32();
        for (dst, src) in my.as_mut_slice()[off[recv_c]..off[recv_c + 1]]
            .iter_mut()
            .zip(data)
        {
            *dst += src;
        }
    }
    // Finish the mean on the owned chunk.
    let own_c = (me + 1) % n;
    for v in &mut my.as_mut_slice()[off[own_c]..off[own_c + 1]] {
        *v /= n as f32;
    }
    // Phase 2: all-gather the reduced chunks around the ring.
    for hop in 0..n - 1 {
        let send_c = (me + 1 + n - hop) % n;
        let recv_c = (me + n - hop) % n;
        let seg = my.as_slice()[off[send_c]..off[send_c + 1]].to_vec();
        ep.send(
            next,
            Tag::new(K_RING, step, ((n + hop) * n + send_c) as u32),
            Payload::F32(seg),
        );
        let m = ep.recv(Tag::new(K_RING, step, ((n + hop) * n + recv_c) as u32));
        my.as_mut_slice()[off[recv_c]..off[recv_c + 1]].copy_from_slice(m.payload.f32());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{Endpoint, Fabric};
    use std::thread;

    /// Run `f(rank, endpoint)` on every rank in its own thread.
    fn spmd<F>(n: usize, f: F) -> Vec<Tensor>
    where
        F: Fn(usize, &mut Endpoint) -> Tensor + Send + Sync + 'static,
    {
        let mut fabric = Fabric::new(n);
        let eps = fabric.take_endpoints();
        let f = std::sync::Arc::new(f);
        let handles: Vec<_> = eps
            .into_iter()
            .enumerate()
            .map(|(rank, mut ep)| {
                let f = f.clone();
                thread::spawn(move || f(rank, &mut ep))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn contribution(rank: usize, len: usize) -> Tensor {
        Tensor::from_vec(
            (0..len).map(|i| (rank * len + i) as f32).collect(),
            &[len],
        )
    }

    fn expected_mean(n: usize, len: usize) -> Vec<f32> {
        let mut acc = vec![0.0f32; len];
        for r in 0..n {
            for (a, b) in acc.iter_mut().zip(contribution(r, len).as_slice()) {
                *a += b;
            }
        }
        acc.iter().map(|x| x / n as f32).collect()
    }

    #[test]
    fn tree_all_reduce_mean_matches_direct_sum() {
        for n in [2usize, 3, 4, 7, 8] {
            let len = 33;
            let group: Vec<usize> = (0..n).collect();
            let out = spmd(n, move |rank, ep| {
                let mut t = contribution(rank, len);
                all_reduce_mean(ep, &group, 5, &mut t);
                t
            });
            let want = expected_mean(n, len);
            for (r, t) in out.iter().enumerate() {
                for (a, b) in t.as_slice().iter().zip(&want) {
                    assert!((a - b).abs() < 1e-4, "n={n} rank={r}");
                }
            }
        }
    }

    #[test]
    fn ring_matches_tree() {
        for n in [2usize, 3, 5, 8] {
            let len = 40; // not divisible by all n — exercises ragged chunks
            let group: Vec<usize> = (0..n).collect();
            let out = spmd(n, move |rank, ep| {
                let mut t = contribution(rank, len);
                reduce_scatter_gather(ep, &group, 9, &mut t);
                t
            });
            let want = expected_mean(n, len);
            for (r, t) in out.iter().enumerate() {
                for (i, (a, b)) in t.as_slice().iter().zip(&want).enumerate() {
                    assert!((a - b).abs() < 1e-3, "n={n} rank={r} i={i} {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn broadcast_from_root() {
        let n = 6;
        let group: Vec<usize> = (0..n).collect();
        let out = spmd(n, move |rank, ep| {
            let mut t = if rank == 0 {
                Tensor::from_slice(&[1.0, 2.0, 3.0])
            } else {
                Tensor::zeros(&[3])
            };
            broadcast(ep, &group, 0, &mut t);
            t
        });
        for t in out {
            assert_eq!(t.as_slice(), &[1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn pair_exchange_swaps() {
        let out = spmd(2, |rank, ep| {
            let mine = Tensor::from_slice(&[rank as f32 * 10.0]);
            pair_exchange(ep, 1 - rank, 0, &mine)
        });
        assert_eq!(out[0].as_slice(), &[10.0]);
        assert_eq!(out[1].as_slice(), &[0.0]);
    }

    #[test]
    fn subgroup_collective_leaves_outsiders_alone() {
        // Ranks 0 and 2 all-reduce; rank 1 does not participate.
        let out = spmd(3, |rank, ep| {
            let mut t = Tensor::from_slice(&[rank as f32]);
            if rank != 1 {
                all_reduce_mean(ep, &[0, 2], 3, &mut t);
            }
            t
        });
        assert_eq!(out[0].as_slice(), &[1.0]);
        assert_eq!(out[1].as_slice(), &[1.0]); // untouched
        assert_eq!(out[2].as_slice(), &[1.0]);
    }

    #[test]
    fn all_reduce_is_deterministic_across_runs() {
        let run = || {
            let group: Vec<usize> = (0..4).collect();
            spmd(4, move |rank, ep| {
                let mut t = contribution(rank, 8);
                all_reduce_mean(ep, &group, 1, &mut t);
                t
            })
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
    }

    #[test]
    fn property_tree_reduce_preserves_mean() {
        crate::prop::run("tree all-reduce preserves elementwise mean", 12, |g| {
            let n = g.usize_in(2, 6);
            let len = g.usize_in(1, 50);
            let inputs: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal(len, 2.0)).collect();
            let mut want = vec![0.0f64; len];
            for row in &inputs {
                for (w, x) in want.iter_mut().zip(row) {
                    *w += *x as f64;
                }
            }
            for w in &mut want {
                *w /= n as f64;
            }
            let group: Vec<usize> = (0..n).collect();
            let inputs2 = inputs.clone();
            let out = spmd(n, move |rank, ep| {
                let mut t = Tensor::from_vec(inputs2[rank].clone(), &[len]);
                all_reduce_mean(ep, &group, 2, &mut t);
                t
            });
            for t in out {
                for (a, b) in t.as_slice().iter().zip(&want) {
                    assert!((*a as f64 - b).abs() < 1e-3);
                }
            }
        });
    }
}
