//! Virtual-time cost models of the collectives (Fig. 5A machinery).
//!
//! Each function walks the collective's communication DAG against a
//! [`SimClock`], returning the completion (virtual) time. Compute inside
//! the collective is treated as free, matching the paper's analysis which
//! isolates message time.
//!
//! Every model exists in two forms: the seed's payload-blind form (one
//! latency draw per message) and a `*_bytes` form that charges each
//! message its wire time through [`SimClock::link_time`] — on a
//! topology-aware clock that is link latency + `bytes / bandwidth`,
//! scaled by straggler multipliers, which is what makes Fig. 5-style
//! comparisons runnable on heterogeneous WANs.

use crate::net::topo::Topology;
use crate::net::SimClock;

use super::{tree_children, tree_parent};

/// Completion time of a binary-tree all-reduce over all `clock.world()`
/// workers: reduce to the root, then broadcast back (Eq. 5 of the paper:
/// ≈ `2 t_c log2(n)` for constant latency).
pub fn tree_all_reduce_time(clock: &mut SimClock) -> f64 {
    tree_all_reduce_time_bytes(clock, 0)
}

/// Payload-aware [`tree_all_reduce_time`]: every edge carries the full
/// `bytes` payload in both the reduce and the broadcast phase, so for a
/// constant-latency link of bandwidth `w` the completion time is
/// `2 · depth(n) · (t_c + bytes/w)` — Eq. 5 with the serialization term.
pub fn tree_all_reduce_time_bytes(clock: &mut SimClock, bytes: u64) -> f64 {
    let all: Vec<usize> = (0..clock.world()).collect();
    tree_all_reduce_time_over(clock, &all, bytes)
}

/// [`tree_all_reduce_time_bytes`] over an explicit member subset: the
/// binary tree is built over `members` (in the given order; `members[0]`
/// is the root) and only those workers synchronize — the elastic-
/// membership form of the collective, used after a group rebuild shrinks
/// or grows the world. Returns the members' barrier time; non-members
/// are untouched.
pub fn tree_all_reduce_time_over(clock: &mut SimClock, members: &[usize], bytes: u64) -> f64 {
    let k = members.len();
    if k <= 1 {
        // Nothing to synchronize: a singleton (or empty) group pays no
        // communication; report its own frontier, not the global one.
        return members
            .iter()
            .map(|&w| clock.ready_at(w))
            .fold(0.0, f64::max);
    }
    // Reduce phase: process tree slots bottom-up. A parent's ready time
    // becomes max(own ready, each child's ready + message latency).
    for slot in (0..k).rev() {
        for c in tree_children(slot, k) {
            clock.send_bytes(members[c], members[slot], bytes);
        }
    }
    // Broadcast phase: top-down.
    for slot in 0..k {
        if let Some(p) = tree_parent(slot) {
            // Parent's ready time already includes the reduce; message
            // from parent to this node.
            clock.send_bytes(members[p], members[slot], bytes);
        }
    }
    // Barrier over the members only.
    let t = members
        .iter()
        .map(|&w| clock.ready_at(w))
        .fold(0.0, f64::max);
    for &w in members {
        let r = clock.ready_at(w);
        clock.compute(w, t - r);
    }
    t
}

/// Completion time of a ring all-reduce (reduce-scatter + all-gather):
/// `2(n-1)` message generations, each a full ring hop.
pub fn ring_all_reduce_time(clock: &mut SimClock) -> f64 {
    ring_all_reduce_time_bytes(clock, 0)
}

/// Payload-aware [`ring_all_reduce_time`]: each of the `2(n-1)` ring
/// generations ships one `bytes / n` chunk per worker, so bandwidth cost
/// is `≈ 2·bytes/w` total while the latency term still pays `2(n-1)`
/// hops — the classic latency/bandwidth trade against the tree.
pub fn ring_all_reduce_time_bytes(clock: &mut SimClock, bytes: u64) -> f64 {
    let n = clock.world();
    if n <= 1 {
        return clock.makespan();
    }
    let chunk = bytes.div_ceil(n as u64);
    for _phase in 0..2 * (n - 1) {
        // Every worker sends to its successor *simultaneously*: arrivals
        // are computed from the pre-generation ready times (snapshot), not
        // chained within the generation.
        let start: Vec<f64> = (0..n).map(|r| clock.ready_at(r)).collect();
        let arrive: Vec<f64> = (0..n)
            .map(|r| start[r] + clock.link_time(r, (r + 1) % n, chunk))
            .collect();
        for r in 0..n {
            let to = (r + 1) % n;
            let t = start[to].max(arrive[r]);
            // Receiver becomes ready once its predecessor's chunk lands.
            clock.compute(to, t - clock.ready_at(to));
        }
    }
    clock.barrier()
}

/// Completion time of NoLoCo's local pair averaging: the world is split
/// into disjoint pairs (given, or implicitly (2k, 2k+1)); each pair does a
/// symmetric exchange. Returns the *mean pair completion time* — there is
/// no global barrier in NoLoCo, so the interesting quantity is how long a
/// pair takes, not the straggler max (§5.3: "2·E(t_local)" as a single
/// leaf-level step of the tree).
pub fn pair_average_time(clock: &mut SimClock, pairs: Option<&[(usize, usize)]>) -> f64 {
    pair_average_time_bytes(clock, pairs, 0)
}

/// Payload-aware [`pair_average_time`]: each member ships its `bytes`
/// payload to its partner (the NoLoCo gossip exchange of (Δ, φ)).
pub fn pair_average_time_bytes(
    clock: &mut SimClock,
    pairs: Option<&[(usize, usize)]>,
    bytes: u64,
) -> f64 {
    let n = clock.world();
    let default: Vec<(usize, usize)> = (0..n / 2).map(|k| (2 * k, 2 * k + 1)).collect();
    let pairs = pairs.unwrap_or(&default);
    if pairs.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0;
    for &(a, b) in pairs {
        acc += clock.exchange_bytes(a, b, bytes);
    }
    acc / pairs.len() as f64
}

/// Residual (non-hidden) time of a *streamed* gossip outer sync
/// (Streaming-DiLoCo-style overlap): the `bytes` payload splits into
/// `fragments` equal chunks, each pair-exchanged behind one inner phase
/// of `compute` seconds, so per fragment only `max(0, t_k − compute)`
/// remains visible at a boundary. Returns the summed residual averaged
/// over pairs — the streamed counterpart of [`pair_average_time_bytes`]
/// (to which it reduces exactly at `fragments = 1`, `compute = 0`).
///
/// Unlike the gated models this does not advance the pair schedules:
/// each fragment's exchange is measured standalone, because in the
/// streamed timeline it starts at its own boundary, not chained after
/// the previous fragment.
pub fn streamed_pair_residual_bytes(
    clock: &mut SimClock,
    pairs: Option<&[(usize, usize)]>,
    bytes: u64,
    fragments: usize,
    compute: f64,
) -> f64 {
    let n = clock.world();
    let default: Vec<(usize, usize)> = (0..n / 2).map(|k| (2 * k, 2 * k + 1)).collect();
    let pairs = pairs.unwrap_or(&default);
    if pairs.is_empty() {
        return 0.0;
    }
    let k = fragments.max(1);
    let chunk = bytes.div_ceil(k as u64);
    let mut acc = 0.0;
    for &(a, b) in pairs {
        let mut resid = 0.0;
        for _ in 0..k {
            // Symmetric exchange: both directions in flight at once, the
            // pair is done when the slower one lands.
            let t = clock.link_time(a, b, chunk).max(clock.link_time(b, a, chunk));
            resid += (t - compute).max(0.0);
        }
        acc += resid;
    }
    acc / pairs.len() as f64
}

/// Streamed counterpart of [`tree_all_reduce_time_over`] for the DiLoCo
/// flavor: each of the `fragments` chunks runs its own tree all-reduce
/// behind an inner phase of `compute` seconds; the returned value is the
/// summed per-fragment residual `max(0, t_k − compute)`. Resets the
/// clock's schedule between fragments (each starts at its own boundary).
pub fn streamed_tree_residual_bytes(
    clock: &mut SimClock,
    members: &[usize],
    bytes: u64,
    fragments: usize,
    compute: f64,
) -> f64 {
    let k = fragments.max(1);
    let chunk = bytes.div_ceil(k as u64);
    let mut resid = 0.0;
    for _ in 0..k {
        clock.reset();
        let t = tree_all_reduce_time_over(clock, members, chunk);
        resid += (t - compute).max(0.0);
    }
    resid
}

/// Straggler / idle-time model of one outer boundary, lockstep vs
/// asynchronous (the async boundary engine's cost-model counterpart).
///
/// `computes[w]` is worker `w`'s inner-phase completion time this round
/// (seconds); pair `(a, b)`'s gossip exchange of `bytes` completes at
/// `max(t_a, t_b) + E[transfer_ab]`. Returns
/// `(lockstep_mean_idle, async_mean_idle)` — the mean per-worker
/// non-compute time at the boundary under each discipline:
///
/// * **lockstep** (the gated boundary): every worker additionally waits
///   at a global barrier for the slowest pair, so
///   `idle_w = T_barrier − t_w`;
/// * **async** (bounded staleness): a worker waits only for its *own*
///   pair, `idle_w = done_pair(w) − t_w`; unpaired workers wait for
///   nobody.
///
/// `async ≤ lockstep` pointwise; the gap is the straggler stall the
/// event-driven boundary removes from the critical path. Expected
/// transfers keep the model deterministic — sample `computes` outside
/// for a Monte-Carlo sweep.
pub fn boundary_idle_times(
    topo: &Topology,
    pairs: &[(usize, usize)],
    computes: &[f64],
    bytes: u64,
) -> (f64, f64) {
    let n = computes.len();
    if n == 0 {
        return (0.0, 0.0);
    }
    let mut done = computes.to_vec();
    for &(a, b) in pairs {
        let t = computes[a].max(computes[b]) + topo.expected_transfer(a, b, bytes);
        done[a] = t;
        done[b] = t;
    }
    let barrier = done.iter().fold(0.0, f64::max);
    let (mut lock, mut asy) = (0.0, 0.0);
    for w in 0..n {
        lock += barrier - computes[w];
        asy += done[w] - computes[w];
    }
    (lock / n as f64, asy / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LatencyModel;

    #[test]
    fn tree_time_matches_eq5_for_constant_latency() {
        // Constant t_c: completion ≈ 2 t_c ceil(log2 n) (depth generations
        // up + down). For a complete binary tree of n=8, depth 3 → 6 t_c.
        let mut c = SimClock::new(8, LatencyModel::Constant(1.0), 0);
        let t = tree_all_reduce_time(&mut c);
        assert_eq!(t, 6.0);
        let mut c = SimClock::new(2, LatencyModel::Constant(1.0), 0);
        assert_eq!(tree_all_reduce_time(&mut c), 2.0);
    }

    #[test]
    fn ring_time_matches_2n_minus_2_hops() {
        let n = 6;
        let mut c = SimClock::new(n, LatencyModel::Constant(0.5), 0);
        let t = ring_all_reduce_time(&mut c);
        assert_eq!(t, 0.5 * 2.0 * (n as f64 - 1.0));
    }

    #[test]
    fn pair_time_is_one_exchange_for_constant_latency() {
        let mut c = SimClock::new(16, LatencyModel::Constant(0.7), 0);
        let t = pair_average_time(&mut c, None);
        assert!((t - 0.7).abs() < 1e-12);
    }

    #[test]
    fn pair_mean_matches_eq7_for_log_normal() {
        // E[pair completion] = E[max(t1,t2)] — Eq. 7.
        let m = LatencyModel::LogNormal { mu: 0.0, sigma: 0.8 };
        let analytic = m.expected_max2();
        let mut acc = 0.0;
        let reps = 4000;
        for seed in 0..reps {
            let mut c = SimClock::new(64, m.clone(), seed);
            acc += pair_average_time(&mut c, None);
        }
        let mc = acc / reps as f64;
        assert!(
            (mc - analytic).abs() / analytic < 0.02,
            "mc={mc} analytic={analytic}"
        );
    }

    #[test]
    fn tree_bytes_matches_eq5_with_serialization_term() {
        use crate::net::topo::{Link, Topology};
        // Homogeneous constant link t_c = 1 s, bandwidth 1000 B/s, payload
        // 500 B: per-edge cost 1.5 s, complete binary tree of n = 8 has
        // depth 3 → 2 · 3 · 1.5 = 9.
        let topo = Topology::single_switch(8, Link::new(LatencyModel::Constant(1.0), 1000.0));
        let mut c = SimClock::with_topology(topo, 0);
        assert_eq!(tree_all_reduce_time_bytes(&mut c, 500), 9.0);
        // Zero payload on the same link reduces to the seed's Eq. 5 form.
        let topo = Topology::single_switch(8, Link::new(LatencyModel::Constant(1.0), 1000.0));
        let mut c = SimClock::with_topology(topo, 0);
        assert_eq!(tree_all_reduce_time_bytes(&mut c, 0), 6.0);
    }

    #[test]
    fn heterogeneous_links_slow_the_tree_not_the_local_pairs() {
        use crate::net::topo::{Link, Topology};
        // Two regions of 4; inter-region links 50× slower. The binary
        // tree inevitably crosses regions; pairs chosen inside regions
        // never do.
        let hetero = || {
            Topology::multi_region(
                &[4, 4],
                Link::constant(0.01),
                Link::constant(0.5),
            )
        };
        let homo = || Topology::single_switch(8, Link::constant(0.01));
        let mut c = SimClock::with_topology(hetero(), 0);
        let tree_het = tree_all_reduce_time_bytes(&mut c, 0);
        let mut c = SimClock::with_topology(homo(), 0);
        let tree_hom = tree_all_reduce_time_bytes(&mut c, 0);
        assert!(
            tree_het > 5.0 * tree_hom,
            "inter-region hops must dominate: het {tree_het} hom {tree_hom}"
        );
        // Intra-region pairs pay only the fast links.
        let pairs = [(0usize, 1usize), (2, 3), (4, 5), (6, 7)];
        let mut c = SimClock::with_topology(hetero(), 0);
        let pair_het = pair_average_time_bytes(&mut c, Some(&pairs), 0);
        assert_eq!(pair_het, 0.01);
    }

    #[test]
    fn subset_tree_syncs_only_its_members() {
        use crate::net::topo::{Link, Topology};
        let topo = Topology::single_switch(8, Link::constant(1.0));
        let mut c = SimClock::with_topology(topo, 0);
        // 4 members form a depth-2 tree: 2 · 2 · 1 s = 4 s.
        let members = [0usize, 2, 4, 6];
        let t = tree_all_reduce_time_over(&mut c, &members, 0);
        assert_eq!(t, 4.0);
        for &m in &members {
            assert_eq!(c.ready_at(m), 4.0);
        }
        // Non-members never waited.
        for w in [1usize, 3, 5, 7] {
            assert_eq!(c.ready_at(w), 0.0);
        }
    }

    #[test]
    fn ring_bytes_amortizes_bandwidth_over_chunks() {
        use crate::net::topo::{Link, Topology};
        // n = 4 workers, constant latency 0, bandwidth 100 B/s, payload
        // 400 B → chunk 100 B, hop cost 1 s, 2(n-1) = 6 generations → 6 s.
        let topo = Topology::single_switch(4, Link::new(LatencyModel::Constant(0.0), 100.0));
        let mut c = SimClock::with_topology(topo, 0);
        assert_eq!(ring_all_reduce_time_bytes(&mut c, 400), 6.0);
        // The tree ships the full payload per edge: depth 2, per-edge 4 s
        // → 2 · 2 · 4 = 16 s. Ring wins on bandwidth-bound payloads.
        let topo = Topology::single_switch(4, Link::new(LatencyModel::Constant(0.0), 100.0));
        let mut c = SimClock::with_topology(topo, 0);
        assert_eq!(tree_all_reduce_time_bytes(&mut c, 400), 16.0);
    }

    #[test]
    fn straggler_node_drags_the_tree_but_only_its_own_pair() {
        use crate::net::topo::{Link, Topology};
        let topo = || Topology::single_switch(8, Link::constant(0.1)).with_straggler(7, 10.0);
        let mut c = SimClock::with_topology(topo(), 0);
        let tree = tree_all_reduce_time_bytes(&mut c, 0);
        // Node 7's edge costs 1.0 in the reduce phase and again in the
        // broadcast; the whole collective waits on it.
        assert!(tree >= 2.0, "straggler must gate the barrier: {tree}");
        // Pairs not involving node 7 finish at fast-link speed.
        let mut c = SimClock::with_topology(topo(), 0);
        let fast_pairs = [(0usize, 1usize), (2, 3), (4, 5)];
        assert!((pair_average_time_bytes(&mut c, Some(&fast_pairs), 0) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn streamed_pair_residual_reduces_to_gated_at_k1_zero_compute() {
        use crate::net::topo::{Link, Topology};
        // Same draw order as `exchange_bytes` on a fresh clock: one
        // fragment at zero compute is exactly the gated exchange.
        let topo = || Topology::single_switch(8, Link::new(LatencyModel::Constant(0.3), 1000.0));
        let mut a = SimClock::with_topology(topo(), 9);
        let gated = pair_average_time_bytes(&mut a, None, 600);
        let mut b = SimClock::with_topology(topo(), 9);
        let streamed = streamed_pair_residual_bytes(&mut b, None, 600, 1, 0.0);
        assert!((gated - streamed).abs() < 1e-12, "{gated} vs {streamed}");
    }

    #[test]
    fn streamed_pair_residual_hides_behind_long_phases() {
        use crate::net::topo::{Link, Topology};
        // Constant 0.1 s latency + 1 MiB/s, 4 MiB payload in 4 fragments:
        // per-fragment exchange is 0.1 + 1.0 = 1.1 s.
        let topo =
            || Topology::single_switch(4, Link::new(LatencyModel::Constant(0.1), (1 << 20) as f64));
        let payload: u64 = 4 << 20;
        // Gated: the whole 4 MiB gates the boundary — 4.1 s.
        let mut c = SimClock::with_topology(topo(), 1);
        let gated = pair_average_time_bytes(&mut c, None, payload);
        assert!((gated - 4.1).abs() < 1e-9);
        // A 2 s inner phase swallows each 1.1 s fragment entirely.
        let mut c = SimClock::with_topology(topo(), 1);
        assert_eq!(streamed_pair_residual_bytes(&mut c, None, payload, 4, 2.0), 0.0);
        // A 0.6 s phase leaves 4 × 0.5 s visible — still half the gated
        // cost, and the fragment count now multiplies only the *latency*.
        let mut c = SimClock::with_topology(topo(), 1);
        let resid = streamed_pair_residual_bytes(&mut c, None, payload, 4, 0.6);
        assert!((resid - 2.0).abs() < 1e-9, "{resid}");
        assert!(resid < gated);
    }

    #[test]
    fn streamed_tree_residual_hides_behind_long_phases() {
        use crate::net::topo::{Link, Topology};
        // n = 8 tree, depth 3, constant 1 s latency, latency-only links:
        // each fragment's all-reduce takes 6 s regardless of the split.
        let topo = || Topology::single_switch(8, Link::constant(1.0));
        let members: Vec<usize> = (0..8).collect();
        let mut c = SimClock::with_topology(topo(), 0);
        let full = streamed_tree_residual_bytes(&mut c, &members, 0, 1, 0.0);
        assert_eq!(full, 6.0);
        let mut c = SimClock::with_topology(topo(), 0);
        assert_eq!(streamed_tree_residual_bytes(&mut c, &members, 0, 2, 6.0), 0.0);
        let mut c = SimClock::with_topology(topo(), 0);
        assert_eq!(streamed_tree_residual_bytes(&mut c, &members, 0, 2, 4.0), 4.0);
    }

    #[test]
    fn async_idle_undercuts_lockstep_under_a_straggler() {
        use crate::net::topo::{Link, Topology};
        // 6 workers, one (node 5) with a 10x-slow compute phase. Pairs
        // (0,1) (2,3) (4,5), zero-latency infinite-bandwidth links so the
        // idle comes purely from waiting on peers.
        let topo = Topology::single_switch(6, Link::constant(0.0));
        let computes = [1.0, 1.0, 1.0, 1.0, 1.0, 10.0];
        let pairs = [(0usize, 1usize), (2, 3), (4, 5)];
        let (lock, asy) = boundary_idle_times(&topo, &pairs, &computes, 0);
        // Lockstep: barrier at 10 s, idle = (9*5 + 0)/6 = 7.5.
        assert!((lock - 7.5).abs() < 1e-12, "{lock}");
        // Async: only worker 4 waits the 9 s for its partner.
        assert!((asy - 1.5).abs() < 1e-12, "{asy}");
        assert!(asy < lock);
        // No straggler, equal compute: both disciplines idle only on the
        // transfer, and they agree.
        let even = [2.0; 6];
        let topo2 = Topology::single_switch(6, Link::constant(0.5));
        let (lock, asy) = boundary_idle_times(&topo2, &pairs, &even, 0);
        assert!((lock - 0.5).abs() < 1e-12);
        assert!((asy - 0.5).abs() < 1e-12);
        // Unpaired workers never idle under async.
        let (lock, asy) = boundary_idle_times(&topo, &[(0, 5)], &computes, 0);
        assert!(asy < lock);
    }

    #[test]
    fn tree_slows_with_latency_variance_pair_does_not() {
        // The qualitative Fig. 5A claim: raising sigma (holding the mean
        // E[t] fixed) hurts tree all-reduce much more than pair averaging.
        let ratio = |sigma: f64| {
            // Fix E[t] = 1 → mu = -sigma^2/2.
            let m = LatencyModel::LogNormal { mu: -sigma * sigma / 2.0, sigma };
            let reps = 600;
            let (mut tree, mut pair) = (0.0, 0.0);
            for seed in 0..reps {
                let mut c = SimClock::new(64, m.clone(), seed);
                tree += tree_all_reduce_time(&mut c);
                let mut c = SimClock::new(64, m.clone(), seed + 10_000);
                pair += pair_average_time(&mut c, None);
            }
            tree / pair
        };
        let low = ratio(0.1);
        let high = ratio(1.2);
        assert!(high > low * 1.5, "low={low} high={high}");
        // And even at low variance the tree pays ~2 log2(64) vs ~1.
        assert!(low > 6.0, "low={low}");
    }
}
