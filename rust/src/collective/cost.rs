//! Virtual-time cost models of the collectives (Fig. 5A machinery).
//!
//! Each function walks the collective's communication DAG against a
//! [`SimClock`], returning the completion (virtual) time. Latencies are
//! drawn per message from the clock's model; compute inside the
//! collective is treated as free, matching the paper's analysis which
//! isolates message time.

use crate::net::SimClock;

use super::{tree_children, tree_parent};

/// Completion time of a binary-tree all-reduce over all `clock.world()`
/// workers: reduce to the root, then broadcast back (Eq. 5 of the paper:
/// ≈ `2 t_c log2(n)` for constant latency).
pub fn tree_all_reduce_time(clock: &mut SimClock) -> f64 {
    let n = clock.world();
    if n <= 1 {
        return clock.makespan();
    }
    // Reduce phase: process nodes bottom-up. A parent's ready time becomes
    // max(own ready, each child's ready + message latency).
    for rank in (0..n).rev() {
        for c in tree_children(rank, n) {
            clock.send(c, rank);
        }
    }
    // Broadcast phase: top-down.
    for rank in 0..n {
        if tree_parent(rank).is_some() {
            // Parent's ready time already includes the reduce; message
            // from parent to this node.
            let p = tree_parent(rank).unwrap();
            clock.send(p, rank);
        }
    }
    clock.barrier()
}

/// Completion time of a ring all-reduce (reduce-scatter + all-gather):
/// `2(n-1)` message generations, each a full ring hop.
pub fn ring_all_reduce_time(clock: &mut SimClock) -> f64 {
    let n = clock.world();
    if n <= 1 {
        return clock.makespan();
    }
    for _phase in 0..2 * (n - 1) {
        // Every worker sends to its successor *simultaneously*: arrivals
        // are computed from the pre-generation ready times (snapshot), not
        // chained within the generation.
        let start: Vec<f64> = (0..n).map(|r| clock.ready_at(r)).collect();
        let arrive: Vec<f64> = (0..n).map(|r| start[r] + clock.draw_latency()).collect();
        for r in 0..n {
            let to = (r + 1) % n;
            let t = start[to].max(arrive[r]);
            // Receiver becomes ready once its predecessor's chunk lands.
            clock.compute(to, t - clock.ready_at(to));
        }
    }
    clock.barrier()
}

/// Completion time of NoLoCo's local pair averaging: the world is split
/// into disjoint pairs (given, or implicitly (2k, 2k+1)); each pair does a
/// symmetric exchange. Returns the *mean pair completion time* — there is
/// no global barrier in NoLoCo, so the interesting quantity is how long a
/// pair takes, not the straggler max (§5.3: "2·E(t_local)" as a single
/// leaf-level step of the tree).
pub fn pair_average_time(clock: &mut SimClock, pairs: Option<&[(usize, usize)]>) -> f64 {
    let n = clock.world();
    let default: Vec<(usize, usize)> = (0..n / 2).map(|k| (2 * k, 2 * k + 1)).collect();
    let pairs = pairs.unwrap_or(&default);
    if pairs.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0;
    for &(a, b) in pairs {
        acc += clock.exchange(a, b);
    }
    acc / pairs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LatencyModel;

    #[test]
    fn tree_time_matches_eq5_for_constant_latency() {
        // Constant t_c: completion ≈ 2 t_c ceil(log2 n) (depth generations
        // up + down). For a complete binary tree of n=8, depth 3 → 6 t_c.
        let mut c = SimClock::new(8, LatencyModel::Constant(1.0), 0);
        let t = tree_all_reduce_time(&mut c);
        assert_eq!(t, 6.0);
        let mut c = SimClock::new(2, LatencyModel::Constant(1.0), 0);
        assert_eq!(tree_all_reduce_time(&mut c), 2.0);
    }

    #[test]
    fn ring_time_matches_2n_minus_2_hops() {
        let n = 6;
        let mut c = SimClock::new(n, LatencyModel::Constant(0.5), 0);
        let t = ring_all_reduce_time(&mut c);
        assert_eq!(t, 0.5 * 2.0 * (n as f64 - 1.0));
    }

    #[test]
    fn pair_time_is_one_exchange_for_constant_latency() {
        let mut c = SimClock::new(16, LatencyModel::Constant(0.7), 0);
        let t = pair_average_time(&mut c, None);
        assert!((t - 0.7).abs() < 1e-12);
    }

    #[test]
    fn pair_mean_matches_eq7_for_log_normal() {
        // E[pair completion] = E[max(t1,t2)] — Eq. 7.
        let m = LatencyModel::LogNormal { mu: 0.0, sigma: 0.8 };
        let analytic = m.expected_max2();
        let mut acc = 0.0;
        let reps = 4000;
        for seed in 0..reps {
            let mut c = SimClock::new(64, m.clone(), seed);
            acc += pair_average_time(&mut c, None);
        }
        let mc = acc / reps as f64;
        assert!(
            (mc - analytic).abs() / analytic < 0.02,
            "mc={mc} analytic={analytic}"
        );
    }

    #[test]
    fn tree_slows_with_latency_variance_pair_does_not() {
        // The qualitative Fig. 5A claim: raising sigma (holding the mean
        // E[t] fixed) hurts tree all-reduce much more than pair averaging.
        let ratio = |sigma: f64| {
            // Fix E[t] = 1 → mu = -sigma^2/2.
            let m = LatencyModel::LogNormal { mu: -sigma * sigma / 2.0, sigma };
            let reps = 600;
            let (mut tree, mut pair) = (0.0, 0.0);
            for seed in 0..reps {
                let mut c = SimClock::new(64, m.clone(), seed);
                tree += tree_all_reduce_time(&mut c);
                let mut c = SimClock::new(64, m.clone(), seed + 10_000);
                pair += pair_average_time(&mut c, None);
            }
            tree / pair
        };
        let low = ratio(0.1);
        let high = ratio(1.2);
        assert!(high > low * 1.5, "low={low} high={high}");
        // And even at low variance the tree pays ~2 log2(64) vs ~1.
        assert!(low > 6.0, "low={low}");
    }
}
