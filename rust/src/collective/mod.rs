//! Collective communication.
//!
//! Two faces of the same algorithms:
//!
//! * **Executable collectives** over a [`Fabric`](crate::net::Fabric)
//!   endpoint — used by the FSDP and DiLoCo baselines on the real training
//!   path (tree all-reduce of gradients / outer gradients) and by the
//!   NoLoCo gossip step (pair exchange).
//! * **Cost models** over a [`SimClock`](crate::net::SimClock) — virtual-
//!   time schedules of the same communication DAGs, used by the latency
//!   studies (Fig. 5A).
//!
//! Tree all-reduce follows the paper's §5.3 description: reduce up a
//! binary tree to rank 0, then broadcast back down, `2·log2(n)` sequential
//! message generations in total (Eq. 5).

pub mod cost;
mod exec;

pub use cost::{
    boundary_idle_times, pair_average_time, pair_average_time_bytes, ring_all_reduce_time,
    ring_all_reduce_time_bytes, streamed_pair_residual_bytes, streamed_tree_residual_bytes,
    tree_all_reduce_time, tree_all_reduce_time_bytes, tree_all_reduce_time_over,
};
pub use exec::{all_reduce_mean, broadcast, pair_exchange, reduce_scatter_gather};

/// Children of `rank` in a binary reduction tree over `0..n` (rank 0 root).
pub(crate) fn tree_children(rank: usize, n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let l = 2 * rank + 1;
    let r = 2 * rank + 2;
    if l < n {
        out.push(l);
    }
    if r < n {
        out.push(r);
    }
    out
}

/// Parent of `rank` in the binary tree (none for the root).
pub(crate) fn tree_parent(rank: usize) -> Option<usize> {
    if rank == 0 {
        None
    } else {
        Some((rank - 1) / 2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_shape_is_consistent() {
        // Every non-root has a parent that lists it as a child.
        for n in [1usize, 2, 3, 7, 8, 13] {
            for r in 1..n {
                let p = tree_parent(r).unwrap();
                assert!(tree_children(p, n).contains(&r), "n={n} r={r}");
            }
            // Root has no parent; every node has <= 2 children.
            assert!(tree_parent(0).is_none());
            for r in 0..n {
                assert!(tree_children(r, n).len() <= 2);
            }
        }
    }

    #[test]
    fn tree_depth_is_log2() {
        let depth = |mut r: usize| {
            let mut d = 0;
            while let Some(p) = tree_parent(r) {
                r = p;
                d += 1;
            }
            d
        };
        assert_eq!(depth(0), 0);
        assert_eq!(depth(1), 1);
        assert_eq!(depth(6), 2);
        assert_eq!(depth(62), 5);
    }
}
