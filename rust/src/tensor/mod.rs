//! Host-side flat tensors.
//!
//! The coordinator moves model state around as contiguous `f32` buffers:
//! collectives average them, the gossip outer step combines them, metrics
//! reduce them. This module is that substrate — a deliberately small,
//! allocation-conscious flat tensor plus the BLAS-1 style kernels the hot
//! paths need (axpy, scale, dot, reductions) and the statistics the
//! paper's figures report (cross-replica standard deviation, Pearson
//! correlation).
//!
//! Device-side math lives in XLA executables (see [`crate::runtime`]);
//! this type is the host staging and consensus-arithmetic representation.

mod stats;

pub use stats::{mean, pearson, replica_std, std_dev, OnlineStats};

/// A flat, contiguous `f32` buffer with a logical shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    data: Vec<f32>,
    shape: Vec<usize>,
}

impl Tensor {
    /// Zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor {
            data: vec![0.0; n],
            shape: shape.to_vec(),
        }
    }

    /// Tensor filled with a constant.
    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Tensor {
            data: vec![v; n],
            shape: shape.to_vec(),
        }
    }

    /// Wrap an existing buffer. Panics if the element count mismatches.
    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "shape/data mismatch"
        );
        Tensor {
            data,
            shape: shape.to_vec(),
        }
    }

    /// 1-D tensor from a slice.
    pub fn from_slice(xs: &[f32]) -> Self {
        Tensor {
            data: xs.to_vec(),
            shape: vec![xs.len()],
        }
    }

    /// Gaussian init with the given std (He/Xavier style scaling is done by
    /// callers who know fan-in).
    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::rngx::Pcg64) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal(0.0, std as f64) as f32).collect();
        Tensor {
            data,
            shape: shape.to_vec(),
        }
    }

    /// Logical shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Immutable view of the backing buffer.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Mutable view of the backing buffer.
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the backing buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret the shape (element count must match).
    pub fn reshape(&mut self, shape: &[usize]) {
        assert_eq!(self.data.len(), shape.iter().product::<usize>());
        self.shape = shape.to_vec();
    }

    // ---- BLAS-1 style kernels (hot in collectives / outer steps) ----

    /// `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.data.len(), other.data.len(), "axpy length mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// `self *= alpha`.
    pub fn scale(&mut self, alpha: f32) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    /// Elementwise in-place add.
    pub fn add_assign(&mut self, other: &Tensor) {
        self.axpy(1.0, other);
    }

    /// Elementwise in-place subtract.
    pub fn sub_assign(&mut self, other: &Tensor) {
        self.axpy(-1.0, other);
    }

    /// Dot product.
    pub fn dot(&self, other: &Tensor) -> f64 {
        assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| *a as f64 * *b as f64)
            .sum()
    }

    /// Squared L2 norm (f64 accumulation).
    pub fn norm_sq(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum()
    }

    /// L2 norm.
    pub fn norm(&self) -> f64 {
        self.norm_sq().sqrt()
    }

    /// Mean of all elements (f64 accumulation).
    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|x| *x as f64).sum::<f64>() / self.data.len() as f64
    }

    /// `out = (a + b) / 2` written into `a` — the pair-averaging primitive
    /// of the NoLoCo gossip step.
    pub fn average_with(&mut self, other: &Tensor) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a = 0.5 * (*a + *b);
        }
    }

    /// Linear interpolation toward `other`: `self = (1-t)*self + t*other`.
    pub fn lerp(&mut self, other: &Tensor, t: f32) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a = (1.0 - t) * *a + t * b;
        }
    }
}

/// Element-count-weighted flatten of a parameter list into one vector —
/// used when the gossip step ships a whole replica's parameters as a
/// single message.
pub fn flatten(params: &[Tensor]) -> Vec<f32> {
    let n: usize = params.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(n);
    for p in params {
        out.extend_from_slice(p.as_slice());
    }
    out
}

/// Inverse of [`flatten`], given the original shapes.
pub fn unflatten(flat: &[f32], shapes: &[Vec<usize>]) -> Vec<Tensor> {
    let mut out = Vec::with_capacity(shapes.len());
    let mut off = 0;
    for s in shapes {
        let n: usize = s.iter().product();
        out.push(Tensor::from_vec(flat[off..off + n].to_vec(), s));
        off += n;
    }
    assert_eq!(off, flat.len(), "unflatten length mismatch");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg64;

    #[test]
    fn zeros_full_from_vec() {
        let z = Tensor::zeros(&[2, 3]);
        assert_eq!(z.len(), 6);
        assert!(z.as_slice().iter().all(|&x| x == 0.0));
        let f = Tensor::full(&[4], 2.5);
        assert_eq!(f.as_slice(), &[2.5; 4]);
        let v = Tensor::from_vec(vec![1.0, 2.0], &[2, 1]);
        assert_eq!(v.shape(), &[2, 1]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_rejects_bad_shape() {
        Tensor::from_vec(vec![1.0; 3], &[2, 2]);
    }

    #[test]
    fn axpy_scale_dot() {
        let mut a = Tensor::from_slice(&[1.0, 2.0, 3.0]);
        let b = Tensor::from_slice(&[4.0, 5.0, 6.0]);
        a.axpy(2.0, &b);
        assert_eq!(a.as_slice(), &[9.0, 12.0, 15.0]);
        a.scale(0.5);
        assert_eq!(a.as_slice(), &[4.5, 6.0, 7.5]);
        assert!((a.dot(&b) - (4.5 * 4.0 + 6.0 * 5.0 + 7.5 * 6.0) as f64).abs() < 1e-9);
    }

    #[test]
    fn average_with_is_midpoint() {
        let mut a = Tensor::from_slice(&[0.0, 2.0]);
        let b = Tensor::from_slice(&[4.0, 2.0]);
        a.average_with(&b);
        assert_eq!(a.as_slice(), &[2.0, 2.0]);
    }

    #[test]
    fn lerp_endpoints() {
        let a0 = Tensor::from_slice(&[1.0, -1.0]);
        let b = Tensor::from_slice(&[3.0, 5.0]);
        let mut a = a0.clone();
        a.lerp(&b, 0.0);
        assert_eq!(a, a0);
        a.lerp(&b, 1.0);
        assert_eq!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn flatten_unflatten_roundtrip() {
        let mut rng = Pcg64::seed_from_u64(11);
        let params = vec![
            Tensor::randn(&[3, 4], 1.0, &mut rng),
            Tensor::randn(&[5], 1.0, &mut rng),
            Tensor::randn(&[2, 2, 2], 1.0, &mut rng),
        ];
        let shapes: Vec<Vec<usize>> = params.iter().map(|p| p.shape().to_vec()).collect();
        let flat = flatten(&params);
        assert_eq!(flat.len(), 12 + 5 + 8);
        let back = unflatten(&flat, &shapes);
        assert_eq!(back, params);
    }

    #[test]
    fn randn_moments() {
        let mut rng = Pcg64::seed_from_u64(12);
        let t = Tensor::randn(&[10_000], 0.02, &mut rng);
        assert!(t.mean().abs() < 0.001);
        let var = t.norm_sq() / t.len() as f64;
        assert!((var.sqrt() - 0.02).abs() < 0.002);
    }
}
