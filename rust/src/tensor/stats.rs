//! Statistics for the paper's figures.
//!
//! Fig. 3B tracks the standard deviation of model weights *across data
//! parallel replicas* (normalized by its max over the run) and reports the
//! Pearson correlation between that σ and the learning-rate schedule
//! (0.91–0.97 in the paper). These helpers compute exactly those
//! quantities, plus a Welford online accumulator used by the benches.

use super::Tensor;

/// Arithmetic mean of a slice (empty → 0).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation of a slice.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Pearson correlation coefficient of two equal-length series.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len(), "pearson length mismatch");
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        let dx = x - mx;
        let dy = y - my;
        cov += dx * dy;
        vx += dx * dx;
        vy += dy * dy;
    }
    if vx == 0.0 || vy == 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Mean elementwise standard deviation across a set of same-shaped replica
/// tensors — the paper's "standard deviation of the model weights across
/// the data parallel world size" (Fig. 3B, Fig. 4A).
///
/// For each coordinate we compute the std over replicas, then average over
/// coordinates; this matches treating the weight vector entries as samples
/// of the replica-divergence process.
pub fn replica_std(replicas: &[&Tensor]) -> f64 {
    assert!(!replicas.is_empty());
    let n = replicas[0].len();
    for r in replicas {
        assert_eq!(r.len(), n, "replica shape mismatch");
    }
    let k = replicas.len() as f64;
    if replicas.len() < 2 || n == 0 {
        return 0.0;
    }
    let mut acc = 0.0;
    for i in 0..n {
        let mut m = 0.0;
        for r in replicas {
            m += r.as_slice()[i] as f64;
        }
        m /= k;
        let mut v = 0.0;
        for r in replicas {
            let d = r.as_slice()[i] as f64 - m;
            v += d * d;
        }
        acc += (v / k).sqrt();
    }
    acc / n as f64
}

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    /// Fresh accumulator.
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold in one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let zs = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_uncorrelated_is_small() {
        let mut rng = crate::rngx::Pcg64::seed_from_u64(13);
        let xs: Vec<f64> = (0..5000).map(|_| rng.next_normal()).collect();
        let ys: Vec<f64> = (0..5000).map(|_| rng.next_normal()).collect();
        assert!(pearson(&xs, &ys).abs() < 0.05);
    }

    #[test]
    fn replica_std_zero_for_identical() {
        let t = Tensor::from_slice(&[1.0, 2.0, 3.0]);
        assert_eq!(replica_std(&[&t, &t, &t]), 0.0);
    }

    #[test]
    fn replica_std_matches_hand_computed() {
        let a = Tensor::from_slice(&[0.0, 0.0]);
        let b = Tensor::from_slice(&[2.0, 4.0]);
        // Coord 0: mean 1, std 1. Coord 1: mean 2, std 2. Mean = 1.5.
        assert!((replica_std(&[&a, &b]) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 2.0, 3.5, -1.0, 0.25, 8.0];
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.std_dev() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(o.min(), -1.0);
        assert_eq!(o.max(), 8.0);
        assert_eq!(o.count(), 6);
    }
}
