//! Structured observability: run journal, counter registry, live
//! metrics snapshots and the cost-model bench emitter.
//!
//! NoLoCo's claims are about *when* communication happens — overlap
//! behind the inner phase, bounded-staleness folds, no global blocking
//! collective — so the evidence has to be boundary-granular, not a
//! post-hoc sum. This module is that evidence layer:
//!
//! * [`journal`] — the versioned JSONL event schema ([`Event`]) plus a
//!   minimal flat-JSON reader ([`parse_line`]) for tests and tooling.
//! * [`ObsHub`] — the shared sink everything reports into. A disabled
//!   hub is a `None` behind a cheap clone: every `record`/`count` call
//!   is a no-op, so untraced runs pay one branch per event site. An
//!   enabled hub derives the counter registry and the per-boundary
//!   breakdown from the same event stream it journals — the journal is
//!   ground truth, the counters are a fold over it.
//! * Live metrics: with `--metrics-out <path>` the hub atomically
//!   rewrites a one-object JSON snapshot every boundary (current loss,
//!   weight-σ, wire totals, fold-age histogram) — the file-based seed
//!   of ROADMAP item 5's live endpoint.
//! * [`bench`] — deterministic expected-cost walks over the net-topology
//!   presets, serialized into `BENCH_baseline.json` and guarded by
//!   `scripts/bench_check.sh`.
//!
//! Wire attribution invariant: the trainers emit one [`Event::Boundary`]
//! per boundary passage carrying the *delta* of the communicator's wire
//! totals since the previous capture, plus one final [`Event::Drain`]
//! with the residual. Summing `bytes`/`msgs` over those events therefore
//! reproduces `TrainReport.comm.bytes_sent`/`msgs_sent` bit-for-bit —
//! at every trace level, since `boundary`/`drain` events are never
//! filtered out of an enabled journal.

pub mod bench;
pub mod journal;

pub use journal::{parse_line, required_keys, Event, JsonVal, SCHEMA_VERSION};

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{ObsConfig, TraceLevel};

/// One per-boundary idle/overlap row, derived from [`Event::Boundary`].
/// On the threaded executor each worker contributes its own rows, so a
/// boundary index appears once per worker that passed it.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BoundaryRow {
    /// Outer boundary index (1-based).
    pub outer_idx: u64,
    /// Seconds spent in the inner phase leading up to this boundary.
    pub inner_s: f64,
    /// Seconds spent in boundary synchronization (offer + fold +
    /// bookkeeping) — the part overlap is supposed to hide.
    pub sync_s: f64,
    /// Wire bytes attributed to this boundary passage.
    pub bytes: u64,
    /// Wire messages attributed to this boundary passage.
    pub msgs: u64,
}

/// Post-hoc summary of the hub's view of a run, carried on
/// `TrainReport.obs`. Default (all empty) when observability was off.
#[derive(Clone, Debug, Default)]
pub struct ObsReport {
    /// Journal path, when `--trace-out` wrote one.
    pub journal_path: Option<String>,
    /// Counter registry contents, sorted by key.
    pub counters: Vec<(String, u64)>,
    /// Fold-admission age histogram: `fold_age_hist[a]` counts folds
    /// that admitted an offer `a` boundaries old.
    pub fold_age_hist: Vec<u64>,
    /// Per-boundary breakdown rows in emission order.
    pub boundaries: Vec<BoundaryRow>,
}

impl ObsReport {
    /// Look up a counter by key (0 when absent).
    pub fn counter(&self, key: &str) -> u64 {
        self.counters.iter().find(|(k, _)| k == key).map(|(_, v)| *v).unwrap_or(0)
    }

    /// Sum of `bytes` over all boundary rows (the drained residual is
    /// *not* included — see the module docs for the full invariant).
    pub fn boundary_bytes(&self) -> u64 {
        self.boundaries.iter().map(|r| r.bytes).sum()
    }
}

struct ObsInner {
    level: TraceLevel,
    start: Instant,
    writer: Option<BufWriter<File>>,
    journal_path: Option<String>,
    metrics_path: Option<String>,
    events: Vec<Event>,
    counters: BTreeMap<String, u64>,
    fold_age_hist: Vec<u64>,
    boundaries: Vec<BoundaryRow>,
}

impl ObsInner {
    fn bump(&mut self, key: &str, n: u64) {
        *self.counters.entry(key.to_string()).or_insert(0) += n;
    }

    fn absorb(&mut self, sim: u64, ev: Event) {
        // Counters and derived tables always fold, at every level.
        match &ev {
            Event::InnerPhase { .. } => self.bump("inner_phases", 1),
            Event::Offer { .. } => self.bump("offers", 1),
            Event::Fold { age, .. } => {
                self.bump("folds", 1);
                let a = *age as usize;
                if self.fold_age_hist.len() <= a {
                    self.fold_age_hist.resize(a + 1, 0);
                }
                self.fold_age_hist[a] += 1;
            }
            Event::HeartbeatMiss { .. } => self.bump("heartbeat_misses", 1),
            Event::Detect { .. } => self.bump("detections", 1),
            Event::ChurnApplied { .. } => self.bump("churn_applied", 1),
            Event::StashSwept { dropped, .. } => self.bump("stash_swept", *dropped),
            Event::Boundary { outer_idx, inner_s, sync_s, bytes, msgs } => {
                self.bump("boundaries", 1);
                self.boundaries.push(BoundaryRow {
                    outer_idx: *outer_idx,
                    inner_s: *inner_s,
                    sync_s: *sync_s,
                    bytes: *bytes,
                    msgs: *msgs,
                });
            }
            Event::Drain { .. } => self.bump("drains", 1),
            Event::Ckpt { .. } => self.bump("ckpts", 1),
            Event::Resume { .. } => self.bump("resumes", 1),
            Event::Analyze { .. } => self.bump("analyzes", 1),
            Event::NetPeer { .. } => self.bump("net_peers", 1),
        }
        // The journal (and its in-memory mirror) honors the trace level.
        let admit = match self.level {
            TraceLevel::Off => false,
            TraceLevel::Boundary => !matches!(ev, Event::InnerPhase { .. }),
            TraceLevel::Step => true,
        };
        if !admit {
            return;
        }
        if let Some(w) = self.writer.as_mut() {
            // A full disk must not kill a training run mid-boundary.
            let _ = writeln!(w, "{}", ev.to_json(self.start.elapsed().as_secs_f64(), sim));
        }
        self.events.push(ev);
    }
}

/// Poison-proof lock: a worker thread that panicked while holding the
/// hub must not take the whole run's observability down with it.
fn locked(m: &Mutex<ObsInner>) -> std::sync::MutexGuard<'_, ObsInner> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// The shared observability sink. Cheap to clone (an `Option<Arc>`);
/// a disabled hub makes every reporting call a no-op branch, so event
/// sites need no `if traced` guards of their own.
#[derive(Clone)]
pub struct ObsHub {
    inner: Option<Arc<Mutex<ObsInner>>>,
}

impl ObsHub {
    /// The no-op hub: records nothing, costs one branch per call.
    pub fn disabled() -> ObsHub {
        ObsHub { inner: None }
    }

    /// Build from config: disabled unless a trace or metrics sink is
    /// set. Fails only if the journal file cannot be created.
    pub fn from_config(cfg: &ObsConfig) -> Result<ObsHub> {
        if !cfg.enabled() {
            return Ok(ObsHub::disabled());
        }
        let writer = match &cfg.trace_out {
            Some(p) => Some(BufWriter::new(
                File::create(p).with_context(|| format!("creating trace journal {p}"))?,
            )),
            None => None,
        };
        let hub =
            ObsHub::build(cfg.trace_level, writer, cfg.trace_out.clone(), cfg.metrics_out.clone());
        // A journaled run self-describes whether its producer passed the
        // static determinism pass (`noloco analyze`, rules R1–R5). The
        // hub is built once per run, so the verdict lands exactly once,
        // as the first journal line. Skipped when the source tree is not
        // reachable (installed binary outside the repo).
        if cfg.trace_out.is_some() {
            if let Some((findings, clean)) = crate::analyze::self_verdict() {
                hub.record(
                    0,
                    Event::Analyze { version: u64::from(crate::analyze::VERSION), findings, clean },
                );
            }
        }
        Ok(hub)
    }

    /// An enabled hub with no file sinks — events and counters
    /// accumulate in memory only (tests, `obs-smoke`).
    pub fn in_memory(level: TraceLevel) -> ObsHub {
        ObsHub::build(level, None, None, None)
    }

    fn build(
        level: TraceLevel,
        writer: Option<BufWriter<File>>,
        journal_path: Option<String>,
        metrics_path: Option<String>,
    ) -> ObsHub {
        ObsHub {
            inner: Some(Arc::new(Mutex::new(ObsInner {
                level,
                start: Instant::now(),
                writer,
                journal_path,
                metrics_path,
                events: Vec::new(),
                counters: BTreeMap::new(),
                fold_age_hist: Vec::new(),
                boundaries: Vec::new(),
            }))),
        }
    }

    /// Whether this hub records anything at all.
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Record one event: fold it into the counter registry and (level
    /// permitting) append it to the journal. `sim` is the sim-clock
    /// stamp — the global inner-step index at emission.
    pub fn record(&self, sim: u64, ev: Event) {
        let Some(inner) = &self.inner else { return };
        locked(inner).absorb(sim, ev);
    }

    /// Add `n` to a named counter (strategy/communicator totals that
    /// have no per-event form).
    pub fn count(&self, key: &str, n: u64) {
        let Some(inner) = &self.inner else { return };
        locked(inner).bump(key, n);
    }

    /// Current value of a counter (0 when absent or disabled).
    pub fn counter(&self, key: &str) -> u64 {
        match &self.inner {
            Some(inner) => locked(inner).counters.get(key).copied().unwrap_or(0),
            None => 0,
        }
    }

    /// Snapshot of the recorded (level-admitted) events.
    pub fn events(&self) -> Vec<Event> {
        match &self.inner {
            Some(inner) => locked(inner).events.clone(),
            None => Vec::new(),
        }
    }

    /// Seconds since the hub was created (0 when disabled).
    pub fn wall(&self) -> f64 {
        match &self.inner {
            Some(inner) => locked(inner).start.elapsed().as_secs_f64(),
            None => 0.0,
        }
    }

    /// Atomically rewrite the live metrics snapshot (`--metrics-out`):
    /// write to `<path>.tmp`, then rename over the target so readers
    /// never observe a torn file. No-op without a metrics sink.
    pub fn snapshot_metrics(
        &self,
        step: u64,
        boundary: u64,
        loss: f64,
        sigma: f64,
        bytes: u64,
        msgs: u64,
    ) {
        let Some(inner) = &self.inner else { return };
        let g = locked(inner);
        let Some(path) = g.metrics_path.clone() else { return };
        let mut s = String::with_capacity(256);
        let _ = write!(
            s,
            "{{\"v\":{SCHEMA_VERSION},\"wall\":{:.6},\"step\":{step},\"boundary\":{boundary}",
            g.start.elapsed().as_secs_f64()
        );
        journal::push_f64(&mut s, "loss", loss);
        journal::push_f64(&mut s, "sigma", sigma);
        journal::push_u64(&mut s, "bytes", bytes);
        journal::push_u64(&mut s, "msgs", msgs);
        s.push_str(",\"fold_age_hist\":[");
        for (i, n) in g.fold_age_hist.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "{n}");
        }
        s.push_str("]}");
        drop(g);
        let tmp = format!("{path}.tmp");
        if fs::write(&tmp, s.as_bytes()).is_ok() {
            let _ = fs::rename(&tmp, &path);
        }
    }

    /// Flush the journal and summarize the registry into an
    /// [`ObsReport`]. Safe to call more than once.
    pub fn report(&self) -> ObsReport {
        let Some(inner) = &self.inner else { return ObsReport::default() };
        let mut g = locked(inner);
        if let Some(w) = g.writer.as_mut() {
            let _ = w.flush();
        }
        ObsReport {
            journal_path: g.journal_path.clone(),
            counters: g.counters.iter().map(|(k, v)| (k.clone(), *v)).collect(),
            fold_age_hist: g.fold_age_hist.clone(),
            boundaries: g.boundaries.clone(),
        }
    }
}

impl std::fmt::Debug for ObsHub {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ObsHub").field("enabled", &self.is_enabled()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_hub_is_inert() {
        let hub = ObsHub::disabled();
        assert!(!hub.is_enabled());
        hub.record(0, Event::Drain { outer_idx: 1, bytes: 1, msgs: 1 });
        hub.count("x", 5);
        assert_eq!(hub.counter("x"), 0);
        assert!(hub.events().is_empty());
        let rep = hub.report();
        assert!(rep.counters.is_empty() && rep.journal_path.is_none());
    }

    #[test]
    fn counters_derive_from_events() {
        let hub = ObsHub::in_memory(TraceLevel::Step);
        hub.record(1, Event::Offer { stage: 0, replica: 0, peer: 1, round: 1, frag: 0, bytes: 64 });
        hub.record(
            2,
            Event::Fold { stage: 0, replica: 0, peer: 1, round: 1, frag: 0, age: 2, bytes: 64 },
        );
        hub.record(2, Event::StashSwept { boundary: 2, dropped: 3 });
        hub.record(
            2,
            Event::Boundary { outer_idx: 2, inner_s: 0.5, sync_s: 0.1, bytes: 128, msgs: 2 },
        );
        assert_eq!(hub.counter("offers"), 1);
        assert_eq!(hub.counter("folds"), 1);
        assert_eq!(hub.counter("stash_swept"), 3);
        let rep = hub.report();
        assert_eq!(rep.fold_age_hist, vec![0, 0, 1]);
        assert_eq!(rep.boundaries.len(), 1);
        assert_eq!(rep.boundary_bytes(), 128);
        assert_eq!(rep.counter("boundaries"), 1);
    }

    #[test]
    fn boundary_level_drops_inner_from_journal_but_not_counters() {
        let hub = ObsHub::in_memory(TraceLevel::Boundary);
        hub.record(
            1,
            Event::InnerPhase { stage: 0, replica: 0, step: 1, loss: 2.0, dur_s: 0.1 },
        );
        hub.record(1, Event::Drain { outer_idx: 1, bytes: 0, msgs: 0 });
        assert_eq!(hub.counter("inner_phases"), 1);
        let evs = hub.events();
        assert_eq!(evs.len(), 1);
        assert!(matches!(evs[0], Event::Drain { .. }));
    }

    #[test]
    fn off_level_keeps_counters_only() {
        let hub = ObsHub::in_memory(TraceLevel::Off);
        hub.record(1, Event::Drain { outer_idx: 1, bytes: 9, msgs: 1 });
        assert_eq!(hub.counter("drains"), 1);
        assert!(hub.events().is_empty());
    }

    #[test]
    fn journal_and_metrics_files_roundtrip() {
        let dir = std::env::temp_dir().join(format!("noloco_obs_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let trace = dir.join("run.jsonl");
        let metrics = dir.join("metrics.json");
        let cfg = ObsConfig {
            trace_out: Some(trace.to_string_lossy().into_owned()),
            metrics_out: Some(metrics.to_string_lossy().into_owned()),
            trace_level: TraceLevel::Step,
        };
        let hub = ObsHub::from_config(&cfg).unwrap();
        assert!(hub.is_enabled());
        hub.record(
            3,
            Event::Boundary { outer_idx: 1, inner_s: 0.5, sync_s: 0.25, bytes: 256, msgs: 4 },
        );
        hub.snapshot_metrics(3, 1, 2.75, f64::NAN, 256, 4);
        let rep = hub.report();
        assert_eq!(rep.journal_path.as_deref(), Some(trace.to_str().unwrap()));

        let text = std::fs::read_to_string(&trace).unwrap();
        let lines: Vec<_> = text.lines().collect();
        // Hub construction journals the static-analysis verdict first;
        // the recorded boundary event follows.
        assert_eq!(lines.len(), 2, "{text}");
        let a = parse_line(lines[0]).unwrap();
        assert_eq!(a["ev"].str_val(), Some("analyze"));
        assert_eq!(a["version"].uint(), Some(u64::from(crate::analyze::VERSION)));
        assert_eq!(a["clean"].boolean(), Some(true), "committed tree must analyze clean");
        let m = parse_line(lines[1]).unwrap();
        assert_eq!(m["ev"].str_val(), Some("boundary"));
        assert_eq!(m["bytes"].uint(), Some(256));

        let snap = std::fs::read_to_string(&metrics).unwrap();
        assert!(snap.contains("\"sigma\":null"), "{snap}");
        assert!(snap.contains("\"bytes\":256"), "{snap}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
