//! Deterministic cost-model baselines (`BENCH_baseline.json`).
//!
//! The CLI's `bench-topo` / `bench-outer-step` studies walk the
//! collective cost models against a *sampling*
//! [`SimClock`](crate::net::SimClock) — great for distributions, useless
//! as a regression gate. This module redoes the same walks in **expected
//! time**: every message costs [`Topology::expected_transfer`] (analytic
//! `E[latency] + bytes/bandwidth`), so each metric is a pure function of
//! the topology presets — no RNG, no wall clock, identical on every
//! machine. `scripts/bench_check.sh` recomputes them (or mirrors the
//! arithmetic in Python when no Rust toolchain is around) and fails on a
//! >10% drift from the checked-in `BENCH_baseline.json`.
//!
//! Fixed scenario: `N = 24` workers, 8 MiB of outer state, the
//! lan / wan / hier presets at their config defaults, adjacent gossip
//! pairs `(0,1) … (22,23)`, and a deterministic staggered compute vector
//! `0.25 + 0.05·(w mod 7)` for the idle-time model.
//!
//! A second family (`BENCH_steps.json`, [`steps_json`]) is the **scale
//! ladder**: the same analytic discipline applied to the O(1000)-replica
//! throughput trajectory. For `dp ∈ {64, 256, 1000}` it emits
//! `steps_per_sec` (fleet replica-steps per second under the modeled
//! per-step compute plus the amortized NoLoCo gossip boundary — linear
//! in `dp` because the pair exchange is O(1) in world size, the paper's
//! headline), `bytes_per_boundary` (total wire bytes of one outer
//! boundary, exactly what [`crate::train::AccountingComm`] meters for a
//! full pairing round — pinned by test), and `peak_rss_mib` (modeled
//! grid-executor residency: six per-replica state vectors plus the
//! shared fold scratch). `noloco perf` writes the file;
//! `scripts/bench_check.sh` gates both families.

use std::fmt::Write as _;

use crate::collective::{boundary_idle_times, tree_children, tree_parent};
use crate::config::{NetPreset, NetTopoConfig};
use crate::net::topo::Topology;

/// Worker count for every baseline metric.
pub const BENCH_WORLD: usize = 24;
/// Per-worker outer-state payload for the preset family (8 MiB).
pub const BENCH_BYTES: u64 = 8 * 1024 * 1024;
/// Payload for the `outer.*` family (the Fig. 5 outer-step scale).
pub const OUTER_BYTES: u64 = 8_000_000;
/// Fragment count for the streaming-overlap residual.
pub const BENCH_FRAGMENTS: u64 = 4;
/// Inner-phase seconds available to hide one streamed fragment behind.
pub const STREAM_COMPUTE_S: f64 = 0.5;
/// Modeled kernel-loopback hop latency for the socket transport walk.
pub const LOOPBACK_LATENCY_S: f64 = 50e-6;
/// Modeled loopback throughput (bytes/s) for the socket transport walk.
pub const LOOPBACK_BANDWIDTH: f64 = 12.5e9;
/// Per-frame wire overhead of the socket codec: u32 length + u32 CRC.
pub const FRAME_HEADER_BYTES: u64 = 8;

fn preset_topo(preset: NetPreset) -> Topology {
    // Config defaults; seed is only consumed by the long-tail preset's
    // straggler draws, which the baseline deliberately excludes.
    NetTopoConfig { preset, ..NetTopoConfig::default() }.build(BENCH_WORLD, 0)
}

fn adjacent_pairs() -> Vec<(usize, usize)> {
    (0..BENCH_WORLD / 2).map(|i| (2 * i, 2 * i + 1)).collect()
}

/// Mean expected pair-exchange time over the adjacent pairs.
fn pair_mean(topo: &Topology, bytes: u64) -> f64 {
    let pairs = adjacent_pairs();
    pairs.iter().map(|&(a, b)| topo.expected_transfer(a, b, bytes)).sum::<f64>()
        / pairs.len() as f64
}

/// Expected-time walk of the §5.3 binary-tree all-reduce: reduce to
/// rank 0, broadcast back down, every edge at its expected transfer.
fn tree_allreduce_expected(topo: &Topology, bytes: u64) -> f64 {
    let n = BENCH_WORLD;
    let mut ready = vec![0.0f64; n];
    // Reduce upward: children (2r+1, 2r+2) have higher ranks, so a
    // reverse sweep finalizes every child before its parent folds it.
    for r in (0..n).rev() {
        for c in tree_children(r, n) {
            let arrive = ready[c] + topo.expected_transfer(c, r, bytes);
            if arrive > ready[r] {
                ready[r] = arrive;
            }
        }
    }
    // Broadcast downward: parents have lower ranks.
    for r in 0..n {
        if let Some(p) = tree_parent(r) {
            let arrive = ready[p] + topo.expected_transfer(p, r, bytes);
            if arrive > ready[r] {
                ready[r] = arrive;
            }
        }
    }
    ready.iter().fold(0.0, |a, &b| a.max(b))
}

/// Expected-time walk of a ring all-reduce: `2(n−1)` generations of
/// chunked neighbor sends, every worker sending simultaneously from a
/// snapshot of the previous generation.
fn ring_allreduce_expected(topo: &Topology, bytes: u64) -> f64 {
    let n = BENCH_WORLD;
    let chunk = bytes.div_ceil(n as u64);
    let mut ready = vec![0.0f64; n];
    for _gen in 0..2 * (n - 1) {
        let start = ready.clone();
        for r in 0..n {
            let to = (r + 1) % n;
            let arrive = start[r] + topo.expected_transfer(r, to, chunk);
            ready[to] = start[to].max(arrive);
        }
    }
    ready.iter().fold(0.0, |a, &b| a.max(b))
}

/// Streaming-overlap residual: the payload splits into
/// [`BENCH_FRAGMENTS`] chunks, each pair exchange hides behind
/// [`STREAM_COMPUTE_S`] of inner compute; what still pokes out (summed
/// over fragments, averaged over pairs) is the visible boundary cost.
fn streamed_residual(topo: &Topology, bytes: u64) -> f64 {
    let chunk = bytes.div_ceil(BENCH_FRAGMENTS);
    let pairs = adjacent_pairs();
    let mut acc = 0.0;
    for &(a, b) in &pairs {
        let t = topo.expected_transfer(a, b, chunk);
        acc += (t - STREAM_COMPUTE_S).max(0.0) * BENCH_FRAGMENTS as f64;
    }
    acc / pairs.len() as f64
}

/// Socket-loopback walk: one symmetric gossip pair exchange of
/// [`OUTER_BYTES`] over 127.0.0.1, each direction one CRC-framed message
/// ([`FRAME_HEADER_BYTES`] of header) across the modeled loopback hop.
/// Pure arithmetic — the regression gate for the 2-process smoke shape.
fn socket_loopback_pair() -> f64 {
    let framed = (OUTER_BYTES + FRAME_HEADER_BYTES) as f64;
    2.0 * (LOOPBACK_LATENCY_S + framed / LOOPBACK_BANDWIDTH)
}

/// The full baseline: `(metric name, seconds-or-ratio)` rows in emission
/// order. Deterministic — two calls return identical values.
pub fn cost_model_baseline() -> Vec<(String, f64)> {
    let presets = [
        ("lan", NetPreset::SingleSwitchLan),
        ("wan", NetPreset::MultiRegionWan),
        ("hier", NetPreset::HierarchicalDc),
    ];
    let pairs = adjacent_pairs();
    let computes: Vec<f64> = (0..BENCH_WORLD).map(|w| 0.25 + 0.05 * (w % 7) as f64).collect();
    let mut out = Vec::new();
    for (name, preset) in presets {
        let topo = preset_topo(preset);
        out.push((format!("{name}.pair_mean_s"), pair_mean(&topo, BENCH_BYTES)));
        out.push((format!("{name}.tree_allreduce_s"), tree_allreduce_expected(&topo, BENCH_BYTES)));
        out.push((format!("{name}.ring_allreduce_s"), ring_allreduce_expected(&topo, BENCH_BYTES)));
        out.push((format!("{name}.streamed_residual_s"), streamed_residual(&topo, BENCH_BYTES)));
        let (lock, asy) = boundary_idle_times(&topo, &pairs, &computes, BENCH_BYTES);
        out.push((format!("{name}.lockstep_idle_s"), lock));
        out.push((format!("{name}.async_idle_s"), asy));
    }
    // Outer-step family (Fig. 5's comparison) on the WAN preset: one
    // NoLoCo gossip pair vs the DiLoCo blocking tree all-reduce.
    let wan = preset_topo(NetPreset::MultiRegionWan);
    let pair = pair_mean(&wan, OUTER_BYTES);
    let tree = tree_allreduce_expected(&wan, OUTER_BYTES);
    out.push(("outer.noloco_pair_s".to_string(), pair));
    out.push(("outer.diloco_tree_s".to_string(), tree));
    out.push(("outer.speedup".to_string(), tree / pair));
    // Socket transport on localhost (the CI loopback smoke shape).
    out.push(("socket.loopback_pair_s".to_string(), socket_loopback_pair()));
    out
}

/// Serialize metric rows into the baseline-file shape:
/// `{"v":1,"metrics":{"<name>":<value>,…}}` (floats in Rust's shortest
/// round-trip form, newline-terminated).
fn metrics_json(rows: &[(String, f64)]) -> String {
    let mut s = String::from("{\"v\":1,\"metrics\":{");
    for (i, (k, v)) in rows.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "\"{k}\":{v}");
    }
    s.push_str("}}\n");
    s
}

/// Serialize [`cost_model_baseline`] into the `BENCH_baseline.json` shape.
pub fn baseline_json() -> String {
    metrics_json(&cost_model_baseline())
}

// ---------------------------------------------------------------------------
// Scale ladder (`BENCH_steps.json`) — the O(1000)-replica throughput
// trajectory. Pure closed forms so the Python mirror in
// `scripts/bench_check.sh` can recompute them without a Rust toolchain;
// the bytes row is additionally pinned against the real
// `AccountingComm` meter by a unit test below.
// ---------------------------------------------------------------------------

/// Replica counts of the scale ladder.
pub const STEPS_LADDER: [u64; 3] = [64, 256, 1000];
/// Outer-state floats per replica (θ/φ/Δ scale): 2 Mi floats = 8 MiB,
/// the same payload the preset family uses.
pub const STEPS_PARAMS: u64 = 2 * 1024 * 1024;
/// Inner steps between outer boundaries (H) for the amortization.
pub const STEPS_INNER: u64 = 50;
/// Modeled fwd+bwd+Adam seconds per inner step for the 2 Mi-float host
/// model.
pub const STEPS_COMPUTE_S: f64 = 0.02;
/// Gossip link latency for the ladder (the LAN intra-switch figure).
pub const STEPS_LINK_LATENCY_S: f64 = 1e-3;
/// Gossip link bandwidth for the ladder (bytes/s).
pub const STEPS_LINK_BANDWIDTH: f64 = 1.25e9;

/// One symmetric NoLoCo pair exchange: each side ships (Δ, φ) =
/// `2·STEPS_PARAMS` floats over the ladder link; directions overlap
/// (full duplex), so the boundary stall is one send. Independent of
/// `dp` — the property the ladder exists to demonstrate.
fn steps_pair_s() -> f64 {
    STEPS_LINK_LATENCY_S + (8 * STEPS_PARAMS) as f64 / STEPS_LINK_BANDWIDTH
}

/// Fleet replica-steps per second at world size `dp`: every replica
/// advances at `1 / (compute + pair/H)`, and NoLoCo has no global
/// collective, so the fleet rate is exactly `dp` times the replica
/// rate.
fn steps_per_sec(dp: u64) -> f64 {
    dp as f64 / (STEPS_COMPUTE_S + steps_pair_s() / STEPS_INNER as f64)
}

/// Total wire bytes of one outer boundary at world size `dp`: every
/// replica offers (Δ, φ) — `2·STEPS_PARAMS` floats, 4 bytes each — to
/// its one partner, which is precisely what `AccountingComm`'s
/// `offer_state` meters for a full pairing round (`dp · 2 · 4 · n`).
fn bytes_per_boundary(dp: u64) -> f64 {
    (dp * 2 * 4 * STEPS_PARAMS) as f64
}

/// Modeled grid-executor peak residency at world size `dp`, MiB: six
/// per-replica f32 vectors (θ, m, v, φ, δ, grad accumulator) plus the
/// two shared [`crate::train::FoldScratch`] buffers (dsum, psum).
fn peak_rss_mib(dp: u64) -> f64 {
    ((6 * dp + 2) * 4 * STEPS_PARAMS) as f64 / (1024.0 * 1024.0)
}

/// The scale ladder: `steps.dp<dp>.{steps_per_sec, bytes_per_boundary,
/// peak_rss_mib}` rows for each rung, in emission order. Deterministic
/// — two calls return identical values.
pub fn steps_ladder() -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for dp in STEPS_LADDER {
        out.push((format!("steps.dp{dp}.steps_per_sec"), steps_per_sec(dp)));
        out.push((format!("steps.dp{dp}.bytes_per_boundary"), bytes_per_boundary(dp)));
        out.push((format!("steps.dp{dp}.peak_rss_mib"), peak_rss_mib(dp)));
    }
    out
}

/// Serialize [`steps_ladder`] into the `BENCH_steps.json` shape.
pub fn steps_json() -> String {
    metrics_json(&steps_ladder())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_is_deterministic() {
        assert_eq!(cost_model_baseline(), cost_model_baseline());
        assert_eq!(baseline_json(), baseline_json());
    }

    fn metric(name: &str) -> f64 {
        cost_model_baseline()
            .into_iter()
            .find(|(k, _)| k == name)
            .unwrap_or_else(|| panic!("missing metric {name}"))
            .1
    }

    #[test]
    fn lan_is_faster_than_wan_everywhere() {
        for m in ["pair_mean_s", "tree_allreduce_s", "ring_allreduce_s"] {
            assert!(
                metric(&format!("lan.{m}")) < metric(&format!("wan.{m}")),
                "lan should beat wan on {m}"
            );
        }
    }

    #[test]
    fn async_idle_never_exceeds_lockstep_idle() {
        for p in ["lan", "wan", "hier"] {
            let lock = metric(&format!("{p}.lockstep_idle_s"));
            let asy = metric(&format!("{p}.async_idle_s"));
            assert!(asy <= lock + 1e-12, "{p}: async {asy} > lockstep {lock}");
        }
    }

    #[test]
    fn outer_speedup_favors_gossip_on_wan() {
        // A 24-worker blocking tree crossing WAN links must cost more
        // than one adjacent (intra-region) gossip pair.
        assert!(metric("outer.speedup") > 1.0);
        let ratio = metric("outer.diloco_tree_s") / metric("outer.noloco_pair_s");
        assert!((metric("outer.speedup") - ratio).abs() < 1e-12);
    }

    #[test]
    fn lan_pair_mean_matches_closed_form() {
        // Single switch, constant 1 ms at 1.25 GB/s: E = 1e-3 + B/1.25e9.
        let expect = 1e-3 + BENCH_BYTES as f64 / 1.25e9;
        assert!((metric("lan.pair_mean_s") - expect).abs() < 1e-12);
    }

    #[test]
    fn socket_loopback_matches_closed_form() {
        // 2 * (50 us + (8_000_000 + 8) / 12.5 GB/s), exactly.
        let expect = 2.0 * (50e-6 + 8_000_008.0 / 12.5e9);
        assert!((metric("socket.loopback_pair_s") - expect).abs() < 1e-15);
        // Sanity: the loopback pair is far below even the LAN pair.
        assert!(metric("socket.loopback_pair_s") < metric("lan.pair_mean_s"));
    }

    #[test]
    fn json_shape_has_version_and_all_metrics() {
        let s = baseline_json();
        assert!(s.starts_with("{\"v\":1,\"metrics\":{"));
        for (k, _) in cost_model_baseline() {
            assert!(s.contains(&format!("\"{k}\":")), "missing {k} in {s}");
        }
    }

    fn step_metric(name: &str) -> f64 {
        steps_ladder()
            .into_iter()
            .find(|(k, _)| k == name)
            .unwrap_or_else(|| panic!("missing ladder metric {name}"))
            .1
    }

    #[test]
    fn steps_ladder_is_deterministic_and_complete() {
        assert_eq!(steps_ladder(), steps_ladder());
        let s = steps_json();
        assert!(s.starts_with("{\"v\":1,\"metrics\":{"));
        for dp in STEPS_LADDER {
            for m in ["steps_per_sec", "bytes_per_boundary", "peak_rss_mib"] {
                assert!(s.contains(&format!("\"steps.dp{dp}.{m}\":")), "missing dp{dp}.{m} in {s}");
            }
        }
    }

    #[test]
    fn steps_per_sec_is_linear_in_world_size() {
        // No collective ⇒ the fleet rate scales exactly with dp: the
        // per-replica denominator is the same on every rung.
        let per_replica_64 = step_metric("steps.dp64.steps_per_sec") / 64.0;
        let per_replica_1000 = step_metric("steps.dp1000.steps_per_sec") / 1000.0;
        assert!((per_replica_64 - per_replica_1000).abs() < 1e-9);
        // Closed form: dp / (compute + (lat + 8n/bw) / H).
        let pair = 1e-3 + (8.0 * 2_097_152.0) / 1.25e9;
        let expect = 64.0 / (0.02 + pair / 50.0);
        assert!((step_metric("steps.dp64.steps_per_sec") - expect).abs() < 1e-9);
    }

    #[test]
    fn ladder_bytes_match_accounting_comm_meter() {
        // Drive a real pairing round through the accounting communicator
        // at a small fragment size and scale up: the analytic row must
        // be exactly what the meter would charge at full payload.
        use crate::train::{AccountingComm, Communicator};
        let dp = 64usize;
        let frag = 1024usize; // STEPS_PARAMS / frag is exact (both powers of two)
        let delta = vec![0.0f32; frag];
        let phi = vec![0.0f32; frag];
        let mut comm = AccountingComm::new();
        for r in 0..dp {
            let partner = r ^ 1; // adjacent symmetric pairs
            comm.offer_state(0, r, &[partner], 1, &delta, &phi).expect("offer");
        }
        let scale = STEPS_PARAMS / frag as u64;
        let metered = comm.stats().bytes_sent * scale;
        assert_eq!(metered as f64, step_metric("steps.dp64.bytes_per_boundary"));
        // And the symmetric exchange is counted once per pair.
        assert_eq!(comm.stats().pair_exchanges, dp as u64 / 2);
    }

    #[test]
    fn peak_rss_matches_closed_form_and_grows_linearly() {
        // (6·dp + 2) resident 8 MiB vectors.
        assert!((step_metric("steps.dp64.peak_rss_mib") - 386.0 * 8.0).abs() < 1e-9);
        assert!((step_metric("steps.dp1000.peak_rss_mib") - 6002.0 * 8.0).abs() < 1e-9);
    }
}
