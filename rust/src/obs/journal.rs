//! The event journal: a versioned JSONL schema for boundary-level runs.
//!
//! One [`Event`] per state transition the training stack cares about —
//! inner phases, offer/fold traffic, heartbeat misses and detections,
//! churn, stash sweeps, per-boundary wire deltas, and the final drain.
//! Every line carries the schema version (`"v"`), a wall-clock stamp in
//! seconds since the hub was created (`"wall"`), a sim-clock stamp
//! (`"sim"`, the global inner-step index at emission) and the event name
//! (`"ev"`); the remaining keys are flat event-specific fields.
//!
//! The encoding is hand-rolled flat JSON — one object per line, no
//! nesting, no string escapes (all strings in the schema are bare
//! identifiers). [`parse_line`] is the matching minimal reader, enough
//! for the invariant tests and `scripts/check_trace_schema.sh` to
//! round-trip a journal without a JSON dependency. JSON has no NaN, so
//! non-finite floats encode as `null` and parse back as NaN.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Journal schema version, written as `"v"` on every line. Bump when an
/// event gains/loses fields or changes meaning; readers should reject
/// versions they do not know.
pub const SCHEMA_VERSION: u32 = 1;

/// One journal entry. Integer ranks (`stage`, `replica`, `peer`, `node`)
/// index the DP × PP grid; `round`/`boundary`/`outer_idx` count outer
/// boundaries (1-based, matching the trainers); `frag` is a fragment
/// index under `outer.fragments`.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// One inner optimization step on one worker: step index, training
    /// loss (NaN when the replica sat out) and phase duration.
    InnerPhase { stage: usize, replica: usize, step: u64, loss: f64, dur_s: f64 },
    /// Outer state offered to a peer: `(round, frag)` identifies the
    /// offer, `bytes` its wire payload size.
    Offer { stage: usize, replica: usize, peer: usize, round: u64, frag: u16, bytes: u64 },
    /// A peer offer folded into the local outer step. `age` = current
    /// boundary minus the offer's round (0 = fresh).
    Fold { stage: usize, replica: usize, peer: usize, round: u64, frag: u16, age: u64, bytes: u64 },
    /// A heartbeat window closed with no signal from `peer`.
    HeartbeatMiss { stage: usize, replica: usize, peer: usize, boundary: u64 },
    /// The miss counter crossed the detection threshold (or a join was
    /// observed): the failure detector's verdict on `node`.
    Detect { boundary: u64, node: usize, join: bool },
    /// The churn schedule dropped (`join = false`) or rejoined
    /// (`join = true`) `node` at `step`.
    ChurnApplied { step: u64, node: usize, join: bool },
    /// The communicator's stash sweep dropped `dropped` expired entries
    /// at `boundary`.
    StashSwept { boundary: u64, dropped: u64 },
    /// Per-boundary breakdown: inner-phase seconds, boundary-sync
    /// seconds, and the wire traffic delta (`bytes`/`msgs`) attributed
    /// to this boundary passage. Summing `bytes`/`msgs` over all
    /// `Boundary` events plus the final [`Event::Drain`] reproduces the
    /// run's wire totals exactly.
    Boundary { outer_idx: u64, inner_s: f64, sync_s: f64, bytes: u64, msgs: u64 },
    /// End-of-run drain: residual wire traffic after the last boundary
    /// (final in-flight folds, validation shipping, etc.).
    Drain { outer_idx: u64, bytes: u64, msgs: u64 },
    /// The `[ckpt]` cadence wrote a snapshot covering `boundary` (cut
    /// after `step` inner steps); `bytes` is the on-disk file size.
    Ckpt { boundary: u64, step: u64, bytes: u64 },
    /// The run resumed from a snapshot cut at `boundary` / `step`.
    Resume { boundary: u64, step: u64 },
    /// Static-analysis verdict for the running build (`noloco analyze`
    /// rules R1–R5), journaled once at hub construction so every trace
    /// self-describes whether its producer passed the determinism pass.
    Analyze { version: u64, findings: u64, clean: bool },
    /// Per-peer transport traffic on the socket executor, journaled once
    /// per peer at end of run: framed TCP bytes and frames actually
    /// written to `peer` (headers included — *not* the logical metering
    /// `CommStats` compare against) and the last handshake RTT.
    NetPeer { peer: usize, bytes: u64, msgs: u64, rtt_us: u64 },
}

impl Event {
    /// The `"ev"` name this event serializes under.
    pub fn name(&self) -> &'static str {
        match self {
            Event::InnerPhase { .. } => "inner",
            Event::Offer { .. } => "offer",
            Event::Fold { .. } => "fold",
            Event::HeartbeatMiss { .. } => "hb_miss",
            Event::Detect { .. } => "detect",
            Event::ChurnApplied { .. } => "churn",
            Event::StashSwept { .. } => "sweep",
            Event::Boundary { .. } => "boundary",
            Event::Drain { .. } => "drain",
            Event::Ckpt { .. } => "ckpt",
            Event::Resume { .. } => "resume",
            Event::Analyze { .. } => "analyze",
            Event::NetPeer { .. } => "net_peer",
        }
    }

    /// Encode as one JSONL line (no trailing newline).
    pub fn to_json(&self, wall: f64, sim: u64) -> String {
        let mut s = String::with_capacity(160);
        let _ = write!(
            s,
            "{{\"v\":{SCHEMA_VERSION},\"wall\":{wall:.6},\"sim\":{sim},\"ev\":\"{}\"",
            self.name()
        );
        match self {
            Event::InnerPhase { stage, replica, step, loss, dur_s } => {
                push_u64(&mut s, "stage", *stage as u64);
                push_u64(&mut s, "replica", *replica as u64);
                push_u64(&mut s, "step", *step);
                push_f64(&mut s, "loss", *loss);
                push_f64(&mut s, "dur_s", *dur_s);
            }
            Event::Offer { stage, replica, peer, round, frag, bytes } => {
                push_u64(&mut s, "stage", *stage as u64);
                push_u64(&mut s, "replica", *replica as u64);
                push_u64(&mut s, "peer", *peer as u64);
                push_u64(&mut s, "round", *round);
                push_u64(&mut s, "frag", u64::from(*frag));
                push_u64(&mut s, "bytes", *bytes);
            }
            Event::Fold { stage, replica, peer, round, frag, age, bytes } => {
                push_u64(&mut s, "stage", *stage as u64);
                push_u64(&mut s, "replica", *replica as u64);
                push_u64(&mut s, "peer", *peer as u64);
                push_u64(&mut s, "round", *round);
                push_u64(&mut s, "frag", u64::from(*frag));
                push_u64(&mut s, "age", *age);
                push_u64(&mut s, "bytes", *bytes);
            }
            Event::HeartbeatMiss { stage, replica, peer, boundary } => {
                push_u64(&mut s, "stage", *stage as u64);
                push_u64(&mut s, "replica", *replica as u64);
                push_u64(&mut s, "peer", *peer as u64);
                push_u64(&mut s, "boundary", *boundary);
            }
            Event::Detect { boundary, node, join } => {
                push_u64(&mut s, "boundary", *boundary);
                push_u64(&mut s, "node", *node as u64);
                push_bool(&mut s, "join", *join);
            }
            Event::ChurnApplied { step, node, join } => {
                push_u64(&mut s, "step", *step);
                push_u64(&mut s, "node", *node as u64);
                push_bool(&mut s, "join", *join);
            }
            Event::StashSwept { boundary, dropped } => {
                push_u64(&mut s, "boundary", *boundary);
                push_u64(&mut s, "dropped", *dropped);
            }
            Event::Boundary { outer_idx, inner_s, sync_s, bytes, msgs } => {
                push_u64(&mut s, "outer_idx", *outer_idx);
                push_f64(&mut s, "inner_s", *inner_s);
                push_f64(&mut s, "sync_s", *sync_s);
                push_u64(&mut s, "bytes", *bytes);
                push_u64(&mut s, "msgs", *msgs);
            }
            Event::Drain { outer_idx, bytes, msgs } => {
                push_u64(&mut s, "outer_idx", *outer_idx);
                push_u64(&mut s, "bytes", *bytes);
                push_u64(&mut s, "msgs", *msgs);
            }
            Event::Ckpt { boundary, step, bytes } => {
                push_u64(&mut s, "boundary", *boundary);
                push_u64(&mut s, "step", *step);
                push_u64(&mut s, "bytes", *bytes);
            }
            Event::Resume { boundary, step } => {
                push_u64(&mut s, "boundary", *boundary);
                push_u64(&mut s, "step", *step);
            }
            Event::Analyze { version, findings, clean } => {
                push_u64(&mut s, "version", *version);
                push_u64(&mut s, "findings", *findings);
                push_bool(&mut s, "clean", *clean);
            }
            Event::NetPeer { peer, bytes, msgs, rtt_us } => {
                push_u64(&mut s, "peer", *peer as u64);
                push_u64(&mut s, "bytes", *bytes);
                push_u64(&mut s, "msgs", *msgs);
                push_u64(&mut s, "rtt_us", *rtt_us);
            }
        }
        s.push('}');
        s
    }
}

/// Event-specific required keys per `"ev"` name (beyond the envelope
/// `v`/`wall`/`sim`/`ev` present on every line). `None` for unknown
/// names. `scripts/check_trace_schema.sh` embeds the same table.
pub fn required_keys(ev: &str) -> Option<&'static [&'static str]> {
    Some(match ev {
        "inner" => &["stage", "replica", "step", "loss", "dur_s"],
        "offer" => &["stage", "replica", "peer", "round", "frag", "bytes"],
        "fold" => &["stage", "replica", "peer", "round", "frag", "age", "bytes"],
        "hb_miss" => &["stage", "replica", "peer", "boundary"],
        "detect" => &["boundary", "node", "join"],
        "churn" => &["step", "node", "join"],
        "sweep" => &["boundary", "dropped"],
        "boundary" => &["outer_idx", "inner_s", "sync_s", "bytes", "msgs"],
        "drain" => &["outer_idx", "bytes", "msgs"],
        "ckpt" => &["boundary", "step", "bytes"],
        "resume" => &["boundary", "step"],
        "analyze" => &["version", "findings", "clean"],
        "net_peer" => &["peer", "bytes", "msgs", "rtt_us"],
        _ => return None,
    })
}

pub(crate) fn push_f64(s: &mut String, key: &str, v: f64) {
    if v.is_finite() {
        let _ = write!(s, ",\"{key}\":{v:.6}");
    } else {
        let _ = write!(s, ",\"{key}\":null");
    }
}

pub(crate) fn push_u64(s: &mut String, key: &str, v: u64) {
    let _ = write!(s, ",\"{key}\":{v}");
}

pub(crate) fn push_bool(s: &mut String, key: &str, v: bool) {
    let _ = write!(s, ",\"{key}\":{v}");
}

/// A value parsed back out of a journal line.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonVal {
    Num(f64),
    Str(String),
    Bool(bool),
    Null,
}

impl JsonVal {
    /// Numeric view: numbers as themselves, `null` as NaN (the inverse
    /// of the NaN → `null` encoding), everything else `None`.
    pub fn num(&self) -> Option<f64> {
        match self {
            JsonVal::Num(x) => Some(*x),
            JsonVal::Null => Some(f64::NAN),
            _ => None,
        }
    }

    /// Unsigned-integer view of a numeric value.
    pub fn uint(&self) -> Option<u64> {
        match self {
            JsonVal::Num(x) if x.is_finite() && *x >= 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    /// String view.
    pub fn str_val(&self) -> Option<&str> {
        match self {
            JsonVal::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean view.
    pub fn boolean(&self) -> Option<bool> {
        match self {
            JsonVal::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse one flat journal line back into a key → value map. Returns
/// `None` on anything that is not a single flat JSON object in the
/// journal's dialect (no nesting, no escaped quotes).
pub fn parse_line(line: &str) -> Option<BTreeMap<String, JsonVal>> {
    let s = line.trim();
    let mut rest = s.strip_prefix('{')?.strip_suffix('}')?.trim_start();
    let mut out = BTreeMap::new();
    while !rest.is_empty() {
        rest = rest.strip_prefix('"')?;
        let kend = rest.find('"')?;
        let key = rest[..kend].to_string();
        rest = rest[kend + 1..].trim_start().strip_prefix(':')?.trim_start();
        let (val, used) = if let Some(r) = rest.strip_prefix('"') {
            let vend = r.find('"')?;
            (JsonVal::Str(r[..vend].to_string()), vend + 2)
        } else if rest.starts_with("true") {
            (JsonVal::Bool(true), 4)
        } else if rest.starts_with("false") {
            (JsonVal::Bool(false), 5)
        } else if rest.starts_with("null") {
            (JsonVal::Null, 4)
        } else {
            let vend = rest
                .find(|c: char| !(c.is_ascii_digit() || "+-.eE".contains(c)))
                .unwrap_or(rest.len());
            (JsonVal::Num(rest[..vend].parse().ok()?), vend)
        };
        out.insert(key, val);
        rest = rest[used..].trim_start();
        match rest.strip_prefix(',') {
            Some(r) => rest = r.trim_start(),
            None if rest.is_empty() => {}
            None => return None,
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_event_roundtrips_through_parse_line() {
        let events = vec![
            Event::InnerPhase { stage: 0, replica: 1, step: 7, loss: 2.5, dur_s: 0.125 },
            Event::Offer { stage: 0, replica: 1, peer: 2, round: 3, frag: 1, bytes: 4096 },
            Event::Fold { stage: 0, replica: 1, peer: 2, round: 3, frag: 1, age: 2, bytes: 4096 },
            Event::HeartbeatMiss { stage: 1, replica: 0, peer: 3, boundary: 5 },
            Event::Detect { boundary: 5, node: 3, join: false },
            Event::ChurnApplied { step: 40, node: 3, join: true },
            Event::StashSwept { boundary: 6, dropped: 2 },
            Event::Boundary { outer_idx: 6, inner_s: 1.5, sync_s: 0.25, bytes: 8192, msgs: 4 },
            Event::Drain { outer_idx: 6, bytes: 128, msgs: 1 },
            Event::Ckpt { boundary: 6, step: 300, bytes: 65536 },
            Event::Resume { boundary: 6, step: 300 },
            Event::Analyze { version: 1, findings: 0, clean: true },
            Event::NetPeer { peer: 1, bytes: 1 << 20, msgs: 512, rtt_us: 180 },
        ];
        for (i, ev) in events.iter().enumerate() {
            let line = ev.to_json(1.25, i as u64);
            let m = parse_line(&line).expect("line parses");
            assert_eq!(m["v"].uint(), Some(u64::from(SCHEMA_VERSION)));
            assert_eq!(m["sim"].uint(), Some(i as u64));
            assert!((m["wall"].num().unwrap() - 1.25).abs() < 1e-9);
            let name = m["ev"].str_val().unwrap();
            assert_eq!(name, ev.name());
            for key in required_keys(name).expect("known event") {
                assert!(m.contains_key(*key), "{name} line missing {key}: {line}");
            }
        }
    }

    #[test]
    fn nan_loss_encodes_as_null_and_parses_back_as_nan() {
        let ev = Event::InnerPhase { stage: 0, replica: 0, step: 1, loss: f64::NAN, dur_s: 0.5 };
        let line = ev.to_json(0.0, 1);
        assert!(line.contains("\"loss\":null"), "{line}");
        assert!(!line.contains("NaN"), "{line}");
        let m = parse_line(&line).unwrap();
        assert!(m["loss"].num().unwrap().is_nan());
    }

    #[test]
    fn parse_line_rejects_trailing_garbage() {
        assert!(parse_line("{\"v\":1} extra").is_none());
        assert!(parse_line("{\"v\":1,\"ev\":\"inner\"").is_none());
        assert!(parse_line("not json").is_none());
    }

    #[test]
    fn booleans_and_negative_exponents_parse() {
        let m = parse_line("{\"join\":true,\"x\":1.5e-3,\"y\":false}").unwrap();
        assert_eq!(m["join"].boolean(), Some(true));
        assert_eq!(m["y"].boolean(), Some(false));
        assert!((m["x"].num().unwrap() - 1.5e-3).abs() < 1e-12);
    }
}
