//! Run metrics: loss / perplexity tracking, cross-replica weight σ,
//! Pearson correlation against the LR schedule, and CSV/Markdown output.
//!
//! These are the quantities the paper's tables and figures report:
//! Table 2/3 (final validation perplexity), Fig. 2 (PPL curves), Fig. 3A
//! (relative PPL difference, Eq. 4), Fig. 3B (normalized weight σ and its
//! Pearson r with the learning rate), Fig. 4 (σ and PPL ratios between
//! routing modes).

use crate::tensor::{pearson, replica_std, Tensor};
use std::fmt::Write as _;
use std::io::Write as _;

/// Perplexity from a mean cross-entropy (nats per token).
pub fn perplexity(mean_nll: f64) -> f64 {
    mean_nll.exp()
}

/// Eq. 4: relative perplexity difference, normalized by the FSDP anchor.
pub fn rel_ppl_diff(diloco: f64, noloco: f64, fsdp: f64) -> f64 {
    (diloco - noloco) / fsdp
}

/// Time series of one run's observables.
#[derive(Clone, Debug, Default)]
pub struct RunTrace {
    /// Inner-step indices where observations were taken.
    pub steps: Vec<usize>,
    /// Training loss (nats) per observation.
    pub train_loss: Vec<f64>,
    /// Validation loss (nats) per observation (NaN when not evaluated).
    pub val_loss: Vec<f64>,
    /// Cross-replica weight σ per observation (NaN when not measured).
    pub weight_std: Vec<f64>,
    /// Learning rate per observation.
    pub lr: Vec<f64>,
}

impl RunTrace {
    /// Append one observation row.
    pub fn push(&mut self, step: usize, train_loss: f64, val_loss: f64, weight_std: f64, lr: f64) {
        self.steps.push(step);
        self.train_loss.push(train_loss);
        self.val_loss.push(val_loss);
        self.weight_std.push(weight_std);
        self.lr.push(lr);
    }

    /// Final validation perplexity (last non-NaN val loss).
    pub fn final_val_ppl(&self) -> f64 {
        self.val_loss
            .iter()
            .rev()
            .find(|v| v.is_finite())
            .map(|v| perplexity(*v))
            .unwrap_or(f64::NAN)
    }

    /// Pearson correlation between weight σ and LR over observations where
    /// both exist — the Fig. 3B statistic (paper: 0.91–0.97).
    pub fn std_lr_pearson(&self) -> f64 {
        let pairs: Vec<(f64, f64)> = self
            .weight_std
            .iter()
            .zip(&self.lr)
            .filter(|(s, _)| s.is_finite())
            .map(|(s, l)| (*s, *l))
            .collect();
        let xs: Vec<f64> = pairs.iter().map(|p| p.0).collect();
        let ys: Vec<f64> = pairs.iter().map(|p| p.1).collect();
        pearson(&xs, &ys)
    }

    /// Weight-σ series normalized by its max (Fig. 3B's y-axis).
    pub fn normalized_weight_std(&self) -> Vec<f64> {
        let max = self
            .weight_std
            .iter()
            .cloned()
            .filter(|v| v.is_finite())
            .fold(0.0, f64::max);
        if max == 0.0 {
            return self.weight_std.clone();
        }
        self.weight_std.iter().map(|s| s / max).collect()
    }

    /// Serialize to CSV. In memory, "not measured" is NaN; on disk it is
    /// an *empty cell* — CSV has no NaN literal, and emitting one breaks
    /// spreadsheet/pandas consumers. [`RunTrace::from_csv`] restores the
    /// NaN convention on read-back.
    pub fn to_csv(&self) -> String {
        fn cell(v: f64) -> String {
            if v.is_finite() {
                format!("{v}")
            } else {
                String::new()
            }
        }
        let mut out = String::from("step,train_loss,val_loss,weight_std,lr\n");
        for i in 0..self.steps.len() {
            let _ = writeln!(
                out,
                "{},{},{},{},{}",
                self.steps[i],
                cell(self.train_loss[i]),
                cell(self.val_loss[i]),
                cell(self.weight_std[i]),
                cell(self.lr[i])
            );
        }
        out
    }

    /// Parse a trace back from [`RunTrace::to_csv`] output. Empty or
    /// unparseable float cells become NaN (the in-memory "not measured"
    /// convention); rows with a bad step index are skipped.
    pub fn from_csv(text: &str) -> RunTrace {
        let mut t = RunTrace::default();
        for line in text.lines().skip(1) {
            let mut cols = line.split(',');
            let Some(step) = cols.next().and_then(|c| c.trim().parse::<usize>().ok()) else {
                continue;
            };
            let mut f = |c: Option<&str>| {
                c.and_then(|c| c.trim().parse::<f64>().ok()).unwrap_or(f64::NAN)
            };
            let train_loss = f(cols.next());
            let val_loss = f(cols.next());
            let weight_std = f(cols.next());
            let lr = f(cols.next());
            t.push(step, train_loss, val_loss, weight_std, lr);
        }
        t
    }

    /// Write CSV to a file, creating parent directories.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

/// Cross-replica σ from per-replica flattened parameter tensors — wrapper
/// over [`replica_std`] taking owned parameter lists.
pub fn weight_std_of(replicas: &[Vec<Tensor>]) -> f64 {
    if replicas.len() < 2 {
        return 0.0;
    }
    let flats: Vec<Tensor> = replicas
        .iter()
        .map(|ps| Tensor::from_vec(crate::tensor::flatten(ps), &[ps.iter().map(|p| p.len()).sum()]))
        .collect();
    let refs: Vec<&Tensor> = flats.iter().collect();
    replica_std(&refs)
}

/// Minimal Markdown table builder for experiment reports.
#[derive(Clone, Debug)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with column headers.
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header width).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render as GitHub-flavoured Markdown.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "| {} |", self.header.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(out, "| {} |", r.join(" | "));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perplexity_of_uniform_distribution() {
        let v = 512f64;
        assert!((perplexity(v.ln()) - v).abs() < 1e-9);
    }

    #[test]
    fn rel_ppl_diff_sign_convention() {
        // Positive = NoLoCo better (lower PPL), matching Fig. 3A's
        // "positive indicates faster convergence compared to DiLoCo".
        assert!(rel_ppl_diff(30.0, 29.0, 25.0) > 0.0);
        assert!(rel_ppl_diff(29.0, 30.0, 25.0) < 0.0);
    }

    #[test]
    fn trace_final_ppl_skips_nan() {
        let mut t = RunTrace::default();
        t.push(0, 5.0, 3.0f64.ln(), 0.1, 1e-3);
        t.push(1, 4.0, f64::NAN, 0.2, 1e-3);
        assert!((t.final_val_ppl() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn trace_pearson_tracks_lr_correlated_std() {
        let mut t = RunTrace::default();
        for i in 0..50 {
            let lr = 1.0 - i as f64 / 50.0;
            t.push(i, 0.0, f64::NAN, 0.5 * lr + 0.01, lr);
        }
        assert!(t.std_lr_pearson() > 0.99);
    }

    #[test]
    fn normalized_std_peaks_at_one() {
        let mut t = RunTrace::default();
        t.push(0, 0.0, f64::NAN, 0.2, 1.0);
        t.push(1, 0.0, f64::NAN, 0.4, 1.0);
        t.push(2, 0.0, f64::NAN, 0.1, 1.0);
        let n = t.normalized_weight_std();
        assert_eq!(n, vec![0.5, 1.0, 0.25]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = RunTrace::default();
        t.push(10, 2.5, 2.4, 0.1, 5e-4);
        let csv = t.to_csv();
        assert!(csv.starts_with("step,"));
        assert!(csv.lines().count() == 2);
        assert!(csv.contains("10,2.5,2.4,0.1,0.0005"));
    }

    #[test]
    fn csv_nan_cells_are_empty_and_roundtrip() {
        let mut t = RunTrace::default();
        t.push(10, 2.5, f64::NAN, 0.1, 5e-4);
        t.push(20, 2.4, 2.3, f64::NAN, 4e-4);
        let csv = t.to_csv();
        // No literal NaN on disk — unmeasured cells are empty.
        assert!(!csv.contains("NaN"), "{csv}");
        assert!(csv.contains("10,2.5,,0.1,0.0005"), "{csv}");
        assert!(csv.contains("20,2.4,2.3,,0.0004"), "{csv}");
        let back = RunTrace::from_csv(&csv);
        assert_eq!(back.steps, t.steps);
        assert_eq!(back.train_loss, t.train_loss);
        assert!(back.val_loss[0].is_nan() && (back.val_loss[1] - 2.3).abs() < 1e-12);
        assert!(back.weight_std[1].is_nan() && (back.weight_std[0] - 0.1).abs() < 1e-12);
        assert_eq!(back.lr, t.lr);
    }

    #[test]
    fn weight_std_of_replicas() {
        let a = vec![Tensor::from_slice(&[0.0, 0.0])];
        let b = vec![Tensor::from_slice(&[2.0, 4.0])];
        let s = weight_std_of(&[a, b]);
        assert!((s - 1.5).abs() < 1e-9);
    }

    #[test]
    fn markdown_table_renders() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| 1 | 2 |"));
    }
}
