//! Minimal Rust source scanner for the static-analysis pass.
//!
//! Not a parser: a line-oriented lexer that strips comments, blanks
//! string/char literal contents, tracks `#[cfg(test)]` regions by brace
//! depth, and recovers function spans — exactly enough structure for
//! the determinism rules in [`crate::analyze::rules`], with no external
//! crates (the `obs::journal::parse_line` school of tooling).
//!
//! The scanner is deliberately conservative: string and comment bodies
//! can never trip a rule (they are blanked before matching), and
//! anything inside a `#[cfg(test)]` item or `#[test]` function is
//! exempt from every rule.

/// One scanned source line.
#[derive(Clone, Debug)]
pub struct Line {
    /// Code text with comments removed and string/char contents blanked
    /// (the delimiting quotes survive so token boundaries stay sane).
    pub code: String,
    /// Comment text carried on this line (line and block comments) —
    /// where `// analyze: <tag>` justifications live.
    pub comment: String,
    /// Inside a `#[cfg(test)]` item or a `#[test]` function.
    pub is_test: bool,
}

/// A function span: name, header line, and inclusive body line range
/// (0-based line indices into the scanned [`Line`] vector).
#[derive(Clone, Debug)]
pub struct FnSpan {
    /// The declared function name.
    pub name: String,
    /// Line carrying the `fn` keyword.
    pub header: usize,
    /// First line of the span (the header line).
    pub start: usize,
    /// Last line of the body (the closing-brace line).
    pub end: usize,
}

/// Lex `src` into per-line code/comment text and mark test regions.
pub fn scan(src: &str) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let mut raw: Vec<(String, String)> = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Code,
        LineComment,
        Block(u32),
        Str,
        RawStr(usize),
    }
    let mut st = St::Code;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            raw.push((std::mem::take(&mut code), std::mem::take(&mut comment)));
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = St::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::Block(1);
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    st = St::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !ends_in_ident(&code) {
                    if let Some((skip, hashes)) = raw_str_start(&chars, i) {
                        code.push('"');
                        st = St::RawStr(hashes);
                        i += skip;
                    } else if c == 'b' && next == Some('"') {
                        code.push('"');
                        st = St::Str;
                        i += 2;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime: an escape or a
                    // one-char-then-quote sequence is a literal;
                    // anything else is a lifetime tick.
                    if next == Some('\\') {
                        let mut j = i + 2;
                        while j < chars.len() && chars[j] != '\'' {
                            j += 1;
                        }
                        code.push_str("''");
                        i = j + 1;
                    } else if chars.get(i + 2).copied() == Some('\'') {
                        code.push_str("''");
                        i += 3;
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                comment.push(c);
                i += 1;
            }
            St::Block(d) => {
                let next = chars.get(i + 1).copied();
                if c == '*' && next == Some('/') {
                    st = if d == 1 { St::Code } else { St::Block(d - 1) };
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::Block(d + 1);
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    // Skip the escaped char, but let the newline of a
                    // line-continuation escape reach the top of the
                    // loop so line numbers stay aligned.
                    if chars.get(i + 1).copied() == Some('\n') {
                        i += 1;
                    } else {
                        i += 2;
                    }
                } else if c == '"' {
                    code.push('"');
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            St::RawStr(h) => {
                if c == '"' && closes_raw(&chars, i, h) {
                    code.push('"');
                    st = St::Code;
                    i += 1 + h;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        raw.push((code, comment));
    }
    mark_tests(raw)
}

fn ends_in_ident(code: &str) -> bool {
    code.chars().next_back().is_some_and(|p| p.is_ascii_alphanumeric() || p == '_')
}

/// `r"…"`, `r#"…"#`, `br#"…"#` openers: returns (chars to skip past the
/// opening quote, hash count).
fn raw_str_start(chars: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
    }
    if chars.get(j).copied() != Some('r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while chars.get(j).copied() == Some('#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j).copied() == Some('"') {
        Some((j + 1 - i, hashes))
    } else {
        None
    }
}

fn closes_raw(chars: &[char], i: usize, hashes: usize) -> bool {
    (1..=hashes).all(|k| chars.get(i + k).copied() == Some('#'))
}

/// Mark lines inside `#[cfg(test)]` items / `#[test]` functions. The
/// attribute arms a pending flag; the next `{` opens the exempt region,
/// a `;` before any brace (attribute on a braceless item) disarms it.
fn mark_tests(raw: Vec<(String, String)>) -> Vec<Line> {
    let mut out = Vec::with_capacity(raw.len());
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut exit_depth: Option<i64> = None;
    for (code, comment) in raw {
        if exit_depth.is_none() && (code.contains("cfg(test") || code.contains("#[test]")) {
            pending = true;
        }
        let mut is_test = exit_depth.is_some() || pending;
        for ch in code.chars() {
            match ch {
                '{' => {
                    if pending && exit_depth.is_none() {
                        exit_depth = Some(depth);
                        pending = false;
                        is_test = true;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if exit_depth.is_some_and(|d| depth <= d) {
                        exit_depth = None;
                        is_test = true; // the closing-brace line itself
                    }
                }
                ';' => {
                    if pending && exit_depth.is_none() {
                        pending = false;
                    }
                }
                _ => {}
            }
        }
        if exit_depth.is_some() {
            is_test = true;
        }
        out.push(Line { code, comment, is_test });
    }
    out
}

/// Recover function spans by brace counting. A `fn name` header arms a
/// pending declaration; the next `{` at argument-paren depth zero opens
/// its body, a `;` there (trait method declaration) disarms it.
pub fn functions(lines: &[Line]) -> Vec<FnSpan> {
    let mut out: Vec<FnSpan> = Vec::new();
    let mut open: Vec<(String, usize, i64)> = Vec::new();
    let mut pending: Option<(String, usize)> = None;
    let mut pending_paren: i64 = 0;
    let mut depth: i64 = 0;
    for (ln, line) in lines.iter().enumerate() {
        let decls = fn_decls(&line.code);
        let mut di = 0usize;
        for (ci, ch) in line.code.chars().enumerate() {
            if di < decls.len() && decls[di].0 == ci {
                pending = Some((decls[di].1.clone(), ln));
                pending_paren = 0;
                di += 1;
            }
            match ch {
                '(' => pending_paren += 1,
                ')' => pending_paren -= 1,
                '{' => {
                    if let Some((name, header)) = pending.take() {
                        open.push((name, header, depth));
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if open.last().is_some_and(|&(_, _, d)| depth <= d) {
                        if let Some((name, header, _)) = open.pop() {
                            out.push(FnSpan { name, header, start: header, end: ln });
                        }
                    }
                }
                ';' => {
                    if pending.is_some() && pending_paren <= 0 {
                        pending = None; // bodiless trait declaration
                    }
                }
                _ => {}
            }
        }
    }
    out.sort_by_key(|s| s.start);
    out
}

/// `(char index, name)` of each `fn` declaration on a code line.
fn fn_decls(code: &str) -> Vec<(usize, String)> {
    let cs: Vec<char> = code.chars().collect();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 1 < cs.len() {
        let boundary_before = i == 0 || !is_ident(cs[i - 1]);
        let boundary_after = match cs.get(i + 2) {
            Some(c) => !is_ident(*c),
            None => true,
        };
        if cs[i] == 'f' && cs[i + 1] == 'n' && boundary_before && boundary_after {
            let mut j = i + 2;
            while j < cs.len() && cs[j].is_whitespace() {
                j += 1;
            }
            let mut name = String::new();
            while j < cs.len() && is_ident(cs[j]) {
                name.push(cs[j]);
                j += 1;
            }
            if !name.is_empty() {
                out.push((i, name));
            }
            i = j.max(i + 2);
        } else {
            i += 1;
        }
    }
    out
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Innermost function span containing line `ln`, if any.
pub fn enclosing<'a>(fns: &'a [FnSpan], ln: usize) -> Option<&'a FnSpan> {
    fns.iter()
        .filter(|s| s.start <= ln && ln <= s.end)
        .min_by_key(|s| s.end - s.start)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_blanked() {
        let src = "let a = \"Instant::now()\"; // Instant::now\nlet b = 1; /* x */ let c = 2;\n";
        let lines = scan(src);
        assert!(!lines[0].code.contains("Instant"));
        assert!(lines[0].comment.contains("Instant::now"));
        assert!(lines[1].code.contains("let b = 1;"));
        assert!(lines[1].code.contains("let c = 2;"));
        assert!(lines[1].comment.contains('x'));
    }

    #[test]
    fn raw_strings_and_char_literals_are_blanked() {
        let src = "let s = r#\"a \"quoted\" Instant::now\"#;\nlet c = 'x';\nlet l: &'static str = \"\";\n";
        let lines = scan(src);
        assert!(!lines[0].code.contains("Instant"), "{}", lines[0].code);
        assert!(lines[1].code.contains("''"));
        assert!(lines[2].code.contains("&'static"));
    }

    #[test]
    fn cfg_test_regions_are_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let lines = scan(src);
        assert!(!lines[0].is_test);
        assert!(lines[1].is_test && lines[2].is_test && lines[3].is_test && lines[4].is_test);
        assert!(!lines[5].is_test);
    }

    #[test]
    fn cfg_test_on_braceless_item_does_not_leak() {
        let src = "#[cfg(test)]\nuse std::fmt;\nfn live() { let x = 1; }\n";
        let lines = scan(src);
        assert!(lines[1].is_test);
        assert!(!lines[2].is_test, "the attribute must die at the semicolon");
    }

    #[test]
    fn function_spans_cover_bodies() {
        let src = "impl Foo {\n    fn bar(&self) {\n        baz();\n    }\n    fn qux() -> u32 {\n        7\n    }\n}\n";
        let lines = scan(src);
        let fns = functions(&lines);
        assert_eq!(fns.len(), 2);
        assert_eq!(fns[0].name, "bar");
        assert_eq!((fns[0].start, fns[0].end), (1, 3));
        assert_eq!(fns[1].name, "qux");
        assert_eq!((fns[1].start, fns[1].end), (4, 6));
        assert_eq!(enclosing(&fns, 2).map(|s| s.name.as_str()), Some("bar"));
        assert!(enclosing(&fns, 0).is_none());
    }

    #[test]
    fn trait_declarations_open_no_span() {
        let src = "trait T {\n    fn a(&self);\n    fn b(&self) {\n        1;\n    }\n}\n";
        let fns = functions(&scan(src));
        assert_eq!(fns.len(), 1);
        assert_eq!(fns[0].name, "b");
    }
}
