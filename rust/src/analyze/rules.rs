//! The determinism rule registry (R1–R5).
//!
//! Each rule walks the scanned [`Line`]s of one file and pushes
//! [`Finding`]s. A finding can be suppressed by a justification
//! comment on the same or the immediately preceding line:
//!
//! ```text
//! // analyze: ordered-ok — keys are folded through a sorted Vec below
//! ```
//!
//! Tags are rule-specific (`wall-clock-ok`, `ordered-ok`, `seed-ok`,
//! `protocol-ok`, `float-ok`) so a justification never silences more
//! than the rule it names. Test code (`#[cfg(test)]` / `#[test]`) is
//! exempt from every rule.

use super::Finding;
use super::scan::{enclosing, FnSpan, Line};

/// R1 justification tag.
pub const TAG_R1: &str = "wall-clock-ok";
/// R2 justification tag.
pub const TAG_R2: &str = "ordered-ok";
/// R3 justification tag.
pub const TAG_R3: &str = "seed-ok";
/// R4 justification tag.
pub const TAG_R4: &str = "protocol-ok";
/// R5 justification tag.
pub const TAG_R5: &str = "float-ok";

/// True when line `ln` carries `// analyze: <tag>`, or the contiguous
/// comment-only block immediately above it does. Multi-line
/// justifications are the norm — the tag opens the block, prose
/// continues below it — so the whole block counts as "immediately
/// preceding".
fn annotated(lines: &[Line], ln: usize, tag: &str) -> bool {
    if has_tag(&lines[ln].comment, tag) {
        return true;
    }
    let mut i = ln;
    while i > 0 {
        i -= 1;
        let above = &lines[i];
        if above.code.trim().is_empty() && !above.comment.is_empty() {
            if has_tag(&above.comment, tag) {
                return true;
            }
            continue; // keep walking up the comment block
        }
        break;
    }
    false
}

fn has_tag(comment: &str, tag: &str) -> bool {
    comment
        .split("analyze:")
        .skip(1)
        .any(|rest| rest.trim_start().starts_with(tag))
}

fn push(out: &mut Vec<Finding>, rel: &str, ln: usize, rule: &'static str, msg: String) {
    out.push(Finding { file: rel.to_string(), line: ln + 1, rule, msg });
}

/// `pat` occurs in `code` at an identifier boundary (so `operand::`
/// does not match `rand::`, nor `thread_rng_x` match `thread_rng`).
fn contains_token(code: &str, pat: &str) -> bool {
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(pat) {
        let at = start + pos;
        let prev_ok = at == 0 || {
            let p = code[..at].chars().next_back().unwrap_or(' ');
            !(p.is_ascii_alphanumeric() || p == '_')
        };
        let end = at + pat.len();
        let next_ok = pat.ends_with(':')
            || pat.ends_with('(')
            || match code[end..].chars().next() {
                Some(n) => !(n.is_ascii_alphanumeric() || n == '_'),
                None => true,
            };
        if prev_ok && next_ok {
            return true;
        }
        start = end;
    }
    false
}

// ---------------------------------------------------------------- R1

const R1_PATTERNS: &[&str] =
    &["SystemTime::now", "Instant::now", "thread_rng", "rand::", "available_parallelism"];

/// Files where wall-clock / ambient randomness is legitimate by role:
/// obs (wall stamps), bench (measurement), main.rs (CLI wall-clock
/// envelope), net/fabric.rs and net/socket.rs (the real-time transports
/// — their latency models, dial retries, handshake RTTs and timeouts
/// are wall-clock by design and never feed the deterministic
/// trajectory), and train/par.rs (the exec pool's `--threads 0`
/// auto-detect reads the machine width — a throughput knob only; the
/// pool's submission-order contract keeps the trajectory identical at
/// any thread count).
const R1_ALLOW: &[&str] =
    &["obs/", "bench/", "main.rs", "net/fabric.rs", "net/socket.rs", "train/par.rs"];

/// R1: no wall-clock reads or ambient randomness on deterministic paths.
pub fn r1_wall_clock(rel: &str, lines: &[Line], out: &mut Vec<Finding>) {
    if R1_ALLOW.iter().any(|p| rel.starts_with(p)) {
        return;
    }
    for (ln, line) in lines.iter().enumerate() {
        if line.is_test {
            continue;
        }
        for pat in R1_PATTERNS {
            if contains_token(&line.code, pat) && !annotated(lines, ln, TAG_R1) {
                push(
                    out,
                    rel,
                    ln,
                    "R1",
                    format!(
                        "`{pat}` — wall-clock/ambient randomness is denied on deterministic \
                         paths; justify with `// analyze: {TAG_R1}` or move it to an \
                         allowlisted module"
                    ),
                );
                break;
            }
        }
    }
}

// ---------------------------------------------------------------- R2

const R2_DIRS: &[&str] = &["train/", "net/", "collective/", "routing/"];
const R2_ITER: &[&str] = &[
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".drain()",
    ".into_iter()",
];

/// R2: no iteration over unordered `HashMap`/`HashSet` bindings in the
/// deterministic directories — iteration order would leak into fold
/// order, wire accounting, and checkpoint bytes.
pub fn r2_unordered_iteration(rel: &str, lines: &[Line], out: &mut Vec<Finding>) {
    if !R2_DIRS.iter().any(|d| rel.starts_with(d)) {
        return;
    }
    let maps = unordered_idents(lines);
    if maps.is_empty() {
        return;
    }
    for (ln, line) in lines.iter().enumerate() {
        if line.is_test {
            continue;
        }
        let hit = maps.iter().find(|name| {
            R2_ITER
                .iter()
                .any(|m| contains_token(&line.code, &format!("{name}{m}")))
                || for_loop_over(&line.code, name)
        });
        if let Some(name) = hit {
            if !annotated(lines, ln, TAG_R2) {
                push(
                    out,
                    rel,
                    ln,
                    "R2",
                    format!(
                        "iteration over unordered `{name}` (HashMap/HashSet) on a \
                         deterministic path — swap to BTreeMap / sort keys, or justify \
                         with `// analyze: {TAG_R2}`"
                    ),
                );
            }
        }
    }
}

/// Names bound or ascribed to a `HashMap`/`HashSet` type anywhere in
/// the file (declarations, struct fields, constructor field inits).
fn unordered_idents(lines: &[Line]) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for line in lines {
        let code = &line.code;
        if code.trim_start().starts_with("use ") {
            continue;
        }
        for marker in ["HashMap", "HashSet"] {
            let mut start = 0usize;
            while let Some(pos) = code[start..].find(marker) {
                let at = start + pos;
                if let Some(name) = binding_before(&code[..at]) {
                    if !out.contains(&name) {
                        out.push(name);
                    }
                }
                start = at + marker.len();
            }
        }
    }
    out
}

/// `prefix` ends just before a `HashMap`/`HashSet` token: recover the
/// binding it is being assigned (`=`) or ascribed (`:`) to, if any.
fn binding_before(prefix: &str) -> Option<String> {
    let cut = prefix.rfind([':', '='])?;
    if prefix[..cut].ends_with(':') {
        // Path segment (`collections::HashMap`): walk past the `::`.
        return binding_before(&prefix[..cut.saturating_sub(1)]);
    }
    let head = prefix[..cut].trim_end();
    let name: String = head
        .chars()
        .rev()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
        .collect::<String>()
        .chars()
        .rev()
        .collect();
    if name.is_empty() || name.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        None
    } else {
        Some(name)
    }
}

/// `for … in [&][mut ]name` (optionally with trailing `{`, `.iter()` …).
fn for_loop_over(code: &str, name: &str) -> bool {
    let Some(pos) = code.find(" in ") else {
        return false;
    };
    if !code[..pos].contains("for ") {
        return false;
    }
    let rest = code[pos + 4..]
        .trim_start()
        .trim_start_matches('&')
        .trim_start_matches("mut ")
        .trim_start();
    rest.starts_with(name)
        && match rest[name.len()..].chars().next() {
            Some(c) => !(c.is_ascii_alphanumeric() || c == '_'),
            None => true,
        }
}

// ---------------------------------------------------------------- R3

const R3_CALLS: &[&str] = &["seed_from_u64(", "Pcg64::new("];

/// R3: every RNG construction must derive from a config seed or
/// restored state — a bare literal seed outside tests silently forks
/// the trajectory from what the config says.
pub fn r3_magic_seed(rel: &str, lines: &[Line], out: &mut Vec<Finding>) {
    if rel.starts_with("rngx/") {
        // The RNG crate itself: reference streams and splitmix
        // constants live here.
        return;
    }
    for (ln, line) in lines.iter().enumerate() {
        if line.is_test {
            continue;
        }
        for call in R3_CALLS {
            if let Some(pos) = line.code.find(call) {
                let args = capture_args(lines, ln, pos + call.len());
                if !has_seed_ident(&args) && !annotated(lines, ln, TAG_R3) {
                    push(
                        out,
                        rel,
                        ln,
                        "R3",
                        format!(
                            "`{call}…)` seeded from literals only — derive from the config \
                             seed or restored state, or justify with `// analyze: {TAG_R3}`"
                        ),
                    );
                }
            }
        }
    }
}

/// Argument text of a call, starting just past its opening paren
/// (which is already consumed), spanning up to 30 lines.
fn capture_args(lines: &[Line], ln: usize, from: usize) -> String {
    let mut depth = 1i64;
    let mut out = String::new();
    let mut idx = ln;
    let mut offset = from;
    while idx < lines.len() && idx <= ln + 30 {
        for c in lines[idx].code[offset..].chars() {
            match c {
                '(' => depth += 1,
                ')' => {
                    depth -= 1;
                    if depth == 0 {
                        return out;
                    }
                }
                _ => {}
            }
            out.push(c);
        }
        out.push(' ');
        idx += 1;
        offset = 0;
    }
    out
}

/// Any free identifier in the argument text (not a cast keyword,
/// primitive type, method name, or the alpha tail of a numeric
/// literal) counts as a derived seed.
fn has_seed_ident(args: &str) -> bool {
    const EXCLUDE: &[&str] = &[
        "as", "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128",
        "isize", "f32", "f64",
    ];
    let cs: Vec<char> = args.chars().collect();
    let mut i = 0usize;
    while i < cs.len() {
        let c = cs[i];
        if c.is_ascii_alphabetic() || c == '_' {
            let prev = if i > 0 { cs[i - 1] } else { ' ' };
            let method_or_tail = prev == '.' || prev.is_ascii_alphanumeric() || prev == '_';
            let mut j = i;
            let mut tok = String::new();
            while j < cs.len() && (cs[j].is_ascii_alphanumeric() || cs[j] == '_') {
                tok.push(cs[j]);
                j += 1;
            }
            if !method_or_tail && !EXCLUDE.contains(&tok.as_str()) {
                return true;
            }
            i = j;
        } else {
            i += 1;
        }
    }
    false
}

// ---------------------------------------------------------------- R4

/// One `Communicator` exchange family: offer/replay methods that must
/// precede its collect/fold methods within a single boundary body.
struct Family {
    name: &'static str,
    offers: &'static [&'static str],
    collects: &'static [&'static str],
}

const FAMILIES: &[Family] = &[
    Family { name: "reduce", offers: &["offer_reduce"], collects: &["all_reduce_mean"] },
    Family { name: "state", offers: &["offer_state"], collects: &["collect_state"] },
    Family {
        name: "fragment",
        offers: &["offer_fragment", "replay_fragment"],
        collects: &["collect_fragment"],
    },
    Family {
        name: "round",
        offers: &["offer_round", "replay_round"],
        collects: &["collect_round"],
    },
];

/// Functions that ARE the protocol (impls and replay/restore paths) —
/// exempt from the R4a intra-body ordering check.
const PROTOCOL_METHODS: &[&str] = &[
    "offer_reduce",
    "all_reduce_mean",
    "offer_state",
    "collect_state",
    "offer_fragment",
    "replay_fragment",
    "collect_fragment",
    "offer_round",
    "replay_round",
    "collect_round",
    "expire_stale",
    "poll_heartbeat",
    "send_heartbeat",
    "replay_heartbeat",
];

const SWEEP_METHOD: &str = "expire_stale";
const SWEEP_SITE: &str = "train/core.rs";
const HEARTBEAT_POLL: &str = "poll_heartbeat";
const BLOCKING: &[&str] = &[".recv(", ".recv_timeout(", "thread::sleep", ".wait(", ".wait_timeout("];

fn calls_on_line(code: &str, method: &str) -> bool {
    code.contains(&format!(".{method}("))
}

/// R4: `Communicator` protocol conformance — offer/replay before
/// collect/fold within one body (R4a), `expire_stale` only from the
/// boundary sweep in train/core.rs (R4b), heartbeat polls non-blocking
/// (R4c).
pub fn r4_protocol(rel: &str, lines: &[Line], fns: &[FnSpan], out: &mut Vec<Finding>) {
    if rel != SWEEP_SITE {
        for (ln, line) in lines.iter().enumerate() {
            if !line.is_test
                && calls_on_line(&line.code, SWEEP_METHOD)
                && !annotated(lines, ln, TAG_R4)
            {
                push(
                    out,
                    rel,
                    ln,
                    "R4",
                    format!(
                        "`.{SWEEP_METHOD}(…)` outside the {SWEEP_SITE} boundary sweep — \
                         stash expiry from a second site races the staleness window"
                    ),
                );
            }
        }
    }
    for span in fns {
        if lines[span.header].is_test {
            continue;
        }
        if span.name == HEARTBEAT_POLL {
            for ln in span.start..=span.end {
                if BLOCKING.iter().any(|b| lines[ln].code.contains(b))
                    && !annotated(lines, ln, TAG_R4)
                {
                    push(
                        out,
                        rel,
                        ln,
                        "R4",
                        "blocking call inside `fn poll_heartbeat` — heartbeat polls must \
                         stay non-blocking (use try_recv-style probes)"
                            .to_string(),
                    );
                }
            }
        }
        if PROTOCOL_METHODS.contains(&span.name.as_str())
            || span.name.starts_with("replay_")
            || span.name.starts_with("restore_")
        {
            continue;
        }
        for fam in FAMILIES {
            let first = |methods: &[&str]| -> Option<usize> {
                (span.start..=span.end).find(|&ln| {
                    !lines[ln].is_test
                        && methods.iter().any(|m| calls_on_line(&lines[ln].code, m))
                })
            };
            if let (Some(c), Some(o)) = (first(fam.collects), first(fam.offers)) {
                if c < o && !annotated(lines, c, TAG_R4) {
                    push(
                        out,
                        rel,
                        c,
                        "R4",
                        format!(
                            "{} collect/fold before its offer/replay inside `fn {}` — \
                             the two-phase protocol offers first within a boundary body",
                            fam.name, span.name
                        ),
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------- R5

const R5_FILES: &[&str] =
    &["train/strategy.rs", "train/streaming.rs", "train/boundary.rs", "train/comm.rs"];
const R5_REDUCERS: &[&str] = &[".sum()", ".sum::<", ".product()", ".product::<"];
/// `fold_noloco_fused` is the single fused Eq. 2–3 implementation (Δ
/// apply, φ mix, θ treatment in one fixed-order elementwise pass);
/// `fold_noloco_weighted` is its φ/δ-only wrapper. Every strategy fold
/// routes through these two.
const R5_APPROVED: &[&str] = &["fold_noloco_fused", "fold_noloco_weighted"];

/// R5: param-space reductions on the fold path go through the approved
/// fixed-association helpers — ad-hoc iterator sums re-associate and
/// break bit-identity across refactors.
pub fn r5_float_reduction(rel: &str, lines: &[Line], fns: &[FnSpan], out: &mut Vec<Finding>) {
    if !R5_FILES.contains(&rel) {
        return;
    }
    for (ln, line) in lines.iter().enumerate() {
        if line.is_test {
            continue;
        }
        if !R5_REDUCERS.iter().any(|r| line.code.contains(r)) {
            continue;
        }
        let approved =
            enclosing(fns, ln).is_some_and(|s| R5_APPROVED.contains(&s.name.as_str()));
        if !approved && !annotated(lines, ln, TAG_R5) {
            push(
                out,
                rel,
                ln,
                "R5",
                format!(
                    "iterator reduction on the fold path — route param-space sums through \
                     an approved helper ({}) or justify with `// analyze: {TAG_R5}`",
                    R5_APPROVED.join(", ")
                ),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::analyze::analyze_source;

    fn rules(rel: &str, src: &str) -> Vec<&'static str> {
        analyze_source(rel, src).into_iter().map(|f| f.rule).collect()
    }

    // -------------------------------------------------------- R1

    #[test]
    fn r1_trips_on_wall_clock_in_deterministic_path() {
        let bad = "fn step() {\n    let t = std::time::Instant::now();\n}\n";
        assert_eq!(rules("train/x.rs", bad), vec!["R1"]);
        let f = &analyze_source("train/x.rs", bad)[0];
        assert_eq!((f.line, f.rule), (2, "R1"));
    }

    #[test]
    fn r1_passes_allowlist_annotation_and_tests() {
        let bad = "fn step() {\n    let t = std::time::Instant::now();\n}\n";
        assert!(rules("obs/x.rs", bad).is_empty(), "obs/ is allowlisted");
        assert!(rules("net/fabric.rs", bad).is_empty(), "fabric is allowlisted");
        let annotated = "fn step() {\n    // analyze: wall-clock-ok — report envelope only\n    let t = std::time::Instant::now();\n}\n";
        assert!(rules("train/x.rs", annotated).is_empty());
        // The tag may open a multi-line justification block; the whole
        // contiguous comment block counts as immediately preceding.
        let block = "fn step() {\n    // analyze: wall-clock-ok — report envelope\n    // only; never feeds the trajectory.\n    let t = std::time::Instant::now();\n}\n";
        assert!(rules("train/x.rs", block).is_empty());
        // But a tag above intervening *code* does not leak downward.
        let detached = "fn step() {\n    // analyze: wall-clock-ok\n    let a = 1;\n    let t = std::time::Instant::now();\n}\n";
        assert_eq!(rules("train/x.rs", detached), vec!["R1"]);
        let test_only = "#[cfg(test)]\nmod tests {\n    fn t() { let t = std::time::Instant::now(); }\n}\n";
        assert!(rules("train/x.rs", test_only).is_empty());
        let in_string = "fn step() {\n    let s = \"Instant::now\";\n}\n";
        assert!(rules("train/x.rs", in_string).is_empty());
    }

    #[test]
    fn r1_trips_on_ambient_randomness() {
        let bad = "fn step() {\n    let r = rand::random::<u64>();\n}\n";
        assert_eq!(rules("net/x.rs", bad), vec!["R1"]);
        let ok = "fn step() {\n    let r = operand::random();\n}\n";
        assert!(rules("net/x.rs", ok).is_empty());
    }

    #[test]
    fn r1_thread_autodetect_is_perf_knob_only_in_pool() {
        // Machine-width detection is ambient state: denied on
        // deterministic paths, legitimate inside the exec pool (whose
        // ordering contract keeps thread count out of the trajectory).
        let bad = "fn plan() {\n    let n = std::thread::available_parallelism().map_or(1, |n| n.get());\n}\n";
        assert_eq!(rules("train/x.rs", bad), vec!["R1"]);
        assert!(rules("train/par.rs", bad).is_empty(), "the pool is allowlisted");
    }

    // -------------------------------------------------------- R2

    #[test]
    fn r2_trips_on_hashmap_iteration() {
        let bad = "fn sweep() {\n    let mut pending = std::collections::HashMap::new();\n    for (k, v) in &pending {\n    }\n    let n: usize = pending.values().count();\n}\n";
        assert_eq!(rules("train/x.rs", bad), vec!["R2", "R2"]);
    }

    #[test]
    fn r2_passes_btreemap_annotation_and_other_dirs() {
        let clean = "fn sweep() {\n    let mut pending = std::collections::BTreeMap::new();\n    for (k, v) in &pending {\n    }\n}\n";
        assert!(rules("train/x.rs", clean).is_empty());
        let annotated = "struct S { seen: HashSet<u32> }\nfn sweep(s: &S) {\n    // analyze: ordered-ok — membership count only, order never observed\n    let n = s.seen.iter().count();\n}\n";
        assert!(rules("train/x.rs", annotated).is_empty());
        let bad = "fn sweep() {\n    let mut pending = std::collections::HashMap::new();\n    for (k, v) in &pending {\n    }\n}\n";
        assert!(rules("obs/x.rs", bad).is_empty(), "R2 scopes to deterministic dirs");
    }

    #[test]
    fn r2_keyed_access_is_fine() {
        let keyed = "struct S { cache: HashMap<String, u32> }\nfn get(s: &S) -> Option<&u32> {\n    s.cache.get(\"k\")\n}\n";
        assert!(rules("train/x.rs", keyed).is_empty());
    }

    // -------------------------------------------------------- R3

    #[test]
    fn r3_trips_on_magic_seed() {
        let bad = "fn init() {\n    let rng = Pcg64::new(0xdead_beef, 0x5eed_5eed);\n}\n";
        assert_eq!(rules("train/x.rs", bad), vec!["R3"]);
    }

    #[test]
    fn r3_passes_derived_seeds_tests_and_rngx() {
        let derived = "fn init(seed: u64) {\n    let rng = Pcg64::new(seed as u128, 0x5eed);\n}\n";
        assert!(rules("train/x.rs", derived).is_empty());
        let multiline = "fn init(seed: u64, step: u64) {\n    let rng = Pcg64::new(\n        (seed as u128) << 64 | step as u128,\n        0x5eed_0000_0000 | step as u128,\n    );\n}\n";
        assert!(rules("routing/x.rs", multiline).is_empty());
        let bad = "fn init() {\n    let rng = Pcg64::new(0xdead_beef, 0x5eed);\n}\n";
        assert!(rules("rngx/x.rs", bad).is_empty(), "rngx/ is allowlisted");
        let test_only = "#[cfg(test)]\nmod tests {\n    fn t() { let rng = Pcg64::new(1, 2); }\n}\n";
        assert!(rules("train/x.rs", test_only).is_empty());
    }

    // -------------------------------------------------------- R4

    #[test]
    fn r4a_trips_on_collect_before_offer() {
        let bad = "fn boundary(&mut self) {\n    let got = self.comm.collect_round(0, 1, 0, 1, 0, false);\n    self.comm.offer_round(0, 0, 1, 1, 0, 2, d, p);\n}\n";
        assert_eq!(rules("train/x.rs", bad), vec!["R4"]);
    }

    #[test]
    fn r4a_passes_offer_first_and_replay_fns() {
        let good = "fn boundary(&mut self) {\n    self.comm.offer_round(0, 0, 1, 1, 0, 2, d, p);\n    let got = self.comm.collect_round(0, 1, 0, 1, 0, false);\n}\n";
        assert!(rules("train/x.rs", good).is_empty());
        let replay = "fn replay_pending(&mut self) {\n    let got = self.comm.collect_round(0, 1, 0, 1, 0, false);\n    self.comm.offer_round(0, 0, 1, 1, 0, 2, d, p);\n}\n";
        assert!(rules("train/x.rs", replay).is_empty(), "replay_* fns are exempt");
    }

    #[test]
    fn r4b_trips_on_stray_expire_stale() {
        let bad = "fn boundary(&mut self) {\n    self.comm.expire_stale(7);\n}\n";
        assert_eq!(rules("train/strategy.rs", bad), vec!["R4"]);
        assert!(rules("train/core.rs", bad).is_empty(), "the sweep site is exempt");
    }

    #[test]
    fn r4c_trips_on_blocking_heartbeat_poll() {
        let bad = "fn poll_heartbeat(&mut self) {\n    let m = self.rx.recv();\n}\n";
        assert_eq!(rules("net/x.rs", bad), vec!["R4"]);
        let good = "fn poll_heartbeat(&mut self) {\n    let m = self.ep.try_recv_ready();\n}\n";
        assert!(rules("net/x.rs", good).is_empty());
    }

    // -------------------------------------------------------- R5

    #[test]
    fn r5_trips_on_adhoc_fold_reduction() {
        let bad = "fn fold(&mut self, xs: &[f32]) -> f64 {\n    xs.iter().map(|x| *x as f64).sum::<f64>()\n}\n";
        assert_eq!(rules("train/comm.rs", bad), vec!["R5"]);
        assert!(rules("train/core.rs", bad).is_empty(), "R5 scopes to fold-path files");
    }

    #[test]
    fn r5_passes_approved_helper_and_annotation() {
        let approved = "fn fold_noloco_weighted(xs: &[f32]) -> f64 {\n    xs.iter().map(|x| *x as f64).sum::<f64>()\n}\n";
        assert!(rules("train/boundary.rs", approved).is_empty());
        let fused = "fn fold_noloco_fused(xs: &[f32]) -> f64 {\n    xs.iter().map(|x| *x as f64).sum::<f64>()\n}\n";
        assert!(rules("train/boundary.rs", fused).is_empty(), "the fused kernel is approved");
        let annotated = "fn count(&self) -> usize {\n    // analyze: float-ok — integer byte accounting, not param space\n    self.msgs.iter().map(|m| m.bytes).sum()\n}\n";
        assert!(rules("train/comm.rs", annotated).is_empty());
    }
}
