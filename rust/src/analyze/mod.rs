//! Dependency-free static analysis of the repo's own source tree.
//!
//! `noloco analyze` walks `rust/src/**` and enforces the determinism
//! invariants the reproduction's guarantees rest on (golden
//! bit-identical trajectories, sender-replay resume, drill
//! kill-restart equality):
//!
//! | rule | invariant |
//! |------|-----------|
//! | R1   | no wall-clock / ambient randomness on deterministic paths |
//! | R2   | no iteration over unordered maps in train/net/collective/routing |
//! | R3   | every RNG seeded from config or restored state, never a magic literal |
//! | R4   | two-phase `Communicator` discipline (offer before fold, single sweep site, non-blocking heartbeat polls) |
//! | R5   | fold-path float reductions through approved fixed-association helpers |
//!
//! Like `obs::journal`, this is deliberately a hand-rolled scanner
//! (no syn, no external crates): see [`scan`] for the lexer and
//! [`rules`] for the registry. Violations are suppressed line-by-line
//! with `// analyze: <tag>` justifications, never wholesale.

pub mod rules;
pub mod scan;

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Analyzer version, journaled with the verdict so traces self-describe
/// which rule set the build was checked against.
pub const VERSION: u32 = 1;

/// One rule violation at a source location.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Path relative to the analyzed source root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule ID (`R1`…`R5`).
    pub rule: &'static str,
    /// Human-readable diagnostic.
    pub msg: String,
}

/// Outcome of analyzing a source tree.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// Number of `.rs` files scanned.
    pub files: usize,
    /// All findings, ordered by (file, line, rule).
    pub findings: Vec<Finding>,
}

impl Report {
    /// True when no rule tripped.
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Run every rule over one file's source text. `rel` is the
/// `/`-separated path relative to the source root (it drives the
/// per-rule allowlists and directory scopes).
pub fn analyze_source(rel: &str, src: &str) -> Vec<Finding> {
    let lines = scan::scan(src);
    let fns = scan::functions(&lines);
    let mut out = Vec::new();
    rules::r1_wall_clock(rel, &lines, &mut out);
    rules::r2_unordered_iteration(rel, &lines, &mut out);
    rules::r3_magic_seed(rel, &lines, &mut out);
    rules::r4_protocol(rel, &lines, &fns, &mut out);
    rules::r5_float_reduction(rel, &lines, &fns, &mut out);
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Analyze every `.rs` file under `root` (deterministic sorted walk).
pub fn run_path(root: &Path) -> Result<Report> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)
        .with_context(|| format!("walking {}", root.display()))?;
    files.sort();
    let mut report = Report::default();
    for path in files {
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        report.files += 1;
        let mut findings = analyze_source(&rel, &src);
        report.findings.append(&mut findings);
    }
    report
        .findings
        .sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Locate the crate's own `src/` tree: `rust/src` (repo root), `src`
/// (crate dir), then the build-time manifest dir as a last resort.
pub fn default_root() -> Option<PathBuf> {
    for cand in ["rust/src", "src"] {
        let p = PathBuf::from(cand);
        if p.join("lib.rs").is_file() {
            return Some(p);
        }
    }
    let baked = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    if baked.join("lib.rs").is_file() {
        return Some(baked);
    }
    None
}

/// Analyze the crate's own tree, for journaling: `(findings, clean)`.
/// `None` when no source tree is reachable (installed binary running
/// outside the repo) — the journal then simply carries no verdict.
pub fn self_verdict() -> Option<(u64, bool)> {
    let root = default_root()?;
    let report = run_path(&root).ok()?;
    Some((report.findings.len() as u64, report.clean()))
}

/// Human-readable rendering: one `file:line: [rule] msg` per finding
/// plus a summary line.
pub fn render_text(report: &Report) -> String {
    let mut out = String::new();
    for f in &report.findings {
        out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.msg));
    }
    out.push_str(&format!(
        "analyze v{}: {} files, {} findings — {}\n",
        VERSION,
        report.files,
        report.findings.len(),
        if report.clean() { "clean" } else { "FAIL" }
    ));
    out
}

/// Machine-readable rendering: flat JSONL in the `obs::journal`
/// dialect (one header line, then one line per finding), parseable by
/// `obs::journal::parse_line`.
pub fn render_json(report: &Report) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{{\"v\":1,\"kind\":\"analyze\",\"version\":{},\"files\":{},\"findings\":{},\"clean\":{}}}\n",
        VERSION,
        report.files,
        report.findings.len(),
        report.clean()
    ));
    for f in &report.findings {
        out.push_str(&format!(
            "{{\"v\":1,\"kind\":\"finding\",\"file\":\"{}\",\"line\":{},\"rule\":\"{}\",\"msg\":\"{}\"}}\n",
            json_str(&f.file),
            f.line,
            f.rule,
            json_str(&f.msg)
        ));
    }
    out
}

/// The flat-JSON dialect has no escapes: strip the two characters that
/// would break framing.
fn json_str(s: &str) -> String {
    s.replace('"', "'").replace('\\', "/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_text_carries_location_and_rule() {
        let bad = "fn step() {\n    let t = std::time::Instant::now();\n}\n";
        let report = Report { files: 1, findings: analyze_source("train/x.rs", bad) };
        let text = render_text(&report);
        assert!(text.contains("train/x.rs:2: [R1]"), "{text}");
        assert!(text.contains("FAIL"));
    }

    #[test]
    fn render_json_is_flat_jsonl() {
        let bad = "fn step() {\n    let t = std::time::Instant::now();\n}\n";
        let report = Report { files: 1, findings: analyze_source("train/x.rs", bad) };
        let json = render_json(&report);
        let lines: Vec<&str> = json.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"kind\":\"analyze\""));
        assert!(lines[0].contains("\"clean\":false"));
        assert!(lines[1].contains("\"rule\":\"R1\""));
        assert!(lines[1].contains("\"line\":2"));
        assert!(!json.contains('\\'), "flat dialect must stay escape-free");
    }
}
