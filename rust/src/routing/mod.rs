//! Dynamic pipeline routing (§3.1).
//!
//! The model is split into `pp` consecutive stages, each replicated `dp`
//! times. NoLoCo routes every iteration's microbatches through a *fresh
//! random permutation* at each stage boundary: replica `i` of stage `s`
//! sends its activations to replica `perm_s[i]` of stage `s+1`. The
//! backward pass retraces the forward route. This samples the SWARM-style
//! message-queue routing under equal workers and uniform topology, which
//! the paper argues it is a good proxy for.
//!
//! A [`RoutePlan`] is computed by the leader (deterministically from the
//! step index and seed, so workers can recompute it independently without
//! a control message) and answers both directions:
//! forward `next_of(s, i)` and backward `prev_of(s+1, j)`.

use crate::config::Routing;
use crate::rngx::Pcg64;

/// The wiring of one training iteration across stage boundaries.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoutePlan {
    dp: usize,
    /// `perms[s][i]` = DP index at stage `s+1` receiving stage `s`,
    /// replica `i`'s output. `perms.len() == pp - 1`.
    perms: Vec<Vec<usize>>,
}

impl RoutePlan {
    /// Identity (fixed) routing: replica `i` always feeds replica `i`.
    pub fn fixed(dp: usize, pp: usize) -> RoutePlan {
        RoutePlan {
            dp,
            perms: vec![(0..dp).collect(); pp.saturating_sub(1)],
        }
    }

    /// Fresh random permutations at every boundary.
    pub fn random(dp: usize, pp: usize, rng: &mut Pcg64) -> RoutePlan {
        RoutePlan {
            dp,
            perms: (0..pp.saturating_sub(1)).map(|_| rng.permutation(dp)).collect(),
        }
    }

    /// Fresh random permutations restricted to a *live subset* of the DP
    /// replicas (elastic membership): at every boundary, live replicas are
    /// permuted among themselves; dead replicas map to themselves and are
    /// never on a live path. `live` must be strictly ascending (the order
    /// [`crate::net::Membership::live_nodes`] returns). When `live` covers
    /// all of `0..dp`, the draw is identical to [`RoutePlan::random`].
    pub fn random_over(live: &[usize], dp: usize, pp: usize, rng: &mut Pcg64) -> RoutePlan {
        debug_assert!(live.windows(2).all(|w| w[0] < w[1]), "live set must be ascending");
        debug_assert!(live.iter().all(|&r| r < dp), "live replica out of range");
        let perms = (0..pp.saturating_sub(1))
            .map(|_| {
                let sigma = rng.permutation(live.len());
                let mut p: Vec<usize> = (0..dp).collect();
                for (i, &src) in live.iter().enumerate() {
                    p[src] = live[sigma[i]];
                }
                p
            })
            .collect();
        RoutePlan { dp, perms }
    }

    /// Deterministic per-step plan: every worker can derive the same plan
    /// from `(seed, step)` with no coordination traffic.
    pub fn for_step(routing: Routing, dp: usize, pp: usize, seed: u64, step: u64) -> RoutePlan {
        match routing {
            Routing::Fixed => RoutePlan::fixed(dp, pp),
            Routing::Random => {
                let mut rng = Self::step_rng(seed, step);
                RoutePlan::random(dp, pp, &mut rng)
            }
        }
    }

    /// [`RoutePlan::for_step`] over a live subset: workers sharing
    /// `(seed, step)` *and* the membership schedule derive identical
    /// live-aware plans with no coordination traffic. With full
    /// membership this equals `for_step` draw-for-draw.
    pub fn for_step_over(
        routing: Routing,
        live: &[usize],
        dp: usize,
        pp: usize,
        seed: u64,
        step: u64,
    ) -> RoutePlan {
        match routing {
            Routing::Fixed => RoutePlan::fixed(dp, pp),
            Routing::Random => {
                let mut rng = Self::step_rng(seed, step);
                RoutePlan::random_over(live, dp, pp, &mut rng)
            }
        }
    }

    /// The per-step RNG both `for_step` variants share.
    fn step_rng(seed: u64, step: u64) -> Pcg64 {
        Pcg64::new(
            (seed as u128) << 64 | step as u128,
            0x5eed_0000_0000_0000u128 | step as u128,
        )
    }

    /// DP index at stage `stage+1` that consumes stage `stage`, replica
    /// `i`'s output.
    pub fn next_of(&self, stage: usize, i: usize) -> usize {
        self.perms[stage][i]
    }

    /// Inverse: DP index at stage `stage-1` that produced the input of
    /// stage `stage`, replica `j` — the backward-pass route.
    #[allow(clippy::expect_used)] // perms are permutations by construction
    pub fn prev_of(&self, stage: usize, j: usize) -> usize {
        self.perms[stage - 1]
            .iter()
            .position(|&x| x == j)
            .expect("permutation inverse")
    }

    /// DP width.
    pub fn dp(&self) -> usize {
        self.dp
    }

    /// Stage-boundary count (pp − 1).
    pub fn boundaries(&self) -> usize {
        self.perms.len()
    }

    /// Full path of the data that *starts* at stage 0, replica `i`:
    /// the DP index it visits at each stage.
    pub fn path_from(&self, i: usize) -> Vec<usize> {
        let mut path = Vec::with_capacity(self.perms.len() + 1);
        let mut cur = i;
        path.push(cur);
        for p in &self.perms {
            cur = p[cur];
            path.push(cur);
        }
        path
    }
}

/// How often each ordered replica pair `(i at s, j at s+1)` is wired
/// together over `steps` random plans — used by tests and the routing
/// ablation to verify load balance (each pair should be hit `steps / dp`
/// times in expectation, i.e. routing is doubly stochastic).
pub fn pair_histogram(dp: usize, pp: usize, seed: u64, steps: u64) -> Vec<Vec<u64>> {
    let mut hist = vec![vec![0u64; dp * dp]; pp.saturating_sub(1)];
    for step in 0..steps {
        let plan = RoutePlan::for_step(Routing::Random, dp, pp, seed, step);
        for s in 0..plan.boundaries() {
            for i in 0..dp {
                hist[s][i * dp + plan.next_of(s, i)] += 1;
            }
        }
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_plan_is_identity() {
        let p = RoutePlan::fixed(4, 3);
        for s in 0..2 {
            for i in 0..4 {
                assert_eq!(p.next_of(s, i), i);
                assert_eq!(p.prev_of(s + 1, i), i);
            }
        }
    }

    #[test]
    fn prev_inverts_next() {
        let mut rng = Pcg64::seed_from_u64(5);
        for _ in 0..20 {
            let p = RoutePlan::random(6, 4, &mut rng);
            for s in 0..p.boundaries() {
                for i in 0..6 {
                    assert_eq!(p.prev_of(s + 1, p.next_of(s, i)), i);
                }
            }
        }
    }

    #[test]
    fn for_step_is_deterministic_and_varies_by_step() {
        let a = RoutePlan::for_step(Routing::Random, 8, 4, 42, 7);
        let b = RoutePlan::for_step(Routing::Random, 8, 4, 42, 7);
        assert_eq!(a, b);
        let c = RoutePlan::for_step(Routing::Random, 8, 4, 42, 8);
        assert_ne!(a, c);
        let d = RoutePlan::for_step(Routing::Random, 8, 4, 43, 7);
        assert_ne!(a, d);
    }

    #[test]
    fn single_stage_has_no_boundaries() {
        let p = RoutePlan::for_step(Routing::Random, 4, 1, 0, 0);
        assert_eq!(p.boundaries(), 0);
        assert_eq!(p.path_from(2), vec![2]);
    }

    #[test]
    fn paths_cover_each_stage_once() {
        let p = RoutePlan::for_step(Routing::Random, 5, 4, 9, 3);
        // The 5 paths at each stage form a permutation (no replica is
        // used twice in the same stage) — this is the load-balancing
        // guarantee of permutation routing vs independent random choice.
        for s in 0..4 {
            let mut used: Vec<usize> = (0..5).map(|i| p.path_from(i)[s]).collect();
            used.sort_unstable();
            assert_eq!(used, vec![0, 1, 2, 3, 4]);
        }
    }

    #[test]
    fn histogram_is_doubly_stochastic_uniform() {
        let dp = 4;
        let steps = 8000;
        let hist = pair_histogram(dp, 2, 1, steps);
        let expect = steps as f64 / dp as f64;
        for c in &hist[0] {
            let c = *c as f64;
            assert!((c - expect).abs() / expect < 0.1, "count {c} vs {expect}");
        }
    }

    #[test]
    fn full_live_set_matches_plain_for_step() {
        let live: Vec<usize> = (0..6).collect();
        for step in 0..20u64 {
            let a = RoutePlan::for_step(Routing::Random, 6, 4, 11, step);
            let b = RoutePlan::for_step_over(Routing::Random, &live, 6, 4, 11, step);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn live_subset_plans_fix_dead_replicas() {
        let live = [0usize, 2, 5];
        let p = RoutePlan::for_step_over(Routing::Random, &live, 6, 3, 3, 9);
        for s in 0..p.boundaries() {
            for dead in [1usize, 3, 4] {
                assert_eq!(p.next_of(s, dead), dead);
            }
            // Live images are exactly the live set.
            let mut img: Vec<usize> = live.iter().map(|&i| p.next_of(s, i)).collect();
            img.sort_unstable();
            assert_eq!(img, live.to_vec());
        }
        // Paths from live origins never touch a dead replica.
        for &r0 in &live {
            for &hop in &p.path_from(r0) {
                assert!(live.contains(&hop), "path through dead replica {hop}");
            }
        }
    }

    #[test]
    fn property_live_routing_stays_bijective_under_churn() {
        // Satellite: RoutePlan permutations remain valid bijections over a
        // shrinking/growing live-replica set. Walk a random membership
        // trajectory (leave/join per step) and check every step's plan.
        crate::prop::run("live-set route plans are bijections", 150, |g| {
            let dp = g.usize_in(2, 12).max(2);
            let pp = g.usize_in(2, 5).max(2);
            let seed = g.rng().next_u64();
            let mut live: Vec<bool> = vec![true; dp];
            for step in 0..12u64 {
                // Random leave or join, keeping at least one live replica.
                let target = g.usize_in(0, dp - 1);
                if g.bool() {
                    live[target] = true;
                } else if live.iter().filter(|&&l| l).count() > 1 {
                    live[target] = false;
                }
                let live_idx: Vec<usize> =
                    (0..dp).filter(|&r| live[r]).collect();
                let p = RoutePlan::for_step_over(
                    Routing::Random, &live_idx, dp, pp, seed, step,
                );
                for s in 0..p.boundaries() {
                    // Bijection over the whole id space…
                    let mut all: Vec<usize> = (0..dp).map(|i| p.next_of(s, i)).collect();
                    all.sort_unstable();
                    assert_eq!(all, (0..dp).collect::<Vec<_>>());
                    // …that restricts to a bijection of the live set and
                    // the identity off it.
                    for r in 0..dp {
                        if live[r] {
                            assert!(live[p.next_of(s, r)], "live → dead route");
                            assert_eq!(p.prev_of(s + 1, p.next_of(s, r)), r);
                        } else {
                            assert_eq!(p.next_of(s, r), r, "dead replica rerouted");
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn property_routing_is_permutation() {
        crate::prop::run("route plans are stage-wise permutations", 200, |g| {
            let dp = g.usize_in(1, 12).max(1);
            let pp = g.usize_in(1, 6).max(1);
            let seed = g.rng().next_u64();
            let step = g.rng().next_u64();
            let p = RoutePlan::for_step(Routing::Random, dp, pp, seed, step);
            for s in 0..p.boundaries() {
                let mut tgt: Vec<usize> = (0..dp).map(|i| p.next_of(s, i)).collect();
                tgt.sort_unstable();
                assert_eq!(tgt, (0..dp).collect::<Vec<_>>());
            }
        });
    }
}
