//! Zipf-distributed sampler for synthetic corpora.
//!
//! Natural-language token frequencies are approximately Zipfian; the
//! synthetic Reddit-like / C4-like corpora in [`crate::data`] draw token
//! ids from `P(k) ∝ 1 / (k+1)^s` over a bounded vocabulary. We use the
//! inverse-CDF method with a precomputed cumulative table — O(log V) per
//! draw, exact (no rejection), deterministic given the RNG stream.

use super::Pcg64;

/// Bounded Zipf distribution over `0..n` with exponent `s`.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build the sampler. `n` is the support size (vocabulary), `s > 0`
    /// the Zipf exponent (≈1.0–1.3 for natural text).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0 && s > 0.0, "Zipf requires n > 0, s > 0");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / ((k + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Support size.
    pub fn n(&self) -> usize {
        self.cdf.len()
    }

    /// Draw one rank in `0..n` (0 = most frequent).
    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        // First index whose cdf >= u. `total_cmp` keeps the search
        // panic-free and totally ordered even if a weight is degenerate.
        match self.cdf.binary_search_by(|c| c.total_cmp(&u)) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_in_range() {
        let z = Zipf::new(100, 1.2);
        let mut r = Pcg64::seed_from_u64(1);
        for _ in 0..1000 {
            assert!(z.sample(&mut r) < 100);
        }
    }

    #[test]
    fn rank_frequencies_decay() {
        let z = Zipf::new(50, 1.1);
        let mut r = Pcg64::seed_from_u64(2);
        let mut counts = vec![0u32; 50];
        for _ in 0..100_000 {
            counts[z.sample(&mut r)] += 1;
        }
        // Monotone-ish decay: rank 0 >> rank 10 >> rank 40.
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[40]);
        // Rank-0 frequency matches the normalized weight within 5%.
        let h: f64 = (1..=50).map(|k| 1.0 / (k as f64).powf(1.1)).sum();
        let p0 = 1.0 / h;
        let f0 = counts[0] as f64 / 100_000.0;
        assert!((f0 - p0).abs() / p0 < 0.05, "f0={f0} p0={p0}");
    }
}
