//! PCG64: `pcg_xsl_rr_128_64` — 128-bit LCG state, 64-bit XSL-RR output.
//!
//! Reference: M. O'Neill, *PCG: A Family of Simple Fast Space-Efficient
//! Statistically Good Algorithms for Random Number Generation* (2014).

const MUL: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// 128-bit-state permuted congruential generator with 64-bit output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // stream selector; must be odd
}

impl Pcg64 {
    /// Construct from an explicit state / stream pair.
    pub fn new(state: u128, stream: u128) -> Self {
        let mut r = Pcg64 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        r.state = r.state.wrapping_mul(MUL).wrapping_add(r.inc);
        r.state = r.state.wrapping_add(state);
        r.state = r.state.wrapping_mul(MUL).wrapping_add(r.inc);
        r
    }

    /// Seed from a single `u64` (SplitMix64 expansion to fill 256 bits).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut s = seed;
        let mut next = || {
            s = s.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = s;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let state = ((next() as u128) << 64) | next() as u128;
        let stream = ((next() as u128) << 64) | next() as u128;
        Self::new(state, stream)
    }

    /// Advance and emit the next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
        let s = self.state;
        let xored = ((s >> 64) as u64) ^ (s as u64);
        let rot = (s >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Raw generator state `(state, inc)` — for checkpointing a stream
    /// mid-sequence. Pair with [`Pcg64::from_state_parts`].
    pub fn state_parts(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Pcg64::state_parts`] output. The next
    /// draw continues the original sequence exactly.
    pub fn from_state_parts(state: u128, inc: u128) -> Pcg64 {
        Pcg64 { state, inc }
    }

    /// Derive an independent child stream (for per-worker RNGs). The child
    /// gets a fresh state *and* a distinct stream increment, so parent and
    /// child sequences never correlate.
    pub fn split(&mut self) -> Pcg64 {
        let a = self.next_u64();
        let b = self.next_u64();
        let c = self.next_u64();
        let d = self.next_u64();
        Pcg64::new(((a as u128) << 64) | b as u128, ((c as u128) << 64) | d as u128)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_streams_from_same_state() {
        let mut a = Pcg64::new(12345, 1);
        let mut b = Pcg64::new(12345, 2);
        let matches = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(matches < 2);
    }

    #[test]
    fn output_is_not_constant() {
        let mut r = Pcg64::seed_from_u64(0);
        let xs: Vec<u64> = (0..16).map(|_| r.next_u64()).collect();
        assert!(xs.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn state_parts_round_trip_mid_sequence() {
        let mut a = Pcg64::seed_from_u64(42);
        for _ in 0..17 {
            a.next_u64();
        }
        let (state, inc) = a.state_parts();
        let mut b = Pcg64::from_state_parts(state, inc);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn bit_balance() {
        // Each of the 64 output bits should be ~50% ones.
        let mut r = Pcg64::seed_from_u64(99);
        let n = 4096;
        let mut ones = [0u32; 64];
        for _ in 0..n {
            let x = r.next_u64();
            for (b, o) in ones.iter_mut().enumerate() {
                *o += ((x >> b) & 1) as u32;
            }
        }
        for (b, &o) in ones.iter().enumerate() {
            let f = o as f64 / n as f64;
            assert!((f - 0.5).abs() < 0.05, "bit {b} frequency {f}");
        }
    }
}
