//! Deterministic pseudo-random substrate.
//!
//! The image has no `rand` crate offline, so we carry our own: a PCG64
//! generator (O'Neill 2014, `pcg_xsl_rr_128_64` variant) plus the
//! distributions the paper's experiments need — uniform, standard normal
//! (Box–Muller), log-normal (Fig. 5 latency model), Zipf (synthetic
//! corpora) — and Fisher–Yates permutations (random pipeline routing,
//! gossip pair sampling).
//!
//! Everything is deterministic given a seed so experiments are exactly
//! reproducible; parallel workers derive independent streams with
//! [`Pcg64::split`].

mod pcg;
mod zipf;

pub use pcg::Pcg64;
pub use zipf::Zipf;

impl Pcg64 {
    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 mantissa bits of the next u64.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift with
    /// rejection (unbiased).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal draw via Box–Muller (single value; the second is
    /// discarded for simplicity — this is not a throughput hot path).
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.next_normal()
    }

    /// Log-normal draw: `exp(N(mu, sigma^2))`. This is the paper's message
    /// latency model (§5.3): `t ~ LogNormal(mu, sigma^2)` with expected
    /// value `exp(mu + sigma^2/2)`.
    #[inline]
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Partition `0..n` into disjoint pairs uniformly at random. When `n`
    /// is odd the leftover index is returned in the second slot of the
    /// final "pair" as `None`. This is the gossip-group sampler for the
    /// NoLoCo outer step with group size n = 2 (§3.2).
    pub fn random_pairs(&mut self, n: usize) -> Vec<(usize, Option<usize>)> {
        let p = self.permutation(n);
        let mut out = Vec::with_capacity(n.div_ceil(2));
        let mut it = p.chunks(2);
        for c in &mut it {
            if c.len() == 2 {
                out.push((c[0], Some(c[1])));
            } else {
                out.push((c[0], None));
            }
        }
        out
    }

    /// Partition `0..n` into disjoint groups of `size` uniformly at
    /// random — the general-n gossip-group sampler of §3.2 (the paper's
    /// experiments use the minimum, `size` = 2 = [`Pcg64::random_pairs`]).
    /// The final group holds the `n % size` leftovers when `size ∤ n`.
    pub fn random_groups(&mut self, n: usize, size: usize) -> Vec<Vec<usize>> {
        assert!(size >= 1);
        let p = self.permutation(n);
        p.chunks(size).map(|c| c.to_vec()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::seed_from_u64(7);
        let mut b = Pcg64::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut a = Pcg64::seed_from_u64(42);
        let mut c = a.split();
        let xs: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..32).map(|_| c.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Pcg64::seed_from_u64(3);
        let n = 20_000;
        let s: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = s / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut r = Pcg64::seed_from_u64(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed_from_u64(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn log_normal_expected_value_matches_formula() {
        // E[LogNormal(mu, sigma^2)] = exp(mu + sigma^2 / 2) — the paper's
        // t_c in §5.3.
        let (mu, sigma) = (0.3, 0.8);
        let mut r = Pcg64::seed_from_u64(6);
        let n = 200_000;
        let s: f64 = (0..n).map(|_| r.log_normal(mu, sigma)).sum();
        let mean = s / n as f64;
        let expect = (mu + sigma * sigma / 2.0f64).exp();
        assert!(
            (mean - expect).abs() / expect < 0.03,
            "mean={mean} expect={expect}"
        );
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Pcg64::seed_from_u64(8);
        for n in [1usize, 2, 3, 17, 64] {
            let mut p = r.permutation(n);
            p.sort_unstable();
            assert_eq!(p, (0..n).collect::<Vec<_>>());
        }
    }

    #[test]
    fn random_pairs_partition_everyone() {
        let mut r = Pcg64::seed_from_u64(9);
        for n in [2usize, 4, 5, 16, 33] {
            let pairs = r.random_pairs(n);
            let mut seen: Vec<usize> = pairs
                .iter()
                .flat_map(|(a, b)| std::iter::once(*a).chain(b.iter().copied()))
                .collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..n).collect::<Vec<_>>());
            let lonely = pairs.iter().filter(|(_, b)| b.is_none()).count();
            assert_eq!(lonely, n % 2);
        }
    }

    #[test]
    fn random_pairs_are_uniformish() {
        // Every ordered pair (i, j) should be matched with roughly equal
        // frequency across many draws.
        let mut r = Pcg64::seed_from_u64(10);
        let n = 4;
        let mut counts = vec![0u32; n * n];
        let trials = 6000;
        for _ in 0..trials {
            for (a, b) in r.random_pairs(n) {
                let b = b.unwrap();
                counts[a * n + b] += 1;
                counts[b * n + a] += 1;
            }
        }
        // 4 workers -> 3 possible partners each; each worker matched every
        // trial, so each cell expects trials/3.
        let expect = trials as f64 / 3.0;
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    assert_eq!(counts[i * n + j], 0);
                } else {
                    let c = counts[i * n + j] as f64;
                    assert!(
                        (c - expect).abs() / expect < 0.15,
                        "pair ({i},{j}) count {c} vs {expect}"
                    );
                }
            }
        }
    }
}
