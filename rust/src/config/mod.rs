//! Typed configuration system.
//!
//! Configs are plain structs assembled from named presets
//! ([`presets`]) and/or TOML files parsed by the in-repo [`toml`] parser,
//! with `--set path=value` CLI overrides on top. Paper Table 1 presets are
//! kept verbatim (`paper-small` / `paper-medium` / `paper-large`) next to
//! the CPU-scaled presets actually trained on this image (`tiny`, `small`,
//! `e2e`).

pub mod presets;
pub mod toml;

use self::toml::Doc;
use std::fmt;

/// Which training method drives the outer loop (§2, §3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Fully synchronous data parallel: gradients all-reduced every step.
    Fsdp,
    /// DiLoCo: inner steps + Nesterov outer step over an all-reduce.
    DiLoCo,
    /// NoLoCo: inner steps + gossip-pair outer step with the modified
    /// Nesterov momentum of Eq. 2 — no collective communication.
    NoLoCo,
}

impl Method {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "fsdp" | "ddp" => Some(Method::Fsdp),
            "diloco" => Some(Method::DiLoCo),
            "noloco" => Some(Method::NoLoCo),
            _ => None,
        }
    }
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Method::Fsdp => write!(f, "FSDP"),
            Method::DiLoCo => write!(f, "DiLoCo"),
            Method::NoLoCo => write!(f, "NoLoCo"),
        }
    }
}

/// How pipeline stage replicas are wired each iteration (§3.1, §5.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Routing {
    /// Fresh random permutation between consecutive stages per iteration.
    Random,
    /// Replica i always talks to replica i of the neighbour stage.
    Fixed,
}

impl Routing {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<Routing> {
        match s.to_ascii_lowercase().as_str() {
            "random" => Some(Routing::Random),
            "fixed" => Some(Routing::Fixed),
            _ => None,
        }
    }
}

/// Transformer architecture + inner-optimizer hyper-parameters (Table 1).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Preset name, used to locate compiled artifacts.
    pub name: String,
    /// Residual stream width.
    pub hidden: usize,
    /// Decoder layer count (total, split across pipeline stages).
    pub layers: usize,
    /// MLP intermediate width.
    pub intermediate: usize,
    /// Attention head count.
    pub heads: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Sequence length in tokens.
    pub seq_len: usize,
    /// Peak inner (Adam) learning rate.
    pub inner_lr: f64,
    /// Global batch size in tokens.
    pub batch_tokens: usize,
}

impl ModelConfig {
    /// Head dimension.
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Sequences per global batch.
    pub fn batch_seqs(&self) -> usize {
        (self.batch_tokens / self.seq_len).max(1)
    }

    /// Approximate transformer parameter count (excluding embeddings) for
    /// *this repo's* SwiGLU architecture. The paper's Table 1 labels
    /// (125M/1.3B/6.8B) follow OPT naming for the same
    /// hidden/layer/intermediate settings; this formula lands in the same
    /// band (see `paper_param_counts_are_in_band`).
    pub fn transformer_params(&self) -> usize {
        // Attention: 4 * h^2. SwiGLU MLP: 3 * h * i. Norms: 2h per layer.
        let per_layer =
            4 * self.hidden * self.hidden + 3 * self.hidden * self.intermediate + 2 * self.hidden;
        self.layers * per_layer + self.hidden // final norm
    }

    /// Total parameter count including embedding and LM head.
    pub fn total_params(&self) -> usize {
        self.transformer_params() + 2 * self.vocab * self.hidden
    }
}

/// DP × PP worker grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TopologyConfig {
    /// Data-parallel world size (replicas per stage).
    pub dp: usize,
    /// Pipeline stage count.
    pub pp: usize,
}

impl TopologyConfig {
    /// Total accelerator count ("Total" column of Table 2).
    pub fn world(&self) -> usize {
        self.dp * self.pp
    }
}

/// Outer-optimizer hyper-parameters (§3.2, §4).
#[derive(Clone, Debug, PartialEq)]
pub struct OuterConfig {
    /// Training method.
    pub method: Method,
    /// Nesterov momentum α (paper: 0.3 DiLoCo, 0.5 NoLoCo).
    pub alpha: f64,
    /// Outer learning rate β (paper: 0.7 for both).
    pub beta: f64,
    /// NoLoCo weight-consensus coefficient γ (Eq. 2). Must satisfy the
    /// Eq. 74 stability window; see [`OuterConfig::gamma_window`].
    pub gamma: f64,
    /// Gossip group size n (paper uses the minimum, 2).
    pub group: usize,
    /// Inner steps per outer step m (paper: 100 DiLoCo, 50 NoLoCo).
    pub inner_steps: usize,
}

impl OuterConfig {
    /// The (exclusive) stability window for γ from Eq. 74:
    /// `sqrt(n/(2(n-1))) α < γ < sqrt(n/(2(n-1)) (2+α²))`.
    pub fn gamma_window(alpha: f64, group: usize) -> (f64, f64) {
        let n = group as f64;
        let c = n / (2.0 * (n - 1.0));
        (c.sqrt() * alpha, (c * (2.0 + alpha * alpha)).sqrt())
    }

    /// Midpoint of the γ window — a safe default when unspecified.
    pub fn default_gamma(alpha: f64, group: usize) -> f64 {
        let (lo, hi) = Self::gamma_window(alpha, group);
        0.5 * (lo + hi)
    }

    /// Validate hyper-parameters; returns a human-readable complaint.
    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..1.0).contains(&self.alpha) {
            return Err(format!("alpha must be in [0,1), got {}", self.alpha));
        }
        if self.beta <= self.alpha {
            return Err(format!(
                "convergence requires beta > alpha (App. A.2), got beta={} alpha={}",
                self.beta, self.alpha
            ));
        }
        if self.method == Method::NoLoCo {
            if self.group < 2 {
                return Err("NoLoCo group size must be >= 2".into());
            }
            let (lo, hi) = Self::gamma_window(self.alpha, self.group);
            if self.gamma <= lo || self.gamma >= hi {
                return Err(format!(
                    "gamma={} outside Eq. 74 stability window ({lo:.4}, {hi:.4})",
                    self.gamma
                ));
            }
        }
        if self.inner_steps == 0 {
            return Err("inner_steps must be >= 1".into());
        }
        Ok(())
    }
}

/// Synthetic corpus flavour (dataset substitution; see DESIGN.md §4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataset {
    /// Narrow-topic Zipf stream standing in for Pushshift Reddit.
    RedditLike,
    /// Broader mixture-of-topics stream standing in for C4.
    C4Like,
}

impl Dataset {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<Dataset> {
        match s.to_ascii_lowercase().as_str() {
            "reddit" | "reddit-like" | "pushshift" => Some(Dataset::RedditLike),
            "c4" | "c4-like" => Some(Dataset::C4Like),
            _ => None,
        }
    }
}

impl fmt::Display for Dataset {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Dataset::RedditLike => write!(f, "reddit"),
            Dataset::C4Like => write!(f, "c4"),
        }
    }
}

/// Top-level run configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub model: ModelConfig,
    pub topology: TopologyConfig,
    pub outer: OuterConfig,
    pub dataset: Dataset,
    /// Total inner optimizer steps.
    pub steps: usize,
    /// Linear LR warm-up steps.
    pub warmup: usize,
    /// Cosine decay floor as a fraction of peak LR (paper: one magnitude,
    /// i.e. 0.1).
    pub lr_floor: f64,
    /// Gradient clip threshold (paper: 1.0).
    pub grad_clip: f64,
    /// Validation cadence in inner steps (0 = only at end).
    pub eval_every: usize,
    /// Tokens per validation pass.
    pub eval_tokens: usize,
    /// Base RNG seed.
    pub seed: u64,
    /// Pipeline routing flavour.
    pub routing: Routing,
    /// Directory holding compiled HLO artifacts.
    pub artifacts_dir: String,
}

impl TrainConfig {
    /// Apply a parsed TOML document on top of this config. Unknown keys
    /// are an error — typos in experiment configs must not pass silently.
    pub fn apply_doc(&mut self, doc: &Doc) -> Result<(), String> {
        for (k, v) in doc.iter() {
            let ok = match k.as_str() {
                "model.hidden" => set_usize(&mut self.model.hidden, v),
                "model.layers" => set_usize(&mut self.model.layers, v),
                "model.intermediate" => set_usize(&mut self.model.intermediate, v),
                "model.heads" => set_usize(&mut self.model.heads, v),
                "model.vocab" => set_usize(&mut self.model.vocab, v),
                "model.seq_len" => set_usize(&mut self.model.seq_len, v),
                "model.inner_lr" => set_f64(&mut self.model.inner_lr, v),
                "model.batch_tokens" => set_usize(&mut self.model.batch_tokens, v),
                "model.name" => set_string(&mut self.model.name, v),
                "topology.dp" => set_usize(&mut self.topology.dp, v),
                "topology.pp" => set_usize(&mut self.topology.pp, v),
                "outer.method" => match v.as_str().and_then(Method::parse) {
                    Some(m) => {
                        self.outer.method = m;
                        true
                    }
                    None => false,
                },
                "outer.alpha" => set_f64(&mut self.outer.alpha, v),
                "outer.beta" => set_f64(&mut self.outer.beta, v),
                "outer.gamma" => set_f64(&mut self.outer.gamma, v),
                "outer.group" => set_usize(&mut self.outer.group, v),
                "outer.inner_steps" => set_usize(&mut self.outer.inner_steps, v),
                "train.steps" => set_usize(&mut self.steps, v),
                "train.warmup" => set_usize(&mut self.warmup, v),
                "train.lr_floor" => set_f64(&mut self.lr_floor, v),
                "train.grad_clip" => set_f64(&mut self.grad_clip, v),
                "train.eval_every" => set_usize(&mut self.eval_every, v),
                "train.eval_tokens" => set_usize(&mut self.eval_tokens, v),
                "train.seed" => match v.as_int() {
                    Some(i) => {
                        self.seed = i as u64;
                        true
                    }
                    None => false,
                },
                "train.dataset" => match v.as_str().and_then(Dataset::parse) {
                    Some(d) => {
                        self.dataset = d;
                        true
                    }
                    None => false,
                },
                "train.routing" => match v.as_str().and_then(Routing::parse) {
                    Some(r) => {
                        self.routing = r;
                        true
                    }
                    None => false,
                },
                "train.artifacts_dir" => set_string(&mut self.artifacts_dir, v),
                _ => return Err(format!("unknown config key `{k}`")),
            };
            if !ok {
                return Err(format!("bad value for `{k}`: {v:?}"));
            }
        }
        Ok(())
    }

    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<(), String> {
        self.outer.validate()?;
        if self.model.hidden % self.model.heads != 0 {
            return Err("hidden must be divisible by heads".into());
        }
        if self.model.layers % self.topology.pp != 0 {
            return Err(format!(
                "layers ({}) must divide evenly into pp ({}) stages",
                self.model.layers, self.topology.pp
            ));
        }
        if self.topology.dp == 0 || self.topology.pp == 0 {
            return Err("dp and pp must be >= 1".into());
        }
        if self.outer.method == Method::NoLoCo && self.topology.dp < 2 {
            return Err("NoLoCo needs dp >= 2 to form gossip pairs".into());
        }
        Ok(())
    }
}

fn set_usize(slot: &mut usize, v: &toml::Value) -> bool {
    match v.as_int() {
        Some(i) if i >= 0 => {
            *slot = i as usize;
            true
        }
        _ => false,
    }
}

fn set_f64(slot: &mut f64, v: &toml::Value) -> bool {
    match v.as_float() {
        Some(f) => {
            *slot = f;
            true
        }
        None => false,
    }
}

fn set_string(slot: &mut String, v: &toml::Value) -> bool {
    match v.as_str() {
        Some(s) => {
            *slot = s.to_string();
            true
        }
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gamma_window_matches_eq74_for_n2() {
        // n=2: sqrt(1) * alpha < gamma < sqrt(2 + alpha^2).
        let (lo, hi) = OuterConfig::gamma_window(0.5, 2);
        assert!((lo - 0.5).abs() < 1e-12);
        assert!((hi - (2.0f64 + 0.25).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn validate_rejects_beta_leq_alpha() {
        let mut o = presets::preset("tiny").unwrap().outer;
        o.beta = o.alpha;
        assert!(o.validate().is_err());
    }

    #[test]
    fn validate_rejects_gamma_outside_window() {
        let mut o = presets::preset("tiny").unwrap().outer;
        o.method = Method::NoLoCo;
        o.gamma = 0.0;
        assert!(o.validate().is_err());
        o.gamma = 10.0;
        assert!(o.validate().is_err());
        o.gamma = OuterConfig::default_gamma(o.alpha, o.group);
        assert!(o.validate().is_ok());
    }

    #[test]
    fn apply_doc_overrides_and_rejects_unknown() {
        let mut c = presets::preset("tiny").unwrap();
        let doc = Doc::parse("[model]\nhidden = 128\n[outer]\nmethod = \"diloco\"\n").unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.model.hidden, 128);
        assert_eq!(c.outer.method, Method::DiLoCo);
        let bad = Doc::parse("[model]\nhiden = 128\n").unwrap();
        assert!(c.apply_doc(&bad).unwrap_err().contains("unknown config key"));
    }

    #[test]
    fn method_and_dataset_parse() {
        assert_eq!(Method::parse("NoLoCo"), Some(Method::NoLoCo));
        assert_eq!(Method::parse("fsdp"), Some(Method::Fsdp));
        assert_eq!(Method::parse("bogus"), None);
        assert_eq!(Dataset::parse("c4"), Some(Dataset::C4Like));
        assert_eq!(Dataset::parse("reddit"), Some(Dataset::RedditLike));
    }

    #[test]
    fn validate_layer_stage_divisibility() {
        let mut c = presets::preset("tiny").unwrap();
        c.topology.pp = 3; // tiny has 4 layers
        assert!(c.validate().is_err());
        c.topology.pp = 2;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn paper_param_counts_are_in_band() {
        // Table 1: 125M / 1.3B / 6.8B transformer parameters.
        let s = presets::preset("paper-small").unwrap().model;
        let m = presets::preset("paper-medium").unwrap().model;
        let l = presets::preset("paper-large").unwrap().model;
        // Table-1 labels are OPT-nominal; our SwiGLU MLP counts land in
        // the same band rather than matching exactly.
        let band = |got: usize, lo: f64, hi: f64| {
            let g = got as f64;
            g >= lo && g <= hi
        };
        assert!(band(s.transformer_params(), 90e6, 160e6), "{}", s.transformer_params());
        assert!(band(m.transformer_params(), 1.0e9, 1.8e9), "{}", m.transformer_params());
        assert!(band(l.transformer_params(), 5.4e9, 9.5e9), "{}", l.transformer_params());
    }
}
