//! Typed configuration system.
//!
//! Configs are plain structs assembled from named presets
//! ([`presets`]) and/or TOML files parsed by the in-repo [`toml`] parser,
//! with `--set path=value` CLI overrides on top. Paper Table 1 presets are
//! kept verbatim (`paper-small` / `paper-medium` / `paper-large`) next to
//! the CPU-scaled presets actually trained on this image (`tiny`, `small`,
//! `e2e`).

pub mod presets;
pub mod toml;

use self::toml::Doc;
use crate::net::topo::{ChurnSchedule, Link, Topology};
use crate::net::LatencyModel;
use std::fmt;

/// Which training method drives the outer loop (§2, §3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Fully synchronous data parallel: gradients all-reduced every step.
    Fsdp,
    /// DiLoCo: inner steps + Nesterov outer step over an all-reduce.
    DiLoCo,
    /// NoLoCo: inner steps + gossip-pair outer step with the modified
    /// Nesterov momentum of Eq. 2 — no collective communication.
    NoLoCo,
}

impl Method {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "fsdp" | "ddp" => Some(Method::Fsdp),
            "diloco" => Some(Method::DiLoCo),
            "noloco" => Some(Method::NoLoCo),
            _ => None,
        }
    }
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Method::Fsdp => write!(f, "FSDP"),
            Method::DiLoCo => write!(f, "DiLoCo"),
            Method::NoLoCo => write!(f, "NoLoCo"),
        }
    }
}

/// How NoLoCo's gossip groups are drawn each outer step (the
/// [`PairingPolicy`](crate::train::PairingPolicy) selector).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PairingMode {
    /// Uniform random disjoint groups over the live set (§3.2, the seed
    /// behaviour).
    Uniform,
    /// Bias pairs toward cheap intra-region links on the configured
    /// network topology, with periodic uniform rounds to keep the gossip
    /// graph mixing across regions.
    BandwidthAware,
    /// Draw a *different* uniform partition per fragment, so each
    /// fragment of the (Δ, φ) state gossips with its own partner — the
    /// multi-partner form used by the bounded-staleness async engine
    /// (and by streamed runs, where each fragment's partner sequence
    /// decorrelates from its siblings'). Mixes K× faster per round at
    /// the same total payload.
    PerFragment,
}

impl PairingMode {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<PairingMode> {
        match s.to_ascii_lowercase().as_str() {
            "uniform" | "random" => Some(PairingMode::Uniform),
            "bandwidth-aware" | "bandwidth" | "bw" => Some(PairingMode::BandwidthAware),
            "per-fragment" | "per-frag" | "fragment" => Some(PairingMode::PerFragment),
            _ => None,
        }
    }
}

impl fmt::Display for PairingMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PairingMode::Uniform => write!(f, "uniform"),
            PairingMode::BandwidthAware => write!(f, "bandwidth-aware"),
            PairingMode::PerFragment => write!(f, "per-fragment"),
        }
    }
}

/// When the outer synchronization's payload crosses the network relative
/// to the inner phases — the scheduling selector for the
/// [`SyncStrategy`](crate::train::SyncStrategy) built by
/// [`crate::train::strategy_for_config`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncMode {
    /// The seed behaviour: the full (Δ, φ) exchange (or outer all-reduce)
    /// gates the boundary between inner phases.
    Gated,
    /// Streaming fragmented sync (Streaming-DiLoCo-style overlap): the
    /// outer state splits into [`StreamConfig::fragments`] chunks on a
    /// round-robin schedule, each offered at one boundary and folded at
    /// the next so the exchange hides behind the intervening inner phase.
    Streaming,
}

impl SyncMode {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<SyncMode> {
        match s.to_ascii_lowercase().as_str() {
            "gated" | "blocking" => Some(SyncMode::Gated),
            "streaming" | "stream" => Some(SyncMode::Streaming),
            _ => None,
        }
    }
}

impl fmt::Display for SyncMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SyncMode::Gated => write!(f, "gated"),
            SyncMode::Streaming => write!(f, "streaming"),
        }
    }
}

/// Shape of the streamed outer sync (`--sync streaming`): how many
/// fragments the (Δ, φ) state splits into and whether each fragment's
/// exchange overlaps the next inner phase. TOML keys `outer.fragments` /
/// `outer.overlap`; ignored under [`SyncMode::Gated`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamConfig {
    /// Fragment count K (1..=256; K = 1 streams the whole state at once).
    pub fragments: usize,
    /// Fold each fragment one boundary *after* its offer (hiding the
    /// transfer behind the inner phase) instead of at the same boundary.
    /// `fragments = 1` with overlap off reproduces the gated trajectory
    /// bit-for-bit.
    pub overlap: bool,
    /// Stash-expiry age in outer boundaries (`outer.stash_age` /
    /// `--stash-age`): sync payloads never collected — churn-dropped
    /// folds, straggler timeouts, suppressed receivers — are swept from
    /// the communicator's retention buffers / endpoint stash once they
    /// are this many boundaries old. `0` disables the sweep (the
    /// pre-expiry behaviour: uncollected messages sit for the rest of
    /// the run). Must cover `outer.staleness` so admissible rounds are
    /// never swept.
    pub stash_age: usize,
}

impl Default for StreamConfig {
    fn default() -> StreamConfig {
        StreamConfig { fragments: 4, overlap: true, stash_age: 4 }
    }
}

/// How pipeline stage replicas are wired each iteration (§3.1, §5.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Routing {
    /// Fresh random permutation between consecutive stages per iteration.
    Random,
    /// Replica i always talks to replica i of the neighbour stage.
    Fixed,
}

impl Routing {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<Routing> {
        match s.to_ascii_lowercase().as_str() {
            "random" => Some(Routing::Random),
            "fixed" => Some(Routing::Fixed),
            _ => None,
        }
    }
}

/// Transformer architecture + inner-optimizer hyper-parameters (Table 1).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Preset name, used to locate compiled artifacts.
    pub name: String,
    /// Residual stream width.
    pub hidden: usize,
    /// Decoder layer count (total, split across pipeline stages).
    pub layers: usize,
    /// MLP intermediate width.
    pub intermediate: usize,
    /// Attention head count.
    pub heads: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Sequence length in tokens.
    pub seq_len: usize,
    /// Peak inner (Adam) learning rate.
    pub inner_lr: f64,
    /// Global batch size in tokens.
    pub batch_tokens: usize,
}

impl ModelConfig {
    /// Head dimension.
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Sequences per global batch.
    pub fn batch_seqs(&self) -> usize {
        (self.batch_tokens / self.seq_len).max(1)
    }

    /// Approximate transformer parameter count (excluding embeddings) for
    /// *this repo's* SwiGLU architecture. The paper's Table 1 labels
    /// (125M/1.3B/6.8B) follow OPT naming for the same
    /// hidden/layer/intermediate settings; this formula lands in the same
    /// band (see `paper_param_counts_are_in_band`).
    pub fn transformer_params(&self) -> usize {
        // Attention: 4 * h^2. SwiGLU MLP: 3 * h * i. Norms: 2h per layer.
        let per_layer =
            4 * self.hidden * self.hidden + 3 * self.hidden * self.intermediate + 2 * self.hidden;
        self.layers * per_layer + self.hidden // final norm
    }

    /// Total parameter count including embedding and LM head.
    pub fn total_params(&self) -> usize {
        self.transformer_params() + 2 * self.vocab * self.hidden
    }
}

/// DP × PP worker grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TopologyConfig {
    /// Data-parallel world size (replicas per stage).
    pub dp: usize,
    /// Pipeline stage count.
    pub pp: usize,
}

impl TopologyConfig {
    /// Total accelerator count ("Total" column of Table 2).
    pub fn world(&self) -> usize {
        self.dp * self.pp
    }
}

/// Named shapes for the simulated network (§5.3 scenario families).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetPreset {
    /// One region, constant sub-ms links: the datacenter baseline.
    SingleSwitchLan,
    /// Several regions, fast log-normal links inside a region and slow
    /// high-variance links between them.
    MultiRegionWan,
    /// One flat "region" of consumer links: heavy-tailed latency, low
    /// bandwidth, per-node straggler multipliers.
    LongTailInternet,
    /// Hierarchical datacenter: rack / pod / spine tiers with per-tier
    /// latency and bandwidth (nodes in racks, racks in pods, pods joined
    /// by the spine). Each deeper tier is slower and narrower.
    HierarchicalDc,
}

impl NetPreset {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<NetPreset> {
        match s.to_ascii_lowercase().as_str() {
            "lan" | "single-switch" => Some(NetPreset::SingleSwitchLan),
            "wan" | "multi-region" => Some(NetPreset::MultiRegionWan),
            "long-tail" | "internet" => Some(NetPreset::LongTailInternet),
            "hier" | "hierarchical" | "datacenter" => Some(NetPreset::HierarchicalDc),
            _ => None,
        }
    }
}

impl fmt::Display for NetPreset {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NetPreset::SingleSwitchLan => write!(f, "lan"),
            NetPreset::MultiRegionWan => write!(f, "wan"),
            NetPreset::LongTailInternet => write!(f, "long-tail"),
            NetPreset::HierarchicalDc => write!(f, "hier"),
        }
    }
}

/// Simulated-network shape: which preset, and its knobs. Latencies are
/// seconds (medians for the log-normal presets), bandwidths bytes/s.
/// Lives in the `[topology]` TOML section next to `dp`/`pp`.
#[derive(Clone, Debug, PartialEq)]
pub struct NetTopoConfig {
    /// Scenario family.
    pub preset: NetPreset,
    /// Region count for the WAN preset (clamped to the world size); the
    /// *pod* count for the hierarchical preset.
    pub regions: usize,
    /// Intra-region link latency (s); the rack-tier latency for `hier`.
    pub intra_latency: f64,
    /// Inter-region link latency (s); also the long-tail median latency
    /// and the spine-tier latency for `hier`.
    pub inter_latency: f64,
    /// Intra-region bandwidth (bytes/s); the rack-tier bandwidth for
    /// `hier`.
    pub intra_bandwidth: f64,
    /// Inter-region bandwidth (bytes/s); also the long-tail bandwidth
    /// and the spine-tier bandwidth for `hier`.
    pub inter_bandwidth: f64,
    /// Log-normal latency spread σ for the WAN / long-tail presets.
    pub latency_sigma: f64,
    /// Straggler-multiplier spread σ for the long-tail preset.
    pub straggler_sigma: f64,
    /// Racks per pod for the hierarchical preset.
    pub racks_per_pod: usize,
    /// Pod-tier (rack-to-rack within a pod) latency (s) for `hier`.
    pub pod_latency: f64,
    /// Pod-tier bandwidth (bytes/s) for `hier`.
    pub pod_bandwidth: f64,
}

impl Default for NetTopoConfig {
    fn default() -> NetTopoConfig {
        NetTopoConfig {
            preset: NetPreset::SingleSwitchLan,
            regions: 3,
            intra_latency: 1e-3,
            inter_latency: 80e-3,
            intra_bandwidth: 1.25e9, // 10 Gb/s
            inter_bandwidth: 1.25e7, // 100 Mb/s
            latency_sigma: 0.6,
            straggler_sigma: 0.5,
            racks_per_pod: 2,
            pod_latency: 5e-3,
            pod_bandwidth: 1.25e8, // 1 Gb/s
        }
    }
}

impl NetTopoConfig {
    /// Materialize a [`Topology`] over `world` nodes. `seed` only affects
    /// the long-tail preset's deterministic straggler draws.
    pub fn build(&self, world: usize, seed: u64) -> Topology {
        match self.preset {
            NetPreset::SingleSwitchLan => Topology::single_switch(
                world,
                Link::new(LatencyModel::Constant(self.intra_latency), self.intra_bandwidth),
            ),
            NetPreset::MultiRegionWan => {
                let r = self.regions.clamp(1, world.max(1));
                let base = world / r;
                let rem = world % r;
                let sizes: Vec<usize> =
                    (0..r).map(|i| base + usize::from(i < rem)).collect();
                let intra = Link::new(
                    LatencyModel::LogNormal {
                        mu: self.intra_latency.ln(),
                        sigma: self.latency_sigma,
                    },
                    self.intra_bandwidth,
                );
                let inter = Link::new(
                    LatencyModel::LogNormal {
                        mu: self.inter_latency.ln(),
                        sigma: self.latency_sigma,
                    },
                    self.inter_bandwidth,
                );
                Topology::multi_region(&sizes, intra, inter)
            }
            NetPreset::LongTailInternet => Topology::long_tail(
                world,
                self.inter_latency.ln(),
                self.latency_sigma,
                self.inter_bandwidth,
                self.straggler_sigma,
                seed,
            ),
            NetPreset::HierarchicalDc => Topology::hierarchical(
                world,
                self.regions.max(1),
                self.racks_per_pod.max(1),
                Link::new(LatencyModel::Constant(self.intra_latency), self.intra_bandwidth),
                Link::new(LatencyModel::Constant(self.pod_latency), self.pod_bandwidth),
                Link::new(
                    LatencyModel::LogNormal {
                        mu: self.inter_latency.ln(),
                        sigma: self.latency_sigma,
                    },
                    self.inter_bandwidth,
                ),
            ),
        }
    }
}

/// Outer-optimizer hyper-parameters (§3.2, §4).
#[derive(Clone, Debug, PartialEq)]
pub struct OuterConfig {
    /// Training method.
    pub method: Method,
    /// Nesterov momentum α (paper: 0.3 DiLoCo, 0.5 NoLoCo).
    pub alpha: f64,
    /// Outer learning rate β (paper: 0.7 for both).
    pub beta: f64,
    /// NoLoCo weight-consensus coefficient γ (Eq. 2). Must satisfy the
    /// Eq. 74 stability window; see [`OuterConfig::gamma_window`].
    pub gamma: f64,
    /// Gossip group size n (paper uses the minimum, 2).
    pub group: usize,
    /// Inner steps per outer step m (paper: 100 DiLoCo, 50 NoLoCo).
    pub inner_steps: usize,
    /// Bounded-staleness admission window for the outer gossip, in
    /// boundaries. `1` (the default) is the lockstep contract — only
    /// state offered at the current boundary folds, through the existing
    /// gated / streaming code paths bit-for-bit. `S > 1` selects the
    /// asynchronous boundary engine
    /// ([`AsyncGossipSync`](crate::train::AsyncGossipSync)): peer state
    /// up to `S − 1` boundaries old is admitted with an age-decayed
    /// weight instead of excluded, so a lagging replica keeps mixing
    /// instead of stalling its partners. NoLoCo only.
    pub staleness: usize,
}

impl OuterConfig {
    /// The (exclusive) stability window for γ from Eq. 74:
    /// `sqrt(n/(2(n-1))) α < γ < sqrt(n/(2(n-1)) (2+α²))`.
    pub fn gamma_window(alpha: f64, group: usize) -> (f64, f64) {
        let n = group as f64;
        let c = n / (2.0 * (n - 1.0));
        (c.sqrt() * alpha, (c * (2.0 + alpha * alpha)).sqrt())
    }

    /// Midpoint of the γ window — a safe default when unspecified.
    pub fn default_gamma(alpha: f64, group: usize) -> f64 {
        let (lo, hi) = Self::gamma_window(alpha, group);
        0.5 * (lo + hi)
    }

    /// Validate hyper-parameters; returns a human-readable complaint.
    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..1.0).contains(&self.alpha) {
            return Err(format!("alpha must be in [0,1), got {}", self.alpha));
        }
        if self.beta <= self.alpha {
            return Err(format!(
                "convergence requires beta > alpha (App. A.2), got beta={} alpha={}",
                self.beta, self.alpha
            ));
        }
        if self.method == Method::NoLoCo {
            if self.group < 2 {
                return Err("NoLoCo group size must be >= 2".into());
            }
            let (lo, hi) = Self::gamma_window(self.alpha, self.group);
            if self.gamma <= lo || self.gamma >= hi {
                return Err(format!(
                    "gamma={} outside Eq. 74 stability window ({lo:.4}, {hi:.4})",
                    self.gamma
                ));
            }
        }
        if self.inner_steps == 0 {
            return Err("inner_steps must be >= 1".into());
        }
        if self.staleness == 0 {
            return Err("outer.staleness must be >= 1 (1 = lockstep boundary)".into());
        }
        if self.staleness > 1 && self.method != Method::NoLoCo {
            return Err(format!(
                "outer.staleness > 1 needs NoLoCo's gossip: {} synchronizes through a \
                 blocking collective with no stale form",
                self.method
            ));
        }
        Ok(())
    }
}

/// Heartbeat-based failure *detection* knobs (the `[churn]` TOML
/// section). With `detect` on, every replica announces liveness to its
/// stage-row peers at each outer boundary; a peer that misses `misses`
/// consecutive boundary heartbeats is suspected dead and removed through
/// the same [`ChurnResponse`](crate::train::ChurnResponse) repair
/// machinery a scheduled leave uses — and re-admitted (with the rejoin
/// adoption logic) when its heartbeats resume. NoLoCo only: collectives
/// have no live-subset form to repair into.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DetectConfig {
    /// Enable the detector (`churn.detect` / `--detect on`).
    pub enabled: bool,
    /// Consecutive missed boundary heartbeats before a peer is declared
    /// dead (`churn.misses` / `--detect-misses`).
    pub misses: usize,
}

impl Default for DetectConfig {
    fn default() -> DetectConfig {
        DetectConfig { enabled: false, misses: 2 }
    }
}

/// Journal verbosity for the [`obs`](crate::obs) subsystem
/// (`obs.trace_level` / `--trace-level`). Counters and the live metrics
/// snapshot always accumulate on an enabled hub; the level only gates
/// what the JSONL journal records.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TraceLevel {
    /// No journal events — counters and metrics snapshots only.
    Off,
    /// Boundary-granular events only (drops the per-step `inner` lines).
    Boundary,
    /// Everything, including one `inner` event per inner step.
    #[default]
    Step,
}

impl TraceLevel {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<TraceLevel> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "none" => Some(TraceLevel::Off),
            "boundary" => Some(TraceLevel::Boundary),
            "step" | "full" => Some(TraceLevel::Step),
            _ => None,
        }
    }
}

impl fmt::Display for TraceLevel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TraceLevel::Off => write!(f, "off"),
            TraceLevel::Boundary => write!(f, "boundary"),
            TraceLevel::Step => write!(f, "step"),
        }
    }
}

/// Observability sinks (the `[obs]` TOML section / `--trace-out`,
/// `--metrics-out`, `--trace-level` CLI flags). Both sinks default off;
/// with neither set the hub is fully disabled and the training path pays
/// one branch per event site.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ObsConfig {
    /// JSONL run-journal path (`obs.trace_out` / `--trace-out`).
    pub trace_out: Option<String>,
    /// Live metrics snapshot path, atomically rewritten every boundary
    /// (`obs.metrics_out` / `--metrics-out`).
    pub metrics_out: Option<String>,
    /// Journal verbosity (`obs.trace_level` / `--trace-level`).
    pub trace_level: TraceLevel,
}

impl ObsConfig {
    /// Whether any sink is configured (the hub is disabled otherwise).
    pub fn enabled(&self) -> bool {
        self.trace_out.is_some() || self.metrics_out.is_some()
    }
}

/// Crash-recovery knobs (the `[ckpt]` TOML section / `--ckpt-out`,
/// `--ckpt-every`, `--resume` CLI flags). Checkpoints cut at outer
/// boundaries — after the fold and any eval of the closing step — so a
/// resumed run replays the exact trajectory suffix (losses and
/// communication accounting bit-for-bit; wall-clock excluded).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CkptConfig {
    /// Checkpoint file path (`ckpt.out` / `--ckpt-out`). Written
    /// atomically (tmp + rename); each write replaces the previous one.
    pub out: Option<String>,
    /// Auto-checkpoint cadence in *outer boundaries* (`ckpt.every` /
    /// `--ckpt-every`; 0 = never). A value of `k` snapshots every `k`-th
    /// boundary.
    pub every: usize,
    /// Resume from this checkpoint file before training
    /// (`ckpt.resume` / `--resume`).
    pub resume: Option<String>,
}

impl CkptConfig {
    /// Whether the periodic writer is armed (both a path and a cadence).
    pub fn armed(&self) -> bool {
        self.out.is_some() && self.every > 0
    }
}

/// Fault-injection knobs for the threaded executor's in-process fabric
/// (the `[faults]` TOML section / `--fault-*` CLI flags). All
/// probabilities are per-message and drawn from a deterministic
/// per-receiver RNG seeded off `train.seed`, so a faulty run is exactly
/// reproducible. The grid executor's mailbox is lossless; these knobs
/// only apply to `--executor threads`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultsConfig {
    /// Probability a message is silently dropped (`faults.drop`).
    pub drop: f64,
    /// Probability a message is delivered twice (`faults.dup`).
    pub dup: f64,
    /// Probability a message is held back `delay_secs` before delivery
    /// (`faults.delay`).
    pub delay: f64,
    /// Hold-back duration in seconds for delayed messages
    /// (`faults.delay_secs`).
    pub delay_secs: f64,
    /// Probability a message is swapped behind its successor
    /// (`faults.reorder`).
    pub reorder: f64,
    /// Probability a message's payload is bit-flipped in flight; CRC
    /// framing detects and drops it on receive, counted per rank
    /// (`faults.corrupt`).
    pub corrupt: f64,
}

impl FaultsConfig {
    /// Whether any fault is configured.
    pub fn any(&self) -> bool {
        self.drop > 0.0
            || self.dup > 0.0
            || self.delay > 0.0
            || self.reorder > 0.0
            || self.corrupt > 0.0
    }

    /// Lower into the fabric's [`FaultPlan`](crate::net::FaultPlan).
    pub fn plan(&self) -> crate::net::FaultPlan {
        crate::net::FaultPlan {
            drop_prob: self.drop,
            dup_prob: self.dup,
            delay_prob: self.delay,
            delay_secs: self.delay_secs,
            reorder_prob: self.reorder,
            corrupt_prob: self.corrupt,
        }
    }
}

/// Host-side performance knobs (the `[perf]` TOML section / `--threads`
/// CLI flag). Thread count is a *throughput* knob, never a determinism
/// input: the grid executor's parallel inner walk dispatches replicas to
/// a worker pool but applies every result in the exact serial order, so
/// any thread count reproduces the single-thread trajectory bit-for-bit
/// (pinned by the parallel-equivalence golden tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PerfConfig {
    /// Worker threads for the grid executor's inner phase
    /// (`perf.threads` / `--threads`). `1` = serial walk (the default);
    /// `0` = auto-detect from the machine's available parallelism
    /// (resolved inside the pool, where the analyzer's R1 allowance for
    /// ambient machine inputs is scoped). Only the `pp = 1` data-parallel
    /// regime fans out — pipeline routing crosses DP columns mid-step, so
    /// deeper grids always take the serial walk.
    pub threads: usize,
}

impl Default for PerfConfig {
    fn default() -> PerfConfig {
        PerfConfig { threads: 1 }
    }
}

impl PerfConfig {
    /// Whether the parallel inner walk is requested (auto counts: `0`
    /// resolves to the machine width, which may still be 1).
    pub fn parallel_requested(&self) -> bool {
        self.threads != 1
    }
}

/// Which channel carries inter-rank traffic on the real executors.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process message fabric: one OS thread per rank, one shared
    /// address space (`--executor threads`, the seed behaviour).
    #[default]
    Threads,
    /// Real TCP sockets: one OS *process* per rank, joined through the
    /// seed-node protocol (`noloco run --transport socket`).
    Socket,
}

impl TransportKind {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s.to_ascii_lowercase().as_str() {
            "threads" | "threaded" | "fabric" => Some(TransportKind::Threads),
            "socket" | "tcp" => Some(TransportKind::Socket),
            _ => None,
        }
    }
}

impl fmt::Display for TransportKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportKind::Threads => write!(f, "threads"),
            TransportKind::Socket => write!(f, "socket"),
        }
    }
}

/// Socket-transport knobs (the `[transport]` TOML section /
/// `--transport`, `--seed-addr`, `--rank`, `--bind`, `--report-out` CLI
/// flags). Only the `run` subcommand reads these: each OS process runs
/// one rank, rank 0 listens at `seed_addr`, and every other rank dials
/// it to join (receiving the live peer address book in the welcome).
#[derive(Clone, Debug, PartialEq)]
pub struct TransportConfig {
    /// Which transport carries inter-rank traffic (`transport.kind`).
    pub kind: TransportKind,
    /// Seed-node address every joiner dials (`transport.seed_addr`).
    /// Rank 0 listens here; the port must be free on rank 0's host.
    pub seed_addr: String,
    /// This process's rank in `0..dp·pp` (`transport.rank` / `--rank`).
    pub rank: usize,
    /// Listener bind address for this rank (`transport.bind`; default an
    /// ephemeral loopback port — set a routable address on a real WAN).
    pub bind: String,
    /// Where to write this rank's [`RankReport`](crate::train::RankReport)
    /// text (`transport.report_out`); stdout when unset.
    pub report_out: Option<String>,
}

impl Default for TransportConfig {
    fn default() -> TransportConfig {
        TransportConfig {
            kind: TransportKind::Threads,
            seed_addr: "127.0.0.1:29400".to_string(),
            rank: 0,
            bind: "127.0.0.1:0".to_string(),
            report_out: None,
        }
    }
}

/// Synthetic corpus flavour (dataset substitution; see DESIGN.md §4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataset {
    /// Narrow-topic Zipf stream standing in for Pushshift Reddit.
    RedditLike,
    /// Broader mixture-of-topics stream standing in for C4.
    C4Like,
}

impl Dataset {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<Dataset> {
        match s.to_ascii_lowercase().as_str() {
            "reddit" | "reddit-like" | "pushshift" => Some(Dataset::RedditLike),
            "c4" | "c4-like" => Some(Dataset::C4Like),
            _ => None,
        }
    }
}

impl fmt::Display for Dataset {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Dataset::RedditLike => write!(f, "reddit"),
            Dataset::C4Like => write!(f, "c4"),
        }
    }
}

/// Top-level run configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub model: ModelConfig,
    pub topology: TopologyConfig,
    pub outer: OuterConfig,
    pub dataset: Dataset,
    /// Total inner optimizer steps.
    pub steps: usize,
    /// Linear LR warm-up steps.
    pub warmup: usize,
    /// Cosine decay floor as a fraction of peak LR (paper: one magnitude,
    /// i.e. 0.1).
    pub lr_floor: f64,
    /// Gradient clip threshold (paper: 1.0).
    pub grad_clip: f64,
    /// Validation cadence in inner steps (0 = only at end).
    pub eval_every: usize,
    /// Tokens per validation pass.
    pub eval_tokens: usize,
    /// Base RNG seed.
    pub seed: u64,
    /// Pipeline routing flavour.
    pub routing: Routing,
    /// Directory holding compiled HLO artifacts.
    pub artifacts_dir: String,
    /// Simulated-network shape for the latency / WAN analyses.
    pub net: NetTopoConfig,
    /// Deterministic membership schedule over the DP replicas (elastic
    /// training; the node index of each event is a DP replica).
    pub churn: ChurnSchedule,
    /// NoLoCo gossip-pair drawing policy (ignored by FSDP / DiLoCo).
    pub pairing: PairingMode,
    /// Outer-sync scheduling: gated (the seed behaviour) or streaming
    /// fragmented overlap.
    pub sync: SyncMode,
    /// Fragment count / overlap shape for [`SyncMode::Streaming`] (the
    /// bounded-staleness engine reuses `fragments` for its per-fragment
    /// pairing form).
    pub stream: StreamConfig,
    /// Heartbeat failure-detection knobs (the `[churn]` section).
    pub detect: DetectConfig,
    /// Observability sinks (the `[obs]` section): run journal, live
    /// metrics snapshot, journal verbosity.
    pub obs: ObsConfig,
    /// Crash recovery (the `[ckpt]` section): periodic full-fidelity
    /// checkpoints and resume.
    pub ckpt: CkptConfig,
    /// Fault injection for the threaded executor's fabric (the
    /// `[faults]` section).
    pub faults: FaultsConfig,
    /// Socket-transport knobs for the process-per-rank executor (the
    /// `[transport]` section; only the `run` subcommand reads these).
    pub transport: TransportConfig,
    /// Host-side performance knobs (the `[perf]` section): inner-phase
    /// worker threads for the grid executor.
    pub perf: PerfConfig,
}

impl TrainConfig {
    /// Apply a parsed TOML document on top of this config. Unknown keys
    /// are an error — typos in experiment configs must not pass silently.
    pub fn apply_doc(&mut self, doc: &Doc) -> Result<(), String> {
        for (k, v) in doc.iter() {
            let ok = match k.as_str() {
                "model.hidden" => set_usize(&mut self.model.hidden, v),
                "model.layers" => set_usize(&mut self.model.layers, v),
                "model.intermediate" => set_usize(&mut self.model.intermediate, v),
                "model.heads" => set_usize(&mut self.model.heads, v),
                "model.vocab" => set_usize(&mut self.model.vocab, v),
                "model.seq_len" => set_usize(&mut self.model.seq_len, v),
                "model.inner_lr" => set_f64(&mut self.model.inner_lr, v),
                "model.batch_tokens" => set_usize(&mut self.model.batch_tokens, v),
                "model.name" => set_string(&mut self.model.name, v),
                "topology.dp" => set_usize(&mut self.topology.dp, v),
                "topology.pp" => set_usize(&mut self.topology.pp, v),
                "topology.net" => match v.as_str().and_then(NetPreset::parse) {
                    Some(p) => {
                        self.net.preset = p;
                        true
                    }
                    None => false,
                },
                "topology.regions" => set_usize(&mut self.net.regions, v),
                "topology.intra_latency" => set_f64(&mut self.net.intra_latency, v),
                "topology.inter_latency" => set_f64(&mut self.net.inter_latency, v),
                "topology.intra_bandwidth" => set_f64(&mut self.net.intra_bandwidth, v),
                "topology.inter_bandwidth" => set_f64(&mut self.net.inter_bandwidth, v),
                "topology.latency_sigma" => set_f64(&mut self.net.latency_sigma, v),
                "topology.straggler_sigma" => set_f64(&mut self.net.straggler_sigma, v),
                "topology.racks_per_pod" => set_usize(&mut self.net.racks_per_pod, v),
                "topology.pod_latency" => set_f64(&mut self.net.pod_latency, v),
                "topology.pod_bandwidth" => set_f64(&mut self.net.pod_bandwidth, v),
                "topology.churn" => match churn_from_value(v) {
                    Some(s) => {
                        self.churn = s;
                        true
                    }
                    None => false,
                },
                "outer.method" => match v.as_str().and_then(Method::parse) {
                    Some(m) => {
                        self.outer.method = m;
                        true
                    }
                    None => false,
                },
                "outer.pairing" => match v.as_str().and_then(PairingMode::parse) {
                    Some(p) => {
                        self.pairing = p;
                        true
                    }
                    None => false,
                },
                "outer.sync" => match v.as_str().and_then(SyncMode::parse) {
                    Some(s) => {
                        self.sync = s;
                        true
                    }
                    None => false,
                },
                "outer.fragments" => set_usize(&mut self.stream.fragments, v),
                "outer.overlap" => set_bool(&mut self.stream.overlap, v),
                "outer.stash_age" => set_usize(&mut self.stream.stash_age, v),
                "outer.staleness" => set_usize(&mut self.outer.staleness, v),
                "churn.detect" => set_bool(&mut self.detect.enabled, v),
                "churn.misses" => set_usize(&mut self.detect.misses, v),
                "obs.trace_out" => set_opt_string(&mut self.obs.trace_out, v),
                "obs.metrics_out" => set_opt_string(&mut self.obs.metrics_out, v),
                "ckpt.out" => set_opt_string(&mut self.ckpt.out, v),
                "ckpt.every" => set_usize(&mut self.ckpt.every, v),
                "ckpt.resume" => set_opt_string(&mut self.ckpt.resume, v),
                "faults.drop" => set_f64(&mut self.faults.drop, v),
                "faults.dup" => set_f64(&mut self.faults.dup, v),
                "faults.delay" => set_f64(&mut self.faults.delay, v),
                "faults.delay_secs" => set_f64(&mut self.faults.delay_secs, v),
                "faults.reorder" => set_f64(&mut self.faults.reorder, v),
                "faults.corrupt" => set_f64(&mut self.faults.corrupt, v),
                "transport.kind" => match v.as_str().and_then(TransportKind::parse) {
                    Some(t) => {
                        self.transport.kind = t;
                        true
                    }
                    None => false,
                },
                "transport.seed_addr" => set_string(&mut self.transport.seed_addr, v),
                "transport.rank" => set_usize(&mut self.transport.rank, v),
                "transport.bind" => set_string(&mut self.transport.bind, v),
                "transport.report_out" => set_opt_string(&mut self.transport.report_out, v),
                "perf.threads" => set_usize(&mut self.perf.threads, v),
                "obs.trace_level" => match v.as_str().and_then(TraceLevel::parse) {
                    Some(l) => {
                        self.obs.trace_level = l;
                        true
                    }
                    None => false,
                },
                "outer.alpha" => set_f64(&mut self.outer.alpha, v),
                "outer.beta" => set_f64(&mut self.outer.beta, v),
                "outer.gamma" => set_f64(&mut self.outer.gamma, v),
                "outer.group" => set_usize(&mut self.outer.group, v),
                "outer.inner_steps" => set_usize(&mut self.outer.inner_steps, v),
                "train.steps" => set_usize(&mut self.steps, v),
                "train.warmup" => set_usize(&mut self.warmup, v),
                "train.lr_floor" => set_f64(&mut self.lr_floor, v),
                "train.grad_clip" => set_f64(&mut self.grad_clip, v),
                "train.eval_every" => set_usize(&mut self.eval_every, v),
                "train.eval_tokens" => set_usize(&mut self.eval_tokens, v),
                "train.seed" => match v.as_int() {
                    Some(i) => {
                        self.seed = i as u64;
                        true
                    }
                    None => false,
                },
                "train.dataset" => match v.as_str().and_then(Dataset::parse) {
                    Some(d) => {
                        self.dataset = d;
                        true
                    }
                    None => false,
                },
                "train.routing" => match v.as_str().and_then(Routing::parse) {
                    Some(r) => {
                        self.routing = r;
                        true
                    }
                    None => false,
                },
                "train.artifacts_dir" => set_string(&mut self.artifacts_dir, v),
                _ => return Err(format!("unknown config key `{k}`")),
            };
            if !ok {
                return Err(format!("bad value for `{k}`: {v:?}"));
            }
        }
        Ok(())
    }

    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<(), String> {
        self.outer.validate()?;
        if self.model.hidden % self.model.heads != 0 {
            return Err("hidden must be divisible by heads".into());
        }
        if self.model.layers % self.topology.pp != 0 {
            return Err(format!(
                "layers ({}) must divide evenly into pp ({}) stages",
                self.model.layers, self.topology.pp
            ));
        }
        if self.topology.dp == 0 || self.topology.pp == 0 {
            return Err("dp and pp must be >= 1".into());
        }
        if self.outer.method == Method::NoLoCo && self.topology.dp < 2 {
            return Err("NoLoCo needs dp >= 2 to form gossip pairs".into());
        }
        for &(step, event) in self.churn.events() {
            if event.node() >= self.topology.dp {
                return Err(format!(
                    "churn event at step {step} names replica {} but dp = {}",
                    event.node(),
                    self.topology.dp
                ));
            }
        }
        if self.sync == SyncMode::Streaming {
            if self.outer.method == Method::Fsdp {
                return Err(
                    "streaming sync needs an outer method (diloco|noloco); \
                     FSDP has no (Δ, φ) state to stream"
                        .into(),
                );
            }
            if self.stream.fragments == 0 || self.stream.fragments > 256 {
                return Err(format!(
                    "outer.fragments must be in 1..=256, got {}",
                    self.stream.fragments
                ));
            }
        }
        if self.outer.staleness > 1 {
            // Either sync mode is fine here: staleness > 1 selects the
            // async boundary engine, which owns the overlap — `gated`
            // and `streaming` collapse to the same bounded-staleness
            // schedule (streaming's one-boundary overlap is the
            // staleness = 1 special case of the same window).
            if self.stream.fragments == 0 || self.stream.fragments > 256 {
                return Err(format!(
                    "outer.fragments must be in 1..=256 for per-fragment async gossip, got {}",
                    self.stream.fragments
                ));
            }
        }
        if self.stream.stash_age > 0 && self.stream.stash_age < self.outer.staleness {
            return Err(format!(
                "outer.stash_age ({}) must cover outer.staleness ({}): the sweep would \
                 expire rounds the admission window still accepts",
                self.stream.stash_age, self.outer.staleness
            ));
        }
        if self.outer.staleness > 1 && self.stream.stash_age == 0 {
            return Err(
                "outer.staleness > 1 needs outer.stash_age > 0: async offers stay \
                 readable for the whole admission window, so only the expiry sweep \
                 bounds the retention buffers"
                    .into(),
            );
        }
        if self.detect.enabled {
            if self.outer.method != Method::NoLoCo {
                return Err(format!(
                    "churn.detect needs NoLoCo's repairable gossip; {} aborts on any \
                     membership change",
                    self.outer.method
                ));
            }
            if self.detect.misses < 2 {
                return Err(
                    "churn.misses must be >= 2: workers heartbeat at boundary granularity \
                     and run concurrently, so one boundary of skew is the healthy steady \
                     state on the threaded executor — misses = 1 would flap live peers"
                        .into(),
                );
            }
            if self.stream.stash_age > 0 && self.stream.stash_age < self.detect.misses {
                return Err(format!(
                    "outer.stash_age ({}) must cover churn.misses ({}): the sweep would \
                     expire heartbeats still inside the detection tolerance",
                    self.stream.stash_age, self.detect.misses
                ));
            }
        }
        if self.net.preset == NetPreset::HierarchicalDc && self.net.racks_per_pod == 0 {
            return Err("topology.racks_per_pod must be >= 1".into());
        }
        for (name, p) in [
            ("faults.drop", self.faults.drop),
            ("faults.dup", self.faults.dup),
            ("faults.delay", self.faults.delay),
            ("faults.reorder", self.faults.reorder),
            ("faults.corrupt", self.faults.corrupt),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("{name} must be a probability in [0, 1], got {p}"));
            }
        }
        if self.faults.delay_secs < 0.0 {
            return Err(format!(
                "faults.delay_secs must be >= 0, got {}",
                self.faults.delay_secs
            ));
        }
        if self.transport.kind == TransportKind::Socket {
            if self.transport.seed_addr.is_empty() {
                return Err("transport.seed_addr must name the seed node (host:port)".into());
            }
            if self.transport.rank >= self.topology.world() {
                return Err(format!(
                    "transport.rank ({}) outside the {}-rank world (dp·pp = {}·{})",
                    self.transport.rank,
                    self.topology.world(),
                    self.topology.dp,
                    self.topology.pp
                ));
            }
        }
        if self.perf.threads > 4096 {
            return Err(format!(
                "perf.threads ({}) is implausibly large; use 0 to auto-detect \
                 the machine's parallelism",
                self.perf.threads
            ));
        }
        if self.ckpt.out.is_some() && self.ckpt.every == 0 {
            return Err(
                "ckpt.out is set but ckpt.every = 0: the periodic writer never fires; \
                 set a boundary cadence (ckpt.every >= 1)"
                    .into(),
            );
        }
        Ok(())
    }
}

/// Parse `topology.churn`: either one `"leave:STEP:NODE;…"` string or an
/// array of per-event strings.
fn churn_from_value(v: &toml::Value) -> Option<ChurnSchedule> {
    match v {
        toml::Value::Str(s) => ChurnSchedule::parse(s).ok(),
        toml::Value::Array(items) => {
            let mut out = ChurnSchedule::none();
            for it in items {
                let (step, e) = ChurnSchedule::parse_event(it.as_str()?).ok()?;
                out.push(step, e);
            }
            Some(out)
        }
        _ => None,
    }
}

fn set_usize(slot: &mut usize, v: &toml::Value) -> bool {
    match v.as_int() {
        Some(i) if i >= 0 => {
            *slot = i as usize;
            true
        }
        _ => false,
    }
}

fn set_f64(slot: &mut f64, v: &toml::Value) -> bool {
    match v.as_float() {
        Some(f) => {
            *slot = f;
            true
        }
        None => false,
    }
}

fn set_bool(slot: &mut bool, v: &toml::Value) -> bool {
    match v.as_bool() {
        Some(b) => {
            *slot = b;
            true
        }
        None => false,
    }
}

fn set_string(slot: &mut String, v: &toml::Value) -> bool {
    match v.as_str() {
        Some(s) => {
            *slot = s.to_string();
            true
        }
        None => false,
    }
}

fn set_opt_string(slot: &mut Option<String>, v: &toml::Value) -> bool {
    match v.as_str() {
        Some("") => {
            *slot = None;
            true
        }
        Some(s) => {
            *slot = Some(s.to_string());
            true
        }
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gamma_window_matches_eq74_for_n2() {
        // n=2: sqrt(1) * alpha < gamma < sqrt(2 + alpha^2).
        let (lo, hi) = OuterConfig::gamma_window(0.5, 2);
        assert!((lo - 0.5).abs() < 1e-12);
        assert!((hi - (2.0f64 + 0.25).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn validate_rejects_beta_leq_alpha() {
        let mut o = presets::preset("tiny").unwrap().outer;
        o.beta = o.alpha;
        assert!(o.validate().is_err());
    }

    #[test]
    fn validate_rejects_gamma_outside_window() {
        let mut o = presets::preset("tiny").unwrap().outer;
        o.method = Method::NoLoCo;
        o.gamma = 0.0;
        assert!(o.validate().is_err());
        o.gamma = 10.0;
        assert!(o.validate().is_err());
        o.gamma = OuterConfig::default_gamma(o.alpha, o.group);
        assert!(o.validate().is_ok());
    }

    #[test]
    fn apply_doc_overrides_and_rejects_unknown() {
        let mut c = presets::preset("tiny").unwrap();
        let doc = Doc::parse("[model]\nhidden = 128\n[outer]\nmethod = \"diloco\"\n").unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.model.hidden, 128);
        assert_eq!(c.outer.method, Method::DiLoCo);
        let bad = Doc::parse("[model]\nhiden = 128\n").unwrap();
        assert!(c.apply_doc(&bad).unwrap_err().contains("unknown config key"));
    }

    #[test]
    fn method_and_dataset_parse() {
        assert_eq!(Method::parse("NoLoCo"), Some(Method::NoLoCo));
        assert_eq!(Method::parse("fsdp"), Some(Method::Fsdp));
        assert_eq!(Method::parse("bogus"), None);
        assert_eq!(Dataset::parse("c4"), Some(Dataset::C4Like));
        assert_eq!(Dataset::parse("reddit"), Some(Dataset::RedditLike));
    }

    #[test]
    fn validate_layer_stage_divisibility() {
        let mut c = presets::preset("tiny").unwrap();
        c.topology.pp = 3; // tiny has 4 layers
        assert!(c.validate().is_err());
        c.topology.pp = 2;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn topology_section_configures_net_and_churn() {
        let mut c = presets::preset("tiny").unwrap();
        let doc = Doc::parse(
            "[topology]\n\
             net = \"wan\"\n\
             regions = 4\n\
             inter_latency = 0.2\n\
             inter_bandwidth = 1000000.0\n\
             churn = [\"leave:30:1\", \"join:45:1\"]\n",
        )
        .unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.net.preset, NetPreset::MultiRegionWan);
        assert_eq!(c.net.regions, 4);
        assert!((c.net.inter_latency - 0.2).abs() < 1e-12);
        assert_eq!(c.churn.events().len(), 2);
        c.validate().unwrap(); // replica 1 exists at dp = 2
        // Churn naming a replica outside the grid must be rejected.
        let bad = Doc::parse("[topology]\nchurn = \"leave:3:9\"\n").unwrap();
        c.apply_doc(&bad).unwrap();
        assert!(c.validate().unwrap_err().contains("churn"));
    }

    #[test]
    fn net_presets_build_expected_shapes() {
        let mut n = NetTopoConfig::default();
        let lan = n.build(8, 0);
        assert_eq!(lan.regions(), 1);
        assert_eq!(lan.world(), 8);
        n.preset = NetPreset::MultiRegionWan;
        n.regions = 3;
        let wan = n.build(8, 0);
        assert_eq!(wan.regions(), 3);
        assert_eq!(wan.world(), 8);
        // 8 over 3 regions: 3 + 3 + 2.
        let counts: Vec<usize> = (0..3)
            .map(|r| (0..8).filter(|&node| wan.region_of(node) == r).count())
            .collect();
        assert_eq!(counts, vec![3, 3, 2]);
        // Inter-region links are slower in expectation than intra.
        assert!(wan.expected_transfer(0, 7, 0) > wan.expected_transfer(0, 1, 0));
        n.preset = NetPreset::LongTailInternet;
        let tail = n.build(8, 42);
        assert_eq!(tail.regions(), 1);
        assert!((0..8).all(|i| tail.straggler_of(i) >= 1.0));
        assert_eq!(NetPreset::parse("long-tail"), Some(NetPreset::LongTailInternet));
        assert_eq!(NetPreset::parse("nope"), None);
    }

    #[test]
    fn pairing_mode_parses_and_plumbs() {
        assert_eq!(PairingMode::parse("uniform"), Some(PairingMode::Uniform));
        assert_eq!(
            PairingMode::parse("Bandwidth-Aware"),
            Some(PairingMode::BandwidthAware)
        );
        assert_eq!(PairingMode::parse("nearest"), None);
        let mut c = presets::preset("tiny").unwrap();
        assert_eq!(c.pairing, PairingMode::Uniform);
        let doc = Doc::parse("[outer]\npairing = \"bandwidth-aware\"\n").unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.pairing, PairingMode::BandwidthAware);
        c.validate().unwrap();
    }

    #[test]
    fn sync_mode_parses_and_plumbs() {
        assert_eq!(SyncMode::parse("streaming"), Some(SyncMode::Streaming));
        assert_eq!(SyncMode::parse("Gated"), Some(SyncMode::Gated));
        assert_eq!(SyncMode::parse("overlapped"), None);
        let mut c = presets::preset("tiny").unwrap();
        assert_eq!(c.sync, SyncMode::Gated);
        let doc = Doc::parse(
            "[outer]\nsync = \"streaming\"\nfragments = 8\noverlap = false\n",
        )
        .unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.sync, SyncMode::Streaming);
        assert_eq!(c.stream.fragments, 8);
        assert!(!c.stream.overlap);
        c.validate().unwrap();
    }

    #[test]
    fn streaming_validation_rejects_fsdp_and_bad_fragment_counts() {
        let mut c = presets::preset("tiny").unwrap();
        c.sync = SyncMode::Streaming;
        c.validate().unwrap();
        c.stream.fragments = 0;
        assert!(c.validate().unwrap_err().contains("fragments"));
        c.stream.fragments = 500;
        assert!(c.validate().unwrap_err().contains("fragments"));
        c.stream.fragments = 4;
        c = presets::as_fsdp(c);
        c.sync = SyncMode::Streaming;
        assert!(c.validate().unwrap_err().contains("streaming"));
        // Gated FSDP stays valid — the streaming restriction is scoped.
        c.sync = SyncMode::Gated;
        c.validate().unwrap();
    }

    #[test]
    fn staleness_parses_and_validates() {
        let mut c = presets::preset("tiny").unwrap();
        assert_eq!(c.outer.staleness, 1);
        let doc = Doc::parse("[outer]\nstaleness = 3\n").unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.outer.staleness, 3);
        c.validate().unwrap();
        // Zero is rejected; staleness > 1 needs NoLoCo but accepts both
        // sync modes (the async boundary engine owns the overlap either
        // way — streaming's one-boundary overlap is its staleness = 1
        // special case).
        c.outer.staleness = 0;
        assert!(c.validate().unwrap_err().contains("staleness"));
        c.outer.staleness = 2;
        c.sync = SyncMode::Streaming;
        c.validate().unwrap();
        c.sync = SyncMode::Gated;
        c.validate().unwrap();
        let mut d = presets::as_diloco(presets::preset("tiny").unwrap());
        d.outer.staleness = 2;
        assert!(d.validate().unwrap_err().contains("staleness"));
    }

    #[test]
    fn detect_knobs_parse_and_validate() {
        let mut c = presets::preset("tiny").unwrap();
        assert!(!c.detect.enabled);
        assert_eq!(c.detect.misses, 2);
        let doc = Doc::parse("[churn]\ndetect = true\nmisses = 3\n").unwrap();
        c.apply_doc(&doc).unwrap();
        assert!(c.detect.enabled);
        assert_eq!(c.detect.misses, 3);
        c.validate().unwrap();
        c.detect.misses = 0;
        assert!(c.validate().unwrap_err().contains("misses"));
        c.detect.misses = 1;
        assert!(c.validate().unwrap_err().contains("misses"), "one boundary of skew is healthy");
        c.detect.misses = 2;
        c = presets::as_diloco(c);
        assert!(c.validate().unwrap_err().contains("detect"));
    }

    #[test]
    fn obs_knobs_parse_and_validate() {
        let mut c = presets::preset("tiny").unwrap();
        assert_eq!(c.obs, ObsConfig::default());
        assert!(!c.obs.enabled());
        let doc = Doc::parse(
            "[obs]\ntrace_out = \"run.jsonl\"\nmetrics_out = \"live.json\"\n\
             trace_level = \"boundary\"\n",
        )
        .unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.obs.trace_out.as_deref(), Some("run.jsonl"));
        assert_eq!(c.obs.metrics_out.as_deref(), Some("live.json"));
        assert_eq!(c.obs.trace_level, TraceLevel::Boundary);
        assert!(c.obs.enabled());
        c.validate().unwrap();
        // Empty string clears a sink; bad levels are rejected.
        let doc = Doc::parse("[obs]\ntrace_out = \"\"\n").unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.obs.trace_out, None);
        let doc = Doc::parse("[obs]\ntrace_level = \"verbose\"\n").unwrap();
        assert!(c.apply_doc(&doc).is_err());
        assert_eq!(TraceLevel::parse("step"), Some(TraceLevel::Step));
        assert_eq!(TraceLevel::Off.to_string(), "off");
    }

    #[test]
    fn transport_knobs_parse_and_validate() {
        let mut c = presets::preset("tiny").unwrap();
        assert_eq!(c.transport, TransportConfig::default());
        let doc = Doc::parse(
            "[transport]\nkind = \"socket\"\nseed_addr = \"10.0.0.1:29500\"\n\
             rank = 1\nbind = \"0.0.0.0:0\"\nreport_out = \"rank1.report\"\n",
        )
        .unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.transport.kind, TransportKind::Socket);
        assert_eq!(c.transport.seed_addr, "10.0.0.1:29500");
        assert_eq!(c.transport.rank, 1);
        assert_eq!(c.transport.bind, "0.0.0.0:0");
        assert_eq!(c.transport.report_out.as_deref(), Some("rank1.report"));
        c.validate().unwrap();
        // Rank outside the dp·pp world is rejected; threads ignores it.
        c.transport.rank = 99;
        assert!(c.validate().unwrap_err().contains("transport.rank"));
        c.transport.kind = TransportKind::Threads;
        c.validate().unwrap();
        assert_eq!(TransportKind::parse("tcp"), Some(TransportKind::Socket));
        assert_eq!(TransportKind::parse("carrier-pigeon"), None);
        assert_eq!(TransportKind::Socket.to_string(), "socket");
    }

    #[test]
    fn per_fragment_pairing_parses() {
        assert_eq!(PairingMode::parse("per-fragment"), Some(PairingMode::PerFragment));
        assert_eq!(PairingMode::parse("Per-Frag"), Some(PairingMode::PerFragment));
        assert_eq!(format!("{}", PairingMode::PerFragment), "per-fragment");
        let mut c = presets::preset("tiny").unwrap();
        let doc = Doc::parse("[outer]\npairing = \"per-fragment\"\n").unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.pairing, PairingMode::PerFragment);
        c.validate().unwrap();
    }

    #[test]
    fn hier_preset_builds_three_tiers() {
        let n = NetTopoConfig {
            preset: NetPreset::HierarchicalDc,
            regions: 2,        // pods
            racks_per_pod: 2,  // -> 4 racks
            ..NetTopoConfig::default()
        };
        let t = n.build(8, 0);
        assert_eq!(t.world(), 8);
        assert_eq!(t.regions(), 4, "one topology region per rack");
        // Node layout is rack-major: nodes 0..2 rack 0, 2..4 rack 1, ...
        // Rack < pod < spine in expected transfer cost.
        let rack = t.expected_transfer(0, 1, 1 << 20); // same rack
        let pod = t.expected_transfer(0, 2, 1 << 20); // same pod, other rack
        let spine = t.expected_transfer(0, 4, 1 << 20); // other pod
        assert!(rack < pod, "rack tier must undercut pod tier: {rack} vs {pod}");
        assert!(pod < spine, "pod tier must undercut spine tier: {pod} vs {spine}");
        assert_eq!(NetPreset::parse("hier"), Some(NetPreset::HierarchicalDc));
        assert_eq!(format!("{}", NetPreset::HierarchicalDc), "hier");
        let bad = NetTopoConfig { racks_per_pod: 0, preset: NetPreset::HierarchicalDc, ..n };
        let mut c = presets::preset("tiny").unwrap();
        c.net = bad;
        assert!(c.validate().unwrap_err().contains("racks_per_pod"));
    }

    #[test]
    fn paper_param_counts_are_in_band() {
        // Table 1: 125M / 1.3B / 6.8B transformer parameters.
        let s = presets::preset("paper-small").unwrap().model;
        let m = presets::preset("paper-medium").unwrap().model;
        let l = presets::preset("paper-large").unwrap().model;
        // Table-1 labels are OPT-nominal; our SwiGLU MLP counts land in
        // the same band rather than matching exactly.
        let band = |got: usize, lo: f64, hi: f64| {
            let g = got as f64;
            g >= lo && g <= hi
        };
        assert!(band(s.transformer_params(), 90e6, 160e6), "{}", s.transformer_params());
        assert!(band(m.transformer_params(), 1.0e9, 1.8e9), "{}", m.transformer_params());
        assert!(band(l.transformer_params(), 5.4e9, 9.5e9), "{}", l.transformer_params());
    }
}
