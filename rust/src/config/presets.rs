//! Named configuration presets.
//!
//! `paper-small` / `paper-medium` / `paper-large` reproduce Table 1
//! verbatim (125M / 1.3B / 6.8B transformer parameters, OPT batch sizes
//! and learning rates). They exist so param-count math, config plumbing
//! and latency models run at paper scale; actually *training* them needs
//! the paper's cluster.
//!
//! `tiny` / `small` / `e2e` are the CPU-scaled presets this image trains
//! end-to-end (DESIGN.md §4 substitutions): same architecture family and
//! optimizer settings, smaller width/depth/vocab.

use super::{
    CkptConfig, Dataset, DetectConfig, FaultsConfig, Method, ModelConfig, NetTopoConfig,
    ObsConfig, OuterConfig, PairingMode, PerfConfig, Routing, StreamConfig, SyncMode,
    TopologyConfig, TrainConfig, TransportConfig,
};
use crate::net::topo::ChurnSchedule;

/// All preset names, for CLI help / validation.
pub const PRESET_NAMES: &[&str] = &[
    "tiny",
    "small",
    "e2e",
    "paper-small",
    "paper-medium",
    "paper-large",
];

fn base(model: ModelConfig, steps: usize, warmup: usize) -> TrainConfig {
    TrainConfig {
        model,
        topology: TopologyConfig { dp: 2, pp: 2 },
        outer: OuterConfig {
            method: Method::NoLoCo,
            alpha: 0.5,
            beta: 0.7,
            gamma: OuterConfig::default_gamma(0.5, 2),
            group: 2,
            inner_steps: 50,
            staleness: 1,
        },
        dataset: Dataset::RedditLike,
        steps,
        warmup,
        lr_floor: 0.1,
        grad_clip: 1.0,
        eval_every: 0,
        eval_tokens: 2048,
        seed: 0x0107c0,
        routing: Routing::Random,
        artifacts_dir: "artifacts".into(),
        net: NetTopoConfig::default(),
        churn: ChurnSchedule::none(),
        pairing: PairingMode::Uniform,
        sync: SyncMode::Gated,
        stream: StreamConfig::default(),
        detect: DetectConfig::default(),
        obs: ObsConfig::default(),
        ckpt: CkptConfig::default(),
        faults: FaultsConfig::default(),
        transport: TransportConfig::default(),
        perf: PerfConfig::default(),
    }
}

/// Look up a preset by name.
pub fn preset(name: &str) -> Option<TrainConfig> {
    let cfg = match name {
        // ---- CPU-scale presets (trained on this image) ----
        "tiny" => base(
            ModelConfig {
                name: "tiny".into(),
                hidden: 64,
                layers: 4,
                intermediate: 256,
                heads: 4,
                vocab: 512,
                seq_len: 64,
                inner_lr: 1e-3,
                batch_tokens: 4 * 64,
            },
            400,
            40,
        ),
        "small" => base(
            ModelConfig {
                name: "small".into(),
                hidden: 128,
                layers: 4,
                intermediate: 512,
                heads: 4,
                vocab: 1024,
                seq_len: 128,
                inner_lr: 6e-4,
                batch_tokens: 8 * 128,
            },
            600,
            60,
        ),
        "e2e" => base(
            ModelConfig {
                name: "e2e".into(),
                hidden: 256,
                layers: 8,
                intermediate: 1024,
                heads: 8,
                vocab: 4096,
                seq_len: 128,
                inner_lr: 3e-4,
                batch_tokens: 8 * 128,
            },
            300,
            50,
        ),
        // ---- Paper Table 1, verbatim ----
        "paper-small" => {
            let mut c = base(
                ModelConfig {
                    name: "paper-small".into(),
                    hidden: 768,
                    layers: 12,
                    intermediate: 3072,
                    heads: 16,
                    vocab: 128_000,
                    seq_len: 1024,
                    inner_lr: 6e-4,
                    batch_tokens: 500_000,
                },
                25_000,
                1000,
            );
            c.topology = TopologyConfig { dp: 8, pp: 1 };
            c
        }
        "paper-medium" => {
            let mut c = base(
                ModelConfig {
                    name: "paper-medium".into(),
                    hidden: 2048,
                    layers: 24,
                    intermediate: 8192,
                    heads: 32,
                    vocab: 128_000,
                    seq_len: 1024,
                    inner_lr: 2e-4,
                    batch_tokens: 1_000_000,
                },
                25_000,
                1000,
            );
            c.topology = TopologyConfig { dp: 8, pp: 2 };
            c
        }
        "paper-large" => {
            let mut c = base(
                ModelConfig {
                    name: "paper-large".into(),
                    hidden: 4096,
                    layers: 32,
                    intermediate: 16_384,
                    heads: 32,
                    vocab: 128_000,
                    seq_len: 1024,
                    inner_lr: 1.2e-4,
                    batch_tokens: 2_000_000,
                },
                25_000,
                1000,
            );
            c.topology = TopologyConfig { dp: 16, pp: 4 };
            c
        }
        _ => return None,
    };
    Some(cfg)
}

/// The DiLoCo variant of a preset: paper §4 uses α = 0.3 and outer steps
/// every 100 inner steps for DiLoCo (vs α = 0.5 / every 50 for NoLoCo).
pub fn as_diloco(mut cfg: TrainConfig) -> TrainConfig {
    cfg.outer.method = Method::DiLoCo;
    cfg.outer.alpha = 0.3;
    cfg.outer.inner_steps = 100.min(cfg.steps.max(1));
    cfg.outer.gamma = 0.0;
    cfg
}

/// The FSDP baseline variant: all-reduce every step, no outer optimizer.
pub fn as_fsdp(mut cfg: TrainConfig) -> TrainConfig {
    cfg.outer.method = Method::Fsdp;
    cfg.outer.inner_steps = 1;
    cfg.outer.gamma = 0.0;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_resolve_and_validate() {
        for name in PRESET_NAMES {
            let c = preset(name).unwrap_or_else(|| panic!("missing preset {name}"));
            c.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        assert!(preset("nope").is_none());
    }

    #[test]
    fn paper_presets_match_table1() {
        let m = preset("paper-medium").unwrap().model;
        assert_eq!(m.hidden, 2048);
        assert_eq!(m.layers, 24);
        assert_eq!(m.intermediate, 8192);
        assert_eq!(m.heads, 32);
        assert!((m.inner_lr - 2e-4).abs() < 1e-12);
        assert_eq!(m.batch_tokens, 1_000_000);
    }

    #[test]
    fn diloco_variant_uses_paper_hparams() {
        let d = as_diloco(preset("small").unwrap());
        assert_eq!(d.outer.method, Method::DiLoCo);
        assert!((d.outer.alpha - 0.3).abs() < 1e-12);
        assert_eq!(d.outer.inner_steps, 100);
        d.validate().unwrap();
    }

    #[test]
    fn fsdp_variant_syncs_every_step() {
        let f = as_fsdp(preset("tiny").unwrap());
        assert_eq!(f.outer.method, Method::Fsdp);
        assert_eq!(f.outer.inner_steps, 1);
        f.validate().unwrap();
    }

    #[test]
    fn scaled_presets_divide_cleanly() {
        for name in ["tiny", "small", "e2e"] {
            let c = preset(name).unwrap();
            assert_eq!(c.model.layers % c.topology.pp, 0);
            assert_eq!(c.model.hidden % c.model.heads, 0);
        }
    }
}
